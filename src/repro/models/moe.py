"""Mixture-of-Experts layer: top-k router + capacity-based scatter dispatch.

TPU adaptation notes (DESIGN.md §3/§6): instead of ragged grouped-GEMM
(GPU-style), tokens are scattered into a dense per-expert capacity buffer
(E, C, d) and experts run as one batched einsum — MXU friendly, and under
GSPMD with experts sharded over the `model` axis the scatter/gather lowers
to the expert-parallel all-to-all pattern. Overflowing tokens are dropped
(standard capacity-factor semantics); the router aux loss keeps load
balanced so drops stay rare.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.mlp import init_mlp, mlp_forward, _act


def init_moe(key, d_model: int, cfg: MoEConfig) -> Dict:
    kr, kg, ki, ko, ks = jax.random.split(key, 5)
    E, f = cfg.n_experts, cfg.d_ff_expert
    si = 1.0 / (d_model ** 0.5)
    so = 1.0 / (f ** 0.5)
    p = {
        "router": jax.random.normal(kr, (d_model, E), jnp.float32) * si,
        "wg": jax.random.normal(kg, (E, d_model, f), jnp.float32) * si,
        "wi": jax.random.normal(ki, (E, d_model, f), jnp.float32) * si,
        "wo": jax.random.normal(ko, (E, f, d_model), jnp.float32) * so,
    }
    if cfg.shared_expert_d_ff:
        p["shared"] = init_mlp(ks, d_model, cfg.shared_expert_d_ff)
    return p


def moe_capacity(n_tokens: int, cfg: MoEConfig, capacity_factor: float) -> int:
    c = int(n_tokens * cfg.top_k * capacity_factor / cfg.n_experts)
    # MXU-aligned capacity floor.
    return max(8, ((c + 7) // 8) * 8)


def moe_forward(
    p: Dict,
    x: jnp.ndarray,
    cfg: MoEConfig,
    act: str = "silu",
    capacity_factor: float | None = None,
) -> Tuple[jnp.ndarray, Dict]:
    """x: (B, S, d) -> (out, metrics). metrics carries the router aux loss.

    dispatch='batched' routes each batch row independently (vmapped), so
    the capacity buffer keeps the batch axis and shards over it — see
    MoEConfig.dispatch.
    """
    B, S, d = x.shape
    if cfg.dispatch == "batched":
        out, metrics = jax.vmap(
            lambda row: _moe_tokens(p, row, cfg, act, capacity_factor)
        )(x.reshape(B, S, d))
        return out, jax.tree.map(jnp.mean, metrics)
    out, metrics = _moe_tokens(p, x.reshape(B * S, d), cfg, act,
                               capacity_factor)
    return out.reshape(B, S, d), metrics


def _moe_tokens(
    p: Dict,
    xt: jnp.ndarray,  # (T, d)
    cfg: MoEConfig,
    act: str = "silu",
    capacity_factor: float | None = None,
) -> Tuple[jnp.ndarray, Dict]:
    T, d = xt.shape
    E, k = cfg.n_experts, cfg.top_k

    logits = (xt.astype(jnp.float32)) @ p["router"]  # (T, E) fp32 routing
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Load-balance aux loss (Switch-style) + router z-loss.
    me = jnp.mean(probs, axis=0)  # (E,)
    one_hot_top1 = jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32)
    ce = jnp.mean(one_hot_top1, axis=0)
    aux = E * jnp.sum(me * ce)
    zloss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    # ---- capacity-based dispatch ------------------------------------------
    C = moe_capacity(T, cfg, capacity_factor or cfg.capacity_factor)
    flat_expert = expert_idx.reshape(T * k)  # assignment order: token-major
    onehot = jax.nn.one_hot(flat_expert, E, dtype=jnp.int32)  # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - 1  # position within expert
    pos_own = jnp.take_along_axis(pos, flat_expert[:, None], axis=1)[:, 0]
    keep = pos_own < C
    safe_pos = jnp.where(keep, pos_own, 0)

    xk = jnp.repeat(xt, k, axis=0)  # (T*k, d) token copies per assignment
    contrib = jnp.where(keep[:, None], xk, jnp.zeros_like(xk))
    buf = jnp.zeros((E, C, d), xt.dtype)
    buf = buf.at[flat_expert, safe_pos].add(contrib, mode="drop")

    # Batched expert GLU: (E, C, d) x (E, d, f) -> (E, C, f)
    g = _act(jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(xt.dtype)), act)
    h = g * jnp.einsum("ecd,edf->ecf", buf, p["wi"].astype(xt.dtype))
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(xt.dtype))

    # Combine: gather each assignment's output, weight by gate, sum over k.
    gathered = out_buf[flat_expert, safe_pos]  # (T*k, d)
    w = (gate_vals.reshape(T * k) * keep.astype(jnp.float32)).astype(xt.dtype)
    out = jnp.sum((gathered * w[:, None]).reshape(T, k, d), axis=1)

    if "shared" in p:
        out = out + mlp_forward(p["shared"], xt, act)

    metrics = {
        "aux_loss": cfg.router_aux_weight * aux + 1e-3 * zloss,
        "drop_frac": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return out, metrics
