"""Mamba-1 block (falcon-mamba): selective SSM with chunked parallel scan.

TPU adaptation (DESIGN.md §3): the CUDA selective-scan kernel is replaced by
a chunked scan — `lax.scan` over sequence chunks carrying the (D, N) state,
with a `lax.associative_scan` inside each chunk. This bounds the materialized
(B, L, D, N) tensor to one chunk and maps onto the TPU VPU; the Pallas
`selective_scan` kernel implements the same contract with explicit VMEM
tiling (kernels/selective_scan).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.kernels.selective_scan.ref import selective_scan_ref


def mamba1_dims(d_model: int, cfg: SSMConfig):
    d_in = cfg.expand * d_model
    dt_rank = max(d_model // 16, 1)
    return d_in, dt_rank


def init_mamba1(key, d_model: int, cfg: SSMConfig) -> Dict:
    d_in, dt_rank = mamba1_dims(d_model, cfg)
    keys = jax.random.split(key, 7)
    si = 1.0 / (d_model ** 0.5)
    sx = 1.0 / (d_in ** 0.5)
    # S4D-real initialization for A.
    A = jnp.tile(jnp.arange(1, cfg.d_state + 1, dtype=jnp.float32), (d_in, 1))
    return {
        "in_x": jax.random.normal(keys[0], (d_model, d_in), jnp.float32) * si,
        "in_z": jax.random.normal(keys[1], (d_model, d_in), jnp.float32) * si,
        "conv_w": jax.random.normal(keys[2], (cfg.d_conv, d_in), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((d_in,), jnp.float32),
        "x_proj": jax.random.normal(
            keys[3], (d_in, dt_rank + 2 * cfg.d_state), jnp.float32) * sx,
        "dt_w": jax.random.normal(keys[4], (dt_rank, d_in), jnp.float32)
        * (1.0 / (dt_rank ** 0.5)),
        "dt_b": jnp.log(jnp.expm1(jnp.full((d_in,), 0.01, jnp.float32))),
        "A_log": jnp.log(A),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": jax.random.normal(keys[5], (d_in, d_model), jnp.float32) * sx,
    }


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv. x: (B, S, D); w: (K, D); b: (D,)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i].astype(x.dtype) for i in range(K)
    )
    return out + b.astype(x.dtype)


def _ssm_inputs(p: Dict, x_c: jnp.ndarray, cfg: SSMConfig):
    d_in = x_c.shape[-1]
    dt_rank = p["dt_w"].shape[0]
    proj = x_c @ p["x_proj"].astype(x_c.dtype)
    dt_in, B_t, C_t = jnp.split(proj, [dt_rank, dt_rank + cfg.d_state], axis=-1)
    dt = jax.nn.softplus(
        (dt_in @ p["dt_w"].astype(x_c.dtype)).astype(jnp.float32) + p["dt_b"]
    )
    A = -jnp.exp(p["A_log"])  # (D, N)
    return dt, A, B_t.astype(jnp.float32), C_t.astype(jnp.float32)


def mamba1_forward(
    p: Dict, x: jnp.ndarray, cfg: SSMConfig, impl: str = "xla",
    h0: jnp.ndarray | None = None, return_state: bool = False,
):
    """x: (B, S, d_model) -> (B, S, d_model) [+ final (conv_tail, h) state]."""
    xz = x @ p["in_x"].astype(x.dtype)
    z = x @ p["in_z"].astype(x.dtype)
    conv_out = causal_conv1d(xz, p["conv_w"], p["conv_b"])
    x_c = jax.nn.silu(conv_out)
    dt, A, B_t, C_t = _ssm_inputs(p, x_c, cfg)
    if impl == "pallas":
        from repro.kernels.selective_scan import ops as ss_ops

        y, h = ss_ops.selective_scan(
            x_c.astype(jnp.float32), dt, A, B_t, C_t, p["D"],
            chunk=cfg.chunk, h0=h0)
    else:
        y, h = selective_scan_ref(
            x_c.astype(jnp.float32), dt, A, B_t, C_t, p["D"],
            chunk=cfg.chunk, h0=h0)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x.dtype)
    if return_state:
        K = p["conv_w"].shape[0]
        conv_tail = xz[:, -(K - 1) :, :]  # last K-1 pre-activation inputs
        return out, (conv_tail, h)
    return out


def init_mamba1_cache(batch: int, d_model: int, cfg: SSMConfig, dtype=jnp.float32):
    d_in, _ = mamba1_dims(d_model, cfg)
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, d_in), dtype),
        "h": jnp.zeros((batch, d_in, cfg.d_state), jnp.float32),
    }


def mamba1_decode_step(
    p: Dict, x: jnp.ndarray, cfg: SSMConfig, cache: Dict
) -> Tuple[jnp.ndarray, Dict]:
    """One-token recurrent step. x: (B, 1, d_model)."""
    xz = x @ p["in_x"].astype(x.dtype)  # (B, 1, D)
    z = x @ p["in_z"].astype(x.dtype)
    window = jnp.concatenate([cache["conv"].astype(x.dtype), xz], axis=1)
    conv_out = (
        jnp.einsum("bkd,kd->bd", window, p["conv_w"].astype(x.dtype))
        + p["conv_b"].astype(x.dtype)
    )[:, None, :]
    x_c = jax.nn.silu(conv_out)
    dt, A, B_t, C_t = _ssm_inputs(p, x_c, cfg)
    xf = x_c.astype(jnp.float32)[:, 0]  # (B, D)
    dt0, B0, C0 = dt[:, 0], B_t[:, 0], C_t[:, 0]
    dA = jnp.exp(dt0[:, :, None] * A[None])  # (B, D, N)
    dBx = dt0[:, :, None] * B0[:, None, :] * xf[:, :, None]
    h = dA * cache["h"] + dBx
    y = jnp.einsum("bdn,bn->bd", h, C0) + p["D"] * xf
    y = y.astype(x.dtype)[:, None, :] * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(x.dtype)
    return out, {"conv": window[:, 1:].astype(cache["conv"].dtype), "h": h}
