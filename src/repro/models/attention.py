"""GQA attention: train forward, prefill (cache write) and decode step.

Supports qk-norm (Qwen3), QKV bias (Qwen2), sliding-window (the sub-quadratic
variant that qualifies dense archs for the long_500k decode shape), and a
Pallas flash-attention path for TPU targets (``impl='pallas'``).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AttentionConfig
from repro.models.norms import init_rms_norm, rms_norm
from repro.models.rope import apply_rope

NEG_INF = -1e30


def init_attention(key, d_model: int, cfg: AttentionConfig) -> Dict:
    """Head-major 3D weights: (d, H, hd) / (H, hd, d).

    SHARDING NOTE (EXPERIMENTS.md §Perf iteration A2): flat (d, H*hd)
    weights force GSPMD to shard the flattened projection dim; after the
    (H, hd) reshape the partitioner re-shards the *contraction* of the
    score einsum and all-reduces fp32 (S, S, heads) partial scores —
    22.5 GB/round on qwen2-0.5b. Head-major weights + head-axis einsums
    keep scores head-sharded (padded when H % mesh != 0) and off the wire.
    """
    kq, kk, kv, ko = jax.random.split(key, 4)
    hd, h, kvh = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    scale_in = 1.0 / (d_model ** 0.5)
    scale_out = 1.0 / ((h * hd) ** 0.5)
    p = {
        "wq": jax.random.normal(kq, (d_model, h, hd), jnp.float32) * scale_in,
        "wk": jax.random.normal(kk, (d_model, kvh, hd), jnp.float32) * scale_in,
        "wv": jax.random.normal(kv, (d_model, kvh, hd), jnp.float32) * scale_in,
        "wo": jax.random.normal(ko, (h, hd, d_model), jnp.float32) * scale_out,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), jnp.float32)
        p["bk"] = jnp.zeros((kvh, hd), jnp.float32)
        p["bv"] = jnp.zeros((kvh, hd), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = init_rms_norm(hd)
        p["k_norm"] = init_rms_norm(hd)
    return p


def _project_qkv(p: Dict, x: jnp.ndarray, cfg: AttentionConfig, positions):
    """x: (B, S, d) -> q (B,S,H,hd), k,v (B,S,KV,hd), roped."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask) -> jnp.ndarray:
    """Reference scaled-dot-product GQA attention.

    q: (B,S,H,hd), k/v: (B,T,KV,hd), mask: (S,T) or (B,S,T) bool (True=keep).
    KV heads are repeated to H *before* the score einsum so both score
    operands carry the same sharded head axis (no contraction resharding).
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum("bshd,bthd->bhst", q, k).astype(jnp.float32)
    logits = logits / (hd ** 0.5)
    if mask.ndim == 2:
        mask_b = mask[None, None]
    else:
        mask_b = mask[:, None]
    logits = jnp.where(mask_b, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bthd->bshd", probs, v)


def _causal_mask(S: int, window: Optional[int]) -> jnp.ndarray:
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    m = j <= i
    if window is not None:
        m = m & (j > i - window)
    return m


def _blocked_causal_sdpa(q, k, v, window: Optional[int], block: int = 2048):
    """Causal attention computed per query block against only its valid
    context — skips the strictly-upper triangle, ~2x fewer score/PV FLOPs
    than the dense-masked _sdpa at long S (the XLA-path analogue of flash
    attention's block skipping; used by the prefill perf path)."""
    B, S, H, hd = q.shape
    outs = []
    for i in range(0, S, block):
        bq = min(block, S - i)
        q_i = q[:, i : i + bq]
        end = i + bq
        start = 0 if window is None else max(0, end - window - bq)
        k_i = k[:, start:end]
        v_i = v[:, start:end]
        q_pos = i + jnp.arange(bq)
        k_pos = start + jnp.arange(end - start)
        mask = k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
        outs.append(_sdpa(q_i, k_i, v_i, mask))
    return jnp.concatenate(outs, axis=1)


def attention_forward(
    p: Dict,
    x: jnp.ndarray,
    cfg: AttentionConfig,
    positions: jnp.ndarray,
    impl: str = "xla",
) -> jnp.ndarray:
    """Causal self-attention over the full sequence. x: (B, S, d)."""
    q, k, v = _project_qkv(p, x, cfg, positions)
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops

        out = fa_ops.flash_attention(q, k, v, causal=True, window=cfg.sliding_window)
    elif impl == "blocked":
        out = _blocked_causal_sdpa(q, k, v, cfg.sliding_window)
    else:
        mask = _causal_mask(x.shape[1], cfg.sliding_window)
        out = _sdpa(q, k, v, mask)
    return jnp.einsum("bshd,hdo->bso", out, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# KV cache (full-length or sliding-window ring buffer)
# ---------------------------------------------------------------------------


def init_kv_cache(
    batch: int, max_len: int, cfg: AttentionConfig, dtype=jnp.bfloat16
) -> Dict:
    length = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    shape = (batch, length, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attention_prefill(
    p: Dict, x: jnp.ndarray, cfg: AttentionConfig, positions, cache: Dict,
    impl: str = "xla",
) -> Tuple[jnp.ndarray, Dict]:
    """Full-sequence forward that also fills the KV cache."""
    q, k, v = _project_qkv(p, x, cfg, positions)
    S = x.shape[1]
    L = cache["k"].shape[1]
    if cfg.sliding_window and S > L:
        # Ring buffer keeps the last L positions at slot p % L (the decode
        # step writes pos % L, so the layout must match).
        slots = jnp.arange(S - L, S) % L
        cache = {"k": cache["k"].at[:, slots].set(
                     k[:, S - L:].astype(cache["k"].dtype)),
                 "v": cache["v"].at[:, slots].set(
                     v[:, S - L:].astype(cache["v"].dtype))}
    else:
        cache = {
            "k": jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)),
            "v": jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)),
        }
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops

        out = fa_ops.flash_attention(q, k, v, causal=True, window=cfg.sliding_window)
    elif impl == "blocked":
        out = _blocked_causal_sdpa(q, k, v, cfg.sliding_window)
    else:
        mask = _causal_mask(S, cfg.sliding_window)
        out = _sdpa(q, k, v, mask)
    return jnp.einsum("bshd,hdo->bso", out, p["wo"].astype(x.dtype)), cache


def attention_decode_step(
    p: Dict, x: jnp.ndarray, cfg: AttentionConfig, pos: jnp.ndarray, cache: Dict
) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode against the KV cache.

    x: (B, 1, d); pos: scalar int32 (current absolute position).
    """
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, positions)
    L = cache["k"].shape[1]
    slot = jnp.mod(pos, L) if cfg.sliding_window else pos
    ck = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
    cache = {"k": ck, "v": cv}
    # Valid positions: for full cache, j <= pos; for ring buffer every slot
    # written so far is in-window by construction.
    j = jnp.arange(L)
    if cfg.sliding_window:
        valid = (j <= jnp.minimum(pos, L - 1)) | (pos >= L)
        mask = valid[None, :]  # (1, L): query row attends to valid slots
    else:
        mask = (j <= pos)[None, :]
    out = _sdpa(q, ck, cv, mask)
    return jnp.einsum("bshd,hdo->bso", out, p["wo"].astype(x.dtype)), cache
