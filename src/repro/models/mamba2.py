"""Mamba-2 block (zamba2): SSD chunked matmul form.

TPU adaptation: the SSD "state-space dual" algorithm is already matmul-
structured; we scan over sequence chunks (carrying the (H, P, N) state) and
compute intra-chunk attention-form and inter-chunk state contributions with
einsums that map onto the MXU. Group count G=1 (zamba2).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.mamba import causal_conv1d
from repro.models.norms import init_rms_norm, rms_norm


def mamba2_dims(d_model: int, cfg: SSMConfig):
    d_in = cfg.expand * d_model
    n_heads = d_in // cfg.head_dim
    conv_dim = d_in + 2 * cfg.n_groups * cfg.d_state
    return d_in, n_heads, conv_dim


def init_mamba2(key, d_model: int, cfg: SSMConfig) -> Dict:
    d_in, H, conv_dim = mamba2_dims(d_model, cfg)
    GN = cfg.n_groups * cfg.d_state
    keys = jax.random.split(key, 7)
    si = 1.0 / (d_model ** 0.5)
    so = 1.0 / (d_in ** 0.5)
    return {
        "in_z": jax.random.normal(keys[0], (d_model, d_in), jnp.float32) * si,
        "in_x": jax.random.normal(keys[1], (d_model, d_in), jnp.float32) * si,
        "in_B": jax.random.normal(keys[2], (d_model, GN), jnp.float32) * si,
        "in_C": jax.random.normal(keys[3], (d_model, GN), jnp.float32) * si,
        "in_dt": jax.random.normal(keys[4], (d_model, H), jnp.float32) * si,
        "conv_w": jax.random.normal(keys[5], (cfg.d_conv, conv_dim), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 0.01, jnp.float32))),
        "norm": init_rms_norm(d_in),
        "out_proj": jax.random.normal(keys[6], (d_in, d_model), jnp.float32) * so,
    }


def ssd_chunked(
    x: jnp.ndarray,  # (B, S, H, P) fp32
    dt: jnp.ndarray,  # (B, S, H) fp32 (softplus'd)
    A: jnp.ndarray,  # (H,) fp32 negative
    Bm: jnp.ndarray,  # (B, S, N) fp32  (G=1)
    Cm: jnp.ndarray,  # (B, S, N) fp32
    chunk: int = 128,
    h0: jnp.ndarray | None = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD scan. Returns y (B,S,H,P) and final state (B,H,P,N)."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    L = min(chunk, S)
    pad = (-S) % L
    if pad:
        # dt=0 => decay=1 / zero input: state carried unchanged through pad.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm, Cm = (jnp.pad(t, ((0, 0), (0, pad), (0, 0))) for t in (Bm, Cm))
        y, h = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk, h0=h0)
        return y[:, :S], h
    nc = S // L
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def to_chunks(t):
        return jnp.swapaxes(t.reshape(Bsz, nc, L, *t.shape[2:]), 0, 1)

    xs = (to_chunks(x), to_chunks(dt), to_chunks(Bm), to_chunks(Cm))

    def chunk_step(h, inp):
        xc, dtc, Bc, Cc = inp  # (B,L,H,P), (B,L,H), (B,L,N), (B,L,N)
        lna = dtc * A[None, None]  # (B,L,H) log-decay per step
        La = jnp.cumsum(lna, axis=1)  # inclusive cumulative log-decay
        # Intra-chunk (attention form): W[l,m] = C_l·B_m * exp(La_l - La_m) for l>=m
        scores = jnp.einsum("bln,bmn->blm", Cc, Bc)  # (B,L,L)
        decay = jnp.exp(La[:, :, None, :] - La[:, None, :, :])  # (B,L,L,H)
        causal = jnp.tril(jnp.ones((L, L), jnp.float32))
        W = scores[..., None] * decay * causal[None, :, :, None]  # (B,L,L,H)
        xdt = xc * dtc[..., None]  # (B,L,H,P)
        y_intra = jnp.einsum("blmh,bmhp->blhp", W, xdt)
        # Inter-chunk: contribution of the carried state.
        y_inter = jnp.einsum("bln,bhpn,blh->blhp", Cc, h, jnp.exp(La))
        # New carried state.
        seg = jnp.exp(La[:, -1:, :] - La)  # decay from step m to chunk end
        S_c = jnp.einsum("bmn,bmhp,bmh->bhpn", Bc, xdt, seg)
        h_new = jnp.exp(La[:, -1, :])[:, :, None, None] * h + S_c
        return h_new, y_intra + y_inter

    h_final, ys = jax.lax.scan(chunk_step, h0, xs)
    y = jnp.swapaxes(ys, 0, 1).reshape(Bsz, S, H, P)
    return y, h_final


def mamba2_forward(
    p: Dict, x: jnp.ndarray, cfg: SSMConfig,
    h0=None, return_state: bool = False,
):
    B_, S, d_model = x.shape
    d_in, H, conv_dim = mamba2_dims(d_model, cfg)
    P = cfg.head_dim
    N = cfg.d_state
    z = x @ p["in_z"].astype(x.dtype)
    xBC = jnp.concatenate(
        [x @ p["in_x"].astype(x.dtype),
         x @ p["in_B"].astype(x.dtype),
         x @ p["in_C"].astype(x.dtype)], axis=-1)
    conv_out = jax.nn.silu(causal_conv1d(xBC, p["conv_w"], p["conv_b"]))
    x_c, Bm, Cm = jnp.split(conv_out, [d_in, d_in + N], axis=-1)
    dt = jax.nn.softplus(
        (x @ p["in_dt"].astype(x.dtype)).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = x_c.astype(jnp.float32).reshape(B_, S, H, P)
    y, h = ssd_chunked(xh, dt, A, Bm.astype(jnp.float32),
                       Cm.astype(jnp.float32), chunk=cfg.chunk, h0=h0)
    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(B_, S, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    out = y @ p["out_proj"].astype(x.dtype)
    if return_state:
        K = p["conv_w"].shape[0]
        conv_tail = xBC[:, -(K - 1):, :]
        return out, (conv_tail, h)
    return out


def init_mamba2_cache(batch: int, d_model: int, cfg: SSMConfig, dtype=jnp.float32):
    d_in, H, conv_dim = mamba2_dims(d_model, cfg)
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, conv_dim), dtype),
        "h": jnp.zeros((batch, H, cfg.head_dim, cfg.d_state), jnp.float32),
    }


def mamba2_decode_step(
    p: Dict, x: jnp.ndarray, cfg: SSMConfig, cache: Dict
) -> Tuple[jnp.ndarray, Dict]:
    """One-token recurrent step. x: (B, 1, d_model)."""
    B_, _, d_model = x.shape
    d_in, H, conv_dim = mamba2_dims(d_model, cfg)
    P, N = cfg.head_dim, cfg.d_state
    z = x @ p["in_z"].astype(x.dtype)
    xBC = jnp.concatenate(
        [x @ p["in_x"].astype(x.dtype),
         x @ p["in_B"].astype(x.dtype),
         x @ p["in_C"].astype(x.dtype)], axis=-1)
    window = jnp.concatenate([cache["conv"].astype(x.dtype), xBC], axis=1)
    conv_out = (
        jnp.einsum("bkd,kd->bd", window, p["conv_w"].astype(x.dtype))
        + p["conv_b"].astype(x.dtype))
    conv_out = jax.nn.silu(conv_out)
    x_c, Bm, Cm = jnp.split(conv_out, [d_in, d_in + N], axis=-1)
    dt = jax.nn.softplus(
        (x @ p["in_dt"].astype(x.dtype)).astype(jnp.float32)[:, 0] + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A[None])  # (B, H)
    xh = x_c.astype(jnp.float32).reshape(B_, H, P)
    dBx = jnp.einsum("bn,bhp,bh->bhpn", Bm.astype(jnp.float32), xh, dt)
    h = a[:, :, None, None] * cache["h"] + dBx
    y = jnp.einsum("bhpn,bn->bhp", h, Cm.astype(jnp.float32))
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(B_, 1, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    out = y @ p["out_proj"].astype(x.dtype)
    return out, {"conv": window[:, 1:].astype(cache["conv"].dtype), "h": h}
