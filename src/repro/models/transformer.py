"""Composable decoder covering all assigned architectures.

One generic stack, configured by ModelConfig:
  mixer  : attention | mamba1 | mamba2
  mlp    : dense | moe | none
  extras : tied shared attention block every k layers (zamba2),
           modality prefix (VLM patches / audio conditioning),
           multi-codebook embedding + K LM heads (musicgen).

Layer parameters are stacked (n_groups, scan_group, ...) and the stack runs
under jax.lax.scan over groups (remat'd), which keeps lowering time and HLO
size flat in depth — essential for 40 (arch x shape) x 2 mesh dry-runs.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mamba as m1
from repro.models import mamba2 as m2
from repro.models.mlp import init_mlp, mlp_forward
from repro.models.moe import init_moe, moe_forward
from repro.models.norms import init_rms_norm, rms_norm


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_layer(cfg: ModelConfig, key) -> Dict:
    k1, k2 = jax.random.split(key)
    p: Dict = {"ln1": init_rms_norm(cfg.d_model)}
    if cfg.mixer == "attention":
        p["attn"] = attn.init_attention(k1, cfg.d_model, cfg.attention)
    elif cfg.mixer == "mamba1":
        p["mamba"] = m1.init_mamba1(k1, cfg.d_model, cfg.ssm)
    elif cfg.mixer == "mamba2":
        p["mamba"] = m2.init_mamba2(k1, cfg.d_model, cfg.ssm)
    else:
        raise ValueError(cfg.mixer)
    if cfg.mlp == "dense":
        p["ln2"] = init_rms_norm(cfg.d_model)
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff)
    elif cfg.mlp == "moe":
        p["ln2"] = init_rms_norm(cfg.d_model)
        p["moe"] = init_moe(k2, cfg.d_model, cfg.moe)
    return p


def _shared_attn_cfg(cfg: ModelConfig):
    from repro.configs.base import AttentionConfig

    hd = cfg.d_model // cfg.shared_attn_heads
    return AttentionConfig(
        n_heads=cfg.shared_attn_heads, n_kv_heads=cfg.shared_attn_heads,
        head_dim=hd)


def init_params(cfg: ModelConfig, key) -> Dict:
    keys = jax.random.split(key, 8)
    d = cfg.d_model
    params: Dict = {}
    if cfg.modality and cfg.modality.kind == "audio":
        params["embed"] = (
            jax.random.normal(
                keys[0], (cfg.modality.n_codebooks, cfg.vocab_size, d),
                jnp.float32) * 0.02)
    else:
        params["embed"] = (
            jax.random.normal(keys[0], (cfg.vocab_size, d), jnp.float32) * 0.02)
    if cfg.modality:
        params["projector"] = {
            "w": jax.random.normal(
                keys[1], (cfg.modality.embed_dim, d), jnp.float32)
            * (1.0 / cfg.modality.embed_dim ** 0.5),
            "b": jnp.zeros((d,), jnp.float32),
        }
    # Stacked layer params: (G, sg, ...)
    G, sg = cfg.n_scan_groups, cfg.scan_group
    layer_keys = jax.random.split(keys[2], G * sg).reshape(G, sg, 2)
    init_one = functools.partial(_init_layer, cfg)
    params["layers"] = jax.vmap(jax.vmap(init_one))(layer_keys)
    if cfg.shared_attn_every:
        sa_cfg = _shared_attn_cfg(cfg)
        k1, k2 = jax.random.split(keys[3])
        params["shared"] = {
            "ln1": init_rms_norm(d),
            "attn": attn.init_attention(k1, d, sa_cfg),
            "ln2": init_rms_norm(d),
            "mlp": init_mlp(k2, d, 4 * d),
        }
    params["ln_f"] = init_rms_norm(d)
    if not cfg.tie_embeddings:
        n_heads_out = cfg.modality.n_codebooks if (
            cfg.modality and cfg.modality.kind == "audio") else 1
        shape = (d, cfg.vocab_size) if n_heads_out == 1 else (
            n_heads_out, d, cfg.vocab_size)
        params["lm_head"] = (
            jax.random.normal(keys[4], shape, jnp.float32) * (1.0 / d ** 0.5))
    return params


# ---------------------------------------------------------------------------
# Forward (training / full-sequence)
# ---------------------------------------------------------------------------


def _layer_forward(cfg: ModelConfig, p: Dict, x, positions, impl: str):
    """One block: pre-norm mixer + pre-norm channel-mixer, residuals."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.mixer == "attention":
        h = attn.attention_forward(p["attn"], h, cfg.attention, positions, impl)
    elif cfg.mixer == "mamba1":
        h = m1.mamba1_forward(p["mamba"], h, cfg.ssm, impl)
    else:
        h = m2.mamba2_forward(p["mamba"], h, cfg.ssm)
    x = x + h
    if cfg.mlp == "dense":
        x = x + mlp_forward(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg.act)
    elif cfg.mlp == "moe":
        h, metrics = moe_forward(
            p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg.moe, cfg.act)
        x = x + h
        aux = aux + metrics["aux_loss"]
    return x, aux


def _shared_block(cfg: ModelConfig, p: Dict, x, positions, impl: str):
    sa_cfg = _shared_attn_cfg(cfg)
    x = x + attn.attention_forward(
        p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), sa_cfg, positions, impl)
    x = x + mlp_forward(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg.act)
    return x


def embed_inputs(cfg: ModelConfig, params: Dict, tokens, prefix_embeds=None):
    """Token (+codebook) embedding with optional projected modality prefix.

    Returns (x, prefix_len). x: (B, S_total, d) in cfg.dtype.
    """
    dtype = jnp.dtype(cfg.dtype)
    emb = params["embed"]
    if cfg.modality and cfg.modality.kind == "audio":
        # tokens: (B, S, K) -> summed codebook embeddings.
        K = cfg.modality.n_codebooks
        x = sum(emb[k][tokens[..., k]] for k in range(K)).astype(dtype)
    else:
        x = emb[tokens].astype(dtype)
    prefix_len = 0
    if cfg.modality and prefix_embeds is not None:
        pr = params["projector"]
        pref = (prefix_embeds.astype(jnp.float32) @ pr["w"] + pr["b"]).astype(dtype)
        x = jnp.concatenate([pref, x], axis=1)
        prefix_len = prefix_embeds.shape[1]
    return x, prefix_len


def _stack_forward(cfg: ModelConfig, params: Dict, x, positions, impl: str):
    """Scan over layer groups (remat'd); returns (x, total_aux)."""
    sg = cfg.scan_group

    def group_body(carry, layer_p):
        h, aux = carry
        for i in range(sg):
            p_i = jax.tree.map(lambda t: t[i], layer_p)
            h, a = _layer_forward(cfg, p_i, h, positions, impl)
            aux = aux + a
        if cfg.shared_attn_every:
            h = _shared_block(cfg, params["shared"], h, positions, impl)
        return (h, aux), None

    body = jax.checkpoint(group_body, prevent_cse=False) if cfg.remat \
        else group_body
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
    return x, aux


def compute_logits(cfg: ModelConfig, params: Dict, x):
    xf = rms_norm(x, params["ln_f"], cfg.norm_eps).astype(jnp.float32)
    if cfg.tie_embeddings:
        return xf @ params["embed"].astype(jnp.float32).T
    head = params["lm_head"]
    if head.ndim == 3:  # audio: K heads -> (B, S, K, V)
        return jnp.einsum("bsd,kdv->bskv", xf, head)
    return xf @ head


def forward(
    cfg: ModelConfig, params: Dict, tokens, prefix_embeds=None, impl: str = "xla",
) -> Tuple[jnp.ndarray, jnp.ndarray, int]:
    """Full-sequence forward. Returns (logits, aux_loss, prefix_len)."""
    x, prefix_len = embed_inputs(cfg, params, tokens, prefix_embeds)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x, aux = _stack_forward(cfg, params, x, positions, impl)
    return compute_logits(cfg, params, x), aux, prefix_len


def loss_fn(
    cfg: ModelConfig, params: Dict, batch: Dict, impl: str = "xla",
) -> Tuple[jnp.ndarray, Dict]:
    """Next-token cross-entropy (+ MoE aux). batch: {'tokens', ['prefix_embeds']}."""
    tokens = batch["tokens"]
    logits, aux, P = forward(
        cfg, params, tokens, batch.get("prefix_embeds"), impl)
    # Predict token t+1 from position P+t (prefix positions excluded).
    if cfg.modality and cfg.modality.kind == "audio":
        logits_t = logits[:, P : P + tokens.shape[1] - 1]  # (B, St-1, K, V)
        targets = tokens[:, 1:]  # (B, St-1, K)
        logp = jax.nn.log_softmax(logits_t, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    else:
        logits_t = logits[:, P : P + tokens.shape[1] - 1]
        targets = tokens[:, 1:]
        logp = jax.nn.log_softmax(logits_t, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    return loss + aux, {"ce_loss": loss, "aux_loss": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + decode with per-layer caches
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    """Stacked per-layer caches: leaves (G, sg, ...)."""
    G, sg = cfg.n_scan_groups, cfg.scan_group

    def one_layer(_):
        if cfg.mixer == "attention":
            return attn.init_kv_cache(batch, max_len, cfg.attention)
        if cfg.mixer == "mamba1":
            return m1.init_mamba1_cache(batch, cfg.d_model, cfg.ssm)
        return m2.init_mamba2_cache(batch, cfg.d_model, cfg.ssm)

    layer_caches = jax.tree.map(
        lambda *xs: jnp.stack(xs).reshape(G, sg, *xs[0].shape),
        *[one_layer(i) for i in range(G * sg)])
    cache: Dict = {"layers": layer_caches, "pos": jnp.zeros((), jnp.int32)}
    if cfg.shared_attn_every:
        sa_cfg = _shared_attn_cfg(cfg)
        shared = [attn.init_kv_cache(batch, max_len, sa_cfg) for _ in range(G)]
        cache["shared"] = jax.tree.map(lambda *xs: jnp.stack(xs), *shared)
    return cache


def _layer_decode(cfg: ModelConfig, p: Dict, x, pos, layer_cache, impl: str):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.mixer == "attention":
        h, layer_cache = attn.attention_decode_step(
            p["attn"], h, cfg.attention, pos, layer_cache)
    elif cfg.mixer == "mamba1":
        h, layer_cache = m1.mamba1_decode_step(p["mamba"], h, cfg.ssm, layer_cache)
    else:
        h, layer_cache = m2.mamba2_decode_step(p["mamba"], h, cfg.ssm, layer_cache)
    x = x + h
    if cfg.mlp == "dense":
        x = x + mlp_forward(p["mlp"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg.act)
    elif cfg.mlp == "moe":
        h, _ = moe_forward(
            p["moe"], rms_norm(x, p["ln2"], cfg.norm_eps), cfg.moe, cfg.act,
            capacity_factor=None)
        x = x + h
    return x, layer_cache


def decode_step(
    cfg: ModelConfig, params: Dict, cache: Dict, tokens, impl: str = "xla",
) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode. tokens: (B, 1) (or (B, 1, K) audio). Returns
    (logits, new_cache)."""
    x, _ = embed_inputs(cfg, params, tokens, None)
    pos = cache["pos"]
    sg = cfg.scan_group
    B = x.shape[0]
    positions = jnp.full((B, 1), pos, jnp.int32)

    def group_body(h, xs):
        layer_p, layer_c, shared_c = xs
        new_c = []
        for i in range(sg):
            p_i = jax.tree.map(lambda t: t[i], layer_p)
            c_i = jax.tree.map(lambda t: t[i], layer_c)
            h, c_i = _layer_decode(cfg, p_i, h, pos, c_i, impl)
            new_c.append(c_i)
        layer_c = jax.tree.map(lambda *xs_: jnp.stack(xs_), *new_c)
        if cfg.shared_attn_every:
            sa_cfg = _shared_attn_cfg(cfg)
            p_s = params["shared"]
            a, shared_c = attn.attention_decode_step(
                p_s["attn"], rms_norm(h, p_s["ln1"], cfg.norm_eps),
                sa_cfg, pos, shared_c)
            h = h + a
            h = h + mlp_forward(
                p_s["mlp"], rms_norm(h, p_s["ln2"], cfg.norm_eps), cfg.act)
        return h, (layer_c, shared_c)

    shared_in = cache.get("shared")
    if shared_in is None:
        G = cfg.n_scan_groups
        shared_in = jnp.zeros((G, 0))  # dummy scannable leaf
    x, (new_layers, new_shared) = jax.lax.scan(
        group_body, x, (params["layers"], cache["layers"], shared_in))
    logits = compute_logits(cfg, params, x)
    new_cache = {"layers": new_layers, "pos": pos + 1}
    if cfg.shared_attn_every:
        new_cache["shared"] = new_shared
    return logits, new_cache


def prefill(
    cfg: ModelConfig, params: Dict, tokens, prefix_embeds=None,
    max_len: Optional[int] = None, impl: str = "xla",
) -> Tuple[jnp.ndarray, Dict]:
    """Full-sequence forward that fills all caches. Returns (logits, cache)."""
    x, prefix_len = embed_inputs(cfg, params, tokens, prefix_embeds)
    B, S = x.shape[:2]
    max_len = max_len or S
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    cache = init_cache(cfg, B, max_len)
    sg = cfg.scan_group

    def group_body(h, xs):
        layer_p, layer_c, shared_c = xs
        new_c = []
        for i in range(sg):
            p_i = jax.tree.map(lambda t: t[i], layer_p)
            c_i = jax.tree.map(lambda t: t[i], layer_c)
            h2 = rms_norm(h, p_i["ln1"], cfg.norm_eps)
            if cfg.mixer == "attention":
                h2, c_i = attn.attention_prefill(
                    p_i["attn"], h2, cfg.attention, positions, c_i, impl)
            elif cfg.mixer == "mamba1":
                h2, (conv_tail, hst) = m1.mamba1_forward(
                    p_i["mamba"], h2, cfg.ssm, impl, return_state=True)
                c_i = {"conv": conv_tail.astype(c_i["conv"].dtype), "h": hst}
            else:
                h2, (conv_tail, hst) = m2.mamba2_forward(
                    p_i["mamba"], h2, cfg.ssm, return_state=True)
                c_i = {"conv": conv_tail.astype(c_i["conv"].dtype), "h": hst}
            h = h + h2
            if cfg.mlp == "dense":
                h = h + mlp_forward(
                    p_i["mlp"], rms_norm(h, p_i["ln2"], cfg.norm_eps), cfg.act)
            elif cfg.mlp == "moe":
                hm, _ = moe_forward(
                    p_i["moe"], rms_norm(h, p_i["ln2"], cfg.norm_eps),
                    cfg.moe, cfg.act, capacity_factor=None)
                h = h + hm
            new_c.append(c_i)
        layer_c = jax.tree.map(lambda *xs_: jnp.stack(xs_), *new_c)
        if cfg.shared_attn_every:
            sa_cfg = _shared_attn_cfg(cfg)
            p_s = params["shared"]
            a, shared_c = attn.attention_prefill(
                p_s["attn"], rms_norm(h, p_s["ln1"], cfg.norm_eps),
                sa_cfg, positions, shared_c, impl)
            h = h + a
            h = h + mlp_forward(
                p_s["mlp"], rms_norm(h, p_s["ln2"], cfg.norm_eps), cfg.act)
        return h, (layer_c, shared_c)

    shared_in = cache.get("shared")
    if shared_in is None:
        shared_in = jnp.zeros((cfg.n_scan_groups, 0))
    x, (new_layers, new_shared) = jax.lax.scan(
        group_body, x, (params["layers"], cache["layers"], shared_in))
    logits = compute_logits(cfg, params, x[:, -1:])
    new_cache = {"layers": new_layers,
                 "pos": jnp.asarray(S, jnp.int32)}
    if cfg.shared_attn_every:
        new_cache["shared"] = new_shared
    return logits, new_cache
