"""Gated-linear-unit MLPs (SwiGLU / GeGLU)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


def init_mlp(key, d_model: int, d_ff: int) -> Dict:
    kg, ki, ko = jax.random.split(key, 3)
    si = 1.0 / (d_model ** 0.5)
    so = 1.0 / (d_ff ** 0.5)
    return {
        "wg": jax.random.normal(kg, (d_model, d_ff), jnp.float32) * si,
        "wi": jax.random.normal(ki, (d_model, d_ff), jnp.float32) * si,
        "wo": jax.random.normal(ko, (d_ff, d_model), jnp.float32) * so,
    }


def _act(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    raise ValueError(f"unknown activation {kind!r}")


def mlp_forward(p: Dict, x: jnp.ndarray, act: str = "silu") -> jnp.ndarray:
    g = _act(x @ p["wg"].astype(x.dtype), act)
    h = g * (x @ p["wi"].astype(x.dtype))
    return h @ p["wo"].astype(x.dtype)
