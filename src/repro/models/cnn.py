"""The paper's evaluation model: the FedAvg CNN (McMahan et al. [2]) for
MNIST / CIFAR-10 image classification — two 5x5 conv + pool stages, one
512-unit FC layer, softmax head."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class CNNConfig:
    name: str
    input_hw: Tuple[int, int]
    in_channels: int
    n_classes: int = 10
    conv_channels: Tuple[int, int] = (32, 64)
    kernel: int = 5
    fc_dim: int = 512

    @property
    def flat_dim(self) -> int:
        h, w = self.input_hw
        return (h // 4) * (w // 4) * self.conv_channels[1]


def mnist_cnn() -> CNNConfig:
    return CNNConfig(name="cnn-mnist", input_hw=(28, 28), in_channels=1)


def mnist_cnn_small() -> CNNConfig:
    """Smoke-scale variant (same topology, ~30x fewer params). The round-
    step bench runs on it so simulator overhead (per-client dispatch, host
    compression roundtrips, device->host syncs) dominates over GEMM time —
    the regime the batched backend exists for."""
    return CNNConfig(name="cnn-mnist-small", input_hw=(28, 28), in_channels=1,
                     conv_channels=(8, 16), fc_dim=64)


def mnist_cnn_tiny() -> CNNConfig:
    """Overhead-scale variant: 1x1 kernels (the im2col path degenerates to
    pointwise GEMMs) and minimal widths, so one round's fwd/bwd compute
    sits at dispatch-overhead scale (~sub-ms). The fleet rows of the
    round-step bench run on it: what `run_fleet` amortizes is per-run
    driver/dispatch cost, which GEMM time would otherwise mask entirely
    (see EXPERIMENTS.md §Driver overhead)."""
    return CNNConfig(name="cnn-mnist-tiny", input_hw=(28, 28), in_channels=1,
                     conv_channels=(1, 2), kernel=1, fc_dim=8)


def cifar_cnn() -> CNNConfig:
    return CNNConfig(name="cnn-cifar", input_hw=(32, 32), in_channels=3)


def init_cnn(cfg: CNNConfig, key) -> Dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    c1, c2 = cfg.conv_channels
    k = cfg.kernel

    def conv_init(key, shape):
        fan_in = shape[0] * shape[1] * shape[2]
        return jax.random.normal(key, shape, jnp.float32) * (2.0 / fan_in) ** 0.5

    def fc_init(key, shape):
        return jax.random.normal(key, shape, jnp.float32) * (2.0 / shape[0]) ** 0.5

    return {
        "conv1": {"w": conv_init(k1, (k, k, cfg.in_channels, c1)),
                  "b": jnp.zeros((c1,), jnp.float32)},
        "conv2": {"w": conv_init(k2, (k, k, c1, c2)),
                  "b": jnp.zeros((c2,), jnp.float32)},
        "fc1": {"w": fc_init(k3, (cfg.flat_dim, cfg.fc_dim)),
                "b": jnp.zeros((cfg.fc_dim,), jnp.float32)},
        "fc2": {"w": fc_init(k4, (cfg.fc_dim, cfg.n_classes)),
                "b": jnp.zeros((cfg.n_classes,), jnp.float32)},
    }


@jax.custom_vjp
def _ps_matmul(a, w):
    """`a @ w` with a *pad-stable* backward.

    Forward is exactly the plain matmul (bit-identical to `a @ w`). The
    backward restructures the filter gradient: XLA's autodiff dW is one
    dot_general contracting over (batch x spatial), whose fp32
    accumulation XLA re-associates when the contraction LENGTH changes —
    so a batch padded with zero-cotangent rows (the Study API's
    (V, b)-envelope, study.py) would not reproduce the unpadded bits.
    Here dW is computed per sample (contraction over the sample's own
    fixed-size spatial dims only) and then reduced over the leading batch
    axis, where appended exact-zero per-sample grads cannot perturb the
    accumulation. Verified bit-identical under zero-masked batch padding
    and under client/fleet vmap in tests/test_study.py.
    """
    return a @ w


def _ps_matmul_fwd(a, w):
    return a @ w, (a, w)


def _ps_matmul_bwd(res, dy):
    a, w = res
    K, O = w.shape
    da = dy @ w.T
    dw_b = jnp.einsum(
        "bnk,bno->bko", a.reshape(a.shape[0], -1, K),
        dy.reshape(dy.shape[0], -1, O))
    return da, jnp.sum(dw_b, axis=0)


_ps_matmul.defvjp(_ps_matmul_fwd, _ps_matmul_bwd)


def _patches(x, k):
    """'SAME' kxk patches of x (B, H, W, C) -> (B, H, W, k*k*C), ordered to
    match an HWIO filter flattened as (k*k*C, O)."""
    B, H, W, C = x.shape
    # Symmetric k//2 padding only equals XLA SAME for odd windows.
    assert k % 2 == 1, f"im2col path requires odd kernel, got {k}"
    p = k // 2
    xp = jnp.pad(x, ((0, 0), (p, p), (p, p), (0, 0)))
    cols = [xp[:, i : i + H, j : j + W, :] for i in range(k) for j in range(k)]
    return jnp.concatenate(cols, axis=-1)


def _conv(x, p):
    # im2col + matmul rather than conv_general_dilated: XLA:CPU lowers the
    # filter/input gradients of a direct conv to transposed convolutions
    # that run ~10-25x slower than the forward pass; the patches+dot form
    # keeps both directions on the (fast) GEMM path and is bit-identical in
    # the forward direction. The FL simulator spends nearly all its compute
    # here (V fwd/bwd passes per client per round).
    k = p["w"].shape[0]
    w = p["w"].reshape(-1, p["w"].shape[-1])  # (k*k*C, O)
    return _ps_matmul(_patches(x, k), w) + p["b"]


def _maxpool(x):
    # Non-overlapping 2x2 window == reshape + max; reduce_window's gradient
    # (select-and-scatter) is a scalar loop on XLA:CPU. Tie-breaking in the
    # VJP differs (split vs first-hit) but the forward is exact.
    B, H, W, C = x.shape
    assert H % 2 == 0 and W % 2 == 0, f"2x2 pool needs even dims, got {H}x{W}"
    return jnp.max(x.reshape(B, H // 2, 2, W // 2, 2, C), axis=(2, 4))


def cnn_forward(cfg: CNNConfig, params: Dict, images: jnp.ndarray) -> jnp.ndarray:
    """images: (B, H, W, C) -> logits (B, n_classes)."""
    x = _maxpool(jax.nn.relu(_conv(images, params["conv1"])))
    x = _maxpool(jax.nn.relu(_conv(x, params["conv2"])))
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return x @ params["fc2"]["w"] + params["fc2"]["b"]


def _seq_mean(v: jnp.ndarray, n) -> jnp.ndarray:
    """Mean over a 1-D array via a sequential left-fold (lax.scan).

    XLA's reduce re-associates its fp32 accumulation when the reduction
    LENGTH changes, so `jnp.mean(nll[:b])` and a zero-masked mean over a
    padded (b_env,) array can differ in the last ulp. A left-fold's
    partial sums are prefix-stable: appending exact-zero terms (masked
    padded samples) leaves every partial — and the total — bit-identical.
    Both `cnn_loss` and `cnn_loss_masked` reduce through this, which is
    what makes the Study envelope's train-loss HISTORY (not just the
    trained params) bit-identical to unpadded runs. The gradient is
    unchanged from jnp.mean (each element's cotangent is exactly 1/n)."""
    total, _ = jax.lax.scan(
        lambda acc, x: (acc + x, None), jnp.zeros((), v.dtype), v)
    return total / n


def cnn_loss(cfg: CNNConfig, params: Dict, batch: Dict) -> Tuple[jnp.ndarray, Dict]:
    logits = cnn_forward(cfg, params, batch["x"])
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, batch["y"][:, None], axis=-1)[:, 0]
    loss = _seq_mean(nll, nll.shape[0])
    acc = jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))
    return loss, {"ce_loss": loss, "accuracy": acc}


def cnn_loss_masked(
    cfg: CNNConfig, params: Dict, batch: Dict, sample_mask: jnp.ndarray,
    n: jnp.ndarray,
) -> Tuple[jnp.ndarray, Dict]:
    """`cnn_loss` over the first `n` samples of a padded batch.

    sample_mask is a traced (B_env,) 0/1 float (the leading int(n) entries
    are 1) and n the valid-sample count as f32. Padded rows contribute an
    exact 0 to the nll sum (x * 0.0) and exact-zero logits cotangents, so
    at any padding — including none — the loss and its params gradient are
    bit-identical to `cnn_loss` on the unpadded batch (the `_ps_matmul`
    backward keeps the conv filter gradients pad-stable). This is the
    loss form the Study API's (V, b)-envelope round step runs."""
    logits = cnn_forward(cfg, params, batch["x"])
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, batch["y"][:, None], axis=-1)[:, 0]
    loss = _seq_mean(nll * sample_mask, n)
    hit = (jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32)
    acc = jnp.sum(hit * sample_mask) / n
    return loss, {"ce_loss": loss, "accuracy": acc}
