"""Normalization layers (functional, param dicts)."""
from __future__ import annotations

import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm computed in fp32, cast back to input dtype.

    Uses the (1 + scale) parameterization (gemma-style) with zero-init scale
    so initialization is exactly unit-gain for every arch.
    """
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    xf = xf * (1.0 / jnp.sqrt(var + eps))
    return (xf * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def init_rms_norm(d: int) -> jnp.ndarray:
    return jnp.zeros((d,), dtype=jnp.float32)
