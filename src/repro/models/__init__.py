from repro.models.transformer import (
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    prefill,
)
