"""Batched serving driver (deliverable b): prefill a batch of requests,
then decode tokens step-by-step against the KV/SSM cache.

  PYTHONPATH=src python -m repro.launch.serve --arch falcon-mamba-7b \
      --smoke --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models import transformer as tfm


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(args.seed)
    params = tfm.init_params(cfg, key)
    B, S = args.batch, args.prompt_len
    max_len = S + args.gen
    if cfg.modality and cfg.modality.kind == "audio":
        prompts = jax.random.randint(
            key, (B, S, cfg.modality.n_codebooks), 0, cfg.vocab_size)
    else:
        prompts = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    prefix = None
    if cfg.modality and cfg.modality.kind == "vision":
        prefix = jax.random.normal(
            key, (B, cfg.modality.prefix_len, cfg.modality.embed_dim),
            jnp.bfloat16)

    prefill = jax.jit(lambda p, t, pe: tfm.prefill(cfg, p, t, pe,
                                                   max_len=max_len))
    decode = jax.jit(lambda p, c, t: tfm.decode_step(cfg, p, c, t))

    t0 = time.time()
    logits, cache = prefill(params, prompts, prefix)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    print(f"prefill: {B}x{S} tokens in {t_prefill:.2f}s "
          f"({B * S / t_prefill:.0f} tok/s)")

    def sample(logits):
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if cfg.modality and cfg.modality.kind == "audio":
            return tok.reshape(B, 1, cfg.modality.n_codebooks)
        return tok.reshape(B, 1)

    tok = sample(logits[:, -1])
    out_tokens = [np.asarray(tok)]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, cache, tok)
        tok = sample(logits[:, 0] if logits.ndim >= 3 else logits)
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_dec = time.time() - t0
    steps = args.gen - 1
    print(f"decode: {steps} steps x {B} seqs in {t_dec:.2f}s "
          f"({steps * B / max(t_dec, 1e-9):.1f} tok/s)")
    gen = np.concatenate(out_tokens, axis=1)
    print(f"generated shape: {gen.shape}; first row: {gen[0].reshape(-1)[:16]}")
    return gen


if __name__ == "__main__":
    main()
