"""input_specs(): ShapeDtypeStruct stand-ins for every model input, per
(architecture x input-shape x mesh). No device allocation — weak-type-
correct abstract arrays the dry-run lowers against.

Shapes follow the assignment:
  train_4k    : train round step — tokens (C, V, b_local, S) per client axis
  prefill_32k : serve prefill     — tokens (B, S)
  decode_32k  : serve decode      — 1 new token against a seq_len cache
  long_500k   : serve decode      — sub-quadratic only (SWA/SSM/hybrid)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, MeshConfig, ModelConfig

SWA_WINDOW = 8192  # sliding window qualifying dense archs for long_500k


def adapt_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Apply shape-driven config adaptations (SWA for long-context decode)."""
    if shape.name == "long_500k" and cfg.attention is not None:
        cfg = cfg.replace(attention=dataclasses.replace(
            cfg.attention, sliding_window=SWA_WINDOW))
    return cfg


def _token_struct(shape: Tuple[int, ...]):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def train_input_specs(
    cfg: ModelConfig, shape: InputShape, mesh_cfg: MeshConfig, V: int,
) -> Dict:
    """Round-step inputs: batches pytree (C, V, b_local, ...) + weights (C,)."""
    C = mesh_cfg.n_clients
    assert shape.global_batch % C == 0, (shape.global_batch, C)
    b = shape.global_batch // C
    S = shape.seq_len
    batch: Dict = {}
    if cfg.modality and cfg.modality.kind == "audio":
        K = cfg.modality.n_codebooks
        batch["tokens"] = _token_struct((C, V, b, S, K))
    elif cfg.modality:  # vlm: patch prefix + text tokens, total length S
        P = cfg.modality.prefix_len
        batch["tokens"] = _token_struct((C, V, b, S - P))
        batch["prefix_embeds"] = jax.ShapeDtypeStruct(
            (C, V, b, P, cfg.modality.embed_dim), jnp.bfloat16)
    else:
        batch["tokens"] = _token_struct((C, V, b, S))
    weights = jax.ShapeDtypeStruct((C,), jnp.float32)
    return {"batches": batch, "weights": weights}


def prefill_input_specs(cfg: ModelConfig, shape: InputShape) -> Dict:
    B, S = shape.global_batch, shape.seq_len
    if cfg.modality and cfg.modality.kind == "audio":
        return {"tokens": _token_struct((B, S, cfg.modality.n_codebooks))}
    if cfg.modality:
        P = cfg.modality.prefix_len
        return {
            "tokens": _token_struct((B, S - P)),
            "prefix_embeds": jax.ShapeDtypeStruct(
                (B, P, cfg.modality.embed_dim), jnp.bfloat16),
        }
    return {"tokens": _token_struct((B, S))}


def decode_input_specs(cfg: ModelConfig, shape: InputShape) -> Dict:
    """One-token decode inputs (the cache spec is built separately from
    eval_shape of init_cache)."""
    B = shape.global_batch
    if cfg.modality and cfg.modality.kind == "audio":
        return {"tokens": _token_struct((B, 1, cfg.modality.n_codebooks))}
    return {"tokens": _token_struct((B, 1))}
