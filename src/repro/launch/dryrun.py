import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

# Multi-pod dry-run: lower + compile every (architecture x input shape)
# on the production meshes, print memory/cost analyses, and dump roofline
# inputs (deliverables e and g).
#
#   PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
#       --mesh single --out experiments/dryrun
#
# Failures (sharding mismatch, OOM at compile, unsupported collective) are
# bugs in the system — the run exits nonzero if any pair fails.
import argparse
import functools
import json
import time
import traceback
from typing import Dict

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, InputShape, MeshConfig, ModelConfig
from repro.configs.registry import ARCH_IDS, get_config
from repro.federated.mesh_rounds import build_round_step, replicate_clients
from repro.launch.mesh import make_production_mesh
from repro.launch.specs_inputs import (
    adapt_config,
    decode_input_specs,
    prefill_input_specs,
    train_input_specs,
)
from repro.models import transformer as tfm
from repro.optim import sgd
from repro.sharding.specs import cache_specs, param_specs
from repro.utils import flops as fl
from repro.utils.hlo import collective_summary, parse_collectives

DEFAULT_V = 4  # baseline local rounds per sync (DEFL hillclimbs this)


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(tfm.init_params, cfg), jax.random.PRNGKey(0))


def _batch_spec(tree, leading_axes):
    ax = leading_axes if len(leading_axes) > 1 else leading_axes[0]
    return jax.tree.map(
        lambda x: P(ax, *([None] * (x.ndim - 1))), tree)


def lower_train(cfg: ModelConfig, shape: InputShape, mesh, mesh_cfg: MeshConfig,
                V: int = DEFAULT_V, aggregation: str = "allreduce",
                donate: bool = True, impl: str = "xla"):
    loss = functools.partial(tfm.loss_fn, cfg, impl=impl)
    opt = sgd(0.01)
    C = mesh_cfg.n_clients
    params_abs = jax.eval_shape(
        lambda p: replicate_clients(p, C), _abstract_params(cfg))
    pspecs = param_specs(params_abs, mesh, client_axes=mesh_cfg.client_axes)
    step = build_round_step(lambda p, b: loss(p, b), opt, V, aggregation,
                            mesh=mesh, param_specs_tree=pspecs,
                            client_axes=mesh_cfg.client_axes)
    inputs = train_input_specs(cfg, shape, mesh_cfg, V)
    bspecs = _batch_spec(inputs["batches"], mesh_cfg.client_axes)
    in_sh = (_ns(mesh, pspecs), (), _ns(mesh, bspecs),
             NamedSharding(mesh, P()))
    out_sh = (_ns(mesh, pspecs), (), NamedSharding(mesh, P()))
    fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                 donate_argnums=(0,) if donate else ())
    with mesh:
        return fn.lower(params_abs, (), inputs["batches"], inputs["weights"])


def lower_prefill(cfg: ModelConfig, shape: InputShape, mesh,
                  mesh_cfg: MeshConfig, impl: str = "xla"):
    batch_axes = mesh_cfg.client_axes  # batch shards over pod+data
    inputs = prefill_input_specs(cfg, shape)
    B = shape.global_batch
    bsize = int(np.prod([mesh.shape[a] for a in batch_axes]))
    b_ax = batch_axes if B % bsize == 0 else ()

    def serve(params, batch):
        return tfm.prefill(cfg, params, batch["tokens"],
                           batch.get("prefix_embeds"),
                           max_len=shape.seq_len, impl=impl)

    params_abs = _abstract_params(cfg)
    pspecs = param_specs(params_abs, mesh, client_axes=None)
    bspecs = jax.tree.map(
        lambda x: P(*((b_ax if len(b_ax) > 1 else b_ax[0] if b_ax else None,)
                      + (None,) * (x.ndim - 1))), inputs)
    fn = jax.jit(serve, in_shardings=(_ns(mesh, pspecs), _ns(mesh, bspecs)))
    with mesh:
        return fn.lower(params_abs, inputs)


def lower_decode(cfg: ModelConfig, shape: InputShape, mesh,
                 mesh_cfg: MeshConfig):
    batch_axes = mesh_cfg.client_axes
    B = shape.global_batch
    bsize = int(np.prod([mesh.shape[a] for a in batch_axes]))
    b_ax = tuple(batch_axes) if B % bsize == 0 else None
    cache_abs = jax.eval_shape(
        functools.partial(tfm.init_cache, cfg, B, shape.seq_len))
    cspecs = cache_specs(cache_abs, mesh, batch_axes=b_ax)
    inputs = decode_input_specs(cfg, shape)

    def serve(params, cache, batch):
        return tfm.decode_step(cfg, params, cache, batch["tokens"])

    params_abs = _abstract_params(cfg)
    pspecs = param_specs(params_abs, mesh, client_axes=None)
    tok_spec = jax.tree.map(
        lambda x: P(*(((b_ax if len(b_ax) > 1 else b_ax[0]) if b_ax else None,)
                      + (None,) * (x.ndim - 1))), inputs)
    fn = jax.jit(
        serve,
        in_shardings=(_ns(mesh, pspecs), _ns(mesh, cspecs), _ns(mesh, tok_spec)),
        out_shardings=(NamedSharding(mesh, P()), _ns(mesh, cspecs)),
        donate_argnums=(1,))
    with mesh:
        return fn.lower(params_abs, cache_abs, inputs)


def lower_pair(arch: str, shape_name: str, mesh, mesh_cfg: MeshConfig,
               V: int = DEFAULT_V, aggregation: str = "allreduce",
               impl: str = "xla", remat: bool = True,
               capacity: float = 0.0, dispatch: str = ""):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    cfg = adapt_config(cfg, shape)
    if not remat:
        cfg = cfg.replace(remat=False)
    if capacity and cfg.moe:
        import dataclasses as _dc
        cfg = cfg.replace(moe=_dc.replace(cfg.moe, capacity_factor=capacity))
    if dispatch and cfg.moe:
        import dataclasses as _dc
        cfg = cfg.replace(moe=_dc.replace(cfg.moe, dispatch=dispatch))
    if shape.kind == "train":
        return lower_train(cfg, shape, mesh, mesh_cfg, V, aggregation,
                           impl=impl), cfg
    if shape.kind == "prefill":
        return lower_prefill(cfg, shape, mesh, mesh_cfg, impl=impl), cfg
    return lower_decode(cfg, shape, mesh, mesh_cfg), cfg


def analyse(lowered, compiled, cfg: ModelConfig, shape: InputShape,
            mesh, V: int) -> Dict:
    n_dev = mesh.devices.size
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # jax < 0.6 returns [per-module dict]
        cost = cost[0] if cost else {}
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    try:
        mem = compiled.memory_analysis()
        memory = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception as e:  # pragma: no cover
        memory = {"error": str(e)}
    colls = parse_collectives(compiled.as_text(), default_group=n_dev)
    csum = collective_summary(colls)
    # Roofline terms (seconds). cost_analysis is the per-device program.
    t_compute = flops_dev / fl.PEAK_FLOPS
    t_memory = bytes_dev / fl.HBM_BW
    t_coll = csum["total_wire_bytes"] / fl.ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mflops = fl.model_flops(cfg, shape, V if shape.kind == "train" else 1)
    return {
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "memory": memory,
        "collectives": csum,
        "terms_seconds": terms,
        "dominant": dominant,
        "model_flops": mflops,
        "hlo_flops_global": flops_dev * n_dev,
        "useful_flops_ratio": mflops / (flops_dev * n_dev) if flops_dev else None,
    }


def run_pair(arch: str, shape_name: str, mesh_name: str, out_dir: str,
             V: int = DEFAULT_V, aggregation: str = "allreduce",
             tag: str = "", impl: str = "xla", remat: bool = True,
             capacity: float = 0.0, dispatch: str = "") -> Dict:
    mesh_cfg = MeshConfig(multi_pod=(mesh_name == "multi"))
    mesh = make_production_mesh(multi_pod=mesh_cfg.multi_pod)
    rec: Dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "V": V, "aggregation": aggregation, "impl": impl,
                 "remat": remat, "capacity": capacity, "dispatch": dispatch,
                 "ok": False}
    t0 = time.time()
    try:
        shape = INPUT_SHAPES[shape_name]
        lowered, cfg = lower_pair(arch, shape_name, mesh, mesh_cfg, V,
                                  aggregation, impl=impl, remat=remat,
                                  capacity=capacity, dispatch=dispatch)
        rec["lower_seconds"] = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_seconds"] = time.time() - t1
        rec.update(analyse(lowered, compiled, cfg, shape, mesh, V))
        rec["ok"] = True
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_seconds"] = time.time() - t0
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"-{tag}" if tag else ""
        fn = os.path.join(
            out_dir, f"{arch}--{shape_name}--{mesh_name}{suffix}.json")
        with open(fn, "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--V", type=int, default=DEFAULT_V)
    ap.add_argument("--aggregation", default="allreduce")
    ap.add_argument("--tag", default="")
    ap.add_argument("--impl", default="xla")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--capacity", type=float, default=0.0)
    ap.add_argument("--dispatch", default="")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(INPUT_SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = 0
    for mesh_name in meshes:
        for arch in archs:
            for shape_name in shapes:
                rec = run_pair(arch, shape_name, mesh_name, args.out,
                               V=args.V, aggregation=args.aggregation,
                               tag=args.tag, impl=args.impl,
                               remat=not args.no_remat,
                               capacity=args.capacity,
                               dispatch=args.dispatch)
                if rec["ok"]:
                    t = rec["terms_seconds"]
                    print(f"OK   {arch:26s} {shape_name:12s} {mesh_name:6s} "
                          f"lower={rec['lower_seconds']:6.1f}s "
                          f"compile={rec['compile_seconds']:6.1f}s "
                          f"comp={t['compute']:.3e} mem={t['memory']:.3e} "
                          f"coll={t['collective']:.3e} dom={rec['dominant']}",
                          flush=True)
                else:
                    failures += 1
                    print(f"FAIL {arch:26s} {shape_name:12s} {mesh_name:6s} "
                          f"{rec['error']}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
