"""End-to-end federated training driver (deliverable b).

Runs DEFL (Algorithm 1) on a transformer architecture over synthetic token
data: M clients, V local steps per round, weighted FedAvg sync, simulated
wall-clock from the paper's delay model alongside real training.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
      --rounds 20 --clients 4 --seq 128 --defl

On the CPU container use --smoke (reduced config); the full configs are
exercised via dryrun.py.
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ComputeConfig, FedConfig, WirelessConfig
from repro.configs.registry import get_config
from repro.core import defl, delay
from repro.data import make_token_stream, token_batches
from repro.federated.client import make_local_update, stack_batches
from repro.federated.server import aggregate_updates
from repro.models import transformer as tfm
from repro.optim import sgd
from repro.utils.tree import tree_bytes


class TokenClientIterator:
    def __init__(self, stream, batch, seq, seed):
        self.stream, self.batch, self.seq = stream, batch, seq
        self.seed = seed
        self.step = 0

    def next_batch(self):
        self.step += 1
        toks = token_batches(self.stream, self.batch, self.seq, self.step,
                             self.seed)
        return {"tokens": toks}


def build_parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--V", type=int, default=0, help="0 = derive from theta")
    ap.add_argument("--defl", action="store_true",
                    help="optimize (b, theta) with the DEFL KKT plan")
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)
    cfg = get_config(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(args.seed)
    params = tfm.init_params(cfg, key)
    update_bits = tree_bytes(params) * 8

    fed = FedConfig(n_devices=args.clients, batch_size=args.batch,
                    lr=args.lr, seed=args.seed)
    pop = delay.draw_population(
        args.clients, ComputeConfig(), WirelessConfig(), args.seed,
        heterogeneity=0.2)
    if args.defl:
        plan = defl.make_plan(fed, pop, update_bits)
        fed = defl.plan_to_fedconfig(plan, fed)
        # Practical caps for the smoke-scale driver.
        fed = type(fed)(**{**fed.__dict__,
                           "batch_size": min(fed.batch_size, 64)})
        print(f"DEFL plan: b*={plan.b} theta*={plan.theta:.4f} V={plan.V} "
              f"H_pred={plan.H_pred:.1f} T_round={plan.T_round:.3f}s")
    V = args.V or fed.local_rounds

    streams = [make_token_stream(200_000, cfg.vocab_size, seed=args.seed + i)
               for i in range(args.clients)]
    iters = [TokenClientIterator(s, min(fed.batch_size, 64), args.seq,
                                 seed=i) for i, s in enumerate(streams)]

    loss_fn = functools.partial(tfm.loss_fn, cfg)
    opt = sgd(fed.lr)
    local_update = make_local_update(lambda p, b: loss_fn(p, b), opt)
    opt_states = [opt.init(params) for _ in range(args.clients)]
    T_cm, T_cp = delay.round_comm_time(
        update_bits, WirelessConfig(), pop.p, pop.h), \
        delay.round_compute_time(fed.batch_size, pop.G, pop.f)

    sim_time = 0.0
    for r in range(1, args.rounds + 1):
        t0 = time.time()
        deltas, losses = [], []
        for m in range(args.clients):
            batches = stack_batches(
                [jax.tree.map(jnp.asarray, iters[m].next_batch())
                 for _ in range(V)])
            new_p, opt_states[m], loss_v = local_update(
                params, opt_states[m], batches)
            deltas.append(jax.tree.map(lambda n, g: n - g, new_p, params))
            losses.append(float(jnp.mean(loss_v)))
        params = aggregate_updates(params, deltas,
                                   np.ones(args.clients))
        sim_time += delay.round_time(T_cm, T_cp, V)
        print(f"round {r:3d}  loss={np.mean(losses):.4f}  "
              f"sim_time={sim_time:9.2f}s  wall={time.time() - t0:6.2f}s",
              flush=True)
    return params


if __name__ == "__main__":
    main()
