"""Post-process dry-run JSONs: add analytic roofline terms (see
utils/analytic.py for why the raw HLO terms need them) and recompute the
dominant bottleneck from the combined estimate.

  PYTHONPATH=src python -m repro.launch.postprocess [dir]
"""
from __future__ import annotations

import glob
import json
import os
import sys

from repro.configs.base import INPUT_SHAPES, MeshConfig
from repro.configs.registry import get_config
from repro.launch.specs_inputs import adapt_config
from repro.utils import flops as fl
from repro.utils.analytic import analytic_costs


def process_file(fn: str) -> None:
    with open(fn) as f:
        rec = json.load(f)
    if not rec.get("ok"):
        return
    cfg = adapt_config(get_config(rec["arch"]), INPUT_SHAPES[rec["shape"]])
    shape = INPUT_SHAPES[rec["shape"]]
    mesh_cfg = MeshConfig(multi_pod=(rec["mesh"] == "multi"))
    V = rec.get("V", 4) if shape.kind == "train" else 1
    # Perf-variant knobs recorded by dryrun (defaults for baseline records).
    if not rec.get("remat", True):
        cfg = cfg.replace(remat=False)
    if rec.get("capacity") and cfg.moe:
        import dataclasses as _dc
        cfg = cfg.replace(moe=_dc.replace(cfg.moe,
                                          capacity_factor=rec["capacity"]))
    # Blocked-causal attention computes ~(S+block)/2S of the dense scores.
    ctx_f = 0.53 if rec.get("impl") == "blocked" else 1.0
    ana = analytic_costs(cfg, shape, mesh_cfg, V=V, attn_ctx_factor=ctx_f)
    n_dev = mesh_cfg.n_devices
    t_compute = ana["flops_per_device"] / fl.PEAK_FLOPS
    t_memory = ana["hbm_bytes_per_device"] / fl.HBM_BW
    # Collective: HLO-parsed (out-of-loop sync, counted correctly) +
    # analytic in-loop tensor-parallel traffic (under-counted by HLO).
    parsed = rec["collectives"]["total_wire_bytes"]
    t_coll = (parsed + ana["collective_inloop_wire_bytes_per_device"]) / fl.ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    rec["analytic"] = ana
    rec["terms_analytic_seconds"] = terms
    rec["dominant_analytic"] = max(terms, key=terms.get)
    rec["useful_flops_ratio_analytic"] = (
        rec["model_flops"] / ana["flops_global"] if ana["flops_global"] else None)
    with open(fn, "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main(dirs):
    for d in dirs:
        for fn in sorted(glob.glob(os.path.join(d, "*.json"))):
            process_file(fn)
        print(f"postprocessed {d}")


if __name__ == "__main__":
    main(sys.argv[1:] or ["experiments/dryrun"])
