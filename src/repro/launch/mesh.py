"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — dryrun.py must set XLA_FLAGS before any jax
device query.
"""
from __future__ import annotations

import jax

from repro.configs.base import MeshConfig


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) ('data', 'model') single pod — 256 chips;
    (2, 16, 16) ('pod', 'data', 'model') — 512 chips across 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_from_config(mc: MeshConfig):
    return make_production_mesh(multi_pod=mc.multi_pod)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CI-scale sharding tests (requires
    XLA_FLAGS=--xla_force_host_platform_device_count>=prod(shape))."""
    return jax.make_mesh(shape, axes)
