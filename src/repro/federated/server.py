"""Parameter-server side (Alg. 1 line 5): weighted aggregation + broadcast."""
from __future__ import annotations

from typing import Any, List, Sequence

import numpy as np

from repro.optim.api import apply_updates
from repro.utils.tree import tree_weighted_mean


def aggregate_updates(
    global_params: Any, deltas: List[Any], data_sizes: Sequence[int],
) -> Any:
    """FedAvg: w <- w + sum_m (D_m / D) * delta_m (Eq. 2 weighting)."""
    weights = np.asarray(data_sizes, dtype=np.float64)
    mean_delta = tree_weighted_mean(deltas, weights)
    return apply_updates(global_params, mean_delta)


def broadcast(global_params: Any, n_devices: int) -> List[Any]:
    """Broadcast the global model (identity copies; device placement is the
    mesh runtime's job in launch/train.py)."""
    return [global_params for _ in range(n_devices)]
