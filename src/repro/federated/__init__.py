from repro.federated import (
    client,
    compression,
    mesh_rounds,
    partition,
    server,
    simulation,
)
