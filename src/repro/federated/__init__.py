from repro.federated import (
    client,
    compression,
    experiment,
    mesh_rounds,
    partition,
    scenarios,
    server,
    simulation,
)
