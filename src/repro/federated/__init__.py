from repro.federated import (
    client,
    compression,
    experiment,
    mesh_rounds,
    partition,
    planner,
    scenarios,
    server,
    simulation,
    traces,
)
