from repro.federated import (
    client,
    compression,
    mesh_rounds,
    partition,
    scenarios,
    server,
    simulation,
)
