"""Update compression for the uplink ('talk' reduction — beyond-paper).

The paper fixes the update size s; this module makes s a design variable:
int8 stochastic-rounding quantization shrinks T_cm ~4x at an unbiased
gradient cost, and the DEFL optimizer re-solves with the smaller s (the
trade-off point moves toward 'talking' more often).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.quantize.ref import dequantize_ref, quantize_ref


# Values per scale: one fp32 scale per ROW-sized chunk.
ROW = 1024


def compress_update(update: Any, key, impl: str = "xla") -> Any:
    """Quantize a pytree of fp32 deltas into int8 + scales.

    The whole tree is quantized as ONE flat-concatenated kernel call: each
    leaf is padded to whole 1024-rows (so a row's scale never mixes
    leaves — per-leaf error bounds and the `compressed_bits` wire
    accounting are unchanged from the old per-leaf form), the padded
    leaves concatenate into one (rows, 1024) matrix, and a single
    quantize draws ONE noise tensor from ONE key and takes one scale pass
    over all rows. The old form dispatched several ops + a PRNG split per
    leaf per client, which batched to ~5x their single-member cost under
    the fleet vmap's extra leading axis on XLA:CPU (run_fleet lost its
    speedup on compressed configs); fused, compressed fleets batch like
    the rest of the round graph (bench_round_step.py's fleet_s8 row)."""
    leaves, treedef = jax.tree_util.tree_flatten(update)
    segs, meta = [], []
    for leaf in leaves:
        flat = leaf.reshape(-1)
        pad = (-flat.size) % ROW
        segs.append(jnp.pad(flat, (0, pad)))
        meta.append((leaf.shape, flat.size, (flat.size + pad) // ROW))
    rows = jnp.concatenate(segs).reshape(-1, ROW)
    if impl == "pallas":
        from repro.kernels.quantize import ops as q_ops

        q, scale = q_ops.quantize(rows, key)
    else:
        q, scale = quantize_ref(rows, key)
    return {"q": q, "scale": scale, "treedef": treedef, "meta": tuple(meta)}


def decompress_update(comp: Any, impl: str = "xla") -> Any:
    if impl == "pallas":
        from repro.kernels.quantize import ops as q_ops

        dequant = q_ops.dequantize
    else:
        dequant = dequantize_ref

    flat = dequant(comp["q"], comp["scale"]).reshape(-1)
    leaves, at = [], 0
    for shape, size, rows in comp["meta"]:
        leaves.append(flat[at : at + size].reshape(shape))
        at += rows * ROW
    return jax.tree_util.tree_unflatten(comp["treedef"], leaves)


def sequential_client_keys(key, n: int):
    """Per-client subkeys with the host loop's schedule: (key, sub) =
    split(key), n times. Both simulator backends derive quantizer keys
    through this, so the batched in-graph roundtrip draws bit-identical
    stochastic-rounding noise to the per-client host roundtrip."""
    subs = []
    for _ in range(n):
        key, sub = jax.random.split(key)
        subs.append(sub)
    return key, jnp.stack(subs)


def compressed_bits(update: Any) -> int:
    """Uplink bits for an int8-compressed update (payload + scales)."""
    total = 0
    for x in jax.tree_util.tree_leaves(update):
        n = int(np.prod(x.shape))
        total += n * 8 + int(np.ceil(n / 1024)) * 32
    return total


def raw_bits(update: Any) -> int:
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize * 8
        for x in jax.tree_util.tree_leaves(update))
