"""Update compression for the uplink ('talk' reduction — beyond-paper).

The paper fixes the update size s; this module makes s a design variable:
int8 stochastic-rounding quantization shrinks T_cm ~4x at an unbiased
gradient cost, and the DEFL optimizer re-solves with the smaller s (the
trade-off point moves toward 'talking' more often).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.quantize.ref import dequantize_ref, quantize_ref


def _leaf_quantize(x: jnp.ndarray, key, impl: str):
    flat = x.reshape(-1)
    # Row-chunked quantization: 1 scale per 1024 values.
    row = 1024
    pad = (-flat.size) % row
    rows = jnp.pad(flat, (0, pad)).reshape(-1, row)
    if impl == "pallas":
        from repro.kernels.quantize import ops as q_ops

        q, scale = q_ops.quantize(rows, key)
    else:
        q, scale = quantize_ref(rows, key)
    return {"q": q, "scale": scale, "shape": x.shape, "pad": pad}


def compress_update(update: Any, key, impl: str = "xla") -> Any:
    """Quantize a pytree of fp32 deltas into int8 + scales."""
    leaves, treedef = jax.tree_util.tree_flatten(update)
    keys = jax.random.split(key, len(leaves))
    return jax.tree_util.tree_unflatten(
        treedef, [_leaf_quantize(l, k, impl) for l, k in zip(leaves, keys)])


def decompress_update(comp: Any, impl: str = "xla") -> Any:
    if impl == "pallas":
        from repro.kernels.quantize import ops as q_ops

        dequant = q_ops.dequantize
    else:
        dequant = dequantize_ref

    def leaf(c):
        flat = dequant(c["q"], c["scale"]).reshape(-1)
        if c["pad"]:
            flat = flat[: flat.size - c["pad"]]
        return flat.reshape(c["shape"])

    return jax.tree.map(
        leaf, comp, is_leaf=lambda x: isinstance(x, dict) and "q" in x)


def sequential_client_keys(key, n: int):
    """Per-client subkeys with the host loop's schedule: (key, sub) =
    split(key), n times. Both simulator backends derive quantizer keys
    through this, so the batched in-graph roundtrip draws bit-identical
    stochastic-rounding noise to the per-client host roundtrip."""
    subs = []
    for _ in range(n):
        key, sub = jax.random.split(key)
        subs.append(sub)
    return key, jnp.stack(subs)


def compressed_bits(update: Any) -> int:
    """Uplink bits for an int8-compressed update (payload + scales)."""
    total = 0
    for x in jax.tree_util.tree_leaves(update):
        n = int(np.prod(x.shape))
        total += n * 8 + int(np.ceil(n / 1024)) * 32
    return total


def raw_bits(update: Any) -> int:
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize * 8
        for x in jax.tree_util.tree_leaves(update))
