"""Scenario engine: named edge-population scenarios for the FL simulator.

The paper's talk/work trade-off is governed by *heterogeneous* device
compute and *unreliable* wireless links (Eqs. 3-8, Fig. 2), but a single
`draw_population` knob can't express the populations that matter: compute-
skewed straggler cohorts, cell-edge devices with attenuated channels,
partial participation (per-round Bernoulli dropout and link failure), and
channels that drift over rounds. A `Scenario` bundles

  1. a *population draw* — per-device (G_m, f_m, p_m, h_m) with named
     skew knobs, feeding `core.delay` and `core.defl.make_plan`; and
  2. a *per-round realization stream* — participation masks and realized
     channel gains, consumed by the simulator (`simulation.Simulator`) on
     the host and fed to the compiled batched round step as traced array
     inputs (fixed shapes: no retrace, no host sync — see
     mesh_rounds.build_round_step). Stream position snapshots
     (`state`/`set_state`) ride in `SimState` for checkpoint/resume.

Registry access is by name (`scenarios.get("stragglers")`), shared by the
simulator, the benchmarks (`benchmarks/run.py --scenario <name>`), and
the tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, NamedTuple, Optional, Tuple, Union

import numpy as np

from repro.configs.base import ComputeConfig, FedConfig, WirelessConfig
from repro.core import defl, delay
from repro.federated.faults import FaultModel


# ---------------------------------------------------------------------------
# Per-round realization
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RoundRealization:
    """What one round of the scenario actually looked like.

    mask        (M,) bool — clients whose update reaches the aggregator
                (present AND upload succeeded, possibly after retries).
                Drives the FedAvg weights.
    clock_mask  (M,) bool — clients the synchronous server waits for
                (present, whether or not their upload then fails). Drives
                the Eq. 8 straggler max. mask is always a subset. Crashed
                clients are absent from BOTH masks (the server's
                heartbeat timeout knows not to wait for them).
    h           (M,) float — realized channel gains this round (drift
                applied), feeding the vectorized Eq. 6 uplink times.

    Fault-path extras (None unless the scenario has an active FaultModel):
    attempts    (M,) int — uplink transmissions made this round (first
                try + retries; 0 for absent clients). Every attempt's
                airtime and bits are accounted.
    h_att       (M, A) float — per-attempt realized channel gains
                (A = 1 + max_retries; column 0 equals h). Retries see
                freshly drawn AR(1) states.
    """

    mask: np.ndarray
    clock_mask: np.ndarray
    h: np.ndarray
    attempts: Optional[np.ndarray] = None
    h_att: Optional[np.ndarray] = None

    @property
    def n_participants(self) -> int:
        return int(self.mask.sum())


@dataclass(frozen=True)
class ChunkRealization:
    """A chunk of R consecutive round realizations, stacked on a leading
    round axis: mask/clock_mask (R, M) bool, h (R, M) float. This is the
    host-side source for the scan backend's device-resident scenario
    stream — one (R, M) transfer per chunk instead of R per-round ones.
    Fault-path extras stack the same way: attempts (R, M) int and h_att
    (R, M, A) float, or None when the scenario has no active FaultModel.
    """

    mask: np.ndarray
    clock_mask: np.ndarray
    h: np.ndarray
    attempts: Optional[np.ndarray] = None
    h_att: Optional[np.ndarray] = None

    def __len__(self) -> int:
        return self.mask.shape[0]

    @property
    def n_participants(self) -> np.ndarray:
        """(R,) int — updates that reached the aggregator each round."""
        return self.mask.sum(axis=1).astype(int)

    def round(self, i: int) -> RoundRealization:
        return RoundRealization(
            mask=self.mask[i], clock_mask=self.clock_mask[i], h=self.h[i],
            attempts=None if self.attempts is None else self.attempts[i],
            h_att=None if self.h_att is None else self.h_att[i])


class TraceRound(NamedTuple):
    """Per-round overlay a trace-driven stream feeds into `_draw_round`.

    present  (M,) bool or None — availability gate ANDed into presence
             (battery/thermal/diurnal state machines, or a replayed log's
             present set). None = everyone eligible.
    lost     (M,) bool or None — deterministic upload losses (a replayed
             log's lost set). ORed into the link-failure outcome and
             final: retransmission retries never resurrect a recorded
             loss. None = no recorded losses.
    h_scale  (M,) float or None — multiplier on this round's realized
             channel gains (device-class channel quality, recorded
             fading), applied after the AR(1) drift to h and to every
             retry attempt's gain. None = unscaled.
    """

    present: Optional[np.ndarray] = None
    lost: Optional[np.ndarray] = None
    h_scale: Optional[np.ndarray] = None


class ScenarioStream:
    """Stateful per-round realization generator (host-side, numpy only).

    Owns the dropout/link-failure draws and the AR(1) log-drift state of
    the channel. One stream per simulation run; seeded so all backends
    (and reruns) see identical realizations.

    Trace-driven subclasses (federated/traces.py) override `_trace_round`
    to overlay availability/loss/channel-quality signals per round; the
    base implementation returns None and consumes no randomness, so plain
    scenario streams keep the pre-trace wire format bit for bit.
    """

    def __init__(self, scenario: "Scenario", pop: delay.DevicePopulation,
                 seed: int = 0, cohort_size: Optional[int] = None,
                 cohort_weights=None):
        self.scenario = scenario
        self.pop = pop
        self._seed = seed
        self._rng = np.random.default_rng(np.random.SeedSequence([seed, 0xED6E]))
        self._log_drift = np.zeros(pop.n)
        # crash/rejoin lifecycle: rounds each client stays down (0 = alive)
        self._down = np.zeros(pop.n, dtype=np.int64)
        # Sampled participation: K-client cohorts drawn per round from a
        # dedicated RNG so the mask/drift wire format above stays
        # bit-identical to a dense (no-cohort) stream at the same seed.
        if cohort_size is not None and not 1 <= int(cohort_size) <= pop.n:
            raise ValueError(
                f"cohort_size must be in [1, {pop.n}], got {cohort_size}")
        self.cohort_size = None if cohort_size is None else int(cohort_size)
        self._cohort_weights = None
        if cohort_weights is not None:
            w = np.asarray(cohort_weights, np.float64)
            if w.shape != (pop.n,) or not np.all(w > 0):
                raise ValueError(
                    f"cohort_weights must be ({pop.n},) positive floats")
            self._cohort_weights = w
        self._cohort_rng = np.random.default_rng(
            np.random.SeedSequence([seed, 0xC047]))

    @property
    def _faults(self) -> Optional[FaultModel]:
        fm = self.scenario.faults
        return fm if (fm is not None and fm.active) else None

    # -- snapshot / restore (SimState checkpointing) ------------------------
    def state(self) -> dict:
        """Value snapshot of the stream position: the RNG bit-generator
        state, the AR(1) drift carry, and the crash/rejoin down-counters.
        A stream restored from this via `set_state` continues the
        realization sequence bit-identically — the simulator's SimState
        carries these snapshots so a saved run resumes on the exact
        mask/channel stream it left, mid-crash-epoch included."""
        return {"rng": self._rng.bit_generator.state,
                "log_drift": self._log_drift.copy(),
                "down": self._down.copy(),
                "cohort_rng": self._cohort_rng.bit_generator.state}

    def set_state(self, state: dict) -> None:
        self._rng.bit_generator.state = state["rng"]
        self._log_drift = np.asarray(state["log_drift"], float).copy()
        # pre-fault snapshots have no "down" key: tolerate them (all-up)
        down = state.get("down")
        self._down = (np.zeros(self.pop.n, dtype=np.int64) if down is None
                      else np.asarray(down, np.int64).copy())
        # pre-cohort snapshots have no "cohort_rng" key: re-seed fresh
        # (dense streams never consume this generator, so it's a no-op)
        crng = state.get("cohort_rng")
        if crng is None:
            self._cohort_rng = np.random.default_rng(
                np.random.SeedSequence([self._seed, 0xC047]))
        else:
            self._cohort_rng.bit_generator.state = crng

    # -- cohort sampling ----------------------------------------------------
    def draw_cohort(self) -> np.ndarray:
        """Draw this round's participant cohort: (K,) sorted int32 client
        ids. K = M (or no cohort configured) returns arange(M) WITHOUT
        consuming the cohort RNG — a K=M sampled stream is state-identical
        to a dense one, which is what the K=M bit-parity contract rests
        on. "uniform" takes the K smallest of M uniform keys; "weighted"
        is Gumbel top-K over the configured positive weights (exact
        weighted sampling without replacement). Sorting makes cohort
        lanes ascend in client id, so at K=M the lane order is exactly
        the dense client order.

        Over-provisioned cohorts (CohortSpec.spare) reuse this draw
        unchanged with cohort_size = K + spare: one random(M) vector is
        consumed regardless of K, so drawing K + spare candidates
        advances the cohort RNG exactly as drawing K would — spare=0 is
        structurally bit-identical to today. The feasible-fastest
        down-select to K happens in the Simulator, after fault
        realizations resolve M-wide."""
        M = self.pop.n
        K = M if self.cohort_size is None else self.cohort_size
        if K == M:
            return np.arange(M, dtype=np.int32)
        if self._cohort_weights is None:
            key = self._cohort_rng.random(M)
        else:
            u = self._cohort_rng.random(M)
            key = -(np.log(self._cohort_weights) - np.log(-np.log(u)))
        idx = np.argpartition(key, K)[:K]
        return np.sort(idx).astype(np.int32)

    def draw_cohorts(self, rounds: int) -> np.ndarray:
        """Next `rounds` cohorts stacked to (R, K) int32 — R sequential
        `draw_cohort()` calls, bit for bit (the cohort twin of the
        draw_chunk == R x next_round contract)."""
        if rounds == 0:
            K = (self.pop.n if self.cohort_size is None
                 else self.cohort_size)
            return np.empty((0, K), np.int32)
        return np.stack([self.draw_cohort() for _ in range(rounds)])

    # -- trace overlay hook -------------------------------------------------
    def _trace_round(self) -> Optional[TraceRound]:
        """Called exactly once at the top of `_draw_round`. Trace-driven
        subclasses return a TraceRound overlay (and may advance their own
        dedicated RNG/state machines); the base returns None, consuming
        nothing — the legacy wire format is untouched."""
        return None

    def _draw_round(self):
        """One round's raw draws: (uploaded, present, h, attempts, h_att).

        The draw order (trace overlay, crash, dropout, link failure,
        drift, then the retry attempts — each an M-vector from the shared
        RNG) is the stream's wire format: draw_chunk must consume the
        generator in exactly this per-round interleaving so a chunked run
        is bit-identical to a per-round run and the two call styles can
        be mixed on one stream. Every fault draw is gated on its knob, so
        a scenario without an active FaultModel consumes the RNG exactly
        as before faults existed (bit-identical legacy streams); the
        trace overlay draws from its own generator, never the shared one,
        so trace scenarios keep the same guarantee."""
        s, M = self.scenario, self.pop.n
        fm = self._faults
        tr = self._trace_round()
        present = np.ones(M, bool)
        if tr is not None and tr.present is not None:
            present &= tr.present
        if fm is not None and fm.crash_rate > 0:
            # alive -> crashed (down for rejoin_rounds) -> alive again
            crashed = (self._down == 0) & (self._rng.random(M) < fm.crash_rate)
            self._down[crashed] = fm.rejoin_rounds
            present &= self._down == 0
            self._down = np.maximum(self._down - 1, 0)
        if s.dropout > 0:
            present &= self._rng.random(M) >= s.dropout
        uploaded = present.copy()
        failed = np.zeros(M, bool)
        if tr is not None and tr.lost is not None:
            failed |= tr.lost
        if s.link_failure > 0:
            failed |= self._rng.random(M) < s.link_failure
        uploaded &= ~failed
        h = self.pop.h
        if s.drift_sigma > 0:
            self._log_drift = (s.drift_rho * self._log_drift
                               + self._rng.normal(0.0, s.drift_sigma, M))
            h = h * np.exp(self._log_drift)
        if tr is not None and tr.h_scale is not None:
            h = h * tr.h_scale
        if fm is None:
            return uploaded, present, h, None, None
        # Retransmission: up to max_retries re-attempts, each against a
        # freshly drawn AR(1) channel state. The retry drift rides a
        # transient copy — the next round's channel continues from the
        # attempt-0 state, so retries don't perturb the round-scale AR(1).
        A = fm.n_attempts
        h_att = np.empty((M, A), np.float64)
        h_att[:, 0] = h
        attempts = present.astype(np.int64)
        pending = present & failed
        if tr is not None and tr.lost is not None:
            # Recorded losses are final: the log says that upload never
            # arrived, so retries must not resurrect it.
            pending &= ~tr.lost
        log_d = self._log_drift.copy()
        for k in range(1, A):
            fail_k = np.zeros(M, bool)
            if s.link_failure > 0:
                fail_k = self._rng.random(M) < s.link_failure
            if s.drift_sigma > 0:
                log_d = (s.drift_rho * log_d
                         + self._rng.normal(0.0, s.drift_sigma, M))
                h_att[:, k] = self.pop.h * np.exp(log_d)
            else:
                h_att[:, k] = self.pop.h
            if tr is not None and tr.h_scale is not None:
                h_att[:, k] *= tr.h_scale
            attempts += pending
            uploaded |= pending & ~fail_k
            pending &= fail_k
        return uploaded, present, h, attempts, h_att

    def next_round(self) -> RoundRealization:
        uploaded, present, h, attempts, h_att = self._draw_round()
        return RoundRealization(mask=uploaded, clock_mask=present, h=h,
                                attempts=attempts, h_att=h_att)

    def draw_chunk(self, rounds: int) -> ChunkRealization:
        """Materialize the next `rounds` realizations as stacked (R, M)
        arrays (the scan backend's per-chunk scenario input).

        Per round the draws are vectorized across clients; across rounds
        the RNG is consumed in the same interleaved order as `next_round`
        (the AR(1) drift recursion is inherently sequential), so
        `draw_chunk(R)` equals R sequential `next_round()` calls bit for
        bit — property-tested in tests/test_scenarios.py — and advances
        the stream state identically."""
        draws = [self._draw_round() for _ in range(rounds)]
        fault = self._faults is not None
        return ChunkRealization(
            mask=np.stack([d[0] for d in draws]),
            clock_mask=np.stack([d[1] for d in draws]),
            h=np.stack([d[2] for d in draws]),
            attempts=np.stack([d[3] for d in draws]) if fault else None,
            h_att=np.stack([d[4] for d in draws]) if fault else None)


# ---------------------------------------------------------------------------
# Scenario definition
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Scenario:
    """A named edge-population scenario (all knobs default to 'off').

    Population knobs (one draw per simulation):
      compute_sigma        lognormal jitter on per-device G_m and f_m
      channel_sigma        lognormal jitter on per-device channel gain h_m
      straggler_frac       fraction of devices in the slow cohort
      straggler_slowdown   f_m divisor for the slow cohort (>1 = slower)
      cell_edge_frac       fraction of devices at the cell edge
      cell_edge_attenuation  h_m multiplier for the cell-edge cohort (<1)

    Per-round knobs (one realization per round):
      dropout        P(client absent this round)         — Bernoulli
      link_failure   P(upload lost | client present)     — Bernoulli
      drift_sigma    AR(1) innovation std of log channel drift
      drift_rho      AR(1) coefficient of the drift (persistence)

    Fault/recovery semantics (deadlines, retransmission with backoff,
    crash/rejoin lifecycle, divergence guards) layer on via `faults`
    (faults.FaultModel); None or an inactive model is bit-identical to
    the plain scenario.
    """

    name: str
    description: str
    compute_sigma: float = 0.0
    channel_sigma: float = 0.0
    straggler_frac: float = 0.0
    straggler_slowdown: float = 1.0
    cell_edge_frac: float = 0.0
    cell_edge_attenuation: float = 1.0
    dropout: float = 0.0
    link_failure: float = 0.0
    drift_sigma: float = 0.0
    drift_rho: float = 0.9
    faults: Optional[FaultModel] = None

    # -- population -------------------------------------------------------
    def population(
        self,
        n_devices: int,
        cc: Optional[ComputeConfig] = None,
        wc: Optional[WirelessConfig] = None,
        seed: int = 0,
    ) -> delay.DevicePopulation:
        """Draw the scenario's device population (Eqs. 3-4 parameters).

        Cohorts (stragglers, cell-edge) are the leading ceil(frac*M)
        devices of the draw — deterministic given the seed, so plans and
        realizations line up across reruns."""
        cc = cc or ComputeConfig()
        wc = wc or WirelessConfig()
        rng = np.random.default_rng(np.random.SeedSequence([seed, 0x5CE9]))
        G0 = delay.cycles_per_iteration(cc)
        f0 = delay.gpu_frequency(cc)
        jit = lambda sig: np.exp(rng.normal(0.0, sig, n_devices))  # noqa: E731
        G = G0 * (jit(self.compute_sigma) if self.compute_sigma else 1.0)
        f = f0 / (jit(self.compute_sigma) if self.compute_sigma else 1.0)
        h = wc.mean_channel_gain * (
            jit(self.channel_sigma) if self.channel_sigma else 1.0)
        G = np.broadcast_to(np.asarray(G, float), (n_devices,)).copy()
        f = np.broadcast_to(np.asarray(f, float), (n_devices,)).copy()
        h = np.broadcast_to(np.asarray(h, float), (n_devices,)).copy()
        if self.straggler_frac > 0 and self.straggler_slowdown != 1.0:
            k = int(np.ceil(self.straggler_frac * n_devices))
            f[:k] /= self.straggler_slowdown
        if self.cell_edge_frac > 0 and self.cell_edge_attenuation != 1.0:
            k = int(np.ceil(self.cell_edge_frac * n_devices))
            h[:k] *= self.cell_edge_attenuation
        return delay.DevicePopulation(
            G=G, f=f, p=np.full(n_devices, wc.tx_power_w), h=h)

    # -- per-round stream -------------------------------------------------
    def stream(self, pop: delay.DevicePopulation, seed: int = 0,
               cohort_size: Optional[int] = None,
               cohort_weights=None) -> ScenarioStream:
        return ScenarioStream(self, pop, seed, cohort_size=cohort_size,
                              cohort_weights=cohort_weights)

    @property
    def expected_participation(self) -> float:
        """E[fraction of clients whose update arrives] per round.

        With an active FaultModel, retransmission turns one link-failure
        draw into up-to-A independent ones (success 1 - q^A) and the
        crash/rejoin chain caps availability at 1/(1 + crash_rate *
        rejoin_rounds); without one this reduces exactly to the legacy
        (1 - dropout)(1 - link_failure)."""
        fm = self.faults if (self.faults is not None and self.faults.active) \
            else None
        if fm is None:
            return (1.0 - self.dropout) * (1.0 - self.link_failure)
        return (fm.availability() * (1.0 - self.dropout)
                * fm.link_success(self.link_failure))

    def replace(self, **kw) -> "Scenario":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Scenario] = {}


def register(scenario: Scenario) -> Scenario:
    if scenario.name in _REGISTRY:
        raise ValueError(f"scenario {scenario.name!r} already registered")
    _REGISTRY[scenario.name] = scenario
    return scenario


def get(name: Union[str, Scenario]) -> Scenario:
    if isinstance(name, Scenario):
        return name
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; registered: {names()}") from None


def names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


register(Scenario(
    "uniform",
    "Paper baseline: homogeneous devices, ideal links, full participation.",
))
register(Scenario(
    "stragglers",
    "Compute-skewed: 20% of devices run 4x slower (plus mild lognormal "
    "compute jitter) — the Eq. 5 straggler max binds on the slow cohort.",
    compute_sigma=0.2, straggler_frac=0.2, straggler_slowdown=4.0,
))
register(Scenario(
    "cell_edge",
    "Channel-skewed: 30% of devices sit at the cell edge with ~13 dB "
    "pathloss penalty — the Eq. 7 uplink max binds on the edge cohort.",
    channel_sigma=0.3, cell_edge_frac=0.3, cell_edge_attenuation=0.05,
))
register(Scenario(
    "dropout",
    "Partial participation: per-round Bernoulli absence (30%) and upload "
    "loss (5%) over a mildly heterogeneous population.",
    compute_sigma=0.2, channel_sigma=0.2, dropout=0.3, link_failure=0.05,
))
register(Scenario(
    "drifting",
    "Drifting channel: per-round AR(1) log-drift of every uplink gain "
    "(rho=0.9, sigma=0.2) — T_cm varies round to round.",
    channel_sigma=0.3, drift_sigma=0.2, drift_rho=0.9,
))
register(Scenario(
    "hetero_storm",
    "Everything at once: straggler cohort, cell-edge cohort, dropout, "
    "link failure and channel drift — the stress population.",
    compute_sigma=0.3, channel_sigma=0.3,
    straggler_frac=0.2, straggler_slowdown=3.0,
    cell_edge_frac=0.2, cell_edge_attenuation=0.1,
    dropout=0.2, link_failure=0.05, drift_sigma=0.15, drift_rho=0.9,
))
register(Scenario(
    "unreliable_edge",
    "Production failure semantics: lossy drifting links with up-to-2 "
    "retransmissions (exponential backoff), a 1.5x-nominal round "
    "deadline that cuts stragglers out of aggregation, and a crash/"
    "rejoin lifecycle (5% crash rate, 3-round heartbeat gap) over a "
    "heterogeneous straggler population.",
    compute_sigma=0.25, channel_sigma=0.25,
    straggler_frac=0.2, straggler_slowdown=3.0,
    dropout=0.1, link_failure=0.2, drift_sigma=0.15, drift_rho=0.9,
    faults=FaultModel(deadline_factor=1.5, max_retries=2,
                      backoff_base=0.05, crash_rate=0.05, rejoin_rounds=3),
))


# ---------------------------------------------------------------------------
# DEFL re-planning against the realized population
# ---------------------------------------------------------------------------


def plan_for_scenario(
    fed: FedConfig,
    scenario: Union[str, Scenario],
    update_bits: float,
    cc: Optional[ComputeConfig] = None,
    wc: Optional[WirelessConfig] = None,
    seed: int = 0,
    method: str = "closed_form",
    cohort_size: Optional[int] = None,
    spare: int = 0,
) -> defl.DEFLPlan:
    """Solve Alg. 1 against the scenario's realized population.

    The straggler maxes (Eqs. 5/7) are taken over the drawn population —
    a straggler or cell-edge cohort shifts (b*, theta*) — and expected
    partial participation shrinks the effective M in the Eq. 12 round-
    count model (fewer updates per round average into the global model).
    With `cohort_size=K` (sampled participation) the Eq. 12 effective M
    is based on the K-client cohort instead of the population, while the
    Eq. 5/7 straggler maxes stay population-wide (any client can be
    drawn) — see defl.make_plan.

    A scenario whose FaultModel sets a round deadline re-solves under the
    truncated delay model (defl.deadline_plan): the unconstrained plan is
    solved first, a `deadline_factor` is resolved against its nominal
    round time (one-step fixed point — the Simulator resolves against the
    final fed's own nominal, so a planned spec can differ slightly; pass
    an absolute `deadline` for exact agreement), and (b, V) are re-derived
    over the deadline-feasible region."""
    scenario = get(scenario)
    pop = scenario.population(fed.n_devices, cc, wc, seed)
    plan = defl.make_plan(fed, pop, update_bits, wireless=wc, method=method,
                          participation=scenario.expected_participation,
                          cohort_size=cohort_size)
    fm = scenario.faults
    if fm is not None and fm.active and (
            fm.deadline is not None or fm.deadline_factor is not None):
        D = fm.resolve_deadline(plan.T_round)
        plan = defl.deadline_plan(
            fed, pop, update_bits, D, wireless=wc,
            participation=scenario.expected_participation,
            cohort_size=cohort_size, spare=spare)
    return plan
