"""Trace-driven production scenarios: device classes, state machines,
and deterministic log replay.

The registry scenarios (scenarios.py) describe populations with a handful
of statistical knobs. Production edge fleets are messier: a fleet is a
*mix of device classes* (phones, tablets, battery-less IoT gateways) whose
availability follows time-of-day waves and whose participation is gated by
battery and thermal state machines. This module compiles that behavior
into the existing `ScenarioStream` wire format — per-round masks /
clock-masks / realized gains as stacked (R, M) arrays — via the
`ScenarioStream._trace_round` hook, so trace-driven traffic runs on the
unchanged scan backend, composes with `FaultModel` retransmission /
crash-rejoin and `CohortSpec` sampling, and checkpoint/resumes
bit-identically (the trace state machines ride the stream snapshot).

Two scenario sources:

  * `TraceScenario` — generative: a tuple of frozen `DeviceClassSpec`s
    (fleet fractions, compute/channel scaling, diurnal availability wave,
    battery and thermal state machines). `TraceStream` advances the
    machines one tick per round, drawing exactly two (M,) vectors per
    round from a dedicated RNG stream (SeedSequence tag 0x7ACE) — the
    shared scenario RNG is never touched, so the dropout/link-failure/
    drift draws stay bit-identical to a plain scenario at the same seed.

  * `ReplayScenario` + `TraceSpec` — replay: a recorded JSONL device-state
    log (one object per round: present ids, lost ids, optional per-device
    channel scale; optional leading meta line with fleet size and
    per-device compute/channel scales) replayed deterministically — no
    randomness at all beyond the base scenario knobs, which default off.

`record_trace` closes the loop: run any scenario's stream, serialize what
happened to JSONL, and replay it later (tests assert recorded == replayed
masks bit for bit).
"""
from __future__ import annotations

import functools
import json
import os
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro.configs.base import ComputeConfig, WirelessConfig
from repro.core import delay
from repro.federated.scenarios import (
    Scenario, ScenarioStream, TraceRound, register,
)

_TWO_PI = 2.0 * np.pi
_TRACE_TAG = 0x7ACE  # SeedSequence stream tag for trace state machines


# ---------------------------------------------------------------------------
# Device classes (generative traces)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DeviceClassSpec:
    """One device class in a trace-driven fleet.

    Fleet composition / hardware:
      frac            fraction of the fleet in this class (normalized
                      across classes; devices are the leading blocks of
                      the population, mirroring scenario cohorts)
      compute_scale   slowdown on the compute slope G/f (>1 = slower);
                      applied as an f divisor so Eq. 3 sees it directly
      channel_scale   multiplier on the mean channel gain h (<1 = worse)
      compute_sigma   per-device lognormal jitter on the compute slope
      channel_sigma   per-device lognormal jitter on the channel gain

    Diurnal availability wave (time-of-day t in [0, 1), 0 = midnight):
      avail_base      mean P(device wants to participate)
      avail_amp       wave amplitude: avail = base + amp*sin(2pi(t-phase))
      avail_phase     phase offset in fractions of a day

    Battery state machine (charge in [0, 1], per-round deltas):
      battery_drain       charge burned by a round of training
      battery_idle_drain  charge burned idling
      battery_charge      charge gained per round while plugged in
      battery_min         participation cutoff (device sits out below it)
      plug_day/plug_night P(plugged in) at solar noon / midnight
                          (interpolated through the day)

    Thermal state machine (heat in [0, 1]):
      heat_per_round   heat added by a round of training
      cool_per_round   passive cooling per round
      thermal_limit    participation cutoff (device throttles above it)

    Battery-less mains devices: battery_min=0, heat_per_round=0.
    """

    name: str
    frac: float
    compute_scale: float = 1.0
    channel_scale: float = 1.0
    compute_sigma: float = 0.0
    channel_sigma: float = 0.0
    avail_base: float = 0.9
    avail_amp: float = 0.0
    avail_phase: float = 0.0
    battery_drain: float = 0.01
    battery_idle_drain: float = 0.001
    battery_charge: float = 0.05
    battery_min: float = 0.2
    plug_day: float = 0.05
    plug_night: float = 0.6
    heat_per_round: float = 0.0
    cool_per_round: float = 0.05
    thermal_limit: float = 0.8

    def __post_init__(self):
        if not self.frac > 0:
            raise ValueError(f"class {self.name!r}: frac must be > 0, "
                             f"got {self.frac}")
        if not (self.compute_scale > 0 and self.channel_scale > 0):
            raise ValueError(f"class {self.name!r}: compute_scale and "
                             "channel_scale must be > 0")
        for knob in ("avail_base", "battery_min", "plug_day", "plug_night",
                     "thermal_limit"):
            v = getattr(self, knob)
            if not 0.0 <= v <= 1.0:
                raise ValueError(
                    f"class {self.name!r}: {knob} must be in [0, 1], got {v}")


PHONE = DeviceClassSpec(
    "phone", frac=0.6,
    compute_sigma=0.15, channel_sigma=0.2,
    avail_base=0.75, avail_amp=0.2, avail_phase=0.3,
    battery_drain=0.02, battery_idle_drain=0.002, battery_charge=0.06,
    battery_min=0.2, plug_day=0.1, plug_night=0.8,
    heat_per_round=0.08, cool_per_round=0.05, thermal_limit=0.85)
TABLET = DeviceClassSpec(
    "tablet", frac=0.25,
    compute_scale=1.6, compute_sigma=0.15, channel_sigma=0.2,
    avail_base=0.6, avail_amp=0.3, avail_phase=0.45,
    battery_drain=0.015, battery_idle_drain=0.001, battery_charge=0.08,
    battery_min=0.15, plug_day=0.2, plug_night=0.7,
    heat_per_round=0.05, cool_per_round=0.06, thermal_limit=0.9)
IOT = DeviceClassSpec(
    "iot", frac=0.15,
    compute_scale=4.0, channel_scale=0.3, channel_sigma=0.3,
    avail_base=0.95,  # mains-powered gateway: always on, no battery/heat
    battery_min=0.0, battery_drain=0.0, battery_idle_drain=0.0,
    heat_per_round=0.0)


@dataclass(frozen=True)
class TraceScenario(Scenario):
    """Generative trace scenario: a device-class fleet with per-round
    battery/thermal/diurnal state machines layered over the base
    Scenario's per-round knobs (dropout/link_failure/drift/faults all
    still apply — the trace overlay gates *presence* and scales the
    channel; the base knobs keep drawing from the shared RNG exactly as
    a plain scenario would).

      classes        fleet composition (fracs normalized)
      round_seconds  wall-clock seconds one FL round represents — with
                     day_seconds this sets how fast the diurnal wave
                     sweeps (86400/round_seconds rounds per day)
      start_frac     time of day at round 0 (0 = midnight, 0.5 = noon)
      battery_init   uniform initial-charge range at stream start
    """

    classes: Tuple[DeviceClassSpec, ...] = (PHONE, TABLET, IOT)
    round_seconds: float = 1800.0
    day_seconds: float = 86400.0
    start_frac: float = 0.0
    battery_init: Tuple[float, float] = (0.5, 1.0)

    def __post_init__(self):
        if not self.classes:
            raise ValueError("TraceScenario needs at least one DeviceClassSpec")
        if not (self.round_seconds > 0 and self.day_seconds > 0):
            raise ValueError("round_seconds and day_seconds must be > 0")
        lo, hi = self.battery_init
        if not 0.0 <= lo <= hi <= 1.0:
            raise ValueError(f"battery_init must be 0 <= lo <= hi <= 1, "
                             f"got {self.battery_init}")

    # -- fleet layout -------------------------------------------------------
    def class_fracs(self) -> np.ndarray:
        f = np.asarray([c.frac for c in self.classes], float)
        return f / f.sum()

    def class_index(self, n_devices: int) -> np.ndarray:
        """(M,) int class assignment: leading contiguous blocks sized by
        largest-remainder apportionment of the normalized fracs —
        deterministic, and every class with frac > 0 gets at least the
        rounding it earns (ties go to the earlier class)."""
        fr = self.class_fracs() * n_devices
        counts = np.floor(fr).astype(int)
        rem = n_devices - counts.sum()
        if rem > 0:
            order = np.argsort(-(fr - counts), kind="stable")
            counts[order[:rem]] += 1
        return np.repeat(np.arange(len(self.classes)), counts)

    # -- population ---------------------------------------------------------
    def population(self, n_devices, cc=None, wc=None, seed: int = 0):
        """Per-class scaled draw of (G, f, p, h): class compute_scale
        divides f (so the Eq. 3 slope G/f scales up), channel_scale
        multiplies h, and per-class lognormal jitter rides a dedicated
        RNG stream (tag 0x7C1A) — the base Scenario population draw is
        not consulted, so the base statistical knobs stay zero here."""
        cc = cc or ComputeConfig()
        wc = wc or WirelessConfig()
        rng = np.random.default_rng(np.random.SeedSequence([seed, 0x7C1A]))
        G0 = delay.cycles_per_iteration(cc)
        f0 = delay.gpu_frequency(cc)
        cls = self.class_index(n_devices)
        c_scale = np.asarray([c.compute_scale for c in self.classes])[cls]
        h_scale = np.asarray([c.channel_scale for c in self.classes])[cls]
        c_sig = np.asarray([c.compute_sigma for c in self.classes])[cls]
        h_sig = np.asarray([c.channel_sigma for c in self.classes])[cls]
        c_jit = np.exp(rng.normal(0.0, 1.0, n_devices) * c_sig)
        h_jit = np.exp(rng.normal(0.0, 1.0, n_devices) * h_sig)
        G = np.full(n_devices, G0, float)
        f = f0 / (c_scale * c_jit)
        h = wc.mean_channel_gain * h_scale * h_jit
        return delay.DevicePopulation(
            G=G, f=f, p=np.full(n_devices, wc.tx_power_w), h=h)

    # -- stream -------------------------------------------------------------
    def stream(self, pop, seed: int = 0, cohort_size=None,
               cohort_weights=None) -> "TraceStream":
        return TraceStream(self, pop, seed, cohort_size=cohort_size,
                           cohort_weights=cohort_weights)

    @property
    def expected_participation(self) -> float:
        """Mean-field estimate: the class-frac-weighted mean availability
        (the diurnal wave averages out over a day) times the base
        scenario's dropout/link/fault factor. The battery/thermal gates
        shave this further when drain outruns charging; the planner's
        rolling estimates (planner.PlannerService) observe the realized
        rate instead of trusting this prior."""
        avail = float(np.dot(self.class_fracs(),
                             [c.avail_base for c in self.classes]))
        return avail * super().expected_participation


class TraceStream(ScenarioStream):
    """ScenarioStream whose `_trace_round` overlay runs the device-class
    state machines.

    Wire-format contract: exactly two (M,) uniform vectors per round from
    the dedicated trace RNG (availability intent, plugged-in), in that
    order — so `draw_chunk(R)` == R `next_round()` calls bit for bit, and
    the shared scenario RNG sequence is untouched (a TraceScenario with
    trace machinery disabled would draw identically to a plain Scenario).
    The battery/thermal vectors, tick counter, and trace RNG state ride
    the `state()` snapshot for bit-identical checkpoint/resume.
    """

    def __init__(self, scenario: TraceScenario, pop, seed: int = 0,
                 cohort_size=None, cohort_weights=None):
        super().__init__(scenario, pop, seed, cohort_size=cohort_size,
                         cohort_weights=cohort_weights)
        cls = scenario.class_index(pop.n)

        def per_dev(attr):
            return np.asarray(
                [getattr(c, attr) for c in scenario.classes], float)[cls]

        self._avail_base = per_dev("avail_base")
        self._avail_amp = per_dev("avail_amp")
        self._avail_phase = per_dev("avail_phase")
        self._b_drain = per_dev("battery_drain")
        self._b_idle = per_dev("battery_idle_drain")
        self._b_charge = per_dev("battery_charge")
        self._b_min = per_dev("battery_min")
        self._plug_day = per_dev("plug_day")
        self._plug_night = per_dev("plug_night")
        self._heat = per_dev("heat_per_round")
        self._cool = per_dev("cool_per_round")
        self._t_limit = per_dev("thermal_limit")
        self._reset_trace()

    def _reset_trace(self) -> None:
        """(Re-)initialize the state machines as at stream construction:
        fresh trace RNG, uniform initial battery draw, cold devices."""
        self._trace_rng = np.random.default_rng(
            np.random.SeedSequence([self._seed, _TRACE_TAG]))
        lo, hi = self.scenario.battery_init
        self._battery = lo + (hi - lo) * self._trace_rng.random(self.pop.n)
        self._thermal = np.zeros(self.pop.n)
        self._tick = 0

    # -- snapshot / restore -------------------------------------------------
    def state(self) -> dict:
        s = super().state()
        s["trace"] = {"rng": self._trace_rng.bit_generator.state,
                      "battery": self._battery.copy(),
                      "thermal": self._thermal.copy(),
                      "tick": self._tick}
        return s

    def set_state(self, state: dict) -> None:
        super().set_state(state)
        tr = state.get("trace")
        if tr is None:  # snapshot from a non-trace stream: start fresh
            self._reset_trace()
            return
        self._trace_rng.bit_generator.state = tr["rng"]
        self._battery = np.asarray(tr["battery"], float).copy()
        self._thermal = np.asarray(tr["thermal"], float).copy()
        self._tick = int(tr["tick"])

    # -- the overlay --------------------------------------------------------
    def _trace_round(self) -> TraceRound:
        sc: TraceScenario = self.scenario
        M = self.pop.n
        t = (sc.start_frac
             + self._tick * sc.round_seconds / sc.day_seconds) % 1.0
        # daylight in [0, 1]: 0 at midnight, 1 at solar noon
        day = 0.5 * (1.0 - np.cos(_TWO_PI * t))
        avail = np.clip(
            self._avail_base
            + self._avail_amp * np.sin(_TWO_PI * (t - self._avail_phase)),
            0.0, 1.0)
        wants = self._trace_rng.random(M) < avail          # draw 1 of 2
        plug_p = self._plug_night + (self._plug_day - self._plug_night) * day
        plugged = self._trace_rng.random(M) < plug_p       # draw 2 of 2
        healthy = (self._battery >= self._b_min) & \
                  (self._thermal <= self._t_limit)
        present = wants & healthy
        # advance the machines: training drains and heats, idling sips,
        # plugged-in devices charge, everyone cools a little
        drain = np.where(present, self._b_drain, self._b_idle)
        self._battery = np.clip(
            self._battery + np.where(plugged, self._b_charge, 0.0) - drain,
            0.0, 1.0)
        self._thermal = np.clip(
            self._thermal + np.where(present, self._heat, 0.0) - self._cool,
            0.0, 1.0)
        self._tick += 1
        return TraceRound(present=present)


# ---------------------------------------------------------------------------
# JSONL trace replay
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TraceSpec:
    """A recorded device-state log to replay deterministically.

    path    JSONL file. Optional first line {"meta": {...}} with
            "devices" (fleet size, validated against the run) and
            optional per-device "compute_scale"/"channel_scale" lists
            (applied to the replay population). Every other line is one
            round: {"present": [ids], "lost": [ids], "h_scale": [M
            floats]} — "lost" and "h_scale" optional.
    on_end  what to do when the run outlives the log:
            'cycle' (wrap around), 'hold' (repeat the last round), or
            'error' (raise — the run must fit the log).
    """

    path: str
    on_end: str = "cycle"

    def __post_init__(self):
        if self.on_end not in ("cycle", "hold", "error"):
            raise ValueError(
                f"TraceSpec.on_end must be 'cycle', 'hold' or 'error', "
                f"got {self.on_end!r}")

    @property
    def name(self) -> str:
        base = os.path.basename(self.path)
        return f"trace:{base}"


def write_trace(path: str, rounds, meta: Optional[dict] = None) -> None:
    """Serialize per-round records (dicts in TraceSpec schema) to JSONL,
    with an optional leading meta line."""
    with open(path, "w") as fh:
        if meta is not None:
            fh.write(json.dumps({"meta": meta}) + "\n")
        for rec in rounds:
            fh.write(json.dumps(rec) + "\n")
    _load_trace.cache_clear()


@functools.lru_cache(maxsize=32)
def _load_trace(path: str):
    """Parse a JSONL trace once per path: (meta dict, tuple of records)."""
    meta, records = {}, []
    with open(path) as fh:
        for ln, line in enumerate(fh):
            line = line.strip()
            if not line:
                continue
            obj = json.loads(line)
            if "meta" in obj:
                if records or meta:
                    raise ValueError(
                        f"{path}:{ln + 1}: meta must be the first line")
                meta = dict(obj["meta"])
                continue
            if "present" not in obj:
                raise ValueError(
                    f"{path}:{ln + 1}: round record needs a 'present' list")
            records.append(obj)
    if not records:
        raise ValueError(f"{path}: trace has no round records")
    return meta, tuple(records)


@dataclass(frozen=True)
class ReplayScenario(Scenario):
    """Scenario that replays a recorded JSONL trace through the stream
    overlay. The base per-round knobs default off, so the replay is fully
    deterministic (no RNG draws consumed); setting them (or faults)
    layers fresh stochastic behavior over the recorded masks — e.g.
    replaying production presence under a synthetic crash model."""

    trace: Optional[TraceSpec] = None

    def __post_init__(self):
        if self.trace is None:
            raise ValueError("ReplayScenario requires a TraceSpec")

    def _meta(self) -> dict:
        return _load_trace(self.trace.path)[0]

    def population(self, n_devices, cc=None, wc=None, seed: int = 0):
        """Base scenario draw, then the meta per-device compute/channel
        scales (if recorded). compute_scale divides f — only the Eq. 3
        slope G/f is observable in the delay model, so scaling f
        reproduces recorded slopes exactly."""
        meta = self._meta()
        rec_m = meta.get("devices")
        if rec_m is not None and int(rec_m) != int(n_devices):
            raise ValueError(
                f"trace {self.trace.path!r} records {rec_m} devices but the "
                f"run asks for {n_devices} (fields n_devices, trace)")
        pop = super().population(n_devices, cc, wc, seed)
        cs = meta.get("compute_scale")
        hs = meta.get("channel_scale")
        f, h = pop.f, pop.h
        if cs is not None:
            f = f / np.asarray(cs, float)
        if hs is not None:
            h = h * np.asarray(hs, float)
        return delay.DevicePopulation(G=pop.G, f=f, p=pop.p, h=h)

    def stream(self, pop, seed: int = 0, cohort_size=None,
               cohort_weights=None) -> "ReplayStream":
        return ReplayStream(self, pop, seed, cohort_size=cohort_size,
                            cohort_weights=cohort_weights)

    @property
    def expected_participation(self) -> float:
        """Empirical: mean fraction of devices whose update arrived per
        recorded round (falls back to the base estimate if the meta has
        no fleet size), times the base dropout/link/fault factor."""
        meta, records = _load_trace(self.trace.path)
        m = meta.get("devices")
        base = super().expected_participation
        if m is None:
            return base
        arrived = [len(set(r["present"]) - set(r.get("lost", ())))
                   for r in records]
        return float(np.mean(arrived) / float(m)) * base


class ReplayStream(ScenarioStream):
    """Replays the recorded per-round present/lost/h_scale overlay.

    Consumes no randomness: the cursor is the only state, carried in the
    `state()` snapshot, so checkpoint/resume lands on the exact recorded
    round it left."""

    def __init__(self, scenario: ReplayScenario, pop, seed: int = 0,
                 cohort_size=None, cohort_weights=None):
        super().__init__(scenario, pop, seed, cohort_size=cohort_size,
                         cohort_weights=cohort_weights)
        meta, self._records = _load_trace(scenario.trace.path)
        rec_m = meta.get("devices")
        if rec_m is not None and int(rec_m) != pop.n:
            raise ValueError(
                f"trace {scenario.trace.path!r} records {rec_m} devices but "
                f"the population has {pop.n}")
        self._cursor = 0

    def state(self) -> dict:
        s = super().state()
        s["replay_cursor"] = self._cursor
        return s

    def set_state(self, state: dict) -> None:
        super().set_state(state)
        self._cursor = int(state.get("replay_cursor", 0))

    def _ids_to_mask(self, ids, what: str) -> np.ndarray:
        mask = np.zeros(self.pop.n, bool)
        idx = np.asarray(ids, int)
        if idx.size and (idx.min() < 0 or idx.max() >= self.pop.n):
            raise ValueError(
                f"trace round {self._cursor}: {what} id out of range "
                f"[0, {self.pop.n})")
        mask[idx] = True
        return mask

    def _trace_round(self) -> TraceRound:
        n = len(self._records)
        i = self._cursor
        if i >= n:
            mode = self.scenario.trace.on_end
            if mode == "error":
                raise RuntimeError(
                    f"trace {self.scenario.trace.path!r} exhausted after "
                    f"{n} rounds (on_end='error')")
            i = i % n if mode == "cycle" else n - 1
        rec = self._records[i]
        present = self._ids_to_mask(rec["present"], "present")
        lost = (self._ids_to_mask(rec["lost"], "lost")
                if rec.get("lost") else None)
        h_scale = None
        if rec.get("h_scale") is not None:
            h_scale = np.asarray(rec["h_scale"], float)
            if h_scale.shape != (self.pop.n,):
                raise ValueError(
                    f"trace round {self._cursor}: h_scale must have "
                    f"{self.pop.n} entries, got {h_scale.shape}")
        self._cursor += 1
        return TraceRound(present=present, lost=lost, h_scale=h_scale)


def replay_scenario(spec: TraceSpec, name: Optional[str] = None,
                    **scenario_kw) -> ReplayScenario:
    """Build a ReplayScenario for a TraceSpec (extra Scenario knobs — e.g.
    faults — pass through)."""
    return ReplayScenario(
        name=name or spec.name,
        description=f"deterministic replay of {spec.path}",
        trace=spec, **scenario_kw)


def record_trace(scenario, n_devices: int, rounds: int, path: str,
                 seed: int = 0, cc: Optional[ComputeConfig] = None,
                 wc: Optional[WirelessConfig] = None) -> TraceSpec:
    """Run `scenario`'s stream for `rounds` and serialize what happened as
    a replayable JSONL trace: per-round present/lost ids and the realized
    channel as a scale relative to the drawn population, plus a meta line
    with the fleet size and per-device compute/channel scales relative to
    the nominal homogeneous device — so a fresh `ReplayScenario` (whose
    base population is homogeneous) reproduces the recorded compute
    slopes exactly and the recorded masks bit for bit."""
    from repro.federated import scenarios as _scenarios
    scenario = _scenarios.get(scenario)
    cc = cc or ComputeConfig()
    wc = wc or WirelessConfig()
    pop = scenario.population(n_devices, cc, wc, seed)
    stream = scenario.stream(pop, seed)
    G0 = delay.cycles_per_iteration(cc)
    f0 = delay.gpu_frequency(cc)
    slope0 = G0 / f0
    meta = {
        "devices": int(n_devices),
        "source": getattr(scenario, "name", "scenario"),
        "seed": int(seed),
        "compute_scale": ((pop.G / pop.f) / slope0).tolist(),
        "channel_scale": (pop.h / wc.mean_channel_gain).tolist(),
    }
    recs = []
    for _ in range(rounds):
        r = stream.next_round()
        present = np.flatnonzero(r.clock_mask)
        lost = np.flatnonzero(r.clock_mask & ~r.mask)
        rec = {"present": present.tolist()}
        if lost.size:
            rec["lost"] = lost.tolist()
        if not np.array_equal(r.h, pop.h):
            rec["h_scale"] = (r.h / pop.h).tolist()
        recs.append(rec)
    write_trace(path, recs, meta=meta)
    return TraceSpec(path=path)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

register(TraceScenario(
    "diurnal_edge",
    "Trace-driven production fleet: 60% phones / 25% tablets / 15% IoT "
    "gateways with per-class compute/channel scaling, diurnal "
    "availability waves, battery + thermal participation gates "
    "(30-minute rounds), over mildly lossy drifting links.",
    classes=(PHONE, TABLET, IOT),
    round_seconds=1800.0, start_frac=0.375,  # round 0 at 09:00
    link_failure=0.05, drift_sigma=0.1, drift_rho=0.9,
))
