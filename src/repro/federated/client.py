"""Client-side local computation (Alg. 1 line 3): V local mini-batch SGD
steps toward a theta-approximate local solution."""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.api import Optimizer, apply_updates


def make_local_update(loss_fn: Callable, opt: Optimizer):
    """Build a jitted V-step local update.

    loss_fn(params, batch) -> (loss, metrics). Batches are stacked pytrees
    with leading axis V; runs jax.lax.scan over them.
    """

    @jax.jit
    def local_update(params, opt_state, batches):
        def step(carry, batch):
            p, s = carry
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(p, batch)
            updates, s = opt.update(grads, s, p)
            return (apply_updates(p, updates), s), loss

        (params, opt_state), losses = jax.lax.scan(step, (params, opt_state), batches)
        return params, opt_state, losses

    return local_update


def client_round(
    local_update, global_params, opt_state, batches_stacked,
) -> Tuple[Any, Any, jnp.ndarray]:
    """One client's round: start at the global model, work V steps, return
    the local model update (delta) and losses."""
    new_params, opt_state, losses = local_update(
        global_params, opt_state, batches_stacked)
    delta = jax.tree.map(lambda n, g: n - g, new_params, global_params)
    return delta, opt_state, losses


def stack_batches(batches: List[Dict]) -> Dict:
    """[batch, ...] (length V) -> pytree with leading V axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *batches)


def stack_client_batches(iterators: List, V: int) -> Dict:
    """One round of batches for all M clients -> pytree with leading
    (M, V) axes. Stacked in numpy so the batched round step sees a single
    host->device transfer at the jit boundary instead of M*V small ones.
    Consumes each iterator in the same order as the per-client host loop."""
    per_client = []
    for it in iterators:
        batches = [it.next_batch() for _ in range(V)]
        per_client.append(jax.tree.map(lambda *xs: np.stack(xs), *batches))
    return jax.tree.map(lambda *xs: np.stack(xs), *per_client)


def stack_chunk_batches(iterators: List, rounds: int, V: int) -> Dict:
    """A whole chunk of rounds -> pytree with leading (R, M, V) axes: the
    scan backend's generic data path (one transfer per chunk). Consumes
    each iterator round-by-round in `stack_client_batches` order, so a
    chunked run sees the same batch stream as R per-round runs."""
    per_round = [stack_client_batches(iterators, V) for _ in range(rounds)]
    return jax.tree.map(lambda *xs: np.stack(xs), *per_round)


def _client_iter(source, m: int):
    """Per-client iterator access over either data source shape: a
    ClientDataPool (lazy, population-scale) or a dense iterator list."""
    return source.client(m) if hasattr(source, "client") else source[m]


def stack_cohort_batches(source, cohort: np.ndarray, V: int) -> Dict:
    """One sampled round of batches -> pytree with leading (K, V) axes:
    lane k holds client cohort[k]'s next V batches. Lanes are consumed in
    ascending-cohort (lane) order, so at K = M (cohort == arange(M)) this
    consumes every iterator exactly like `stack_client_batches` — the
    data leg of the K=M bit-parity contract."""
    per_client = []
    for m in np.asarray(cohort):
        it = _client_iter(source, int(m))
        batches = [it.next_batch() for _ in range(V)]
        per_client.append(jax.tree.map(lambda *xs: np.stack(xs), *batches))
    return jax.tree.map(lambda *xs: np.stack(xs), *per_client)


def stack_cohort_indices(source, cohorts: np.ndarray, V: int) -> np.ndarray:
    """A sampled chunk of batch indices -> (R, K, V, B) int32: round r's
    lane k draws from client cohorts[r, k]'s stream. Only participating
    clients' iterators advance (absent clients keep their batch cursor —
    they re-enter later exactly where they left off); per round, lanes
    are consumed in ascending order, so at K = M this is bit-identical to
    `stack_chunk_indices` over the full iterator list."""
    cohorts = np.asarray(cohorts)
    R, K = cohorts.shape
    bs = (source.batch_size if hasattr(source, "batch_size")
          else source[0].batch_size)
    out = np.empty((R, K, V, bs), np.int32)
    for r in range(R):
        for k in range(K):
            it = _client_iter(source, int(cohorts[r, k]))
            for v in range(V):
                out[r, k, v] = it.next_indices()
    return out


def stack_chunk_indices(iterators: List, rounds: int, V: int) -> np.ndarray:
    """A whole chunk of batch *indices* -> (R, M, V, B) int32: the scan
    backend's device-resident data path. Only the indices cross the
    host->device boundary; the samples are gathered in-graph from the
    uploaded dataset (BatchIterator.batch_from). Same per-round iterator
    consumption order as stack_client_batches, so the drawn batches are
    identical to the host-gathered path's."""
    out = np.empty(
        (rounds, len(iterators), V, iterators[0].batch_size), np.int32)
    for r in range(rounds):
        for c, it in enumerate(iterators):
            for v in range(V):
                out[r, c, v] = it.next_indices()
    return out
