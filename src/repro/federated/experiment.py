"""Declarative experiment API: `ExperimentSpec.build() -> Simulator`.

The paper's results are *comparisons* — DEFL vs FedAvg vs Rand across
heterogeneous populations (Fig. 2), swept over epsilon/batch/theta/rounds
(Fig. 1) — and every benchmark/example/test used to hand-wire the same
13-argument simulator constructor to express one of them. An
`ExperimentSpec` is the frozen value form of that wiring: model, data +
partition, population, wireless, plan-or-fed, scenario, compression and
backend, with `build()` materializing the `Simulator` and a small
registry for named configurations:

    spec = experiment.ExperimentSpec(
        fed=FedConfig(n_devices=10, epsilon=0.01, c=4.0, lr=0.05),
        model="mnist_cnn", dataset="mnist", scenario="stragglers",
        plan=True)                      # solve (b*, theta*) before running
    sim = spec.build()
    state, res = sim.run(sim.init(), max_rounds=100, eval_every=10)
    fleet = sim.run_fleet(seeds=range(8), max_rounds=100, eval_every=10)

Specs are plain frozen dataclasses: `replace(...)` derives sweeps, the
registry (`experiment.register/get/names`) shares baseline configurations
between benchmarks, examples and tests.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from dataclasses import dataclass
from typing import Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ComputeConfig, FedConfig, WirelessConfig
from repro.core import defl, delay
from repro.data import BatchIterator, make_cifar_like, make_mnist_like
from repro.data.pipeline import ClientDataPool
from repro.federated import scenarios
from repro.federated.events import AsyncSpec
from repro.federated.faults import FaultModel
from repro.federated.traces import TraceSpec, replay_scenario
from repro.federated.partition import (partition_dirichlet, partition_sizes,
                                       partition_virtual)
from repro.federated.simulation import Simulator
from repro.models import cnn
from repro.optim import sgd
from repro.utils.tree import tree_bytes

# Calibration (see EXPERIMENTS.md §Claims): per-sample compute ~10 ms at
# b=1 on the 2 GHz edge GPU pins theta* ~= 0.13-0.15 (the paper's reported
# operating point, independent of c), and c ~= 4.0 then pins b* ~= 32
# (the paper's "rounded off" batch size) at eps = 0.01.
CALIBRATED_COMPUTE = ComputeConfig(bits_per_sample=6.8e5)
CALIBRATED_C = 4.0

# Model registry: named CNN configurations the spec can reference (a
# literal CNNConfig is also accepted for one-off model sweeps).
MODELS = {
    "mnist_cnn": cnn.mnist_cnn,
    "mnist_cnn_small": cnn.mnist_cnn_small,
    "mnist_cnn_tiny": cnn.mnist_cnn_tiny,
    "cifar_cnn": cnn.cifar_cnn,
}

DATASETS = {"mnist": make_mnist_like, "cifar": make_cifar_like}

# Dense device state above this many clients is almost certainly a
# mistake (the stacked params/opt carry one lane per client): emitting a
# first-party DeprecationWarning here — an ERROR under the tier-1 filter
# — pushes callers onto PopulationSpec(M, cohort=CohortSpec(K)).
DENSE_M_DEPRECATION_THRESHOLD = 4096


@dataclass(frozen=True)
class CohortSpec:
    """Per-round sampled participation: K clients drawn from the
    population each round.

    K        cohort size — the device-resident client state is O(K).
    sampler  'uniform' (each round's cohort uniform without replacement)
             | 'weighted' (D_m-weighted Gumbel top-K without
             replacement: data-rich clients are drawn more often).
    spare    over-provisioning: each round draws K + spare candidates
             from the same cohort RNG stream and keeps the K deadline-
             feasible-fastest (ties by client index) — resilience
             against deadline-cut stragglers without growing the
             device-resident cohort. spare=0 (default) is bit-identical
             to today's draw.
    """

    K: int
    sampler: str = "uniform"
    spare: int = 0

    def __post_init__(self):
        if self.K < 1:
            raise ValueError(f"CohortSpec.K must be >= 1, got {self.K}")
        if self.sampler not in ("uniform", "weighted"):
            raise ValueError(
                f"unknown CohortSpec.sampler {self.sampler!r}; expected "
                "'uniform' or 'weighted'")
        if not isinstance(self.spare, int) or self.spare < 0:
            raise ValueError(
                f"CohortSpec.spare must be an int >= 0, got {self.spare!r}")


@dataclass(frozen=True)
class PopulationSpec:
    """The client population, declaratively: its size and (optionally)
    the per-round participation regime.

    M       population size. Plain `fed.n_devices` (no PopulationSpec)
            stays sugar for a dense M-client population — identical
            simulators, bit for bit.
    cohort  None runs dense (every client computes every round, device
            state O(M)); CohortSpec(K) runs sampled participation
            (device state O(K), population model host-side O(M)) —
            required above DENSE_M_DEPRECATION_THRESHOLD clients.
    """

    M: int
    cohort: Optional[CohortSpec] = None

    def __post_init__(self):
        if self.M < 1:
            raise ValueError(f"PopulationSpec.M must be >= 1, got {self.M}")
        if self.cohort is not None and self.cohort.K > self.M:
            raise ValueError(
                f"cohort K={self.cohort.K} exceeds population M={self.M}")
        if (self.cohort is not None
                and self.cohort.K + self.cohort.spare > self.M):
            raise ValueError(
                f"cohort K+spare={self.cohort.K + self.cohort.spare} "
                f"exceeds population M={self.M}")


@dataclass(frozen=True)
class ExperimentSpec:
    """One experiment, declaratively. All fields have paper-faithful
    defaults; `replace()` derives variants.

    fed            the federated/DEFL configuration (M, b, theta, lr,
                   compression, ...). When `plan=True`, b/theta/V are
                   re-solved against the realized population and `fed`
                   provides the problem constants (epsilon, nu, c, M).
    model          registry name (MODELS) or a literal cnn.CNNConfig.
    dataset        'mnist' | 'cifar' (synthetic *-like tasks).
    n_train/n_test dataset sizes; alpha the Dirichlet non-IID knob.
    seed           draw seed for dataset, partition and population —
                   fixed per experiment; *run* seeds (PRNG key, scenario
                   stream, batch order) are chosen at `Simulator.init` /
                   `run_fleet` time, which is what multi-seed confidence
                   bands vary.
    scenario       registered edge-scenario name (scenarios.py) or None;
                   draws the population and the per-round
                   participation/channel stream.
    trace          optional traces.TraceSpec: replay a recorded JSONL
                   device-state log as the scenario source (deterministic
                   presence/loss/channel overlay on the unchanged
                   backends). Mutually exclusive with `scenario` — the
                   log IS the realization stream, so a registry scenario
                   cannot also drive it; the validation error names both
                   fields. `scenario_ref()` resolves whichever is set.
    faults         optional faults.FaultModel overriding (or adding to)
                   the scenario's failure semantics — deadlines, uplink
                   retransmission, crash/rejoin, divergence guards. None
                   keeps the scenario's own `faults` (if any).
    heterogeneity  population lognormal spread when no scenario is given.
    population     optional PopulationSpec. When set, its M overrides
                   fed.n_devices (the M-free way to scale a registered
                   spec to 10^4-10^6 clients) and its CohortSpec turns on
                   K-client sampled participation: device state O(K),
                   per-round cohorts drawn host-side from the M-client
                   population. `PopulationSpec(M)` with no cohort is
                   exactly `fed.n_devices=M` (dense — deprecated above
                   DENSE_M_DEPRECATION_THRESHOLD clients).
    shard_clients  shard the stacked client axis over all JAX devices
                   (scan backend; prototype on CPU via
                   XLA_FLAGS=--xla_force_host_platform_device_count=N).
    plan           solve Alg. 1 for (b*, theta*) against the population
                   before building (plan-or-fed: False runs `fed` as-is).
                   Under a CohortSpec the Eq. 12 effective M is the
                   cohort's K (defl.make_plan cohort_size).
    batch_cap      dataset-bounded cap applied to a planned b* (paper
                   §VI-B discussion); None disables.
    backend        'scan' (default) | 'batched' | 'loop' | 'async'.
    async_spec     events.AsyncSpec for backend='async': buffered
                   staleness-weighted aggregation over a compiled
                   device-side event queue. Requires backend='async'
                   (and vice versa). Mutually exclusive with sampled
                   participation (population.cohort), shard_clients and
                   quorum/update-norm fault guards — the validation
                   errors name the offending fields.
    """

    fed: FedConfig = FedConfig()
    population: Optional[PopulationSpec] = None
    shard_clients: bool = False
    model: Union[str, cnn.CNNConfig] = "mnist_cnn"
    dataset: str = "mnist"
    n_train: int = 1500
    n_test: int = 400
    alpha: float = 1.0
    seed: int = 0
    scenario: Optional[str] = None
    trace: Optional[TraceSpec] = None
    faults: Optional[FaultModel] = None
    heterogeneity: float = 0.0
    compute: ComputeConfig = CALIBRATED_COMPUTE
    wireless: WirelessConfig = WirelessConfig()
    plan: bool = False
    plan_method: str = "closed_form"
    batch_cap: Optional[int] = 32
    backend: str = "scan"
    impl: str = "xla"
    with_eval: bool = True
    label: str = ""
    async_spec: Optional[AsyncSpec] = None

    def __post_init__(self):
        # Satellite knob-compatibility contract: mutually-exclusive
        # combinations fail at spec construction, naming the fields, so
        # a bad sweep dies before any build()/compile cost is paid.
        if self.trace is not None and self.scenario is not None:
            raise ValueError(
                f"ExperimentSpec: trace={self.trace.name!r} and scenario="
                f"{self.scenario!r} are mutually exclusive (fields "
                "scenario, trace) — a TraceSpec replays its own recorded "
                "device-state stream, so a registry scenario cannot also "
                "drive the population; drop one of them")
        if self.backend == "async" and self.async_spec is None:
            raise ValueError(
                "ExperimentSpec: backend='async' requires async_spec="
                "AsyncSpec(...) (fields backend, async_spec)")
        if self.async_spec is not None and self.backend != "async":
            raise ValueError(
                f"ExperimentSpec: async_spec is set but backend="
                f"{self.backend!r}; asynchronous aggregation requires "
                "backend='async' (fields backend, async_spec)")
        if self.backend != "async":
            return
        if self.population is not None and self.population.cohort is not None:
            raise ValueError(
                "ExperimentSpec: backend='async' is incompatible with "
                "sampled participation (fields backend, population.cohort) "
                "— the event queue tracks every client; use a dense "
                "PopulationSpec(M) or drop the CohortSpec")
        if self.shard_clients:
            raise ValueError(
                "ExperimentSpec: backend='async' is incompatible with "
                "client sharding (fields backend, shard_clients) — the "
                "event queue pops one client per step, which does not "
                "shard across devices")
        fm = self.effective_faults()
        if fm is not None and fm.min_quorum is not None:
            raise ValueError(
                "ExperimentSpec: backend='async' is incompatible with "
                "quorum gating (fields backend, faults.min_quorum) — "
                "rounds are buffer fills, not synchronized cohorts; use "
                "AsyncSpec.buffer_size to set the fill threshold")
        if fm is not None and fm.max_update_norm is not None:
            raise ValueError(
                "ExperimentSpec: backend='async' is incompatible with "
                "update-norm clipping (fields backend, "
                "faults.max_update_norm); deadline/retransmission/crash "
                "fault channels do compose with async")

    def replace(self, **kw) -> "ExperimentSpec":
        return dataclasses.replace(self, **kw)

    # -- resolution ---------------------------------------------------------
    def model_config(self) -> cnn.CNNConfig:
        if isinstance(self.model, str):
            try:
                return MODELS[self.model]()
            except KeyError:
                raise KeyError(
                    f"unknown model {self.model!r}; registered: "
                    f"{tuple(MODELS)}") from None
        return self.model

    def scenario_ref(self) -> Union[str, scenarios.Scenario, None]:
        """The scenario source this spec actually runs: the ReplayScenario
        materialized from `trace` when set, else the registry `scenario`
        name, else None. Every scenario consumer (faults, population,
        plan, build) resolves through this, so a trace-driven spec rides
        the identical code paths as a registry-scenario one."""
        if self.trace is not None:
            return replay_scenario(self.trace)
        return self.scenario

    def effective_faults(self) -> Optional[FaultModel]:
        """The FaultModel this spec actually runs under: the spec's own
        override when set, else the scenario's, else None. Inactive
        models normalize to None (they are bit-identical to no model)."""
        fm = self.faults
        ref = self.scenario_ref()
        if fm is None and ref is not None:
            fm = scenarios.get(ref).faults
        return fm if fm is not None and fm.active else None

    def n_devices(self) -> int:
        """Population size M: PopulationSpec.M when given (it overrides
        fed.n_devices), else fed.n_devices."""
        return (self.fed.n_devices if self.population is None
                else self.population.M)

    def cohort_spec(self) -> Optional[CohortSpec]:
        """The sampled-participation regime, or None for dense."""
        return None if self.population is None else self.population.cohort

    def base_fed(self) -> FedConfig:
        """`fed` with the PopulationSpec's M applied (the single source of
        truth every downstream consumer — plan, build, study grouping —
        resolves n_devices through)."""
        M = self.n_devices()
        if M == self.fed.n_devices:
            return self.fed
        return dataclasses.replace(self.fed, n_devices=M)

    def device_population(self) -> delay.DevicePopulation:
        """Draw the (M,) device population (compute + channel). Renamed
        from `population()`, which the PopulationSpec field now owns."""
        M = self.n_devices()
        ref = self.scenario_ref()
        if ref is not None:
            return scenarios.get(ref).population(
                M, self.compute, self.wireless, self.seed)
        return delay.draw_population(
            M, self.compute, self.wireless, self.seed, self.heterogeneity)

    def update_bits(self) -> float:
        """Raw wire size of one model update (plan input; the simulator
        separately applies compression accounting at run time)."""
        cfg = self.model_config()
        params = jax.eval_shape(
            lambda k: cnn.init_cnn(cfg, k), jax.random.PRNGKey(0))
        return tree_bytes(params) * 8.0

    def _solve_plan(self, pop: delay.DevicePopulation,
                    ) -> Optional[defl.DEFLPlan]:
        if not self.plan:
            return None
        bits = self.update_bits()
        fed = self.base_fed()
        cohort = self.cohort_spec()
        K = None if cohort is None else cohort.K
        ref = self.scenario_ref()
        if ref is not None:
            return scenarios.plan_for_scenario(
                fed, ref, bits, cc=self.compute,
                wc=self.wireless, seed=self.seed, method=self.plan_method,
                cohort_size=K,
                spare=0 if cohort is None else cohort.spare)
        return defl.make_plan(fed, pop, bits, wireless=self.wireless,
                              method=self.plan_method, cohort_size=K)

    def _fed_with_plan(self, plan: Optional[defl.DEFLPlan]) -> FedConfig:
        base = self.base_fed()
        if plan is None:
            return base
        fed = defl.plan_to_fedconfig(plan, base)
        b = fed.batch_size if self.batch_cap is None else min(
            fed.batch_size, self.batch_cap)
        return dataclasses.replace(fed, batch_size=b, update_bytes=None)

    def resolve_plan(self) -> Optional[defl.DEFLPlan]:
        """The DEFL plan this spec runs under (None when plan=False)."""
        return self._solve_plan(self.device_population())

    def resolve_fed(self) -> FedConfig:
        """Plan-or-fed: `fed` with the solved (b*, theta*) applied when
        plan=True (batch capped at `batch_cap`, wire size left to the
        simulator's exact accounting), `fed` unchanged otherwise."""
        return self._fed_with_plan(self.resolve_plan())

    def plan_request(self) -> Optional[defl.PlanRequest]:
        """The arm's Alg. 1 solve in batchable value form: a
        `defl.PlanRequest` when `resolve_plan()` reduces to a plain
        `defl.make_plan` (plan=True and no deadline re-derivation), else
        None — fixed-(b, V) baselines solve nothing and deadline-fault
        scenarios re-derive over the truncated delay model, so both keep
        their bespoke scalar paths. `Study.plans()` collects these to
        solve all batchable arms in one vectorized KKT dispatch,
        bit-identical to per-arm `analytic_plan()`."""
        if not self.plan:
            return None
        participation = 1.0
        ref = self.scenario_ref()
        if ref is not None:
            sc = scenarios.get(ref)
            fm = sc.faults
            if fm is not None and fm.active and (
                    fm.deadline is not None
                    or fm.deadline_factor is not None):
                return None
            participation = sc.expected_participation
        cohort = self.cohort_spec()
        return defl.PlanRequest(
            fed=self.base_fed(), pop=self.device_population(),
            update_bits=self.update_bits(), wireless=self.wireless,
            method=self.plan_method, participation=participation,
            cohort_size=None if cohort is None else cohort.K)

    def analytic_plan(self) -> defl.DEFLPlan:
        """The arm's delay-model operating point, always available: the
        solved DEFL plan when plan=True, otherwise Eq. 12/8 evaluated at
        the spec's fixed (b, theta) (`defl.fixed_plan` at the EXACT
        theta, so a swept theta's H is not shifted by V's integer
        quantization — the FedAvg/Rand baseline rows of the paper's
        tables). The analytic figures (fig1a/fig1d, ablation_straggler)
        read their predicted columns from this via `Study.plans()`."""
        if self.plan:
            return self.resolve_plan()
        fed = self.base_fed()
        return defl.fixed_plan(
            fed, self.device_population(), self.update_bits(),
            b=fed.batch_size, V=fed.local_rounds,
            wireless=self.wireless, theta=fed.theta)

    # -- materialization ----------------------------------------------------
    def build(self) -> Simulator:
        """Materialize the Simulator: draw data/partition/population at
        `self.seed`, wire model/loss/eval, and hand the per-client data
        factory to the functional core (each `init(seed)` / fleet member
        gets its own independently-seeded batch streams over the shared
        dataset — keeping the device-resident one-upload data path).
        The population is drawn once and the DEFL plan solved once per
        build (both are seed-deterministic, but redundancy here would
        double every plan=True build's KKT solve).

        Sampled participation (PopulationSpec.cohort) swaps the dense
        per-client iterator list for a lazy ClientDataPool: at M <=
        n_train it wraps the SAME Dirichlet partition with the SAME
        per-client seeds (so a K=M sampled build is bit-identical to the
        dense one), above that — where a disjoint split is impossible —
        each client owns a deterministic virtual shard
        (partition.partition_virtual), O(1) host state per client."""
        make = DATASETS[self.dataset]
        pop = self.device_population()
        fed = self._fed_with_plan(self._solve_plan(pop))
        cohort = self.cohort_spec()
        if (cohort is None and self.backend != "loop"
                and fed.n_devices >= DENSE_M_DEPRECATION_THRESHOLD):
            warnings.warn(
                f"dense device state with M={fed.n_devices} clients is "
                "deprecated: the stacked params/opt carry one lane per "
                "client. Use population=PopulationSpec(M=..., "
                "cohort=CohortSpec(K=...)) for O(K) device state.",
                DeprecationWarning, stacklevel=2)
        cfg = self.model_config()
        data = make(self.n_train, seed=self.seed)
        params = cnn.init_cnn(cfg, jax.random.PRNGKey(self.seed))
        if cohort is not None and fed.n_devices > self.n_train:
            # Population scale: no M-long partition list exists anywhere.
            indices_fn, sizes = partition_virtual(
                self.n_train, fed.n_devices, seed=self.seed)
            data_sizes = sizes

            def data_factory(seed: int):
                return ClientDataPool(data, indices_fn, sizes,
                                      fed.batch_size, seed=seed)
        else:
            parts = partition_dirichlet(data, fed.n_devices,
                                        alpha=self.alpha, seed=self.seed)
            data_sizes = partition_sizes(parts)
            if cohort is not None:
                def data_factory(seed: int):
                    return ClientDataPool.from_parts(data, parts,
                                                     fed.batch_size,
                                                     seed=seed)
            else:
                def data_factory(seed: int):
                    return [BatchIterator(data, p, fed.batch_size,
                                          seed=seed + i)
                            for i, p in enumerate(parts)]

        eval_fn = eval_batch_fn = None
        if self.with_eval:
            test = make(self.n_test, seed=self.seed + 1)
            xb, yb = jnp.asarray(test.x), jnp.asarray(test.y)

            @jax.jit
            def eval_acc(p):
                logits = cnn.cnn_forward(cfg, p, xb)
                return jnp.mean(
                    (jnp.argmax(logits, -1) == yb).astype(jnp.float32))

            # Vmapped twin over a stacked member axis: fleet/study eval is
            # ONE dispatch for all members instead of a host loop. Exact
            # per-member agreement with eval_acc is guaranteed: the hit
            # indicators are exact 0/1 floats whose sum is integral, so no
            # reduction order can perturb the accuracy.
            @jax.jit
            def eval_acc_S(ps):
                logits = jax.vmap(lambda p: cnn.cnn_forward(cfg, p, xb))(ps)
                hits = (jnp.argmax(logits, -1) == yb[None]).astype(
                    jnp.float32)
                return jnp.mean(hits, axis=-1)

            eval_fn = lambda p: {"acc": float(eval_acc(p))}  # noqa: E731
            eval_batch_fn = lambda ps: {  # noqa: E731
                "acc": np.asarray(jax.device_get(eval_acc_S(ps)))}

        ref = self.scenario_ref()
        label = self.label or (
            f"{self.dataset}@{scenarios.get(ref).name}" if ref is not None
            else self.dataset)
        # The study-grouping capabilities: the (V, b)-envelope form of the
        # loss and a hashable compiled-graph signature — two sims with
        # equal envelope_key (and equal envelope dims) can share one
        # compiled envelope chunk (study._chunk_for). The effective
        # FaultModel is part of the signature: guard knobs and the fault
        # branch are compiled into the chunk (an active FaultModel with
        # no scenario also promotes the sim onto the scenario path).
        eff_faults = self.effective_faults()
        envelope_key = (cfg, fed.n_devices, fed.lr, fed.compress_updates,
                        self.impl,
                        ref is not None or eff_faults is not None,
                        eff_faults, cohort, self.shard_clients,
                        self.async_spec)
        return Simulator(
            functools.partial(cnn.cnn_loss, cfg), params, data_factory,
            data_sizes, fed, sgd(fed.lr), pop,
            wireless=self.wireless, eval_fn=eval_fn, label=label,
            backend=self.backend, impl=self.impl, scenario=ref,
            faults=self.faults, eval_batch_fn=eval_batch_fn,
            masked_loss_fn=functools.partial(cnn.cnn_loss_masked, cfg),
            envelope_key=envelope_key,
            cohort=None if cohort is None else cohort.K,
            cohort_sampler="uniform" if cohort is None else cohort.sampler,
            cohort_spare=0 if cohort is None else cohort.spare,
            shard_clients=self.shard_clients,
            async_spec=self.async_spec)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ExperimentSpec] = {}


def register(name: str, spec: ExperimentSpec) -> ExperimentSpec:
    if name in _REGISTRY:
        raise ValueError(f"experiment {name!r} already registered")
    _REGISTRY[name] = spec
    return spec


def get(name: Union[str, ExperimentSpec]) -> ExperimentSpec:
    if isinstance(name, ExperimentSpec):
        return name
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown experiment {name!r}; registered: {names()}") from None


def names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


register("mnist_paper", ExperimentSpec(
    fed=FedConfig(n_devices=10, epsilon=0.01, nu=2.0, c=CALIBRATED_C,
                  lr=0.05),
    model="mnist_cnn", dataset="mnist", plan=True,
    label="mnist_paper"))
register("cifar_paper", ExperimentSpec(
    fed=FedConfig(n_devices=10, epsilon=0.01, nu=2.0, c=CALIBRATED_C,
                  lr=0.05),
    model="cifar_cnn", dataset="cifar", plan=True,
    label="cifar_paper"))
register("mnist_smoke", ExperimentSpec(
    fed=FedConfig(n_devices=3, batch_size=8, theta=0.62, lr=0.05),
    model="mnist_cnn_small", dataset="mnist", n_train=240, n_test=80,
    label="mnist_smoke"))
register("mnist_sampled", ExperimentSpec(
    fed=FedConfig(batch_size=8, theta=0.62, lr=0.05),
    population=PopulationSpec(M=40, cohort=CohortSpec(K=8)),
    model="mnist_cnn_small", dataset="mnist", n_train=240, n_test=80,
    scenario="dropout",
    label="mnist_sampled"))
register("mnist_async", ExperimentSpec(
    fed=FedConfig(n_devices=10, batch_size=8, theta=0.62, lr=0.05),
    model="mnist_cnn_small", dataset="mnist", n_train=240, n_test=80,
    scenario="stragglers", backend="async",
    async_spec=AsyncSpec(buffer_size=4, staleness="poly"),
    label="mnist_async"))
register("mnist_diurnal", ExperimentSpec(
    fed=FedConfig(n_devices=12, epsilon=0.01, nu=2.0, c=CALIBRATED_C,
                  lr=0.05),
    model="mnist_cnn_small", dataset="mnist", n_train=240, n_test=80,
    scenario="diurnal_edge", plan=True,
    label="mnist_diurnal"))
register("mnist_storm", ExperimentSpec(
    fed=FedConfig(n_devices=10, epsilon=0.01, nu=2.0, c=CALIBRATED_C,
                  lr=0.05),
    model="mnist_cnn", dataset="mnist", scenario="hetero_storm", plan=True,
    label="mnist_storm"))
