"""Federated data partitioning: IID and Dirichlet non-IID splits."""
from __future__ import annotations

from typing import List

import numpy as np

from repro.data.synthetic import ClassificationData


def partition_iid(n: int, m_devices: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    return [np.sort(s) for s in np.array_split(perm, m_devices)]


def partition_dirichlet(
    data: ClassificationData, m_devices: int, alpha: float = 0.5, seed: int = 0,
) -> List[np.ndarray]:
    """Label-Dirichlet non-IID split (standard FL benchmark protocol).

    Every device is guaranteed at least one sample (re-draw on empties).
    """
    rng = np.random.default_rng(seed)
    for _ in range(100):
        shares = [[] for _ in range(m_devices)]
        for cls in range(data.n_classes):
            idx = np.flatnonzero(data.y == cls)
            rng.shuffle(idx)
            p = rng.dirichlet([alpha] * m_devices)
            cuts = (np.cumsum(p)[:-1] * len(idx)).astype(int)
            for dev, part in enumerate(np.split(idx, cuts)):
                shares[dev].append(part)
        parts = [np.sort(np.concatenate(s)) for s in shares]
        if all(len(p) > 0 for p in parts):
            return parts
    raise RuntimeError("could not produce non-empty Dirichlet partition")


def partition_sizes(parts: List[np.ndarray]) -> np.ndarray:
    """D_m (Eq. 1-2 weights)."""
    return np.array([len(p) for p in parts], dtype=np.int64)


def shard_indices(n: int, m: int, shard_size: int, seed: int = 0) -> np.ndarray:
    """Client m's virtual data shard: `shard_size` sorted rows of the
    n-row dataset, drawn from a per-client seed sequence — O(1) state per
    client, no M-long partition list. Clients share rows (the dataset is
    a sample library at M >> n, not a disjoint split); draws are without
    replacement unless shard_size > n."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0x5AAD, m]))
    return np.sort(rng.choice(n, size=shard_size, replace=shard_size > n))


def partition_virtual(n: int, m_devices: int, shard_size: int = None,
                      seed: int = 0):
    """Population-scale partition: a lazy `indices_fn(m)` + (M,) sizes
    instead of M materialized index arrays. Disjoint Dirichlet splits are
    infeasible (and meaningless) at M >> n_train; each client instead owns
    a deterministic virtual shard (`shard_indices`). Feed the pair to
    `repro.data.pipeline.ClientDataPool`."""
    shard_size = min(64, n) if shard_size is None else int(shard_size)
    sizes = np.full(m_devices, shard_size, dtype=np.int64)
    return (lambda m: shard_indices(n, m, shard_size, seed)), sizes
