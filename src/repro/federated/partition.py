"""Federated data partitioning: IID and Dirichlet non-IID splits."""
from __future__ import annotations

from typing import List

import numpy as np

from repro.data.synthetic import ClassificationData


def partition_iid(n: int, m_devices: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    return [np.sort(s) for s in np.array_split(perm, m_devices)]


def partition_dirichlet(
    data: ClassificationData, m_devices: int, alpha: float = 0.5, seed: int = 0,
) -> List[np.ndarray]:
    """Label-Dirichlet non-IID split (standard FL benchmark protocol).

    Every device is guaranteed at least one sample (re-draw on empties).
    """
    rng = np.random.default_rng(seed)
    for _ in range(100):
        shares = [[] for _ in range(m_devices)]
        for cls in range(data.n_classes):
            idx = np.flatnonzero(data.y == cls)
            rng.shuffle(idx)
            p = rng.dirichlet([alpha] * m_devices)
            cuts = (np.cumsum(p)[:-1] * len(idx)).astype(int)
            for dev, part in enumerate(np.split(idx, cuts)):
                shares[dev].append(part)
        parts = [np.sort(np.concatenate(s)) for s in shares]
        if all(len(p) > 0 for p in parts):
            return parts
    raise RuntimeError("could not produce non-empty Dirichlet partition")


def partition_sizes(parts: List[np.ndarray]) -> np.ndarray:
    """D_m (Eq. 1-2 weights)."""
    return np.array([len(p) for p in parts], dtype=np.int64)
