"""Online DEFL planner service: streaming device state in, (b, V) plans out.

The repo's studies solve Alg. 1 once, offline, at Study build time. A
serving deployment faces the inverse shape: device state (compute slope,
channel quality, availability) arrives as a telemetry stream, conditions
drift hour to hour (traces.TraceScenario), and *many* plan queries — one
per tenant / cohort / what-if — must be answered concurrently. This module
provides that layer:

  * `PlannerService` — ingests `DeviceStateUpdate`s into a rolling
    per-client state table, materializes population snapshots on demand,
    and answers plan queries through the exact Alg. 1 pipeline
    (`defl.make_plan` / `defl.make_plan_batch`). `plan_batch(queries)`
    routes every query into ONE vectorized `kkt.solve_batch` dispatch per
    method (closed-form and the golden-section numerical path are both
    batched), each lane bit-identical to the scalar `plan()` —
    tests/test_planner.py asserts the identity at Q=256.

  * `replan_trace` — the replanning driver: walk a trace scenario epoch
    by epoch, feed the planner the previous epoch's observations, emit a
    re-planned operating point per epoch (all epochs solved in one
    batched dispatch — the trace realization is open-loop, so plan e
    depends only on telemetry before e), then score every plan sequence
    on the *realized* rounds: simulated time until the Eq. 12 convergence
    budget is met, where each round contributes 1/H(b, V; arrived
    updates) progress and costs its realized straggler round time. The
    report compares the replanned sequence against every fixed plan
    (including deliberately bad corners), names the oracle (best fixed in
    hindsight) and the worst, and quotes the regret vs the oracle.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.configs.base import ComputeConfig, FedConfig, WirelessConfig
from repro.core import defl, delay
from repro.federated import scenarios
from repro.federated import traces  # noqa: F401  (registers trace scenarios)


# ---------------------------------------------------------------------------
# Telemetry and queries
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DeviceStateUpdate:
    """One device's latest observed state.

    g  compute slope G_m/f_m (seconds per unit batch) — what Eq. 3/5
       actually consume; a device reports its measured per-iteration time
       divided by its batch size.
    p  uplink transmit power (W).
    h  observed channel gain (drives the Eq. 6 rate).
    t  observation timestamp (seconds); used for staleness eviction.
    """

    client_id: int
    g: float
    p: float
    h: float
    t: float = 0.0

    def __post_init__(self):
        if self.client_id < 0:
            raise ValueError(f"client_id must be >= 0, got {self.client_id}")
        if not (self.g > 0 and self.p > 0 and self.h > 0):
            raise ValueError(
                f"device {self.client_id}: g, p, h must be > 0 "
                f"(got g={self.g}, p={self.p}, h={self.h})")


@dataclass(frozen=True)
class PlanQuery:
    """One plan request against the service's rolling population estimate.

    Every field is optional: an empty query plans for the service's
    current snapshot with its default fed/method. Overrides let one
    batched dispatch serve heterogeneous tenants (different participation
    estimates, cohort sizes, epsilon targets, even explicit population
    snapshots, as the replanning driver uses for causality)."""

    participation: float = 1.0
    cohort_size: Optional[int] = None
    method: Optional[str] = None
    update_bits: Optional[float] = None
    fed: Optional[FedConfig] = None
    pop: Optional[delay.DevicePopulation] = None
    tag: str = ""


class PlannerService:
    """Rolling device-state table + batched Alg. 1 solves.

    The service is deliberately thin on the solve side: `plan` IS
    `defl.make_plan` and `plan_batch` IS `defl.make_plan_batch` on the
    service's snapshots, so the scalar/batched bit-identity contract
    those carry (tests/test_plan_batch.py) transfers to the service
    verbatim — a batched answer never differs from the one-off answer.
    """

    def __init__(self, fed: FedConfig, update_bits: float,
                 wireless: Optional[WirelessConfig] = None,
                 method: str = "closed_form",
                 stale_after: Optional[float] = None):
        self.fed = fed
        self.update_bits = float(update_bits)
        self.wireless = wireless or WirelessConfig()
        self.method = method
        self.stale_after = stale_after
        self._state: Dict[int, DeviceStateUpdate] = {}
        self._participation: Optional[float] = None

    # -- ingest -------------------------------------------------------------
    def observe(self, updates: Union[DeviceStateUpdate,
                                     Iterable[DeviceStateUpdate]]) -> None:
        """Ingest one update or a batch; latest write per client wins."""
        if isinstance(updates, DeviceStateUpdate):
            updates = (updates,)
        for u in updates:
            self._state[u.client_id] = u

    def observe_population(self, pop: delay.DevicePopulation,
                           t: float = 0.0) -> None:
        """Seed/refresh the table from a DevicePopulation (ids 0..M-1) —
        the cold-start prior before any live telemetry arrives."""
        g = np.asarray(pop.G, float) / np.asarray(pop.f, float)
        self.observe([DeviceStateUpdate(i, float(g[i]), float(pop.p[i]),
                                        float(pop.h[i]), t=t)
                      for i in range(pop.n)])

    def observe_round(self, real, t: float = 0.0) -> None:
        """Ingest one realized round (scenarios.RoundRealization): present
        clients report their realized channel; the participation fraction
        feeds the rolling estimate (EWMA, beta=0.5)."""
        ids = np.flatnonzero(np.asarray(real.clock_mask, bool))
        h = np.asarray(real.h, float)
        self.observe([dataclasses.replace(self._state[i], h=float(h[i]), t=t)
                      for i in ids if int(i) in self._state])
        self.observe_participation(float(np.mean(real.clock_mask)))

    def observe_participation(self, fraction: float) -> None:
        f = float(np.clip(fraction, 0.0, 1.0))
        self._participation = (f if self._participation is None
                               else 0.5 * self._participation + 0.5 * f)

    def participation_estimate(self, default: float = 1.0) -> float:
        return default if self._participation is None else self._participation

    # -- snapshots ----------------------------------------------------------
    @property
    def n_devices(self) -> int:
        return len(self._state)

    def population(self, now: Optional[float] = None) -> delay.DevicePopulation:
        """Current population snapshot: non-stale clients sorted by id,
        encoded so the delay model sees exactly the observed slopes
        (G = g, f = 1 — only G/f is observable in Eqs. 3-8)."""
        rows = sorted(self._state.values(), key=lambda u: u.client_id)
        if self.stale_after is not None and now is not None:
            rows = [u for u in rows if u.t >= now - self.stale_after]
        if not rows:
            raise ValueError(
                "PlannerService has no (fresh) device state to plan on — "
                "observe() telemetry first")
        return delay.DevicePopulation(
            G=np.asarray([u.g for u in rows], float),
            f=np.ones(len(rows), float),
            p=np.asarray([u.p for u in rows], float),
            h=np.asarray([u.h for u in rows], float))

    # -- solves -------------------------------------------------------------
    def _resolve(self, q: PlanQuery,
                 pop: Optional[delay.DevicePopulation]) -> defl.PlanRequest:
        return defl.PlanRequest(
            fed=q.fed or self.fed,
            pop=q.pop if q.pop is not None else pop,
            update_bits=(self.update_bits if q.update_bits is None
                         else q.update_bits),
            wireless=self.wireless,
            method=q.method or self.method,
            participation=q.participation,
            cohort_size=q.cohort_size)

    def _shared_pop(self, queries) -> Optional[delay.DevicePopulation]:
        if all(q.pop is not None for q in queries):
            return None
        return self.population()

    def plan(self, query: PlanQuery = PlanQuery()) -> defl.DEFLPlan:
        """Scalar reference path: one query, one `defl.make_plan`."""
        r = self._resolve(query, self._shared_pop([query]))
        return defl.make_plan(
            r.fed, r.pop, r.update_bits, wireless=r.wireless,
            method=r.method, participation=r.participation,
            cohort_size=r.cohort_size)

    def plan_batch(self, queries: Sequence[PlanQuery]) -> List[defl.DEFLPlan]:
        """Answer Q concurrent queries with the KKT stage batched: ONE
        `kkt.solve_batch` dispatch per distinct method, each lane
        bit-identical to `plan(queries[i])`."""
        queries = list(queries)
        if not queries:
            return []
        pop = self._shared_pop(queries)
        return defl.make_plan_batch([self._resolve(q, pop) for q in queries])


# ---------------------------------------------------------------------------
# Replanning driver
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class EpochPlan:
    """The operating point the service chose for one trace epoch."""

    epoch: int
    b: int
    V: int
    participation: float  # the estimate the solve used
    T_round_pred: float


@dataclass(frozen=True)
class ReplanReport:
    """Outcome of `replan_trace`: the per-epoch plans, the simulated
    time-to-target of the replanned sequence vs every fixed plan, and the
    regret vs the oracle (best fixed plan in hindsight). Times are np.inf
    when a plan never reaches the convergence budget inside the trace."""

    scenario: str
    epochs: int
    rounds_per_epoch: int
    target: float
    plans: Tuple[EpochPlan, ...]
    replanned_time: float
    fixed_times: Dict[str, float]
    oracle: str
    worst: str

    @property
    def oracle_time(self) -> float:
        return self.fixed_times[self.oracle]

    @property
    def worst_time(self) -> float:
        return self.fixed_times[self.worst]

    @property
    def regret(self) -> float:
        return self.replanned_time - self.oracle_time

    def beats_worst(self) -> bool:
        return self.replanned_time < self.worst_time

    def table(self) -> str:
        rows = [f"{'plan':>14} {'time-to-target (s)':>20}",
                f"{'replanned':>14} {self.replanned_time:>20.2f}"]
        for label, t in sorted(self.fixed_times.items(), key=lambda kv: kv[1]):
            mark = {self.oracle: "  <- oracle",
                    self.worst: "  <- worst"}.get(label, "")
            rows.append(f"{label:>14} {t:>20.2f}{mark}")
        rows.append(f"regret vs oracle: {self.regret:+.2f}s")
        return "\n".join(rows)

    def to_json(self) -> dict:
        return {
            "scenario": self.scenario,
            "epochs": self.epochs,
            "rounds_per_epoch": self.rounds_per_epoch,
            "target": self.target,
            "plans": [dataclasses.asdict(p) for p in self.plans],
            "replanned_time": self.replanned_time,
            "fixed_times": dict(self.fixed_times),
            "oracle": self.oracle,
            "worst": self.worst,
            "oracle_time": self.oracle_time,
            "worst_time": self.worst_time,
            "regret": self.regret,
            "beats_worst": self.beats_worst(),
        }


def _epoch_round_model(fed, wc, pop, bits_eff, chunk, b, V):
    """Realized per-round (cost, progress) for operating point (b, V) on
    one trace chunk: cost is the Eq. 8 straggler round time over the
    clients the server waits for; progress is 1/H with Eq. 12's M set to
    the updates that actually arrived that round (0 arrivals = 0
    progress — the round is spent but buys nothing)."""
    t_cp = delay.per_client_compute_time(b, pop.G, pop.f)
    t_cm = delay.per_client_uplink_time(bits_eff, wc, pop.p, chunk.h)
    T_cm, T_cp = delay.chunk_round_times(t_cp, t_cm, chunk.clock_mask)
    T = T_cm + V * T_cp
    n_upd = chunk.mask.sum(axis=1)
    alpha = max(V / fed.nu, 1e-12)
    M_eff = np.maximum(n_upd, 1).astype(float)
    H = (fed.c / (b * b * fed.epsilon * fed.epsilon * M_eff * fed.nu * alpha)
         + fed.c * M_eff / (b * fed.epsilon))
    dp = np.where(n_upd > 0, 1.0 / H, 0.0)
    return T, dp


def _walk(fed, wc, pop, bits_eff, chunks, plan_seq, target=None):
    """Walk the realized trace under a per-epoch plan sequence.

    target=None: return (total_time, total_progress) over the whole
    trace. Otherwise: simulated time until cumulative progress reaches
    `target` (linear credit inside the crossing round), or np.inf if the
    trace ends short of it."""
    t, prog = 0.0, 0.0
    for chunk, (b, V) in zip(chunks, plan_seq):
        T, dp = _epoch_round_model(fed, wc, pop, bits_eff, chunk, b, V)
        if target is not None:
            cum = prog + np.cumsum(dp)
            hit = np.nonzero(cum >= target)[0]
            if hit.size:
                k = int(hit[0])
                before = cum[k] - dp[k]
                t += float(T[:k].sum()) + float(T[k]) * \
                    ((target - before) / dp[k])
                return t
        t += float(T.sum())
        prog = prog + float(dp.sum())
    return (t, prog) if target is None else float("inf")


def replan_trace(
    scenario: Union[str, scenarios.Scenario],
    fed: FedConfig,
    update_bits: float,
    epochs: int = 6,
    rounds_per_epoch: int = 16,
    wireless: Optional[WirelessConfig] = None,
    cc: Optional[ComputeConfig] = None,
    seed: int = 0,
    method: str = "closed_form",
    target: Optional[float] = None,
    target_frac: float = 0.5,
    extra_candidates: Tuple[Tuple[int, int], ...] = ((1, 1), (64, 16)),
    service: Optional[PlannerService] = None,
) -> ReplanReport:
    """Walk `scenario` for epochs x rounds_per_epoch rounds, re-planning
    (b, V) each epoch from the telemetry of the rounds before it.

    Causality: epoch e's query carries the population snapshot and
    participation estimate as of the END of epoch e-1 (epoch 0 plans on
    the analytic prior). Because the trace realization is open-loop — the
    masks/channels do not depend on the plan — every epoch's query is
    known upfront and all E solves run as ONE `plan_batch` dispatch.

    Scoring: the replanned sequence and every fixed candidate (each
    distinct replanned operating point held for the whole trace, plus
    `extra_candidates` — deliberately including bad corners like (1, 1))
    are walked over the SAME realized rounds. `target` is the Eq. 12
    progress budget; by default it is `target_frac` of the replanned
    sequence's total realized progress — the budget the service commits
    to and comfortably meets — applied identically to every sequence (a
    fixed plan that cannot deliver it inside the trace scores np.inf).
    The oracle is the fixed plan with the smallest time-to-target in
    hindsight; regret = replanned - oracle.
    """
    scen = scenarios.get(scenario)
    wc = wireless or WirelessConfig()
    pop = scen.population(fed.n_devices, cc, wc, seed)
    stream = scen.stream(pop, seed)
    chunks = [stream.draw_chunk(rounds_per_epoch) for _ in range(epochs)]
    bits_eff = update_bits / 4.0 if fed.compress_updates else update_bits

    svc = service or PlannerService(fed, update_bits, wireless=wc,
                                    method=method)
    svc.observe_population(pop)
    prior = scen.expected_participation
    queries = []
    for e in range(epochs):
        part = svc.participation_estimate(default=prior)
        queries.append(PlanQuery(pop=svc.population(), participation=part,
                                 tag=f"epoch{e}"))
        # ingest epoch e's telemetry (feeds epoch e+1's query): each
        # device's mean realized channel over the epoch + the realized
        # participation rate
        ch = chunks[e]
        h_mean = ch.h.mean(axis=0)
        svc.observe([DeviceStateUpdate(i, float(pop.G[i] / pop.f[i]),
                                       float(pop.p[i]), float(h_mean[i]),
                                       t=float(e))
                     for i in range(pop.n)])
        svc.observe_participation(float(ch.clock_mask.mean()))
    plans = svc.plan_batch(queries)  # ONE batched dispatch for all epochs

    epoch_plans = tuple(
        EpochPlan(epoch=e, b=p.b, V=p.V, participation=q.participation,
                  T_round_pred=p.T_round)
        for e, (p, q) in enumerate(zip(plans, queries)))
    replanned_seq = [(p.b, p.V) for p in epoch_plans]

    candidates: Dict[str, Tuple[int, int]] = {}
    for b, V in replanned_seq + list(extra_candidates):
        candidates.setdefault(f"b{int(b)}.V{int(V)}", (int(b), int(V)))

    if target is None:
        _, replanned_prog = _walk(fed, wc, pop, bits_eff, chunks,
                                  replanned_seq)
        target = target_frac * replanned_prog
    replanned_time = _walk(fed, wc, pop, bits_eff, chunks, replanned_seq,
                           target=target)
    fixed_times = {
        label: _walk(fed, wc, pop, bits_eff, chunks, [bv] * epochs,
                     target=target)
        for label, bv in candidates.items()}
    oracle = min(fixed_times, key=lambda k: fixed_times[k])
    worst = max(fixed_times, key=lambda k: fixed_times[k])
    return ReplanReport(
        scenario=getattr(scen, "name", str(scenario)),
        epochs=epochs, rounds_per_epoch=rounds_per_epoch,
        target=float(target), plans=epoch_plans,
        replanned_time=float(replanned_time),
        fixed_times=fixed_times, oracle=oracle, worst=worst)
