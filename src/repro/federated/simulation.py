"""Host-level FL simulator: Algorithm 1 with the paper's delay accounting.

Runs real training (JAX) while advancing a *simulated* wall clock from the
paper's delay models (Eqs. 5, 7, 8) — exactly how the paper reports
"overall time" for DEFL vs FedAvg vs Rand (Fig. 2). Heterogeneous device
populations, non-IID partitions and update compression are supported, and
a named `scenario` (federated/scenarios.py) layers per-round partial
participation (Bernoulli dropout / link failure) and channel drift on top.

The public API is two layers:

  `Simulator` — a pure functional core. All run state (stacked client
      params/opt, PRNG key, sim clock, round cursor, scenario-stream and
      data-iterator positions) lives in an immutable `SimState` pytree;
      every method is state-in/state-out:

          sim   = Simulator(loss_fn, params, data, sizes, fed, opt, pop)
          state = sim.init(seed)
          state, result  = sim.run(state, max_rounds=100, eval_every=10)
          state, records = sim.run_chunk(state, rounds=10)
          fleet = sim.run_fleet(seeds=range(8), max_rounds=100)

      Because `SimState` is a pytree and the compiled chunk function is
      pure, `run_fleet` vmaps the existing scan chunk over an extra
      leading axis: S seeds execute in ONE dispatch per chunk instead of
      S sequential runs, bit-identical per seed to sequential `run()`
      calls. `SimState` round-trips through `jax.device_get` and
      `save_state`/`load_state` for checkpoint/resume — a restored state
      continues the loss/clock/participation history bit-identically.

      One caveat to the value semantics: the compiled steps DONATE the
      input state's device buffers (the peak-memory contract of the
      batched/scan backends), so passing a state into
      run/run_round/run_chunk/run_fleet CONSUMES it — always rebind to
      the returned state; a reused input fails with JAX's
      deleted-buffer error. To branch several runs off one state,
      snapshot it first: `jax.device_get(state)` (host copies are
      re-uploaded, never donated away from you) or
      `save_state`/`load_state`.

  `repro.federated.experiment.ExperimentSpec` — a frozen declarative
      description (model, data/partition, population, wireless,
      plan-or-fed, scenario, compression, backend) whose `build()`
      returns a `Simulator`; replaces hand-wiring this constructor at
      every call site.

`FLSimulation` remains as a thin deprecated shim (one `DeprecationWarning`
per process) holding a (Simulator, SimState) pair behind the old mutable
interface.

Three execution backends share the same math:

  backend='scan' (default): an entire `eval_every`-round chunk is one
      compiled `jax.lax.scan` over the batched round step
      (mesh_rounds.build_round_chunk). The host touches the device once
      per chunk — scenario masks/clocks ride in as stacked (R, C) arrays
      (ScenarioStream.draw_chunk), batches either pre-stack to
      (R, C, V, ...) or, when the client iterators share one dataset
      (data.BatchIterator), stay device-resident and are gathered
      in-graph from (R, C, V, B) index arrays — and per-round metrics
      come back as stacked scan outputs in a single device_get. Carry
      buffers (params/opt/PRNG key) are donated across chunks; ragged
      final chunks are padded under a `valid` flag so a whole run costs
      exactly one trace (Simulator.trace_count).
  backend='batched': all M clients live on a stacked leading C axis and
      one jit-compiled round step (mesh_rounds.build_round_step) runs V
      vmapped local steps + weighted FedAvg + optional in-graph int8
      stochastic quantization per round — one dispatch and one host
      batch-feed per round. Host syncs happen only at `eval_every`
      boundaries — train losses stay on device in between. Kept as the
      per-round parity reference for 'scan' (bit-identical under a fixed
      seed — tests/test_scan_backend.py).
  backend='loop': the original per-client Python loop (one jitted
      local_update dispatch per client, host-side compress/decompress
      roundtrip, per-client host sync). Kept as the reference
      implementation; backends agree to fp32 tolerance under a fixed
      seed (bit-for-bit on the quantizer noise — see
      compression.sequential_client_keys).
"""
from __future__ import annotations

import dataclasses
import functools
import os
import pickle
import tempfile
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig, WirelessConfig
from repro.core import delay
from repro.federated import compression, mesh_rounds, scenarios
from repro.federated.faults import DivergenceError, FaultModel, RecoveryPolicy
from repro.federated.client import (
    client_round,
    make_local_update,
    stack_batches,
    stack_chunk_batches,
    stack_chunk_indices,
    stack_client_batches,
    stack_cohort_batches,
    stack_cohort_indices,
)
from repro.federated.server import aggregate_updates
from repro.optim.api import Optimizer
from repro.utils.tree import tree_bytes


@dataclass
class RoundRecord:
    round: int
    sim_time: float  # cumulative simulated seconds (Eq. 8 accumulated)
    T_cm: float
    T_cp: float
    train_loss: float  # may hold a device scalar until the next host sync
    test_acc: Optional[float] = None
    test_loss: Optional[float] = None
    # Scenario rounds: how many client updates reached the aggregator
    # (None on the no-scenario path — implicitly all M).
    n_participants: Optional[int] = None
    # Total uplink bits the round actually carried (participants x bits
    # per update, exact compression.compressed_bits accounting).
    uplink_bits: Optional[float] = None
    # Quorum gate (faults.FaultModel.min_quorum): True when this round's
    # participation fell below quorum. Under quorum_policy='reject' the
    # round's params/opt update was a no-op and sim_time additionally
    # paid the re-dispatch cost. None on quorum-less runs.
    rejected: Optional[bool] = None


@dataclass
class SimResult:
    history: List[RoundRecord]
    params: Any
    label: str
    fed: FedConfig
    # Auto-recovery audit trail (Simulator.run(recovery=...)): one dict
    # per restart — attempt, offending/resume rounds, the cumulative lr
    # scale and guard norm applied, and the error message. Empty on runs
    # that never diverged.
    restarts: List[dict] = field(default_factory=list)

    @property
    def total_time(self) -> float:
        return self.history[-1].sim_time if self.history else 0.0

    @property
    def rounds(self) -> int:
        return len(self.history)

    @property
    def rounds_rejected(self) -> int:
        """Rounds the quorum gate rejected (0 on quorum-less runs)."""
        return sum(1 for r in self.history if r.rejected)

    def time_to_accuracy(self, acc: float) -> Optional[float]:
        for r in self.history:
            if r.test_acc is not None and r.test_acc >= acc:
                return r.sim_time
        return None


# ---------------------------------------------------------------------------
# SimState: the immutable run state
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SimState:
    """Everything a run mutates, as one immutable value.

    Device leaves (pytree children — what `run_fleet` stacks and vmaps,
    and what `jax.device_get` materializes):
      params_C  stacked (C, ...) client params ('batched'/'scan'); the
                plain global param tree on 'loop'
      opt_C     stacked per-client optimizer state ('batched'/'scan'); a
                tuple of per-client states on 'loop'
      key       the run's PRNG key (compression noise schedule)

    Host fields (pytree aux data — position of the host-side streams):
      seed      the seed `Simulator.init` was called with; rebuilds the
                data iterators / scenario stream that `data` / `stream`
                snapshots are restored into
      round     global round cursor (continues across run() calls — a
                resumed run numbers its history after the saved one)
      sim_time  cumulative Eq. 8 simulated seconds
      stream    ScenarioStream.state() snapshot. None = "fresh at
                `seed`": a freshly-seeded stream with no fast-forward
                (initial states; also any scenario-less sim).
      data      per-client BatchIterator.state() snapshots. None =
                "factory-fresh at `seed`" (initial states), and also
                what a post-run state stores when the iterators don't
                expose the snapshot protocol (then the data source is
                assumed stateless/deterministic).

    Asynchronous backend (backend='async') extension — all None/0 on the
    synchronous backends, so sync states flatten/signature/checkpoint
    exactly as before:
      async_c     the device-side event-queue carry (a 4th pytree child):
                  global model, staleness-weighted buffer, per-client
                  finish times / dispatch versions / drop flags — see
                  mesh_rounds.build_async_chunk. Mid-buffer states
                  checkpoint/resume bit-identically because the whole
                  pending-update structure lives here.
      event       arrival-event cursor (host int): how many events the
                  run has consumed (state.round counts AGGREGATIONS).
      async_host  f64 dispatch bookkeeping for the history records
                  {'t_cm_disp' (C,), 'attempts_disp' (C,)}: each
                  in-flight update's effective uplink seconds and
                  attempt count, fixed at its dispatch.

    States are produced by `Simulator.init` and threaded through
    state-in/state-out methods; `save_state`/`load_state` round-trip one
    through disk for checkpoint/resume.

    NOTE: the value is immutable, but its device buffers are donated to
    the compiled step — a state passed into run/run_round/run_chunk/
    run_fleet is consumed. Rebind to the returned state; to keep a
    branch point, take a host snapshot first (`jax.device_get(state)`
    or `save_state`).

    Pytree support is intentionally shallow: the host fields live in
    aux_data (so `jax.device_get`, `tree.map` over ONE state, and
    serialization work), but aux holds numpy-laden snapshot dicts —
    multi-tree ops (`tree.map(f, state_a, state_b)`) and passing a
    SimState across a jit boundary are unsupported; operate on
    `(params_C, opt_C, key)` directly for that.
    """

    params_C: Any
    opt_C: Any
    key: Any
    seed: int = 0
    round: int = 0
    sim_time: float = 0.0
    stream: Optional[dict] = None
    data: Optional[tuple] = None
    async_c: Optional[Any] = None
    event: int = 0
    async_host: Optional[dict] = None


def _simstate_flatten(s: SimState):
    # async_c joins the device children (None is an empty subtree, so a
    # synchronous state's treedef carries no extra leaves).
    return ((s.params_C, s.opt_C, s.key, s.async_c),
            (s.seed, s.round, s.sim_time, s.stream, s.data, s.event,
             s.async_host))


def _simstate_unflatten(aux, children):
    params_C, opt_C, key, async_c = children
    seed, rnd, sim_time, stream, data, event, async_host = aux
    return SimState(params_C=params_C, opt_C=opt_C, key=key, seed=seed,
                    round=rnd, sim_time=sim_time, stream=stream, data=data,
                    async_c=async_c, event=event, async_host=async_host)


jax.tree_util.register_pytree_node(
    SimState, _simstate_flatten, _simstate_unflatten)


# Checkpoint schema version: bump when the on-disk payload layout changes.
_STATE_VERSION = 1


def _state_signature(state: SimState) -> tuple:
    """Shape signature of a state's device trio: the (params, opt, key)
    treedef plus every leaf's shape/dtype. Pure metadata — np.shape and
    .dtype never transfer device buffers — so it is cheap to compute at
    save AND load and catches a checkpoint fed to the wrong spec (or a
    truncated/corrupt payload) before JAX hits a cryptic unflatten or
    donation shape error deep in the first compiled step."""
    trio = (state.params_C, state.opt_C, state.key)
    if getattr(state, "async_c", None) is not None:
        # Async states append the event-queue carry; synchronous states
        # keep the historical 3-tuple signature byte-identical, so every
        # pre-async checkpoint still validates.
        trio = trio + (state.async_c,)
    treedef = str(jax.tree.structure(trio))
    leaves = tuple(
        (tuple(np.shape(x)), str(getattr(x, "dtype", type(x).__name__)))
        for x in jax.tree.leaves(trio))
    return (treedef, leaves)


def _atomic_pickle(path: str, payload: Any) -> None:
    """Crash-safe pickle write: serialize into a temp file in the
    TARGET's directory (os.replace must not cross filesystems), fsync,
    then atomically rename into place. A kill at any instant leaves
    either the previous file or none — never a torn pickle that would
    surface as a confusing UnpicklingError instead of the versioned-
    envelope ValueError."""
    d = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(
        dir=d, prefix=os.path.basename(path) + ".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            pickle.dump(payload, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def save_state(path: str, state: SimState) -> None:
    """Checkpoint a SimState: device leaves are fetched with
    `jax.device_get` and the whole value (host stream/iterator snapshots
    included) is serialized under a versioned envelope carrying the
    state's shape signature, written crash-safely (temp file + fsync +
    atomic rename — `_atomic_pickle`). `load_state` + `Simulator.run`
    continues the run bit-identically (tests/test_checkpoint_resume.py)."""
    host = jax.device_get(state)
    payload = {"__repro_simstate__": _STATE_VERSION,
               "signature": _state_signature(host),
               "state": host}
    _atomic_pickle(path, payload)


def load_state(path: str, like: Optional[SimState] = None) -> SimState:
    """Restore a `save_state` checkpoint. Leaves come back as numpy; the
    first compiled step re-uploads them (and re-donates from there).

    The payload is validated up front — schema version, held type, and
    the saved shape signature against the actual leaves — so corruption
    or a version skew fails here with a clear ValueError instead of as a
    pytree/unflatten failure deep in JAX. Pass `like=` (any SimState from
    the target Simulator, e.g. `sim.init()`) to additionally verify the
    checkpoint matches that simulator's shapes before running it.
    Legacy raw-pickle checkpoints (pre-envelope) still load; checkpoints
    written before the async backend existed (no async_c/event fields in
    the pickled dataclass) are fixed up with the synchronous defaults."""
    try:
        with open(path, "rb") as f:
            payload = pickle.load(f)
    except (pickle.UnpicklingError, EOFError, AttributeError) as e:
        raise ValueError(
            f"{path!r} is not a readable checkpoint "
            f"(corrupt or truncated pickle): {e}") from e
    if isinstance(payload, SimState):  # legacy: raw SimState pickle
        state = payload
    elif isinstance(payload, dict) and "__repro_simstate__" in payload:
        version = payload["__repro_simstate__"]
        if version != _STATE_VERSION:
            raise ValueError(
                f"{path!r} holds checkpoint schema v{version}, this build "
                f"reads v{_STATE_VERSION} — re-save the state with this "
                "version (or load it with the matching build)")
        state = payload.get("state")
        if not isinstance(state, SimState):
            raise ValueError(f"{path!r} does not hold a SimState")
        sig = payload.get("signature")
        if sig is not None and sig != _state_signature(state):
            raise ValueError(
                f"{path!r} is corrupt: its stored shape signature does not "
                "match the payload's leaves")
    else:
        raise ValueError(f"{path!r} does not hold a SimState")
    if not hasattr(state, "async_c"):
        # Pre-async checkpoint: pickle restored the old dataclass __dict__
        # (bypassing __init__), so the new fields are absent entirely —
        # install the synchronous defaults so dataclasses.replace and the
        # pytree flatten see a complete instance.
        object.__setattr__(state, "async_c", None)
        object.__setattr__(state, "event", 0)
        object.__setattr__(state, "async_host", None)
    if like is not None:
        want, got = _state_signature(like), _state_signature(state)
        if want != got:
            raise ValueError(
                f"checkpoint {path!r} was saved from a different spec: its "
                "(params, opt, key) shape signature does not match the "
                "target simulator's states")
    return state


@dataclass
class FleetResult:
    """`run_fleet` output: per-member final states and SimResults, in
    input order (member s = seed/state s)."""

    states: List[SimState]
    results: List[SimResult]

    def __len__(self) -> int:
        return len(self.results)

    def __iter__(self):
        return iter(self.results)

    def loss_history(self) -> np.ndarray:
        """(S, R) train-loss matrix across the fleet."""
        return np.asarray(
            [[r.train_loss for r in res.history] for res in self.results])

    def total_times(self) -> np.ndarray:
        return np.asarray([res.total_time for res in self.results])

    def summary(self) -> Dict[str, float]:
        """Mean/std over the fleet of final train loss and overall time —
        the confidence-band numbers multi-seed FL papers report."""
        losses = self.loss_history()[:, -1]
        times = self.total_times()
        return {"final_loss_mean": float(np.nanmean(losses)),
                "final_loss_std": float(np.nanstd(losses)),
                "total_time_mean": float(times.mean()),
                "total_time_std": float(times.std())}


@functools.partial(jax.jit, static_argnums=1)
def _unstack_members(tree, S: int):
    """Split stacked (S, ...) fleet buffers into S per-member trees in ONE
    compiled dispatch (eager per-member indexing costs S x leaves separate
    device ops — measurable against a whole fleet chunk)."""
    return tuple(
        jax.tree.map(lambda x, s=s: x[s], tree) for s in range(S))


def _validate_run_args(max_rounds: int, eval_every: int) -> None:
    """Up-front validation on every backend (no silent clamping)."""
    if not isinstance(max_rounds, (int, np.integer)) or max_rounds < 1:
        raise ValueError(
            f"max_rounds must be an int >= 1, got {max_rounds!r}")
    if not isinstance(eval_every, (int, np.integer)) or eval_every < 1:
        raise ValueError(
            f"eval_every must be an int >= 1, got {eval_every!r}")


def _scaled_optimizer(opt: Optimizer, scale: float) -> Optimizer:
    """`opt` with every update scaled by `scale` — exact learning-rate
    backoff for SGD-family optimizers (updates are linear in lr), used by
    the recovery path (`RecoveryPolicy.lr_backoff`). Deterministic: the
    scale is a compiled constant of the restarted run's graphs."""
    s = jnp.float32(scale)

    def update(grads, state, params=None):
        updates, state = opt.update(grads, state, params)
        return jax.tree.map(lambda u: u * s, updates), state

    return Optimizer(init=opt.init, update=update)


# ---------------------------------------------------------------------------
# Simulator: the pure functional core
# ---------------------------------------------------------------------------


class Simulator:
    """One FL system: M clients with data + a delay model, as pure
    state-in/state-out methods over `SimState`.

    `data` is either a list of per-client batch iterators (shared, legacy
    style) or a factory `seed -> list of iterators` — the factory form is
    what makes `init(seed)` / `run_fleet(seeds=...)` give every member its
    own independently-seeded data stream. Everything else (population,
    wireless, compiled step functions, the device-resident dataset upload)
    is immutable and shared across all states and fleet members.
    """

    def __init__(
        self,
        loss_fn: Callable,  # (params, batch) -> (loss, metrics)
        init_params: Any,
        data: Any,  # List[iterator] | Callable[[int], List[iterator]]
        data_sizes: np.ndarray,  # D_m
        fed: FedConfig,
        opt: Optimizer,
        pop: delay.DevicePopulation,
        wireless: Optional[WirelessConfig] = None,
        eval_fn: Optional[Callable] = None,  # (params) -> {'acc','loss'}
        label: str = "defl",
        backend: str = "scan",
        impl: str = "xla",  # quantize kernel: 'xla' | 'pallas'
        scenario: Optional[Any] = None,  # scenarios.Scenario | name | None
        eval_batch_fn: Optional[Callable] = None,  # stacked (S,...) params
        masked_loss_fn: Optional[Callable] = None,  # (p, batch, mask, n)
        envelope_key: Optional[Any] = None,  # study.py graph-cache key
        faults: Optional[FaultModel] = None,  # fault/recovery overlay
        cohort: Optional[int] = None,  # K-client sampled participation
        cohort_sampler: str = "uniform",  # 'uniform' | 'weighted' (by D_m)
        cohort_spare: int = 0,  # over-provisioned candidates per round
        shard_clients: bool = False,  # shard the client axis over devices
        async_spec: Optional[Any] = None,  # events.AsyncSpec (backend='async')
    ):
        """eval_batch_fn evaluates a whole stacked member axis at once —
        (S, ...) param leaves -> dict of (S,) metrics — so fleet/study
        time-to-accuracy sweeps don't serialize on a host eval loop at
        chunk boundaries. masked_loss_fn is the (V, b)-envelope form of
        loss_fn (see mesh_rounds.envelope_local_steps_fn) and
        envelope_key a hashable graph signature; both are optional
        capabilities the Study API (federated/study.py) uses to group
        this simulator's arm with others — ExperimentSpec.build provides
        all three.

        `faults` overlays a faults.FaultModel on the scenario (deadline-
        bounded rounds, uplink retransmission with backoff, crash/rejoin
        lifecycle, divergence guards — see the faults module). A
        fault-bearing scenario (e.g. the registered 'unreliable_edge')
        works without this argument; the explicit kwarg layers faults on
        any scenario — including none, which overlays onto 'uniform' so
        the realization stream exists. An inactive FaultModel is ignored
        entirely: the compiled graphs, RNG streams and accounting are
        bit-identical to not passing one.

        `cohort=K` turns on sampled participation: each round a K-client
        cohort is drawn from the M-client population (uniformly, or
        D_m-weighted with cohort_sampler='weighted') and only its members
        compute/upload. Device client-state shrinks to O(K) — the stacked
        params/opt carry K lanes, re-initialized from the global model
        every round (FedAvg broadcasts it, so this is automatic for
        params; the local optimizer must be stateless) — while the
        population model (data partitions, scenario masks, channel
        state) stays O(M) host-side. K = M runs the sampled machinery
        over the full population and is bit-identical to the dense path.

        `shard_clients=True` shards the stacked client axis over all
        JAX devices (scan backend): FedAvg aggregation becomes a
        shard_map psum (mesh_rounds._psum_shardmap_sync). Prototype on
        CPU via XLA_FLAGS=--xla_force_host_platform_device_count=N."""
        # Original constructor arguments, captured before any overlay/
        # promotion below mutates the derived views: the recovery path
        # (_recovery_variant) rebuilds a near-identical Simulator from
        # these with only the optimizer scale / guard norm changed.
        self._ctor = dict(
            loss_fn=loss_fn, init_params=init_params, data=data,
            data_sizes=data_sizes, fed=fed, opt=opt, pop=pop,
            wireless=wireless, eval_fn=eval_fn, label=label,
            backend=backend, impl=impl, scenario=scenario,
            eval_batch_fn=eval_batch_fn, masked_loss_fn=masked_loss_fn,
            envelope_key=envelope_key, faults=faults, cohort=cohort,
            cohort_sampler=cohort_sampler, cohort_spare=cohort_spare,
            shard_clients=shard_clients, async_spec=async_spec)
        if backend not in ("scan", "batched", "loop", "async"):
            raise ValueError(f"unknown backend {backend!r}")
        if backend == "async" and async_spec is None:
            raise ValueError(
                "backend='async' needs an aggregation policy — pass "
                "async_spec=events.AsyncSpec(buffer_size=K, ...)")
        if (async_spec is not None
                and async_spec.buffer_size > fed.n_devices):
            raise ValueError(
                f"AsyncSpec.buffer_size ({async_spec.buffer_size}) must "
                f"not exceed n_devices ({fed.n_devices}): accepted "
                "updates block their client until the consuming "
                "aggregation, so a buffer larger than the population "
                "could never fill")
        if async_spec is not None and backend != "async":
            raise ValueError(
                f"async_spec is only meaningful with backend='async' "
                f"(got backend={backend!r}) — drop it or switch backends")
        if backend == "async":
            if cohort is not None:
                raise ValueError(
                    "backend='async' and cohort=K (sampled participation) "
                    "are mutually exclusive: the event queue already "
                    "schedules per-client work continuously, so there is "
                    "no per-round cohort to draw. Drop cohort (every "
                    "client stays in flight) or use backend='scan'.")
            if shard_clients:
                raise ValueError(
                    "backend='async' and shard_clients are mutually "
                    "exclusive: the event scan runs ONE client per event "
                    "(nothing to shard over a client mesh). Drop "
                    "shard_clients or use backend='scan'.")
        self._async = async_spec if backend == "async" else None
        if cohort_sampler not in ("uniform", "weighted"):
            raise ValueError(
                f"unknown cohort_sampler {cohort_sampler!r}; "
                "expected 'uniform' or 'weighted'")
        if cohort is not None:
            if backend == "loop":
                raise ValueError(
                    "cohort (sampled participation) requires backend "
                    "'scan' or 'batched' — the loop reference is dense-only")
            if not 1 <= int(cohort) <= pop.n:
                raise ValueError(
                    f"cohort must be in [1, {pop.n}], got {cohort}")
        self._cohort = None if cohort is None else int(cohort)
        self._sampled = self._cohort is not None
        if not isinstance(cohort_spare, (int, np.integer)) or cohort_spare < 0:
            raise ValueError(
                f"cohort_spare must be an int >= 0, got {cohort_spare!r}")
        if cohort_spare and not self._sampled:
            raise ValueError(
                "cohort_spare (over-provisioned cohorts) requires sampled "
                "participation — pass cohort=K as well")
        if self._sampled and self._cohort + int(cohort_spare) > pop.n:
            raise ValueError(
                f"cohort + cohort_spare ({cohort} + {cohort_spare}) must "
                f"not exceed the population size {pop.n}")
        self._spare = int(cohort_spare)
        # Candidate-draw width: each round draws K + spare candidates and
        # keeps the K deadline-feasible-fastest (_select_cohorts).
        self._cohort_draw = (
            None if self._cohort is None else self._cohort + self._spare)
        self._cohort_weights = (
            np.asarray(np.asarray(data_sizes), np.float64)
            if (self._sampled and cohort_sampler == "weighted") else None)
        self.loss_fn = loss_fn
        self._data_src = data
        self.data_sizes = data_sizes
        self.fed = fed
        self.opt = opt
        self.pop = pop
        self.wireless = wireless or WirelessConfig()
        self.eval_fn = eval_fn
        self.eval_batch_fn = eval_batch_fn
        self.masked_loss_fn = masked_loss_fn
        self.envelope_key = envelope_key
        self.label = label
        self.backend = backend
        self.impl = impl
        self.scenario = scenarios.get(scenario) if scenario is not None else None
        if faults is not None and faults.active:
            base = self.scenario or scenarios.get("uniform")
            self.scenario = base.replace(faults=faults)
        if self._sampled and self.scenario is None:
            # Cohort draws live on the ScenarioStream: promote to the
            # neutral 'uniform' scenario so the stream exists (same
            # pattern as the faults overlay above).
            self.scenario = scenarios.get("uniform")
        if self._async is not None and self.scenario is None:
            # The event queue draws per-dispatch service times from the
            # realization stream — promote like the sampled path does.
            self.scenario = scenarios.get("uniform")
        fm = self.scenario.faults if self.scenario is not None else None
        self._faults = fm if (fm is not None and fm.active) else None
        self._guard = None
        if self._faults is not None:
            self._faults.validate()
            g = self._faults.guard_spec()
            # A trivial guard (no clipping, no rejection) builds no ops at
            # all — the graph stays byte-identical to the guard-less one.
            self._guard = None if (g[0] == float("inf") and not g[1]) else g
        # Quorum gate: resolved to an absolute participant count against
        # the round's cohort size (K when sampled, M dense). None when no
        # quorum is configured — then NO quorum ops/inputs are built and
        # the compiled graphs stay byte-identical to a quorum-less sim.
        self._quorum = self._quorum_policy = None
        if self._faults is not None:
            q = self._faults.resolve_quorum(
                self._cohort if self._sampled else fed.n_devices)
            if q is not None:
                self._quorum = q
                self._quorum_policy = self._faults.quorum_policy
        if self._async is not None and self._quorum is not None:
            raise ValueError(
                "backend='async' and FaultModel.min_quorum are mutually "
                "exclusive: the buffered server aggregates whenever "
                "buffer_size updates arrive — there is no per-round "
                "participant count to gate. Drop min_quorum from the "
                "FaultModel (AsyncSpec.buffer_size IS the async quorum) "
                "or use backend='scan'.")
        if (self._async is not None and self._faults is not None
                and self._faults.max_update_norm is not None):
            raise ValueError(
                "backend='async' and FaultModel.max_update_norm are "
                "mutually exclusive: update sanitation runs at the sync "
                "round step's participant axis, which the event scan "
                "does not have. Drop max_update_norm or use "
                "backend='scan'. (The always-on defaults "
                "reject_nonfinite/divergence_guard are round-level "
                "guards and are inert on the async backend.)")
        # Envelope-form graphs: when the masked loss is available, the
        # compiled batched/scan graphs run mesh_rounds' (V, b)-envelope
        # round step at the TRIVIAL envelope (V_env=V, B_env=b, all-ones
        # masks as traced inputs). The masking ops change XLA's fusion of
        # the loss computation by an ulp relative to the plain form, and
        # fusion follows op structure, not mask values — so sharing the
        # structure is what makes a native run() bit-identical to the same
        # arm running padded inside a Study group (observed: padded ==
        # trivial-envelope bit-for-bit; plain == neither). The loop
        # backend keeps the plain loss (its parity is tolerance-based).
        # (The async event scan runs one client per event — there is no
        # member axis to envelope-pad, so async arms run solo in a Study.)
        self._envelope = (masked_loss_fn is not None
                          and backend not in ("loop", "async"))
        self._env_cache: Optional[dict] = None
        probe = self._make_iters(fed.seed)
        assert len(probe) == fed.n_devices == pop.n
        if hasattr(probe, "client") and not self._sampled:
            raise ValueError(
                "a ClientDataPool data source requires cohort sampling "
                "(cohort=K) — the dense backends stack every client's "
                "batches, which is exactly what the pool exists to avoid")
        self._init_params = jax.tree.map(jnp.asarray, init_params)
        if self._sampled and jax.tree.leaves(opt.init(self._init_params)):
            raise ValueError(
                "sampled participation carries no per-client optimizer "
                "state between rounds (cohort lanes change owners every "
                "round; clients re-initialize from the global model) — "
                "use a stateless local optimizer (plain SGD)")
        if (self._async is not None
                and jax.tree.leaves(opt.init(self._init_params))):
            raise ValueError(
                "backend='async' re-dispatches every client from the "
                "current global model, so per-client optimizer state "
                "carried across stale dispatches is ill-defined — use a "
                "stateless local optimizer (plain SGD)")
        # Sharded client axis: FedAvg aggregation as a shard_map psum
        # over a 1-D 'clients' device mesh.
        self._mesh = self._param_specs = None
        self.shard_clients = bool(shard_clients)
        if shard_clients:
            if backend != "scan":
                raise ValueError(
                    f"shard_clients requires backend='scan', not {backend!r}")
            if fed.compress_updates:
                raise ValueError(
                    "shard_clients with compress_updates is unsupported: "
                    "the int8 quantizer uses its own aggregation path")
            n_dev = jax.device_count()
            C = self._cohort if self._sampled else fed.n_devices
            if C % n_dev:
                raise ValueError(
                    f"client axis ({C} lanes) must divide evenly over the "
                    f"{n_dev} available devices")
            self._mesh = jax.sharding.Mesh(
                np.array(jax.devices()), ("clients",))
            spec = jax.sharding.PartitionSpec("clients")
            self._param_specs = jax.tree.map(
                lambda _: spec, self._init_params)
        # Static per-client compute times (Eq. 4); uplink times depend on
        # the realized per-round channel and are computed per round.
        self._t_cp_clients = delay.per_client_compute_time(
            fed.batch_size, pop.G, pop.f)
        # Host f32 twin of the FedAvg size-weight vector: the sampled path
        # gathers per-round (R, K) cohort rows from it instead of
        # uploading M-sized arrays per chunk. The cast matches the dense
        # path's jnp.float32 conversion exactly, so a gathered K=M row is
        # bit-identical to the dense chunk constant.
        self._sizes_host = np.asarray(np.asarray(data_sizes), np.float32)
        # Shape-only view of the global model: _update_bits computes wire
        # sizes from this, so delay accounting never dispatches a device op
        # or blocks the async queue (see the _update_bits docstring).
        self._param_struct = jax.eval_shape(lambda p: p, init_params)
        self._bits_cache: Optional[float] = None
        # Round deadline in simulated seconds: a `deadline_factor` resolves
        # against THIS sim's nominal full-population Eq. 8 round time, so
        # the same FaultModel ports across models/populations.
        self._deadline = None
        if self._faults is not None:
            nominal = delay.round_time(*self.round_times(), fed.local_rounds)
            self._deadline = self._faults.resolve_deadline(nominal)
        self._fleet_fn = None
        self._fleet_base = None
        if backend == "loop":
            self.local_update = make_local_update(loss_fn, opt)
        elif backend == "async":
            # The event scan renormalizes size weights in-graph per
            # aggregation; only the raw sizes ship.
            self._sizes_f32 = jnp.asarray(np.asarray(data_sizes), jnp.float32)
        else:
            w = jnp.asarray(np.asarray(data_sizes), jnp.float32)
            # Legacy path: host-normalized FedAvg weights. The scenario path
            # instead ships the raw sizes and renormalizes in-graph over the
            # round's participation mask (mesh_rounds._participation_weights).
            self._weights = w / jnp.sum(w)
            self._sizes_f32 = w
            self._round_fn = self._build_batched_round()
        if backend == "scan":
            self._detect_device_data(probe)
            self._t_cp_dev = jnp.asarray(self._t_cp_clients, jnp.float32)
            self._chunk_raw = self._build_scan_chunk()
            # Same donation contract as the batched round step, amortized
            # over a whole chunk: XLA reuses the carry buffers across
            # chunks. All per-chunk inputs are traced arrays of fixed
            # (R, ...) shape and a ragged final chunk pads to R under the
            # valid flag, so a whole run compiles exactly once.
            self._chunk_fn = jax.jit(self._chunk_raw, donate_argnums=(0, 1, 2))
        if backend == "async":
            from repro.federated import events as _events

            self._events_mod = _events
            self._detect_device_data(probe)
            # Static per-chunk event budget E: every chunk pads its event
            # axis to E (the ragged-tail trick on the event axis), so one
            # trace serves the whole run. The default covers several full
            # population turnovers (or buffer fills) per dispatch.
            self._async_E = int(
                self._async.event_budget
                if self._async.event_budget is not None
                else 8 * max(fed.n_devices, self._async.buffer_size))
            self._chunk_raw = mesh_rounds.build_async_chunk(
                loss_fn, self.opt, fed.local_rounds, fed.n_devices,
                self._async, impl=self.impl, batch_from=self._batch_from,
                compress=fed.compress_updates)
            # params/opt/key AND the async carry are donated: the event
            # queue's finish-time/buffer leaves reuse their buffers across
            # chunks exactly like the sync carry trio.
            self._chunk_fn = jax.jit(
                self._chunk_raw, donate_argnums=(0, 1, 2, 3))

    def _detect_device_data(self, its) -> None:
        """Device-resident data path: when every client iterator draws
        from one shared dataset and speaks the index protocol
        (data.BatchIterator), upload the backing arrays once and gather
        batches in-graph — per chunk only int32 index arrays cross the
        host->device boundary. Anything else falls back to pre-stacked
        host batches per chunk."""
        self._data_dev = self._batch_from = None
        if hasattr(its, "client"):  # ClientDataPool: one shared dataset
            self._data_dev = jax.tree.map(
                jnp.asarray, its.device_arrays())
            self._batch_from = its.batch_from
        elif (its
                and all(hasattr(it, "next_indices")
                        and hasattr(it, "device_arrays") for it in its)
                and getattr(its[0], "data", None) is not None
                and len({id(getattr(it, "data", None))
                         for it in its}) == 1):
            self._data_dev = jax.tree.map(
                jnp.asarray, its[0].device_arrays())
            self._batch_from = type(its[0]).batch_from

    # -- state construction -------------------------------------------------
    def init(self, seed: Optional[int] = None) -> SimState:
        """A fresh run state at `seed` (default: fed.seed): replicated
        client params/opt, PRNGKey(seed), round 0, clock 0, and the
        seed's scenario-stream / data-iterator start positions.

        Data-stream caveat for the legacy fixed-list form: when the
        Simulator was built with a list of live iterators (instead of a
        `seed -> iterators` factory), `seed` cannot reseed the data —
        init() snapshots the shared iterators' CURRENT position, so a
        second init() after a run starts where the run left off (the
        deprecated FLSimulation's semantics, which constructs one state
        per instance). For reproducible multi-state/multi-seed work,
        build with a factory (ExperimentSpec does)."""
        seed = int(self.fed.seed if seed is None else seed)
        M = self.fed.n_devices
        # Sampled participation: the stacked device state carries K cohort
        # lanes, not M clients — O(K) regardless of population size.
        C = self._cohort if self._sampled else M
        if self.backend == "loop":
            params = self._init_params
            opt_C: Any = tuple(self.opt.init(params) for _ in range(M))
        else:
            params = mesh_rounds.replicate_clients(self._init_params, C)
            opt_C = jax.vmap(
                lambda _: self.opt.init(self._init_params))(jnp.arange(C))
        if self.backend == "async":
            # The initial dispatch hands every client version-0 work at
            # t=0, which consumes ONE realization draw — so the stream
            # position is snapshotted into the state here (unlike the
            # sync backends' "factory-fresh" None).
            stream = self.scenario.stream(self.pop, seed)
            t_svc0, drop0, t_cm0, att0 = self._async_dispatch_draw(stream)
            async_c = {
                "params_g": jax.tree.map(lambda x: x.copy(),
                                         self._init_params),
                "buf": jax.tree.map(
                    lambda x: jnp.zeros(x.shape, jnp.float32),
                    self._init_params),
                "buf_w": jnp.float32(0.0),
                "cnt": jnp.int32(0),
                "loss_sum": jnp.float32(0.0),
                "t_finish": jnp.asarray(t_svc0),
                "t_next": jnp.zeros(C, jnp.float32),
                "now": jnp.float32(0.0),
                "version": jnp.int32(0),
                "version_C": jnp.zeros(C, jnp.int32),
                "drop_C": jnp.asarray(drop0),
            }
            async_host = {"t_cm_disp": np.asarray(t_cm0, np.float64),
                          "attempts_disp": np.asarray(att0, np.float64),
                          "bits_acc": 0.0}
            return SimState(params_C=params, opt_C=opt_C,
                            key=jax.random.PRNGKey(seed), seed=seed,
                            stream=stream.state(), async_c=async_c,
                            async_host=async_host)
        # stream/data stay None — "factory-fresh at `seed`", which is
        # exactly what _materialize constructs with no fast-forward, so
        # init() never has to build (and immediately discard) the
        # iterators/stream just to snapshot their start position.
        return SimState(params_C=params, opt_C=opt_C,
                        key=jax.random.PRNGKey(seed), seed=seed)

    def _make_iters(self, seed: int):
        src = (self._data_src(seed) if callable(self._data_src)
               else self._data_src)
        # A ClientDataPool is one lazy object, not a per-client list.
        return src if hasattr(src, "client") else list(src)

    @staticmethod
    def _snapshot_iters(iters) -> Optional[Any]:
        if hasattr(iters, "client"):  # ClientDataPool: O(touched clients)
            return iters.state()
        if all(hasattr(it, "state") and hasattr(it, "set_state")
               for it in iters):
            return tuple(it.state() for it in iters)
        return None

    @staticmethod
    def _restore_iters(iters, snap) -> None:
        if hasattr(iters, "client"):
            iters.set_state(snap)
        else:
            for it, s in zip(iters, snap):
                it.set_state(s)

    def _materialize(self, state: SimState):
        """Live host-side streams positioned at `state`: data iterators
        (factory-fresh, then fast-forwarded from the state's snapshots)
        and the scenario realization stream (cohort-configured when
        sampled — its snapshot carries the cohort-RNG cursor too)."""
        iters = self._make_iters(state.seed)
        if state.data is not None:
            self._restore_iters(iters, state.data)
        stream = None
        if self.scenario is not None:
            stream = self.scenario.stream(
                self.pop, state.seed, cohort_size=self._cohort_draw,
                cohort_weights=self._cohort_weights)
            if state.stream is not None:
                stream.set_state(state.stream)
        return iters, stream

    def _rebuild_state(self, state, params_C, opt_C, key, rnd, sim_time,
                       iters, stream, **extra) -> SimState:
        return dataclasses.replace(
            state, params_C=params_C, opt_C=opt_C, key=key, round=int(rnd),
            sim_time=float(sim_time),
            stream=stream.state() if stream is not None else None,
            data=self._snapshot_iters(iters), **extra)

    # -- state views --------------------------------------------------------
    def params(self, state: SimState) -> Any:
        """The global model in `state` (post-aggregation every client row
        is equal, so row 0 of the stacked state is the global model; the
        async backend carries it explicitly — client rows are dispatch
        snapshots that differ between aggregations)."""
        if self.backend == "loop":
            return state.params_C
        if self.backend == "async":
            return state.async_c["params_g"]
        return jax.tree.map(lambda x: x[0], state.params_C)

    @staticmethod
    def block_until_ready(state: SimState) -> None:
        """Drain the async dispatch queue (benchmarking / checkpoint use)."""
        jax.block_until_ready(state.params_C)

    @property
    def trace_count(self) -> int:
        """Number of compiled traces so far (batched: the round step; scan:
        the chunk step plus any direct run_round calls; +1 once a fleet fn
        is compiled). Scenario masking and chunking must stay at 1 across
        a run — per-round masks, delay inputs and the ragged-final-chunk
        padding are traced values, never new shapes/constants."""
        if self.backend == "loop":
            return 0
        if self.backend == "async":
            # One compiled event-scan chunk serves the whole run: every
            # chunk pads its event axis to the static budget E.
            return int(self._chunk_fn._cache_size())
        count = int(self._round_fn._cache_size())
        if self.backend == "scan":
            count += int(self._chunk_fn._cache_size())
            if self._fleet_fn is not None:
                count += int(self._fleet_fn._cache_size())
        return count

    # -- delay accounting ---------------------------------------------------
    def _update_bits(self) -> float:
        # Memoized, and computed from the shape-only _param_struct captured
        # at init: wire accounting is a pure function of the (static) param
        # structure, so it must never slice device buffers or enqueue work —
        # on the scenario path it feeds every round's realized uplink times,
        # and any device touch here would sit between dispatches and defeat
        # the async round pipeline.
        if self._bits_cache is None:
            if self.fed.update_bytes is not None:
                self._bits_cache = self.fed.update_bytes * 8.0
            elif self.fed.compress_updates:
                # Exact wire accounting for the int8 quantizer: 8-bit payload
                # plus one fp32 scale per 1024-chunk
                # (compression.compressed_bits), not the bits/4 approximation.
                self._bits_cache = float(
                    compression.compressed_bits(self._param_struct))
            else:
                self._bits_cache = float(tree_bytes(self._param_struct) * 8.0)
        return self._bits_cache

    def round_times(self) -> tuple:
        T_cm = delay.round_comm_time(
            self._update_bits(), self.wireless, self.pop.p, self.pop.h)
        T_cp = delay.round_compute_time(
            self.fed.batch_size, self.pop.G, self.pop.f)
        return T_cm, T_cp

    # -- envelope plumbing ---------------------------------------------------
    def _trivial_env(self) -> dict:
        """The all-ones (V, b)-envelope masks for this sim's native
        shapes, passed as TRACED inputs into the compiled steps (closing
        over them would constant-fold the masking and change fusion — the
        exact divergence the envelope form exists to avoid)."""
        if self._env_cache is None:
            fed = self.fed
            self._env_cache = {
                "v_mask": jnp.ones(fed.local_rounds, jnp.float32),
                "sample_mask": jnp.ones(fed.batch_size, jnp.float32),
                "n_samples": jnp.float32(fed.batch_size),
                "v_count": jnp.float32(fed.local_rounds),
                "update_bits": jnp.float32(self._update_bits()),
            }
        return self._env_cache

    # -- compiled step builders ---------------------------------------------
    def _build_batched_round(self):
        fed = self.fed
        M, V = fed.n_devices, fed.local_rounds
        if self._sampled:
            M = self._cohort  # K cohort lanes (PRNG keys are lane-indexed)
        compress = fed.compress_updates
        agg = "int8_stochastic" if compress else "allreduce"
        envelope = self._envelope
        step = mesh_rounds.build_round_step(
            self.masked_loss_fn if envelope else self.loss_fn, self.opt, V,
            aggregation=agg, impl=self.impl, envelope=envelope,
            guard=self._guard)
        q_min, q_policy = self._quorum, self._quorum_policy

        def fault_tail(new_p, new_s, old_p, old_s, key, loss, n, metrics):
            """Shared fault-path epilogue: the per-lane finite mask (the
            DivergenceError diagnostic) plus the quorum gate — below
            quorum under policy 'reject' the params/opt write reverts to
            the round's inputs (the batched twin of the scan body's
            ok-gated keep mask; same jnp.where, bit-identical)."""
            extras = {"finite": jnp.isfinite(metrics["per_client_loss"])}
            if q_min is not None:
                rejected = n < jnp.float32(q_min)
                if q_policy == "reject":
                    rv = lambda nw, old: jnp.where(  # noqa: E731
                        rejected, old.astype(nw.dtype), nw)
                    new_p = jax.tree.map(rv, new_p, old_p)
                    new_s = jax.tree.map(rv, new_s, old_s)
                extras["rejected"] = rejected
            return new_p, new_s, key, loss, n, extras

        if self.scenario is None:
            weights = self._weights

            def round_fn(params_C, opt_C, key, batches, env=None):
                keys_C = None
                if compress:
                    key, keys_C = compression.sequential_client_keys(key, M)
                new_p, new_s, metrics = step(
                    params_C, opt_C, batches, weights, keys=keys_C, env=env)
                # Unweighted client mean, matching the loop backend's metric.
                return new_p, new_s, key, jnp.mean(metrics["per_client_loss"])
        elif self._sampled:
            fault = self._faults is not None

            # Sampled form: cohort lanes change owners every round, so
            # the FedAvg size-weights arrive as a traced argument (the
            # gathered (K,) cohort row) instead of a closed-over constant.
            def round_fn(params_C, opt_C, key, batches, sizes,
                         mask, clock_mask, t_cp, t_cm, env=None):
                keys_C = None
                if compress:
                    key, keys_C = compression.sequential_client_keys(key, M)
                new_p, new_s, metrics = step(
                    params_C, opt_C, batches, sizes, keys=keys_C,
                    mask=mask, clock_mask=clock_mask, t_cp=t_cp, t_cm=t_cm,
                    env=env)
                msk = metrics.get("mask_eff", mask)
                n = jnp.sum(msk)
                loss = (jnp.sum(metrics["per_client_loss"] * msk)
                        / jnp.where(n > 0, n, 1.0))
                loss = jnp.where(n > 0, loss, jnp.nan)
                if fault:
                    return fault_tail(new_p, new_s, params_C, opt_C, key,
                                      loss, n, metrics)
                return new_p, new_s, key, loss
        else:
            sizes = self._sizes_f32
            fault = self._faults is not None

            def round_fn(params_C, opt_C, key, batches,
                         mask, clock_mask, t_cp, t_cm, env=None):
                keys_C = None
                if compress:
                    key, keys_C = compression.sequential_client_keys(key, M)
                new_p, new_s, metrics = step(
                    params_C, opt_C, batches, sizes, keys=keys_C,
                    mask=mask, clock_mask=clock_mask, t_cp=t_cp, t_cm=t_cm,
                    env=env)
                # Mean over *participating* clients (the loop backend never
                # runs dropped clients); NaN on a zero-participation round.
                # With a divergence guard, participation is the post-
                # sanitation mask (rejected clients count as dropped).
                msk = metrics.get("mask_eff", mask)
                n = jnp.sum(msk)
                loss = (jnp.sum(metrics["per_client_loss"] * msk)
                        / jnp.where(n > 0, n, 1.0))
                loss = jnp.where(n > 0, loss, jnp.nan)
                if fault:
                    # Guard rejections are decided in-graph, so the true
                    # participant count is a device scalar here (synced at
                    # eval boundaries like the train losses).
                    return fault_tail(new_p, new_s, params_C, opt_C, key,
                                      loss, n, metrics)
                return new_p, new_s, key, loss

        # Donating the stacked params/opt/key buffers lets XLA write round
        # N+1's state into round N's memory: peak HBM stays ~1x the stacked
        # state regardless of round count. The per-round scenario inputs
        # (mask/clock_mask/t_cp/t_cm) are plain traced arrays of fixed
        # shape: new values every round, ONE trace for the whole run.
        return jax.jit(round_fn, donate_argnums=(0, 1, 2))

    def _build_scan_chunk(self):
        """The pure chunk fn (mesh_rounds.build_round_chunk): closure-free
        over run state — params/opt/key and all per-round inputs ride in
        as arguments, which is what lets run_fleet vmap it over a fleet
        axis (mesh_rounds.build_fleet_chunk)."""
        fed = self.fed
        agg = ("int8_stochastic" if fed.compress_updates
               else ("allreduce_shardmap" if self._mesh is not None
                     else "allreduce"))
        n_lanes = self._cohort if self._sampled else fed.n_devices
        return mesh_rounds.build_round_chunk(
            self.masked_loss_fn if self._envelope else self.loss_fn,
            self.opt, fed.local_rounds, n_lanes,
            aggregation=agg, impl=self.impl,
            scenario=self.scenario is not None,
            batch_from=self._batch_from,
            update_bits=self._update_bits(),
            envelope=self._envelope,
            guard=self._guard,
            faults=self._faults is not None,
            sampled=self._sampled,
            quorum=None if self._quorum is None else self._quorum_policy,
            mesh=self._mesh,
            param_specs_tree=self._param_specs,
            client_axes=("clients",) if self._mesh is not None else None)

    def _chunk_call(self, params_C, opt_C, key, weights, t_cp_arg, xs):
        """One compiled chunk dispatch, threading the trivial envelope
        masks on envelope-form sims."""
        if self._envelope:
            return self._chunk_fn(params_C, opt_C, key, weights, t_cp_arg,
                                  self._data_dev, xs, self._trivial_env())
        return self._chunk_fn(params_C, opt_C, key, weights, t_cp_arg,
                              self._data_dev, xs)

    def _get_fleet_fn(self):
        if self._fleet_fn is None:
            self._fleet_fn = jax.jit(
                mesh_rounds.build_fleet_chunk(self._chunk_raw,
                                              envelope=self._envelope,
                                              sampled=self._sampled),
                donate_argnums=(0, 1, 2))
        return self._fleet_fn

    def _fleet_init_base(self):
        """The (params_C, opt_C) every fresh member starts from, cached —
        never donated itself (run_fleet broadcasts a new stacked buffer
        out of it per call), so reuse across calls is safe."""
        if self._fleet_base is None:
            C = self._cohort if self._sampled else self.fed.n_devices
            self._fleet_base = (
                mesh_rounds.replicate_clients(self._init_params, C),
                jax.vmap(lambda _: self.opt.init(self._init_params))(
                    jnp.arange(C)))
        return self._fleet_base

    # -- fault semantics (host f64 side) ------------------------------------
    def _fault_round(self, real):
        """Resolve a realization's retransmission + deadline semantics:
        (real', t_cm_clients, attempts_total).

        t_cm_clients is the effective per-client uplink time — the SUM of
        every attempt's Eq. 6 airtime plus backoff waits (f64, the host
        clock twin). With a deadline, clients whose V*t_cp + effective
        uplink exceeds it are cut from the aggregation mask (they stay in
        clock_mask: the server waited on them until the deadline). Both
        decisions are host-side f64 — the compiled graph only consumes
        their traced results — and idempotent, so re-applying to an
        already-resolved realization is a no-op."""
        fm = self._faults
        t_cm = delay.effective_uplink_times(
            self._update_bits(), self.wireless, self.pop.p,
            real.h_att, real.attempts, fm.backoff_base, fm.backoff_factor)
        if self._deadline is not None:
            finish = self.fed.local_rounds * self._t_cp_clients + t_cm
            mask = np.asarray(real.mask, bool) & (finish <= self._deadline)
            real = dataclasses.replace(real, mask=mask)
        return real, t_cm, int(real.attempts.sum())

    @staticmethod
    def _gather_real(real, cohort):
        """Restrict an M-wide realization to the cohort's columns. Fault
        semantics (retransmission clocks, deadline cuts) are resolved
        M-wide FIRST, then gathered — sampling selects who participates,
        it never changes what would have happened to them."""
        return dataclasses.replace(
            real,
            mask=np.asarray(real.mask)[cohort],
            clock_mask=np.asarray(real.clock_mask)[cohort],
            h=np.asarray(real.h)[cohort],
            attempts=(None if real.attempts is None
                      else np.asarray(real.attempts)[cohort]),
            h_att=(None if real.h_att is None
                   else np.asarray(real.h_att)[cohort]))

    def _chunk_uplink(self, chunk):
        """M-wide (mask, t_cm) for a chunk realization: the effective
        per-client uplink times (retransmission sums on the fault path,
        single-shot Eq. 6 otherwise) and the aggregation mask after the
        deadline cut. f64 host twin, vectorized over the round axis —
        each row bit-identical to the per-round _fault_round resolution.
        Fault semantics resolve POPULATION-wide even under sampling, so
        cohort gathers see exactly the rows a dense run would."""
        mask = np.asarray(chunk.mask, bool)
        if self._faults is not None:
            fm = self._faults
            t_cm = delay.effective_uplink_times(
                self._update_bits(), self.wireless, self.pop.p,
                chunk.h_att, chunk.attempts,
                fm.backoff_base, fm.backoff_factor)
            if self._deadline is not None:
                finish = delay.finish_times(
                    self._t_cp_clients, t_cm, self.fed.local_rounds)
                mask = mask & (finish <= self._deadline)
        else:
            t_cm = delay.per_client_uplink_time(
                self._update_bits(), self.wireless, self.pop.p, chunk.h)
        return mask, t_cm

    def _select_cohorts(self, cands: np.ndarray, t_cm: np.ndarray,
                        ) -> np.ndarray:
        """Over-provisioned cohort selection: keep the K deadline-
        feasible-fastest of each round's (K + spare) candidate draw.

        Ranking is by the f64 per-client finish time V*t_cp + t_cm
        (delay.finish_times) with deadline-infeasible candidates sorted
        last and ties broken by client index; the kept K are returned
        sorted ascending (the cohort-index convention draw_cohort
        establishes). Selection happens AFTER the M-wide fault
        resolution (t_cm is the effective uplink time) and BEFORE any
        cohort gather — sampling selects who participates, it never
        changes what would have happened to them."""
        K = self._cohort
        finish_all = delay.finish_times(
            self._t_cp_clients, t_cm, self.fed.local_rounds)
        finish = np.take_along_axis(finish_all, cands, axis=1)
        infeas = (finish > self._deadline if self._deadline is not None
                  else np.zeros(finish.shape, bool))
        out = np.empty((cands.shape[0], K), np.int32)
        for r in range(cands.shape[0]):
            # lexsort: LAST key is primary — feasible first, then
            # fastest, ties by client id.
            order = np.lexsort((cands[r], finish[r], infeas[r]))
            out[r] = np.sort(cands[r][order[:K]])
        return out

    def _raise_if_diverged(self, history, start: int, snap,
                           finites=None) -> int:
        """run()-level divergence guard: a non-finite train loss on a
        round that HAD participants means the aggregate itself is
        poisoned (zero-participation rounds are legitimately NaN and
        pass). Raises DivergenceError carrying the last-good snapshot —
        plus the offending round's per-lane finite mask (`finites`,
        aligned with `history`, when the backend collected them) and the
        FaultModel / guard spec in force, so a diagnosing caller sees
        WHICH clients went non-finite without re-running. Returns the
        new checked-up-to index otherwise."""
        for i in range(start, len(history)):
            rec = history[i]
            n_p = rec.n_participants
            if (isinstance(rec.train_loss, float)
                    and not np.isfinite(rec.train_loss)
                    and (n_p is None or n_p > 0)):
                fin = finites[i] if finites is not None and i < len(finites) else None
                raise DivergenceError(
                    f"train loss became non-finite ({rec.train_loss}) at "
                    f"round {rec.round} with "
                    f"{'all' if n_p is None else n_p} participating "
                    "clients; .state holds the last-good SimState "
                    "snapshot, .history the records up to the failure",
                    state=snap, history=history[:i + 1], round=rec.round,
                    faults=self._faults, guard=self._guard,
                    finite_mask=(None if fin is None
                                 else jax.device_get(fin)))
        return len(history)

    # -- per-round execution ------------------------------------------------
    def run_round(self, state: SimState, real=None, t_cm_clients=None):
        """One communication round: (state, metrics-dict). `real` is the
        scenario's per-round realization (drawn from the state's stream
        when omitted); passing it on a scenario-less simulation raises —
        there is no participation/channel semantics to apply it to.
        `t_cm_clients` lets run() share its per-client uplink-time vector
        instead of recomputing. The scan backend shares the batched
        backend's per-round step here (same stacked state layout);
        chunking only applies inside run()."""
        if self.backend == "async":
            raise ValueError(
                "run_round is round-synchronous; backend='async' advances "
                "by arrival events, not rounds — use run() (aggregation "
                "cadence) or run_events() (exact event counts).")
        if real is not None and self.scenario is None:
            raise ValueError(
                "run_round(real=...) was given a scenario realization but "
                "this simulation has no scenario — the mask/channel inputs "
                "would be silently ignored. Construct the Simulator with "
                "scenario=... or drop the argument.")
        if real is not None and self._sampled:
            raise ValueError(
                "run_round(real=...) is unsupported with sampled cohorts: "
                "an externally supplied M-wide realization has no cohort "
                "to condition on. Drop the argument (the state's stream "
                "draws both) or run dense.")
        iters, stream = self._materialize(state)
        cohort = None
        if self.scenario is not None and real is None:
            if self._sampled:
                cohort = stream.draw_cohort()
            real = stream.next_round()
        if self._faults is not None and real is not None:
            real, t_cm_fault, _ = self._fault_round(real)
            if t_cm_clients is None:
                t_cm_clients = t_cm_fault
        if cohort is not None:
            if self._spare:
                # Rank the K+spare candidates by effective finish time
                # (M-wide fault semantics already resolved above).
                if t_cm_clients is None:
                    t_cm_clients = delay.per_client_uplink_time(
                        self._update_bits(), self.wireless, self.pop.p,
                        real.h)
                cohort = self._select_cohorts(
                    np.asarray(cohort)[None],
                    np.asarray(t_cm_clients, np.float64)[None])[0]
            real = self._gather_real(real, cohort)
            if t_cm_clients is not None:
                t_cm_clients = np.asarray(t_cm_clients)[cohort]
        if self.backend == "loop":
            params, opt_C, key, metrics = self._round_loop(
                state.params_C, state.opt_C, state.key, iters, real)
        else:
            params, opt_C, key, metrics = self._round_batched(
                state.params_C, state.opt_C, state.key, iters, real,
                t_cm_clients, cohort)
        new_state = self._rebuild_state(
            state, params, opt_C, key, state.round + 1, state.sim_time,
            iters, stream)
        return new_state, metrics

    def _round_batched(self, params_C, opt_C, key, iters, real,
                       t_cm_clients=None, cohort=None):
        V = self.fed.local_rounds
        batches = (stack_cohort_batches(iters, cohort, V)
                   if cohort is not None else stack_client_batches(iters, V))
        env = self._trivial_env() if self._envelope else None
        if self.scenario is None:
            params_C, opt_C, key, loss = self._round_fn(
                params_C, opt_C, key, batches, env)
            return params_C, opt_C, key, {"train_loss": loss}  # device scalar
        if t_cm_clients is None:  # direct run_round callers; run() shares its vector
            p = self.pop.p if cohort is None else self.pop.p[cohort]
            t_cm_clients = delay.per_client_uplink_time(
                self._update_bits(), self.wireless, p, real.h)
        mask = jnp.asarray(real.mask, jnp.float32)
        clock_mask = jnp.asarray(real.clock_mask, jnp.float32)
        t_cp = jnp.asarray(self._t_cp_clients if cohort is None
                           else self._t_cp_clients[cohort], jnp.float32)
        t_cm = jnp.asarray(t_cm_clients, jnp.float32)
        if cohort is not None:
            sizes = jnp.asarray(self._sizes_host[cohort])
            if self._faults is not None:
                params_C, opt_C, key, loss, n_dev, extras = self._round_fn(
                    params_C, opt_C, key, batches, sizes, mask, clock_mask,
                    t_cp, t_cm, env)
                return params_C, opt_C, key, {
                    "train_loss": loss, "n_participants": n_dev, **extras}
            params_C, opt_C, key, loss = self._round_fn(
                params_C, opt_C, key, batches, sizes, mask, clock_mask,
                t_cp, t_cm, env)
            return params_C, opt_C, key, {
                "train_loss": loss, "n_participants": real.n_participants}
        if self._faults is not None:
            # Guard rejections happen in-graph: the participant count is
            # the compiled step's fifth output (a device scalar until the
            # next _sync_history boundary).
            params_C, opt_C, key, loss, n_dev, extras = self._round_fn(
                params_C, opt_C, key, batches, mask, clock_mask, t_cp,
                t_cm, env)
            return params_C, opt_C, key, {
                "train_loss": loss, "n_participants": n_dev, **extras}
        params_C, opt_C, key, loss = self._round_fn(
            params_C, opt_C, key, batches, mask, clock_mask, t_cp, t_cm, env)
        return params_C, opt_C, key, {
            "train_loss": loss, "n_participants": real.n_participants}

    def _round_loop(self, params, opt_states, key, iters, real):
        V = self.fed.local_rounds
        M = len(iters)
        deltas, sizes, losses = [], [], []
        keys_C = None
        if self.fed.compress_updates:
            # Keys are drawn for all M clients regardless of participation
            # (the batched backend must: vmap is shape-static), so the two
            # backends' PRNG streams stay aligned under any mask.
            key, keys_C = compression.sequential_client_keys(key, M)
        mask = np.ones(M, bool) if real is None else np.asarray(real.mask, bool)
        opt_states = list(opt_states)
        # Quorum gate reference: pre-round opt snapshot so a rejected
        # round can revert every client's local-opt advance (the loop
        # twin of the batched/scan no-op write).
        pre_opts = list(opt_states) if self._quorum is not None else None
        for m, it in enumerate(iters):
            # Data is drawn for every client every round — participating or
            # not — matching stack_client_batches on the batched backend so
            # both consume identical iterator streams.
            raw = [it.next_batch() for _ in range(V)]
            if not mask[m]:
                continue
            batches = stack_batches(
                [jax.tree.map(jnp.asarray, b) for b in raw])
            prev_opt = opt_states[m]
            delta, opt_states[m], loss_v = client_round(
                self.local_update, params, opt_states[m], batches)
            loss_m = float(jnp.mean(loss_v))
            if self._guard is not None:
                # Reference implementation of the in-graph divergence
                # guard (mesh_rounds._guard_clients): same f32 norm, same
                # reject/clip decisions, so the backends agree to the
                # usual loop tolerance.
                max_norm, reject = self._guard
                sq = jnp.float32(0.0)
                for d in jax.tree.leaves(delta):
                    sq = sq + jnp.sum(jnp.asarray(d, jnp.float32) ** 2)
                norm = float(jnp.sqrt(sq))
                finite = np.isfinite(norm) and np.isfinite(loss_m)
                if reject and not finite:
                    # Rejected = dropped this round: pre-round opt state
                    # restored, no delta, not counted a participant.
                    opt_states[m] = prev_opt
                    continue
                if np.isfinite(max_norm) and finite:
                    scale = min(1.0, max_norm / max(norm, 1e-12))
                    # Mirror the batched clip exactly: reconstruct the
                    # clipped params (o + d*scale) and re-derive the
                    # delta from them, rounding included.
                    delta = jax.tree.map(
                        lambda o, d: (o.astype(jnp.float32)
                                      + d.astype(jnp.float32) * scale)
                        - o.astype(jnp.float32),
                        params, delta)
            if self.fed.compress_updates:
                delta = compression.decompress_update(
                    compression.compress_update(delta, keys_C[m], impl=self.impl),
                    impl=self.impl)
            deltas.append(delta)
            sizes.append(self.data_sizes[m])
            losses.append(loss_m)
        rejected = None
        if self._quorum is not None and real is not None:
            # Same participant count the batched/scan gates compare:
            # post-guard when a guard is in force, the raw mask otherwise.
            n_q = (len(deltas) if self._guard is not None
                   else int(mask.sum()))
            rejected = n_q < self._quorum
        if rejected and self._quorum_policy == "reject":
            # Below quorum: the whole round is a no-op write — no
            # aggregation, pre-round opt states restored. (The clock
            # still advances; run() pays the re-dispatch cost.)
            opt_states = pre_opts
        elif deltas:  # zero-participation round: params unchanged
            params = aggregate_updates(params, deltas, sizes)
        out = {"train_loss": float(np.mean(losses)) if losses else float("nan")}
        if real is not None:
            out["n_participants"] = (len(deltas) if self._guard is not None
                                     else int(mask.sum()))
            if rejected is not None:
                out["rejected"] = rejected
        return params, tuple(opt_states), key, out

    # -- chunked execution (scan backend) -----------------------------------
    @staticmethod
    def _pad_rounds(a: np.ndarray, R: int) -> np.ndarray:
        """Pad a round-stacked array to R rounds with zeros (ragged final
        chunk; the padded tail is masked out in-graph via `valid`)."""
        n = a.shape[0]
        if n == R:
            return a
        return np.concatenate([a, np.zeros((R - n, *a.shape[1:]), a.dtype)])

    def _chunk_inputs(self, iters, stream, R: int, n: int,
                      envelope: Optional[tuple] = None):
        """Host-side prep for one chunk: draw n rounds of data (+ scenario
        realizations), pad to R, and return (xs pytree for the scan — all
        numpy leaves so run_fleet can stack members before the single
        upload — plus a host dict with the f64 clock accounting for the
        history records). With `envelope=(V_env, B_env)` (the Study
        group executor) the native draws are additionally zero-padded
        into the group envelope — never extra draws, so the
        iterator/stream consumption is identical to a native run's."""
        V, b = self.fed.local_rounds, self.fed.batch_size
        M = self.fed.n_devices
        L = self._cohort if self._sampled else M  # lanes in the xs leaves
        V_env, B_env = envelope if envelope is not None else (V, b)
        pad = self._pad_rounds

        def pad_env(a):
            a = np.asarray(a)
            if (V_env, B_env) == (V, b):
                return pad(a, R)
            out = np.zeros((R, L, V_env, B_env) + a.shape[4:], a.dtype)
            out[:n, :, :V, :b] = a
            return out

        # Cohort candidates are drawn first (dedicated RNG, independent
        # of the realization stream) so only selected clients' data
        # iterators advance. The chunk realization is drawn NEXT — before
        # the data advance — because over-provisioned draws (spare > 0)
        # rank the K+spare candidates by realized finish time; the RNG
        # streams are independent generators, so the spare=0 draws are
        # bit-identical to the historical cohorts->data->chunk order
        # (_rewind_chunk replays this exact order).
        cohorts = chunk = mask_M = t_cm_M = None
        if self._sampled:
            cands = stream.draw_cohorts(n)
            chunk = stream.draw_chunk(n)
            mask_M, t_cm_M = self._chunk_uplink(chunk)
            cohorts = (self._select_cohorts(cands, t_cm_M)
                       if self._spare else cands)
        if self._data_dev is not None:
            idx = (stack_cohort_indices(iters, cohorts, V) if self._sampled
                   else stack_chunk_indices(iters, n, V))
            xs = {"idx": pad_env(idx)}
        else:
            if self._sampled:
                rounds_b = [stack_cohort_batches(iters, cohorts[r], V)
                            for r in range(n)]
                batches = jax.tree.map(lambda *bs: np.stack(bs), *rounds_b)
            else:
                batches = stack_chunk_batches(iters, n, V)
            xs = {"batches": jax.tree.map(pad_env, batches)}
        valid = np.zeros(R, bool)
        valid[:n] = True
        xs["valid"] = valid
        host = {}
        if self.scenario is not None:
            if not self._sampled:
                chunk = stream.draw_chunk(n)
                # Retransmission sums + deadline exclusion, resolved
                # M-wide (f64 host twin — see _chunk_uplink).
                mask_M, t_cm_M = self._chunk_uplink(chunk)
            mask, t_cm = mask_M, t_cm_M
            clock_mask = np.asarray(chunk.clock_mask)
            if self._sampled:
                # Everything below the gather sees only cohort columns —
                # bits, attempts and the round clock are conditioned on
                # the sampled cohort (absent clients never hit the air).
                g = lambda a: np.take_along_axis(np.asarray(a), cohorts,
                                                 axis=1)
                mask, clock_mask, t_cm = g(mask), g(clock_mask), g(t_cm)
                t_cp_rows = np.take(self._t_cp_clients, cohorts)
                if self._faults is not None:
                    host["attempts"] = g(chunk.attempts).sum(axis=1)
            else:
                t_cp_rows = self._t_cp_clients
                if self._faults is not None:
                    host["attempts"] = chunk.attempts.sum(axis=1)
            # f64 host twin of the in-graph clock: bit-identical to the
            # per-round backends' accounting (delay.chunk_round_times).
            T_cm, T_cp = delay.chunk_round_times(t_cp_rows, t_cm, clock_mask)
            host.update({"T_cm": T_cm, "T_cp": T_cp,
                         "n_participants": mask.sum(axis=1)})
            xs["mask"] = pad(mask.astype(np.float32), R)
            xs["clock_mask"] = pad(clock_mask.astype(np.float32), R)
            xs["t_cm"] = pad(t_cm.astype(np.float32), R)
            if self._sampled:
                # Per-round cohort rows of the chunk-constant dense args:
                # FedAvg size weights (raw sizes — the step renormalizes
                # in-graph) and compute times, as the SAME f32 values the
                # dense path uploads.
                xs["weights"] = pad(np.take(self._sizes_host, cohorts), R)
                xs["t_cp"] = pad(t_cp_rows.astype(np.float32), R)
            if self._faults is not None:
                cap = np.inf if self._deadline is None else self._deadline
                xs["t_cap"] = pad(np.full(n, cap, np.float32), R)
                xs["bits_mult"] = pad(
                    host["attempts"].astype(np.float32), R)
                if self._quorum is not None:
                    # Padded tail rows carry quorum_min = 0: n >= 0 never
                    # rejects, so padding can't trip the gate.
                    xs["quorum_min"] = pad(
                        np.full(n, self._quorum, np.float32), R)
                    if self._quorum_policy == "reject":
                        xs["q_penalty"] = pad(np.full(
                            n, self._faults.redispatch_cost, np.float32), R)
        return xs, host

    def _rewind_chunk(self, iters, stream, pre_data, pre_stream, t: int):
        """Reposition the host streams as if only the first t rounds of
        the just-drawn chunk had been consumed: restore the pre-chunk
        snapshots and replay t rounds in chunk order. Iterators without
        the snapshot protocol can't be rewound — acceptable only if they
        are stateless (the same assumption checkpointing makes)."""
        V = self.fed.local_rounds
        if self._sampled:
            # Candidates -> chunk -> data, the exact _chunk_inputs order.
            # Index replay (next_indices) is RNG-identical to next_batch.
            stream.set_state(pre_stream)
            cohorts = stream.draw_cohorts(t)
            chunk = stream.draw_chunk(t)
            if self._spare:
                _, t_cm = self._chunk_uplink(chunk)
                cohorts = self._select_cohorts(cohorts, t_cm)
            if pre_data is not None:
                self._restore_iters(iters, pre_data)
                stack_cohort_indices(iters, cohorts, V)
            return
        if pre_data is not None:
            self._restore_iters(iters, pre_data)
            if self._data_dev is not None:
                stack_chunk_indices(iters, t, V)
            else:
                stack_chunk_batches(iters, t, V)
        if stream is not None:
            stream.set_state(pre_stream)
            stream.draw_chunk(t)

    def _chunk_args(self):
        """(weights, t_cp) chunk-fn arguments for this configuration.
        Sampled sims carry both as per-round xs leaves (the gathered
        cohort rows) instead of chunk-constant arguments."""
        if self._sampled:
            return None, None
        if self.scenario is None:
            return self._weights, None
        return self._sizes_f32, self._t_cp_dev

    def _chunk_records(self, ys, host, n: int, r0: int, t0: float,
                       ) -> List[RoundRecord]:
        """Build the n RoundRecords of one chunk from the fetched scan
        outputs `ys` (host numpy, leaves (R,)) and the f64 host-twin clock
        dict, starting at global round r0 and clock t0."""
        update_bits = self._update_bits()
        V = self.fed.local_rounds
        M = self.fed.n_devices
        if self.scenario is None:
            T_cm_const, T_cp_const = self.round_times()
        records = []
        sim_time = t0
        for i in range(n):
            if self.scenario is None:
                T_cm, T_cp, n_part = T_cm_const, T_cp_const, None
                bits = float(M * update_bits)
            elif self._faults is not None:
                T_cm = float(host["T_cm"][i])
                T_cp = float(host["T_cp"][i])
                # With a guard the true participant count is the in-graph
                # post-sanitation one; client counts are exact in fp32.
                n_part = int(ys["n_participants"][i])
                # Every retransmission attempt's bits hit the air.
                bits = float(host["attempts"][i] * update_bits)
            else:
                T_cm = float(host["T_cm"][i])
                T_cp = float(host["T_cp"][i])
                n_part = int(host["n_participants"][i])
                bits = float(n_part * update_bits)
            rej = bool(ys["rejected"][i]) if "rejected" in ys else None
            sim_time += delay.round_time(T_cm, T_cp, V,
                                         deadline=self._deadline)
            if rej and self._quorum_policy == "reject":
                # Rejected rounds pay wall time AND the re-dispatch
                # penalty (host f64 twin of the in-graph T_round term).
                sim_time += self._faults.redispatch_cost
            records.append(RoundRecord(
                round=r0 + i + 1, sim_time=sim_time, T_cm=T_cm, T_cp=T_cp,
                train_loss=float(ys["loss"][i]),
                n_participants=n_part, uplink_bits=bits, rejected=rej))
        return records

    def run_chunk(self, state: SimState, rounds: int):
        """Run `rounds` rounds as ONE compiled scan dispatch (scan backend
        only): (state', [RoundRecord]). The building block `run()` drives
        at eval_every cadence; exposed for custom drivers (schedulers,
        in-graph stopping rules) that want chunk-level control."""
        if self.backend != "scan":
            raise ValueError(
                f"run_chunk requires backend='scan', not {self.backend!r}")
        _validate_run_args(rounds, 1)
        iters, stream = self._materialize(state)
        weights, t_cp_arg = self._chunk_args()
        xs, host = self._chunk_inputs(iters, stream, rounds, rounds)
        params_C, opt_C, key, ys = self._chunk_call(
            state.params_C, state.opt_C, state.key, weights, t_cp_arg, xs)
        ys = jax.device_get(ys)
        records = self._chunk_records(ys, host, rounds, state.round,
                                      state.sim_time)
        new_state = self._rebuild_state(
            state, params_C, opt_C, key, state.round + rounds,
            records[-1].sim_time, iters, stream)
        return new_state, records

    # -- asynchronous (event-driven) execution ------------------------------
    def _async_dispatch_draw(self, stream):
        """One M-wide dispatch realization from the scenario stream:
        (t_svc f32, drop f32, t_cm f64, attempts f64), all (M,).

        t_svc is the full service time V*t_cp + effective uplink (f32 —
        it feeds the f32 finish-time schedule, host twin and in-graph
        alike). drop marks dispatches whose update will be LOST: the
        scenario participation mask, composed with the fault model's
        deadline cut (a dispatch whose service time exceeds the deadline
        never lands — _fault_round resolves that M-wide in f64 exactly as
        the sync path does). Retransmission attempts/backoff waits are
        already inside the effective uplink time, so a retrying client
        simply finishes later."""
        real = stream.next_round()
        if self._faults is not None:
            real, t_cm, _ = self._fault_round(real)
            attempts = np.asarray(real.attempts, np.float64)
        else:
            t_cm = delay.per_client_uplink_time(
                self._update_bits(), self.wireless, self.pop.p, real.h)
            attempts = np.ones(self.fed.n_devices, np.float64)
        t_svc = (self.fed.local_rounds * self._t_cp_clients
                 + t_cm).astype(np.float32)
        drop = (~np.asarray(real.mask, bool)).astype(np.float32)
        return t_svc, drop, np.asarray(t_cm, np.float64), attempts

    def _async_twin(self, state: SimState):
        """The host f32 schedule twin positioned at `state`: a numpy
        replay of the device carry's scheduling slice (events.TwinState).
        One small fetch of the scheduling leaves — params never leave the
        device."""
        a = jax.device_get({k: state.async_c[k] for k in (
            "t_finish", "t_next", "drop_C", "version", "version_C",
            "cnt", "now")})
        h = state.async_host
        return self._events_mod.TwinState(
            t_finish=np.asarray(a["t_finish"], np.float32).copy(),
            t_next=np.asarray(a["t_next"], np.float32).copy(),
            drop=np.asarray(a["drop_C"], np.float32).copy(),
            version=int(a["version"]),
            version_disp=np.asarray(a["version_C"], np.int32).copy(),
            cnt=int(a["cnt"]),
            now=np.float32(a["now"]),
            t_cm_disp=np.asarray(h["t_cm_disp"], np.float64).copy(),
            attempts_disp=np.asarray(h["attempts_disp"], np.float64).copy())

    def _async_chunk_inputs(self, iters, stream, twin, stop_aggs=None,
                            stop_events=None, max_sim_time=None):
        """Host-side prep for one event chunk: advance the schedule twin
        event by event — drawing one M-wide dispatch realization and the
        arriving client's V batches per event — until `stop_aggs`
        aggregations have fired (chunks end exactly at aggregation
        boundaries, the async analogue of eval_every chunking), an
        aggregation crosses `max_sim_time`, `stop_events` events have run
        (run_events' exact-event mode), or the static budget E is full.
        Returns (xs padded to E, [TwinEvent], n_events). The twin is
        mutated in place; because np and jnp share f32 arithmetic and
        first-min argmin, its predicted arrival order is exact (asserted
        against the scan ys in _async_records)."""
        E = self._async_E
        V = self.fed.local_rounds
        limit = E if stop_events is None else min(E, int(stop_events))
        t_svc_rows, drop_rows, data_rows, evs = [], [], [], []
        n_aggs = 0
        while len(evs) < limit:
            c = int(np.argmin(twin.t_finish))
            # The arriving client's batches: its iterator advances at
            # arrival (per-client streams are independent, so client c's
            # k-th dispatch consumes its k-th V-block — the same
            # sequence a dispatch-time draw would produce).
            it = iters[c]
            if self._data_dev is not None:
                data_rows.append(
                    np.stack([it.next_indices() for _ in range(V)]).astype(
                        np.int32))
            else:
                bs = [it.next_batch() for _ in range(V)]
                data_rows.append(
                    jax.tree.map(lambda *x: np.stack(x), *bs))
            t_svc, drop, t_cm, att = self._async_dispatch_draw(stream)
            e = self._events_mod.twin_step(
                self._async, twin, t_svc, drop, t_cm, att)
            assert e.client == c
            t_svc_rows.append(t_svc)
            drop_rows.append(drop)
            evs.append(e)
            if e.aggregated and stop_events is None:
                n_aggs += 1
                if stop_aggs is not None and n_aggs >= stop_aggs:
                    break
                if (max_sim_time is not None
                        and float(e.t_event) >= max_sim_time):
                    break
        n_ev = len(evs)
        pad = self._pad_rounds
        xs = {
            "t_svc": pad(np.stack(t_svc_rows), E),
            "drop_next": pad(np.stack(drop_rows), E),
        }
        valid = np.zeros(E, bool)
        valid[:n_ev] = True
        xs["valid"] = valid
        if self._data_dev is not None:
            xs["idx"] = pad(np.stack(data_rows), E)
        else:
            xs["batches"] = jax.tree.map(
                lambda *r: pad(np.stack(r), E), *data_rows)
        return xs, evs, n_ev

    def _async_records(self, ys, evs, n_ev, r0: int, bits_acc: float):
        """Per-AGGREGATION RoundRecords from one event chunk's fetched
        scan outputs, plus the carried-over uplink-bits accumulator
        (bits of arrivals since the previous aggregation — it spans
        chunk/checkpoint boundaries via SimState.async_host).

        Clock semantics (EXPERIMENTS.md §Asynchronous execution): an
        async 'round' is one buffer fill; sim_time is the ABSOLUTE f32
        event clock at the filling update's arrival (not a per-round f64
        delta sum — the event clock IS the schedule, so the record clock
        deliberately shares its f32 arithmetic). T_cm/T_cp are the
        FILLING update's own f64 uplink and compute times."""
        clients = np.asarray(ys["client"][:n_ev])
        twin_clients = np.array([e.client for e in evs], np.int32)
        if not np.array_equal(clients, twin_clients):
            j = int(np.argmin(clients == twin_clients))
            raise RuntimeError(
                "async schedule twin diverged from the compiled event "
                f"queue at event {j}: twin predicted client "
                f"{int(twin_clients[j])}, the scan popped "
                f"{int(clients[j])}. The f32 replay contract "
                "(events.twin_step) is broken — records would be "
                "misattributed, refusing to continue.")
        update_bits = self._update_bits()
        records = []
        k = 0
        for j, e in enumerate(evs):
            # Wire accounting: every arrival's dispatch paid its uplink.
            # Fault path: every retransmission attempt hit the air,
            # dropped or not (the sync chunk's attempts-sum rule).
            # Plain path: one upload per non-dropped arrival.
            if self._faults is not None:
                bits_acc += float(e.attempts_done) * update_bits
            elif not e.dropped:
                bits_acc += update_bits
            if e.aggregated:
                k += 1
                records.append(RoundRecord(
                    round=r0 + k,
                    sim_time=float(e.t_event),
                    T_cm=float(e.t_cm_done),
                    T_cp=float(self._t_cp_clients[e.client]),
                    train_loss=float(ys["loss_agg"][j]),
                    n_participants=int(self._async.buffer_size),
                    uplink_bits=bits_acc))
                bits_acc = 0.0
        return records, bits_acc

    def _async_state(self, state, params_C, opt_C, key, async_c, twin,
                     rnd, n_events, bits_acc, iters, stream) -> SimState:
        """Rebuild a SimState after async chunks: the device carry plus
        the twin's f64 dispatch bookkeeping and the event cursor."""
        return self._rebuild_state(
            state, params_C, opt_C, key, rnd, float(twin.now), iters,
            stream, async_c=async_c, event=int(state.event) + int(n_events),
            async_host={"t_cm_disp": twin.t_cm_disp.copy(),
                        "attempts_disp": twin.attempts_disp.copy(),
                        "bits_acc": float(bits_acc)})

    def _run_async(self, state, max_rounds, target_acc, eval_every,
                   max_sim_time):
        """Event-driven driver: one compiled event-scan dispatch + one
        device_get per chunk, chunk boundaries at aggregation (round)
        boundaries so eval cadence matches the sync drivers'. A 'round'
        is a buffer fill; max_rounds counts fills."""
        iters, stream = self._materialize(state)
        twin = self._async_twin(state)
        params_C, opt_C, key = state.params_C, state.opt_C, state.key
        async_c = state.async_c
        bits_acc = float(state.async_host.get("bits_acc", 0.0))
        history: List[RoundRecord] = []
        r0 = state.round
        n_events = 0
        done, stop, idle_chunks = 0, False, 0
        while done < max_rounds and not stop:
            n_t = min(eval_every - done % eval_every, max_rounds - done)
            xs, evs, n_ev = self._async_chunk_inputs(
                iters, stream, twin, stop_aggs=n_t,
                max_sim_time=max_sim_time)
            params_C, opt_C, key, async_c, ys = self._chunk_fn(
                params_C, opt_C, key, async_c, self._sizes_f32,
                self._data_dev, xs)
            # The chunk's only device->host sync, same as the sync scan.
            ys = jax.device_get(ys)
            records, bits_acc = self._async_records(
                ys, evs, n_ev, r0 + done, bits_acc)
            n_events += n_ev
            history.extend(records)
            done += len(records)
            # Aggregation-progress watchdog: a scenario that drops every
            # update (or a buffer larger than the surviving arrival rate
            # can ever fill) would otherwise burn event chunks forever.
            idle_chunks = 0 if records else idle_chunks + 1
            if idle_chunks >= 1000:
                raise RuntimeError(
                    f"async run made no aggregation progress over "
                    f"{idle_chunks * self._async_E} consecutive events "
                    f"(buffer_size={self._async.buffer_size}) — the "
                    "scenario drops too many updates to ever fill the "
                    "buffer. Shrink buffer_size or fix the scenario.")
            if max_sim_time and float(twin.now) >= max_sim_time:
                stop = True
            at_boundary = done > 0 and (done % eval_every == 0
                                        or done == max_rounds)
            if self.eval_fn and records and (at_boundary or stop):
                rec = history[-1]
                ev = self.eval_fn(async_c["params_g"])
                rec.test_acc = float(ev.get("acc", np.nan))
                rec.test_loss = float(ev.get("loss", np.nan))
                if (target_acc and rec.test_acc is not None
                        and rec.test_acc >= target_acc):
                    stop = True
        new_state = self._async_state(
            state, params_C, opt_C, key, async_c, twin, r0 + done,
            n_events, bits_acc, iters, stream)
        return new_state, SimResult(
            history=history, params=async_c["params_g"],
            label=self.label, fed=self.fed)

    def run_events(self, state: SimState, events: int):
        """Run EXACTLY `events` arrival events (async backend only):
        (state', [RoundRecord]). Unlike run(), this may stop mid-buffer —
        pending updates, the partial buffer and the event cursor all live
        in the returned SimState, and a save/load/resume from it is
        bit-identical to the uninterrupted run (the mid-buffer
        checkpoint contract, tests/test_async_events.py)."""
        if self.backend != "async":
            raise ValueError(
                f"run_events requires backend='async', not {self.backend!r}")
        if not isinstance(events, (int, np.integer)) or events < 1:
            raise ValueError(f"events must be an int >= 1, got {events!r}")
        iters, stream = self._materialize(state)
        twin = self._async_twin(state)
        params_C, opt_C, key = state.params_C, state.opt_C, state.key
        async_c = state.async_c
        bits_acc = float(state.async_host.get("bits_acc", 0.0))
        history: List[RoundRecord] = []
        done_ev = 0
        while done_ev < events:
            xs, evs, n_ev = self._async_chunk_inputs(
                iters, stream, twin, stop_events=events - done_ev)
            params_C, opt_C, key, async_c, ys = self._chunk_fn(
                params_C, opt_C, key, async_c, self._sizes_f32,
                self._data_dev, xs)
            ys = jax.device_get(ys)
            records, bits_acc = self._async_records(
                ys, evs, n_ev, state.round + len(history), bits_acc)
            history.extend(records)
            done_ev += n_ev
        new_state = self._async_state(
            state, params_C, opt_C, key, async_c, twin,
            state.round + len(history), done_ev, bits_acc, iters, stream)
        return new_state, history

    def _run_scan(self, state, max_rounds, target_acc, eval_every,
                  max_sim_time):
        """Chunked driver: one compiled scan call + one device_get per
        eval_every rounds. Chunk boundaries coincide exactly with the
        per-round driver's eval boundaries (k % eval_every == 0 or the
        final round). On a max_sim_time stop the history is truncated at
        the first exceeding round, matching the per-round backends; the
        device state is end-of-chunk (documented deviation — the chunk is
        already in flight)."""
        iters, stream = self._materialize(state)
        guard_on = (self._faults is not None
                    and self._faults.divergence_guard)
        # Last-good snapshot for DivergenceError recovery: taken BEFORE
        # the chunk consumes (donates) the state, refreshed per chunk.
        snap = jax.device_get(state) if guard_on else None
        checked = 0
        # Per-round (C,) finite masks aligned with `history` — the
        # DivergenceError diagnostic payload (fault-path scan output).
        finites: List[Any] = []
        params_C, opt_C, key = state.params_C, state.opt_C, state.key
        history: List[RoundRecord] = []
        sim_time = state.sim_time
        r0 = state.round
        weights, t_cp_arg = self._chunk_args()
        R = min(eval_every, max_rounds)
        done, stop = 0, False
        while done < max_rounds and not stop:
            n = min(R, max_rounds - done)
            if max_sim_time:
                # Pre-chunk host-stream positions: if the budget stop
                # truncates mid-chunk, the streams are rewound to the
                # truncation round so the returned state's snapshots
                # agree with its round cursor (see below).
                pre_data = self._snapshot_iters(iters)
                pre_stream = stream.state() if stream is not None else None
            xs, host = self._chunk_inputs(iters, stream, R, n)
            params_C, opt_C, key, ys = self._chunk_call(
                params_C, opt_C, key, weights, t_cp_arg, xs)
            # The chunk's only device->host sync: one stacked fetch of all
            # per-round scan outputs.
            ys = jax.device_get(ys)
            records = self._chunk_records(ys, host, n, r0 + done, sim_time)
            if max_sim_time:
                for j, rec in enumerate(records):
                    if rec.sim_time >= max_sim_time:
                        if j + 1 < n:
                            # The host streams consumed the whole chunk
                            # but the run stops after j+1 of its rounds:
                            # restore the pre-chunk positions and replay
                            # exactly j+1 rounds, so a resume from the
                            # returned state draws round j+2's data and
                            # realization (not round n+1's). The device
                            # params remain end-of-chunk — the documented
                            # deviation; the stream-driven accounting
                            # (clocks, participation) stays exact.
                            self._rewind_chunk(iters, stream, pre_data,
                                               pre_stream, j + 1)
                        records = records[:j + 1]
                        stop = True
                        break
            history.extend(records)
            done = history[-1].round - r0
            sim_time = history[-1].sim_time
            if guard_on:
                if "finite" in ys:
                    finites.extend(ys["finite"][:len(records)])
                checked = self._raise_if_diverged(
                    history, checked, snap,
                    finites=finites if finites else None)
                snap = jax.device_get(self._rebuild_state(
                    state, params_C, opt_C, key, r0 + done, sim_time,
                    iters, stream))
            rec = history[-1]
            k = rec.round - r0
            at_boundary = k % eval_every == 0 or k == max_rounds
            if self.eval_fn and at_boundary:
                ev = self.eval_fn(self._params_from(params_C))
                rec.test_acc = float(ev.get("acc", np.nan))
                rec.test_loss = float(ev.get("loss", np.nan))
                if (target_acc and rec.test_acc is not None
                        and rec.test_acc >= target_acc):
                    stop = True
        new_state = self._rebuild_state(
            state, params_C, opt_C, key, r0 + len(history), sim_time,
            iters, stream)
        return new_state, SimResult(
            history=history, params=self._params_from(params_C),
            label=self.label, fed=self.fed)

    def _params_from(self, params_C):
        if self.backend == "loop":
            return params_C
        return jax.tree.map(lambda x: x[0], params_C)

    # -- training -----------------------------------------------------------
    @staticmethod
    def _sync_history(history: List[RoundRecord]) -> None:
        """Host-sync boundary: materialize any still-on-device train losses
        (and, on the fault path, participant counts)."""
        for rec in history:
            if not isinstance(rec.train_loss, float):
                rec.train_loss = float(rec.train_loss)
            if rec.n_participants is not None and not isinstance(
                    rec.n_participants, int):
                rec.n_participants = int(rec.n_participants)

    def run(
        self,
        state: SimState,
        max_rounds: int = 200,
        target_acc: Optional[float] = None,
        eval_every: int = 1,
        max_sim_time: Optional[float] = None,
        recovery: Optional[RecoveryPolicy] = None,
    ):
        """Run up to `max_rounds` MORE rounds from `state`:
        (state', SimResult). Round numbering and the Eq. 8 clock continue
        from the state's cursors, so a run resumed from a checkpointed
        state produces exactly the history an uninterrupted run would.
        The input state's device buffers are donated (consumed) — rebind
        to the returned state; branch points need a host snapshot first
        (`jax.device_get(state)` / `save_state`).

        `recovery=RecoveryPolicy(...)` arms the auto-recovering driver:
        a DivergenceError (divergence-guarded fault runs) is caught, the
        run rewinds to the error's last-good SimState snapshot, the
        learning rate is deterministically backed off (and the guard
        norm optionally tightened), and the run resumes — up to
        max_restarts attempts, each logged in SimResult.restarts."""
        _validate_run_args(max_rounds, eval_every)
        if self.backend == "async":
            if recovery is not None:
                raise ValueError(
                    "recovery=RecoveryPolicy requires the divergence-"
                    "guarded sync backends — backend='async' has no "
                    "in-graph guard to raise from. Use backend='scan'.")
            return self._run_async(state, max_rounds, target_acc,
                                   eval_every, max_sim_time)
        if recovery is not None:
            return self._run_recovering(state, recovery, max_rounds,
                                        target_acc, eval_every, max_sim_time)
        if self.backend == "scan":
            return self._run_scan(state, max_rounds, target_acc, eval_every,
                                  max_sim_time)
        iters, stream = self._materialize(state)
        guard_on = (self._faults is not None
                    and self._faults.divergence_guard)
        snap = jax.device_get(state) if guard_on else None
        checked = 0
        finites: List[Any] = []
        params_C, opt_C, key = state.params_C, state.opt_C, state.key
        history: List[RoundRecord] = []
        sim_time = state.sim_time
        r0 = state.round
        T_cm, T_cp = self.round_times()
        V = self.fed.local_rounds
        update_bits = self._update_bits()
        for k in range(1, max_rounds + 1):
            real = None
            t_cm_clients = None
            n_attempts = None
            cohort = None
            if self.scenario is not None:
                # Realize the round (host-side numpy: mask + channel), take
                # the Eq. 8 clock as the straggler max over participating
                # clients, and feed the same realization to the round step.
                if self._sampled:
                    cohort = stream.draw_cohort()
                real = stream.next_round()
                if self._faults is not None:
                    real, t_cm_clients, n_attempts = self._fault_round(real)
                else:
                    t_cm_clients = delay.per_client_uplink_time(
                        update_bits, self.wireless, self.pop.p, real.h)
                if cohort is not None:
                    if self._spare:
                        # K+spare candidates -> the K feasible-fastest,
                        # ranked on the M-wide effective uplink times.
                        cohort = self._select_cohorts(
                            np.asarray(cohort)[None],
                            np.asarray(t_cm_clients, np.float64)[None])[0]
                    # Fault semantics above resolved M-wide; everything
                    # from here on (clock, bits, attempts, the step) is
                    # conditioned on the cohort's columns.
                    real = self._gather_real(real, cohort)
                    t_cm_clients = np.asarray(t_cm_clients)[cohort]
                    if n_attempts is not None:
                        n_attempts = int(real.attempts.sum())
                t_cp_vec = (self._t_cp_clients if cohort is None
                            else self._t_cp_clients[cohort])
                T_cm, T_cp = delay.masked_round_times(
                    t_cp_vec, t_cm_clients, real.clock_mask)
            if self.backend == "loop":
                params_C, opt_C, key, metrics = self._round_loop(
                    params_C, opt_C, key, iters, real)
            else:
                params_C, opt_C, key, metrics = self._round_batched(
                    params_C, opt_C, key, iters, real, t_cm_clients, cohort)
            sim_time += delay.round_time(T_cm, T_cp, V,
                                         deadline=self._deadline)
            rej = metrics.get("rejected")
            if rej is not None:
                # Device scalar on the batched backend — the host sync is
                # the per-round parity reference's price; the scan
                # backend reads it from the chunk's stacked outputs.
                rej = bool(rej)
                if rej and self._quorum_policy == "reject":
                    sim_time += self._faults.redispatch_cost
            n_part = metrics.get("n_participants")
            if n_attempts is not None:
                bits = float(n_attempts * update_bits)
            else:
                bits = float(
                    (self.fed.n_devices if n_part is None else n_part)
                    * update_bits)
            rec = RoundRecord(
                round=r0 + k, sim_time=sim_time, T_cm=T_cm, T_cp=T_cp,
                train_loss=metrics["train_loss"],
                n_participants=n_part,
                uplink_bits=bits, rejected=rej)
            history.append(rec)
            if guard_on:
                finites.append(metrics.get("finite"))
            at_boundary = k % eval_every == 0 or k == max_rounds
            if self.eval_fn and at_boundary:
                ev = self.eval_fn(self._params_from(params_C))
                rec.test_acc = float(ev.get("acc", np.nan))
                rec.test_loss = float(ev.get("loss", np.nan))
            if at_boundary:
                self._sync_history(history)
                if guard_on:
                    checked = self._raise_if_diverged(
                        history, checked, snap, finites=finites)
                    snap = jax.device_get(self._rebuild_state(
                        state, params_C, opt_C, key, r0 + k, sim_time,
                        iters, stream))
            if target_acc and rec.test_acc is not None and rec.test_acc >= target_acc:
                break
            if max_sim_time and sim_time >= max_sim_time:
                break
        self._sync_history(history)
        if guard_on:
            self._raise_if_diverged(history, checked, snap, finites=finites)
        new_state = self._rebuild_state(
            state, params_C, opt_C, key, r0 + len(history), sim_time,
            iters, stream)
        return new_state, SimResult(
            history=history, params=self._params_from(params_C),
            label=self.label, fed=self.fed)

    # -- crash-safe auto-recovery -------------------------------------------
    def _recovery_variant(self, lr_scale: float, fm) -> "Simulator":
        """A rebuilt Simulator for a restart attempt: identical to this
        one except the optimizer's updates are scaled by `lr_scale`
        (exact lr backoff for SGD-family optimizers) and the FaultModel
        is replaced by `fm` (guard-tightened when the policy asks).
        Rebuilding recompiles the round graphs — acceptable on the rare
        recovery path, and the only way the scale/guard become compiled
        constants (determinism over cleverness)."""
        kw = dict(self._ctor)
        kw["opt"] = _scaled_optimizer(kw["opt"], lr_scale)
        if fm is not None:
            if kw.get("faults") is not None and kw["faults"].active:
                kw["faults"] = fm
            elif kw.get("scenario") is not None:
                sc = scenarios.get(kw["scenario"])
                if sc.faults is not None and sc.faults.active:
                    kw["scenario"] = sc.replace(faults=fm)
        return Simulator(**kw)

    def _run_recovering(self, state, recovery, max_rounds, target_acc,
                        eval_every, max_sim_time):
        """The auto-recovering driver behind run(recovery=...): run,
        catch DivergenceError, rewind to the carried last-good SimState,
        deterministically back off the learning rate (and optionally
        tighten the guard norm), re-run — bounded by
        recovery.max_restarts, with every restart logged in the returned
        SimResult.restarts audit trail. The error's .state is a HOST
        snapshot (never donated away), so resuming from it is safe."""
        recovery.validate()
        sim = self
        fm = self._faults
        lr_scale = 1.0
        restarts: List[dict] = []
        prefix: List[RoundRecord] = []
        r_start = int(state.round)
        attempt = 0
        while True:
            rounds_left = max_rounds - (int(state.round) - r_start)
            try:
                state, res = sim.run(
                    state, max_rounds=rounds_left, target_acc=target_acc,
                    eval_every=eval_every, max_sim_time=max_sim_time)
            except DivergenceError as e:
                attempt += 1
                if e.state is None or attempt > recovery.max_restarts:
                    raise
                good = int(e.state.round)
                # Keep only the records the snapshot actually covers —
                # the rounds past it (same chunk as the failure) re-run.
                prefix.extend(r for r in e.history if r.round <= good)
                lr_scale *= recovery.lr_backoff
                if (recovery.tighten_guard is not None and fm is not None
                        and fm.max_update_norm is not None
                        and np.isfinite(fm.max_update_norm)):
                    fm = dataclasses.replace(
                        fm,
                        max_update_norm=(fm.max_update_norm
                                         * recovery.tighten_guard))
                restarts.append({
                    "attempt": attempt,
                    "round": int(e.round),
                    "resume_round": good,
                    "lr_scale": lr_scale,
                    "max_update_norm": (
                        None if fm is None else fm.max_update_norm),
                    "error": str(e)})
                sim = self._recovery_variant(lr_scale, fm)
                state = e.state
                continue
            res.history = prefix + res.history
            res.restarts = restarts
            return state, res

    # -- fleet execution (vmapped multi-seed / multi-state) ------------------
    def run_fleet(
        self,
        seeds: Optional[Iterable[int]] = None,
        states: Optional[Sequence[SimState]] = None,
        max_rounds: int = 200,
        eval_every: int = 1,
        target_acc: Optional[float] = None,
        max_sim_time: Optional[float] = None,
    ) -> FleetResult:
        """Run S member states in lockstep with ONE vmapped dispatch per
        chunk (scan backend only): the compiled chunk fn is mapped over a
        leading fleet axis (mesh_rounds.build_fleet_chunk), so S seeds
        cost one compiled call per eval_every rounds instead of S.

        Pass `seeds` (each becomes `init(seed)`) or pre-built `states`
        (e.g. restored checkpoints — they must share a round cursor so the
        lockstep chunking lines up). Per-member results are bit-identical
        to sequential `run()` calls at the same seeds: host-side draws
        (data indices, masks, channel drift) are per-member and vmap only
        batches the already-pure device graph.

        Early stopping (target_acc / max_sim_time) is per-member: a
        member that reaches the target (or exhausts the simulated-time
        budget) is marked done and rides along FROZEN — its subsequent
        chunks feed an all-False `valid` mask, the in-graph done-mask
        that turns every state write (params/opt/PRNG advance) into a
        no-op, while its host streams stop being consumed. The frozen
        member's history and final state match a solo early-stopped
        `run()` bit for bit (tests/test_study.py). Eval at chunk
        boundaries goes through `eval_batch_fn` (one vmapped dispatch for
        the whole stacked member axis) when the Simulator has one,
        falling back to a per-member host loop otherwise."""
        if self.backend != "scan":
            raise ValueError(
                f"run_fleet requires backend='scan', not {self.backend!r}")
        if target_acc and self.eval_fn is None and self.eval_batch_fn is None:
            raise ValueError(
                "run_fleet(target_acc=...) needs an eval_fn/eval_batch_fn "
                "(build the spec with with_eval=True)")
        if not callable(self._data_src):
            # A fixed iterator list is ONE set of live objects: every
            # member's _materialize would alias it, so members would
            # consume each other's batch stream and the per-seed
            # bit-identity contract would silently break.
            raise ValueError(
                "run_fleet needs a per-seed data factory: this Simulator "
                "was built with a fixed iterator list, which all fleet "
                "members would share (and advance past each other). "
                "Construct it with data=lambda seed: [...fresh iterators...] "
                "or via ExperimentSpec.build().")
        _validate_run_args(max_rounds, eval_every)
        if states is None:
            if seeds is None:
                raise ValueError("run_fleet needs seeds=... or states=...")
            seeds = [int(s) for s in seeds]
            if not seeds:
                raise ValueError("run_fleet needs at least one member")
            # Fresh-seed fast path: every member starts from the SAME
            # replicated params/opt (only the PRNG key differs), so the
            # stacked (S, C, ...) device state is one broadcast per leaf
            # instead of S eager init() + a per-leaf stack — at S=8 that
            # is hundreds of small dispatches saved per call.
            base_p, base_o = self._fleet_init_base()
            S = len(seeds)
            bcast = lambda x: jnp.broadcast_to(x[None], (S, *x.shape))  # noqa: E731
            params_S = jax.tree.map(bcast, base_p)
            opt_S = jax.tree.map(bcast, base_o)
            key_S = jnp.stack([jax.random.PRNGKey(s) for s in seeds])
            states = [SimState(params_C=None, opt_C=None, key=None, seed=s)
                      for s in seeds]
        else:
            states = list(states)
            if not states:
                raise ValueError("run_fleet needs at least one member")
            if len({st.round for st in states}) != 1:
                raise ValueError(
                    "fleet members must share a round cursor (got rounds "
                    f"{sorted({st.round for st in states})}) — lockstep "
                    "chunking has no per-member ragged tails")
            S = len(states)
            params_S, opt_S, key_S = jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[(st.params_C, st.opt_C, st.key) for st in states])
            # Fleet-memory ceiling fix: drop our references to the members'
            # unstacked device buffers now that the stacked (S, C, ...)
            # copies exist — otherwise S per-member state trios stay alive
            # alongside the (donated) stacked fleet state for the whole
            # run, doubling peak device memory. The caller's own state
            # objects are unaffected; the returned states carry fresh
            # slices of the final stacked buffers.
            states = [dataclasses.replace(
                st, params_C=None, opt_C=None, key=None) for st in states]
        mats = [self._materialize(st) for st in states]
        weights, t_cp_arg = self._chunk_args()
        fleet_fn = self._get_fleet_fn()
        histories: List[List[RoundRecord]] = [[] for _ in range(S)]
        times = [st.sim_time for st in states]
        r0 = states[0].round
        R = min(eval_every, max_rounds)
        done = 0
        finished = [False] * S
        last_xs: List[Any] = [None] * S
        can_eval = self.eval_fn is not None or self.eval_batch_fn is not None
        env_S = t_cp_S = None
        if self._envelope:
            # Loop-invariant: the envelope fleet maps t_cp and env per
            # member (the Study's arms differ in b); a same-spec fleet
            # broadcasts its shared values onto the member axis once.
            bcast = lambda x: jnp.broadcast_to(x[None], (S, *x.shape))  # noqa: E731
            env_S = jax.tree.map(bcast, self._trivial_env())
            t_cp_S = None if t_cp_arg is None else bcast(t_cp_arg)
        # LOCKSTEP NOTE: the per-chunk member bookkeeping below mirrors
        # study._run_group's (multi-arm) driver — both are bit-parity
        # tested against solo runs; change them together.
        while done < max_rounds and not all(finished):
            n = min(R, max_rounds - done)
            per: List[Any] = []
            pre: List[Any] = []
            for s in range(S):
                if finished[s]:
                    # Done-mask: an all-zero xs (valid=False rows) makes
                    # the member's whole chunk an in-graph no-op — params,
                    # opt state and PRNG key ride along untouched — and
                    # its host streams are not consumed.
                    per.append((jax.tree.map(np.zeros_like, last_xs[s]),
                                None))
                    pre.append(None)
                    continue
                if max_sim_time:
                    pre.append((self._snapshot_iters(mats[s][0]),
                                mats[s][1].state()
                                if mats[s][1] is not None else None))
                else:
                    pre.append(None)
                per.append(self._chunk_inputs(mats[s][0], mats[s][1], R, n))
                last_xs[s] = per[s][0]
            # One stacked (S, R, ...) upload per chunk for the whole fleet.
            xs = jax.tree.map(lambda *ls: np.stack(ls), *[p[0] for p in per])
            if self._envelope:
                params_S, opt_S, key_S, ys = fleet_fn(
                    params_S, opt_S, key_S, weights, t_cp_S,
                    self._data_dev, xs, env_S)
            else:
                params_S, opt_S, key_S, ys = fleet_fn(
                    params_S, opt_S, key_S, weights, t_cp_arg,
                    self._data_dev, xs)
            ys = jax.device_get(ys)  # leaves (S, R): ONE fetch per chunk
            for s in range(S):
                if finished[s]:
                    continue
                recs = self._chunk_records(
                    {k2: v[s] for k2, v in ys.items()}, per[s][1], n,
                    r0 + done, times[s])
                if max_sim_time:
                    for j, rec in enumerate(recs):
                        if rec.sim_time >= max_sim_time:
                            if j + 1 < n:
                                # Same semantics as the solo driver: the
                                # history truncates at the first exceeding
                                # round and the member's host streams
                                # rewind to it (device state stays
                                # end-of-chunk, the documented deviation).
                                self._rewind_chunk(
                                    mats[s][0], mats[s][1], pre[s][0],
                                    pre[s][1], j + 1)
                            recs = recs[:j + 1]
                            finished[s] = True
                            break
                histories[s].extend(recs)
                times[s] = histories[s][-1].sim_time
            done += n
            if can_eval and (done % eval_every == 0 or done == max_rounds):
                evs = self._eval_members(params_S, S)
                for s in range(S):
                    rec = histories[s][-1]
                    # Only members whose history reaches this boundary get
                    # the eval record — a member truncated mid-chunk by
                    # max_sim_time did not (its solo run would not eval
                    # there either).
                    if rec.round != r0 + done:
                        continue
                    rec.test_acc = float(evs[s].get("acc", np.nan))
                    rec.test_loss = float(evs[s].get("loss", np.nan))
                    if (target_acc and rec.test_acc is not None
                            and rec.test_acc >= target_acc):
                        finished[s] = True
        # One jitted call slices every member's (params, opt, key, global
        # model) out of the stacked buffers — per-member eager indexing
        # would cost S x leaves separate dispatches.
        members = _unstack_members(
            (params_S, opt_S, key_S,
             jax.tree.map(lambda x: x[:, 0], params_S)), S)
        out_states, results = [], []
        for s in range(S):
            p_s, o_s, k_s, global_s = members[s]
            st = self._rebuild_state(
                states[s], p_s, o_s, k_s, r0 + len(histories[s]), times[s],
                mats[s][0], mats[s][1])
            out_states.append(st)
            results.append(SimResult(
                history=histories[s], params=global_s,
                label=f"{self.label}[seed={st.seed}]", fed=self.fed))
        return FleetResult(states=out_states, results=results)

    def _eval_members(self, params_S, S: int) -> List[Dict]:
        """Chunk-boundary eval for a stacked fleet: ONE vmapped dispatch
        over the member axis via eval_batch_fn when available (each dict
        value comes back (S,)), else the host-loop fallback over unstacked
        globals."""
        globals_S = jax.tree.map(lambda x: x[:, 0], params_S)
        if self.eval_batch_fn is not None:
            ev = self.eval_batch_fn(globals_S)
            return [{k: v[s] for k, v in ev.items()} for s in range(S)]
        members = _unstack_members(globals_S, S)
        return [self.eval_fn(members[s]) for s in range(S)]


# ---------------------------------------------------------------------------
# Deprecated stateful facade
# ---------------------------------------------------------------------------

_FLSIM_WARNED = False


class FLSimulation:
    """Deprecated: the old mutable simulator interface, now a thin shim
    holding a (Simulator, SimState) pair. Prefer building a `Simulator`
    directly (or declaratively via
    `repro.federated.experiment.ExperimentSpec.build()`) and threading
    `SimState` through `run()` — that is what unlocks `run_fleet`,
    checkpoint/resume, and multi-seed sweeps. Emits one
    `DeprecationWarning` per process."""

    def __init__(
        self,
        loss_fn: Callable,
        init_params: Any,
        client_iterators: List,
        data_sizes: np.ndarray,
        fed: FedConfig,
        opt: Optimizer,
        pop: delay.DevicePopulation,
        wireless: Optional[WirelessConfig] = None,
        eval_fn: Optional[Callable] = None,
        label: str = "defl",
        backend: str = "scan",
        impl: str = "xla",
        scenario: Optional[Any] = None,
    ):
        global _FLSIM_WARNED
        if not _FLSIM_WARNED:
            warnings.warn(
                "FLSimulation is deprecated: build a "
                "repro.federated.simulation.Simulator (or an "
                "repro.federated.experiment.ExperimentSpec) and thread "
                "SimState through run()/run_fleet() instead.",
                DeprecationWarning, stacklevel=2)
            _FLSIM_WARNED = True
        self.sim = Simulator(
            loss_fn, init_params, client_iterators, data_sizes, fed, opt,
            pop, wireless=wireless, eval_fn=eval_fn, label=label,
            backend=backend, impl=impl, scenario=scenario)
        self.state = self.sim.init(fed.seed)

    def __getattr__(self, name):
        # Delegate config views (fed, pop, wireless, trace_count,
        # _update_bits, round_times, _data_dev, ...) to the core. Note
        # __getattr__ only fires for names not found on the shim itself.
        if name in ("sim", "state"):
            raise AttributeError(name)
        return getattr(self.sim, name)

    @property
    def eval_fn(self):
        return self.sim.eval_fn

    @eval_fn.setter
    def eval_fn(self, fn):
        self.sim.eval_fn = fn

    @property
    def params(self):
        return self.sim.params(self.state)

    def block_until_ready(self) -> None:
        self.sim.block_until_ready(self.state)

    def run_round(self, real=None, t_cm_clients=None) -> Dict:
        self.state, metrics = self.sim.run_round(self.state, real,
                                                 t_cm_clients)
        return metrics

    def run(
        self,
        max_rounds: int = 200,
        target_acc: Optional[float] = None,
        eval_every: int = 1,
        max_sim_time: Optional[float] = None,
    ) -> SimResult:
        self.state, res = self.sim.run(
            self.state, max_rounds=max_rounds, target_acc=target_acc,
            eval_every=eval_every, max_sim_time=max_sim_time)
        return res
