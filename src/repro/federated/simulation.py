"""Host-level FL simulator: Algorithm 1 with the paper's delay accounting.

Runs real training (JAX) while advancing a *simulated* wall clock from the
paper's delay models (Eqs. 5, 7, 8) — exactly how the paper reports
"overall time" for DEFL vs FedAvg vs Rand (Fig. 2). Heterogeneous device
populations, non-IID partitions and update compression are supported.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig, WirelessConfig
from repro.core import delay
from repro.federated import compression
from repro.federated.client import client_round, make_local_update, stack_batches
from repro.federated.server import aggregate_updates
from repro.optim.api import Optimizer
from repro.utils.tree import tree_bytes


@dataclass
class RoundRecord:
    round: int
    sim_time: float  # cumulative simulated seconds (Eq. 8 accumulated)
    T_cm: float
    T_cp: float
    train_loss: float
    test_acc: Optional[float] = None
    test_loss: Optional[float] = None


@dataclass
class SimResult:
    history: List[RoundRecord]
    params: Any
    label: str
    fed: FedConfig

    @property
    def total_time(self) -> float:
        return self.history[-1].sim_time if self.history else 0.0

    @property
    def rounds(self) -> int:
        return len(self.history)

    def time_to_accuracy(self, acc: float) -> Optional[float]:
        for r in self.history:
            if r.test_acc is not None and r.test_acc >= acc:
                return r.sim_time
        return None


class FLSimulation:
    """One FL system: M clients with data iterators + a delay model."""

    def __init__(
        self,
        loss_fn: Callable,  # (params, batch) -> (loss, metrics)
        init_params: Any,
        client_iterators: List,  # per-client .next_batch() sources
        data_sizes: np.ndarray,  # D_m
        fed: FedConfig,
        opt: Optimizer,
        pop: delay.DevicePopulation,
        wireless: Optional[WirelessConfig] = None,
        eval_fn: Optional[Callable] = None,  # (params) -> {'acc','loss'}
        label: str = "defl",
    ):
        assert len(client_iterators) == fed.n_devices == pop.n
        self.loss_fn = loss_fn
        self.params = init_params
        self.iterators = client_iterators
        self.data_sizes = data_sizes
        self.fed = fed
        self.opt = opt
        self.pop = pop
        self.wireless = wireless or WirelessConfig()
        self.eval_fn = eval_fn
        self.label = label
        self.local_update = make_local_update(loss_fn, opt)
        self.opt_states = [opt.init(init_params) for _ in range(fed.n_devices)]
        self._key = jax.random.PRNGKey(fed.seed)

    # -- delay accounting ---------------------------------------------------
    def _update_bits(self) -> float:
        if self.fed.update_bytes is not None:
            return self.fed.update_bytes * 8.0
        bits = tree_bytes(self.params) * 8.0
        return bits / 4.0 if self.fed.compress_updates else bits

    def round_times(self) -> tuple:
        T_cm = delay.round_comm_time(
            self._update_bits(), self.wireless, self.pop.p, self.pop.h)
        T_cp = delay.round_compute_time(
            self.fed.batch_size, self.pop.G, self.pop.f)
        return T_cm, T_cp

    # -- training -----------------------------------------------------------
    def run_round(self) -> Dict:
        V = self.fed.local_rounds
        deltas, losses = [], []
        for m, it in enumerate(self.iterators):
            batches = stack_batches([
                jax.tree.map(jnp.asarray, it.next_batch()) for _ in range(V)])
            delta, self.opt_states[m], loss_v = client_round(
                self.local_update, self.params, self.opt_states[m], batches)
            if self.fed.compress_updates:
                self._key, sub = jax.random.split(self._key)
                delta = compression.decompress_update(
                    compression.compress_update(delta, sub))
            deltas.append(delta)
            losses.append(float(jnp.mean(loss_v)))
        self.params = aggregate_updates(self.params, deltas, self.data_sizes)
        return {"train_loss": float(np.mean(losses))}

    def run(
        self,
        max_rounds: int = 200,
        target_acc: Optional[float] = None,
        eval_every: int = 1,
        max_sim_time: Optional[float] = None,
    ) -> SimResult:
        history: List[RoundRecord] = []
        sim_time = 0.0
        T_cm, T_cp = self.round_times()
        V = self.fed.local_rounds
        for r in range(1, max_rounds + 1):
            metrics = self.run_round()
            sim_time += delay.round_time(T_cm, T_cp, V)
            rec = RoundRecord(
                round=r, sim_time=sim_time, T_cm=T_cm, T_cp=T_cp,
                train_loss=metrics["train_loss"])
            if self.eval_fn and (r % eval_every == 0 or r == max_rounds):
                ev = self.eval_fn(self.params)
                rec.test_acc = float(ev.get("acc", np.nan))
                rec.test_loss = float(ev.get("loss", np.nan))
            history.append(rec)
            if target_acc and rec.test_acc is not None and rec.test_acc >= target_acc:
                break
            if max_sim_time and sim_time >= max_sim_time:
                break
        return SimResult(history=history, params=self.params,
                         label=self.label, fed=self.fed)
