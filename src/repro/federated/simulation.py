"""Host-level FL simulator: Algorithm 1 with the paper's delay accounting.

Runs real training (JAX) while advancing a *simulated* wall clock from the
paper's delay models (Eqs. 5, 7, 8) — exactly how the paper reports
"overall time" for DEFL vs FedAvg vs Rand (Fig. 2). Heterogeneous device
populations, non-IID partitions and update compression are supported.

Two execution backends share the same math:

  backend='batched' (default): all M clients live on a stacked leading C
      axis and one jit-compiled round step (mesh_rounds.build_round_step)
      runs V vmapped local steps + weighted FedAvg + optional in-graph
      int8 stochastic quantization per round. The stacked params/opt-state
      /PRNG-key buffers are donated, so round N+1 reuses round N's memory.
      Host syncs happen only at `eval_every` boundaries — train losses stay
      on device in between.
  backend='loop': the original per-client Python loop (one jitted
      local_update dispatch per client, host-side compress/decompress
      roundtrip, per-client host sync). Kept as the reference
      implementation; the two backends agree to fp32 tolerance under a
      fixed seed (bit-for-bit on the quantizer noise — see
      compression.sequential_client_keys).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig, WirelessConfig
from repro.core import delay
from repro.federated import compression, mesh_rounds
from repro.federated.client import (
    client_round,
    make_local_update,
    stack_batches,
    stack_client_batches,
)
from repro.federated.server import aggregate_updates
from repro.optim.api import Optimizer
from repro.utils.tree import tree_bytes


@dataclass
class RoundRecord:
    round: int
    sim_time: float  # cumulative simulated seconds (Eq. 8 accumulated)
    T_cm: float
    T_cp: float
    train_loss: float  # may hold a device scalar until the next host sync
    test_acc: Optional[float] = None
    test_loss: Optional[float] = None


@dataclass
class SimResult:
    history: List[RoundRecord]
    params: Any
    label: str
    fed: FedConfig

    @property
    def total_time(self) -> float:
        return self.history[-1].sim_time if self.history else 0.0

    @property
    def rounds(self) -> int:
        return len(self.history)

    def time_to_accuracy(self, acc: float) -> Optional[float]:
        for r in self.history:
            if r.test_acc is not None and r.test_acc >= acc:
                return r.sim_time
        return None


class FLSimulation:
    """One FL system: M clients with data iterators + a delay model."""

    def __init__(
        self,
        loss_fn: Callable,  # (params, batch) -> (loss, metrics)
        init_params: Any,
        client_iterators: List,  # per-client .next_batch() sources
        data_sizes: np.ndarray,  # D_m
        fed: FedConfig,
        opt: Optimizer,
        pop: delay.DevicePopulation,
        wireless: Optional[WirelessConfig] = None,
        eval_fn: Optional[Callable] = None,  # (params) -> {'acc','loss'}
        label: str = "defl",
        backend: str = "batched",
        impl: str = "xla",  # quantize kernel: 'xla' | 'pallas'
    ):
        assert len(client_iterators) == fed.n_devices == pop.n
        assert backend in ("batched", "loop"), backend
        self.loss_fn = loss_fn
        self.iterators = client_iterators
        self.data_sizes = data_sizes
        self.fed = fed
        self.opt = opt
        self.pop = pop
        self.wireless = wireless or WirelessConfig()
        self.eval_fn = eval_fn
        self.label = label
        self.backend = backend
        self.impl = impl
        self._key = jax.random.PRNGKey(fed.seed)
        if backend == "loop":
            self._params = init_params
            self.local_update = make_local_update(loss_fn, opt)
            self.opt_states = [opt.init(init_params) for _ in range(fed.n_devices)]
        else:
            M = fed.n_devices
            self._params_C = mesh_rounds.replicate_clients(
                jax.tree.map(jnp.asarray, init_params), M)
            self._opt_C = jax.vmap(lambda _: opt.init(init_params))(jnp.arange(M))
            w = jnp.asarray(np.asarray(data_sizes), jnp.float32)
            self._weights = w / jnp.sum(w)
            self._round_fn = self._build_batched_round()

    # -- state views --------------------------------------------------------
    @property
    def params(self) -> Any:
        """The global model (post-aggregation every client row is equal, so
        row 0 of the stacked state is the global model)."""
        if self.backend == "batched":
            return jax.tree.map(lambda x: x[0], self._params_C)
        return self._params

    def block_until_ready(self) -> None:
        """Drain the async dispatch queue (benchmarking / checkpoint use)."""
        state = self._params_C if self.backend == "batched" else self._params
        jax.block_until_ready(state)

    # -- delay accounting ---------------------------------------------------
    def _update_bits(self) -> float:
        if self.fed.update_bytes is not None:
            return self.fed.update_bytes * 8.0
        if self.fed.compress_updates:
            # Exact wire accounting for the int8 quantizer: 8-bit payload
            # plus one fp32 scale per 1024-chunk (compression.compressed_bits),
            # not the old bits/4 approximation.
            return float(compression.compressed_bits(self.params))
        return float(tree_bytes(self.params) * 8.0)

    def round_times(self) -> tuple:
        T_cm = delay.round_comm_time(
            self._update_bits(), self.wireless, self.pop.p, self.pop.h)
        T_cp = delay.round_compute_time(
            self.fed.batch_size, self.pop.G, self.pop.f)
        return T_cm, T_cp

    # -- batched backend ----------------------------------------------------
    def _build_batched_round(self):
        fed = self.fed
        M, V = fed.n_devices, fed.local_rounds
        compress = fed.compress_updates
        agg = "int8_stochastic" if compress else "allreduce"
        step = mesh_rounds.build_round_step(
            self.loss_fn, self.opt, V, aggregation=agg, impl=self.impl)
        weights = self._weights

        def round_fn(params_C, opt_C, key, batches):
            keys_C = None
            if compress:
                key, keys_C = compression.sequential_client_keys(key, M)
            new_p, new_s, metrics = step(
                params_C, opt_C, batches, weights, keys=keys_C)
            # Unweighted client mean, matching the loop backend's metric.
            return new_p, new_s, key, jnp.mean(metrics["per_client_loss"])

        # Donating the stacked params/opt/key buffers lets XLA write round
        # N+1's state into round N's memory: peak HBM stays ~1x the stacked
        # state regardless of round count.
        return jax.jit(round_fn, donate_argnums=(0, 1, 2))

    def _run_round_batched(self) -> Dict:
        batches = stack_client_batches(self.iterators, self.fed.local_rounds)
        self._params_C, self._opt_C, self._key, loss = self._round_fn(
            self._params_C, self._opt_C, self._key, batches)
        return {"train_loss": loss}  # device scalar; synced lazily

    # -- loop backend (reference) -------------------------------------------
    def _run_round_loop(self) -> Dict:
        V = self.fed.local_rounds
        deltas, losses = [], []
        keys_C = None
        if self.fed.compress_updates:
            self._key, keys_C = compression.sequential_client_keys(
                self._key, len(self.iterators))
        for m, it in enumerate(self.iterators):
            batches = stack_batches([
                jax.tree.map(jnp.asarray, it.next_batch()) for _ in range(V)])
            delta, self.opt_states[m], loss_v = client_round(
                self.local_update, self._params, self.opt_states[m], batches)
            if self.fed.compress_updates:
                delta = compression.decompress_update(
                    compression.compress_update(delta, keys_C[m], impl=self.impl),
                    impl=self.impl)
            deltas.append(delta)
            losses.append(float(jnp.mean(loss_v)))
        self._params = aggregate_updates(self._params, deltas, self.data_sizes)
        return {"train_loss": float(np.mean(losses))}

    # -- training -----------------------------------------------------------
    def run_round(self) -> Dict:
        if self.backend == "batched":
            return self._run_round_batched()
        return self._run_round_loop()

    @staticmethod
    def _sync_history(history: List[RoundRecord]) -> None:
        """Host-sync boundary: materialize any still-on-device train losses."""
        for rec in history:
            if not isinstance(rec.train_loss, float):
                rec.train_loss = float(rec.train_loss)

    def run(
        self,
        max_rounds: int = 200,
        target_acc: Optional[float] = None,
        eval_every: int = 1,
        max_sim_time: Optional[float] = None,
    ) -> SimResult:
        history: List[RoundRecord] = []
        sim_time = 0.0
        T_cm, T_cp = self.round_times()
        V = self.fed.local_rounds
        for r in range(1, max_rounds + 1):
            metrics = self.run_round()
            sim_time += delay.round_time(T_cm, T_cp, V)
            rec = RoundRecord(
                round=r, sim_time=sim_time, T_cm=T_cm, T_cp=T_cp,
                train_loss=metrics["train_loss"])
            history.append(rec)
            at_boundary = r % eval_every == 0 or r == max_rounds
            if self.eval_fn and at_boundary:
                ev = self.eval_fn(self.params)
                rec.test_acc = float(ev.get("acc", np.nan))
                rec.test_loss = float(ev.get("loss", np.nan))
            if at_boundary:
                self._sync_history(history)
            if target_acc and rec.test_acc is not None and rec.test_acc >= target_acc:
                break
            if max_sim_time and sim_time >= max_sim_time:
                break
        self._sync_history(history)
        return SimResult(history=history, params=self.params,
                         label=self.label, fed=self.fed)
