"""Host-level FL simulator: Algorithm 1 with the paper's delay accounting.

Runs real training (JAX) while advancing a *simulated* wall clock from the
paper's delay models (Eqs. 5, 7, 8) — exactly how the paper reports
"overall time" for DEFL vs FedAvg vs Rand (Fig. 2). Heterogeneous device
populations, non-IID partitions and update compression are supported, and
a named `scenario` (federated/scenarios.py) layers per-round partial
participation (Bernoulli dropout / link failure) and channel drift on top:
the round clock becomes the straggler max over *participating* clients,
dropped clients are masked out of the FedAvg, and on the batched backend
all of it rides the one compiled round step as traced inputs (one trace
per run, no extra host syncs — see FLSimulation.trace_count).

Three execution backends share the same math:

  backend='scan' (default): an entire `eval_every`-round chunk is one
      compiled `jax.lax.scan` over the batched round step
      (mesh_rounds.build_round_chunk). The host touches the device once
      per chunk — scenario masks/clocks ride in as stacked (R, C) arrays
      (ScenarioStream.draw_chunk), batches either pre-stack to
      (R, C, V, ...) or, when the client iterators share one dataset
      (data.BatchIterator), stay device-resident and are gathered
      in-graph from (R, C, V, B) index arrays — and per-round metrics
      come back as stacked scan outputs in a single device_get. Carry
      buffers (params/opt/PRNG key) are donated across chunks; ragged
      final chunks are padded under a `valid` flag so a whole run costs
      exactly one trace (FLSimulation.trace_count).
  backend='batched': all M clients live on a stacked leading C axis and
      one jit-compiled round step (mesh_rounds.build_round_step) runs V
      vmapped local steps + weighted FedAvg + optional in-graph int8
      stochastic quantization per round — one dispatch and one host
      batch-feed per round. Host syncs happen only at `eval_every`
      boundaries — train losses stay on device in between. Kept as the
      per-round parity reference for 'scan' (bit-identical under a fixed
      seed — tests/test_scan_backend.py).
  backend='loop': the original per-client Python loop (one jitted
      local_update dispatch per client, host-side compress/decompress
      roundtrip, per-client host sync). Kept as the reference
      implementation; backends agree to fp32 tolerance under a fixed
      seed (bit-for-bit on the quantizer noise — see
      compression.sequential_client_keys).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig, WirelessConfig
from repro.core import delay
from repro.federated import compression, mesh_rounds, scenarios
from repro.federated.client import (
    client_round,
    make_local_update,
    stack_batches,
    stack_chunk_batches,
    stack_chunk_indices,
    stack_client_batches,
)
from repro.federated.server import aggregate_updates
from repro.optim.api import Optimizer
from repro.utils.tree import tree_bytes


@dataclass
class RoundRecord:
    round: int
    sim_time: float  # cumulative simulated seconds (Eq. 8 accumulated)
    T_cm: float
    T_cp: float
    train_loss: float  # may hold a device scalar until the next host sync
    test_acc: Optional[float] = None
    test_loss: Optional[float] = None
    # Scenario rounds: how many client updates reached the aggregator
    # (None on the no-scenario path — implicitly all M).
    n_participants: Optional[int] = None
    # Total uplink bits the round actually carried (participants x bits
    # per update, exact compression.compressed_bits accounting).
    uplink_bits: Optional[float] = None


@dataclass
class SimResult:
    history: List[RoundRecord]
    params: Any
    label: str
    fed: FedConfig

    @property
    def total_time(self) -> float:
        return self.history[-1].sim_time if self.history else 0.0

    @property
    def rounds(self) -> int:
        return len(self.history)

    def time_to_accuracy(self, acc: float) -> Optional[float]:
        for r in self.history:
            if r.test_acc is not None and r.test_acc >= acc:
                return r.sim_time
        return None


class FLSimulation:
    """One FL system: M clients with data iterators + a delay model."""

    def __init__(
        self,
        loss_fn: Callable,  # (params, batch) -> (loss, metrics)
        init_params: Any,
        client_iterators: List,  # per-client .next_batch() sources
        data_sizes: np.ndarray,  # D_m
        fed: FedConfig,
        opt: Optimizer,
        pop: delay.DevicePopulation,
        wireless: Optional[WirelessConfig] = None,
        eval_fn: Optional[Callable] = None,  # (params) -> {'acc','loss'}
        label: str = "defl",
        backend: str = "scan",
        impl: str = "xla",  # quantize kernel: 'xla' | 'pallas'
        scenario: Optional[Any] = None,  # scenarios.Scenario | name | None
    ):
        assert len(client_iterators) == fed.n_devices == pop.n
        assert backend in ("scan", "batched", "loop"), backend
        self.loss_fn = loss_fn
        self.iterators = client_iterators
        self.data_sizes = data_sizes
        self.fed = fed
        self.opt = opt
        self.pop = pop
        self.wireless = wireless or WirelessConfig()
        self.eval_fn = eval_fn
        self.label = label
        self.backend = backend
        self.impl = impl
        self.scenario = scenarios.get(scenario) if scenario is not None else None
        # One realization stream per sim, seeded from the FedConfig: both
        # backends (and reruns at the same seed) see identical per-round
        # masks and channel draws.
        self._stream = (self.scenario.stream(pop, fed.seed)
                        if self.scenario is not None else None)
        # Static per-client compute times (Eq. 4); uplink times depend on
        # the realized per-round channel and are computed per round.
        self._t_cp_clients = delay.per_client_compute_time(
            fed.batch_size, pop.G, pop.f)
        # Shape-only view of the global model: _update_bits computes wire
        # sizes from this, so delay accounting never dispatches a device op
        # or blocks the async queue (see the _update_bits docstring).
        self._param_struct = jax.eval_shape(lambda p: p, init_params)
        self._bits_cache: Optional[float] = None
        self._key = jax.random.PRNGKey(fed.seed)
        if backend == "loop":
            self._params = init_params
            self.local_update = make_local_update(loss_fn, opt)
            self.opt_states = [opt.init(init_params) for _ in range(fed.n_devices)]
        else:
            M = fed.n_devices
            self._params_C = mesh_rounds.replicate_clients(
                jax.tree.map(jnp.asarray, init_params), M)
            self._opt_C = jax.vmap(lambda _: opt.init(init_params))(jnp.arange(M))
            w = jnp.asarray(np.asarray(data_sizes), jnp.float32)
            # Legacy path: host-normalized FedAvg weights. The scenario path
            # instead ships the raw sizes and renormalizes in-graph over the
            # round's participation mask (mesh_rounds._participation_weights).
            self._weights = w / jnp.sum(w)
            self._sizes_f32 = w
            self._round_fn = self._build_batched_round()
        if backend == "scan":
            # Device-resident data path: when every client iterator draws
            # from one shared dataset and speaks the index protocol
            # (data.BatchIterator), upload the backing arrays once and
            # gather batches in-graph — per chunk only (R, C, V, B) int32
            # indices cross the host->device boundary. Anything else falls
            # back to pre-stacked (R, C, V, ...) host batches per chunk.
            self._data_dev = self._batch_from = None
            its = client_iterators
            if (its
                    and all(hasattr(it, "next_indices")
                            and hasattr(it, "device_arrays") for it in its)
                    and getattr(its[0], "data", None) is not None
                    and len({id(getattr(it, "data", None))
                             for it in its}) == 1):
                self._data_dev = jax.tree.map(
                    jnp.asarray, its[0].device_arrays())
                self._batch_from = type(its[0]).batch_from
            self._t_cp_dev = jnp.asarray(self._t_cp_clients, jnp.float32)
            self._chunk_fn = self._build_scan_chunk()

    # -- state views --------------------------------------------------------
    @property
    def params(self) -> Any:
        """The global model (post-aggregation every client row is equal, so
        row 0 of the stacked state is the global model)."""
        if self.backend == "loop":
            return self._params
        return jax.tree.map(lambda x: x[0], self._params_C)

    def block_until_ready(self) -> None:
        """Drain the async dispatch queue (benchmarking / checkpoint use)."""
        state = self._params if self.backend == "loop" else self._params_C
        jax.block_until_ready(state)

    # -- delay accounting ---------------------------------------------------
    def _update_bits(self) -> float:
        # Memoized, and computed from the shape-only _param_struct captured
        # at init: wire accounting is a pure function of the (static) param
        # structure, so it must never slice device buffers or enqueue work —
        # on the scenario path it feeds every round's realized uplink times,
        # and any device touch here would sit between dispatches and defeat
        # the async round pipeline.
        if self._bits_cache is None:
            if self.fed.update_bytes is not None:
                self._bits_cache = self.fed.update_bytes * 8.0
            elif self.fed.compress_updates:
                # Exact wire accounting for the int8 quantizer: 8-bit payload
                # plus one fp32 scale per 1024-chunk
                # (compression.compressed_bits), not the bits/4 approximation.
                self._bits_cache = float(
                    compression.compressed_bits(self._param_struct))
            else:
                self._bits_cache = float(tree_bytes(self._param_struct) * 8.0)
        return self._bits_cache

    def round_times(self) -> tuple:
        T_cm = delay.round_comm_time(
            self._update_bits(), self.wireless, self.pop.p, self.pop.h)
        T_cp = delay.round_compute_time(
            self.fed.batch_size, self.pop.G, self.pop.f)
        return T_cm, T_cp

    # -- batched backend ----------------------------------------------------
    def _build_batched_round(self):
        fed = self.fed
        M, V = fed.n_devices, fed.local_rounds
        compress = fed.compress_updates
        agg = "int8_stochastic" if compress else "allreduce"
        step = mesh_rounds.build_round_step(
            self.loss_fn, self.opt, V, aggregation=agg, impl=self.impl)

        if self.scenario is None:
            weights = self._weights

            def round_fn(params_C, opt_C, key, batches):
                keys_C = None
                if compress:
                    key, keys_C = compression.sequential_client_keys(key, M)
                new_p, new_s, metrics = step(
                    params_C, opt_C, batches, weights, keys=keys_C)
                # Unweighted client mean, matching the loop backend's metric.
                return new_p, new_s, key, jnp.mean(metrics["per_client_loss"])
        else:
            sizes = self._sizes_f32

            def round_fn(params_C, opt_C, key, batches,
                         mask, clock_mask, t_cp, t_cm):
                keys_C = None
                if compress:
                    key, keys_C = compression.sequential_client_keys(key, M)
                new_p, new_s, metrics = step(
                    params_C, opt_C, batches, sizes, keys=keys_C,
                    mask=mask, clock_mask=clock_mask, t_cp=t_cp, t_cm=t_cm)
                # Mean over *participating* clients (the loop backend never
                # runs dropped clients); NaN on a zero-participation round.
                n = jnp.sum(mask)
                loss = (jnp.sum(metrics["per_client_loss"] * mask)
                        / jnp.where(n > 0, n, 1.0))
                loss = jnp.where(n > 0, loss, jnp.nan)
                return new_p, new_s, key, loss

        # Donating the stacked params/opt/key buffers lets XLA write round
        # N+1's state into round N's memory: peak HBM stays ~1x the stacked
        # state regardless of round count. The per-round scenario inputs
        # (mask/clock_mask/t_cp/t_cm) are plain traced arrays of fixed
        # shape: new values every round, ONE trace for the whole run.
        return jax.jit(round_fn, donate_argnums=(0, 1, 2))

    # -- scan backend -------------------------------------------------------
    def _build_scan_chunk(self):
        fed = self.fed
        agg = "int8_stochastic" if fed.compress_updates else "allreduce"
        chunk = mesh_rounds.build_round_chunk(
            self.loss_fn, self.opt, fed.local_rounds, fed.n_devices,
            aggregation=agg, impl=self.impl,
            scenario=self.scenario is not None,
            batch_from=self._batch_from,
            update_bits=self._update_bits())
        # Same donation contract as the batched round step, amortized over
        # a whole chunk: XLA reuses the carry buffers across chunks. All
        # per-chunk inputs are traced arrays of fixed (R, ...) shape and a
        # ragged final chunk pads to R under the valid flag, so the whole
        # run compiles exactly once (trace_count).
        return jax.jit(chunk, donate_argnums=(0, 1, 2))

    @staticmethod
    def _pad_rounds(a: np.ndarray, R: int) -> np.ndarray:
        """Pad a round-stacked array to R rounds with zeros (ragged final
        chunk; the padded tail is masked out in-graph via `valid`)."""
        n = a.shape[0]
        if n == R:
            return a
        return np.concatenate([a, np.zeros((R - n, *a.shape[1:]), a.dtype)])

    def _chunk_inputs(self, R: int, n: int, update_bits: float):
        """Host-side prep for one chunk: draw n rounds of data (+ scenario
        realizations), pad to R, and return (xs pytree for the scan, host
        dict with the f64 clock accounting for the history records)."""
        V = self.fed.local_rounds
        pad = self._pad_rounds
        if self._data_dev is not None:
            idx = stack_chunk_indices(self.iterators, n, V)
            xs = {"idx": jnp.asarray(pad(idx, R))}
        else:
            batches = stack_chunk_batches(self.iterators, n, V)
            xs = {"batches": jax.tree.map(
                lambda a: jnp.asarray(pad(np.asarray(a), R)), batches)}
        valid = np.zeros(R, bool)
        valid[:n] = True
        xs["valid"] = jnp.asarray(valid)
        host = {}
        if self.scenario is not None:
            chunk = self._stream.draw_chunk(n)
            t_cm = delay.per_client_uplink_time(
                update_bits, self.wireless, self.pop.p, chunk.h)
            # f64 host twin of the in-graph clock: bit-identical to the
            # per-round backends' accounting (delay.chunk_round_times).
            T_cm, T_cp = delay.chunk_round_times(
                self._t_cp_clients, t_cm, chunk.clock_mask)
            host = {"T_cm": T_cm, "T_cp": T_cp,
                    "n_participants": chunk.n_participants}
            xs["mask"] = jnp.asarray(
                pad(chunk.mask.astype(np.float32), R))
            xs["clock_mask"] = jnp.asarray(
                pad(chunk.clock_mask.astype(np.float32), R))
            xs["t_cm"] = jnp.asarray(pad(t_cm.astype(np.float32), R))
        return xs, host

    def _run_scan(self, max_rounds, target_acc, eval_every, max_sim_time,
                  ) -> SimResult:
        """Chunked driver: one compiled scan call + one device_get per
        eval_every rounds. Chunk boundaries coincide exactly with the
        per-round driver's eval boundaries (r % eval_every == 0 or the
        final round). On a max_sim_time stop the history is truncated at
        the first exceeding round, matching the per-round backends; the
        device state is end-of-chunk (documented deviation — the chunk is
        already in flight)."""
        history: List[RoundRecord] = []
        sim_time = 0.0
        V = self.fed.local_rounds
        update_bits = self._update_bits()
        M = self.fed.n_devices
        if self.scenario is None:
            T_cm_const, T_cp_const = self.round_times()
            weights = self._weights
            t_cp_arg = None
        else:
            weights = self._sizes_f32
            t_cp_arg = self._t_cp_dev
        R = max(1, min(eval_every, max_rounds))
        r, stop = 0, False
        while r < max_rounds and not stop:
            n = min(R, max_rounds - r)
            xs, host = self._chunk_inputs(R, n, update_bits)
            self._params_C, self._opt_C, self._key, ys = self._chunk_fn(
                self._params_C, self._opt_C, self._key,
                weights, t_cp_arg, self._data_dev, xs)
            # The chunk's only device->host sync: one stacked fetch of all
            # per-round scan outputs.
            ys = jax.device_get(ys)
            for i in range(n):
                r += 1
                if self.scenario is None:
                    T_cm, T_cp, n_part = T_cm_const, T_cp_const, None
                    bits = float(M * update_bits)
                else:
                    T_cm = float(host["T_cm"][i])
                    T_cp = float(host["T_cp"][i])
                    n_part = int(host["n_participants"][i])
                    bits = float(n_part * update_bits)
                sim_time += delay.round_time(T_cm, T_cp, V)
                history.append(RoundRecord(
                    round=r, sim_time=sim_time, T_cm=T_cm, T_cp=T_cp,
                    train_loss=float(ys["loss"][i]),
                    n_participants=n_part, uplink_bits=bits))
                if max_sim_time and sim_time >= max_sim_time:
                    stop = True
                    break
            rec = history[-1]
            at_boundary = rec.round % eval_every == 0 or rec.round == max_rounds
            if self.eval_fn and at_boundary:
                ev = self.eval_fn(self.params)
                rec.test_acc = float(ev.get("acc", np.nan))
                rec.test_loss = float(ev.get("loss", np.nan))
                if (target_acc and rec.test_acc is not None
                        and rec.test_acc >= target_acc):
                    stop = True
        return SimResult(history=history, params=self.params,
                         label=self.label, fed=self.fed)

    @property
    def trace_count(self) -> int:
        """Number of compiled traces so far (batched: the round step; scan:
        the chunk step plus any direct run_round calls). Scenario masking
        and chunking must stay at 1 across a run — per-round masks, delay
        inputs and the ragged-final-chunk padding are traced values, never
        new shapes/constants."""
        if self.backend == "loop":
            return 0
        count = int(self._round_fn._cache_size())
        if self.backend == "scan":
            count += int(self._chunk_fn._cache_size())
        return count

    def _run_round_batched(self, real=None, t_cm_clients=None) -> Dict:
        batches = stack_client_batches(self.iterators, self.fed.local_rounds)
        if self.scenario is None:
            self._params_C, self._opt_C, self._key, loss = self._round_fn(
                self._params_C, self._opt_C, self._key, batches)
            return {"train_loss": loss}  # device scalar; synced lazily
        if t_cm_clients is None:  # direct run_round() callers; run() shares its vector
            t_cm_clients = delay.per_client_uplink_time(
                self._update_bits(), self.wireless, self.pop.p, real.h)
        mask = jnp.asarray(real.mask, jnp.float32)
        clock_mask = jnp.asarray(real.clock_mask, jnp.float32)
        t_cp = jnp.asarray(self._t_cp_clients, jnp.float32)
        t_cm = jnp.asarray(t_cm_clients, jnp.float32)
        self._params_C, self._opt_C, self._key, loss = self._round_fn(
            self._params_C, self._opt_C, self._key, batches,
            mask, clock_mask, t_cp, t_cm)
        return {"train_loss": loss, "n_participants": real.n_participants}

    # -- loop backend (reference) -------------------------------------------
    def _run_round_loop(self, real=None) -> Dict:
        V = self.fed.local_rounds
        M = len(self.iterators)
        deltas, sizes, losses = [], [], []
        keys_C = None
        if self.fed.compress_updates:
            # Keys are drawn for all M clients regardless of participation
            # (the batched backend must: vmap is shape-static), so the two
            # backends' PRNG streams stay aligned under any mask.
            self._key, keys_C = compression.sequential_client_keys(
                self._key, M)
        mask = np.ones(M, bool) if real is None else np.asarray(real.mask, bool)
        for m, it in enumerate(self.iterators):
            # Data is drawn for every client every round — participating or
            # not — matching stack_client_batches on the batched backend so
            # both consume identical iterator streams.
            raw = [it.next_batch() for _ in range(V)]
            if not mask[m]:
                continue
            batches = stack_batches(
                [jax.tree.map(jnp.asarray, b) for b in raw])
            delta, self.opt_states[m], loss_v = client_round(
                self.local_update, self._params, self.opt_states[m], batches)
            if self.fed.compress_updates:
                delta = compression.decompress_update(
                    compression.compress_update(delta, keys_C[m], impl=self.impl),
                    impl=self.impl)
            deltas.append(delta)
            sizes.append(self.data_sizes[m])
            losses.append(float(jnp.mean(loss_v)))
        if deltas:  # zero-participation round: params unchanged
            self._params = aggregate_updates(self._params, deltas, sizes)
        out = {"train_loss": float(np.mean(losses)) if losses else float("nan")}
        if real is not None:
            out["n_participants"] = int(mask.sum())
        return out

    # -- training -----------------------------------------------------------
    def run_round(self, real=None, t_cm_clients=None) -> Dict:
        """One communication round. `real` is the scenario's per-round
        realization (drawn from the stream when omitted on a scenario sim;
        ignored semantics-free on a plain sim). `t_cm_clients` lets run()
        share its per-client uplink-time vector instead of recomputing.
        The scan backend shares the batched backend's per-round step here
        (same stacked state layout); chunking only applies inside run()."""
        if self.scenario is not None and real is None:
            real = self._stream.next_round()
        if self.backend == "loop":
            return self._run_round_loop(real)
        return self._run_round_batched(real, t_cm_clients)

    @staticmethod
    def _sync_history(history: List[RoundRecord]) -> None:
        """Host-sync boundary: materialize any still-on-device train losses."""
        for rec in history:
            if not isinstance(rec.train_loss, float):
                rec.train_loss = float(rec.train_loss)

    def run(
        self,
        max_rounds: int = 200,
        target_acc: Optional[float] = None,
        eval_every: int = 1,
        max_sim_time: Optional[float] = None,
    ) -> SimResult:
        if self.backend == "scan":
            return self._run_scan(max_rounds, target_acc, eval_every,
                                  max_sim_time)
        history: List[RoundRecord] = []
        sim_time = 0.0
        T_cm, T_cp = self.round_times()
        V = self.fed.local_rounds
        update_bits = self._update_bits()
        for r in range(1, max_rounds + 1):
            real = None
            t_cm_clients = None
            if self.scenario is not None:
                # Realize the round (host-side numpy: mask + channel), take
                # the Eq. 8 clock as the straggler max over participating
                # clients, and feed the same realization to the round step.
                real = self._stream.next_round()
                t_cm_clients = delay.per_client_uplink_time(
                    update_bits, self.wireless, self.pop.p, real.h)
                T_cm, T_cp = delay.masked_round_times(
                    self._t_cp_clients, t_cm_clients, real.clock_mask)
            metrics = self.run_round(real, t_cm_clients)
            sim_time += delay.round_time(T_cm, T_cp, V)
            n_part = metrics.get("n_participants")
            rec = RoundRecord(
                round=r, sim_time=sim_time, T_cm=T_cm, T_cp=T_cp,
                train_loss=metrics["train_loss"],
                n_participants=n_part,
                uplink_bits=float(
                    (self.fed.n_devices if n_part is None else n_part)
                    * update_bits))
            history.append(rec)
            at_boundary = r % eval_every == 0 or r == max_rounds
            if self.eval_fn and at_boundary:
                ev = self.eval_fn(self.params)
                rec.test_acc = float(ev.get("acc", np.nan))
                rec.test_loss = float(ev.get("loss", np.nan))
            if at_boundary:
                self._sync_history(history)
            if target_acc and rec.test_acc is not None and rec.test_acc >= target_acc:
                break
            if max_sim_time and sim_time >= max_sim_time:
                break
        self._sync_history(history)
        return SimResult(history=history, params=self.params,
                         label=self.label, fed=self.fed)
