"""Declarative multi-arm studies: whole method comparisons as grouped
vmapped dispatches.

The paper's headline results are *comparisons* — DEFL vs FedAvg vs Rand
(Fig. 2), sweeps over epsilon/b/theta (Fig. 1) — and each comparison arm
is one `ExperimentSpec`. A `Study` is the frozen value form of the whole
comparison:

    study = Study(
        arms=[("DEFL", defl_spec), ("FedAvg", fedavg_spec),
              ("Rand", rand_spec)],
        seeds=range(8), max_rounds=100, eval_every=1, target_acc=0.90)
    result = study.run()
    header, rows = result.table()
    json.dump(result.to_json(), f)

`run()` does NOT loop over arms. Arms are grouped by *shape signature* —
model shapes, client count M, dataset/partition/population draw, scenario,
lr, compression — everything that shapes the compiled graph or its shared
inputs EXCEPT the per-arm (b, V) plan. Each group executes as ONE vmapped
fleet over the (arm x seed) member axis:

  * Mixed (b, V) plans share one graph through the **(V, b) envelope**
    (mesh_rounds.build_round_chunk(envelope=True)): every member is
    padded to the group's (V_env, B_env) = (max V, max b) under traced
    validity masks. Padded local steps are in-graph no-ops (`where`
    state keeps), padded samples carry exact-zero loss/gradient
    contributions (models.cnn.cnn_loss_masked + the pad-stable `_ps_matmul`
    conv backward), and the native simulator runs the SAME envelope-form
    graph at the trivial all-ones masks — so each member's history and
    trained params are bit-identical to its own sequential
    `Simulator.run()` (tests/test_study.py).
  * `target_acc` / `max_sim_time` work per member through the device-side
    done-mask: a finished member's subsequent chunks feed an all-False
    `valid` mask and it rides along frozen, matching a solo early-stopped
    run bit for bit.
  * Eval at chunk boundaries is ONE vmapped dispatch over the stacked
    member axis (`Simulator.eval_batch_fn`), not a host loop.

`plans()` resolves each arm's analytic operating point (DEFL plan or the
fixed-(b, V) Eq. 12/8 evaluation) for the prediction-only figures
(fig1a/fig1d, ablation_straggler).

Compiled envelope graphs are cached per (envelope_key, V_env, B_env):
e.g. Fig. 2's five scenario studies share one compiled group graph when
their arms resolve to the same envelope.
"""
from __future__ import annotations

import dataclasses
import os
import pickle
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import defl
from repro.federated import mesh_rounds
from repro.federated.experiment import ExperimentSpec
from repro.federated.simulation import (
    SimResult,
    SimState,
    Simulator,
    _atomic_pickle,
    _unstack_members,
    _validate_run_args,
)

# Compiled envelope graphs shared across groups (and whole studies) with
# the same (graph signature, V_env, B_env) — e.g. every fig2 scenario
# study reuses one graph per dataset.
_GROUP_FNS: Dict[tuple, tuple] = {}


def _group_signature(spec: ExperimentSpec, fed) -> tuple:
    """Everything that shapes a group's compiled graph or its shared
    inputs — model, data/partition/population draw, scenario, lr,
    compression, impl — EXCEPT the per-arm (b, V) plan, which the
    envelope absorbs, and plan constants (epsilon/nu/c) that only exist
    to derive it. The effective FaultModel is part of the signature —
    guard knobs and the fault branch are compiled into the group's
    graph, and the fault inputs (attempt times, deadlines) are per-arm
    host values that must agree across a group's members."""
    return (spec.model, spec.dataset, spec.n_train, spec.n_test, spec.alpha,
            spec.seed, spec.scenario, spec.trace, spec.effective_faults(),
            spec.heterogeneity, spec.compute,
            spec.wireless, spec.backend, spec.impl, spec.with_eval,
            spec.population, spec.shard_clients,
            fed.n_devices, fed.lr, fed.compress_updates)


@dataclass
class _Member:
    """One (arm x seed) row of a group's fleet axis."""

    arm: int
    label: str
    sim: Simulator
    seed: int
    iters: Any = None
    stream: Any = None
    history: List = dataclasses.field(default_factory=list)
    sim_time: float = 0.0
    finished: bool = False
    last_xs: Any = None


def _member_env(sim: Simulator, V_env: int, B_env: int) -> dict:
    """The member's traced (V, b)-envelope masks (host numpy; stacked over
    the fleet axis before the single per-chunk upload)."""
    V, b = sim.fed.local_rounds, sim.fed.batch_size
    v_mask = np.zeros(V_env, np.float32)
    v_mask[:V] = 1.0
    s_mask = np.zeros(B_env, np.float32)
    s_mask[:b] = 1.0
    return {"v_mask": v_mask, "sample_mask": s_mask,
            "n_samples": np.float32(b), "v_count": np.float32(V),
            "update_bits": np.float32(sim._update_bits())}


def _group_fns(rep: Simulator, V_env: int, B_env: int):
    """(chunk, jitted fleet) for a group, cached on the representative's
    envelope_key + envelope dims (same-shaped groups across studies share
    one compilation)."""
    key = None
    if rep.envelope_key is not None:
        try:
            key = (rep.envelope_key, V_env, B_env)
            if key in _GROUP_FNS:
                return _GROUP_FNS[key]
        except TypeError:  # unhashable user key: build uncached
            key = None
    agg = ("int8_stochastic" if rep.fed.compress_updates
           else ("allreduce_shardmap" if rep._mesh is not None
                 else "allreduce"))
    n_lanes = rep._cohort if rep._sampled else rep.fed.n_devices
    chunk = mesh_rounds.build_round_chunk(
        rep.masked_loss_fn, rep.opt, V_env, n_lanes,
        aggregation=agg, impl=rep.impl, scenario=rep.scenario is not None,
        batch_from=rep._batch_from, envelope=True,
        guard=rep._guard, faults=rep._faults is not None,
        sampled=rep._sampled,
        quorum=None if rep._quorum is None else rep._quorum_policy,
        mesh=rep._mesh,
        param_specs_tree=rep._param_specs,
        client_axes=("clients",) if rep._mesh is not None else None)
    fns = (chunk, jax.jit(mesh_rounds.build_fleet_chunk(
               chunk, envelope=True, sampled=rep._sampled),
                          donate_argnums=(0, 1, 2)))
    if key is not None:
        _GROUP_FNS[key] = fns
    return fns


def _run_group(members: List[_Member], max_rounds: int, eval_every: int,
               target_acc: Optional[float], max_sim_time: Optional[float],
               envelope: Optional[Tuple[int, int]] = None,
               ) -> List[Tuple[SimState, SimResult]]:
    """Execute one shape group as a single vmapped fleet over its
    (arm x seed) members — the Study-side twin of `Simulator.run_fleet`
    with per-member (b, V) envelopes, per-member delay accounting and the
    same done-mask early-stop semantics. `envelope` forces the
    (V_env, B_env) dims (the bit probe pads a single member beyond its
    own shapes); by default they resolve to the group maxes.

    LOCKSTEP NOTE: the per-chunk member bookkeeping below (frozen-member
    zeroed xs, max_sim_time truncation + stream rewind, eval-boundary
    round gating, target_acc freeze) must mirror run_fleet's driver —
    both are tested for bit-parity against solo early-stopped runs
    (tests/test_study.py), so a semantics change in one that is not made
    in the other fails those tests; change them together."""
    rep = members[0].sim
    S = len(members)
    if envelope is not None:
        V_env, B_env = envelope
    else:
        V_env = max(m.sim.fed.local_rounds for m in members)
        B_env = max(m.sim.fed.batch_size for m in members)
    _, fleet_fn = _group_fns(rep, V_env, B_env)
    weights, _ = rep._chunk_args()
    scenario = rep.scenario is not None
    t_cp_S = None
    if scenario and not rep._sampled:
        # Sampled groups carry per-round (R, K) t_cp rows in xs instead
        # (lanes change owners every round); weights is None for the
        # same reason (_chunk_args).
        t_cp_S = jnp.asarray(
            np.stack([m.sim._t_cp_clients for m in members]), jnp.float32)
    env_S = jax.tree.map(
        lambda *ls: jnp.asarray(np.stack(ls)),
        *[_member_env(m.sim, V_env, B_env) for m in members])

    # Stacked fresh member states: every member starts from the SAME
    # replicated params/opt (the group signature pins model and draw
    # seed), so the (S, C, ...) state is one broadcast per leaf.
    base_p, base_o = rep._fleet_init_base()
    bcast = lambda x: jnp.broadcast_to(x[None], (S, *x.shape))  # noqa: E731
    params_S = jax.tree.map(bcast, base_p)
    opt_S = jax.tree.map(bcast, base_o)
    key_S = jnp.stack([jax.random.PRNGKey(m.seed) for m in members])
    shells = []
    for m in members:
        shell = SimState(params_C=None, opt_C=None, key=None, seed=m.seed)
        m.iters, m.stream = m.sim._materialize(shell)
        shells.append(shell)

    can_eval = (rep.eval_fn is not None or rep.eval_batch_fn is not None)
    R = min(eval_every, max_rounds)
    done = 0
    r0 = 0
    while done < max_rounds and not all(m.finished for m in members):
        n = min(R, max_rounds - done)
        per: List[Any] = []
        pre: List[Any] = []
        for m in members:
            if m.finished:
                # Device-side done-mask: all-zero xs (valid=False rows)
                # freeze the member in-graph; its host streams are not
                # consumed.
                per.append((jax.tree.map(np.zeros_like, m.last_xs), None))
                pre.append(None)
                continue
            if max_sim_time:
                pre.append((m.sim._snapshot_iters(m.iters),
                            m.stream.state() if m.stream is not None
                            else None))
            else:
                pre.append(None)
            per.append(m.sim._chunk_inputs(
                m.iters, m.stream, R, n, envelope=(V_env, B_env)))
            m.last_xs = per[-1][0]
        xs = jax.tree.map(lambda *ls: np.stack(ls), *[p[0] for p in per])
        params_S, opt_S, key_S, ys = fleet_fn(
            params_S, opt_S, key_S, weights, t_cp_S, rep._data_dev, xs,
            env_S)
        ys = jax.device_get(ys)  # leaves (S, R): ONE fetch per chunk
        for s, m in enumerate(members):
            if m.finished:
                continue
            recs = m.sim._chunk_records(
                {k: v[s] for k, v in ys.items()}, per[s][1], n, r0 + done,
                m.sim_time)
            if max_sim_time:
                for j, rec in enumerate(recs):
                    if rec.sim_time >= max_sim_time:
                        if j + 1 < n:
                            m.sim._rewind_chunk(m.iters, m.stream,
                                                pre[s][0], pre[s][1], j + 1)
                        recs = recs[:j + 1]
                        m.finished = True
                        break
            m.history.extend(recs)
            m.sim_time = m.history[-1].sim_time
        done += n
        if can_eval and (done % eval_every == 0 or done == max_rounds):
            evs = rep._eval_members(params_S, S)
            for s, m in enumerate(members):
                rec = m.history[-1]
                if rec.round != r0 + done:
                    continue  # truncated mid-chunk: solo would not eval
                rec.test_acc = float(evs[s].get("acc", np.nan))
                rec.test_loss = float(evs[s].get("loss", np.nan))
                if (target_acc and rec.test_acc is not None
                        and rec.test_acc >= target_acc):
                    m.finished = True

    unstacked = _unstack_members(
        (params_S, opt_S, key_S,
         jax.tree.map(lambda x: x[:, 0], params_S)), S)
    out = []
    for s, m in enumerate(members):
        p_s, o_s, k_s, global_s = unstacked[s]
        st = m.sim._rebuild_state(
            shells[s], p_s, o_s, k_s, len(m.history), m.sim_time,
            m.iters, m.stream)
        out.append((st, SimResult(
            history=m.history, params=global_s,
            label=f"{m.label}[seed={m.seed}]", fed=m.sim.fed)))
    return out


def _fmt(mean: float, std: float, nd: int, multi: bool) -> str:
    if not np.isfinite(mean):
        return ""
    if multi:
        return f"{mean:.{nd}f}+-{std:.{nd}f}"
    return str(round(mean, nd))


# -- study checkpointing ------------------------------------------------------
# One file per completed (arm, seed) member, written crash-safely
# (_atomic_pickle): a SIGKILL at any instant leaves only whole member
# files, and `Study.run(checkpoint_dir=..., resume=True)` skips them and
# runs the rest — the assembled StudyResult is bit-identical to an
# uninterrupted run because every member is independent (the fleet axis
# never mixes members; tests/test_chaos_resume.py proves it under a real
# mid-study kill).

_MEMBER_CKPT_VERSION = 1


def _member_ckpt_path(directory: str, arm: int, seed: int) -> str:
    return os.path.join(directory, f"arm{arm:03d}_seed{seed}.pkl")


def _save_member(path: str, label: str, seed: int,
                 state: SimState, result: SimResult) -> None:
    res = dataclasses.replace(result, params=jax.device_get(result.params))
    payload = {"__repro_study_member__": _MEMBER_CKPT_VERSION,
               "label": label, "seed": int(seed),
               "state": jax.device_get(state), "result": res}
    _atomic_pickle(path, payload)


def _load_member(path: str, label: str, seed: int,
                 ) -> Tuple[SimState, SimResult]:
    try:
        with open(path, "rb") as f:
            payload = pickle.load(f)
    except (pickle.UnpicklingError, EOFError, AttributeError) as e:
        raise ValueError(
            f"{path!r} is not a readable study checkpoint "
            f"(corrupt or truncated pickle): {e}") from e
    if not (isinstance(payload, dict)
            and "__repro_study_member__" in payload):
        raise ValueError(
            f"{path!r} does not hold a study member checkpoint")
    version = payload["__repro_study_member__"]
    if version != _MEMBER_CKPT_VERSION:
        raise ValueError(
            f"{path!r} holds member checkpoint schema v{version}, this "
            f"build reads v{_MEMBER_CKPT_VERSION}")
    if payload.get("label") != label or int(payload.get("seed", -1)) != seed:
        raise ValueError(
            f"checkpoint {path!r} holds arm {payload.get('label')!r} "
            f"seed {payload.get('seed')!r}, expected {label!r} seed {seed} "
            "— the study's arms/seeds changed since the checkpoint was "
            "written; point checkpoint_dir at a fresh directory")
    return payload["state"], payload["result"]


@dataclass
class StudyResult:
    """Per-arm frame of a study run: histories, final states,
    time-to-accuracy, confidence bands, paper-style table + JSON emit."""

    labels: Tuple[str, ...]
    seeds: Tuple[int, ...]
    results: Dict[str, List[SimResult]]  # label -> per-seed SimResults
    states: Dict[str, List[SimState]]
    groups: Tuple[Tuple[str, ...], ...]  # grouping report (labels/group)
    target_acc: Optional[float] = None
    max_sim_time: Optional[float] = None
    # label -> cohort size K for sampled-participation arms (None = dense).
    cohorts: Dict[str, Optional[int]] = dataclasses.field(
        default_factory=dict)
    # label -> "mode/K=buffer/staleness" for backend='async' arms (None =
    # synchronous): the aggregation regime column of table()/to_json().
    async_modes: Dict[str, Optional[str]] = dataclasses.field(
        default_factory=dict)

    def __getitem__(self, label: str) -> List[SimResult]:
        return self.results[label]

    def time_to_target(self, label: str) -> np.ndarray:
        """(S,) per-seed time to `target_acc` — NaN for a seed that never
        hit the target (previously its total time leaked in, silently
        deflating 'time-to-target' means for arms that never got there).
        With no target_acc every seed 'hits' at its total simulated time.
        `time_to_target_or_total` keeps the old semantics for headline
        comparisons that need a finite per-seed number."""
        if not self.target_acc:
            return np.asarray([r.total_time for r in self.results[label]])
        return np.asarray([
            t if (t := r.time_to_accuracy(self.target_acc)) is not None
            else np.nan
            for r in self.results[label]], np.float64)

    def time_to_target_or_total(self, label: str) -> np.ndarray:
        """(S,) per-seed time to target, falling back to the member's
        total simulated time for seeds that missed — the conservative
        finite bound the paper-style reduction/table columns compare on
        (a missed seed costs its whole run)."""
        tta = self.time_to_target(label)
        totals = np.asarray([r.total_time for r in self.results[label]])
        return np.where(np.isfinite(tta), tta, totals)

    def target_hit_rate(self, label: str) -> float:
        """Fraction of seeds that reached `target_acc` (1.0 when no
        target was set: every run 'completes')."""
        return float(np.isfinite(self.time_to_target(label)).mean())

    def final_accs(self, label: str) -> np.ndarray:
        return np.asarray([
            next((h.test_acc for h in reversed(r.history)
                  if h.test_acc is not None), np.nan)
            for r in self.results[label]])

    def summary(self, label: str) -> Dict[str, float]:
        times = np.asarray([r.total_time for r in self.results[label]])
        accs = self.final_accs(label)
        have_acc = bool(np.isfinite(accs).any())
        tta = self.time_to_target(label)
        have_tta = bool(np.isfinite(tta).any())
        rounds = np.asarray([r.rounds for r in self.results[label]])
        parts = [h.n_participants for r in self.results[label]
                 for h in r.history if h.n_participants is not None]
        return {
            "total_time_mean": float(times.mean()),
            "total_time_std": float(times.std()),
            "final_acc_mean": (float(np.nanmean(accs)) if have_acc
                               else float("nan")),
            "final_acc_std": (float(np.nanstd(accs)) if have_acc
                              else float("nan")),
            # Means over the seeds that HIT the target: one missed seed
            # used to poison these to NaN (or worse, count its total time
            # as a 'time to target'); the hit rate says how many made it.
            "time_to_target_mean": (float(np.nanmean(tta)) if have_tta
                                    else float("nan")),
            "time_to_target_std": (float(np.nanstd(tta)) if have_tta
                                   else float("nan")),
            "target_hit_rate": self.target_hit_rate(label),
            "rounds_mean": float(rounds.mean()),
            "mean_participants": (float(np.mean(parts)) if parts
                                  else float("nan")),
            # Resilience: quorum-rejected rounds (FaultModel.min_quorum)
            # and recovery restarts (RecoveryPolicy) summed over seeds —
            # both 0 for studies that run without those knobs.
            "rounds_rejected": int(sum(
                r.rounds_rejected for r in self.results[label])),
            "restarts": int(sum(
                len(r.restarts) for r in self.results[label])),
        }

    def reduction(self, label: str, baseline: str) -> float:
        """Paper-style '% overall-time reduction' of `label` vs `baseline`
        on mean time-to-target — like-for-like on both the solo and the
        fleet path (both early stop in-run). Missed seeds count their
        total run time (time_to_target_or_total), so the comparison stays
        finite and conservative when an arm misses the target."""
        a = float(self.time_to_target_or_total(label).mean())
        b = float(self.time_to_target_or_total(baseline).mean())
        return 100.0 * (1.0 - a / b)

    def table(self) -> Tuple[str, List[tuple]]:
        """Paper-style per-arm rows:
        label,b,V,K,agg,rounds,mean_participants,overall_time_s,acc,
        time_to_target,rounds_rejected,restarts — K is the sampled
        cohort size (blank for dense arms); agg is the aggregation
        regime ('sync', or 'mode/K=buffer/staleness' for backend='async'
        arms); time/acc as mean+-std bands
        when the study ran multiple seeds; rounds_rejected/restarts are
        seed totals of quorum-rejected rounds and recovery restarts
        (0 when those knobs are off)."""
        multi = len(self.seeds) > 1
        rows = []
        for label in self.labels:
            s = self.summary(label)
            fed = self.results[label][0].fed
            K = self.cohorts.get(label)
            mode = self.async_modes.get(label)
            tta = self.time_to_target_or_total(label)
            hit = [r.time_to_accuracy(self.target_acc) is not None
                   for r in self.results[label]] if self.target_acc else []
            rows.append((
                label, fed.batch_size, fed.local_rounds,
                K if K is not None else "",
                mode if mode is not None else "sync",
                round(s["rounds_mean"], 1),
                (round(s["mean_participants"], 1)
                 if np.isfinite(s["mean_participants"]) else ""),
                _fmt(s["total_time_mean"], s["total_time_std"], 2, multi),
                _fmt(s["final_acc_mean"], s["final_acc_std"], 4, multi),
                (_fmt(float(tta.mean()), float(tta.std()), 2, multi)
                 if (not self.target_acc or any(hit)) else ""),
                s["rounds_rejected"],
                s["restarts"],
            ))
        return ("label,b,V,K,agg,rounds,mean_participants,overall_time_s,"
                "acc,time_to_target_s,rounds_rejected,restarts", rows)

    def to_json(self) -> dict:
        """Machine-readable emit (benchmarks/run.py --json, the CI study
        artifact): study config, grouping report, per-arm summaries and
        full per-seed histories."""
        arms = {}
        for label in self.labels:
            per_seed = []
            for seed, r in zip(self.seeds, self.results[label]):
                per_seed.append({
                    "seed": int(seed),
                    "rounds": r.rounds,
                    "total_time": r.total_time,
                    "time_to_target": (r.time_to_accuracy(self.target_acc)
                                       if self.target_acc else None),
                    "rounds_rejected": r.rounds_rejected,
                    "restarts": r.restarts,
                    "history": {
                        "round": [h.round for h in r.history],
                        "sim_time": [h.sim_time for h in r.history],
                        "train_loss": [float(h.train_loss)
                                       for h in r.history],
                        "test_acc": [h.test_acc for h in r.history],
                        "n_participants": [h.n_participants
                                           for h in r.history],
                        "uplink_bits": [h.uplink_bits for h in r.history],
                        "rejected": [h.rejected for h in r.history],
                    },
                })
            fed = self.results[label][0].fed
            arms[label] = {
                "b": fed.batch_size, "V": fed.local_rounds, "lr": fed.lr,
                "K": self.cohorts.get(label),
                "async": self.async_modes.get(label),
                "compress_updates": fed.compress_updates,
                "summary": self.summary(label),
                "per_seed": per_seed,
            }
        return {"seeds": [int(s) for s in self.seeds],
                "target_acc": self.target_acc,
                "max_sim_time": self.max_sim_time,
                "groups": [list(g) for g in self.groups],
                "arms": arms}


@dataclass(frozen=True)
class Study:
    """A frozen multi-arm comparison: `(label, ExperimentSpec)` arms, run
    seeds, and the shared run/stop policy. `run()` executes the whole
    study as grouped vmapped fleets (see the module docstring);
    `plans()` resolves the arms' analytic operating points without
    training (the prediction-only figures).

    grouping='envelope' (default) fuses same-signature arms across their
    (b, V) plans; 'exact' additionally splits on (b, V) — no padding, at
    the cost of one dispatch stream per distinct shape. bit_check=True
    runs a one-round bit-probe per enveloped arm (native vs padded) and
    raises on any mismatch before spending the full budget — the padding
    is engineered to be exact and tested on the shipped configurations,
    but XLA owns fp32 fusion, so out-of-registry configs can opt into
    the self-check."""

    arms: Tuple[Tuple[str, ExperimentSpec], ...]
    seeds: Tuple[int, ...] = (0,)
    max_rounds: int = 200
    eval_every: int = 1
    target_acc: Optional[float] = None
    max_sim_time: Optional[float] = None
    grouping: str = "envelope"
    bit_check: bool = False

    def __post_init__(self):
        object.__setattr__(self, "arms",
                           tuple((str(k), v) for k, v in self.arms))
        object.__setattr__(self, "seeds",
                           tuple(int(s) for s in self.seeds))
        labels = [k for k, _ in self.arms]
        if not labels:
            raise ValueError("Study needs at least one arm")
        if len(set(labels)) != len(labels):
            raise ValueError(f"duplicate arm labels: {labels}")
        if not self.seeds:
            raise ValueError("Study needs at least one seed")
        if self.grouping not in ("envelope", "exact"):
            raise ValueError(f"unknown grouping {self.grouping!r}")
        for label, spec in self.arms:
            if not isinstance(spec, ExperimentSpec):
                raise TypeError(f"arm {label!r}: expected ExperimentSpec, "
                                f"got {type(spec).__name__}")
            if spec.backend not in ("scan", "async"):
                raise ValueError(
                    f"arm {label!r}: studies run on backend='scan' or "
                    f"'async' (got {spec.backend!r})")

    def replace(self, **kw) -> "Study":
        return dataclasses.replace(self, **kw)

    # -- analytic ------------------------------------------------------------
    def plans(self) -> Dict[str, defl.DEFLPlan]:
        """Per-arm analytic operating points (no training): the DEFL plan
        for plan=True arms, the fixed-(b, V) Eq. 12/8 evaluation
        otherwise. Arms whose solve reduces to a plain Alg. 1 problem
        (spec.plan_request() is not None) are solved together through ONE
        vectorized KKT dispatch (defl.make_plan_batch) — bit-identical to
        per-arm analytic_plan(); fixed-(b, V) baselines and deadline-
        fault arms keep their scalar paths."""
        reqs = [(label, spec.plan_request()) for label, spec in self.arms]
        batch = [(label, r) for label, r in reqs if r is not None]
        out: Dict[str, defl.DEFLPlan] = {}
        if batch:
            for (label, _), plan in zip(
                    batch, defl.make_plan_batch([r for _, r in batch])):
                out[label] = plan
        for label, spec in self.arms:
            if label not in out:
                out[label] = spec.analytic_plan()
        return out

    # -- execution -----------------------------------------------------------
    def build_sims(self) -> Dict[str, Simulator]:
        """Materialize every arm's Simulator once. `run()` builds its own
        when not given these; pass them in to amortize the per-arm build
        cost (dataset generation + upload, partition/population draw, the
        DEFL plan solve) across repeated runs of one study — what the
        bench_study timing loop does. Reuse is safe: Simulators are
        state-in/state-out and every run() materializes fresh per-seed
        host streams."""
        return {label: spec.build() for label, spec in self.arms}

    def run(self, sims: Optional[Dict[str, Simulator]] = None,
            checkpoint_dir: Optional[str] = None,
            resume: bool = True) -> StudyResult:
        """Execute the study. With `checkpoint_dir` set, every completed
        (arm, seed) member is autosaved to
        `{checkpoint_dir}/arm{a:03d}_seed{s}.pkl` via an atomic
        temp-file + fsync + rename write, and (with `resume=True`, the
        default) members whose file already exists are loaded instead of
        re-run — a killed study picks up where it left off and assembles
        a StudyResult bit-identical to an uninterrupted run. A checkpoint
        whose stored (label, seed) disagrees with the study raises
        ValueError rather than silently mixing studies."""
        _validate_run_args(self.max_rounds, self.eval_every)
        arm_of = {label: a for a, (label, _) in enumerate(self.arms)}
        done: Dict[Tuple[str, int], Tuple[SimState, SimResult]] = {}
        if checkpoint_dir is not None:
            checkpoint_dir = str(checkpoint_dir)
            os.makedirs(checkpoint_dir, exist_ok=True)
            if resume:
                for label, _ in self.arms:
                    for seed in self.seeds:
                        path = _member_ckpt_path(
                            checkpoint_dir, arm_of[label], seed)
                        if os.path.exists(path):
                            done[(label, seed)] = _load_member(
                                path, label, seed)

        def finish(label: str, seed: int, st, res) -> None:
            done[(label, seed)] = (st, res)
            if checkpoint_dir is not None:
                _save_member(
                    _member_ckpt_path(checkpoint_dir, arm_of[label], seed),
                    label, seed, st, res)

        built = sims if sims is not None else self.build_sims()
        sims = [(label, spec, built[label]) for label, spec in self.arms]
        if self.target_acc:
            missing = [label for label, _, sim in sims
                       if sim.eval_fn is None and sim.eval_batch_fn is None]
            if missing:
                raise ValueError(
                    f"target_acc needs with_eval=True on every arm; "
                    f"missing eval: {missing}")
        groups: Dict[Any, List[Tuple[str, ExperimentSpec, Simulator]]] = {}
        order: List[Any] = []
        for i, (label, spec, sim) in enumerate(sims):
            if sim.masked_loss_fn is None or sim.backend == "async":
                # No envelope form (hand-built Simulator) or async arm
                # (its own event clock cannot be vmapped against sync
                # round loops): runs solo, sequentially per seed.
                sig: Any = ("__solo__", i)
            else:
                sig = _group_signature(spec, sim.fed)
                if self.grouping == "exact":
                    sig = sig + (sim.fed.batch_size, sim.fed.local_rounds)
            if sig not in groups:
                groups[sig] = []
                order.append(sig)
            groups[sig].append((label, spec, sim))
        if self.bit_check:
            for sig in order:
                self._bit_probe(groups[sig])
        for sig in order:
            if len(sig) == 2 and sig[0] == "__solo__":
                # No envelope form (a hand-built Simulator passed through
                # run(sims=...)): the arm runs sequentially per seed —
                # correct, just not grouped.
                (label, _, sim), = groups[sig]
                for seed in self.seeds:
                    if (label, seed) in done:
                        continue
                    st, res = sim.run(
                        sim.init(seed), max_rounds=self.max_rounds,
                        eval_every=self.eval_every,
                        target_acc=self.target_acc,
                        max_sim_time=self.max_sim_time)
                    finish(label, seed, st, res)
                continue
            members = [
                _Member(arm=a, label=label, sim=sim, seed=seed)
                for a, (label, spec, sim) in enumerate(groups[sig])
                for seed in self.seeds
                if (label, seed) not in done
            ]
            if not members:
                continue  # every member restored from checkpoint
            for m, (st, res) in zip(members, _run_group(
                    members, self.max_rounds, self.eval_every,
                    self.target_acc, self.max_sim_time)):
                finish(m.label, m.seed, st, res)
        results: Dict[str, List[SimResult]] = {
            label: [done[(label, seed)][1] for seed in self.seeds]
            for label, _ in self.arms}
        states: Dict[str, List[SimState]] = {
            label: [done[(label, seed)][0] for seed in self.seeds]
            for label, _ in self.arms}
        return StudyResult(
            labels=tuple(l for l, _ in self.arms), seeds=self.seeds,
            results=results, states=states,
            groups=tuple(tuple(label for label, _, _ in groups[sig])
                         for sig in order),
            target_acc=self.target_acc, max_sim_time=self.max_sim_time,
            cohorts={label: (c.K if (c := spec.cohort_spec()) is not None
                             else None)
                     for label, spec in self.arms},
            async_modes={
                label: (f"{a.mode}/K={a.buffer_size}/{a.staleness}"
                        if (a := spec.async_spec) is not None else None)
                for label, spec in self.arms})

    def _bit_probe(self, group) -> None:
        """One-round native-vs-enveloped bit comparison per arm of a
        group whose envelope actually pads (a trivial envelope IS the
        native graph). Raises on the first mismatch — before the study
        spends its full round budget on a grouping that would not
        reproduce sequential runs."""
        if len(group) < 2:
            return
        V_env = max(sim.fed.local_rounds for _, _, sim in group)
        B_env = max(sim.fed.batch_size for _, _, sim in group)
        seed = self.seeds[0]
        for label, spec, sim in group:
            if (sim.fed.local_rounds, sim.fed.batch_size) == (V_env, B_env):
                continue
            state, native = sim.run_chunk(sim.init(seed), rounds=1)
            p_native = jax.device_get(sim.params(state))
            probe = spec.build()  # fresh sim: run_chunk consumed the state
            m = _Member(arm=0, label=label, sim=probe, seed=seed)
            (st, res), = _run_group([m], 1, 1, None, None,
                                    envelope=(V_env, B_env))
            a, b = native[0].train_loss, res.history[0].train_loss
            loss_ok = np.float32(a).tobytes() == np.float32(b).tobytes()
            params_ok = all(
                np.asarray(x).tobytes() == np.asarray(y).tobytes()
                for x, y in zip(jax.tree.leaves(p_native),
                                jax.tree.leaves(jax.device_get(res.params))))
            if not (loss_ok and params_ok):
                what = "loss" if not loss_ok else "params"
                raise ValueError(
                    f"bit_check: arm {label!r} diverges under the "
                    f"(V={V_env}, b={B_env}) envelope (round-1 {what}; "
                    f"loss {a!r} vs {b!r}); use grouping='exact' for "
                    f"this study or split the arm out")
