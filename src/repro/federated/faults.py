"""Fault-injection & recovery layer: the production failure semantics the
paper's model stops short of.

The paper motivates DEFL with "unreliable network connections", but the
scenario engine's failure model ends at per-round Bernoulli masks — a
failed uplink costs nothing and a straggler can stall the Eq. 8 clock
unboundedly. A `FaultModel` layers the missing production behaviors on a
`Scenario` (scenarios.Scenario.faults) without leaving the compiled path:

  round deadlines    the server truncates every round at `deadline`
                     seconds (or `deadline_factor` x the nominal full-
                     population Eq. 8 round time, resolved at Simulator
                     build). Clients whose V*t_cp + effective-uplink time
                     exceeds it are excluded from aggregation exactly like
                     dropouts (participation-renormalized), and the Eq. 8
                     clock becomes min(deadline, masked straggler max).
  retransmission     a failed uplink re-attempts up to `max_retries`
                     times with exponential backoff (`backoff_base` *
                     `backoff_factor`**(k-1) wait before attempt k), each
                     attempt against a freshly drawn AR(1) channel state.
                     Every attempt's airtime and bits are accounted: a
                     client's effective uplink time is the SUM of its
                     attempt times plus backoff waits, and uplink_bits
                     counts attempts x bits-per-update. Exhausted retries
                     = dropped this round.
  crash/rejoin       a per-client lifecycle state machine: an alive
                     client crashes with `crash_rate` per round and stays
                     down (absent from mask AND clock_mask — the server's
                     heartbeat timeout knows not to wait) for
                     `rejoin_rounds` rounds before rejoining. Crash
                     epochs span rounds: the down-counters ride in
                     ScenarioStream.state() so checkpoint/resume
                     continues an epoch bit-identically.
  quorum gating      a round whose post-dropout/deadline/guard
                     participation falls below `min_quorum` (an absolute
                     count, or a fraction of the cohort) is resolved
                     in-graph by `quorum_policy`: 'reject' makes the
                     params/opt writes a no-op (the round never happened
                     to the model) while the Eq. 8 clock still pays the
                     failed round's wall time plus `redispatch_cost`
                     seconds of re-dispatch overhead; 'accept' merely
                     counts the violation (`RoundRecord.rejected`).
  divergence guards  in-graph per-client update sanitation at aggregation
                     (mesh_rounds.build_round_step(guard=...)): non-finite
                     updates/losses are rejected (client dropped that
                     round) and update norms clipped at
                     `max_update_norm`; plus a run()-level guard that
                     snapshots the pre-chunk state and raises a
                     structured `DivergenceError` carrying the last-good
                     SimState instead of silently producing NaN history.

Everything is compiled into the scan backend as traced inputs (host-side
draws feed fixed-shape arrays; one trace per run), and a disabled
FaultModel (`active == False`) is bit-identical to not having one: the
fault draws are gated per knob, so the scenario RNG stream, the compiled
graphs and the clock accounting are untouched.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import numpy as np


@dataclass(frozen=True)
class FaultModel:
    """Failure/recovery knobs layered on a Scenario (all default 'off').

    deadline          server-side round deadline, simulated seconds.
    deadline_factor   alternative to `deadline`: the deadline as a
                      multiple of the nominal full-population Eq. 8 round
                      time (T_cm + V*T_cp at the resolved FedConfig) —
                      portable across models/populations; the Simulator
                      resolves it to seconds at build.
    max_retries       uplink re-attempts after a failed transmission.
    backoff_base      wait before the first retry, seconds.
    backoff_factor    exponential backoff multiplier per further retry.
    crash_rate        P(alive client crashes) per round.
    rejoin_rounds     heartbeat-timeout gap: rounds a crashed client
                      stays down before rejoining.
    reject_nonfinite  guard: drop clients whose update or loss is
                      non-finite (on whenever the model is active).
    max_update_norm   guard: clip each client's update to this L2 norm
                      before aggregation (None = no clipping).
    divergence_guard  run()-level guard: snapshot state per chunk and
                      raise DivergenceError on a non-finite round loss
                      with participants, instead of a NaN history.
    min_quorum        quorum gate: the minimum participation a round
                      needs to count. An int is an absolute client
                      count; a float in (0, 1] is a fraction of the
                      cohort (resolved with ceil at Simulator build —
                      `resolve_quorum`). None = no gate.
    quorum_policy     what a below-quorum round does: 'reject' no-ops
                      the params/opt update in-graph (clock still pays
                      the round plus `redispatch_cost`); 'accept' keeps
                      the update and only counts the violation.
    redispatch_cost   extra simulated seconds a rejected round costs on
                      top of its wall time (server re-dispatch overhead;
                      'reject' policy only).
    """

    deadline: Optional[float] = None
    deadline_factor: Optional[float] = None
    max_retries: int = 0
    backoff_base: float = 0.0
    backoff_factor: float = 2.0
    crash_rate: float = 0.0
    rejoin_rounds: int = 1
    reject_nonfinite: bool = True
    max_update_norm: Optional[float] = None
    divergence_guard: bool = True
    min_quorum: Optional[float] = None  # int count | float fraction
    quorum_policy: str = "reject"
    redispatch_cost: float = 0.0

    @property
    def active(self) -> bool:
        """False == disabled == bit-identical to no FaultModel at all."""
        return bool(self.deadline is not None
                    or self.deadline_factor is not None
                    or self.max_retries > 0
                    or self.crash_rate > 0
                    or self.max_update_norm is not None
                    or self.min_quorum is not None)

    @property
    def n_attempts(self) -> int:
        """Attempt-axis length A of a realization (first try + retries)."""
        return 1 + int(self.max_retries)

    def validate(self) -> None:
        if self.deadline is not None and self.deadline <= 0:
            raise ValueError(f"deadline must be > 0, got {self.deadline}")
        if self.deadline_factor is not None and self.deadline_factor <= 0:
            raise ValueError(
                f"deadline_factor must be > 0, got {self.deadline_factor}")
        if self.deadline is not None and self.deadline_factor is not None:
            raise ValueError(
                "set deadline (seconds) OR deadline_factor (x nominal "
                "round time), not both")
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if self.backoff_base < 0:
            raise ValueError(
                f"backoff_base must be >= 0, got {self.backoff_base}")
        if self.backoff_factor < 1.0:
            raise ValueError(
                f"backoff_factor must be >= 1, got {self.backoff_factor}")
        if not 0.0 <= self.crash_rate < 1.0:
            raise ValueError(
                f"crash_rate must be in [0, 1), got {self.crash_rate}")
        if self.rejoin_rounds < 1:
            raise ValueError(
                f"rejoin_rounds must be >= 1, got {self.rejoin_rounds}")
        if self.max_update_norm is not None and self.max_update_norm <= 0:
            raise ValueError(
                f"max_update_norm must be > 0, got {self.max_update_norm}")
        if self.min_quorum is not None:
            q = self.min_quorum
            if isinstance(q, bool) or not isinstance(
                    q, (int, float, np.integer, np.floating)):
                raise ValueError(
                    f"min_quorum must be an int count or a float fraction, "
                    f"got {q!r}")
            if isinstance(q, (int, np.integer)):
                if q < 1:
                    raise ValueError(
                        f"min_quorum as a count must be >= 1, got {q}")
            elif not 0.0 < q <= 1.0:
                raise ValueError(
                    f"min_quorum as a fraction must be in (0, 1], got {q}")
        if self.quorum_policy not in ("reject", "accept"):
            raise ValueError(
                f"unknown quorum_policy {self.quorum_policy!r}; "
                "expected 'reject' or 'accept'")
        if self.redispatch_cost < 0:
            raise ValueError(
                f"redispatch_cost must be >= 0, got {self.redispatch_cost}")

    def resolve_deadline(self, nominal_round_time: float) -> Optional[float]:
        """The deadline in seconds, resolving `deadline_factor` against
        the caller's nominal (full-population, fault-free) Eq. 8 round
        time. None when no deadline is configured."""
        if self.deadline is not None:
            return float(self.deadline)
        if self.deadline_factor is not None:
            return float(self.deadline_factor * nominal_round_time)
        return None

    def resolve_quorum(self, cohort_size: int) -> Optional[int]:
        """The quorum as an absolute client count for a `cohort_size`-
        client round (K when sampled, M dense). Fractions resolve with
        ceil, floored at 1; None when no quorum is configured."""
        if self.min_quorum is None:
            return None
        q = self.min_quorum
        if isinstance(q, (int, np.integer)):
            q_abs = int(q)
        else:
            q_abs = max(1, int(np.ceil(float(q) * cohort_size)))
        if q_abs > cohort_size:
            raise ValueError(
                f"min_quorum {q!r} resolves to {q_abs} clients but rounds "
                f"have only {cohort_size} — no round could ever pass")
        return q_abs

    def guard_spec(self) -> tuple:
        """Static (max_norm, reject_nonfinite) pair compiled into the
        round step's sanitation path (mesh_rounds.build_round_step's
        `guard` argument)."""
        max_norm = (float(self.max_update_norm)
                    if self.max_update_norm is not None else float("inf"))
        return (max_norm, bool(self.reject_nonfinite))

    def link_success(self, link_failure: float) -> float:
        """P(an upload eventually lands | client present): retries turn
        one Bernoulli failure draw into A independent ones."""
        return float(1.0 - link_failure ** self.n_attempts)

    def availability(self) -> float:
        """Stationary P(client not in a crash epoch): the alive/down
        Markov chain spends 1/crash_rate rounds up per `rejoin_rounds`
        down, so uptime = 1 / (1 + crash_rate * rejoin_rounds)."""
        return float(1.0 / (1.0 + self.crash_rate * self.rejoin_rounds))

    def backoff_waits(self, attempts) -> np.ndarray:
        """Total backoff wait (seconds) for clients that made `attempts`
        tries: sum_{k=1}^{a-1} backoff_base * backoff_factor**(k-1)."""
        attempts = np.asarray(attempts)
        if self.backoff_base == 0.0 or self.max_retries == 0:
            return np.zeros(attempts.shape, np.float64)
        k = np.arange(1, self.n_attempts)
        waits = self.backoff_base * self.backoff_factor ** (k - 1.0)
        used = k[..., :] < attempts[..., None]
        return np.where(used, waits, 0.0).sum(axis=-1)

    def replace(self, **kw) -> "FaultModel":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class RecoveryPolicy:
    """Auto-recovery from divergence, consumed by
    `Simulator.run(recovery=...)`: on a `DivergenceError` the run rewinds
    to the carried last-good SimState, deterministically shrinks the
    learning rate by `lr_backoff` (cumulative across restarts), optionally
    tightens the norm guard by `tighten_guard` (multiplies the model's
    `max_update_norm`; a no-op when none is set), and re-runs — at most
    `max_restarts` times before the error propagates. Every restart is
    recorded in `SimResult.restarts` (attempt, round, lr scale, guard,
    message) so recovered runs stay auditable."""

    max_restarts: int = 3
    lr_backoff: float = 0.5
    tighten_guard: Optional[float] = None

    def validate(self) -> None:
        if self.max_restarts < 1:
            raise ValueError(
                f"max_restarts must be >= 1, got {self.max_restarts}")
        if not 0.0 < self.lr_backoff <= 1.0:
            raise ValueError(
                f"lr_backoff must be in (0, 1], got {self.lr_backoff}")
        if self.tighten_guard is not None and not (
                0.0 < self.tighten_guard <= 1.0):
            raise ValueError(
                f"tighten_guard must be in (0, 1], got {self.tighten_guard}")


class DivergenceError(RuntimeError):
    """Raised by Simulator.run() (divergence_guard on) when a round's
    train loss goes non-finite with participants — e.g. the guard's
    non-finite rejection was disabled, or the aggregate itself diverged.

    Carries enough to recover instead of rerunning from scratch:
      state        the last-good SimState host snapshot (taken at the
                   chunk / eval boundary BEFORE the offending rounds) —
                   resumable via Simulator.run(state, ...)
      history      RoundRecords up to and including the offending round
      round        global round number where the loss went non-finite
      faults       the run's FaultModel (None on guard-less sims)
      guard        the compiled (max_norm, reject_nonfinite) guard spec
                   in effect, or None
      finite_mask  (C,) bool per-client finite-loss mask of the offending
                   round (which clients' local losses were still finite) —
                   distinguishes "one client NaN'd" from "global blow-up"
                   without a re-run. None when the backend didn't surface
                   it (loop reference).
    """

    def __init__(self, message: str, state=None, history=None,
                 round: int = -1, faults=None, guard=None,
                 finite_mask=None):
        super().__init__(message)
        self.state = state
        self.history = list(history) if history is not None else []
        self.round = int(round)
        self.faults = faults
        self.guard = guard
        self.finite_mask = (None if finite_mask is None
                            else np.asarray(finite_mask, bool))
