"""Fault-injection & recovery layer: the production failure semantics the
paper's model stops short of.

The paper motivates DEFL with "unreliable network connections", but the
scenario engine's failure model ends at per-round Bernoulli masks — a
failed uplink costs nothing and a straggler can stall the Eq. 8 clock
unboundedly. A `FaultModel` layers the missing production behaviors on a
`Scenario` (scenarios.Scenario.faults) without leaving the compiled path:

  round deadlines    the server truncates every round at `deadline`
                     seconds (or `deadline_factor` x the nominal full-
                     population Eq. 8 round time, resolved at Simulator
                     build). Clients whose V*t_cp + effective-uplink time
                     exceeds it are excluded from aggregation exactly like
                     dropouts (participation-renormalized), and the Eq. 8
                     clock becomes min(deadline, masked straggler max).
  retransmission     a failed uplink re-attempts up to `max_retries`
                     times with exponential backoff (`backoff_base` *
                     `backoff_factor`**(k-1) wait before attempt k), each
                     attempt against a freshly drawn AR(1) channel state.
                     Every attempt's airtime and bits are accounted: a
                     client's effective uplink time is the SUM of its
                     attempt times plus backoff waits, and uplink_bits
                     counts attempts x bits-per-update. Exhausted retries
                     = dropped this round.
  crash/rejoin       a per-client lifecycle state machine: an alive
                     client crashes with `crash_rate` per round and stays
                     down (absent from mask AND clock_mask — the server's
                     heartbeat timeout knows not to wait) for
                     `rejoin_rounds` rounds before rejoining. Crash
                     epochs span rounds: the down-counters ride in
                     ScenarioStream.state() so checkpoint/resume
                     continues an epoch bit-identically.
  divergence guards  in-graph per-client update sanitation at aggregation
                     (mesh_rounds.build_round_step(guard=...)): non-finite
                     updates/losses are rejected (client dropped that
                     round) and update norms clipped at
                     `max_update_norm`; plus a run()-level guard that
                     snapshots the pre-chunk state and raises a
                     structured `DivergenceError` carrying the last-good
                     SimState instead of silently producing NaN history.

Everything is compiled into the scan backend as traced inputs (host-side
draws feed fixed-shape arrays; one trace per run), and a disabled
FaultModel (`active == False`) is bit-identical to not having one: the
fault draws are gated per knob, so the scenario RNG stream, the compiled
graphs and the clock accounting are untouched.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import numpy as np


@dataclass(frozen=True)
class FaultModel:
    """Failure/recovery knobs layered on a Scenario (all default 'off').

    deadline          server-side round deadline, simulated seconds.
    deadline_factor   alternative to `deadline`: the deadline as a
                      multiple of the nominal full-population Eq. 8 round
                      time (T_cm + V*T_cp at the resolved FedConfig) —
                      portable across models/populations; the Simulator
                      resolves it to seconds at build.
    max_retries       uplink re-attempts after a failed transmission.
    backoff_base      wait before the first retry, seconds.
    backoff_factor    exponential backoff multiplier per further retry.
    crash_rate        P(alive client crashes) per round.
    rejoin_rounds     heartbeat-timeout gap: rounds a crashed client
                      stays down before rejoining.
    reject_nonfinite  guard: drop clients whose update or loss is
                      non-finite (on whenever the model is active).
    max_update_norm   guard: clip each client's update to this L2 norm
                      before aggregation (None = no clipping).
    divergence_guard  run()-level guard: snapshot state per chunk and
                      raise DivergenceError on a non-finite round loss
                      with participants, instead of a NaN history.
    """

    deadline: Optional[float] = None
    deadline_factor: Optional[float] = None
    max_retries: int = 0
    backoff_base: float = 0.0
    backoff_factor: float = 2.0
    crash_rate: float = 0.0
    rejoin_rounds: int = 1
    reject_nonfinite: bool = True
    max_update_norm: Optional[float] = None
    divergence_guard: bool = True

    @property
    def active(self) -> bool:
        """False == disabled == bit-identical to no FaultModel at all."""
        return bool(self.deadline is not None
                    or self.deadline_factor is not None
                    or self.max_retries > 0
                    or self.crash_rate > 0
                    or self.max_update_norm is not None)

    @property
    def n_attempts(self) -> int:
        """Attempt-axis length A of a realization (first try + retries)."""
        return 1 + int(self.max_retries)

    def validate(self) -> None:
        if self.deadline is not None and self.deadline <= 0:
            raise ValueError(f"deadline must be > 0, got {self.deadline}")
        if self.deadline_factor is not None and self.deadline_factor <= 0:
            raise ValueError(
                f"deadline_factor must be > 0, got {self.deadline_factor}")
        if self.deadline is not None and self.deadline_factor is not None:
            raise ValueError(
                "set deadline (seconds) OR deadline_factor (x nominal "
                "round time), not both")
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {self.max_retries}")
        if self.backoff_base < 0:
            raise ValueError(
                f"backoff_base must be >= 0, got {self.backoff_base}")
        if self.backoff_factor < 1.0:
            raise ValueError(
                f"backoff_factor must be >= 1, got {self.backoff_factor}")
        if not 0.0 <= self.crash_rate < 1.0:
            raise ValueError(
                f"crash_rate must be in [0, 1), got {self.crash_rate}")
        if self.rejoin_rounds < 1:
            raise ValueError(
                f"rejoin_rounds must be >= 1, got {self.rejoin_rounds}")
        if self.max_update_norm is not None and self.max_update_norm <= 0:
            raise ValueError(
                f"max_update_norm must be > 0, got {self.max_update_norm}")

    def resolve_deadline(self, nominal_round_time: float) -> Optional[float]:
        """The deadline in seconds, resolving `deadline_factor` against
        the caller's nominal (full-population, fault-free) Eq. 8 round
        time. None when no deadline is configured."""
        if self.deadline is not None:
            return float(self.deadline)
        if self.deadline_factor is not None:
            return float(self.deadline_factor * nominal_round_time)
        return None

    def guard_spec(self) -> tuple:
        """Static (max_norm, reject_nonfinite) pair compiled into the
        round step's sanitation path (mesh_rounds.build_round_step's
        `guard` argument)."""
        max_norm = (float(self.max_update_norm)
                    if self.max_update_norm is not None else float("inf"))
        return (max_norm, bool(self.reject_nonfinite))

    def link_success(self, link_failure: float) -> float:
        """P(an upload eventually lands | client present): retries turn
        one Bernoulli failure draw into A independent ones."""
        return float(1.0 - link_failure ** self.n_attempts)

    def availability(self) -> float:
        """Stationary P(client not in a crash epoch): the alive/down
        Markov chain spends 1/crash_rate rounds up per `rejoin_rounds`
        down, so uptime = 1 / (1 + crash_rate * rejoin_rounds)."""
        return float(1.0 / (1.0 + self.crash_rate * self.rejoin_rounds))

    def backoff_waits(self, attempts) -> np.ndarray:
        """Total backoff wait (seconds) for clients that made `attempts`
        tries: sum_{k=1}^{a-1} backoff_base * backoff_factor**(k-1)."""
        attempts = np.asarray(attempts)
        if self.backoff_base == 0.0 or self.max_retries == 0:
            return np.zeros(attempts.shape, np.float64)
        k = np.arange(1, self.n_attempts)
        waits = self.backoff_base * self.backoff_factor ** (k - 1.0)
        used = k[..., :] < attempts[..., None]
        return np.where(used, waits, 0.0).sum(axis=-1)

    def replace(self, **kw) -> "FaultModel":
        return dataclasses.replace(self, **kw)


class DivergenceError(RuntimeError):
    """Raised by Simulator.run() (divergence_guard on) when a round's
    train loss goes non-finite with participants — e.g. the guard's
    non-finite rejection was disabled, or the aggregate itself diverged.

    Carries enough to recover instead of rerunning from scratch:
      state    the last-good SimState host snapshot (taken at the chunk /
               eval boundary BEFORE the offending rounds) — resumable via
               Simulator.run(state, ...)
      history  RoundRecords up to and including the offending round
      round    global round number where the loss went non-finite
    """

    def __init__(self, message: str, state=None, history=None,
                 round: int = -1):
        super().__init__(message)
        self.state = state
        self.history = list(history) if history is not None else []
        self.round = int(round)
