"""Mesh-level DEFL round step: the datacenter realization of Algorithm 1.

Clients are a stacked leading axis C on every param/opt leaf, sharded over
the mesh's client axes ('data', and 'pod' x 'data' multi-pod). One round
step = V local SGD steps per client (vmapped: zero cross-client
collectives) + weighted FedAvg aggregation (one param-sized all-reduce) +
broadcast. The paper's talk/work ratio is therefore visible directly in
the compiled HLO: collective bytes per round ~ |params|, compute ~ V
forward/backward passes (see EXPERIMENTS.md §Roofline).

Aggregation modes:
  'allreduce'  : psum-style weighted mean in fp32 (paper-faithful sync).
  'int8_gather': beyond-paper — per-client int8 quantized deltas are
                 all-gathered and combined locally, shrinking collective
                 bytes ~4x (federated/compression.py semantics inline).
  'int8_stochastic': the exact federated/compression.py quantizer
                 (stochastic rounding, one fp32 scale per 1024-chunk) run
                 in-graph on per-client deltas — the compiled form of the
                 simulator's host-side compress/decompress roundtrip.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.optim.api import Optimizer, apply_updates


def local_steps_fn(loss_fn: Callable, opt: Optimizer):
    """(params, opt_state, batches[V]) -> (params', opt_state', mean_loss).

    The V-step loss mean accumulates in the scan CARRY (a sequential
    left-fold) rather than stacking and reducing: the fold's partial sums
    are prefix-stable, so the envelope form below — the same fold over
    V_env steps whose padded tail adds exact zeros — reproduces it bit for
    bit at any padding (XLA's reduce would re-associate with length)."""

    def run(params, opt_state, batches):
        def step(carry, batch):
            p, s, acc = carry
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(p, batch)
            updates, s = opt.update(grads, s, p)
            return (apply_updates(p, updates), s, acc + loss), None

        (params, opt_state, total), _ = jax.lax.scan(
            step, (params, opt_state, jnp.zeros(())), batches)
        V = jax.tree.leaves(batches)[0].shape[0]
        return params, opt_state, total / V

    return run


def envelope_local_steps_fn(loss_fn: Callable, opt: Optimizer):
    """`local_steps_fn` over a padded (V_env, B_env) shape envelope.

    The Study API (federated/study.py) runs arms with different (b, V)
    plans in ONE vmapped fleet by padding every member to the group's
    common envelope; this is the member-level local step that makes the
    padding a bitwise no-op:

      batches      (V_env, B_env, ...) — the member's real V x b draws,
                   padded along both axes
      v_mask       (V_env,) 0/1 — 1 for the member's own local steps;
                   padded steps run (shapes are static) but their
                   params/opt writes are masked out with `where`, exactly
                   the ragged-final-chunk `valid` trick of
                   build_round_chunk, so they cannot perturb state
      sample_mask  (B_env,) 0/1 and n_samples (f32 count) — forwarded to
                   the masked loss; loss_fn(params, batch, sample_mask, n)
                   must make padded samples exact zeros in the loss and
                   its gradient (e.g. models.cnn.cnn_loss_masked, whose
                   conv backward is pad-stable via `_ps_matmul`)

    The returned mean loss accumulates in the scan carry exactly like
    `local_steps_fn`'s (padded steps add an exact 0) and divides by the
    member's own V — bit-identical to the unpadded fold."""

    def run(params, opt_state, batches, v_mask, sample_mask, n_samples):
        def step(carry, xs):
            p, s, acc = carry
            batch, valid = xs
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                p, batch, sample_mask, n_samples)
            updates, s2 = opt.update(grads, s, p)
            p2 = apply_updates(p, updates)
            keep = lambda nw, old: jnp.where(valid > 0, nw, old.astype(nw.dtype))  # noqa: E731
            return ((jax.tree.map(keep, p2, p), jax.tree.map(keep, s2, s),
                     acc + jnp.where(valid > 0, loss, 0.0)), None)

        (params, opt_state, total), _ = jax.lax.scan(
            step, (params, opt_state, jnp.zeros(())), (batches, v_mask))
        return params, opt_state, total / jnp.sum(v_mask)

    return run


def _get_shard_map():
    """shard_map + its replication-check kwarg across jax versions: the
    top-level export with check_vma (jax >= 0.8) or the experimental one
    with check_rep (jax < 0.8, e.g. the 0.4.x CPU container)."""
    try:
        from jax import shard_map as sm
        return sm, {"check_vma": False}
    except ImportError:
        from jax.experimental.shard_map import shard_map as sm
        return sm, {"check_rep": False}


def _participation_weights(weights, mask):
    """FedAvg weights renormalized over the round's participating clients.

    mask is a traced (C,) array (1.0 = update arrived). Dropped clients get
    exactly-zero weight (their rows are also reset to finite pre-round
    values before the contraction, so x * 0.0 contributes an exact +0.0
    and a masked client can never perturb the aggregate bits). A zero-
    participation round divides by 1 instead of 0; the caller keeps the old
    params via `_keep_old_params`. Returns (weights', any_participant)."""
    wm = weights.astype(jnp.float32) * mask.astype(jnp.float32)
    s = jnp.sum(wm)
    any_p = s > 0
    return wm / jnp.where(any_p, s, 1.0), any_p


def _keep_old_params(agg_p, old_params, any_p):
    """Zero-participation guard: no update arrived -> params unchanged."""
    return jax.tree.map(
        lambda a, o: jnp.where(any_p, a, o.astype(a.dtype)), agg_p, old_params)


def _select_participating_state(new_s, old_s, mask):
    """Per-client opt-state select: dropped clients keep their pre-round
    state (the loop backend never runs them, so momentum etc. must not
    advance). mask broadcasts from (C,) over each leaf's trailing dims."""
    def sel(n, o):
        m = mask.reshape((mask.shape[0],) + (1,) * (n.ndim - 1))
        return jnp.where(m > 0, n, o)

    return jax.tree.map(sel, new_s, old_s)


def _masked_clock(t_cp, t_cm, clock_mask, V):
    """Eq. 8 round clock as the straggler max over *participating* clients,
    computed in-graph from traced per-client delay inputs (seconds).

    Zero participation falls back to the full-population max: the
    synchronous server's wait times out at the slowest possible client, so
    the wall clock advances even though no update arrives (host twin:
    core.delay.masked_round_times)."""
    any_p = jnp.any(clock_mask > 0)

    def mmax(t):
        t = t.astype(jnp.float32)
        masked = jnp.max(jnp.where(clock_mask > 0, t, -jnp.inf))
        return jnp.where(any_p, masked, jnp.max(t))

    T_cm, T_cp = mmax(t_cm), mmax(t_cp)
    return {"T_cm": T_cm, "T_cp": T_cp, "T_round": T_cm + V * T_cp}


def _weighted_client_sum(weights, x):
    """sum_c w_c x_c over the leading client axis, as an explicit
    multiply + reduce rather than a tensordot/dot_general contraction.

    Deliberate: XLA lowers a dot_general differently once an extra
    leading batch dimension appears (the fleet vmap in
    `build_fleet_chunk`), reassociating the fp32 accumulation and
    breaking bit-identity between a vmapped fleet member and the same
    seed run alone. A reduce keeps the per-output-element accumulation
    order over C fixed regardless of leading batch dims, which is what
    the run_fleet == sequential-run bit-parity contract rests on."""
    w = weights.astype(jnp.float32).reshape(
        (weights.shape[0],) + (1,) * (x.ndim - 1))
    return jnp.sum(w * x.astype(jnp.float32), axis=0)


def _weighted_mean_bcast(stacked, weights):
    """sum_c w_c x_c, broadcast back to all C rows (keeps leaves (C, ...))."""

    def agg(x):
        mean = _weighted_client_sum(weights, x)
        return jnp.broadcast_to(mean[None].astype(x.dtype), x.shape)

    return jax.tree.map(agg, stacked)


def _int8_gather_mean_bcast(new_params, old_params, weights, key):
    """Quantize per-client deltas to int8, combine, add to the (shared) old
    params, broadcast. old_params rows are identical pre-round, so using row
    data is consistent under the client-axis sharding."""

    def agg(new, old):
        delta = (new - old).astype(jnp.float32)  # (C, ...)
        flat = delta.reshape(delta.shape[0], -1)
        absmax = jnp.max(jnp.abs(flat), axis=-1, keepdims=True)
        scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
        q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
        # The all-gather happens here under GSPMD: q is client-sharded and the
        # weighted sum contracts the client axis.
        deq = q.astype(jnp.float32) * scale
        mean = jnp.tensordot(weights.astype(jnp.float32), deq, axes=(0, 0))
        agg_new = old[0].reshape(-1) + mean
        return jnp.broadcast_to(
            agg_new.reshape(old.shape[1:])[None].astype(new.dtype), new.shape)

    return jax.tree.map(agg, new_params, old_params)


def _int8_stochastic_mean_bcast(new_params, old_params, weights, keys, impl):
    """federated/compression.py semantics in-graph: every client's delta
    goes through the stochastic int8 quantize/dequantize roundtrip (per-
    1024-chunk fp32 scales), then weighted FedAvg + broadcast. keys (C, 2)
    carry one PRNG key per client; fed from the same sequential schedule as
    the host loop, the reconstruction is bit-identical to it."""
    from repro.federated import compression

    deltas = jax.tree.map(lambda n, o: n - o, new_params, old_params)
    rec = jax.vmap(
        lambda d, k: compression.decompress_update(
            compression.compress_update(d, k, impl=impl), impl=impl)
    )(deltas, keys)

    def agg(r, old):
        flat = r.reshape(r.shape[0], -1).astype(jnp.float32)
        # multiply+reduce, not tensordot: see _weighted_client_sum.
        mean = _weighted_client_sum(weights, flat)
        out = old[0].reshape(-1).astype(jnp.float32) + mean
        return jnp.broadcast_to(
            out.reshape(old.shape[1:])[None].astype(old.dtype), old.shape)

    return jax.tree.map(agg, rec, old_params)


def _int8_shardmap_sync(mesh, param_specs_tree, client_axes):
    """Explicit-collective int8 sync: each client quantizes its delta to
    int8 locally, `lax.all_gather` moves INT8 (+ fp32 scales) over the
    client axes, dequant + weighted-combine happen after the gather.

    Why not GSPMD: quantize-then-contract under pjit lets the partitioner
    place the collective on the dequantized fp32 tensor (measured: WORSE
    than plain all-reduce — EXPERIMENTS.md §Perf iteration A3/B-int8).
    shard_map pins int8 on the wire: ~4x fewer sync bytes than fp32
    all-reduce at one extra rounding step (unbiased via the stochastic
    quantizer semantics; deterministic rounding here since the round-step
    PRNG lives outside the sync)."""
    _shard_map, _sm_kw = _get_shard_map()

    axis = client_axes if len(client_axes) > 1 else client_axes[0]

    def sync(new_p, old_p, weights):
        def leaf(new, old, spec):
            def body(n_loc, o_loc, w_all):
                # n_loc/o_loc: (1, ...) local client row(s).
                delta = (n_loc - o_loc).astype(jnp.float32).reshape(
                    n_loc.shape[0], -1)
                absmax = jnp.max(jnp.abs(delta), axis=-1, keepdims=True)
                scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
                q = jnp.clip(jnp.round(delta / scale), -127, 127).astype(jnp.int8)
                qg = jax.lax.all_gather(q, axis)  # int8 on the wire
                sg = jax.lax.all_gather(scale, axis)
                if isinstance(axis, tuple):
                    qg = qg.reshape(-1, *qg.shape[len(axis):])
                    sg = sg.reshape(-1, *sg.shape[len(axis):])
                qg = qg.reshape(-1, delta.shape[-1])
                sg = sg.reshape(-1, 1)
                mean = jnp.tensordot(
                    w_all, qg.astype(jnp.float32) * sg, axes=(0, 0))
                out = o_loc.reshape(o_loc.shape[0], -1) + mean[None]
                return out.reshape(o_loc.shape).astype(n_loc.dtype)

            in_specs = (spec, spec, jax.sharding.PartitionSpec())
            return _shard_map(body, mesh=mesh, in_specs=in_specs,
                              out_specs=spec, **_sm_kw)(
                new, old, weights)

        return jax.tree.map(leaf, new_p, old_p, param_specs_tree)

    return sync


def _psum_shardmap_sync(mesh, param_specs_tree, client_axes):
    """Explicit-collective fp32 FedAvg sync: weighted psum over the client
    axes inside shard_map.

    Why not GSPMD tensordot: for leaves whose trailing dims are replicated
    (e.g. small attention weight stacks) the partitioner lowers the
    client-axis contraction as a FULL all-gather of the stacked fp32
    weights (measured 197 GB/leaf on llava-next-34b — EXPERIMENTS.md
    §Perf B). A pinned psum moves 2x the leaf shard instead."""
    _shard_map, _sm_kw = _get_shard_map()

    axes = tuple(client_axes)

    def sync(new_p, weights):
        def leaf(new, spec):
            def body(n_loc, w_all):
                idx = jax.lax.axis_index(axes[0])
                if len(axes) > 1:
                    for a in axes[1:]:
                        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
                # n_loc is this shard's (rows, ...) slice of the client
                # axis — rows > 1 when C exceeds the device count. Each
                # shard reduces its own rows locally, then one psum of the
                # param-sized partial crosses the wire.
                rows = n_loc.shape[0]
                w = jax.lax.dynamic_slice_in_dim(
                    w_all, idx * rows, rows).astype(jnp.float32)
                wl = w.reshape((rows,) + (1,) * (n_loc.ndim - 1))
                local = jnp.sum(wl * n_loc.astype(jnp.float32), axis=0,
                                keepdims=True)
                agg = jax.lax.psum(local,
                                   axes if len(axes) > 1 else axes[0])
                return jnp.broadcast_to(agg, n_loc.shape).astype(n_loc.dtype)

            in_specs = (spec, jax.sharding.PartitionSpec())
            return _shard_map(body, mesh=mesh, in_specs=in_specs,
                              out_specs=spec, **_sm_kw)(new, weights)

        return jax.tree.map(leaf, new_p, param_specs_tree)

    return sync


def _guard_clients(guard, new_p, params_C, losses, mask):
    """Divergence-guard sanitation of per-client updates (fault layer).

    guard is a STATIC (max_norm, reject_nonfinite) pair (see
    faults.FaultModel.guard_spec — static per compiled graph, so the
    clipping ops only exist when max_norm is finite). Per client the
    update's global L2 norm across all leaves decides its fate:

      non-finite (norm or loss) + reject  -> masked out of this round's
          aggregation (the caller's mask-handling resets the row to its
          pre-round state, so a NaN client restarts from the next global
          model instead of poisoning it)
      norm > max_norm -> delta scaled back to max_norm before
          aggregation (the opt state keeps the raw step — clipping caps
          the aggregate's exposure, it does not rewrite client history)

    Returns (new_p, mask') where mask' folds the rejections into the
    participation mask (mask=None is treated as full participation).
    """
    max_norm, reject = guard
    deltas = jax.tree.map(
        lambda n, o: n.astype(jnp.float32) - o.astype(jnp.float32),
        new_p, params_C)
    sq = jnp.zeros(losses.shape[0], jnp.float32)
    for d in jax.tree.leaves(deltas):
        sq = sq + jnp.sum(d.reshape(d.shape[0], -1) ** 2, axis=1)
    norm = jnp.sqrt(sq)
    finite = jnp.isfinite(norm) & jnp.isfinite(losses)
    if max_norm < float("inf"):
        scale = jnp.where(
            finite, jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12)),
            1.0)

        def clip(o, d):
            s = scale.reshape((scale.shape[0],) + (1,) * (d.ndim - 1))
            return (o.astype(jnp.float32) + d * s).astype(o.dtype)

        new_p = jax.tree.map(clip, params_C, deltas)
    if reject:
        ok = finite.astype(jnp.float32)
        mask = ok if mask is None else mask * ok
    return new_p, mask


def build_round_step(
    loss_fn: Callable,
    opt: Optimizer,
    V: int,
    aggregation: str = "allreduce",
    mesh=None,
    param_specs_tree=None,
    client_axes=None,
    impl: str = "xla",
    envelope: bool = False,
    guard=None,
):
    """Build round_step(params_C, opt_C, batches, weights, keys=None,
    mask=None, clock_mask=None, t_cp=None, t_cm=None, env=None) with
    leaves stacked on a leading client axis C and batches (C, V, ...).

    aggregation in ('allreduce_shardmap', 'int8_shardmap') needs
    (mesh, param_specs_tree, client_axes) for the explicit-collective path;
    'allreduce' is the plain GSPMD tensordot used on a single device.
    'int8_stochastic' additionally takes keys (C, 2) — one quantizer PRNG
    key per client — and honors impl ('xla' | 'pallas') for the quantize
    kernel. metrics carries both the weighted loss and the raw per-client
    losses so callers can match the host loop's unweighted mean.

    Scenario inputs (all traced (C,) arrays — per-round values change
    without retracing, and nothing here forces a host sync):
      mask        participation mask; weights are renormalized over the
                  participating clients (`_participation_weights`) and
                  dropped clients keep their pre-round opt state. With no
                  participants at all, params pass through unchanged.
                  mask=None is the legacy full-participation path and is
                  bit-identical to it (mask of ones multiplies weights by
                  exactly 1.0 and the zero-guard selects are no-ops).
      clock_mask  clients the synchronous server waits for (defaults to
                  mask); with t_cp/t_cm (per-client seconds, Eqs. 4/6)
                  metrics gains the in-graph Eq. 8 round clock
                  ('T_cm', 'T_cp', 'T_round') as the straggler max over
                  waiting clients.

    envelope=True runs the (V, b) shape-envelope form: `loss_fn` takes
    (params, batch, sample_mask, n) and batches are (C, V_env, B_env, ...)
    with the per-member masks arriving via `env` — a dict of traced
    arrays {'v_mask' (V_env,), 'sample_mask' (B_env,), 'n_samples' f32,
    'v_count' f32} shared across the C clients of one member (the Study
    API's members all pad client-uniformly). The in-graph T_round then
    uses the traced v_count in place of the static V.

    guard (static (max_norm, reject_nonfinite) pair, or None) compiles
    the fault layer's divergence sanitation in front of aggregation: see
    `_guard_clients`. Rejections fold into the participation mask, so
    downstream weight renormalization / state selection / clock handling
    are untouched; metrics gains 'mask_eff' (the post-guard mask) so
    chunk-level consumers count participants guard-aware. guard=None
    builds today's graph unchanged.
    """
    local = (envelope_local_steps_fn(loss_fn, opt) if envelope
             else local_steps_fn(loss_fn, opt))
    int8_sync = psum_sync = None
    if aggregation == "int8_shardmap":
        int8_sync = _int8_shardmap_sync(mesh, param_specs_tree, client_axes)
    if aggregation == "allreduce_shardmap":
        psum_sync = _psum_shardmap_sync(mesh, param_specs_tree, client_axes)

    def round_step(params_C, opt_C, batches, weights, keys=None,
                   mask=None, clock_mask=None, t_cp=None, t_cm=None,
                   env=None):
        if envelope:
            new_p, new_s, losses = jax.vmap(
                local, in_axes=(0, 0, 0, None, None, None))(
                    params_C, opt_C, batches, env["v_mask"],
                    env["sample_mask"], env["n_samples"])
        else:
            new_p, new_s, losses = jax.vmap(local)(params_C, opt_C, batches)
        if guard is not None:
            new_p, mask = _guard_clients(guard, new_p, params_C, losses, mask)
        any_p = None
        if mask is not None:
            weights, any_p = _participation_weights(weights, mask)
            # Replace dropped clients' rows with their pre-round state (and
            # zero their loss) BEFORE the contraction: weight-0 alone is
            # not enough if a never-aggregated client diverged to inf/NaN
            # (0 * inf = NaN would poison the weighted mean, which the
            # loop backend — never running that client — cannot hit).
            new_p = _select_participating_state(new_p, params_C, mask)
            new_s = _select_participating_state(new_s, opt_C, mask)
            losses = jnp.where(mask > 0, losses, 0.0)
        if aggregation == "allreduce":
            agg_p = _weighted_mean_bcast(new_p, weights)
        elif aggregation == "allreduce_shardmap":
            agg_p = psum_sync(new_p, weights)
        elif aggregation == "int8_gather":
            agg_p = _int8_gather_mean_bcast(
                new_p, params_C, weights, key=None)
        elif aggregation == "int8_stochastic":
            assert keys is not None, "int8_stochastic needs per-client keys"
            agg_p = _int8_stochastic_mean_bcast(
                new_p, params_C, weights, keys, impl)
        elif aggregation == "int8_shardmap":
            agg_p = int8_sync(new_p, params_C, weights)
        else:
            raise ValueError(aggregation)
        if any_p is not None:
            agg_p = _keep_old_params(agg_p, params_C, any_p)
        metrics = {"loss": jnp.tensordot(weights.astype(jnp.float32),
                                         losses, axes=(0, 0)),
                   "per_client_loss": losses}
        if mask is not None:
            metrics["n_participants"] = jnp.sum(mask.astype(jnp.float32))
            if guard is not None:
                metrics["mask_eff"] = mask.astype(jnp.float32)
        if t_cp is not None and t_cm is not None:
            cmask = mask if clock_mask is None else clock_mask
            assert cmask is not None, "in-graph clock needs a clock_mask/mask"
            v = env["v_count"] if envelope else V
            metrics.update(_masked_clock(t_cp, t_cm, cmask, v))
        return agg_p, new_s, metrics

    return round_step


def build_round_chunk(
    loss_fn: Callable,
    opt: Optimizer,
    V: int,
    n_clients: int,
    aggregation: str = "allreduce",
    impl: str = "xla",
    scenario: bool = False,
    batch_from: Callable = None,
    update_bits: float = None,
    envelope: bool = False,
    guard=None,
    faults: bool = False,
    sampled: bool = False,
    quorum: str = None,
    mesh=None,
    param_specs_tree=None,
    client_axes=None,
):
    """Fuse a whole chunk of rounds into one `jax.lax.scan` over the round
    step: the host touches the device once per chunk instead of once per
    round (one stacked input transfer in, one stacked metrics fetch out).

    Returns chunk_step(params_C, opt_C, key, weights, t_cp, data, xs)
    -> (params_C', opt_C', key', ys) where xs is the per-round scanned
    input pytree, every leaf stacked on a leading R axis:

      batches  (R, C, V, ...) pre-stacked batch pytree (generic path), OR
      idx      (R, C, V, B) int32 global sample indices, gathered in-graph
               from the device-resident `data` arrays via `batch_from`
               (zero per-round batch bytes over PCIe/host memory)
      valid    (R,) bool — padding flag for a ragged final chunk. Invalid
               rounds run (shapes are static) but their state writes and
               PRNG-key advance are masked out, so a chunk padded from n
               to R rounds leaves params/opt/key exactly as n rounds would
               — and every chunk of a run reuses ONE trace.
      mask, clock_mask, t_cm   (R, C) scenario inputs (scenario=True),
               with t_cp the static (C,) compute times (Eq. 4).

    ys stacks per-round metrics: 'loss' (and with scenario=True
    'n_participants', the in-graph Eq. 8 clocks 'T_cm'/'T_cp'/'T_round');
    with update_bits set, 'uplink_bits' = participants x bits-per-update
    (compression.compressed_bits accounting, computed in-graph in fp32 —
    callers needing exact counts multiply on the host). The caller fetches
    ys with a single device_get per chunk. Note FLSimulation's history
    records rebuild clocks/bits from the f64 host twin of the same inputs
    (delay.chunk_round_times — bit parity with the per-round backends);
    the fp32 in-graph copies exist for device-side consumers that must
    not touch the host (custom in-graph stopping rules, on-device logs).

    aggregation='int8_stochastic' draws per-client quantizer keys inside
    the scan body through compression.sequential_client_keys — the same
    schedule as the per-round backends, so the stochastic-rounding noise
    stream is bit-identical to theirs.

    envelope=True builds the Study API's (V, b) shape-envelope chunk:
    `loss_fn` is the masked form, V is the padded V_env (batches/idx carry
    (C, V_env, B_env) per round), and the chunk fn gains a trailing `env`
    argument — {'v_mask', 'sample_mask', 'n_samples', 'v_count',
    'update_bits'} traced per-member values (see build_round_step). The
    in-graph uplink_bits then uses env['update_bits'] (traced, so arms
    with different wire sizes share one compiled graph) instead of the
    static update_bits constant.

    The fault layer (faults.FaultModel) adds two static build knobs that
    keep everything in the ONE compiled scan:
      guard        static (max_norm, reject) sanitation pair, forwarded
                   to build_round_step — rejected clients count out of
                   'loss'/'n_participants' via the post-guard 'mask_eff'.
      faults=True  xs gains two traced (R,) leaves: 't_cap' (the round
                   deadline in seconds, +inf when none — the in-graph
                   'T_round' becomes min(t_cap, straggler max)) and
                   'bits_mult' (total uplink ATTEMPTS this round — with
                   retransmission every attempt's bits hit the air, so
                   'uplink_bits' = bits_mult x bits-per-update instead of
                   participants x bits). Deadline/retry exclusions are
                   drawn host-side into the mask (simulation._fault_round)
                   — the graph only consumes their traced results, so
                   fault rounds neither retrace nor sync. ys additionally
                   stacks 'finite' (R, C) — each round's per-client
                   finite-loss mask, the DivergenceError diagnostic.

    quorum (static; None | 'reject' | 'accept') compiles the quorum gate
    in-graph: xs gains a traced (R,) leaf 'quorum_min' (the round's
    minimum participant count) and ys a per-round 'rejected' flag
    (post-guard participation < quorum_min). Under 'reject' the xs also
    carry 'q_penalty' (R,) re-dispatch seconds: a rejected round's
    params/opt writes are masked out exactly like an invalid padded round
    (the model never sees it) while the PRNG key still advances (the
    compression keys were drawn — the per-round backends' stream does the
    same), and the in-graph 'T_round' gains the penalty. 'accept' only
    raises the flag. quorum=None builds a byte-identical graph to
    pre-quorum code — no extra ops, no extra xs leaves.

    sampled=True builds the K-cohort form of the chunk (sampled
    participation: n_clients = K lanes, each round occupied by a freshly
    gathered cohort of the M-client population). Lanes change owners
    every round, so the per-lane FedAvg weights and Eq. 4 compute times
    stop being chunk constants and ride in xs instead — two extra traced
    leaves 'weights' (R, K) and 't_cp' (R, K); callers pass the
    positional `weights`/`t_cp` chunk args as None. Everything else —
    masks, clocks, faults, envelope, compression keys (lane-indexed) — is
    unchanged, and at K = M (cohort == arange(M) every round) the xs rows
    equal the dense chunk constants, so the math is value-identical to
    the dense graph.

    aggregation='allreduce_shardmap' shards the client axis over `mesh`
    (forwarding mesh/param_specs_tree/client_axes to build_round_step):
    each device reduces its own client rows locally and one param-sized
    psum crosses the wire per round.
    """
    from repro.federated import compression

    if quorum not in (None, "reject", "accept"):
        raise ValueError(
            f"quorum must be None, 'reject' or 'accept', got {quorum!r}")
    if quorum is not None and not scenario:
        raise ValueError("quorum gating needs the scenario path "
                         "(participation masks) — scenario=True")
    step = build_round_step(loss_fn, opt, V, aggregation=aggregation,
                            mesh=mesh, param_specs_tree=param_specs_tree,
                            client_axes=client_axes,
                            impl=impl, envelope=envelope, guard=guard)
    compress = aggregation == "int8_stochastic"

    def chunk_step(params_C, opt_C, key, weights, t_cp, data, xs, env=None):
        bits = (env["update_bits"] if envelope
                else (None if update_bits is None
                      else jnp.float32(update_bits)))

        def body(carry, x):
            params, opt_state, k = carry
            w_r = x["weights"] if sampled else weights
            t_cp_r = x["t_cp"] if sampled else t_cp
            if batch_from is not None:
                batches = batch_from(data, x["idx"])
            else:
                batches = x["batches"]
            new_key, keys_C = k, None
            if compress:
                new_key, keys_C = compression.sequential_client_keys(
                    k, n_clients)
            if scenario:
                new_p, new_s, m = step(
                    params, opt_state, batches, w_r, keys=keys_C,
                    mask=x["mask"], clock_mask=x["clock_mask"],
                    t_cp=t_cp_r, t_cm=x["t_cm"], env=env)
                # Mean over participating clients; NaN on a zero-
                # participation round (same formula as the per-round
                # backends, for bit parity). With a guard, participation
                # is the post-sanitation mask.
                msk = m.get("mask_eff", x["mask"])
                n = jnp.sum(msk)
                loss = (jnp.sum(m["per_client_loss"] * msk)
                        / jnp.where(n > 0, n, 1.0))
                loss = jnp.where(n > 0, loss, jnp.nan)
                T_round = m["T_round"]
                if faults:
                    T_round = jnp.minimum(x["t_cap"], T_round)
                rejected = None
                if quorum is not None:
                    # Quorum gate on the POST-guard participation: below
                    # quorum raises the flag; 'reject' additionally pays
                    # the re-dispatch penalty in the in-graph clock (the
                    # host f64 twin mirrors it) and no-ops the state
                    # writes below.
                    rejected = n < x["quorum_min"]
                    if quorum == "reject":
                        T_round = T_round + jnp.where(
                            rejected, x["q_penalty"], 0.0)
                ys = {"loss": loss, "n_participants": n,
                      "T_cm": m["T_cm"], "T_cp": m["T_cp"],
                      "T_round": T_round}
                if rejected is not None:
                    ys["rejected"] = rejected
                if faults:
                    # Per-client finite-loss mask: the DivergenceError
                    # diagnostic (which clients were still finite on the
                    # offending round).
                    ys["finite"] = jnp.isfinite(m["per_client_loss"])
                if bits is not None:
                    ys["uplink_bits"] = (x["bits_mult"] * bits if faults
                                         else n * bits)
            else:
                rejected = None
                new_p, new_s, m = step(
                    params, opt_state, batches, w_r, keys=keys_C,
                    env=env)
                ys = {"loss": jnp.mean(m["per_client_loss"])}
                if bits is not None:
                    ys["uplink_bits"] = n_clients * bits
            valid = x["valid"]
            ok = valid
            if quorum == "reject":
                # A quorum-rejected round is the padded-round trick
                # applied in-graph: params/opt keep their pre-round
                # values. The PRNG key still advances (its compression
                # keys were drawn — the per-round backends consume the
                # stream identically), unlike a padded round's.
                ok = jnp.logical_and(valid, jnp.logical_not(rejected))
            keep = lambda nw, old: jnp.where(ok, nw, old.astype(nw.dtype))  # noqa: E731
            new_p = jax.tree.map(keep, new_p, params)
            new_s = jax.tree.map(keep, new_s, opt_state)
            new_key = jnp.where(valid, new_key, k)
            return (new_p, new_s, new_key), ys

        (params_C, opt_C, key), ys = jax.lax.scan(
            body, (params_C, opt_C, key), xs)
        return params_C, opt_C, key, ys

    return chunk_step


def build_async_chunk(
    loss_fn: Callable,
    opt: Optimizer,
    V: int,
    n_clients: int,
    spec,  # events.AsyncSpec — static policy (buffer size, staleness, mode)
    impl: str = "xla",
    batch_from: Callable = None,
    compress: bool = False,
):
    """Fuse a whole event-budget chunk of the asynchronous server into one
    `jax.lax.scan`: the scan axis is ARRIVAL EVENTS, not rounds, and the
    carry holds a device-side pending-update structure — a (C,) finish-time
    array whose argmin is the compiled analogue of a priority-queue pop.
    No Python event loop: E events cost one dispatch.

    Returns chunk_step(params_C, opt_C, key, async_c, sizes, data, xs)
    -> (params_C', opt_C', key', async_c', ys).

    async_c is the async carry dict (the extra SimState leaves):
      params_g   the server's global model (unstacked param tree)
      buf        staleness-weighted delta accumulator (f32 param tree)
      buf_w      f32 sum of accepted weights in the buffer
      cnt        int32 number of buffered updates
      loss_sum   f32 sum of accepted updates' local losses
      t_finish   (C,) f32 ABSOLUTE finish time of each client's in-flight
                 dispatch (the pending-update structure); +inf marks a
                 client blocked awaiting the aggregation ack
      t_next     (C,) f32 service time of the NEXT dispatch a blocked
                 client was handed (applied at its release)
      now        f32 event clock (arrival time of the last valid event)
      version    int32 server aggregation count
      version_C  (C,) int32 server version each client was dispatched at
      drop_C     (C,) f32 1.0 where the in-flight update will be lost
                 (participation mask / fault realization, resolved at
                 dispatch time)

    params_C/opt_C keep the synchronous layout — row c is the params/opt
    snapshot client c was DISPATCHED with (rows now differ between
    aggregations, unlike the sync backends' identical post-broadcast rows).

    xs leaves, every one stacked on a leading (E,) event axis:
      t_svc      (E, C) f32 service time (V t_cp + effective uplink) of the
                 dispatch HANDED OUT at this event, drawn M-wide per event
                 (prefix-stable stream consumption); only the arriving
                 client's column is consumed
      drop_next  (E, C) f32 loss indicator for that dispatch
      valid      (E,) padding flag — invalid events run but every state
                 write is masked out, exactly the sync chunk's ragged-tail
                 trick, so one trace serves every chunk of a run
      idx/batches  the ARRIVING client's V local batches — (E, V, B) int32
                 gather indices (device-resident data) or (E, V, ...)
                 pre-stacked host batches. The host knows who arrives at
                 each event ahead of dispatch via the f32 schedule twin
                 (events.twin_step): jnp.argmin == np.argmin (first-min
                 tie-break) over IEEE-identical f32 adds.

    Per event: pop c = argmin(t_finish); run c's V local steps from its
    dispatch snapshot; weight the delta by w = w_stale(version -
    version_C[c]) * sizes[c] (events.staleness_weight); a non-dropped
    update enters the buffer, and the K-th buffered update fires the
    aggregation params_g += buf / buf_w (mode='fedbuff' — a weighted mean
    of deltas, which in the sync limit K=M / uniform scenario equals
    FedAvg's weighted mean up to the delta-form association; see
    EXPERIMENTS.md §Asynchronous execution) or the immediate mixing
    params_g = (1 - lr w_stale) params_g + lr w_stale new_p
    (mode='fedasync', K=1). Re-dispatch is ACK-AT-AGGREGATION: an
    accepted update's client blocks until the aggregation that consumes
    its update, then re-dispatches from the fresh aggregate at the fill
    instant (finish time now + t_svc[e, c]); a dropped update's client
    re-dispatches immediately from the current global model. The K=M
    sync limit is therefore EXACTLY FedAvg's broadcast schedule.

    ys per event: t_event, client, dropped, agg (buffer filled here),
    loss_agg (mean buffered loss at a fill, NaN otherwise), staleness,
    version and cnt after the event — the event-aligned metrics the
    simulator turns into per-aggregation RoundRecords.
    """
    from repro.federated import compression, events as ev

    local = local_steps_fn(loss_fn, opt)
    K = int(spec.buffer_size)
    fedasync = spec.mode == "fedasync"

    def chunk_step(params_C, opt_C, key, async_c, sizes, data, xs):
        sizes_f32 = sizes.astype(jnp.float32)

        def body(carry, x):
            params_C, opt_C, k, a = carry
            valid = x["valid"]
            t_finish = a["t_finish"]
            # Priority-queue pop, compiled: earliest finisher arrives.
            # First-minimum tie-break == np.argmin, the twin contract.
            c = jnp.argmin(t_finish)
            now = t_finish[c]
            p_c = jax.tree.map(lambda t: t[c], params_C)
            s_c = jax.tree.map(lambda t: t[c], opt_C)
            if batch_from is not None:
                batches = batch_from(data, x["idx"])
            else:
                batches = x["batches"]
            new_p, new_s, loss = local(p_c, s_c, batches)
            delta = jax.tree.map(
                lambda n, o: n.astype(jnp.float32) - o.astype(jnp.float32),
                new_p, p_c)
            new_key = k
            if compress:
                # One quantizer key per event — the async twin of the sync
                # backends' per-round sequential_client_keys schedule.
                new_key, keys_1 = compression.sequential_client_keys(k, 1)
                delta = compression.decompress_update(
                    compression.compress_update(
                        delta, keys_1[0], impl=impl), impl=impl)
            drop = a["drop_C"][c]
            stale = (a["version"] - a["version_C"][c]).astype(jnp.float32)
            ws = ev.staleness_weight(spec, stale, xp=jnp)
            w = ws * sizes_f32[c]
            take = jnp.logical_and(valid, drop == 0)
            takef = take.astype(jnp.float32)
            onehot_c = jnp.arange(n_clients) == c
            # Buffer entry (exact +0.0 when dropped/invalid — the update
            # cannot perturb the aggregate's bits, same discipline as the
            # sync path's masked weighted sum).
            buf = jax.tree.map(lambda b, d: b + takef * (w * d),
                               a["buf"], delta)
            buf_w = a["buf_w"] + takef * w
            cnt = a["cnt"] + take.astype(jnp.int32)
            loss_sum = a["loss_sum"] + takef * loss
            fill = take if fedasync else jnp.logical_and(take, cnt >= K)
            if fedasync:
                am = jnp.where(fill, jnp.float32(spec.server_lr) * ws, 0.0)
                params_g = jax.tree.map(
                    lambda g, n: ((jnp.float32(1.0) - am)
                                  * g.astype(jnp.float32)
                                  + am * n.astype(jnp.float32)
                                  ).astype(g.dtype),
                    a["params_g"], new_p)
            else:
                denom = jnp.where(fill, buf_w, jnp.float32(1.0))
                params_g = jax.tree.map(
                    lambda g, b: jnp.where(
                        fill, g.astype(jnp.float32) + b / denom,
                        g.astype(jnp.float32)).astype(g.dtype),
                    a["params_g"], buf)
            version = a["version"] + fill.astype(jnp.int32)
            loss_agg = jnp.where(
                fill, loss_sum / jnp.maximum(cnt.astype(jnp.float32), 1.0),
                jnp.nan)
            # Aggregation drains the buffer.
            buf = jax.tree.map(
                lambda b: jnp.where(fill, jnp.zeros_like(b), b), buf)
            buf_w = jnp.where(fill, jnp.float32(0.0), buf_w)
            cnt = jnp.where(fill, jnp.int32(0), cnt)
            loss_sum = jnp.where(fill, jnp.float32(0.0), loss_sum)
            # Ack-at-aggregation re-dispatch (all writes valid-masked so
            # padded events are exact no-ops): an ACCEPTED update's client
            # blocks (finish time +inf) holding its next service draw, and
            # is released — re-dispatched FROM THE FRESH AGGREGATE at the
            # fill instant — by the aggregation that consumes its update
            # (the server's model broadcast is the ack). A DROPPED
            # update's client re-dispatches immediately from the current
            # global model (the server never saw it). This is what makes
            # the K=M sync limit EXACT: every generation starts from the
            # just-aggregated model, like FedAvg's broadcast (see
            # EXPERIMENTS.md §Asynchronous execution).
            t_next = jax.tree.map(
                lambda t: t.at[c].set(
                    jnp.where(take, x["t_svc"][c], t[c])), a["t_next"])
            t_fin = t_finish.at[c].set(jnp.where(
                valid,
                jnp.where(take, jnp.float32(jnp.inf),
                          now + x["t_svc"][c]),
                t_finish[c]))
            idle = jnp.isinf(t_fin)
            release = jnp.logical_and(fill, idle)  # includes c itself
            t_fin = jnp.where(release, now + t_next, t_fin)
            version_C = a["version_C"].at[c].set(
                jnp.where(valid, version, a["version_C"][c]))
            version_C = jnp.where(release, version, version_C)
            # Model binding: dropped -> rebind row c to the current global
            # now; released -> rebind every idle row to the fresh
            # aggregate. (fill == False on a drop, so params_g is the
            # right model in both cases.)
            bind = jnp.logical_or(
                release,
                jnp.logical_and(onehot_c,
                                jnp.logical_and(valid,
                                                jnp.logical_not(take))))
            params_C = jax.tree.map(
                lambda t, g: jnp.where(
                    bind.reshape((-1,) + (1,) * (t.ndim - 1)),
                    g.astype(t.dtype), t),
                params_C, params_g)
            opt_C = jax.tree.map(
                lambda t, n: t.at[c].set(
                    jnp.where(valid, n.astype(t.dtype), t[c])),
                opt_C, new_s)
            a2 = {
                "params_g": params_g,
                "buf": buf,
                "buf_w": buf_w,
                "cnt": cnt,
                "loss_sum": loss_sum,
                "t_finish": t_fin,
                "t_next": t_next,
                "now": jnp.where(valid, now, a["now"]),
                "version": version,
                "version_C": version_C,
                "drop_C": a["drop_C"].at[c].set(
                    jnp.where(valid, x["drop_next"][c], drop)),
            }
            ys = {"t_event": now, "client": c.astype(jnp.int32),
                  "dropped": drop, "agg": fill, "loss_agg": loss_agg,
                  "staleness": jnp.where(take, stale, 0.0),
                  "version": version, "cnt": cnt}
            return (params_C, opt_C, jnp.where(valid, new_key, k), a2), ys

        (params_C, opt_C, key, async_c), ys = jax.lax.scan(
            body, (params_C, opt_C, key, async_c), xs)
        return params_C, opt_C, key, async_c, ys

    return chunk_step


def build_fleet_chunk(chunk_step: Callable, envelope: bool = False,
                      sampled: bool = False) -> Callable:
    """vmap a `build_round_chunk` step over a leading fleet axis S.

    The chunk step is pure and closure-free over run state (everything it
    touches rides in as arguments), so a whole fleet — S seeds, or S arms
    sharing one (model, b, V, M) shape signature — executes as ONE
    dispatch per chunk instead of S sequential chunk calls:

      carry (params_C, opt_C, key)  (S, C, ...) / (S, 2)   mapped, axis 0
      weights, data                 shared, broadcast (in_axes=None) —
                                    one population / one device-resident
                                    dataset upload serves the whole fleet
      t_cp                          shared when all members run one batch
                                    size; per-member (mapped axis 0) under
                                    envelope=True, where b varies by arm
      xs                            every leaf (S, R, ...), mapped axis 0
      env (envelope=True only)      per-member (V, b) masks, mapped axis 0

    ys leaves come back stacked (S, R). Per-member math is exactly the
    single-chunk graph batched over S (vmap is a compile-time transform,
    not a loop), which is what makes the per-seed results bit-identical to
    sequential runs — asserted in tests/test_experiment_api.py (seeds) and
    tests/test_study.py (mixed-(b, V) arm groups).

    sampled=True (cohort chunks): per-round weights/t_cp live in xs
    (mapped, per-member cohorts differ) and the positional weights/t_cp
    args are None, so their in_axes must be None even under envelope.
    """
    if envelope:
        t_axis = None if sampled else 0
        return jax.vmap(chunk_step,
                        in_axes=(0, 0, 0, None, t_axis, None, 0, 0))
    return jax.vmap(chunk_step, in_axes=(0, 0, 0, None, None, None, 0))


def replicate_clients(tree: Any, n_clients: int) -> Any:
    """Stack identical client copies on a new leading axis."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_clients, *x.shape)), tree)
