"""Event-driven asynchronous FL: policy spec, staleness weights, and the
host-side f32 schedule twin of the compiled event queue.

The async backend (simulation.Simulator(backend="async")) replaces the
synchronous round barrier with a device-side event queue: every client
carries a finish time, the server repeatedly extracts the EARLIEST
finisher (argmin over a (C,) float32 array — the compiled analogue of a
priority-queue pop), applies its update to a staleness-weighted buffer,
and re-dispatches the client from the current global model. The Eq. 8
round clock becomes a true event clock: server time is the arrival time
of the update that fills the buffer (FedBuff, arXiv 2106.06639 via the
delayed-aggregation lens of arXiv 2008.09323 / 2112.13926), not the
straggler max.

Everything scan-shaped lives in mesh_rounds.build_async_chunk; this
module holds what the host needs:

  AsyncSpec        the aggregation policy (buffer size, staleness
                   weighting, fedbuff vs fedasync server update).
  staleness_weight the weight function, usable on jnp traced values and
                   np.float32 host values alike.
  ScheduleTwin     a numpy float32 replay of the in-graph scheduling ops
                   (argmin pop, finish-time writes, buffer counting).
                   jnp.argmin and np.argmin share first-minimum
                   tie-breaking, and IEEE f32 arithmetic is deterministic,
                   so the twin predicts EXACTLY which client arrives at
                   each event and which events aggregate — the simulator
                   driver uses it to size chunks (stop a chunk at an
                   aggregation boundary) and to stack per-event batch
                   inputs for only the arriving client.
  reference_run    a slow, obviously-correct Python event-loop executor
                   over pure host functions — the parity oracle for
                   tests/test_async_events.py.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

STALENESS_MODES = ("constant", "poly", "exp")
ASYNC_MODES = ("fedbuff", "fedasync")


@dataclasses.dataclass(frozen=True)
class AsyncSpec:
    """Buffered asynchronous aggregation policy.

    buffer_size   K — the server aggregates once K accepted updates sit in
                  the buffer. K = M with staleness='constant' on a uniform
                  scenario degenerates to synchronous FedAvg (the
                  sync-limit identity contract, EXPERIMENTS.md
                  §Asynchronous execution).
    staleness     per-update weight from the update's staleness s =
                  server_version_now - server_version_at_dispatch:
                    'constant' w(s) = 1
                    'poly'     w(s) = (1 + s)^(-a)
                    'exp'      w(s) = exp(-a s)
    staleness_a   the decay constant a above (ignored for 'constant').
    mode          'fedbuff': params += sum_i w_i sizes_i delta_i /
                  sum_i w_i sizes_i once the buffer fills (weighted mean
                  of deltas — reduces to FedAvg in the sync limit).
                  'fedasync': immediate mixing params = (1 - lr w) params
                  + lr w new_params per update (requires buffer_size=1).
    server_lr     fedasync mixing rate (alpha in arXiv 1903.03934).
    event_budget  static per-chunk scan length E (number of arrival
                  events per compiled chunk). None -> the simulator picks
                  8 * max(C, buffer_size). Larger E amortizes dispatch
                  overhead; every chunk pads to E, so oversized budgets
                  waste padded events, never correctness.
    """

    buffer_size: int = 1
    staleness: str = "constant"
    staleness_a: float = 0.5
    mode: str = "fedbuff"
    server_lr: float = 1.0
    event_budget: Optional[int] = None

    def __post_init__(self):
        if self.mode not in ASYNC_MODES:
            raise ValueError(
                f"AsyncSpec.mode must be one of {ASYNC_MODES}, "
                f"got {self.mode!r}")
        if self.staleness not in STALENESS_MODES:
            raise ValueError(
                f"AsyncSpec.staleness must be one of {STALENESS_MODES}, "
                f"got {self.staleness!r}")
        if self.buffer_size < 1:
            raise ValueError(
                f"AsyncSpec.buffer_size must be >= 1, got {self.buffer_size}")
        if self.mode == "fedasync" and self.buffer_size != 1:
            raise ValueError(
                "AsyncSpec(mode='fedasync') aggregates every update "
                f"immediately — buffer_size must be 1, got {self.buffer_size}")
        if self.server_lr <= 0:
            raise ValueError(
                f"AsyncSpec.server_lr must be > 0, got {self.server_lr}")
        if self.event_budget is not None and self.event_budget < 1:
            raise ValueError(
                f"AsyncSpec.event_budget must be >= 1, "
                f"got {self.event_budget}")

    def replace(self, **kw) -> "AsyncSpec":
        return dataclasses.replace(self, **kw)


def staleness_weight(spec: AsyncSpec, s, xp=np):
    """w(s) for staleness s (int or array), on numpy (host twin) or
    jax.numpy (in-graph) via `xp`. Returns xp float32."""
    s = xp.asarray(s, xp.float32)
    if spec.staleness == "constant":
        return xp.ones_like(s)
    a = xp.float32(spec.staleness_a)
    if spec.staleness == "poly":
        return (xp.float32(1.0) + s) ** (-a)
    return xp.exp(-a * s)


@dataclasses.dataclass
class TwinState:
    """Host mirror of the scheduling slice of the device carry — ONLY the
    f32/int fields that decide which client pops next and which events
    aggregate. No params. np.float32 throughout so every add matches the
    in-graph f32 op bit for bit."""

    t_finish: np.ndarray     # (C,) f32 absolute finish times (+inf = blocked)
    t_next: np.ndarray       # (C,) f32 next service time of blocked clients
    drop: np.ndarray         # (C,) f32 1.0 = this dispatch will be dropped
    version: int             # server aggregation count
    version_disp: np.ndarray  # (C,) int32 server version at dispatch
    cnt: int                 # updates in the buffer
    now: np.float32          # event clock (arrival time of last event)
    # f64 bookkeeping for records (NOT part of the f32 schedule):
    t_cm_disp: np.ndarray    # (C,) f64 uplink seconds at dispatch
    attempts_disp: np.ndarray  # (C,) f64 uplink attempt count at dispatch

    def copy(self) -> "TwinState":
        return TwinState(
            self.t_finish.copy(), self.t_next.copy(), self.drop.copy(),
            self.version, self.version_disp.copy(), self.cnt, self.now,
            self.t_cm_disp.copy(), self.attempts_disp.copy())


@dataclasses.dataclass(frozen=True)
class TwinEvent:
    """One arrival event as the twin predicts it."""

    client: int          # arriving client index (argmin pop)
    t_event: np.float32  # arrival time (f32 event clock)
    dropped: bool        # update lost (scenario mask / fault realization)
    aggregated: bool     # this arrival filled the buffer
    staleness: int       # version - version_disp[client] at arrival
    # service components of the dispatch that JUST COMPLETED (what the
    # arriving update actually paid — consumed for RoundRecord.T_cm):
    t_cm_done: float
    attempts_done: float
    # dispatch-time service components of the NEXT task handed to the
    # client (consumed by the simulator when building records):
    t_cm_next: float
    attempts_next: float


def twin_init(t_finish0: np.ndarray, drop0: np.ndarray,
              t_cm0: np.ndarray, attempts0: np.ndarray) -> TwinState:
    """Fresh twin from the initial dispatch realization (all clients
    handed version-0 work at t=0)."""
    C = t_finish0.shape[0]
    return TwinState(
        t_finish=np.asarray(t_finish0, np.float32).copy(),
        t_next=np.zeros(C, np.float32),
        drop=np.asarray(drop0, np.float32).copy(),
        version=0,
        version_disp=np.zeros(C, np.int32),
        cnt=0,
        now=np.float32(0.0),
        t_cm_disp=np.asarray(t_cm0, np.float64).copy(),
        attempts_disp=np.asarray(attempts0, np.float64).copy())


def twin_step(spec: AsyncSpec, tw: TwinState, t_svc: np.ndarray,
              drop_next: np.ndarray, t_cm_next: np.ndarray,
              attempts_next: np.ndarray) -> TwinEvent:
    """Advance the twin by ONE arrival event, mutating tw in place.

    t_svc (C,) f32 — the service time (V t_cp + t_cm) the arriving client
    would get for its NEXT dispatch; only t_svc[c] is consumed, but the
    realization is drawn M-wide per event (prefix-stable stream
    consumption, mirroring the sync chunk's per-round draws).
    drop_next (C,) f32 — 1.0 where the next dispatch's update will be
    dropped (participation mask / fault realization, resolved at
    dispatch time exactly like the in-graph xs row).

    The arithmetic here replays mesh_rounds.build_async_chunk's scheduling
    ops verbatim in np.float32: argmin (first minimum), now = t_finish[c],
    drop re-dispatch t_finish[c] = now + t_svc[c], and the
    ack-at-aggregation release np.where(isinf(t_finish), now + t_next,
    t_finish). Both sides are IEEE f32, so the replay is exact — asserted
    per chunk against the scan ys in the simulator.
    """
    c = int(np.argmin(tw.t_finish))
    now = tw.t_finish[c]
    dropped = bool(tw.drop[c] > 0)
    s = tw.version - int(tw.version_disp[c])
    t_cm_done = float(tw.t_cm_disp[c])
    attempts_done = float(tw.attempts_disp[c])
    aggregated = False
    if dropped:
        # Lost update: immediate re-dispatch from the current model.
        tw.t_finish[c] = np.float32(now) + np.float32(t_svc[c])
    else:
        # Accepted update: block until the consuming aggregation acks.
        tw.cnt += 1
        tw.t_next[c] = np.float32(t_svc[c])
        tw.t_finish[c] = np.float32(np.inf)
        if spec.buffer_size == 1 or tw.cnt >= spec.buffer_size:
            aggregated = True
            tw.version += 1
            tw.cnt = 0
    tw.now = np.float32(now)
    tw.version_disp[c] = tw.version
    if aggregated:
        # Release every blocked client (including c) from the fresh
        # aggregate at the fill instant.
        idle = np.isinf(tw.t_finish)
        tw.t_finish = np.where(
            idle, np.float32(now) + tw.t_next,
            tw.t_finish).astype(np.float32)
        tw.version_disp = np.where(
            idle, np.int32(tw.version),
            tw.version_disp).astype(np.int32)
    tw.drop[c] = np.float32(drop_next[c])
    tw.t_cm_disp[c] = float(t_cm_next[c])
    tw.attempts_disp[c] = float(attempts_next[c])
    return TwinEvent(client=c, t_event=np.float32(now), dropped=dropped,
                     aggregated=aggregated, staleness=s,
                     t_cm_done=t_cm_done, attempts_done=attempts_done,
                     t_cm_next=float(t_cm_next[c]),
                     attempts_next=float(attempts_next[c]))


def reference_run(
    spec: AsyncSpec,
    n_events: int,
    init_params,
    init_opt,
    local_update: Callable,
    next_batches: Callable,
    sizes: np.ndarray,
    draw_dispatch: Callable,
):
    """Slow, obviously-correct Python event-loop executor — the parity
    oracle for the compiled scan path (tests/test_async_events.py).

    local_update(params, opt_state, batches) -> (params', opt_state',
    mean_loss) runs one client's V local steps (host-side, e.g. the
    jitted mesh_rounds.local_steps_fn on unstacked leaves).
    next_batches(client) yields that client's next V-batch stack —
    clients' data iterators advance ONLY when that client is dispatched,
    in arrival order (matching the twin-ordered xs the simulator stacks).
    draw_dispatch() -> (t_svc (C,) f32, drop (C,) f32) draws one M-wide
    dispatch realization; called once for the initial dispatch and once
    per event, in that order (the simulator's stream consumption
    contract).

    Returns (params, events) where events is a list of dicts with the
    per-event fields (client, t_event, dropped, aggregated, staleness,
    weight) — enough to check every queue invariant.
    """
    import jax

    t_svc0, drop0 = draw_dispatch()
    C = t_svc0.shape[0]
    tw = twin_init(t_svc0, drop0, np.zeros(C), np.zeros(C))
    params_g = init_params
    client_params = [init_params] * C
    client_opt = [init_opt] * C
    client_batches = [next_batches(c) for c in range(C)]
    buf = None
    buf_w = np.float32(0.0)
    sizes = np.asarray(sizes, np.float32)
    pending: set = set()  # clients blocked awaiting the aggregation ack
    events = []
    for _ in range(n_events):
        t_svc, drop_next = draw_dispatch()
        c = int(np.argmin(tw.t_finish))
        s = tw.version - int(tw.version_disp[c])
        # Run the client's local work (it was dispatched earlier with the
        # params snapshot held in client_params[c]).
        new_p, _, _ = local_update(
            client_params[c], client_opt[c], client_batches[c])
        delta = jax.tree.map(
            lambda n, p: np.asarray(n, np.float32) - np.asarray(p, np.float32),
            new_p, client_params[c])
        ev = twin_step(spec, tw, t_svc, drop_next,
                       np.zeros(C), np.zeros(C))
        assert ev.client == c and ev.staleness == s
        w = np.float32(staleness_weight(spec, s)) * sizes[c]
        if not ev.dropped:
            if spec.mode == "fedasync":
                ws = np.float32(staleness_weight(spec, s))
                a = np.float32(spec.server_lr) * ws
                params_g = jax.tree.map(
                    lambda g, n: (np.float32(1.0) - a)
                    * np.asarray(g, np.float32)
                    + a * np.asarray(n, np.float32), params_g, new_p)
            else:
                contrib = jax.tree.map(lambda d: w * d, delta)
                buf = contrib if buf is None else jax.tree.map(
                    lambda b, x: b + x, buf, contrib)
                buf_w = buf_w + w
                if ev.aggregated:
                    params_g = jax.tree.map(
                        lambda g, b: np.asarray(g, np.float32) + b / buf_w,
                        params_g, buf)
                    buf, buf_w = None, np.float32(0.0)
        events.append({"client": c, "t_event": float(ev.t_event),
                       "dropped": ev.dropped, "aggregated": ev.aggregated,
                       "staleness": s, "weight": float(w)})
        # Ack-at-aggregation re-dispatch: a dropped client restarts from
        # the current model immediately; an accepted client blocks until
        # the aggregation that consumes its update rebinds it (and every
        # other blocked client) to the fresh aggregate.
        if ev.dropped:
            client_params[c] = params_g
        else:
            pending.add(c)
        if ev.aggregated:
            for i in pending:
                client_params[i] = params_g
            pending.clear()
        client_batches[c] = next_batches(c)
    return params_g, events
