"""Pallas TPU kernel: rowwise int8 stochastic-rounding quantization.

Used by the federated 'talk' compression (DESIGN.md §6): each client's
update rows are scaled to int8 with an unbiased stochastic round before
the uplink/all-gather. Grid tiles rows into VMEM blocks; randomness comes
in as a pre-drawn uniform tile (keeps the kernel deterministic w.r.t. the
caller's PRNG and identical between interpret and compiled modes).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, u_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)  # (block_r, D)
    u = u_ref[...].astype(jnp.float32)
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.floor(x / scale + u)
    q_ref[...] = jnp.clip(q, -127, 127).astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


def quantize_kernel(
    x: jnp.ndarray,  # (R, D) fp32
    uniform: jnp.ndarray,  # (R, D) fp32 in [0, 1)
    *,
    block_r: int = 256,
    interpret: bool = True,
):
    R, D = x.shape
    assert R % block_r == 0
    grid = (R // block_r,)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, D), lambda r: (r, 0)),
            pl.BlockSpec((block_r, D), lambda r: (r, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_r, D), lambda r: (r, 0)),
            pl.BlockSpec((block_r, 1), lambda r: (r, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, D), jnp.int8),
            jax.ShapeDtypeStruct((R, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x, uniform)
