"""Pure-jnp oracle for int8 stochastic-rounding quantization.

Contract (shared with the Pallas kernel):

  q, scale = quantize(x, key)     x: (..., d) fp32 -> q int8, scale fp32 per row
  x_hat    = dequantize(q, scale)

Stochastic rounding makes the quantizer unbiased: E[x_hat] = x, which is
what lets FedAvg aggregate compressed updates without systematic drift
(the 'talk' compression of DESIGN.md §6).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_ref(x: jnp.ndarray, key) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Rowwise symmetric int8 quantization with stochastic rounding.

    x: (R, D) fp32. Returns (q int8 (R, D), scale fp32 (R, 1))."""
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    y = x / scale
    noise = jax.random.uniform(key, x.shape, jnp.float32)
    q = jnp.floor(y + noise)
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_ref(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale
