"""Pure-jnp oracle for int8 stochastic-rounding quantization.

Contract (shared with the Pallas kernel):

  q, scale = quantize(x, key)     x: (..., d) fp32 -> q int8, scale fp32 per row
  x_hat    = dequantize(q, scale)

Stochastic rounding makes the quantizer unbiased: E[x_hat] = x, which is
what lets FedAvg aggregate compressed updates without systematic drift
(the 'talk' compression of DESIGN.md §6).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def stochastic_noise(key, shape) -> jnp.ndarray:
    """Rounding noise in [0, 1) at 8-bit resolution from packed PRNG words.

    Stochastic rounding only needs enough resolution to keep the rounding
    bias far below one int8 step: 8 bits bounds the deterministic bias at
    2^-8 of a step, while drawing 4x fewer threefry words than
    jax.random.uniform. The quantizer is bandwidth/PRNG-bound (it runs over
    every model parameter per client per round in the FL simulator), so
    this roughly halves its cost. Shared by quantize_ref and the Pallas
    ops wrapper so both impls stay bit-identical for a given key."""
    n = 1
    for d in shape:
        n *= int(d)
    words = jax.random.bits(key, ((n + 3) // 4,), jnp.uint32)
    b = jax.lax.bitcast_convert_type(words, jnp.uint8).reshape(-1)[:n]
    # +0.5 centers the grid: mean is exactly 1/2 (unbiased rounding) and no
    # noise value is exactly 0, which would put floor(y + u) on an integer
    # boundary whenever y is — where fused vs op-by-op fp32 evaluation of
    # x/scale can legitimately differ by an ulp and flip the bucket.
    return (b.astype(jnp.float32) + 0.5).reshape(shape) * (1.0 / 256.0)


def quantize_ref(x: jnp.ndarray, key) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Rowwise symmetric int8 quantization with stochastic rounding.

    x: (R, D) fp32. Returns (q int8 (R, D), scale fp32 (R, 1))."""
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    y = x / scale
    noise = stochastic_noise(key, x.shape)
    q = jnp.floor(y + noise)
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_ref(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale
