"""jit'd wrapper for the quantize kernel (row padding + PRNG handling)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.quantize.kernel import quantize_kernel
from repro.kernels.quantize.ref import dequantize_ref, stochastic_noise


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def quantize(x: jnp.ndarray, key, block_r: int = 256) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (R, D) fp32 -> (q int8 (R, D), scale (R, 1))."""
    R, D = x.shape
    # Same packed-8-bit noise stream as quantize_ref: given the same key the
    # two impls stay bit-identical (tests/test_kernels_quantize.py).
    u = stochastic_noise(key, (R, D))
    pad = (-R) % block_r
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        u = jnp.pad(u, ((0, pad), (0, 0)))
    q, s = quantize_kernel(x, u, block_r=min(block_r, x.shape[0]),
                           interpret=not _is_tpu())
    return q[:R], s[:R]


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return dequantize_ref(q, scale)
