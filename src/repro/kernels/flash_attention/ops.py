"""jit'd public wrapper for the flash-attention kernel.

Handles GQA head grouping, (B, S, H, hd) <-> (BH, S, hd) reshapes, block
padding, and backend selection (interpret mode on CPU; compiled Pallas on
TPU). The backward pass falls back to the reference implementation via
custom_vjp (forward speed is what the serving/prefill path needs; training
uses the XLA path by default).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import attention_ref


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash_bh(q, k, v, causal, window, block):
    block_q, block_k = block
    Sq = q.shape[1]
    pad_q = (-Sq) % block_q
    pad_k = (-k.shape[1]) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))
    out = flash_attention_kernel(
        qp, kp, vp, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=not _is_tpu())
    return out[:, :Sq]


def _flash_bh_fwd(q, k, v, causal, window, block):
    return _flash_bh(q, k, v, causal, window, block), (q, k, v)


def _flash_bh_bwd(causal, window, block, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: attention_ref(q_, k_, v_, causal, window), q, k, v)
    return vjp(g)


_flash_bh.defvjp(_flash_bh_fwd, _flash_bh_bwd)


def flash_attention(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Sk, KV, hd)
    v: jnp.ndarray,  # (B, Sk, KV, hd)
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
) -> jnp.ndarray:
    """GQA flash attention. Returns (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    # (B, S, H, hd) -> (B*H, S, hd) with KV heads repeated per group.
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kt = jnp.repeat(k.transpose(0, 2, 1, 3), rep, axis=1).reshape(B * H, -1, hd)
    vt = jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1).reshape(B * H, -1, hd)
    out = _flash_bh(qt, kt, vt, causal, window, (block_q, block_k))
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
