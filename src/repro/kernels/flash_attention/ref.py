"""Pure-jnp oracle for flash attention (same contract as kernel.py)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jnp.ndarray,  # (BH, Sq, hd)
    k: jnp.ndarray,  # (BH, Sk, hd)
    v: jnp.ndarray,  # (BH, Sk, hd)
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    Sq, Sk = q.shape[1], k.shape[1]
    hd = q.shape[-1]
    s = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (hd ** -0.5)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask = mask & (k_pos[None, :] <= q_pos[:, None])
    if window is not None:
        mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
    s = jnp.where(mask[None], s, NEG_INF)
    # Fully-masked rows -> zeros (matches kernel semantics).
    row_valid = mask.any(axis=1)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(row_valid[None, :, None], p, 0.0)
    return jnp.einsum("bqk,bkh->bqh", p, v.astype(jnp.float32)).astype(q.dtype)
