"""Pallas TPU flash-attention kernel (causal GQA, online softmax).

TPU mapping: grid = (batch*kv_heads*q_rep, num_q_blocks); each program
streams K/V blocks for one query tile through VMEM, maintaining the
running (max, sum, accumulator) online-softmax state in VMEM scratch.
Block sizes default to (128, 128) — MXU-aligned on the (8,128)/(128,128)
tiling of v5e. Sliding-window masking folds into the same block loop by
skipping blocks wholly outside the window.

Validated on CPU via interpret=True against ref.py (tests/test_kernels_flash.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref,
    *, block_q: int, block_k: int, seq_k: int, causal: bool,
    window: Optional[int], q_offset_blocks: int,
):
    """One (q-tile x full-K loop) program.

    q_ref: (block_q, hd); k_ref/v_ref: (seq_k, hd); o_ref: (block_q, hd).
    """
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32)
    hd = q.shape[-1]
    scale = hd ** -0.5
    q_pos = (qi + q_offset_blocks) * block_q + jax.lax.iota(
        jnp.int32, block_q)  # absolute query positions

    n_kb = seq_k // block_k

    def body(kb, carry):
        m_prev, l_prev, acc = carry
        k_blk = pl.load(k_ref, (pl.ds(kb * block_k, block_k), slice(None)))
        v_blk = pl.load(v_ref, (pl.ds(kb * block_k, block_k), slice(None)))
        s = (q @ k_blk.astype(jnp.float32).T) * scale  # (bq, bk)
        k_pos = kb * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if window is not None:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask, s, NEG_INF)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_cur[:, None])
        alpha = jnp.exp(m_prev - m_cur)
        l_cur = alpha * l_prev + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + p @ v_blk.astype(jnp.float32)
        return m_cur, l_cur, acc

    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_kb, body, (m0, l0, acc0))
    # Rows with no valid key (fully masked) keep l=0; emit zeros there.
    safe_l = jnp.where(l > 0, l, 1.0)
    o_ref[...] = (acc / safe_l[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jnp.ndarray,  # (BH, Sq, hd) — batch*heads flattened
    k: jnp.ndarray,  # (BH, Sk, hd)
    v: jnp.ndarray,  # (BH, Sk, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    q_offset: int = 0,
    interpret: bool = True,
) -> jnp.ndarray:
    """Lowers one pallas_call. Sq % block_q == 0 and Sk % block_k == 0
    (ops.py pads); q_offset supports q positions starting mid-sequence."""
    BH, Sq, hd = q.shape
    Sk = k.shape[1]
    assert Sq % block_q == 0 and Sk % block_k == 0
    assert q_offset % block_q == 0
    grid = (BH, Sq // block_q)
    kernel = functools.partial(
        _attn_kernel, block_q=block_q, block_k=block_k, seq_k=Sk,
        causal=causal, window=window, q_offset_blocks=q_offset // block_q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, Sk, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, Sk, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
