"""Pure-jnp oracle for the Mamba-1 selective scan (chunked parallel form).

Contract (shared with the Pallas kernel in kernel.py):

  y, h_final = selective_scan(x, dt, A, B, C, D, chunk, h0)

  x  : (B, S, D)  fp32   post-conv activations
  dt : (B, S, D)  fp32   softplus'd step sizes
  A  : (D, N)     fp32   negative-real state matrix (diag)
  B  : (B, S, N)  fp32   input projection
  C  : (B, S, N)  fp32   output projection
  D  : (D,)       fp32   skip
  h0 : (B, D, N)  fp32   initial state (None = zeros)

Recurrence: h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t
            y_t = (h_t · C_t) + D * x_t
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def _assoc_op(left, right):
    a1, b1 = left
    a2, b2 = right
    return a1 * a2, b1 * a2 + b2


def selective_scan_ref(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    B: jnp.ndarray,
    C: jnp.ndarray,
    D: jnp.ndarray,
    chunk: int = 128,
    h0: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    Bsz, S, Dm = x.shape
    N = A.shape[1]
    L = min(chunk, S)
    pad = (-S) % L
    if pad:
        # Zero-pad the tail: dt=0 => decay=1 and input=0, so the state is
        # carried through padding unchanged and padded outputs are dropped.
        x, dt, B, C = (jnp.pad(t, ((0, 0), (0, pad), (0, 0))) for t in (x, dt, B, C))
        y, h = selective_scan_ref(x, dt, A, B, C, D, chunk=chunk, h0=h0)
        return y[:, :S], h
    nc = S // L
    if h0 is None:
        h0 = jnp.zeros((Bsz, Dm, N), jnp.float32)

    # Reshape to (nc, B, L, ...) for lax.scan over chunks.
    def to_chunks(t):
        return jnp.swapaxes(t.reshape(Bsz, nc, L, *t.shape[2:]), 0, 1)

    xs = (to_chunks(x), to_chunks(dt), to_chunks(B), to_chunks(C))

    def chunk_step(h, inp):
        xc, dtc, Bc, Cc = inp  # (B, L, ...)
        dA = jnp.exp(dtc[..., None] * A[None, None])  # (B, L, D, N)
        dBx = (dtc * xc)[..., None] * Bc[:, :, None, :]  # (B, L, D, N)
        a_cum, b_cum = jax.lax.associative_scan(_assoc_op, (dA, dBx), axis=1)
        hs = a_cum * h[:, None] + b_cum  # (B, L, D, N)
        yc = jnp.einsum("bldn,bln->bld", hs, Cc)
        return hs[:, -1], yc

    h_final, ys = jax.lax.scan(chunk_step, h0, xs)
    y = jnp.swapaxes(ys, 0, 1).reshape(Bsz, S, Dm)
    return y + D[None, None] * x, h_final


def selective_scan_sequential(
    x, dt, A, B, C, D, h0=None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Step-by-step scan — the ground-truth oracle for the chunked forms."""
    Bsz, S, Dm = x.shape
    N = A.shape[1]
    if h0 is None:
        h0 = jnp.zeros((Bsz, Dm, N), jnp.float32)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp
        dA = jnp.exp(dtt[..., None] * A[None])
        h = dA * h + (dtt * xt)[..., None] * Bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, Ct)
        return h, y

    xs = tuple(jnp.swapaxes(t, 0, 1) for t in (x, dt, B, C))
    h_final, ys = jax.lax.scan(step, h0, xs)
    return jnp.swapaxes(ys, 0, 1) + D[None, None] * x, h_final
