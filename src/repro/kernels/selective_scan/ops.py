"""jit'd public wrapper for the selective-scan kernel: block-size choice,
d_inner padding, h0 fast-path, and interpret-mode selection on CPU."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.selective_scan.kernel import selective_scan_kernel
from repro.kernels.selective_scan.ref import selective_scan_ref


def _is_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pick_block_d(Dm: int) -> int:
    for bd in (512, 256, 128):
        if Dm % bd == 0:
            return bd
    return Dm


def selective_scan(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    B: jnp.ndarray,
    C: jnp.ndarray,
    D: jnp.ndarray,
    chunk: int = 128,
    h0: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Same contract as ref.selective_scan_ref."""
    if h0 is not None:
        # Kernel carries state from zeros; nonzero h0 (rare: chunked prefill
        # resume) falls back to the reference path.
        return selective_scan_ref(x, dt, A, B, C, D, chunk=chunk, h0=h0)
    Bsz, S, Dm = x.shape
    L = min(chunk, S)
    pad_s = (-S) % L
    if pad_s:
        x, dt = (jnp.pad(t, ((0, 0), (0, pad_s), (0, 0))) for t in (x, dt))
        B, C = (jnp.pad(t, ((0, 0), (0, pad_s), (0, 0))) for t in (B, C))
    y, h = selective_scan_kernel(
        x, dt, A, B, C, D, chunk=L, block_d=_pick_block_d(Dm),
        interpret=not _is_tpu())
    return y[:, :S], h
