"""Pallas TPU kernel for the Mamba-1 selective scan.

TPU mapping (DESIGN.md §6): grid = (batch, d_inner/block_d, n_chunks).
The TPU executes the grid sequentially (last axis fastest), so the SSM
state h (block_d, N) lives in VMEM scratch and is carried across the
chunk axis — an explicit realization of the chunked-scan recurrence with
only (1, L, block_d) tiles of x/dt and (1, L, N) tiles of B/C resident in
VMEM per step. Inside a chunk the recurrence runs as a fori_loop over L
steps of (block_d, N) VPU element-wise ops.

Validated interpret=True against ref.selective_scan_ref / _sequential
(tests/test_kernels_scan.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref,
                 y_ref, h_ref, h_scratch, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    x = x_ref[0].astype(jnp.float32)  # (L, bd)
    dt = dt_ref[0].astype(jnp.float32)  # (L, bd)
    A = a_ref[...].astype(jnp.float32)  # (bd, N)
    Bc = b_ref[0].astype(jnp.float32)  # (L, N)
    Cc = c_ref[0].astype(jnp.float32)  # (L, N)
    D = d_ref[...].astype(jnp.float32)  # (bd,)

    def step(t, carry):
        h, y = carry
        dA = jnp.exp(dt[t][:, None] * A)  # (bd, N)
        h = dA * h + (dt[t] * x[t])[:, None] * Bc[t][None, :]
        y = y.at[t].set(jnp.sum(h * Cc[t][None, :], axis=1))
        return h, y

    h0 = h_scratch[...]
    y0 = jnp.zeros((chunk, x.shape[1]), jnp.float32)
    h, y = jax.lax.fori_loop(0, chunk, step, (h0, y0))
    h_scratch[...] = h
    y_ref[0] = (y + D[None, :] * x).astype(y_ref.dtype)
    h_ref[0] = h.astype(h_ref.dtype)


def selective_scan_kernel(
    x: jnp.ndarray,  # (B, S, D) fp32
    dt: jnp.ndarray,  # (B, S, D)
    A: jnp.ndarray,  # (D, N)
    B: jnp.ndarray,  # (B, S, N)
    C: jnp.ndarray,  # (B, S, N)
    D: jnp.ndarray,  # (D,)
    *,
    chunk: int = 128,
    block_d: int = 512,
    interpret: bool = True,
):
    """Returns (y (B,S,D), h_final (B,D,N)). S % chunk == 0, D % block_d == 0
    (ops.py pads/chooses blocks)."""
    Bsz, S, Dm = x.shape
    N = A.shape[1]
    assert S % chunk == 0 and Dm % block_d == 0
    nc = S // chunk
    nd = Dm // block_d
    grid = (Bsz, nd, nc)
    kernel = functools.partial(_scan_kernel, chunk=chunk)
    y, h = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((block_d, N), lambda b, d, c: (d, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),
            pl.BlockSpec((block_d,), lambda b, d, c: (d,)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            # h written every chunk; the last write (final state) survives.
            pl.BlockSpec((1, block_d, N), lambda b, d, c: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, S, Dm), x.dtype),
            jax.ShapeDtypeStruct((Bsz, Dm, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C, D)
    return y, h
