from repro.optim.adam import adamw
from repro.optim.api import Optimizer, apply_updates
from repro.optim.sgd import sgd
