"""Minimal optax-style optimizer API (built from scratch; optax is not a
dependency of this framework)."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]  # (grads, state, params)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)
