"""SGD (+momentum) — the paper's local optimizer (mini-batch SGD, lr=0.01)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.api import Optimizer


def sgd(lr: float, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return ()
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, state, params=None):
        del params
        if momentum == 0.0:
            return jax.tree.map(lambda g: -lr * g, grads), state
        new_state = jax.tree.map(lambda m, g: momentum * m + g, state, grads)
        return jax.tree.map(lambda m: -lr * m, new_state), new_state

    return Optimizer(init=init, update=update)
