"""AdamW from scratch (used for the LLM-architecture federated configs)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.api import Optimizer


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: object
    nu: object


def adamw(
    lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
    weight_decay: float = 0.0,
) -> Optimizer:
    def init(params):
        return AdamState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(jnp.zeros_like, params),
            nu=jax.tree.map(jnp.zeros_like, params),
        )

    def update(grads, state, params=None):
        step = state.step + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m, v, p):
            u = -lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay and p is not None:
                u = u - lr * weight_decay * p
            return u

        if params is None:
            updates = jax.tree.map(lambda m, v: upd(m, v, None), mu, nu)
        else:
            updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamState(step=step, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)
