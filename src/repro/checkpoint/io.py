"""msgpack-based checkpointing of parameter / optimizer pytrees.

Layout: a single .msgpack file holding {flat_key: (dtype, shape, bytes)}
plus a JSON-able metadata dict. Flat keys are '/'-joined pytree paths, so
restore is structure-checked against a template tree.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional, Tuple

import jax
import msgpack
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, tree: Any, metadata: Optional[Dict] = None) -> None:
    flat = _flatten(tree)
    payload = {
        "metadata": metadata or {},
        "arrays": {
            k: {"dtype": str(v.dtype), "shape": list(v.shape),
                "data": v.tobytes()}
            for k, v in flat.items()
        },
    }
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)


def load_checkpoint(path: str, template: Any) -> Tuple[Any, Dict]:
    """Restore into the structure of `template` (shape/dtype checked)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    arrays = payload["arrays"]
    tmpl_flat = _flatten(template)
    missing = set(tmpl_flat) - set(arrays)
    extra = set(arrays) - set(tmpl_flat)
    if missing or extra:
        raise ValueError(f"checkpoint mismatch: missing={missing} extra={extra}")
    restored_flat = {}
    for k, t in tmpl_flat.items():
        a = arrays[k]
        arr = np.frombuffer(a["data"], dtype=np.dtype(a["dtype"])).reshape(a["shape"])
        if tuple(arr.shape) != tuple(t.shape):
            raise ValueError(f"{k}: shape {arr.shape} != template {t.shape}")
        restored_flat[k] = arr
    # Rebuild in template order.
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            for path, _ in leaves_paths]
    leaves = [restored_flat[k] for k in keys]
    return jax.tree_util.tree_unflatten(treedef, leaves), payload["metadata"]
