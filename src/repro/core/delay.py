"""The paper's delay models (§II-B/C/D, Eqs. 3-8).

Everything here is the *system model*: deterministic functions of device
and channel parameters. The federated simulator draws heterogeneous device
populations and evaluates these; the KKT optimizer (core/kkt.py) inverts
them. Units: seconds, Hz, watts, bits.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from repro.configs.base import ComputeConfig, WirelessConfig


# ---------------------------------------------------------------------------
# Computation model (Eqs. 3-5)
# ---------------------------------------------------------------------------


def gpu_frequency(cc: ComputeConfig) -> float:
    """Eq. 3: f_m = 1 / (a_s + a_c/f_c + a_M/f_M).

    With the paper's constants this caps at the effective GPU frequency
    combining static, core and memory terms (Abe et al. [12]).
    """
    return 1.0 / (cc.a_s + cc.a_c / cc.core_freq_hz + cc.a_m / cc.mem_freq_hz)


def cycles_per_iteration(cc: ComputeConfig) -> float:
    """G_m: GPU cycles for one mini-batch-size-1 iteration (measured
    offline in the paper; here cycles/bit x bits/sample)."""
    return cc.cycles_per_bit * cc.bits_per_sample


def local_compute_time(b: float, G_m: float, f_m: float) -> float:
    """Eq. 4: T_cp^m = G_m * b / f_m (one mini-batch SGD iteration)."""
    return G_m * b / f_m


def per_client_compute_time(
    b: float, G: Sequence[float], f: Sequence[float],
) -> np.ndarray:
    """Vectorized Eq. 4: T_cp^m for every device, shape (M,)."""
    return np.asarray(G, np.float64) * b / np.asarray(f, np.float64)


def round_compute_time(b: float, G: Sequence[float], f: Sequence[float]) -> float:
    """Eq. 5: synchronous straggler bound T_cp = max_m T_cp^m."""
    return float(np.max(per_client_compute_time(b, G, f)))


# ---------------------------------------------------------------------------
# Communication model (Eqs. 6-7)
# ---------------------------------------------------------------------------


def uplink_rate(wc: WirelessConfig, p_m: float, h_m: float) -> float:
    """Shannon rate B*log2(1 + p*h/N0) in bits/s. N0 is total noise power
    over the band (noise PSD x bandwidth)."""
    n0_w = 10 ** (wc.noise_dbm_per_hz / 10.0) * 1e-3 * wc.bandwidth_hz
    snr = p_m * h_m / n0_w
    return wc.bandwidth_hz * np.log2(1.0 + snr)


def uplink_time(update_bits: float, wc: WirelessConfig, p_m: float, h_m: float) -> float:
    """Eq. 6: T_cm^m = s / rate."""
    return update_bits / uplink_rate(wc, p_m, h_m)


def per_client_uplink_time(
    update_bits: float, wc: WirelessConfig,
    p: Sequence[float], h: Sequence[float],
) -> np.ndarray:
    """Vectorized Eq. 6: T_cm^m for every device, shape (M,).

    uplink_rate already broadcasts over arrays (np.log2), so this is one
    vector expression instead of an M-long Python loop."""
    return update_bits / uplink_rate(
        wc, np.asarray(p, np.float64), np.asarray(h, np.float64))


def round_comm_time(
    update_bits: float, wc: WirelessConfig,
    p: Sequence[float], h: Sequence[float],
) -> float:
    """Eq. 7: synchronous T_cm = max_m T_cm^m."""
    return float(np.max(per_client_uplink_time(update_bits, wc, p, h)))


def effective_uplink_times(
    update_bits: float, wc: WirelessConfig,
    p: Sequence[float], h_att: np.ndarray, attempts: np.ndarray,
    backoff_base: float = 0.0, backoff_factor: float = 2.0,
) -> np.ndarray:
    """Per-client uplink time under retransmission (fault path).

    A client that made `a` attempts occupies the channel for the SUM of
    its per-attempt Eq. 6 airtimes (each against that attempt's realized
    gain, h_att[..., k]) plus the exponential-backoff waits before
    attempts 2..a (backoff_base * backoff_factor**(k-1) before attempt
    k+1). Clients with attempts == 0 (absent/crashed) fall back to their
    attempt-0 single-shot time so the zero-participation full-population
    clock fallback stays meaningful.

    Shapes: p (M,) or broadcastable; h_att (..., M, A); attempts (..., M)
    int. Returns (..., M) float64. Vectorized over an optional leading
    round axis — the (R, M, A) chunk case is one expression, and each row
    is bit-identical to the per-round call (the host f64 clock twin the
    backends' bit parity rests on).
    """
    h_att = np.asarray(h_att, np.float64)
    attempts = np.asarray(attempts)
    p = np.asarray(p, np.float64)
    t_att = per_client_uplink_time(update_bits, wc, p[..., None], h_att)
    k = np.arange(h_att.shape[-1])
    used = k < attempts[..., None]
    t_used = np.where(used, t_att, 0.0).sum(axis=-1)
    wait = np.where((k >= 1) & used,
                    backoff_base * np.power(backoff_factor, k - 1.0),
                    0.0).sum(axis=-1)
    return np.where(attempts > 0, t_used + wait, t_att[..., 0])


# ---------------------------------------------------------------------------
# Round / overall time (Eq. 8, Eq. 13)
# ---------------------------------------------------------------------------


def finish_times(t_cp, t_cm, V: int) -> np.ndarray:
    """Per-client round finish time V * T_cp^m + T_cm^m (f64, Eqs. 4+6).

    The per-client form of Eq. 8's straggler argument: when a round
    deadline is in force, `finish <= deadline` is the feasibility mask
    (simulation's deadline cut), and sorting by it picks the
    deadline-feasible-fastest candidates of an over-provisioned cohort
    (CohortSpec.spare)."""
    return (np.asarray(t_cp, np.float64) * V
            + np.asarray(t_cm, np.float64))


def round_time(T_cm: float, T_cp: float, V: int, deadline=None) -> float:
    """Eq. 8: T = T_cm + V * T_cp — truncated at the server's round
    deadline when one is set (deadline-bounded rounds: the server stops
    waiting at `deadline` seconds and aggregates what arrived)."""
    T = T_cm + V * T_cp
    return min(deadline, T) if deadline is not None else T


def masked_round_times(
    t_cp: Sequence[float], t_cm: Sequence[float], mask: Sequence[bool],
) -> tuple[float, float]:
    """(T_cm, T_cp) as the straggler max over *participating* clients.

    Eq. 5/7 semantics restricted to the round's realized population: absent
    clients neither compute nor upload, so they cannot be the straggler.
    A zero-participation round falls back to the full-population max — the
    server's synchronous wait times out at the slowest possible client, so
    the wall clock still advances even though no update arrives (the
    in-graph twin of this rule lives in mesh_rounds._masked_clock).
    """
    t_cp = np.asarray(t_cp, np.float64)
    t_cm = np.asarray(t_cm, np.float64)
    mask = np.asarray(mask, bool)
    if not mask.any():
        return float(np.max(t_cm)), float(np.max(t_cp))
    return float(np.max(t_cm[mask])), float(np.max(t_cp[mask]))


def chunk_round_times(
    t_cp: Sequence[float], t_cm: Sequence[float], mask: Sequence[bool],
) -> tuple[np.ndarray, np.ndarray]:
    """Vectorized `masked_round_times` over a leading round axis.

    t_cp is (M,) (static per-client compute times) or (R, M); t_cm and
    mask are (R, M). Returns (T_cm, T_cp), each (R,) float64 — per-round
    straggler maxes over the participating clients, with the same
    zero-participation fallback to the full-population max. np.max over a
    boolean-selected subset is exact selection, so each row is
    bit-identical to a per-round `masked_round_times` call (the scan
    backend's clock accounting relies on this for parity with the
    per-round backends)."""
    mask = np.asarray(mask, bool)
    t_cp = np.broadcast_to(np.asarray(t_cp, np.float64), mask.shape)
    t_cm = np.broadcast_to(np.asarray(t_cm, np.float64), mask.shape)
    any_p = mask.any(axis=1)

    def mmax(t):
        masked = np.where(mask, t, -np.inf).max(axis=1)
        return np.where(any_p, masked, t.max(axis=1))

    return mmax(t_cm), mmax(t_cp)


def overall_time(H: float, T: float) -> float:
    """Eq. 13: 𝒯 = H * T."""
    return H * T


# ---------------------------------------------------------------------------
# Device population (heterogeneity draw for the simulator)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DevicePopulation:
    """Per-device compute (G_m, f_m) and channel (p_m, h_m) draws."""

    G: np.ndarray  # cycles per sample per iteration
    f: np.ndarray  # effective processor frequency, Hz
    p: np.ndarray  # tx power, W
    h: np.ndarray  # channel gain

    @property
    def n(self) -> int:
        return len(self.G)


def draw_population(
    n_devices: int,
    cc: ComputeConfig,
    wc: WirelessConfig,
    seed: int = 0,
    heterogeneity: float = 0.3,
) -> DevicePopulation:
    """Draw a heterogeneous device population.

    G_m and f_m jitter log-normally around the paper's nominal values;
    channel gains follow exponential (Rayleigh-power) fading around the
    mean pathloss. heterogeneity=0 gives the paper's homogeneous setting
    (equal f_m = 2 GHz for all devices).
    """
    rng = np.random.default_rng(seed)
    G0 = cycles_per_iteration(cc)
    f0 = gpu_frequency(cc)
    jitter = lambda: np.exp(rng.normal(0.0, heterogeneity, n_devices))
    h = wc.mean_channel_gain * (
        rng.exponential(1.0, n_devices) if heterogeneity > 0
        else np.ones(n_devices))
    return DevicePopulation(
        G=G0 * jitter() if heterogeneity > 0 else np.full(n_devices, G0),
        f=f0 / jitter() if heterogeneity > 0 else np.full(n_devices, f0),
        p=np.full(n_devices, wc.tx_power_w),
        h=h,
    )


def population_round_times(
    pop: DevicePopulation, b: float, update_bits: float, wc: WirelessConfig,
) -> tuple[float, float]:
    """(T_cm, T_cp) for a population at batch size b (Eqs. 5, 7)."""
    T_cp = round_compute_time(b, pop.G, pop.f)
    T_cm = round_comm_time(update_bits, wc, pop.p, pop.h)
    return T_cm, T_cp
