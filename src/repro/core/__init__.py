"""The paper's primary contribution: DEFL delay-efficient FL.

delay.py        Eqs. 3-8 computation/communication/round-time models
convergence.py  Theorem 1, Corollaries 1-2, Eq. 12 round-count model
kkt.py          problem (18) + closed form (Eq. 29) + numerical optimum
defl.py         Algorithm 1 plan construction
tradeoff.py     talk-vs-work decomposition sweeps (Fig. 1)
"""
from repro.core import convergence, defl, delay, kkt, tradeoff
