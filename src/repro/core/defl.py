"""Algorithm 1 (DEFL): plan construction.

Ties together the delay models (core/delay.py), the convergence model
(core/convergence.py) and the KKT solution (core/kkt.py) into an executable
federated training plan: the optimized (b*, theta*, V*) plus the predicted
round/overall times. federated/rounds.py executes the plan.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.configs.base import FedConfig, WirelessConfig
from repro.core import delay, kkt


@dataclass(frozen=True)
class DEFLPlan:
    """The algorithm's inputs for a concrete system (Alg. 1 line 0)."""

    b: int  # b* (power-of-two quantized)
    theta: float  # theta*
    V: int  # V = nu log(1/theta)
    H_pred: float  # predicted communication rounds (Eq. 12)
    T_cm: float  # round uplink time (Eq. 7)
    T_cp: float  # per-iteration compute time at b* (Eq. 5)
    T_round: float  # Eq. 8
    overall_pred: float  # Eq. 13
    update_bits: float
    solution: kkt.DelaySolution
    problem: kkt.DelayProblem


def make_plan(
    fed: FedConfig,
    pop: delay.DevicePopulation,
    update_bits: float,
    wireless: Optional[WirelessConfig] = None,
    method: str = "closed_form",
    participation: float = 1.0,
) -> DEFLPlan:
    """Solve the paper's optimization for a device population.

    update_bits: local model update size s in bits (actual parameter bytes
    unless FedConfig overrides; compression shrinks it).
    participation: expected fraction of clients whose update arrives each
    round (scenarios with Bernoulli dropout / link failure). The Eq. 12
    round-count model sees the effective M = round(participation * M) >= 1
    — fewer arriving updates per round means more rounds to the target,
    which moves the optimal talk/work point.
    """
    wireless = wireless or WirelessConfig()
    if fed.compress_updates:
        update_bits = update_bits / 4.0  # fp32 -> int8 quantized updates
    T_cm = delay.round_comm_time(update_bits, wireless, pop.p, pop.h)
    g = float(max(pop.G / pop.f))  # bottleneck compute slope (s per batch unit)
    M_eff = max(1, int(round(fed.n_devices * participation)))
    prob = kkt.DelayProblem(
        T_cm=T_cm, g=g, M=M_eff, eps=fed.epsilon, nu=fed.nu, c=fed.c)
    sol = kkt.solve(prob, method=method).quantized(prob)
    return DEFLPlan(
        b=int(sol.b),
        theta=sol.theta,
        V=sol.V,
        H_pred=sol.H,
        T_cm=T_cm,
        T_cp=sol.T_cp,
        T_round=sol.T_round,
        overall_pred=sol.overall,
        update_bits=update_bits,
        solution=sol,
        problem=prob,
    )


def plan_to_fedconfig(plan: DEFLPlan, fed: FedConfig) -> FedConfig:
    """Apply the DEFL plan onto a FedConfig (Alg. 1: run with b*, theta*)."""
    return dataclasses.replace(
        fed, batch_size=plan.b, theta=plan.theta,
        update_bytes=int(plan.update_bits // 8))


def fixed_plan(
    fed: FedConfig,
    pop: delay.DevicePopulation,
    update_bits: float,
    b: int,
    V: int,
    wireless: Optional[WirelessConfig] = None,
    theta: Optional[float] = None,
) -> DEFLPlan:
    """A baseline plan with manually chosen (b, V) — FedAvg / 'Rand.' rows.

    H is NOT predicted by Eq. 12 for baselines in the paper; the simulator
    measures it. We still fill H_pred from Eq. 12 for reference — at the
    exact `theta` when given (a swept theta whose V quantization would
    otherwise shift H, e.g. fig1d's talk/work decomposition), otherwise at
    theta = exp(-V/nu).
    """
    wireless = wireless or WirelessConfig()
    if fed.compress_updates:
        update_bits = update_bits / 4.0
    T_cm = delay.round_comm_time(update_bits, wireless, pop.p, pop.h)
    g = float(max(pop.G / pop.f))
    prob = kkt.DelayProblem(
        T_cm=T_cm, g=g, M=fed.n_devices, eps=fed.epsilon, nu=fed.nu, c=fed.c)
    if theta is not None:
        alpha = max(float(-np.log(theta)), 1e-6)
    else:
        alpha = max(V / fed.nu, 1e-6)
    sol = kkt.evaluate(prob, float(b), alpha, method="fixed")
    return DEFLPlan(
        b=b, theta=float(np.exp(-alpha)), V=V, H_pred=sol.H, T_cm=T_cm,
        T_cp=sol.T_cp, T_round=sol.T_round, overall_pred=sol.overall,
        update_bits=update_bits, solution=sol, problem=prob)
