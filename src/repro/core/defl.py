"""Algorithm 1 (DEFL): plan construction.

Ties together the delay models (core/delay.py), the convergence model
(core/convergence.py) and the KKT solution (core/kkt.py) into an executable
federated training plan: the optimized (b*, theta*, V*) plus the predicted
round/overall times. federated/rounds.py executes the plan.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from repro.configs.base import FedConfig, WirelessConfig
from repro.core import delay, kkt


@dataclass(frozen=True)
class DEFLPlan:
    """The algorithm's inputs for a concrete system (Alg. 1 line 0)."""

    b: int  # b* (power-of-two quantized)
    theta: float  # theta*
    V: int  # V = nu log(1/theta)
    H_pred: float  # predicted communication rounds (Eq. 12)
    T_cm: float  # round uplink time (Eq. 7)
    T_cp: float  # per-iteration compute time at b* (Eq. 5)
    T_round: float  # Eq. 8
    overall_pred: float  # Eq. 13
    update_bits: float
    solution: kkt.DelaySolution
    problem: kkt.DelayProblem


def make_plan(
    fed: FedConfig,
    pop: delay.DevicePopulation,
    update_bits: float,
    wireless: Optional[WirelessConfig] = None,
    method: str = "closed_form",
    participation: float = 1.0,
    cohort_size: Optional[int] = None,
) -> DEFLPlan:
    """Solve the paper's optimization for a device population.

    update_bits: local model update size s in bits (actual parameter bytes
    unless FedConfig overrides; compression shrinks it).
    participation: expected fraction of clients whose update arrives each
    round (scenarios with Bernoulli dropout / link failure). The Eq. 12
    round-count model sees the effective M = round(participation * M) >= 1
    — fewer arriving updates per round means more rounds to the target,
    which moves the optimal talk/work point.
    cohort_size: sampled-participation regime (K-client cohorts drawn
    from the M-client population each round). The population statistics —
    Eq. 7's straggler uplink max and the bottleneck compute slope g —
    still come from the FULL population `pop` (any client can be drawn,
    so the worst straggler still bounds a round), but the Eq. 12 round
    count sees M_eff = round(participation * K): only the cohort's
    updates average into a round, so the variance-reduction term that
    drives H is cohort-conditional. `participation` composes on top
    (dropout strikes the drawn cohort).
    """
    prob, T_cm, update_bits = _plan_problem(
        fed, pop, update_bits, wireless, participation, cohort_size)
    sol = kkt.solve(prob, method=method).quantized(prob)
    return _assemble_plan(sol, prob, T_cm, update_bits)


def _plan_problem(fed, pop, update_bits, wireless, participation,
                  cohort_size):
    """The Alg. 1 problem setup shared by the scalar and batched solvers:
    wire size -> Eq. 7 uplink straggler max, bottleneck compute slope,
    participation-scaled effective M."""
    wireless = wireless or WirelessConfig()
    if fed.compress_updates:
        update_bits = update_bits / 4.0  # fp32 -> int8 quantized updates
    T_cm = delay.round_comm_time(update_bits, wireless, pop.p, pop.h)
    g = float(max(pop.G / pop.f))  # bottleneck compute slope (s per batch unit)
    M_base = fed.n_devices if cohort_size is None else int(cohort_size)
    M_eff = max(1, int(round(M_base * participation)))
    prob = kkt.DelayProblem(
        T_cm=T_cm, g=g, M=M_eff, eps=fed.epsilon, nu=fed.nu, c=fed.c)
    return prob, T_cm, update_bits


def _assemble_plan(sol: kkt.DelaySolution, prob: kkt.DelayProblem,
                   T_cm: float, update_bits: float) -> DEFLPlan:
    return DEFLPlan(
        b=int(sol.b),
        theta=sol.theta,
        V=sol.V,
        H_pred=sol.H,
        T_cm=T_cm,
        T_cp=sol.T_cp,
        T_round=sol.T_round,
        overall_pred=sol.overall,
        update_bits=update_bits,
        solution=sol,
        problem=prob,
    )


@dataclass(frozen=True, eq=False)
class PlanRequest:
    """One arm's Alg. 1 solve in value form — the batchable unit of
    `make_plan_batch`. Field-for-field the `make_plan` signature."""

    fed: FedConfig
    pop: delay.DevicePopulation
    update_bits: float
    wireless: Optional[WirelessConfig] = None
    method: str = "closed_form"
    participation: float = 1.0
    cohort_size: Optional[int] = None


def make_plan_batch(requests: Sequence[PlanRequest]) -> List[DEFLPlan]:
    """`make_plan` over N requests with the KKT stage batched: requests
    sharing a method are solved by ONE vectorized `kkt.solve_batch`
    dispatch instead of N scalar solves. Each returned plan is
    bit-identical to `make_plan(**request)` — solve_batch's closed form
    is elementwise-exact and the problem setup/assembly code is shared
    verbatim (tests/test_plan_batch.py asserts the identity).
    """
    reqs = list(requests)
    pieces = [
        _plan_problem(r.fed, r.pop, r.update_bits, r.wireless,
                      r.participation, r.cohort_size)
        for r in reqs]
    by_method = {}
    for i, r in enumerate(reqs):
        by_method.setdefault(r.method, []).append(i)
    plans: List[Optional[DEFLPlan]] = [None] * len(reqs)
    for method, idxs in by_method.items():
        sols = kkt.solve_batch([pieces[i][0] for i in idxs], method=method)
        for i, sol in zip(idxs, sols):
            prob, T_cm, bits = pieces[i]
            plans[i] = _assemble_plan(sol.quantized(prob), prob, T_cm, bits)
    return plans


def deadline_plan(
    fed: FedConfig,
    pop: delay.DevicePopulation,
    update_bits: float,
    deadline: float,
    wireless: Optional[WirelessConfig] = None,
    participation: float = 1.0,
    b_max: float = 64.0,
    cohort_size: Optional[int] = None,
    spare: int = 0,
) -> DEFLPlan:
    """Deadline-aware variant of Algorithm 1: re-derive (b, V) when the
    server truncates every round at `deadline` seconds (faults.FaultModel).

    A deadline changes the problem in two coupled ways the unconstrained
    KKT point cannot see:
      * the Eq. 8 round cost saturates at min(deadline, T_cm + V*T_cp) —
        talking/working past the deadline is free in wall clock but
        useless (the update misses aggregation), so J = H * min(D, T);
      * clients whose V*t_cp^m + t_cm^m exceeds the deadline are excluded,
        shrinking the Eq. 12 effective M — an operating point is only
        worth its feasible fraction of the population.

    The objective is no longer smooth (the min kink and the per-client
    feasibility steps), so instead of KKT conditions this does an exact
    grid sweep over the quantized decision space: b in {2^n} up to b_max
    x alpha on a log grid, scoring each point by H (at the
    feasibility-scaled M) times the truncated round time, keeping only
    points where at least one client finishes inside the deadline.
    Raises ValueError when no (b, alpha) is feasible — the deadline is
    shorter than the fastest client's single-iteration round.

    cohort_size: as in `make_plan` — Eq. 12's effective M is based on the
    K-client cohort (feasibility is still measured over the FULL
    population: the feasible fraction of M is the expected feasible
    fraction of a uniformly drawn cohort).
    spare: over-provisioned cohorts (CohortSpec.spare): each round draws
    K + spare candidates and keeps the K deadline-feasible-fastest, so
    the expected feasible participation rises from K * feas to
    min(K, (K + spare) * feas) — the Eq. 12 effective M sees the
    correction. spare requires cohort_size; spare=0 reduces exactly to
    the plain cohort formula.
    """
    if spare and cohort_size is None:
        raise ValueError("spare over-provisioning requires cohort_size=K")
    if spare < 0:
        raise ValueError(f"spare must be >= 0, got {spare}")
    wireless = wireless or WirelessConfig()
    if fed.compress_updates:
        update_bits = update_bits / 4.0
    t_cm_m = delay.per_client_uplink_time(update_bits, wireless, pop.p, pop.h)
    T_cm = float(np.max(t_cm_m))
    g = float(max(pop.G / pop.f))
    slopes = np.asarray(pop.G, np.float64) / np.asarray(pop.f, np.float64)
    M_base = fed.n_devices if cohort_size is None else int(cohort_size)

    n_pow = max(int(np.floor(np.log2(b_max))), 0)
    bs = 2.0 ** np.arange(0, n_pow + 1)
    als = np.geomspace(1.0 / fed.nu, 20.0, 96)

    best, best_J = None, np.inf
    for b in bs:
        for alpha in als:
            V = max(int(round(fed.nu * alpha)), 1)
            finish = V * slopes * b + t_cm_m  # per-client round span
            feas = finish <= deadline
            if not feas.any():
                continue
            if cohort_size is None or spare == 0:
                M_eff = max(1, int(round(
                    M_base * participation * feas.mean())))
            else:
                # Over-provisioning: K + spare candidates, keep the K
                # feasible-fastest — expected feasible participation
                # saturates at the cohort size.
                exp_feas = (cohort_size + spare) * feas.mean()
                M_eff = max(1, int(round(
                    min(float(M_base), exp_feas) * participation)))
            H = kkt.communication_rounds_alpha(
                b, alpha, M_eff, fed.epsilon, fed.nu, fed.c)
            T = min(deadline, T_cm + fed.nu * alpha * g * b)
            J = H * T
            if J < best_J:
                best, best_J = (float(b), float(alpha), M_eff), J
    if best is None:
        raise ValueError(
            f"deadline {deadline:.4g}s is infeasible: no client can finish "
            "even one local iteration + upload inside it at any batch size")
    b, alpha, M_eff = best
    prob = kkt.DelayProblem(
        T_cm=T_cm, g=g, M=M_eff, eps=fed.epsilon, nu=fed.nu, c=fed.c)
    sol = kkt.evaluate(prob, b, alpha, method="deadline_grid")
    return DEFLPlan(
        b=int(sol.b), theta=sol.theta, V=sol.V, H_pred=sol.H, T_cm=T_cm,
        T_cp=sol.T_cp,
        T_round=min(deadline, sol.T_round),
        overall_pred=sol.H * min(deadline, sol.T_round),
        update_bits=update_bits, solution=sol, problem=prob)


def async_plan(
    fed: FedConfig,
    pop: delay.DevicePopulation,
    update_bits: float,
    buffer_size: int,
    wireless: Optional[WirelessConfig] = None,
    b_max: float = 64.0,
) -> DEFLPlan:
    """Alg. 1 re-derived for buffered asynchronous aggregation
    (backend='async', events.AsyncSpec(buffer_size=K)).

    Two terms of the synchronous objective change:

      * Eq. 8's round time is a straggler MAX (T_cm + nu alpha T_cp at
        the slowest device). Under ack-at-aggregation every accepted
        client is re-dispatched at an aggregation instant, so in steady
        state client m contributes updates as a renewal process at rate
        1/s_m with service span s_m = V t_cp_m + t_cm_m. The buffer
        fills after K arrivals from the pooled process: the expected
        aggregation period is T_agg = K / sum_m (1/s_m) — K over the
        HARMONIC sum of client spans. A straggler hurts only in
        proportion to its rate share, not as a hard round floor.
      * Eq. 12's effective M is the number of updates averaged per
        aggregation. Asynchronously that is the buffer size K — the
        expected concurrency replaces the synchronous cohort M.

    J(b, alpha) = H(b, alpha; M=K) * T_agg(b, alpha) has per-client
    feasibility steps baked into neither term, but H's M-dependence and
    T_agg's harmonic pooling make the objective non-smooth in K, so —
    like `deadline_plan` — this sweeps the exact quantized decision
    space (b in {2^n} up to b_max x alpha on a log grid, alpha >=
    1/nu so V >= 1) rather than solving KKT conditions. The staleness
    discount is a second-order effect on H (weights are normalized per
    fill) and is not modeled.

    Returns a DEFLPlan whose T_round/overall_pred are the async
    T_agg / H*T_agg; `problem.M` records K (method 'async_grid').
    """
    wireless = wireless or WirelessConfig()
    if fed.compress_updates:
        update_bits = update_bits / 4.0
    t_cm_m = delay.per_client_uplink_time(update_bits, wireless, pop.p, pop.h)
    slopes = np.asarray(pop.G, np.float64) / np.asarray(pop.f, np.float64)
    K = int(buffer_size)
    if not 1 <= K <= slopes.size:
        raise ValueError(
            f"buffer_size must be in [1, M={slopes.size}], got {K}")

    n_pow = max(int(np.floor(np.log2(b_max))), 0)
    bs = 2.0 ** np.arange(0, n_pow + 1)
    als = np.geomspace(1.0 / fed.nu, 20.0, 96)

    best, best_J = None, np.inf
    for b in bs:
        for alpha in als:
            V = max(int(round(fed.nu * alpha)), 1)
            spans = V * slopes * b + t_cm_m  # per-client service span s_m
            T_agg = K / float(np.sum(1.0 / spans))
            H = kkt.communication_rounds_alpha(
                b, alpha, K, fed.epsilon, fed.nu, fed.c)
            J = H * T_agg
            if J < best_J:
                best, best_J = (float(b), float(alpha), float(T_agg)), J
    b, alpha, T_agg = best
    T_cm = float(np.max(t_cm_m))
    g = float(max(pop.G / pop.f))
    prob = kkt.DelayProblem(
        T_cm=T_cm, g=g, M=K, eps=fed.epsilon, nu=fed.nu, c=fed.c)
    sol = kkt.evaluate(prob, b, alpha, method="async_grid")
    return DEFLPlan(
        b=int(sol.b), theta=sol.theta, V=sol.V, H_pred=sol.H, T_cm=T_cm,
        T_cp=sol.T_cp,
        T_round=T_agg,
        overall_pred=sol.H * T_agg,
        update_bits=update_bits, solution=sol, problem=prob)


def plan_to_fedconfig(plan: DEFLPlan, fed: FedConfig) -> FedConfig:
    """Apply the DEFL plan onto a FedConfig (Alg. 1: run with b*, theta*)."""
    return dataclasses.replace(
        fed, batch_size=plan.b, theta=plan.theta,
        update_bytes=int(plan.update_bits // 8))


def fixed_plan(
    fed: FedConfig,
    pop: delay.DevicePopulation,
    update_bits: float,
    b: int,
    V: int,
    wireless: Optional[WirelessConfig] = None,
    theta: Optional[float] = None,
) -> DEFLPlan:
    """A baseline plan with manually chosen (b, V) — FedAvg / 'Rand.' rows.

    H is NOT predicted by Eq. 12 for baselines in the paper; the simulator
    measures it. We still fill H_pred from Eq. 12 for reference — at the
    exact `theta` when given (a swept theta whose V quantization would
    otherwise shift H, e.g. fig1d's talk/work decomposition), otherwise at
    theta = exp(-V/nu).
    """
    wireless = wireless or WirelessConfig()
    if fed.compress_updates:
        update_bits = update_bits / 4.0
    T_cm = delay.round_comm_time(update_bits, wireless, pop.p, pop.h)
    g = float(max(pop.G / pop.f))
    prob = kkt.DelayProblem(
        T_cm=T_cm, g=g, M=fed.n_devices, eps=fed.epsilon, nu=fed.nu, c=fed.c)
    if theta is not None:
        alpha = max(float(-np.log(theta)), 1e-6)
    else:
        alpha = max(V / fed.nu, 1e-6)
    sol = kkt.evaluate(prob, float(b), alpha, method="fixed")
    return DEFLPlan(
        b=b, theta=float(np.exp(-alpha)), V=V, H_pred=sol.H, T_cm=T_cm,
        T_cp=sol.T_cp, T_round=sol.T_round, overall_pred=sol.overall,
        update_bits=update_bits, solution=sol, problem=prob)
