"""Convergence theory (§III): Theorem 1, Corollaries 1-2, Remark 3.

These give the round-count model H(b, theta; M, eps, nu, c) that the delay
optimization (core/kkt.py) multiplies against the per-round time model.
"""
from __future__ import annotations

import numpy as np


def theorem1_bound(
    w0_dist_sq: float, sigma_sq: float, L: float,
    M: int, K: int, V: int, b: int = 1,
) -> float:
    """Corollary 1 (Eq. 10) upper bound on E[F(w̄_K) - F(w*)].

    b=1 recovers Theorem 1 (Eq. 9).
    """
    t1 = 8.0 * w0_dist_sq / np.sqrt(M * K)
    t2 = sigma_sq / (2.0 * b * L * np.sqrt(M * K))
    t3 = sigma_sq * M * (V - 1) / (b * L * K)
    return t1 + t2 + t3


def local_rounds(theta: float, nu: float) -> int:
    """Remark 3: V = nu * log(1/theta), >= 1."""
    return max(int(round(nu * np.log(1.0 / max(theta, 1e-12)))), 1)


def communication_rounds(
    b: float, theta: float, M: int, eps: float, nu: float, c: float,
) -> float:
    """Eq. 12: H = c/(b^2 eps^2 M nu log(1/theta)) + c M/(b eps).

    The first term is the variance-driven requirement (shrinks with more
    local work nu*log(1/theta) and bigger batches); the second is the
    drift/communication floor.
    """
    alpha = np.log(1.0 / max(theta, 1e-12))
    alpha = max(alpha, 1e-12)
    return c / (b * b * eps * eps * M * nu * alpha) + c * M / (b * eps)


def communication_rounds_alpha(
    b: float, alpha: float, M: int, eps: float, nu: float, c: float,
) -> float:
    """Eq. 12 in the alpha = log(1/theta) parameterization (Section V)."""
    alpha = max(alpha, 1e-12)
    return c / (b * b * eps * eps * M * nu * alpha) + c * M / (b * eps)


def gradient_steps_for_eps(
    eps: float, w0_dist_sq: float, sigma_sq: float, L: float,
    M: int, V: int, b: int,
) -> int:
    """Invert Corollary 1 numerically: smallest K with bound(K) <= eps."""
    lo, hi = 1, 1
    while theorem1_bound(w0_dist_sq, sigma_sq, L, M, hi, V, b) > eps:
        hi *= 2
        if hi > 1 << 40:
            raise ValueError("eps unreachable under this bound")
    while lo < hi:
        mid = (lo + hi) // 2
        if theorem1_bound(w0_dist_sq, sigma_sq, L, M, mid, V, b) <= eps:
            hi = mid
        else:
            lo = mid + 1
    return lo
