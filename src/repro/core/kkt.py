"""§IV-V: the delay-minimization problem and its KKT solution (Eq. 29).

Problem (18):  minimize over (b, alpha, T_cp)
    J = ( c/(b^2 eps^2 M nu alpha) + c M /(b eps) ) * ( T_cm + nu alpha T_cp )
    s.t. b >= 1, alpha >= 0, T_cp >= G_m b / f_m  for all m.

At the optimum the compute constraint is active at the bottleneck device:
T_cp = g * b with g = max_m G_m / f_m. The paper's closed form (Eq. 29):

    alpha* = sqrt( T_cm f_m / (M^2 eps nu^2 G_m) )   [f/G at the bottleneck]
    b*     = 2 c M sqrt( T_cm f_m eps / G_m )
    T_cp*  = g * b*

We implement the closed form verbatim plus a numerical optimizer
(log-space grid + coordinate refinement) used to (a) cross-validate the
closed form in property tests and (b) quantify its optimality gap, which we
report in the benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.convergence import communication_rounds_alpha


@dataclass(frozen=True)
class DelayProblem:
    """Inputs of problem (18)."""

    T_cm: float  # round communication time (Eq. 7), seconds
    g: float  # bottleneck compute slope max_m G_m/f_m, seconds per unit batch
    M: int  # number of devices
    eps: float  # preset global convergence error
    nu: float  # Remark-3 constant
    c: float  # big-O constant


@dataclass(frozen=True)
class DelaySolution:
    b: float
    alpha: float
    theta: float
    T_cp: float
    V: int
    H: float
    T_round: float
    overall: float
    method: str

    def quantized(self, prob: DelayProblem) -> "DelaySolution":
        """Apply constraint (15): b in {2^n}, plus V >= 1 integrality."""
        b = quantize_batch(self.b)
        return evaluate(prob, b, self.alpha, method=self.method + "+quant")


def quantize_batch(b: float) -> int:
    """Round to the nearest power of two, >= 1 (constraint 15)."""
    b = max(b, 1.0)
    lo = 2 ** int(np.floor(np.log2(b)))
    hi = lo * 2
    return int(lo if b / lo <= hi / b else hi)


def objective(prob: DelayProblem, b: float, alpha: float) -> float:
    """J(b, alpha) with the compute constraint active (T_cp = g b)."""
    H = communication_rounds_alpha(b, alpha, prob.M, prob.eps, prob.nu, prob.c)
    T = prob.T_cm + prob.nu * alpha * prob.g * b
    return H * T


def evaluate(prob: DelayProblem, b: float, alpha: float, method: str) -> DelaySolution:
    H = communication_rounds_alpha(b, alpha, prob.M, prob.eps, prob.nu, prob.c)
    T_cp = prob.g * b
    V = max(int(round(prob.nu * alpha)), 1)
    T = prob.T_cm + prob.nu * alpha * T_cp
    return DelaySolution(
        b=b, alpha=alpha, theta=float(np.exp(-alpha)), T_cp=T_cp, V=V,
        H=H, T_round=T, overall=H * T, method=method)


def closed_form(prob: DelayProblem) -> DelaySolution:
    """Eq. 29 verbatim (f_m/G_m at the bottleneck device = 1/g)."""
    inv_g = 1.0 / prob.g
    alpha = np.sqrt(prob.T_cm * inv_g / (prob.M ** 2 * prob.eps * prob.nu ** 2))
    b = 2.0 * prob.c * prob.M * np.sqrt(prob.T_cm * inv_g * prob.eps)
    b = max(b, 1.0)
    alpha = max(alpha, 1e-6)
    return evaluate(prob, b, alpha, method="closed_form")


def stationary_alpha(prob: DelayProblem, b: float) -> float:
    """Exact interior argmin over alpha at fixed b.

    Expanding (18): J(alpha) = A/alpha + B*alpha + C with
      A = c*T_cm/(b^2 eps^2 M nu),  B = c*M*nu*g/eps
    so argmin alpha = sqrt(A/B) = sqrt(T_cm/(eps M^2 nu^2 g)) / b.

    REPRODUCTION FINDING (validated in tests/test_kkt.py): the paper's
    Eq. 29 alpha* equals b * stationary_alpha(b) — i.e. Eq. 29 is the b=1
    stationary point; a factor of b was dropped in the paper's KKT algebra.
    We keep closed_form() faithful and expose this corrected point for the
    beyond-paper comparison (EXPERIMENTS.md §Perf).
    """
    return float(np.sqrt(prob.T_cm / (prob.eps * prob.M ** 2
                                      * prob.nu ** 2 * prob.g)) / b)


def corrected_solution(prob: DelayProblem, b_max: float = 64.0) -> DelaySolution:
    """Beyond-paper 'DEFL+' point: J is strictly decreasing in b
    (J = P/b^2 + Q/b + R, all positive), so b* sits at the practical upper
    bound (dataset/memory/generalization budget — constraint 15's
    'commonly used effective batch sizes'), with the exact stationary alpha.
    """
    b = float(b_max)
    # alpha floored at 1/nu so V = nu*alpha >= 1 (Eq. 12's regime).
    return evaluate(prob, b, max(stationary_alpha(prob, b), 1.0 / prob.nu),
                    method="corrected")


def grid_search(
    prob: DelayProblem,
    b_range=(1.0, 4096.0),
    alpha_range=(1e-3, 20.0),
    n: int = 160,
) -> DelaySolution:
    """Log-space grid over (b, alpha).

    Scalars are hoisted to np.float64 so `eps ** 2` runs numpy's power
    kernel (not Python's libm pow), matching `_grid_search_batch`'s
    array path bit-for-bit."""
    bs = np.geomspace(*b_range, n)
    als = np.geomspace(*alpha_range, n)
    Bm, Am = np.meshgrid(bs, als, indexing="ij")
    T_cm, g = np.float64(prob.T_cm), np.float64(prob.g)
    M, eps = np.float64(prob.M), np.float64(prob.eps)
    nu, c = np.float64(prob.nu), np.float64(prob.c)
    H = (c / (Bm ** 2 * eps ** 2 * M * nu * Am)
         + c * M / (Bm * eps))
    T = T_cm + nu * Am * g * Bm
    J = H * T
    i, j = np.unravel_index(np.argmin(J), J.shape)
    return evaluate(prob, float(bs[i]), float(als[j]), method="grid")


def _grid_search_batch(T_cm, g, M, eps, nu, c,
                       b_range=(1.0, 4096.0), alpha_range=(1e-3, 20.0),
                       n: int = 160):
    """`grid_search` over N lanes: one (N, n, n) objective evaluation.

    The grid axes are shared across lanes (they depend only on the
    ranges), the lane parameters broadcast as (N, 1, 1), and the
    per-cell expression is the exact scalar association — so lane i's
    argmin cell is the cell scalar `grid_search(probs[i])` picks."""
    bs = np.geomspace(*b_range, n)
    als = np.geomspace(*alpha_range, n)
    Bm, Am = np.meshgrid(bs, als, indexing="ij")

    def lane(x):
        return np.asarray(x, np.float64)[:, None, None]

    T_cm, g, M = lane(T_cm), lane(g), lane(M)
    eps, nu, c = lane(eps), lane(nu), lane(c)
    H = (c / (Bm ** 2 * eps ** 2 * M * nu * Am)
         + c * M / (Bm * eps))
    T = T_cm + nu * Am * g * Bm
    J = H * T
    flat = np.argmin(J.reshape(J.shape[0], -1), axis=1)
    i, j = np.divmod(flat, n)
    return bs[i], als[j]


def _golden_min(f, lo: float, hi: float, iters: int = 80) -> float:
    """Golden-section minimize a unimodal f on [lo, hi] (log-space).

    Arithmetic is numpy float64 scalar ops (not math.*): numpy's scalar
    and array element paths produce identical bits, while math.exp and
    np.exp can disagree by an ulp — sharing the numpy kernels is what
    lets `_golden_min_vec` be bit-identical per lane to this."""
    gr = (np.sqrt(5.0) - 1.0) / 2.0
    a, b = np.log(lo), np.log(hi)
    c = b - gr * (b - a)
    d = a + gr * (b - a)
    fc, fd = f(np.exp(c)), f(np.exp(d))
    for _ in range(iters):
        if fc < fd:
            b, d, fd = d, c, fc
            c = b - gr * (b - a)
            fc = f(np.exp(c))
        else:
            a, c, fc = c, d, fd
            d = a + gr * (b - a)
            fd = f(np.exp(d))
    return float(np.exp((a + b) / 2.0))


def _golden_min_vec(f, lo, hi, iters: int = 80) -> np.ndarray:
    """`_golden_min` over N independent lanes at once.

    lo/hi are (N,) float64 arrays and f maps (N,) probe points to (N,)
    objective values elementwise. Each lane runs the exact scalar
    control flow — its bracket updates depend only on its own fc < fd
    comparison, selected with np.where — and every probe/bracket value
    is produced by the same elementwise expressions as the scalar code,
    so lane i is bit-identical to `_golden_min(f_i, lo[i], hi[i])`
    (asserted in tests/test_plan_batch.py via solve_batch). One lane
    evaluates exactly one new probe per iteration, same as the scalar
    loop; the N lanes' probes are batched into one f call."""
    gr = (np.sqrt(5.0) - 1.0) / 2.0
    a, b = np.log(np.asarray(lo, np.float64)), np.log(np.asarray(hi, np.float64))
    c = b - gr * (b - a)
    d = a + gr * (b - a)
    fc, fd = f(np.exp(c)), f(np.exp(d))
    for _ in range(iters):
        left = fc < fd  # per-lane branch: shrink from the right
        na = np.where(left, a, c)
        nb = np.where(left, d, b)
        # left lanes probe a new c; right lanes probe a new d — both are
        # the same expression the scalar branch computes on its updated
        # bracket, evaluated lane-wise and gathered into ONE f call.
        probe_c = nb - gr * (nb - na)
        probe_d = na + gr * (nb - na)
        probe = np.where(left, probe_c, probe_d)
        fp = f(np.exp(probe))
        nc = np.where(left, probe_c, d)
        nd = np.where(left, c, probe_d)
        nfc = np.where(left, fp, fd)
        nfd = np.where(left, fc, fp)
        a, b, c, d, fc, fd = na, nb, nc, nd, nfc, nfd
    return np.exp((a + b) / 2.0)


def _objective_batch(T_cm, g, M, eps, nu, c, b, alpha):
    """Elementwise J(b, alpha) over lanes — same association as
    `objective` / `communication_rounds_alpha` (bit-identical per lane:
    +, *, / are exact IEEE ops, max -> np.maximum)."""
    alpha = np.maximum(alpha, 1e-12)
    H = c / (b * b * eps * eps * M * nu * alpha) + c * M / (b * eps)
    T = T_cm + nu * alpha * g * b
    return H * T


def _coordinate_descent_batch(T_cm, g, M, eps, nu, c, b0, alpha0,
                              sweeps: int = 8, b_max: float = 64.0):
    """`coordinate_descent` over N lanes: the same 8 alternating
    golden-section sweeps, each running all lanes through ONE
    `_golden_min_vec` call (alpha_min = 1/nu per lane)."""
    alpha_min = 1.0 / np.asarray(nu, np.float64)
    b = np.minimum(np.maximum(np.asarray(b0, np.float64), 1.0), b_max)
    alpha = np.maximum(np.asarray(alpha0, np.float64), alpha_min)
    hi_a = np.full_like(b, 100.0)
    lo_b, hi_b = np.ones_like(b), np.full_like(b, b_max)
    for _ in range(sweeps):
        alpha = _golden_min_vec(
            lambda a: _objective_batch(T_cm, g, M, eps, nu, c, b, a),
            alpha_min, hi_a)
        b = _golden_min_vec(
            lambda bb: _objective_batch(T_cm, g, M, eps, nu, c, bb, alpha),
            lo_b, hi_b)
    return b, alpha


def coordinate_descent(
    prob: DelayProblem, b0: float = 32.0, alpha0: float = 1.0,
    sweeps: int = 8, b_max: float = 64.0, alpha_min: float = None,
) -> DelaySolution:
    """Numerical optimum of the BOUNDED problem: b in [1, b_max],
    alpha >= alpha_min (default 1/nu so that V >= 1).

    The unbounded relaxation of (18) is degenerate (inf J = 0 along
    b->inf, alpha->0 paths), so bounds are required for the numerical
    cross-check to be meaningful; see kkt.stationary_alpha docstring.
    J is unimodal per coordinate (A/x + Bx + C or P/x^2 + Q/x + R), so
    golden-section coordinate descent converges.
    """
    alpha_min = alpha_min if alpha_min is not None else 1.0 / prob.nu
    b, alpha = min(max(b0, 1.0), b_max), max(alpha0, alpha_min)
    for _ in range(sweeps):
        alpha = _golden_min(lambda a: objective(prob, b, a), alpha_min, 100.0)
        b = _golden_min(lambda bb: objective(prob, bb, alpha), 1.0, b_max)
    return evaluate(prob, b, alpha, method="numerical")


def solve(prob: DelayProblem, method: str = "closed_form",
          b_max: float = 64.0) -> DelaySolution:
    if method == "closed_form":
        return closed_form(prob)
    if method == "corrected":
        return corrected_solution(prob, b_max=b_max)
    if method == "numerical":
        grid = grid_search(prob, b_range=(1.0, b_max))
        return coordinate_descent(prob, grid.b, grid.alpha, b_max=b_max)
    raise ValueError(method)


def solve_batch(probs, method: str = "closed_form",
                b_max: float = 64.0):
    """`solve` over N problems at once, bit-identical to the scalar path.

    method='closed_form' (the default, and what every plan=True study
    arm runs) evaluates the Eq. 29 algebra as ONE (N,)-vectorized numpy
    dispatch; method='numerical' runs the grid seed as one (N, n, n)
    evaluation and the golden-section coordinate descent as lockstep
    `_golden_min_vec` sweeps (one batched objective probe per iteration
    for all lanes). Every lane reproduces the scalar expression
    association exactly, so each is bit-identical to `solve(probs[i])`
    — asserted in tests/test_plan_batch.py. method='corrected' is a
    two-expression closed form; it stays a scalar loop, which is
    trivially identical.

    Returns a list of DelaySolution, one per problem, in order.
    """
    probs = list(probs)
    if not probs:
        return []
    if method == "corrected":
        return [solve(p, method=method, b_max=b_max) for p in probs]
    T_cm = np.asarray([p.T_cm for p in probs], np.float64)
    g = np.asarray([p.g for p in probs], np.float64)
    M = np.asarray([p.M for p in probs], np.float64)
    eps = np.asarray([p.eps for p in probs], np.float64)
    nu = np.asarray([p.nu for p in probs], np.float64)
    c = np.asarray([p.c for p in probs], np.float64)
    if method == "closed_form":
        inv_g = 1.0 / g
        alpha = np.sqrt(T_cm * inv_g / (M ** 2 * eps * nu ** 2))
        b = 2.0 * c * M * np.sqrt(T_cm * inv_g * eps)
        b = np.maximum(b, 1.0)
        alpha = np.maximum(alpha, 1e-6)
    elif method == "numerical":
        b0, a0 = _grid_search_batch(T_cm, g, M, eps, nu, c,
                                    b_range=(1.0, b_max))
        b, alpha = _coordinate_descent_batch(T_cm, g, M, eps, nu, c,
                                             b0, a0, b_max=b_max)
    else:
        raise ValueError(method)
    return [evaluate(p, float(bi), float(ai), method=method)
            for p, bi, ai in zip(probs, b, alpha)]
