"""§IV-V: the delay-minimization problem and its KKT solution (Eq. 29).

Problem (18):  minimize over (b, alpha, T_cp)
    J = ( c/(b^2 eps^2 M nu alpha) + c M /(b eps) ) * ( T_cm + nu alpha T_cp )
    s.t. b >= 1, alpha >= 0, T_cp >= G_m b / f_m  for all m.

At the optimum the compute constraint is active at the bottleneck device:
T_cp = g * b with g = max_m G_m / f_m. The paper's closed form (Eq. 29):

    alpha* = sqrt( T_cm f_m / (M^2 eps nu^2 G_m) )   [f/G at the bottleneck]
    b*     = 2 c M sqrt( T_cm f_m eps / G_m )
    T_cp*  = g * b*

We implement the closed form verbatim plus a numerical optimizer
(log-space grid + coordinate refinement) used to (a) cross-validate the
closed form in property tests and (b) quantify its optimality gap, which we
report in the benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.convergence import communication_rounds_alpha


@dataclass(frozen=True)
class DelayProblem:
    """Inputs of problem (18)."""

    T_cm: float  # round communication time (Eq. 7), seconds
    g: float  # bottleneck compute slope max_m G_m/f_m, seconds per unit batch
    M: int  # number of devices
    eps: float  # preset global convergence error
    nu: float  # Remark-3 constant
    c: float  # big-O constant


@dataclass(frozen=True)
class DelaySolution:
    b: float
    alpha: float
    theta: float
    T_cp: float
    V: int
    H: float
    T_round: float
    overall: float
    method: str

    def quantized(self, prob: DelayProblem) -> "DelaySolution":
        """Apply constraint (15): b in {2^n}, plus V >= 1 integrality."""
        b = quantize_batch(self.b)
        return evaluate(prob, b, self.alpha, method=self.method + "+quant")


def quantize_batch(b: float) -> int:
    """Round to the nearest power of two, >= 1 (constraint 15)."""
    b = max(b, 1.0)
    lo = 2 ** int(np.floor(np.log2(b)))
    hi = lo * 2
    return int(lo if b / lo <= hi / b else hi)


def objective(prob: DelayProblem, b: float, alpha: float) -> float:
    """J(b, alpha) with the compute constraint active (T_cp = g b)."""
    H = communication_rounds_alpha(b, alpha, prob.M, prob.eps, prob.nu, prob.c)
    T = prob.T_cm + prob.nu * alpha * prob.g * b
    return H * T


def evaluate(prob: DelayProblem, b: float, alpha: float, method: str) -> DelaySolution:
    H = communication_rounds_alpha(b, alpha, prob.M, prob.eps, prob.nu, prob.c)
    T_cp = prob.g * b
    V = max(int(round(prob.nu * alpha)), 1)
    T = prob.T_cm + prob.nu * alpha * T_cp
    return DelaySolution(
        b=b, alpha=alpha, theta=float(np.exp(-alpha)), T_cp=T_cp, V=V,
        H=H, T_round=T, overall=H * T, method=method)


def closed_form(prob: DelayProblem) -> DelaySolution:
    """Eq. 29 verbatim (f_m/G_m at the bottleneck device = 1/g)."""
    inv_g = 1.0 / prob.g
    alpha = np.sqrt(prob.T_cm * inv_g / (prob.M ** 2 * prob.eps * prob.nu ** 2))
    b = 2.0 * prob.c * prob.M * np.sqrt(prob.T_cm * inv_g * prob.eps)
    b = max(b, 1.0)
    alpha = max(alpha, 1e-6)
    return evaluate(prob, b, alpha, method="closed_form")


def stationary_alpha(prob: DelayProblem, b: float) -> float:
    """Exact interior argmin over alpha at fixed b.

    Expanding (18): J(alpha) = A/alpha + B*alpha + C with
      A = c*T_cm/(b^2 eps^2 M nu),  B = c*M*nu*g/eps
    so argmin alpha = sqrt(A/B) = sqrt(T_cm/(eps M^2 nu^2 g)) / b.

    REPRODUCTION FINDING (validated in tests/test_kkt.py): the paper's
    Eq. 29 alpha* equals b * stationary_alpha(b) — i.e. Eq. 29 is the b=1
    stationary point; a factor of b was dropped in the paper's KKT algebra.
    We keep closed_form() faithful and expose this corrected point for the
    beyond-paper comparison (EXPERIMENTS.md §Perf).
    """
    return float(np.sqrt(prob.T_cm / (prob.eps * prob.M ** 2
                                      * prob.nu ** 2 * prob.g)) / b)


def corrected_solution(prob: DelayProblem, b_max: float = 64.0) -> DelaySolution:
    """Beyond-paper 'DEFL+' point: J is strictly decreasing in b
    (J = P/b^2 + Q/b + R, all positive), so b* sits at the practical upper
    bound (dataset/memory/generalization budget — constraint 15's
    'commonly used effective batch sizes'), with the exact stationary alpha.
    """
    b = float(b_max)
    # alpha floored at 1/nu so V = nu*alpha >= 1 (Eq. 12's regime).
    return evaluate(prob, b, max(stationary_alpha(prob, b), 1.0 / prob.nu),
                    method="corrected")


def grid_search(
    prob: DelayProblem,
    b_range=(1.0, 4096.0),
    alpha_range=(1e-3, 20.0),
    n: int = 160,
) -> DelaySolution:
    """Log-space grid over (b, alpha)."""
    bs = np.geomspace(*b_range, n)
    als = np.geomspace(*alpha_range, n)
    Bm, Am = np.meshgrid(bs, als, indexing="ij")
    H = (prob.c / (Bm ** 2 * prob.eps ** 2 * prob.M * prob.nu * Am)
         + prob.c * prob.M / (Bm * prob.eps))
    T = prob.T_cm + prob.nu * Am * prob.g * Bm
    J = H * T
    i, j = np.unravel_index(np.argmin(J), J.shape)
    return evaluate(prob, float(bs[i]), float(als[j]), method="grid")


def _golden_min(f, lo: float, hi: float, iters: int = 80) -> float:
    """Golden-section minimize a unimodal f on [lo, hi] (log-space)."""
    import math

    gr = (math.sqrt(5.0) - 1.0) / 2.0
    a, b = math.log(lo), math.log(hi)
    c = b - gr * (b - a)
    d = a + gr * (b - a)
    fc, fd = f(math.exp(c)), f(math.exp(d))
    for _ in range(iters):
        if fc < fd:
            b, d, fd = d, c, fc
            c = b - gr * (b - a)
            fc = f(math.exp(c))
        else:
            a, c, fc = c, d, fd
            d = a + gr * (b - a)
            fd = f(math.exp(d))
    return math.exp((a + b) / 2.0)


def coordinate_descent(
    prob: DelayProblem, b0: float = 32.0, alpha0: float = 1.0,
    sweeps: int = 8, b_max: float = 64.0, alpha_min: float = None,
) -> DelaySolution:
    """Numerical optimum of the BOUNDED problem: b in [1, b_max],
    alpha >= alpha_min (default 1/nu so that V >= 1).

    The unbounded relaxation of (18) is degenerate (inf J = 0 along
    b->inf, alpha->0 paths), so bounds are required for the numerical
    cross-check to be meaningful; see kkt.stationary_alpha docstring.
    J is unimodal per coordinate (A/x + Bx + C or P/x^2 + Q/x + R), so
    golden-section coordinate descent converges.
    """
    alpha_min = alpha_min if alpha_min is not None else 1.0 / prob.nu
    b, alpha = min(max(b0, 1.0), b_max), max(alpha0, alpha_min)
    for _ in range(sweeps):
        alpha = _golden_min(lambda a: objective(prob, b, a), alpha_min, 100.0)
        b = _golden_min(lambda bb: objective(prob, bb, alpha), 1.0, b_max)
    return evaluate(prob, b, alpha, method="numerical")


def solve(prob: DelayProblem, method: str = "closed_form",
          b_max: float = 64.0) -> DelaySolution:
    if method == "closed_form":
        return closed_form(prob)
    if method == "corrected":
        return corrected_solution(prob, b_max=b_max)
    if method == "numerical":
        grid = grid_search(prob, b_range=(1.0, b_max))
        return coordinate_descent(prob, grid.b, grid.alpha, b_max=b_max)
    raise ValueError(method)


def solve_batch(probs, method: str = "closed_form",
                b_max: float = 64.0):
    """`solve` over N problems at once, bit-identical to the scalar path.

    For method='closed_form' (the default, and what every plan=True study
    arm runs) the Eq. 29 algebra is evaluated as ONE (N,)-vectorized
    numpy dispatch instead of N scalar solves: every operation is an
    elementwise IEEE-754 double op (mul/div/sqrt/max), so each lane is
    bit-identical to `solve(probs[i])` — asserted in
    tests/test_plan_batch.py. Other methods (golden-section coordinate
    descent is inherently sequential per problem) fall back to the
    scalar loop, which is trivially identical.

    Returns a list of DelaySolution, one per problem, in order.
    """
    probs = list(probs)
    if not probs:
        return []
    if method != "closed_form":
        return [solve(p, method=method, b_max=b_max) for p in probs]
    T_cm = np.asarray([p.T_cm for p in probs], np.float64)
    g = np.asarray([p.g for p in probs], np.float64)
    M = np.asarray([p.M for p in probs], np.float64)
    eps = np.asarray([p.eps for p in probs], np.float64)
    nu = np.asarray([p.nu for p in probs], np.float64)
    c = np.asarray([p.c for p in probs], np.float64)
    inv_g = 1.0 / g
    alpha = np.sqrt(T_cm * inv_g / (M ** 2 * eps * nu ** 2))
    b = 2.0 * c * M * np.sqrt(T_cm * inv_g * eps)
    b = np.maximum(b, 1.0)
    alpha = np.maximum(alpha, 1e-6)
    return [evaluate(p, float(bi), float(ai), method="closed_form")
            for p, bi, ai in zip(probs, b, alpha)]
