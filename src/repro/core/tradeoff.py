"""Talk-vs-work trade-off analysis (§II-E) — curves for Fig. 1.

Decomposes predicted overall time into 'talking' (H * T_cm) and 'working'
(H * V * T_cp) for sweeps over theta, b and eps.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence


from repro.core import kkt
from repro.core.convergence import communication_rounds, local_rounds


@dataclass(frozen=True)
class TradeoffPoint:
    theta: float
    b: float
    V: int
    H: float
    talk_time: float  # H * T_cm
    work_time: float  # H * V * T_cp
    overall: float


def sweep_theta(
    prob: kkt.DelayProblem, b: float, thetas: Sequence[float],
) -> list[TradeoffPoint]:
    out = []
    for th in thetas:
        V = local_rounds(th, prob.nu)
        H = communication_rounds(b, th, prob.M, prob.eps, prob.nu, prob.c)
        T_cp = prob.g * b
        out.append(TradeoffPoint(
            theta=float(th), b=b, V=V, H=H,
            talk_time=H * prob.T_cm, work_time=H * V * T_cp,
            overall=H * (prob.T_cm + V * T_cp)))
    return out


def sweep_batch(
    prob: kkt.DelayProblem, theta: float, batches: Sequence[int],
) -> list[TradeoffPoint]:
    out = []
    V = local_rounds(theta, prob.nu)
    for b in batches:
        H = communication_rounds(b, theta, prob.M, prob.eps, prob.nu, prob.c)
        T_cp = prob.g * b
        out.append(TradeoffPoint(
            theta=theta, b=float(b), V=V, H=H,
            talk_time=H * prob.T_cm, work_time=H * V * T_cp,
            overall=H * (prob.T_cm + V * T_cp)))
    return out


def sweep_epsilon(
    base: kkt.DelayProblem, epsilons: Sequence[float],
) -> list[tuple[float, kkt.DelaySolution]]:
    """Fig. 1(a): optimized solution per preset epsilon."""
    out = []
    for eps in epsilons:
        prob = kkt.DelayProblem(
            T_cm=base.T_cm, g=base.g, M=base.M, eps=float(eps),
            nu=base.nu, c=base.c)
        out.append((float(eps), kkt.closed_form(prob).quantized(prob)))
    return out
