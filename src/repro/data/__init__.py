from repro.data.pipeline import BatchIterator, token_batches
from repro.data.synthetic import (
    ClassificationData,
    make_cifar_like,
    make_mnist_like,
    make_token_stream,
)
