"""Batching pipeline: deterministic, seeded, epoch-shuffled mini-batches."""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np

from repro.data.synthetic import ClassificationData


class BatchIterator:
    """Infinite shuffled mini-batch iterator over index-selected data."""

    def __init__(
        self, data: ClassificationData, indices: np.ndarray, batch_size: int,
        seed: int = 0,
    ):
        self.data = data
        self.indices = np.asarray(indices)
        self.batch_size = int(batch_size)
        self.rng = np.random.default_rng(seed)
        self._order = self.rng.permutation(self.indices)
        self._ptr = 0

    def next_batch(self) -> Dict[str, np.ndarray]:
        """Always returns exactly batch_size samples (fixed shapes keep one
        jit compilation across heterogeneous clients); small partitions
        sample with replacement."""
        n = len(self._order)
        bs = self.batch_size
        if n < bs:
            idx = self.rng.choice(self.indices, size=bs, replace=True)
            return {"x": self.data.x[idx], "y": self.data.y[idx]}
        if self._ptr + bs > n:
            self._order = self.rng.permutation(self.indices)
            self._ptr = 0
        idx = self._order[self._ptr : self._ptr + bs]
        self._ptr += bs
        return {"x": self.data.x[idx], "y": self.data.y[idx]}

    def batches(self, count: int) -> Iterator[Dict[str, np.ndarray]]:
        for _ in range(count):
            yield self.next_batch()


def token_batches(stream: np.ndarray, batch: int, seq: int, step: int, seed: int = 0):
    """Slice a token stream into (batch, seq+1) training windows."""
    rng = np.random.default_rng(seed + step)
    starts = rng.integers(0, len(stream) - seq - 1, batch)
    return np.stack([stream[s : s + seq + 1] for s in starts]).astype(np.int32)
