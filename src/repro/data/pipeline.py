"""Batching pipeline: deterministic, seeded, epoch-shuffled mini-batches."""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np

from repro.data.synthetic import ClassificationData


class BatchIterator:
    """Infinite shuffled mini-batch iterator over index-selected data.

    Two consumption styles share one RNG stream: `next_batch` gathers the
    sample arrays on the host (loop/batched backends), while
    `next_indices` returns only the drawn *global* row indices so the
    scan backend can keep the dataset device-resident and gather batches
    in-graph (`device_arrays` + `batch_from`). Interleaving the two styles
    keeps the draws aligned — `next_batch` is exactly
    `batch_from(host arrays, next_indices())`.
    """

    def __init__(
        self, data: ClassificationData, indices: np.ndarray, batch_size: int,
        seed: int = 0,
    ):
        self.data = data
        self.indices = np.asarray(indices)
        self.batch_size = int(batch_size)
        self.rng = np.random.default_rng(seed)
        self._reshuffle()

    def _reshuffle(self) -> None:
        """Start a new epoch: snapshot the RNG position the permutation is
        drawn from (what `state` stores instead of the permutation itself),
        then draw it."""
        self._epoch_rng = self.rng.bit_generator.state
        self._order = self.rng.permutation(self.indices)
        self._ptr = 0

    # -- snapshot / restore (SimState checkpointing) ------------------------
    def state(self) -> Dict:
        """Value snapshot of the draw position: the current RNG state, the
        RNG state the current epoch's permutation was drawn from, and the
        cursor. The permutation itself is NOT stored — `set_state`
        regenerates it from `epoch_rng` — so a snapshot is O(rng state),
        not O(partition size) (SimState carries one per client per
        checkpoint; at real dataset scale the old per-client `order`
        arrays dominated the checkpoint). Restoring via `set_state` — on
        this iterator or a freshly constructed one over the same
        data/partition — continues the batch stream bit-identically."""
        if self._epoch_rng is None:
            # Restored from a legacy snapshot: the epoch-start RNG
            # position is unknowable, so keep emitting the legacy
            # (permutation-inline) form until the next reshuffle records
            # one — otherwise this snapshot would be unrestorable.
            return {"rng": self.rng.bit_generator.state,
                    "order": self._order.copy(), "ptr": self._ptr}
        return {"rng": self.rng.bit_generator.state,
                "epoch_rng": self._epoch_rng, "ptr": self._ptr}

    def set_state(self, state: Dict) -> None:
        if "order" in state:  # legacy pre-PR5 snapshot: permutation inline
            self.rng.bit_generator.state = state["rng"]
            self._epoch_rng = None
            self._order = np.asarray(state["order"]).copy()
            self._ptr = int(state["ptr"])
            return
        # Replay the epoch's permutation draw from its recorded RNG
        # position, then restore the CURRENT position (ahead of the
        # epoch's whenever sample-with-replacement draws consumed the
        # stream since) — bit-identical to the state at snapshot time.
        self.rng.bit_generator.state = state["epoch_rng"]
        self._epoch_rng = state["epoch_rng"]
        self._order = self.rng.permutation(self.indices)
        self.rng.bit_generator.state = state["rng"]
        self._ptr = int(state["ptr"])

    def next_indices(self) -> np.ndarray:
        """Global row indices of the next mini-batch, always exactly
        batch_size of them (fixed shapes keep one jit compilation across
        heterogeneous clients); small partitions sample with replacement."""
        n = len(self._order)
        bs = self.batch_size
        if n < bs:
            return self.rng.choice(self.indices, size=bs, replace=True)
        if self._ptr + bs > n:
            self._reshuffle()
        idx = self._order[self._ptr : self._ptr + bs]
        self._ptr += bs
        return idx

    def device_arrays(self) -> Dict[str, np.ndarray]:
        """The full backing arrays for in-graph gathering. All iterators
        over the same dataset return views of the same arrays, so the
        simulator uploads them once per run, not once per client."""
        return {"x": self.data.x, "y": self.data.y}

    @staticmethod
    def batch_from(arrays: Dict, idx) -> Dict:
        """Gather a batch from (possibly device-resident) backing arrays by
        global indices. Works under jit/vmap/scan: with idx shaped
        (..., B) the leaves come out (..., B, sample...)."""
        return {"x": arrays["x"][idx], "y": arrays["y"][idx]}

    def next_batch(self) -> Dict[str, np.ndarray]:
        return self.batch_from(self.device_arrays(), self.next_indices())

    def batches(self, count: int) -> Iterator[Dict[str, np.ndarray]]:
        for _ in range(count):
            yield self.next_batch()


class ClientDataPool:
    """Lazy per-client batch-iterator pool for population-scale M.

    The dense data path materializes one `BatchIterator` per client up
    front (an M-long Python list — fine at M <= a few hundred, absurd at
    M = 10^5-10^6 when only K clients participate per round). The pool
    holds an `indices_fn(m)` instead and materializes a client's iterator
    on first touch, seeded `seed + m` — exactly the dense factory's
    per-client seed, so a pool over the same partition produces
    bit-identical batch streams to the dense list.

    Checkpoint state is O(touched clients): untouched clients carry no
    state (a fresh `BatchIterator(seed + m)` IS their snapshot), so
    `state()` snapshots only the materialized ones.
    """

    def __init__(self, data: ClassificationData, indices_fn, sizes,
                 batch_size: int, seed: int = 0):
        self.data = data
        self._indices_fn = indices_fn
        self.sizes = np.asarray(sizes, np.int64)
        self.batch_size = int(batch_size)
        self.seed = int(seed)
        self._iters: Dict[int, BatchIterator] = {}

    @classmethod
    def from_parts(cls, data: ClassificationData, parts, batch_size: int,
                   seed: int = 0) -> "ClientDataPool":
        """Pool over an explicit partition list (small-M sampled runs):
        same indices, same per-client seeds as the dense factory."""
        sizes = np.array([len(p) for p in parts], np.int64)
        return cls(data, lambda m: parts[m], sizes, batch_size, seed)

    def __len__(self) -> int:
        return len(self.sizes)

    def client(self, m: int) -> BatchIterator:
        it = self._iters.get(m)
        if it is None:
            it = BatchIterator(self.data, self._indices_fn(m),
                               self.batch_size, seed=self.seed + m)
            self._iters[m] = it
        return it

    # -- snapshot / restore (SimState checkpointing) ------------------------
    def state(self) -> Dict:
        return {"clients": {int(m): it.state()
                            for m, it in self._iters.items()}}

    def set_state(self, state: Dict) -> None:
        self._iters = {}
        for m, s in state.get("clients", {}).items():
            self.client(int(m)).set_state(s)

    # -- device-resident gathering (scan backend) ---------------------------
    def device_arrays(self) -> Dict[str, np.ndarray]:
        return {"x": self.data.x, "y": self.data.y}

    batch_from = staticmethod(BatchIterator.batch_from)


def token_batches(stream: np.ndarray, batch: int, seq: int, step: int, seed: int = 0):
    """Slice a token stream into (batch, seq+1) training windows."""
    rng = np.random.default_rng(seed + step)
    starts = rng.integers(0, len(stream) - seq - 1, batch)
    return np.stack([stream[s : s + seq + 1] for s in starts]).astype(np.int32)
