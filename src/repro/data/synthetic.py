"""Synthetic datasets (the container is offline — see DESIGN.md §7).

Classification sets mimic MNIST / CIFAR-10 in shape and cardinality: inputs
are drawn from per-class Gaussian blobs pushed through a fixed random
teacher CNN-ish map, giving a learnable but non-trivial task. Token streams
serve the LM architectures.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class ClassificationData:
    x: np.ndarray  # (N, H, W, C) float32
    y: np.ndarray  # (N,) int32
    n_classes: int

    @property
    def n(self) -> int:
        return len(self.y)


def _teacher_features(rng, n, hw, c, n_classes, y):
    """Class-conditional images: smooth class template + structured noise."""
    h, w = hw
    # Low-frequency class templates upsampled from 7x7 seeds.
    seeds = rng.normal(0.0, 1.0, (n_classes, 7, 7, c)).astype(np.float32)
    reps = (int(np.ceil(h / 7)), int(np.ceil(w / 7)))
    templates = np.kron(seeds, np.ones((1, *reps, 1), np.float32))[:, :h, :w, :]
    x = templates[y]
    x = x + rng.normal(0.0, 0.8, x.shape).astype(np.float32)
    # Mild nonlinearity so linear probes don't trivially solve it.
    return np.tanh(x).astype(np.float32)


def make_mnist_like(n: int = 10_000, seed: int = 0) -> ClassificationData:
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 10, n).astype(np.int32)
    x = _teacher_features(rng, n, (28, 28), 1, 10, y)
    return ClassificationData(x=x, y=y, n_classes=10)


def make_cifar_like(n: int = 10_000, seed: int = 0) -> ClassificationData:
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 10, n).astype(np.int32)
    x = _teacher_features(rng, n, (32, 32), 3, 10, y)
    return ClassificationData(x=x, y=y, n_classes=10)


def make_token_stream(
    n_tokens: int, vocab_size: int, seed: int = 0, order: int = 2,
) -> np.ndarray:
    """Markov-ish synthetic token stream (learnable bigram structure)."""
    rng = np.random.default_rng(seed)
    # Sparse bigram transition: each token strongly prefers a few successors.
    fanout = 8
    succ = rng.integers(0, vocab_size, (vocab_size, fanout))
    toks = np.empty(n_tokens, np.int32)
    toks[0] = rng.integers(0, vocab_size)
    noise = rng.random(n_tokens)
    choice = rng.integers(0, fanout, n_tokens)
    rand_tok = rng.integers(0, vocab_size, n_tokens)
    for i in range(1, n_tokens):
        toks[i] = succ[toks[i - 1], choice[i]] if noise[i] < 0.8 else rand_tok[i]
    return toks
