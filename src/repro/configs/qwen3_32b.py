"""qwen3-32b [dense] — 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936, qk_norm. [hf:Qwen/Qwen3-8B family]"""
from repro.configs.base import AttentionConfig, ModelConfig

ARCH_ID = "qwen3-32b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",
        source="hf:Qwen/Qwen3-8B",
        n_layers=64,
        d_model=5120,
        vocab_size=151_936,
        d_ff=25_600,
        attention=AttentionConfig(
            n_heads=64, n_kv_heads=8, head_dim=128, qk_norm=True,
            rope_theta=1e6,
        ),
        mixer="attention",
        mlp="dense",
    )


def make_smoke_config() -> ModelConfig:
    return make_config().replace(
        n_layers=2,
        d_model=128,
        vocab_size=512,
        d_ff=512,
        attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=32, qk_norm=True),
    )
