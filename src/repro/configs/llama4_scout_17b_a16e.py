"""llama4-scout-17b-a16e [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff(expert)=8192 vocab=202048, MoE 16 experts top-1 + shared expert.
[hf:meta-llama/Llama-4-Scout-17B-16E]"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

ARCH_ID = "llama4-scout-17b-a16e"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="moe",
        source="hf:meta-llama/Llama-4-Scout-17B-16E",
        n_layers=48,
        d_model=5120,
        vocab_size=202_048,
        attention=AttentionConfig(
            n_heads=40, n_kv_heads=8, head_dim=128, rope_theta=5e5,
        ),
        moe=MoEConfig(
            n_experts=16, top_k=1, d_ff_expert=8192, shared_expert_d_ff=8192,
        ),
        mixer="attention",
        mlp="moe",
    )


def make_smoke_config() -> ModelConfig:
    return make_config().replace(
        n_layers=2,
        d_model=128,
        vocab_size=512,
        attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=32),
        moe=MoEConfig(n_experts=4, top_k=1, d_ff_expert=64, shared_expert_d_ff=64),
    )
