"""llava-next-34b [vlm] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000, anyres tiling. [hf:llava-hf/llava-v1.6-mistral-7b-hf]

The ViT/SigLIP vision tower + anyres tiling is a STUB per the assignment
carve-out: input_specs supplies precomputed patch embeddings
(prefix_len x embed_dim); we implement the language decoder + projector.
"""
from repro.configs.base import AttentionConfig, ModalityConfig, ModelConfig

ARCH_ID = "llava-next-34b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="vlm",
        source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
        n_layers=60,
        d_model=7168,
        vocab_size=64_000,
        d_ff=20_480,
        attention=AttentionConfig(
            n_heads=56, n_kv_heads=8, head_dim=128, rope_theta=5e6,
        ),
        modality=ModalityConfig(kind="vision", embed_dim=1024, prefix_len=1152),
        mixer="attention",
        mlp="dense",
    )


def make_smoke_config() -> ModelConfig:
    return make_config().replace(
        n_layers=2,
        d_model=128,
        vocab_size=512,
        d_ff=512,
        attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=32),
        modality=ModalityConfig(kind="vision", embed_dim=64, prefix_len=16),
    )
