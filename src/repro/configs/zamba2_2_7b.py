"""zamba2-2.7b [hybrid] — 54L d_model=2560, Mamba2 backbone (ssm_state=64)
with a tied shared attention block (32H) every 6 layers. [arXiv:2411.15242]"""
from repro.configs.base import ModelConfig, SSMConfig

ARCH_ID = "zamba2-2.7b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="hybrid",
        source="arXiv:2411.15242",
        n_layers=54,
        d_model=2560,
        vocab_size=32_000,
        ssm=SSMConfig(kind="mamba2", d_state=64, d_conv=4, expand=2, head_dim=64),
        mixer="mamba2",
        mlp="none",
        shared_attn_every=6,
        shared_attn_heads=32,
        scan_group=6,
        tie_embeddings=True,
    )


def make_smoke_config() -> ModelConfig:
    return make_config().replace(
        n_layers=2,
        d_model=128,
        vocab_size=512,
        ssm=SSMConfig(kind="mamba2", d_state=16, d_conv=4, expand=2, head_dim=32, chunk=32),
        shared_attn_every=2,
        shared_attn_heads=4,
        scan_group=2,
    )
