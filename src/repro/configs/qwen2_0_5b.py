"""qwen2-0.5b [dense] — 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936.
GQA with QKV bias. [arXiv:2407.10671]"""
from repro.configs.base import AttentionConfig, ModelConfig

ARCH_ID = "qwen2-0.5b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",
        source="arXiv:2407.10671",
        n_layers=24,
        d_model=896,
        vocab_size=151_936,
        d_ff=4864,
        attention=AttentionConfig(
            n_heads=14, n_kv_heads=2, head_dim=64, qkv_bias=True,
            rope_theta=1e6,
        ),
        mixer="attention",
        mlp="dense",
        tie_embeddings=True,
    )


def make_smoke_config() -> ModelConfig:
    return make_config().replace(
        n_layers=2,
        d_model=128,
        vocab_size=512,
        d_ff=256,
        attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=32, qkv_bias=True),
    )
