"""musicgen-large [audio] — 48L d_model=2048 32H (kv=32) d_ff=8192
vocab=2048, decoder-only over EnCodec tokens (4 codebooks, delay pattern).
[arXiv:2306.05284]

The mel-spectrogram/EnCodec frontend is a STUB per the assignment carve-out:
input_specs supplies the 4-codebook token grid plus precomputed text-
conditioning embeddings; we implement the decoder (summed codebook
embeddings, K parallel LM heads).
"""
from repro.configs.base import AttentionConfig, ModalityConfig, ModelConfig

ARCH_ID = "musicgen-large"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="audio",
        source="arXiv:2306.05284",
        n_layers=48,
        d_model=2048,
        vocab_size=2048,
        d_ff=8192,
        attention=AttentionConfig(n_heads=32, n_kv_heads=32, head_dim=64),
        modality=ModalityConfig(
            kind="audio", embed_dim=1536, prefix_len=128, n_codebooks=4,
        ),
        mixer="attention",
        mlp="dense",
        act="gelu",
    )


def make_smoke_config() -> ModelConfig:
    return make_config().replace(
        n_layers=2,
        d_model=128,
        vocab_size=256,
        d_ff=256,
        attention=AttentionConfig(n_heads=4, n_kv_heads=4, head_dim=32),
        modality=ModalityConfig(kind="audio", embed_dim=64, prefix_len=8, n_codebooks=4),
    )
