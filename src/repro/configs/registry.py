"""Architecture registry: --arch <id> resolution."""
from __future__ import annotations

from typing import Callable, Dict

from repro.configs import (
    falcon_mamba_7b,
    gemma_7b,
    llama4_scout_17b_a16e,
    llava_next_34b,
    moonshot_v1_16b_a3b,
    musicgen_large,
    qwen2_0_5b,
    qwen3_32b,
    qwen3_moe_30b_a3b,
    zamba2_2_7b,
)
from repro.configs.base import ModelConfig

_MODULES = [
    qwen3_moe_30b_a3b,
    qwen2_0_5b,
    gemma_7b,
    zamba2_2_7b,
    qwen3_32b,
    falcon_mamba_7b,
    llama4_scout_17b_a16e,
    moonshot_v1_16b_a3b,
    llava_next_34b,
    musicgen_large,
]

ARCH_IDS = [m.ARCH_ID for m in _MODULES]

_FULL: Dict[str, Callable[[], ModelConfig]] = {m.ARCH_ID: m.make_config for m in _MODULES}
_SMOKE: Dict[str, Callable[[], ModelConfig]] = {
    m.ARCH_ID: m.make_smoke_config for m in _MODULES
}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    table = _SMOKE if smoke else _FULL
    if arch not in table:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(table)}")
    return table[arch]()


def all_configs(smoke: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, smoke=smoke) for a in ARCH_IDS}
