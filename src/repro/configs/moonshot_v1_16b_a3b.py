"""moonshot-v1-16b-a3b — 48L d_model=2048 16H (GQA kv=16) d_ff(expert)=1408
vocab=163840, MoE 64 experts top-6. [hf:moonshotai/Moonlight-16B-A3B]

NOTE: the assignment brackets this as [dense] but its spec carries
``MoE 64e top-6``; the concrete expert numbers win — implemented as MoE
(discrepancy recorded in DESIGN.md §4).
"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

ARCH_ID = "moonshot-v1-16b-a3b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="moe",
        source="hf:moonshotai/Moonlight-16B-A3B",
        n_layers=48,
        d_model=2048,
        vocab_size=163_840,
        attention=AttentionConfig(n_heads=16, n_kv_heads=16, head_dim=128),
        moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408,
                      shared_expert_d_ff=2816),
        mixer="attention",
        mlp="moe",
    )


def make_smoke_config() -> ModelConfig:
    return make_config().replace(
        n_layers=2,
        d_model=128,
        vocab_size=512,
        attention=AttentionConfig(n_heads=4, n_kv_heads=4, head_dim=32),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64, shared_expert_d_ff=64),
    )
