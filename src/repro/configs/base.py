"""Config system: model / federated / wireless / run configs.

Every assigned architecture gets a ``configs/<id>.py`` exporting
``make_config()`` (the exact published shape) and ``make_smoke_config()``
(a reduced variant: <=2 layers, d_model<=512, <=4 experts) for CPU tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AttentionConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    # None = full causal attention; int = sliding-window size. The
    # long_500k shape requires sub-quadratic attention: dense archs run it
    # through this flag (see DESIGN.md §4).
    sliding_window: Optional[int] = None


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    # Optional always-on shared expert (Llama-4 style).
    shared_expert_d_ff: Optional[int] = None
    router_aux_weight: float = 0.01
    capacity_factor: float = 1.25
    # 'global': one capacity buffer over all tokens (baseline; under GSPMD
    # the (E, C, d) buffer's C dim is unsharded, replicating expert GEMMs
    # across the data axis). 'batched': dispatch per batch row so the
    # buffer is (B, E, C_b, d), sharded batch x expert — EXPERIMENTS.md
    # §Perf iteration C.
    dispatch: str = "global"


@dataclass(frozen=True)
class SSMConfig:
    kind: str  # 'mamba1' | 'mamba2'
    d_state: int
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64  # mamba2 only
    n_groups: int = 1  # mamba2 only
    chunk: int = 128  # scan chunk length


@dataclass(frozen=True)
class ModalityConfig:
    """Stub frontend description for [vlm]/[audio] archs.

    The frontend itself is NOT implemented (assignment carve-out): input_specs
    provides precomputed patch/frame embeddings with ``embed_dim`` features and
    ``prefix_len`` positions, which the decoder consumes via a linear projector.
    """

    kind: str  # 'vision' | 'audio'
    embed_dim: int
    prefix_len: int
    n_codebooks: int = 1  # audio: EnCodec codebooks (parallel heads)


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | vlm | audio
    source: str  # citation bracket from the assignment
    n_layers: int
    d_model: int
    vocab_size: int
    d_ff: int = 0  # dense-MLP hidden size (0 for attn-free / pure-MoE)
    attention: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    modality: Optional[ModalityConfig] = None
    # 'attention' | 'mamba1' | 'mamba2' — the per-layer sequence mixer.
    mixer: str = "attention"
    # 'dense' | 'moe' | 'none' — the per-layer channel mixer.
    mlp: str = "dense"
    act: str = "silu"  # 'silu' (SwiGLU) | 'gelu' (GeGLU)
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # Zamba2-style tied shared attention+MLP block applied every k backbone
    # layers (None = no shared block).
    shared_attn_every: Optional[int] = None
    shared_attn_heads: int = 32
    # Layers per scan group; the layer stack is scanned over
    # n_layers // scan_group groups (shared_attn blocks run between groups).
    scan_group: int = 1
    # Rematerialize activations in training (checkpoint per scan group).
    # Perf lever: off trades HBM for ~25% less compute (no re-forward).
    remat: bool = True
    dtype: str = "bfloat16"

    # -- derived -----------------------------------------------------------
    @property
    def attn_dim(self) -> int:
        a = self.attention
        return a.n_heads * a.head_dim if a else 0

    @property
    def n_scan_groups(self) -> int:
        assert self.n_layers % self.scan_group == 0, (
            f"{self.name}: n_layers={self.n_layers} % scan_group="
            f"{self.scan_group} != 0"
        )
        return self.n_layers // self.scan_group

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- analytic parameter counts ------------------------------------------
    def _attn_params(self, heads: int, kv: int, hd: int) -> int:
        d = self.d_model
        p = d * heads * hd + 2 * d * kv * hd + heads * hd * d
        if self.attention and self.attention.qkv_bias:
            p += (heads + 2 * kv) * hd
        if self.attention and self.attention.qk_norm:
            p += 2 * hd
        return p

    def _dense_mlp_params(self, d_ff: int) -> int:
        return 3 * self.d_model * d_ff  # gate, up, down

    def _moe_params(self) -> Tuple[int, int]:
        """(total, active) MoE params per layer."""
        m = self.moe
        e = 3 * self.d_model * m.d_ff_expert
        total = m.n_experts * e + self.d_model * m.n_experts
        active = m.top_k * e + self.d_model * m.n_experts
        if m.shared_expert_d_ff:
            s = self._dense_mlp_params(m.shared_expert_d_ff)
            total += s
            active += s
        return total, active

    def _ssm_params(self) -> int:
        s = self.ssm
        d = self.d_model
        d_in = s.expand * d
        if s.kind == "mamba1":
            dt_rank = max(d // 16, 1)
            p = d * 2 * d_in  # in_proj
            p += d_in * s.d_conv + d_in  # conv1d + bias
            p += d_in * (dt_rank + 2 * s.d_state)  # x_proj
            p += dt_rank * d_in + d_in  # dt_proj
            p += d_in * s.d_state + d_in  # A_log, D
            p += d_in * d  # out_proj
            return p
        # mamba2
        n_heads = d_in // s.head_dim
        conv_dim = d_in + 2 * s.n_groups * s.d_state
        p = d * (2 * d_in + 2 * s.n_groups * s.d_state + n_heads)  # in_proj
        p += conv_dim * s.d_conv + conv_dim  # conv1d
        p += 3 * n_heads  # A_log, D, dt_bias
        p += d_in  # gated rmsnorm
        p += d_in * d  # out_proj
        return p

    def param_count(self) -> Tuple[int, int]:
        """Analytic (total, active) parameter count. Approximate to ~1%."""
        d = self.d_model
        total = self.vocab_size * d  # embedding
        if self.modality and self.modality.kind == "audio":
            total += (self.modality.n_codebooks - 1) * self.vocab_size * d
        if self.modality:
            total += self.modality.embed_dim * d + d  # projector
        per_layer = 2 * d  # 2 rmsnorm scales
        if self.mixer == "attention":
            a = self.attention
            per_layer += self._attn_params(a.n_heads, a.n_kv_heads, a.head_dim)
        else:
            per_layer += self._ssm_params()
        active_per_layer = per_layer
        if self.mlp == "dense":
            per_layer += self._dense_mlp_params(self.d_ff)
            active_per_layer += self._dense_mlp_params(self.d_ff)
        elif self.mlp == "moe":
            t, a_ = self._moe_params()
            per_layer += t
            active_per_layer += a_
        total_layers = total + self.n_layers * per_layer
        active = total + self.n_layers * active_per_layer
        if self.shared_attn_every:
            hd = d // self.shared_attn_heads
            shared = self._attn_params(self.shared_attn_heads, self.shared_attn_heads, hd)
            shared += self._dense_mlp_params(4 * d) + 2 * d
            total_layers += shared
            active += shared
        total_layers += d  # final norm
        active += d
        if not self.tie_embeddings:
            n_heads_out = self.modality.n_codebooks if self.modality else 1
            total_layers += n_heads_out * d * self.vocab_size
            active += n_heads_out * d * self.vocab_size
        return int(total_layers), int(active)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Federated / wireless / run configs (the paper's system model)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class WirelessConfig:
    """Paper §II-C communication model parameters (Eq. 6)."""

    bandwidth_hz: float = 20e6  # B = 20 MHz
    noise_dbm_per_hz: float = -174.0  # N_o
    tx_power_w: float = 0.5  # p_m
    # Channel gains h_m are drawn per device by the simulator; this is the
    # mean pathloss used when a deterministic value is needed.
    mean_channel_gain: float = 1e-8


@dataclass(frozen=True)
class ComputeConfig:
    """Paper §II-B computation model parameters (Eqs. 3-4)."""

    # GPU frequency model constants (Eq. 3), from Abe et al. [12].
    a_s: float = 1e-10
    a_c: float = 0.7
    a_m: float = 0.3
    core_freq_hz: float = 2.0e9  # f_c (paper: 2 GHz cap)
    mem_freq_hz: float = 7.0e9  # f_M
    cycles_per_bit: float = 30.0  # G_m base (paper: 30 cycles/bit)
    # Per-sample bits processed per iteration (dataset dependent).
    bits_per_sample: float = 28 * 28 * 8.0


@dataclass(frozen=True)
class FedConfig:
    """DEFL algorithm configuration (Alg. 1)."""

    n_devices: int = 10  # M
    epsilon: float = 0.01  # preset global convergence error
    theta: float = 0.15  # relative local error (theta* from Eq. 29)
    batch_size: int = 32  # b (b* from Eq. 29)
    nu: float = 2.0  # ν: step-size/gradient-noise constant (Remark 3)
    c: float = 1.0  # big-O constant of Eq. 12
    lr: float = 0.01
    update_bytes: Optional[int] = None  # s; None -> actual param bytes
    # Beyond-paper: int8 update compression on the uplink.
    compress_updates: bool = False
    seed: int = 0

    @property
    def local_rounds(self) -> int:
        """V = ν·log(1/θ) (Remark 3), at least 1."""
        return max(int(round(self.nu * np.log(1.0 / max(self.theta, 1e-9)))), 1)


@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False

    @property
    def shape(self):
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self):
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")

    @property
    def n_devices(self) -> int:
        return int(np.prod(self.shape))

    @property
    def client_axes(self):
        return ("pod", "data") if self.multi_pod else ("data",)

    @property
    def n_clients(self) -> int:
        return 32 if self.multi_pod else 16
