"""gemma-7b [dense] — 28L d_model=3072 16H (kv=16) d_ff=24576 vocab=256000.
GeGLU MLP, head_dim=256. [arXiv:2403.08295]"""
from repro.configs.base import AttentionConfig, ModelConfig

ARCH_ID = "gemma-7b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="dense",
        source="arXiv:2403.08295",
        n_layers=28,
        d_model=3072,
        vocab_size=256_000,
        d_ff=24_576,
        attention=AttentionConfig(n_heads=16, n_kv_heads=16, head_dim=256),
        mixer="attention",
        mlp="dense",
        act="gelu",
        tie_embeddings=True,
    )


def make_smoke_config() -> ModelConfig:
    return make_config().replace(
        n_layers=2,
        d_model=128,
        vocab_size=512,
        d_ff=512,
        attention=AttentionConfig(n_heads=4, n_kv_heads=4, head_dim=32),
    )
