"""qwen3-moe-30b-a3b [moe] — 48L d_model=2048 32H (GQA kv=4) d_ff(expert)=768
vocab=151936, MoE 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B]"""
from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

ARCH_ID = "qwen3-moe-30b-a3b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="moe",
        source="hf:Qwen/Qwen3-30B-A3B",
        n_layers=48,
        d_model=2048,
        vocab_size=151_936,
        attention=AttentionConfig(
            n_heads=32, n_kv_heads=4, head_dim=128, qk_norm=True,
            rope_theta=1e6,
        ),
        moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=768),
        mixer="attention",
        mlp="moe",
    )


def make_smoke_config() -> ModelConfig:
    return make_config().replace(
        n_layers=2,
        d_model=128,
        vocab_size=512,
        attention=AttentionConfig(n_heads=4, n_kv_heads=2, head_dim=32, qk_norm=True),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64),
    )
