from repro.configs.base import (
    AttentionConfig,
    ComputeConfig,
    FedConfig,
    InputShape,
    INPUT_SHAPES,
    MeshConfig,
    ModalityConfig,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    WirelessConfig,
)
