"""falcon-mamba-7b [ssm] — 64L d_model=4096, attention-free Mamba-1 blocks,
ssm_state=16, vocab=65024. [arXiv:2410.05355]"""
from repro.configs.base import ModelConfig, SSMConfig

ARCH_ID = "falcon-mamba-7b"


def make_config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        arch_type="ssm",
        source="arXiv:2410.05355",
        n_layers=64,
        d_model=4096,
        vocab_size=65_024,
        ssm=SSMConfig(kind="mamba1", d_state=16, d_conv=4, expand=2),
        mixer="mamba1",
        mlp="none",
        tie_embeddings=True,
    )


def make_smoke_config() -> ModelConfig:
    return make_config().replace(
        n_layers=2,
        d_model=128,
        vocab_size=512,
        ssm=SSMConfig(kind="mamba1", d_state=8, d_conv=4, expand=2, chunk=32),
    )
