from repro.utils.tree import (
    tree_bytes,
    tree_count,
    tree_zeros_like,
    tree_add,
    tree_scale,
    tree_weighted_mean,
    tree_allclose,
    tree_any_nan,
)
