"""Roofline constants and analytic MODEL_FLOPS (6*N_active*D)."""
from __future__ import annotations

from repro.configs.base import InputShape, ModelConfig

# TPU v5e per-chip constants (assignment-specified).
PEAK_FLOPS = 197e12  # bf16 FLOP/s
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link


def tokens_per_call(cfg: ModelConfig, shape: InputShape, V: int = 1) -> int:
    if shape.kind == "train":
        return shape.global_batch * shape.seq_len * V
    if shape.kind == "prefill":
        return shape.global_batch * shape.seq_len
    return shape.global_batch  # decode: one token per sequence


def model_flops(cfg: ModelConfig, shape: InputShape, V: int = 1) -> float:
    """6*N_active*D for training (fwd+bwd), 2*N_active*D for inference."""
    _, n_active = cfg.param_count()
    D = tokens_per_call(cfg, shape, V)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * D
