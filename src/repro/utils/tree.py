"""Pytree utilities used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_count(tree) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes across all leaves (dtype-aware)."""
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree.leaves(tree)
    )


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_scale(tree, s):
    return jax.tree.map(lambda x: x * s, tree)


def tree_weighted_mean(trees, weights):
    """Weighted mean of a list of pytrees. weights need not be normalized."""
    w = jnp.asarray(weights, dtype=jnp.float32)
    w = w / jnp.sum(w)
    out = tree_scale(trees[0], w[0])
    for i in range(1, len(trees)):
        out = tree_add(out, tree_scale(trees[i], w[i]))
    return out


def tree_allclose(a, b, rtol=1e-5, atol=1e-6) -> bool:
    leaves_a, treedef_a = jax.tree.flatten(a)
    leaves_b, treedef_b = jax.tree.flatten(b)
    if treedef_a != treedef_b:
        return False
    return all(
        np.allclose(np.asarray(x), np.asarray(y), rtol=rtol, atol=atol)
        for x, y in zip(leaves_a, leaves_b)
    )


def tree_any_nan(tree) -> bool:
    return any(bool(jnp.any(jnp.isnan(x))) for x in jax.tree.leaves(tree))
