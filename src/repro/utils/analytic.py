"""Analytic roofline cost model.

WHY THIS EXISTS (validated in EXPERIMENTS.md §Dry-run): XLA's
HloCostAnalysis visits each while-loop body ONCE, so for scan-over-layers
programs `compiled.cost_analysis()` under-counts FLOPs/bytes by ~(layers x
V) and the HLO text shows in-loop collectives once. Out-of-loop ops (the
FedAvg param sync — the dominant collective for training) are counted
correctly. We therefore report BOTH the raw HLO-derived terms and these
analytic totals; the analytic model is exact in the matmul terms
("as-written" semantics: dense full-S attention scores, all-E expert
capacity GEMMs) and approximate (~20%) in elementwise terms.
"""
from __future__ import annotations

from typing import Dict


from repro.configs.base import InputShape, MeshConfig, ModelConfig
from repro.models.moe import moe_capacity


def _attn_flops_per_token(cfg: ModelConfig, ctx: int) -> float:
    """Projections + score/PV terms against a ctx-length context."""
    a = cfg.attention
    d = cfg.d_model
    proj = 2 * d * (a.n_heads + 2 * a.n_kv_heads) * a.head_dim \
        + 2 * a.n_heads * a.head_dim * d
    scores = 4 * ctx * a.n_heads * a.head_dim  # QK^T + PV, as-written (full S)
    return proj + scores


def _mlp_flops_per_token(cfg: ModelConfig) -> float:
    return 2 * 3 * cfg.d_model * cfg.d_ff


def _moe_flops_per_token(cfg: ModelConfig, n_tokens: int) -> float:
    m = cfg.moe
    d = cfg.d_model
    router = 2 * d * m.n_experts
    C = moe_capacity(n_tokens, m, m.capacity_factor)
    # Capacity GEMMs process E*C rows regardless of fill: per-token share.
    expert_rows = m.n_experts * C / max(n_tokens, 1)
    experts = expert_rows * 2 * 3 * d * m.d_ff_expert
    shared = 2 * 3 * d * m.shared_expert_d_ff if m.shared_expert_d_ff else 0
    return router + experts + shared


def _ssm_flops_per_token(cfg: ModelConfig) -> float:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    if s.kind == "mamba1":
        rank = max(d // 16, 1)
        proj = 2 * d * 2 * d_in + 2 * d_in * (rank + 2 * s.d_state) \
            + 2 * rank * d_in + 2 * d_in * d
        scan = 10 * d_in * s.d_state  # exp, recurrence, output dot
        conv = 2 * s.d_conv * d_in
        return proj + scan + conv
    H = d_in // s.head_dim
    P = s.head_dim
    N = s.d_state
    L = s.chunk
    proj = 2 * d * (2 * d_in + 2 * s.n_groups * N + H) + 2 * d_in * d
    conv = 2 * s.d_conv * (d_in + 2 * s.n_groups * N)
    # SSD per token: scores 2LN + intra 2LHP + states/inter ~4NHP.
    ssd = 2 * L * N + 2 * L * H * P + 4 * N * H * P
    return proj + conv + ssd


def _shared_block_flops_per_token(cfg: ModelConfig, ctx: int) -> float:
    d = cfg.d_model
    proj = 8 * d * d  # qkvo at full MHA heads
    scores = 4 * ctx * d
    return proj + scores + 2 * 3 * d * 4 * d  # + 4d GLU mlp


def flops_per_token(cfg: ModelConfig, ctx: int, n_tokens_for_moe: int) -> float:
    per_layer = 0.0
    if cfg.mixer == "attention":
        per_layer += _attn_flops_per_token(cfg, ctx)
    else:
        per_layer += _ssm_flops_per_token(cfg)
    if cfg.mlp == "dense":
        per_layer += _mlp_flops_per_token(cfg)
    elif cfg.mlp == "moe":
        per_layer += _moe_flops_per_token(cfg, n_tokens_for_moe)
    total = per_layer * cfg.n_layers
    if cfg.shared_attn_every:
        n_shared = cfg.n_layers // cfg.shared_attn_every
        total += n_shared * _shared_block_flops_per_token(cfg, ctx)
    n_heads_out = cfg.modality.n_codebooks if (
        cfg.modality and cfg.modality.kind == "audio") else 1
    total += 2 * cfg.d_model * cfg.vocab_size * n_heads_out  # logits
    return total


def analytic_costs(
    cfg: ModelConfig, shape: InputShape, mesh_cfg: MeshConfig, V: int = 1,
    param_bytes: int = 4, attn_ctx_factor: float = 1.0,
) -> Dict:
    """Global FLOPs + per-device HBM bytes + per-device in-loop collective
    wire bytes for one jitted call of the (arch x shape) pair."""
    n_dev = mesh_cfg.n_devices
    msize = 16  # model-axis size on both meshes
    total_p, active_p = cfg.param_count()
    B, S = shape.global_batch, shape.seq_len
    window = cfg.attention.sliding_window if cfg.attention else None

    if shape.kind == "train":
        tokens = B * S * V
        ctx = min(S, window) if window else S
        ctx = max(int(ctx * attn_ctx_factor), 1)
        f = flops_per_token(cfg, ctx, B * S) * tokens
        f *= 4.0 if cfg.remat else 3.0  # fwd + bwd(2x) [+ remat re-fwd]
        # HBM: V local steps each stream params 3x (fwd/bwd/update) + acts.
        # Each device holds ONE client's model-shard: total_p / msize.
        p_dev = total_p * param_bytes / msize
        act = tokens / (n_dev / msize) * cfg.d_model * 2 * cfg.n_layers * 6
        hbm_dev = V * 4 * p_dev + act
        # In-loop TP collectives: 2 activation all-reduces per layer per pass,
        # 3 passes (fwd/bwd/remat), over the model axis.
        act_bytes = tokens / (n_dev / msize) * cfg.d_model * 2
        coll_inloop_dev = (2 * cfg.n_layers * 3 * 2 * act_bytes
                           * (msize - 1) / msize) / msize
    elif shape.kind == "prefill":
        tokens = B * S
        ctx = min(S, window) if window else S
        ctx = max(int(ctx * attn_ctx_factor), 1)
        f = flops_per_token(cfg, ctx, tokens)
        f *= tokens
        p_dev = total_p * param_bytes / msize
        act = tokens / (n_dev / msize) * cfg.d_model * 2 * cfg.n_layers * 4
        kv = 0
        if cfg.attention:
            a = cfg.attention
            L = min(S, window) if window else S
            kv = B * L * a.n_kv_heads * a.head_dim * 2 * 2 * cfg.n_layers / n_dev
        hbm_dev = p_dev + act + kv
        act_bytes = tokens / (n_dev / msize) * cfg.d_model * 2
        coll_inloop_dev = (2 * cfg.n_layers * act_bytes
                           * (msize - 1) / msize) / msize
    else:  # decode: one token against the cache
        tokens = B
        ctx = min(S, window) if window else S
        f = flops_per_token(cfg, ctx, tokens) * tokens
        p_dev = total_p * param_bytes / msize  # all params stream per step
        kv = 0.0
        if cfg.attention:
            a = cfg.attention
            L = min(S, window) if window else S
            kv = B * L * a.n_kv_heads * a.head_dim * 2 * 2 * cfg.n_layers
        if cfg.ssm:
            d_in = cfg.ssm.expand * cfg.d_model
            kv += B * d_in * cfg.ssm.d_state * 4 * cfg.n_layers * 2
        hbm_dev = p_dev + kv / n_dev  # cache sharded batch x model
        act_bytes = tokens * cfg.d_model * 2
        coll_inloop_dev = (2 * cfg.n_layers * act_bytes
                           * (msize - 1) / msize) / msize

    return {
        "flops_global": float(f),
        "flops_per_device": float(f / n_dev),
        "hbm_bytes_per_device": float(hbm_dev),
        "collective_inloop_wire_bytes_per_device": float(coll_inloop_dev),
        "tokens": int(tokens),
    }
