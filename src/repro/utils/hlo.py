"""Parse collective ops out of compiled HLO text for the roofline's
collective term (cost_analysis does not report collective bytes).

The compiled module is the post-SPMD per-device program, so parsed shapes
are shard shapes; wire-byte formulas below are per-device bytes moved:

  all-reduce        2 * bytes * (g-1)/g      (ring reduce-scatter+all-gather)
  all-gather        bytes_out * (g-1)/g      (bytes received)
  reduce-scatter    bytes_in * (g-1)/g
  all-to-all        bytes * (g-1)/g
  collective-permute bytes
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, List

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g.  %all-gather.7 = bf16[4,1024,128]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*(\w+)\[([\d,]*)\][^)]*?\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
# tuple-result collectives:  = (f32[...], f32[...]) all-reduce(
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


@dataclass
class CollectiveOp:
    kind: str
    dtype: str
    shape: tuple
    bytes: int
    group_size: int
    wire_bytes: float


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str, default: int) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


def _wire_bytes(kind: str, nbytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    frac = (g - 1) / g
    if kind == "all-reduce":
        return 2.0 * nbytes * frac
    if kind == "all-gather":
        return nbytes * frac  # nbytes = output (gathered) size
    if kind == "reduce-scatter":
        return nbytes * g * frac  # nbytes = output (scattered) shard
    if kind == "all-to-all":
        return nbytes * frac
    return float(nbytes)  # collective-permute


def parse_collectives(hlo_text: str, default_group: int) -> List[CollectiveOp]:
    ops: List[CollectiveOp] = []
    seen_start = set()
    for line in hlo_text.splitlines():
        if not any(c in line for c in _COLLECTIVES):
            continue
        if "-done(" in line:
            continue  # paired with -start; avoid double count
        shapes = []
        m = _OP_RE.search(line)
        kind = None
        if m:
            kind = m.group(3)
            shapes = [(m.group(1), m.group(2))]
        else:
            mt = _TUPLE_RE.search(line)
            if mt:
                kind = mt.group(2)
                shapes = _SHAPE_RE.findall(mt.group(1))
        if not kind:
            continue
        g = _group_size(line, default_group)
        for dtype, dims in shapes:
            if dtype not in _DTYPE_BYTES:
                continue
            nbytes = _shape_bytes(dtype, dims)
            ops.append(CollectiveOp(
                kind=kind, dtype=dtype,
                shape=tuple(int(d) for d in dims.split(",") if d),
                bytes=nbytes, group_size=g,
                wire_bytes=_wire_bytes(kind, nbytes, g)))
    return ops


def collective_summary(ops: List[CollectiveOp]) -> Dict:
    by_kind: Dict[str, Dict] = {}
    for op in ops:
        d = by_kind.setdefault(op.kind, {"count": 0, "bytes": 0, "wire_bytes": 0.0})
        d["count"] += 1
        d["bytes"] += op.bytes
        d["wire_bytes"] += op.wire_bytes
    return {
        "total_wire_bytes": sum(o.wire_bytes for o in ops),
        "total_bytes": sum(o.bytes for o in ops),
        "count": len(ops),
        "by_kind": by_kind,
    }
