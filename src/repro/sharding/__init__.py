from repro.sharding.specs import cache_specs, named_shardings, param_specs
