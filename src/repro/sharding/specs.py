"""PartitionSpec rules for every parameter / activation / cache leaf.

Rules address the TRAILING dims of each leaf by parameter name; leading
stacking dims (layer-scan group axes, and the federated client axis) are
padded with None / the client axes. A 'model' assignment is only applied
when the dim is divisible by the model-axis size (GSPMD could pad uneven
shardings, but divisible mappings keep the collective schedule clean);
otherwise the dim stays replicated and the roofline shows the cost.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# name -> trailing-dim logical roles; 'M' marks model-shardable dims.
# 'H'/'Hd' mark attention head / head_dim axes resolved by the head policy.
# Attention rules are keyed separately — 'wo' exists in BOTH attention
# (H, hd, d) and dense MLP (ff, d); resolving by leaf name alone silently
# mis-shards one of them (found the hard way, EXPERIMENTS.md §Perf A).
_ATTN_RULES = {
    "wq": (None, "H", "Hd"), "wk": (None, "H", "Hd"), "wv": (None, "H", "Hd"),
    "bq": ("H", "Hd"), "bk": ("H", "Hd"), "bv": ("H", "Hd"),
    "wo": ("H", "Hd", None),
}

_TRAILING_RULES = {
    # dense mlp
    "wg": (None, "M"), "wi": (None, "M"), "wo": ("M", None),
    # moe (3D leaves override by rank below)
    "router": (None, "M"),
    # mamba
    "in_x": (None, "M"), "in_z": (None, "M"), "in_B": (None, "M"),
    "in_C": (None, "M"), "in_dt": (None, "M"),
    "conv_w": (None, "M"), "conv_b": ("M",),
    "x_proj": ("M", None), "dt_w": (None, "M"), "dt_b": ("M",),
    "A_log": ("M", None), "D": ("M",),
    "dt_bias": ("M",), "norm": ("M",),
    "out_proj": ("M", None),
}

_MOE_3D = {"wg": ("M", None, None), "wi": ("M", None, None),
           "wo": ("M", None, None)}


def _leaf_trailing_spec(path_keys, shape) -> Tuple:
    name = path_keys[-1]
    parents = set(path_keys[:-1])
    if name == "embed":
        if len(shape) == 3:  # audio (K, V, d)
            return (None, "M", None)
        return ("M", None)
    if name == "lm_head":
        if len(shape) == 3:  # (K, d, V)
            return (None, None, "M")
        return (None, "M")
    if "moe" in parents and name in _MOE_3D:
        return _MOE_3D[name]
    if "attn" in parents and name in _ATTN_RULES:
        return _ATTN_RULES[name]
    if "mlp" in parents and name == "wo":
        # Replicate small dense down-projections. Measured (§Perf A):
        # keeping the small-model residual path fully replicated stops the
        # partitioner from sharding the fp32 (S, S, H) score intermediate's
        # contraction and all-reducing it (45 GB/round on qwen2-0.5b).
        total_bytes = 1
        for d in shape:
            total_bytes *= d
        if total_bytes * 4 < 1e9:
            return ()
        return ("M", None)
    rule = _TRAILING_RULES.get(name)
    if rule is None:
        return ()  # replicate (norm scales, projector, CNN leaves, biases)
    return rule


def param_specs(
    abstract_params: Any,
    mesh: Mesh,
    model_axis: str = "model",
    client_axes: Optional[Tuple[str, ...]] = None,
    stack_dims: int = 0,
) -> Any:
    """PartitionSpec tree matching abstract_params.

    stack_dims: number of leading layer-stack dims on 'layers' leaves
    (informational only; trailing rules self-align by rank).
    client_axes: if set, every leaf gets a leading client axis sharded over
    these mesh axes.
    """
    msize = int(np.prod([mesh.shape[a] for a in (model_axis,)])) \
        if model_axis in mesh.shape else 1

    def spec_for(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        shape = leaf.shape
        offset = 1 if client_axes else 0
        trailing = _leaf_trailing_spec(keys, shape[offset:])
        n_trailing = len(trailing)
        ndim = len(shape)
        spec = [None] * ndim
        if client_axes:
            spec[0] = client_axes if len(client_axes) > 1 else client_axes[0]
        # Align trailing rule to the end; disambiguate mamba A_log rank:
        if keys and keys[-1] == "A_log" and (ndim - offset) % 2 == 1:
            trailing = ("M",)  # stacked mamba2 (G, sg, H) has odd base rank
            n_trailing = 1
        # Attention head/head_dim policy (measured — EXPERIMENTS.md §Perf):
        #   H % msize == 0       -> shard heads (scores stay off the wire)
        #   small weight stack   -> replicate (cheap; avoids both the score
        #                           all-reduce and any resharding; pjit
        #                           rejects padded/uneven input shardings)
        #   hd % msize == 0      -> shard head_dim (score einsum contracts a
        #                           sharded dim => per-layer score all-reduce,
        #                           mild for big-H archs)
        #   else                 -> replicate
        if any(r in ("H", "Hd") for r in trailing):
            h_pos = ndim - n_trailing + trailing.index("H")
            hd_pos = ndim - n_trailing + trailing.index("Hd")
            H = shape[h_pos]
            hd = shape[hd_pos]
            total_bytes = int(np.prod(shape)) * 4
            is_wo = keys[-1] == "wo"
            if msize > 1:
                if H % msize == 0:
                    spec[h_pos] = model_axis
                elif hd % msize == 0 and (is_wo or total_bytes >= 1e9):
                    # Indivisible heads: hd-shard. For small stacks only the
                    # out-projection is sharded (q/k/v replicated) — measured
                    # to keep GSPMD from sharding the S^2 score intermediate
                    # and all-reducing it (EXPERIMENTS.md §Perf A2).
                    spec[hd_pos] = model_axis
            return P(*spec)
        for i, role in enumerate(trailing):
            dim = ndim - n_trailing + i
            if dim < offset:
                continue
            if role == "M" and shape[dim] % msize == 0 and msize > 1:
                spec[dim] = model_axis
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_for, abstract_params)


def named_shardings(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def cache_specs(
    abstract_cache: Any,
    mesh: Mesh,
    batch_axes: Optional[Tuple[str, ...]],
    model_axis: str = "model",
) -> Any:
    """Sharding for serve caches.

    KV leaves (G, sg, B, L, KV, hd): batch over batch_axes (replicated when
    indivisible, e.g. long_500k B=1); KV heads over model when divisible,
    else head_dim over model. SSM conv/h leaves shard their channel dim.
    """
    msize = mesh.shape.get(model_axis, 1)
    bsize = int(np.prod([mesh.shape[a] for a in (batch_axes or ())])) or 1

    def spec_for(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = keys[-1]
        shape = leaf.shape
        ndim = len(shape)
        spec = [None] * ndim
        if name == "pos":
            return P()
        # Identify batch dim: first dim whose size matches a multiple of bsize
        # after the (G, sg) stack prefix. Caches are (G, sg, B, ...) except
        # shared-attn caches (G, B, ...).
        bdim = None
        for i in range(min(3, ndim)):
            if shape[i] % bsize == 0 and i >= 1:
                bdim = i
                break
        if batch_axes and bdim is not None and shape[bdim] % bsize == 0:
            spec[bdim] = tuple(batch_axes) if len(batch_axes) > 1 else batch_axes[0]
        if name in ("k", "v") and ndim >= 2:
            kv_dim, hd_dim = ndim - 2, ndim - 1
            if shape[kv_dim] % msize == 0:
                spec[kv_dim] = model_axis
            elif shape[hd_dim] % msize == 0:
                spec[hd_dim] = model_axis
        elif name in ("conv", "h"):
            # channel dim: conv (..., B, K-1, C) -> last; h (..., B, D, N) or
            # (..., B, H, P, N) -> first after batch.
            tgt = ndim - 1 if name == "conv" else (bdim + 1 if bdim is not None else ndim - 2)
            if tgt < ndim and shape[tgt] % msize == 0:
                spec[tgt] = model_axis
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_for, abstract_cache)
