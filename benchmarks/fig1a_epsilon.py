"""Fig. 1(a): impact of the preset global error eps on the optimized
(b*, theta*, H, predicted overall time)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import cnn_update_bits, paper_problem
from repro.core import kkt, tradeoff


def run(quick: bool = False):
    bits = cnn_update_bits("mnist")
    base = paper_problem(bits)
    epsilons = [0.05, 0.02, 0.01, 0.005, 0.002]
    rows = []
    for eps, sol in tradeoff.sweep_epsilon(base, epsilons):
        rows.append(("fig1a", eps, int(sol.b), round(sol.theta, 4), sol.V,
                     round(sol.H, 1), round(sol.overall, 2)))
    return ("name,epsilon,b_star,theta_star,V,H,overall_pred_s", rows)


if __name__ == "__main__":
    header, rows = run()
    print(header)
    for r in rows:
        print(",".join(map(str, r)))
