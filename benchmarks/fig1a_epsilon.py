"""Fig. 1(a): impact of the preset global error eps on the optimized
(b*, theta*, H, predicted overall time).

Declared as a `Study` of plan=True arms (one per epsilon); the rows are
the arms' analytic operating points (`Study.plans()` — Alg. 1 solved
against the calibrated population), no training."""
from __future__ import annotations

from repro.configs.base import FedConfig
from repro.federated.experiment import CALIBRATED_C, ExperimentSpec
from repro.federated.study import Study

EPSILONS = (0.05, 0.02, 0.01, 0.005, 0.002)


def study() -> Study:
    arms = [
        (f"eps{eps}", ExperimentSpec(
            fed=FedConfig(n_devices=10, epsilon=eps, nu=2.0,
                          c=CALIBRATED_C, lr=0.05),
            model="mnist_cnn", dataset="mnist", plan=True, batch_cap=None,
            label=f"eps{eps}"))
        for eps in EPSILONS
    ]
    return Study(arms=arms)


def run(quick: bool = False):
    plans = study().plans()
    rows = []
    for eps, (label, plan) in zip(EPSILONS, plans.items()):
        rows.append(("fig1a", eps, int(plan.b), round(plan.theta, 4),
                     plan.V, round(plan.H_pred, 1),
                     round(plan.overall_pred, 2)))
    return ("name,epsilon,b_star,theta_star,V,H,overall_pred_s", rows)


if __name__ == "__main__":
    header, rows = run()
    print(header)
    for r in rows:
        print(",".join(map(str, r)))
