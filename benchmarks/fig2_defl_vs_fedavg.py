"""Fig. 2: DEFL vs FedAvg vs Rand — overall time to a matched accuracy on
MNIST-like and CIFAR-like tasks (the paper's headline comparison), run
per edge scenario (federated/scenarios.py).

Paper settings: FedAvg (b=10, V=20); Rand (b=16, V=15) for MNIST and
(b=64, V=30) for CIFAR; DEFL uses (b*, theta*) re-planned against each
scenario's realized population (plan=True on the spec — straggler and
cell-edge cohorts shift the Eq. 5/7 maxes; expected dropout shrinks the
effective M in Eq. 12).

Each (scenario, dataset) comparison is ONE declarative `Study`
(federated/study.py): the three method arms share a (V, b)-envelope group
and execute as a single vmapped fleet over the (arm x seed) axis —
bit-identical per arm to sequential runs — with in-fleet `target_acc`
early stopping, so the single-seed and multi-seed paths report the SAME
time-to-target semantics (each member stops when it reaches 90%; the band
is mean +- std over realization seeds)."""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import make_cnn_spec
from repro.configs.base import FedConfig
from repro.federated.experiment import CALIBRATED_C
from repro.federated.study import Study

# The scenario table of the headline comparison (>= 4 registered names).
SCENARIO_NAMES = ("uniform", "stragglers", "cell_edge", "dropout", "drifting")
TARGET_ACC = 0.90


def arm_specs(dataset: str, scenario: str, seed: int = 0,
              n_train: int = 1500):
    """The three method arms as ExperimentSpecs. DEFL is plan=True (the
    spec solves (b*, theta*) against the scenario population at build
    time, batch capped at 32 — paper §VI-B); FedAvg/Rand run the paper's
    fixed settings."""
    defl_fed = FedConfig(n_devices=10, epsilon=0.01, nu=2.0,
                         c=CALIBRATED_C, lr=0.05)
    fedavg = FedConfig(n_devices=10, batch_size=10,
                       theta=float(np.exp(-20 / 2.0)), nu=2.0, lr=0.05)
    rand_b, rand_v = (16, 15) if dataset == "mnist" else (64, 30)
    rand = FedConfig(n_devices=10, batch_size=rand_b,
                     theta=float(np.exp(-rand_v / 2.0)), nu=2.0, lr=0.05)

    def spec(label, fed):
        return make_cnn_spec(dataset, fed, f"{label}@{scenario}",
                             n_train=n_train, seed=seed, scenario=scenario)

    return [("DEFL", spec("DEFL", defl_fed).replace(plan=True)),
            ("FedAvg", spec("FedAvg", fedavg)),
            ("Rand", spec("Rand", rand))]


def study_for(dataset: str, scenario: str, seed: int = 0, seeds: int = 1,
              quick: bool = False) -> Study:
    """The (scenario, dataset) comparison as one declarative Study."""
    return Study(
        arms=arm_specs(dataset, scenario, seed,
                       n_train=600 if quick else 1500),
        seeds=range(seed, seed + seeds),
        max_rounds=4 if quick else 12, eval_every=1,
        target_acc=TARGET_ACC)


def run(quick: bool = False, scenario: str = "", seed: int = 0,
        seeds: int = 1, checkpoint_dir: str = "", resume: bool = True):
    """One row per (scenario, dataset, method) from the grouped study,
    plus the DEFL-vs-FedAvg reduction row per comparison. With seeds > 1
    every arm's column becomes a mean +- std confidence band over the
    (arm x seed) fleet; time-to-target is each member's own early-stop
    time on both paths. `checkpoint_dir` turns on per-(arm, seed)
    crash-safe autosave/resume (Study.run) under one subdirectory per
    (scenario, dataset) comparison — a killed sweep picks up where it
    left off."""
    rows = []
    payload = {}
    scens = (scenario,) if scenario else SCENARIO_NAMES
    datasets = ["mnist"] if quick else ["mnist", "cifar"]
    for scen in scens:
        for ds in datasets:
            res = study_for(ds, scen, seed=seed, seeds=seeds,
                            quick=quick).run(
                checkpoint_dir=(os.path.join(checkpoint_dir,
                                             f"{scen}_{ds}")
                                if checkpoint_dir else None),
                resume=resume)
            payload[f"{scen}/{ds}"] = res.to_json()
            multi = seeds > 1
            for label in res.labels:
                s = res.summary(label)
                fed = res[label][0].fed
                # NaN-for-miss semantics: nanmean over the seeds that hit
                # the target (a missed seed no longer poisons the band).
                tta = res.time_to_target(label)
                hit = bool(np.isfinite(tta).any())
                band = lambda m, sd, nd: (  # noqa: E731
                    f"{m:.{nd}f}+-{sd:.{nd}f}" if multi else round(m, nd))
                rows.append((
                    "fig2", scen, ds, label, fed.batch_size,
                    fed.local_rounds, round(s["rounds_mean"], 1),
                    (round(s["mean_participants"], 1)
                     if np.isfinite(s["mean_participants"]) else ""),
                    band(s["total_time_mean"], s["total_time_std"], 2),
                    band(s["final_acc_mean"], s["final_acc_std"], 4),
                    (band(float(np.nanmean(tta)), float(np.nanstd(tta)), 2)
                     if hit else "")))
            # Like-for-like on both paths: mean time-to-target (early-stop
            # time when reached, total time otherwise) per arm.
            rows.append(("fig2", scen, ds, "reduction_vs_fedavg", "", "",
                         "", "", round(res.reduction("DEFL", "FedAvg"), 1),
                         "", ""))
    return ("name,scenario,dataset,method,b,V,rounds,mean_participants,"
            "overall_time_s,acc,time_to_90", rows, payload)


if __name__ == "__main__":
    header, rows, _ = run()
    print(header)
    for r in rows:
        print(",".join(map(str, r)))
