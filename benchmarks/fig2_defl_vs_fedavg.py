"""Fig. 2: DEFL vs FedAvg vs Rand — overall time to a matched accuracy on
MNIST-like and CIFAR-like tasks (the paper's headline comparison), run
per edge scenario (federated/scenarios.py).

Paper settings: FedAvg (b=10, V=20); Rand (b=16, V=15) for MNIST and
(b=64, V=30) for CIFAR; DEFL uses (b*, theta*) re-planned against each
scenario's realized population (straggler/cell-edge cohorts shift the
Eq. 5/7 maxes; expected dropout shrinks the effective M in Eq. 12).

Every sim runs on the chunk-fused scan backend (whole eval_every-round
chunks per compiled dispatch); run_cnn_fl asserts one trace per
(scenario, method) — per-round participation masks and drifting channels
ride the same compiled chunk as traced scan inputs."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    CALIBRATED_C,
    CALIBRATED_COMPUTE,
    cnn_update_bits,
    run_cnn_fl,
    run_cnn_fleet,
)
from repro.configs.base import FedConfig, WirelessConfig
from repro.core import defl
from repro.federated import scenarios

# The scenario table of the headline comparison (>= 4 registered names).
SCENARIO_NAMES = ("uniform", "stragglers", "cell_edge", "dropout", "drifting")


def _defl_fed(dataset: str, scenario: str, seed: int = 0) -> FedConfig:
    fed = FedConfig(n_devices=10, epsilon=0.01, nu=2.0, c=CALIBRATED_C,
                    lr=0.05)
    # Same seed as the simulation below: DEFL plans against the exact
    # population realization it will be timed on.
    plan = scenarios.plan_for_scenario(
        fed, scenario, cnn_update_bits(dataset),
        cc=CALIBRATED_COMPUTE, wc=WirelessConfig(), seed=seed)
    fed = defl.plan_to_fedconfig(plan, fed)
    # Dataset-bounded batch cap (constraint 15 discussion / paper §VI-B).
    return FedConfig(**{**fed.__dict__, "batch_size": min(fed.batch_size, 32),
                        "update_bytes": None})


def _configs(dataset: str, scenario: str, seed: int = 0):
    defl_fed = _defl_fed(dataset, scenario, seed)
    fedavg = FedConfig(n_devices=10, batch_size=10, theta=float(np.exp(-20 / 2.0)),
                       nu=2.0, lr=0.05)  # V = 20
    if dataset == "mnist":
        rand = FedConfig(n_devices=10, batch_size=16,
                         theta=float(np.exp(-15 / 2.0)), nu=2.0, lr=0.05)
    else:
        rand = FedConfig(n_devices=10, batch_size=64,
                         theta=float(np.exp(-30 / 2.0)), nu=2.0, lr=0.05)
    return [("DEFL", defl_fed), ("FedAvg", fedavg), ("Rand", rand)]


def run(quick: bool = False, scenario: str = "", seed: int = 0,
        seeds: int = 1):
    """One row per (scenario, dataset, method). With seeds > 1 each method
    additionally runs a vmapped `run_fleet` over that many realization
    seeds (data order, participation masks, channel drift — one dispatch
    per chunk for the whole fleet) and reports the confidence band:
    mean +/- std of overall time across the fleet in place of the single
    run's numbers."""
    rows = []
    scens = (scenario,) if scenario else SCENARIO_NAMES
    datasets = ["mnist"] if quick else ["mnist", "cifar"]
    rounds = 4 if quick else 12
    n_train = 600 if quick else 1500
    for scen in scens:
        for ds in datasets:
            target = 0.90
            results = {}
            for label, fed in _configs(ds, scen, seed):
                if seeds > 1:
                    fleet = run_cnn_fleet(
                        ds, fed, label=f"{label}@{scen}",
                        seeds=range(seed, seed + seeds), rounds=rounds,
                        n_train=n_train, eval_every=1, seed=seed,
                        scenario=scen)
                    res = fleet[0]  # band below; first member keeps shape
                    # Fleet members run all rounds (no in-fleet early
                    # stop); time-to-target is still exact post-hoc from
                    # the per-round eval history. The reduction row
                    # below averages it over the fleet.
                    results[label] = float(np.mean(
                        [f.time_to_accuracy(target) or f.total_time
                         for f in fleet]))
                else:
                    fleet = None
                    res = run_cnn_fl(ds, fed, label=f"{label}@{scen}",
                                     rounds=rounds, n_train=n_train,
                                     eval_every=1, target_acc=target,
                                     seed=seed, scenario=scen)
                    results[label] = (res.time_to_accuracy(target)
                                      or res.total_time)
                tta = res.time_to_accuracy(target)
                last_acc = next((r.test_acc for r in reversed(res.history)
                                 if r.test_acc is not None), float("nan"))
                parts = [r.n_participants for r in res.history
                         if r.n_participants is not None]
                if fleet is not None:
                    times = [f.total_time for f in fleet]
                    accs = [next((r.test_acc for r in reversed(f.history)
                                  if r.test_acc is not None), float("nan"))
                            for f in fleet]
                    time_s = (f"{np.mean(times):.2f}+-{np.std(times):.2f}")
                    acc_s = f"{np.nanmean(accs):.4f}+-{np.nanstd(accs):.4f}"
                else:
                    time_s = round(res.total_time, 2)
                    acc_s = round(last_acc, 4)
                rows.append(("fig2", scen, ds, label, fed.batch_size,
                             fed.local_rounds, res.rounds,
                             round(float(np.mean(parts)), 1) if parts else "",
                             time_s, acc_s,
                             round(tta, 2) if tta else ""))
            if "DEFL" in results and "FedAvg" in results:
                # results holds time-to-target (or total time) — the
                # single run's value, or the fleet mean when seeds > 1 —
                # so the reduction is computed on like-for-like numbers.
                dt, ft = results["DEFL"], results["FedAvg"]
                rows.append(("fig2", scen, ds, "reduction_vs_fedavg", "", "",
                             "", "", round(100 * (1 - dt / ft), 1), "", ""))
    return ("name,scenario,dataset,method,b,V,rounds,mean_participants,"
            "overall_time_s,acc,time_to_90", rows)


if __name__ == "__main__":
    header, rows = run()
    print(header)
    for r in rows:
        print(",".join(map(str, r)))
