"""Fig. 1(c): relative-local-error theta impact — loss-vs-simulated-time
at theta in {0.05, 0.15, 0.5} (V = nu log 1/theta local steps)."""
from __future__ import annotations

from benchmarks.common import run_cnn_fl
from repro.configs.base import FedConfig


def run(quick: bool = False):
    rounds = 5 if quick else 10
    rows = []
    for theta in (0.05, 0.15, 0.5):
        fed = FedConfig(n_devices=10, batch_size=32, theta=theta, nu=2.0,
                        lr=0.05)
        res = run_cnn_fl("mnist", fed, label=f"theta{theta}", rounds=rounds,
                         n_train=800 if quick else 1500)
        rows.append(("fig1c", theta, fed.local_rounds, res.rounds,
                     round(res.total_time, 2),
                     round(res.history[-1].train_loss, 4)))
    return ("name,theta,V,rounds,overall_time_s,final_loss", rows)


if __name__ == "__main__":
    header, rows = run()
    print(header)
    for r in rows:
        print(",".join(map(str, r)))
