"""Fig. 1(c): relative-local-error theta impact — loss-vs-simulated-time
at theta in {0.05, 0.15, 0.5} (V = nu log 1/theta local steps).

Declared as one `Study`: the theta-arms differ only in V, so the
shape-envelope grouping pads local iterations to V_env=6 and the sweep
runs as ONE vmapped fleet."""
from __future__ import annotations

from benchmarks.common import make_cnn_spec
from repro.configs.base import FedConfig
from repro.federated.study import Study

THETAS = (0.05, 0.15, 0.5)


def study(quick: bool = False) -> Study:
    n_train = 800 if quick else 1500
    arms = [
        (f"theta{t}", make_cnn_spec(
            "mnist",
            FedConfig(n_devices=10, batch_size=32, theta=t, nu=2.0,
                      lr=0.05),
            f"theta{t}", n_train=n_train))
        for t in THETAS
    ]
    return Study(arms=arms, max_rounds=5 if quick else 10, eval_every=3)


def run(quick: bool = False):
    res = study(quick).run()
    rows = []
    for t, label in zip(THETAS, res.labels):
        r = res[label][0]
        rows.append(("fig1c", t, r.fed.local_rounds, r.rounds,
                     round(r.total_time, 2),
                     round(r.history[-1].train_loss, 4)))
    return ("name,theta,V,rounds,overall_time_s,final_loss", rows,
            res.to_json())


if __name__ == "__main__":
    header, rows, _ = run()
    print(header)
    for r in rows:
        print(",".join(map(str, r)))
