"""Round-step throughput: backend='loop' vs 'batched' vs 'scan' vs fleet.

The tentpole perf path, across PRs: one compiled, donated, vmapped round
step versus the per-client host loop (PR 1), whole round-chunks fused
into a single `lax.scan` dispatch (backend='scan', PR 3), and now the
vmapped multi-seed *fleet* (PR 4): `Simulator.run_fleet` maps the
compiled chunk over a leading seed axis so S seeds cost one dispatch per
chunk instead of S sequential runs. Runs the CNN-FL harness with int8
update compression at M in {10, 50, 200} and writes
``BENCH_round_step.json`` next to the repo root so the perf trajectory is
tracked across PRs: per-round rows ``{m, backend, rounds_per_sec,
round_ms}``, eval-cadence rows for 'batched'/'scan' carrying an extra
``eval_every`` key, and at M=10 a ``fleet_s8`` row (vmapped 8-seed fleet)
next to ``scan_seq_s8`` (the same 8 seeds run sequentially) — both
amortized to seconds per seed-round. PR 7 adds sampled-participation
rows: a K=50 cohort drawn per round from an M=10,000 population
(``sampled_k50``) next to its dense 50-client baseline, each carrying a
``state_bytes`` key (the device-resident params/opt/key trio — the O(K)
memory contract). PR 9 adds async event-engine rows at M=10: the
compiled event queue at its synchronous limit (buffer K=M, constant
staleness; ``async_k10``) on matched work (E = R*M events) next to the
scan backend on the same uniform scenario (``scan_uniform``).

  PYTHONPATH=src python -m benchmarks.run --only round_step [--quick]
  PYTHONPATH=src python benchmarks/bench_round_step.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Optional

sys.path.insert(0, __file__.rsplit("/", 2)[0])

import jax  # noqa: E402

from repro.configs.base import FedConfig  # noqa: E402
from repro.federated.experiment import (CohortSpec,  # noqa: E402
                                        PopulationSpec)
from repro.federated.faults import FaultModel  # noqa: E402

from benchmarks.common import make_cnn_sim, make_cnn_spec  # noqa: E402

JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_round_step.json")

# theta=0.62 -> V=1: the talk-heavy end of the paper's trade-off (sync
# every local step), where simulator overhead is the round time. The
# smoke-scale CNN keeps model GEMMs from masking the overhead under
# measurement; int8 compression exercises the full uplink path.
BENCH_FED = dict(batch_size=4, theta=0.62, lr=0.01, compress_updates=True)

# Chunk lengths for the chunked rows: eval_every=1 is the no-amortization
# floor (scan overhead vs batched), 10 the CI gate point, 50 the long-
# sweep regime (Fig. 2 style eval cadence). Both 'batched' and 'scan' get
# eval_every rows so the gate compares equal work through the same run()
# driver — a single 21 ms batched round sampled between host-side gaps
# runs at burst (turbo) clocks while a 10-round scan chunk is sustained
# load, so per-round-vs-chunk comparisons flatter the batched backend.
SCAN_EVALS = (1, 10, 50)
GATE_EVAL = 10
# Noise band for the CI gate: at M=10 the two drivers are at parity
# (overhead is small at 10 clients), so an exact >= 1.0 check would flake
# on shared runners; regressions show up far below 0.9.
GATE_TOL = 0.9
# Fleet rows: 8 seeds, vmapped vs sequential, at eval_every=1 — the
# Fig. 2 benchmark cadence (per-round eval for time-to-accuracy), which
# is also where per-chunk dispatch overhead is maximal and the fleet's
# one-dispatch-per-chunk amortization shows cleanest. The --check gate
# requires the vmapped fleet to beat 8 sequential scan runs by >= 1.5x
# at M=10 uncompressed (the batching win run_fleet exists for), and —
# re-enabled by the quantizer fusion (one flat-concatenated call per
# client; the old per-leaf form batched ~5x worse under the fleet vmap
# and ran at ~0.9x) — >= 1.15x on the compressed twin rows
# (fleet_s8c/scan_seq_s8c), whose extra per-member quantize compute
# dilutes the amortization on the 2-core CPU (measured ~1.26x).
FLEET_SEEDS = 8
FLEET_ROUNDS = 10
FLEET_EVAL = 1
FLEET_GATE = 1.5
FLEET_GATE_C = 1.15
# Sampled-participation rows (PR 7): a K-client cohort drawn each round
# from an M >> K population must cost what a dense K-client sim costs —
# the round graph is K lanes either way; the population only lives
# host-side. Benchmarked as M=SAMPLED_M/K=SAMPLED_K vs dense
# M=SAMPLED_K, both through run() at eval_every=GATE_EVAL on the same
# scenario. The --check gate requires (a) sampled throughput >=
# SAMPLED_GATE x the dense-K baseline (the host-side cohort draw +
# per-round gathers must stay off the critical path) and (b) the
# device-resident state trio (params_C, opt_C, key) to byte-match the
# dense-K trio — O(K), not O(M); (b) is exact, not a timing, so it
# never retries.
SAMPLED_M = 10_000
SAMPLED_K = 50
SAMPLED_GATE = 0.9
# Async event-queue rows (PR 9): the compiled event engine
# (backend='async', buffer K=M, constant staleness — the synchronous
# limit) vs the scan backend on MATCHED WORK: R rounds of M client
# updates = E = R*M events, both through run() at eval_every=GATE_EVAL
# on scenario='uniform'. Parity (1.0x) is NOT the bar: the synchronous
# round vmaps its M client GEMMs into one batched dispatch, which a
# one-client-per-event queue structurally cannot (measured 0.55-0.65x
# across b/V/compression on the 2-core reference CPU). The gate
# protects the event-step machinery itself — argmin pop, buffer adds,
# the ack-release branch — whose regressions show up well below the
# measured band.
ASYNC_M = 10
ASYNC_GATE = 0.5
# Best-of reps per M (larger M amortizes noise over longer rounds).
REPS = {10: 5, 50: 4, 200: 3}


def _make_sim(m: int, backend: str):
    fed = FedConfig(n_devices=m, **BENCH_FED)
    return make_cnn_sim("mnist", fed, f"{backend}-m{m}", seed=0,
                        backend=backend, with_eval=False,
                        cnn_cfg="mnist_cnn_small")


def _bench_m(m: int, reps: int) -> dict:
    """Best-of-reps seconds/round for every backend at one M.

    All sims are built and warmed first (warmup absorbs jit compilation),
    then the timed samples are taken *interleaved* — one sample per
    backend per rep, round-robin — so slow drift on a contended CPU
    (frequency scaling, co-tenants) biases every backend equally instead
    of whichever ran last; min-of-reps then drops the contended samples.
    'loop'/'batched' samples are one run_round() + sync (the PR 1 rows,
    kept for trajectory continuity); ('batched'|'scan', E) samples are E
    rounds through run(max_rounds=E, eval_every=E) — the real driver at
    eval cadence E, so async dispatch (batched), host-side chunk prep +
    the single per-chunk device_get (scan), and history records are all
    in the measurement — amortized to seconds/round."""
    sample = {}
    for backend in ("loop", "batched"):
        sim = _make_sim(m, backend)
        cell = {"st": sim.init()}
        cell["st"], _ = sim.run_round(cell["st"])
        sim.block_until_ready(cell["st"])

        def one(sim=sim, cell=cell):
            cell["st"], _ = sim.run_round(cell["st"])
            sim.block_until_ready(cell["st"])
            return 1

        sample[backend] = one
    scan_sims = []
    for backend in ("batched", "scan"):
        for ev in SCAN_EVALS:
            sim = _make_sim(m, backend)
            cell = {"st": sim.init()}
            cell["st"], _ = sim.run(  # compile + warm
                cell["st"], max_rounds=ev, eval_every=ev)
            if backend == "scan":
                scan_sims.append(sim)

            def runner(sim=sim, cell=cell, ev=ev):
                cell["st"], _ = sim.run(cell["st"], max_rounds=ev,
                                        eval_every=ev)
                return ev

            sample[(backend, ev)] = runner
    best = {k: float("inf") for k in sample}
    for _ in range(reps):
        for k, fn in sample.items():
            t0 = time.perf_counter()
            rounds = fn()
            best[k] = min(best[k], (time.perf_counter() - t0) / rounds)
    for sim in scan_sims:
        assert sim.trace_count == 1, f"scan retraced {sim.trace_count}x"
    return best


def _bench_fleet(m: int, reps: int, compress: bool) -> dict:
    """Seconds per seed-round: the vmapped FLEET_SEEDS-seed fleet vs the
    same seeds run sequentially through the SAME Simulator (shared
    compiled chunk, shared device-resident dataset). Both sides include
    per-member init() and host-side chunk prep — the fleet's win is one
    dispatch + one stacked transfer per chunk instead of S.

    Runs on mnist_cnn_tiny (1x1 kernels, overhead-scale): at
    mnist_cnn_small scale one round is ~25-30 ms of GEMM on the 2-core
    reference CPU (>90% compute share), and the vmapped batched-GEMM
    graph lowers at ~0.9-1.1x of the sequential one — ANY driver win is
    masked (same ceiling physics as scan-vs-batched, EXPERIMENTS.md
    §Driver overhead). What remains is exactly what run_fleet exists to
    amortize: per-chunk dispatch + host-touch cost, at FLEET_EVAL=1
    cadence (one chunk per round, the Fig. 2 time-to-accuracy workload)
    over FLEET_ROUNDS rounds.

    `compress` selects the plain rows (fleet_s8/scan_seq_s8, the PR 4
    trajectory) or the int8 twins (fleet_s8c/scan_seq_s8c): the fused
    quantizer (ONE flat-concatenated kernel call per client —
    compression.compress_update) batches like the rest of the round
    graph, so compressed fleets beat sequential again; the old per-leaf
    form blew up ~5x under the extra fleet axis and forced the fleet
    rows to run uncompressed."""
    fed = FedConfig(n_devices=m,
                    **dict(BENCH_FED, compress_updates=compress))
    suffix = "_s8c" if compress else "_s8"
    sim = make_cnn_sim("mnist", fed, f"fleet{suffix}-m{m}", seed=0,
                       backend="scan", with_eval=False,
                       cnn_cfg="mnist_cnn_tiny")
    seeds = list(range(FLEET_SEEDS))
    E, T = FLEET_EVAL, FLEET_ROUNDS
    sim.run_fleet(seeds=seeds, max_rounds=T, eval_every=E)  # compile fleet fn
    sim.run(sim.init(0), max_rounds=T, eval_every=E)  # compile single chunk
    traces = sim.trace_count
    work = FLEET_SEEDS * T

    def sequential():
        for s in seeds:
            sim.run(sim.init(s), max_rounds=T, eval_every=E)
        return work

    def fleet():
        sim.run_fleet(seeds=seeds, max_rounds=T, eval_every=E)
        return work

    sample = {f"scan_seq{suffix}": sequential, f"fleet{suffix}": fleet}
    best = {k: float("inf") for k in sample}
    for _ in range(reps):
        for k, fn in sample.items():
            t0 = time.perf_counter()
            rounds = fn()
            best[k] = min(best[k], (time.perf_counter() - t0) / rounds)
    assert sim.trace_count == traces, "fleet/scan retraced while timing"
    return best


def _state_trio_bytes(st) -> int:
    """Device-buffer bytes of the per-run state the client axis scales:
    stacked params, stacked opt state, PRNG key."""
    return sum(leaf.nbytes for leaf in
               jax.tree.leaves((st.params_C, st.opt_C, st.key)))


def _bench_sampled(reps: int) -> dict:
    """Best-of-reps seconds/round + exact state bytes: the sampled
    (M=SAMPLED_M, K=SAMPLED_K) simulator vs the dense K-client one, both
    run() at eval_every=GATE_EVAL on scenario='uniform' (the sampled
    engine always runs the scenario path; giving the dense baseline the
    same path keeps the comparison driver-for-driver)."""
    E = GATE_EVAL
    dense_sim = make_cnn_sim(
        "mnist", FedConfig(n_devices=SAMPLED_K, **BENCH_FED),
        f"dense-m{SAMPLED_K}", seed=0, backend="scan", with_eval=False,
        cnn_cfg="mnist_cnn_small", scenario="uniform")
    sampled_sim = make_cnn_spec(
        "mnist", FedConfig(**BENCH_FED),
        f"sampled-m{SAMPLED_M}-k{SAMPLED_K}", seed=0, backend="scan",
        with_eval=False, cnn_cfg="mnist_cnn_small", scenario="uniform",
        population=PopulationSpec(
            M=SAMPLED_M, cohort=CohortSpec(K=SAMPLED_K))).build()
    out = {}
    sample = {}
    for name, sim in (("dense", dense_sim), ("sampled", sampled_sim)):
        st = sim.init()
        out[f"{name}_state_bytes"] = _state_trio_bytes(st)
        cell = {"st": st}
        cell["st"], _ = sim.run(cell["st"], max_rounds=E, eval_every=E)

        def runner(sim=sim, cell=cell):
            cell["st"], _ = sim.run(cell["st"], max_rounds=E, eval_every=E)
            return E

        sample[name] = runner
    best = {k: float("inf") for k in sample}
    for _ in range(reps):
        for k, fn in sample.items():
            t0 = time.perf_counter()
            rounds = fn()
            best[k] = min(best[k], (time.perf_counter() - t0) / rounds)
    assert sampled_sim.trace_count == 1, (
        f"sampled scan retraced {sampled_sim.trace_count}x")
    out["dense"], out["sampled"] = best["dense"], best["sampled"]
    return out


def _bench_async(reps: int) -> dict:
    """Best-of-reps seconds/round on matched work: the async event
    engine at buffer K=M (every aggregation consumes one update per
    client on 'uniform' — E = R*M events) vs the scan backend's R
    synchronized rounds, both through run() at eval_every=GATE_EVAL."""
    from repro.federated.events import AsyncSpec
    E = GATE_EVAL
    fed = FedConfig(n_devices=ASYNC_M, **BENCH_FED)
    scan_sim = make_cnn_sim(
        "mnist", fed, f"scan-async-base-m{ASYNC_M}", seed=0,
        backend="scan", with_eval=False, cnn_cfg="mnist_cnn_small",
        scenario="uniform")
    async_sim = make_cnn_spec(
        "mnist", fed, f"async-m{ASYNC_M}", seed=0, backend="async",
        with_eval=False, cnn_cfg="mnist_cnn_small", scenario="uniform",
        async_spec=AsyncSpec(buffer_size=ASYNC_M,
                             staleness="constant")).build()
    sample = {}
    for name, sim in (("scan_base", scan_sim), ("async", async_sim)):
        cell = {"st": sim.init()}
        cell["st"], _ = sim.run(cell["st"], max_rounds=E, eval_every=E)

        def runner(sim=sim, cell=cell):
            cell["st"], _ = sim.run(cell["st"], max_rounds=E, eval_every=E)
            return E

        sample[name] = runner
    best = {k: float("inf") for k in sample}
    for _ in range(reps):
        for k, fn in sample.items():
            t0 = time.perf_counter()
            rounds = fn()
            best[k] = min(best[k], (time.perf_counter() - t0) / rounds)
    assert async_sim.trace_count == 1, (
        f"async event chunk retraced {async_sim.trace_count}x")
    return best


def check_async_identity() -> None:
    """Exact gate (no timing, never retried): the synchronous limit of
    the event engine — AsyncSpec(buffer_size=M, staleness='constant') on
    scenario='uniform' — must reproduce the scan backend's loss
    trajectory and final params. Under ack-at-aggregation each buffer
    fill consumes exactly one update per client, all dispatched from the
    same global model: FedAvg on the event clock. Raises SystemExit(1)
    on divergence."""
    import numpy as np
    from repro.federated.events import AsyncSpec
    m, rounds = 4, 6
    fed = FedConfig(n_devices=m, **BENCH_FED)
    scan_sim = make_cnn_sim(
        "mnist", fed, "ident-scan", n_train=96, n_test=32, seed=0,
        backend="scan", with_eval=False, cnn_cfg="mnist_cnn_tiny",
        scenario="uniform")
    async_sim = make_cnn_spec(
        "mnist", fed, "ident-async", n_train=96, n_test=32, seed=0,
        backend="async", with_eval=False, cnn_cfg="mnist_cnn_tiny",
        scenario="uniform",
        async_spec=AsyncSpec(buffer_size=m, staleness="constant")).build()
    st_s, res_s = scan_sim.run(scan_sim.init(), max_rounds=rounds)
    st_a, res_a = async_sim.run(async_sim.init(), max_rounds=rounds)
    ls = [r.train_loss for r in res_s.history]
    la = [r.train_loss for r in res_a.history]
    if not np.allclose(la, ls, rtol=2e-5, atol=1e-6):
        print(f"FAIL: async sync-limit (K=M, constant staleness, uniform) "
              f"diverges from scan losses:\n  scan  {ls}\n  async {la}")
        raise SystemExit(1)
    ps = jax.device_get(scan_sim.params(st_s))
    pa = jax.device_get(async_sim.params(st_a))
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(ps)):
        if not np.allclose(a, b, rtol=2e-5, atol=1e-6):
            print("FAIL: async sync-limit final params diverge from scan")
            raise SystemExit(1)
    print(f"check: async sync-limit (K=M={m}, constant) reproduces the "
          f"scan trajectory over {rounds} rounds")


def _chunk_hlo(faults) -> str:
    """Lowered HLO text of the compiled scan-chunk graph for a tiny CNN
    sim at the given FaultModel — the graph-byte probe behind the
    inactive-quorum gate. Lowering is deterministic, so equal configs
    produce equal text."""
    fed = FedConfig(n_devices=4, **BENCH_FED)
    spec = make_cnn_spec("mnist", fed, "hlo-probe", n_train=48, n_test=16,
                         seed=0, backend="scan", with_eval=False,
                         cnn_cfg="mnist_cnn_tiny", scenario="dropout")
    sim = spec.replace(faults=faults).build()
    st = sim.init()
    iters, stream = sim._materialize(st)
    xs, _ = sim._chunk_inputs(iters, stream, 2, 2)
    weights, t_cp = sim._chunk_args()
    args = [st.params_C, st.opt_C, st.key, weights, t_cp, sim._data_dev, xs]
    if sim._envelope:
        args.append(sim._trivial_env())
    return sim._chunk_fn.lower(*args).as_text()


def check_quorum_graph() -> None:
    """Exact graph-byte gate (never retried — no timing in it): a sim
    carrying an inactive FaultModel must lower to HLO byte-identical to
    the no-faults sim (zero ops paid for the resilience knobs when they
    are off), and setting `min_quorum` on an otherwise-identical active
    FaultModel must CHANGE the graph (the quorum gate really compiles in
    — proves the identity probe is not vacuous). Raises SystemExit(1) on
    violation."""
    plain = _chunk_hlo(None)
    inactive = _chunk_hlo(FaultModel())
    if plain != inactive:
        print("FAIL: an inactive FaultModel changes the compiled chunk "
              "graph (must be byte-identical to faults=None)")
        raise SystemExit(1)
    print("check: inactive FaultModel lowers byte-identical to faults=None "
          f"({len(plain)} HLO bytes)")
    base = _chunk_hlo(FaultModel(deadline_factor=2.0))
    quorum = _chunk_hlo(FaultModel(deadline_factor=2.0, min_quorum=2))
    if base == quorum:
        print("FAIL: min_quorum=2 lowers the SAME graph as min_quorum=None "
              "— the quorum gate is not being compiled in")
        raise SystemExit(1)
    print("check: min_quorum compiles quorum ops only when set "
          f"({len(base)} vs {len(quorum)} HLO bytes)")


def run(quick: bool = False, smoke: bool = False, out: str = "",
        speedups: Optional[dict] = None, scan_speedups: Optional[dict] = None,
        fleet_speedups: Optional[dict] = None,
        sampled_stats: Optional[dict] = None,
        async_stats: Optional[dict] = None):
    """smoke=True is the CI gate: tiny config (M=10 only). `out` gets the
    timing rows plus speedup rows as a CI artifact; pass dicts as
    `speedups` / `scan_speedups` / `fleet_speedups` to receive the raw
    {m: loop/batched}, {m: batched/scan@GATE_EVAL} and
    {(m, suffix): seq/fleet@8 seeds} ratios, and `sampled_stats` /
    `async_stats` for the raw sampled/dense and scan_base/async
    seconds (main --check uses these — never the rounded CSV
    strings). smoke/quick runs never clobber the
    tracked full-size BENCH_round_step.json trajectory; its per-round
    rows keep the documented {m, backend, rounds_per_sec, round_ms}
    shape, scan rows add an `eval_every` key, and the M=10 fleet rows use
    backends 'fleet_s8'/'scan_seq_s8' (uncompressed) and
    'fleet_s8c'/'scan_seq_s8c' (int8) in seconds per seed-round."""
    ms = [10] if smoke else ([10, 50] if quick else [10, 50, 200])
    reps = REPS
    rows_json = []
    speedup_json = []
    rows_csv = []
    for m in ms:
        best = _bench_m(m, reps[m])
        for backend in ("loop", "batched"):
            sec = best[backend]
            rows_json.append({
                "m": m,
                "backend": backend,
                "rounds_per_sec": 1.0 / sec,
                "round_ms": sec * 1e3,
            })
            rows_csv.append((f"round_step_m{m}_{backend}",
                             f"{sec * 1e6:.0f}", f"{1.0 / sec:.3f}"))
        speedup = best["loop"] / best["batched"]
        if speedups is not None:
            speedups[m] = speedup
        speedup_json.append({"m": m, "speedup_x": speedup})
        rows_csv.append((f"round_step_m{m}_loop_over_batched", "",
                         f"{speedup:.2f}"))
        for backend in ("batched", "scan"):
            for ev in SCAN_EVALS:
                sec = best[(backend, ev)]
                rows_json.append({
                    "m": m,
                    "backend": backend,
                    "eval_every": ev,
                    "rounds_per_sec": 1.0 / sec,
                    "round_ms": sec * 1e3,
                })
                rows_csv.append((f"round_step_m{m}_{backend}_e{ev}",
                                 f"{sec * 1e6:.0f}", f"{1.0 / sec:.3f}"))
        for ev in SCAN_EVALS:
            scan_x = best[("batched", ev)] / best[("scan", ev)]
            speedup_json.append(
                {"m": m, "eval_every": ev, "scan_speedup_x": scan_x})
            rows_csv.append((f"round_step_m{m}_batched_over_scan_e{ev}", "",
                             f"{scan_x:.2f}"))
            if ev == GATE_EVAL and scan_speedups is not None:
                scan_speedups[m] = scan_x
        if m == 10:
            # Fleet rows at the gate M only: at M=200 the stacked fleet is
            # 1600 client rows — a memory-bound config the tracked
            # trajectory doesn't need (noted here rather than silently
            # skipped).
            for compress in (False, True):
                suffix = "_s8c" if compress else "_s8"
                fbest = _bench_fleet(m, reps[m], compress)
                for name in (f"scan_seq{suffix}", f"fleet{suffix}"):
                    sec = fbest[name]
                    rows_json.append({
                        "m": m,
                        "backend": name,
                        "eval_every": FLEET_EVAL,
                        "rounds_per_sec": 1.0 / sec,
                        "round_ms": sec * 1e3,
                    })
                    rows_csv.append((f"round_step_m{m}_{name}",
                                     f"{sec * 1e6:.0f}", f"{1.0 / sec:.3f}"))
                fleet_x = (fbest[f"scan_seq{suffix}"]
                           / fbest[f"fleet{suffix}"])
                speedup_json.append(
                    {"m": m, "seeds": FLEET_SEEDS, "compressed": compress,
                     "fleet_speedup_x": fleet_x})
                rows_csv.append((f"round_step_m{m}_seq_over_fleet{suffix}",
                                 "", f"{fleet_x:.2f}"))
                if fleet_speedups is not None:
                    fleet_speedups[(m, suffix)] = fleet_x
    # Sampled-participation rows (all modes, including --smoke: the O(K)
    # contract is exactly what CI must hold): M=SAMPLED_M population,
    # K=SAMPLED_K cohort, vs the dense K-client baseline.
    sstats = _bench_sampled(reps[SAMPLED_K])
    if sampled_stats is not None:
        sampled_stats.update(sstats)
    for name, m_col in (("dense", SAMPLED_K), ("sampled", SAMPLED_M)):
        sec = sstats[name]
        backend = ("scan" if name == "dense"
                   else f"sampled_k{SAMPLED_K}")
        rows_json.append({
            "m": m_col,
            "backend": backend,
            "eval_every": GATE_EVAL,
            "rounds_per_sec": 1.0 / sec,
            "round_ms": sec * 1e3,
            "state_bytes": sstats[f"{name}_state_bytes"],
        })
        rows_csv.append((f"round_step_m{m_col}_{backend}_e{GATE_EVAL}",
                         f"{sec * 1e6:.0f}", f"{1.0 / sec:.3f}"))
    sampled_x = sstats["dense"] / sstats["sampled"]
    speedup_json.append({
        "m": SAMPLED_M, "k": SAMPLED_K,
        "sampled_over_dense_k_x": sampled_x,
        "state_bytes_sampled": sstats["sampled_state_bytes"],
        "state_bytes_dense_k": sstats["dense_state_bytes"],
    })
    rows_csv.append(
        (f"round_step_m{SAMPLED_M}_sampled_over_dense{SAMPLED_K}", "",
         f"{sampled_x:.2f}"))
    # Async event-queue rows (all modes): matched work at K=M — the
    # engine's event-step cost vs the vmapped synchronous round.
    astats = _bench_async(reps[ASYNC_M])
    if async_stats is not None:
        async_stats.update(astats)
    for name in ("scan_base", "async"):
        sec = astats[name]
        # 'scan_uniform' (not 'scan') so the row can't be confused with
        # the main scan sweep: this baseline runs on scenario='uniform'.
        backend = ("scan_uniform" if name == "scan_base"
                   else f"async_k{ASYNC_M}")
        rows_json.append({
            "m": ASYNC_M,
            "backend": backend,
            "eval_every": GATE_EVAL,
            "rounds_per_sec": 1.0 / sec,
            "round_ms": sec * 1e3,
        })
        rows_csv.append((f"round_step_m{ASYNC_M}_{backend}_e{GATE_EVAL}",
                         f"{sec * 1e6:.0f}", f"{1.0 / sec:.3f}"))
    async_x = astats["scan_base"] / astats["async"]
    speedup_json.append({"m": ASYNC_M, "k": ASYNC_M,
                         "async_over_scan_x": async_x})
    rows_csv.append((f"round_step_m{ASYNC_M}_async_over_scan", "",
                     f"{async_x:.2f}"))
    if not (quick or smoke):
        # Only full runs update the tracked artifact: a reduced sweep must
        # not clobber the M=200 rows of the cross-PR perf trajectory.
        with open(JSON_PATH, "w") as f:
            json.dump(rows_json, f, indent=2)
            f.write("\n")
    if out:
        with open(out, "w") as f:
            json.dump(rows_json + speedup_json, f, indent=2)
            f.write("\n")
    return "name,us_per_round,rounds_per_sec_or_x", rows_csv


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: M=10 only, no tracked-artifact write")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if the batched backend is not faster than "
                         "the loop backend at any M (the PR 1 speedup), "
                         "if the scan backend falls below the batched "
                         f"driver at eval_every={GATE_EVAL} by more than "
                         f"the {GATE_TOL} noise band (equal-work run() "
                         "comparison; the chunk-fusion speedup), or if the "
                         f"vmapped {FLEET_SEEDS}-seed fleet beats "
                         f"sequential runs by less than {FLEET_GATE}x "
                         f"uncompressed / {FLEET_GATE_C}x int8-compressed "
                         "at M=10 (the run_fleet batching win; the "
                         "compressed gate exists since the quantizer "
                         "fusion), or if the sampled "
                         f"(M={SAMPLED_M}, K={SAMPLED_K}) engine falls "
                         f"below {SAMPLED_GATE}x the dense K-client "
                         "baseline or its device state stops byte-"
                         "matching the dense-K trio (O(K), not O(M)); "
                         "or if the async event engine falls below "
                         f"{ASYNC_GATE}x the scan baseline at matched "
                         f"work (M={ASYNC_M}, K=M, E=R*M events); "
                         "also asserts — exactly, never retried — that "
                         "an inactive FaultModel lowers to HLO byte-"
                         "identical to faults=None, that min_quorum "
                         "compiles quorum ops only when set, and that "
                         "the K=M async sync limit matches the scan "
                         "backend's losses/params")
    ap.add_argument("--out", default="",
                    help="also write the rows JSON here (CI artifact)")
    args = ap.parse_args(argv)
    speedups: dict = {}
    scan_speedups: dict = {}
    fleet_speedups: dict = {}
    sampled_stats: dict = {}
    async_stats: dict = {}
    header, rows = run(quick=args.quick, smoke=args.smoke, out=args.out,
                       speedups=speedups, scan_speedups=scan_speedups,
                       fleet_speedups=fleet_speedups,
                       sampled_stats=sampled_stats,
                       async_stats=async_stats)
    print(header)
    for r in rows:
        print(",".join(map(str, r)))
    if args.check:
        # Exact gates first: no timing, no retry.
        check_quorum_graph()
        check_async_identity()
        # Timing gates on shared runners are noisy: a failing comparison
        # is re-measured ONCE (only the failing M / fleet config, not the
        # whole sweep) before it fails the run — a genuine regression
        # fails both measurements, a scheduler hiccup doesn't.
        def retry(name, bad, remeasure):
            if not bad:
                return bad
            print(f"check: {name} gate failed on first measurement "
                  f"({bad}); re-measuring the failing configuration(s)")
            return remeasure(sorted(bad))

        def re_loop(ms):
            out = {}
            for m in ms:
                best = _bench_m(m, REPS[m])
                x = speedups[m] = best["loop"] / best["batched"]
                if x <= 1.0:
                    out[m] = x
            return out

        def re_scan(ms):
            out = {}
            for m in ms:
                best = _bench_m(m, REPS[m])
                x = best[("batched", GATE_EVAL)] / best[("scan", GATE_EVAL)]
                scan_speedups[m] = x
                if x < GATE_TOL:
                    out[m] = x
            return out

        def re_fleet(keys):
            out = {}
            for m, suffix in keys:
                fbest = _bench_fleet(m, REPS[m], suffix == "_s8c")
                x = fbest[f"scan_seq{suffix}"] / fbest[f"fleet{suffix}"]
                fleet_speedups[(m, suffix)] = x
                if x < (FLEET_GATE_C if suffix == "_s8c" else FLEET_GATE):
                    out[(m, suffix)] = x
            return out

        bad = retry("loop/batched",
                    {m: x for m, x in speedups.items() if x <= 1.0}, re_loop)
        if bad:
            print(f"FAIL: batched backend slower than loop: {bad}")
            raise SystemExit(1)
        print("check: batched backend faster than loop at every M")
        bad = retry("scan/batched",
                    {m: x for m, x in scan_speedups.items() if x < GATE_TOL},
                    re_scan)
        if bad:
            print(f"FAIL: scan backend slower than batched at "
                  f"eval_every={GATE_EVAL} (tol {GATE_TOL}): {bad}")
            raise SystemExit(1)
        print(f"check: scan backend >= batched at eval_every={GATE_EVAL} "
              f"(tol {GATE_TOL}) at every M")
        bad = retry("fleet",
                    {k: x for k, x in fleet_speedups.items()
                     if x < (FLEET_GATE_C if k[1] == "_s8c" else FLEET_GATE)},
                    re_fleet)
        if bad:
            print(f"FAIL: vmapped {FLEET_SEEDS}-seed fleet below its gate "
                  f"({FLEET_GATE}x plain / {FLEET_GATE_C}x int8): {bad}")
            raise SystemExit(1)
        print(f"check: fleet >= {FLEET_GATE}x (plain) / {FLEET_GATE_C}x "
              f"(int8) sequential at M=10")
        # O(K) memory gate first: exact byte counts, no timing noise.
        sb = sampled_stats["sampled_state_bytes"]
        db = sampled_stats["dense_state_bytes"]
        if sb != db:
            print(f"FAIL: sampled (M={SAMPLED_M}, K={SAMPLED_K}) device "
                  f"state is {sb} bytes vs {db} for dense K={SAMPLED_K}: "
                  "the state trio must scale with K, not M")
            raise SystemExit(1)
        print(f"check: sampled device state byte-matches dense "
              f"K={SAMPLED_K} ({sb} bytes; O(K), not O(M={SAMPLED_M}))")

        def re_sampled(_keys):
            s = _bench_sampled(REPS[SAMPLED_K])
            sampled_stats.update(s)
            x = s["dense"] / s["sampled"]
            return {} if x >= SAMPLED_GATE else {"sampled": x}

        x = sampled_stats["dense"] / sampled_stats["sampled"]
        bad = retry("sampled/dense",
                    {} if x >= SAMPLED_GATE else {"sampled": x}, re_sampled)
        if bad:
            print(f"FAIL: sampled (M={SAMPLED_M}, K={SAMPLED_K}) below "
                  f"{SAMPLED_GATE}x the dense K={SAMPLED_K} baseline: "
                  f"{bad}")
            raise SystemExit(1)
        print(f"check: sampled (M={SAMPLED_M}, K={SAMPLED_K}) >= "
              f"{SAMPLED_GATE}x dense K={SAMPLED_K} throughput")

        def re_async(_keys):
            s = _bench_async(REPS[ASYNC_M])
            async_stats.update(s)
            x = s["scan_base"] / s["async"]
            return {} if x >= ASYNC_GATE else {"async": x}

        x = async_stats["scan_base"] / async_stats["async"]
        bad = retry("async/scan",
                    {} if x >= ASYNC_GATE else {"async": x}, re_async)
        if bad:
            print(f"FAIL: async event engine below {ASYNC_GATE}x the scan "
                  f"baseline at matched work (M={ASYNC_M}, K=M): {bad}")
            raise SystemExit(1)
        print(f"check: async event engine >= {ASYNC_GATE}x scan at "
              f"matched work (M={ASYNC_M}, K=M, E=R*M events)")


if __name__ == "__main__":
    main()
