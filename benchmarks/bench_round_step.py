"""Round-step throughput: backend='loop' vs backend='batched'.

The tentpole perf path: one compiled, donated, vmapped round step versus
the per-client host loop (one dispatch + host compress/decompress
roundtrip + device->host sync per client per round). Runs the CNN-FL
harness with int8 update compression at M in {10, 50, 200} and writes
``BENCH_round_step.json`` (rows ``{m, backend, rounds_per_sec, round_ms}``)
next to the repo root so the perf trajectory is tracked across PRs.

  PYTHONPATH=src python -m benchmarks.run --only round_step [--quick]
  PYTHONPATH=src python benchmarks/bench_round_step.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Optional

sys.path.insert(0, __file__.rsplit("/", 2)[0])

from repro.configs.base import FedConfig  # noqa: E402
from repro.models import cnn  # noqa: E402

from benchmarks.common import make_cnn_sim  # noqa: E402

JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_round_step.json")

# theta=0.62 -> V=1: the talk-heavy end of the paper's trade-off (sync
# every local step), where simulator overhead is the round time. The
# smoke-scale CNN keeps model GEMMs from masking the overhead under
# measurement; int8 compression exercises the full uplink path.
BENCH_FED = dict(batch_size=4, theta=0.62, lr=0.01, compress_updates=True)


def _time_backend(m: int, backend: str, timed_rounds: int) -> float:
    """Best-of-timed-rounds seconds/round after a warmup round (the warmup
    absorbs jit compilation for the batched backend; min is robust to CPU
    contention on shared runners)."""
    fed = FedConfig(n_devices=m, **BENCH_FED)
    sim = make_cnn_sim("mnist", fed, f"{backend}-m{m}", seed=0,
                       backend=backend, with_eval=False,
                       cnn_cfg=cnn.mnist_cnn_small())
    sim.run_round()
    sim.block_until_ready()
    best = float("inf")
    for _ in range(timed_rounds):
        t0 = time.perf_counter()
        sim.run_round()
        sim.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def run(quick: bool = False, smoke: bool = False, out: str = "",
        speedups: Optional[dict] = None):
    """smoke=True is the CI gate: tiny config (M=10 only). `out` gets the
    timing rows plus per-M speedup rows as a CI artifact; pass a dict as
    `speedups` to receive the raw {m: loop/batched} ratios (main --check
    uses this — never the rounded CSV strings). smoke/quick runs never
    clobber the tracked full-size BENCH_round_step.json trajectory, whose
    rows keep the documented {m, backend, rounds_per_sec, round_ms} shape."""
    ms = [10] if smoke else ([10, 50] if quick else [10, 50, 200])
    timed = {10: 5, 50: 4, 200: 3}
    rows_json = []
    speedup_json = []
    rows_csv = []
    per_m = {}
    for m in ms:
        for backend in ("loop", "batched"):
            sec = _time_backend(m, backend, timed[m])
            per_m.setdefault(m, {})[backend] = sec
            rows_json.append({
                "m": m,
                "backend": backend,
                "rounds_per_sec": 1.0 / sec,
                "round_ms": sec * 1e3,
            })
            rows_csv.append((f"round_step_m{m}_{backend}",
                             f"{sec * 1e6:.0f}", f"{1.0 / sec:.3f}"))
        speedup = per_m[m]["loop"] / per_m[m]["batched"]
        if speedups is not None:
            speedups[m] = speedup
        speedup_json.append({"m": m, "speedup_x": speedup})
        rows_csv.append((f"round_step_m{m}_speedup", "", f"{speedup:.2f}"))
    if not (quick or smoke):
        # Only full runs update the tracked artifact: a reduced sweep must
        # not clobber the M=200 rows of the cross-PR perf trajectory.
        with open(JSON_PATH, "w") as f:
            json.dump(rows_json, f, indent=2)
            f.write("\n")
    if out:
        with open(out, "w") as f:
            json.dump(rows_json + speedup_json, f, indent=2)
            f.write("\n")
    return "name,us_per_round,rounds_per_sec_or_x", rows_csv


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: M=10 only, no tracked-artifact write")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if the batched backend is not faster than "
                         "the loop backend at any M (guards the PR 1 "
                         "speedup)")
    ap.add_argument("--out", default="",
                    help="also write the rows JSON here (CI artifact)")
    args = ap.parse_args(argv)
    speedups: dict = {}
    header, rows = run(quick=args.quick, smoke=args.smoke, out=args.out,
                       speedups=speedups)
    print(header)
    for r in rows:
        print(",".join(map(str, r)))
    if args.check:
        bad = {m: x for m, x in speedups.items() if x <= 1.0}
        if bad:
            print(f"FAIL: batched backend slower than loop: {bad}")
            raise SystemExit(1)
        print("check: batched backend faster than loop at every M")


if __name__ == "__main__":
    main()
