"""Round-step throughput: backend='loop' vs 'batched' vs 'scan'.

The tentpole perf path, across PRs: one compiled, donated, vmapped round
step versus the per-client host loop (PR 1), and now whole round-chunks
fused into a single `lax.scan` dispatch (backend='scan') versus the
per-round batched driver — one host touch per `eval_every` rounds instead
of one dispatch + one host batch-feed per round. Runs the CNN-FL harness
with int8 update compression at M in {10, 50, 200} and writes
``BENCH_round_step.json`` next to the repo root so the perf trajectory is
tracked across PRs: per-round rows ``{m, backend, rounds_per_sec,
round_ms}`` plus eval-cadence rows for both 'batched' and 'scan' carrying
an extra ``eval_every`` key (amortized ms/round through the real run()
driver at that cadence — the equal-work comparison the --check gate uses).

  PYTHONPATH=src python -m benchmarks.run --only round_step [--quick]
  PYTHONPATH=src python benchmarks/bench_round_step.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Optional

sys.path.insert(0, __file__.rsplit("/", 2)[0])

from repro.configs.base import FedConfig  # noqa: E402
from repro.models import cnn  # noqa: E402

from benchmarks.common import make_cnn_sim  # noqa: E402

JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_round_step.json")

# theta=0.62 -> V=1: the talk-heavy end of the paper's trade-off (sync
# every local step), where simulator overhead is the round time. The
# smoke-scale CNN keeps model GEMMs from masking the overhead under
# measurement; int8 compression exercises the full uplink path.
BENCH_FED = dict(batch_size=4, theta=0.62, lr=0.01, compress_updates=True)

# Chunk lengths for the chunked rows: eval_every=1 is the no-amortization
# floor (scan overhead vs batched), 10 the CI gate point, 50 the long-
# sweep regime (Fig. 2 style eval cadence). Both 'batched' and 'scan' get
# eval_every rows so the gate compares equal work through the same run()
# driver — a single 21 ms batched round sampled between host-side gaps
# runs at burst (turbo) clocks while a 10-round scan chunk is sustained
# load, so per-round-vs-chunk comparisons flatter the batched backend.
SCAN_EVALS = (1, 10, 50)
GATE_EVAL = 10
# Noise band for the CI gate: at M=10 the two drivers are at parity
# (overhead is small at 10 clients), so an exact >= 1.0 check would flake
# on shared runners; regressions show up far below 0.9.
GATE_TOL = 0.9


def _make_sim(m: int, backend: str):
    fed = FedConfig(n_devices=m, **BENCH_FED)
    return make_cnn_sim("mnist", fed, f"{backend}-m{m}", seed=0,
                        backend=backend, with_eval=False,
                        cnn_cfg=cnn.mnist_cnn_small())


def _bench_m(m: int, reps: int) -> dict:
    """Best-of-reps seconds/round for every backend at one M.

    All sims are built and warmed first (warmup absorbs jit compilation),
    then the timed samples are taken *interleaved* — one sample per
    backend per rep, round-robin — so slow drift on a contended CPU
    (frequency scaling, co-tenants) biases every backend equally instead
    of whichever ran last; min-of-reps then drops the contended samples.
    'loop'/'batched' samples are one run_round() + sync (the PR 1 rows,
    kept for trajectory continuity); ('batched'|'scan', E) samples are E
    rounds through run(max_rounds=E, eval_every=E) — the real driver at
    eval cadence E, so async dispatch (batched), host-side chunk prep +
    the single per-chunk device_get (scan), and history records are all
    in the measurement — amortized to seconds/round."""
    sample = {}
    for backend in ("loop", "batched"):
        sim = _make_sim(m, backend)
        sim.run_round()
        sim.block_until_ready()

        def one(sim=sim):
            sim.run_round()
            sim.block_until_ready()
            return 1

        sample[backend] = one
    scan_sims = []
    for backend in ("batched", "scan"):
        for ev in SCAN_EVALS:
            sim = _make_sim(m, backend)
            sim.run(max_rounds=ev, eval_every=ev)  # compile + warm
            if backend == "scan":
                scan_sims.append(sim)
            sample[(backend, ev)] = (
                lambda sim=sim, ev=ev: sim.run(max_rounds=ev, eval_every=ev)
                and ev)
    best = {k: float("inf") for k in sample}
    for _ in range(reps):
        for k, fn in sample.items():
            t0 = time.perf_counter()
            rounds = fn()
            best[k] = min(best[k], (time.perf_counter() - t0) / rounds)
    for sim in scan_sims:
        assert sim.trace_count == 1, f"scan retraced {sim.trace_count}x"
    return best


def run(quick: bool = False, smoke: bool = False, out: str = "",
        speedups: Optional[dict] = None, scan_speedups: Optional[dict] = None):
    """smoke=True is the CI gate: tiny config (M=10 only). `out` gets the
    timing rows plus speedup rows as a CI artifact; pass dicts as
    `speedups` / `scan_speedups` to receive the raw {m: loop/batched} and
    {m: batched/scan@GATE_EVAL} ratios (main --check uses these — never
    the rounded CSV strings). smoke/quick runs never clobber the tracked
    full-size BENCH_round_step.json trajectory; its per-round rows keep
    the documented {m, backend, rounds_per_sec, round_ms} shape and scan
    rows add an `eval_every` key."""
    ms = [10] if smoke else ([10, 50] if quick else [10, 50, 200])
    reps = {10: 5, 50: 4, 200: 3}
    rows_json = []
    speedup_json = []
    rows_csv = []
    for m in ms:
        best = _bench_m(m, reps[m])
        for backend in ("loop", "batched"):
            sec = best[backend]
            rows_json.append({
                "m": m,
                "backend": backend,
                "rounds_per_sec": 1.0 / sec,
                "round_ms": sec * 1e3,
            })
            rows_csv.append((f"round_step_m{m}_{backend}",
                             f"{sec * 1e6:.0f}", f"{1.0 / sec:.3f}"))
        speedup = best["loop"] / best["batched"]
        if speedups is not None:
            speedups[m] = speedup
        speedup_json.append({"m": m, "speedup_x": speedup})
        rows_csv.append((f"round_step_m{m}_loop_over_batched", "",
                         f"{speedup:.2f}"))
        for backend in ("batched", "scan"):
            for ev in SCAN_EVALS:
                sec = best[(backend, ev)]
                rows_json.append({
                    "m": m,
                    "backend": backend,
                    "eval_every": ev,
                    "rounds_per_sec": 1.0 / sec,
                    "round_ms": sec * 1e3,
                })
                rows_csv.append((f"round_step_m{m}_{backend}_e{ev}",
                                 f"{sec * 1e6:.0f}", f"{1.0 / sec:.3f}"))
        for ev in SCAN_EVALS:
            scan_x = best[("batched", ev)] / best[("scan", ev)]
            speedup_json.append(
                {"m": m, "eval_every": ev, "scan_speedup_x": scan_x})
            rows_csv.append((f"round_step_m{m}_batched_over_scan_e{ev}", "",
                             f"{scan_x:.2f}"))
            if ev == GATE_EVAL and scan_speedups is not None:
                scan_speedups[m] = scan_x
    if not (quick or smoke):
        # Only full runs update the tracked artifact: a reduced sweep must
        # not clobber the M=200 rows of the cross-PR perf trajectory.
        with open(JSON_PATH, "w") as f:
            json.dump(rows_json, f, indent=2)
            f.write("\n")
    if out:
        with open(out, "w") as f:
            json.dump(rows_json + speedup_json, f, indent=2)
            f.write("\n")
    return "name,us_per_round,rounds_per_sec_or_x", rows_csv


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: M=10 only, no tracked-artifact write")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if the batched backend is not faster than "
                         "the loop backend at any M (the PR 1 speedup), or "
                         "if the scan backend falls below the batched "
                         f"driver at eval_every={GATE_EVAL} by more than "
                         f"the {GATE_TOL} noise band (equal-work run() "
                         "comparison; the chunk-fusion speedup)")
    ap.add_argument("--out", default="",
                    help="also write the rows JSON here (CI artifact)")
    args = ap.parse_args(argv)
    speedups: dict = {}
    scan_speedups: dict = {}
    header, rows = run(quick=args.quick, smoke=args.smoke, out=args.out,
                       speedups=speedups, scan_speedups=scan_speedups)
    print(header)
    for r in rows:
        print(",".join(map(str, r)))
    if args.check:
        bad = {m: x for m, x in speedups.items() if x <= 1.0}
        if bad:
            print(f"FAIL: batched backend slower than loop: {bad}")
            raise SystemExit(1)
        print("check: batched backend faster than loop at every M")
        bad = {m: x for m, x in scan_speedups.items() if x < GATE_TOL}
        if bad:
            print(f"FAIL: scan backend slower than batched at "
                  f"eval_every={GATE_EVAL} (tol {GATE_TOL}): {bad}")
            raise SystemExit(1)
        print(f"check: scan backend >= batched at eval_every={GATE_EVAL} "
              f"(tol {GATE_TOL}) at every M")


if __name__ == "__main__":
    main()
