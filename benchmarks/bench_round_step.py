"""Round-step throughput: backend='loop' vs backend='batched'.

The tentpole perf path: one compiled, donated, vmapped round step versus
the per-client host loop (one dispatch + host compress/decompress
roundtrip + device->host sync per client per round). Runs the CNN-FL
harness with int8 update compression at M in {10, 50, 200} and writes
``BENCH_round_step.json`` (rows ``{m, backend, rounds_per_sec, round_ms}``)
next to the repo root so the perf trajectory is tracked across PRs.

  PYTHONPATH=src python -m benchmarks.run --only round_step [--quick]
  PYTHONPATH=src python benchmarks/bench_round_step.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, __file__.rsplit("/", 2)[0])

from repro.configs.base import FedConfig  # noqa: E402
from repro.models import cnn  # noqa: E402

from benchmarks.common import make_cnn_sim  # noqa: E402

JSON_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_round_step.json")

# theta=0.62 -> V=1: the talk-heavy end of the paper's trade-off (sync
# every local step), where simulator overhead is the round time. The
# smoke-scale CNN keeps model GEMMs from masking the overhead under
# measurement; int8 compression exercises the full uplink path.
BENCH_FED = dict(batch_size=4, theta=0.62, lr=0.01, compress_updates=True)


def _time_backend(m: int, backend: str, timed_rounds: int) -> float:
    """Best-of-timed-rounds seconds/round after a warmup round (the warmup
    absorbs jit compilation for the batched backend; min is robust to CPU
    contention on shared runners)."""
    fed = FedConfig(n_devices=m, **BENCH_FED)
    sim = make_cnn_sim("mnist", fed, f"{backend}-m{m}", seed=0,
                       backend=backend, with_eval=False,
                       cnn_cfg=cnn.mnist_cnn_small())
    sim.run_round()
    sim.block_until_ready()
    best = float("inf")
    for _ in range(timed_rounds):
        t0 = time.perf_counter()
        sim.run_round()
        sim.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def run(quick: bool = False):
    ms = [10, 50] if quick else [10, 50, 200]
    timed = {10: 5, 50: 4, 200: 3}
    rows_json = []
    rows_csv = []
    per_m = {}
    for m in ms:
        for backend in ("loop", "batched"):
            sec = _time_backend(m, backend, timed[m])
            per_m.setdefault(m, {})[backend] = sec
            rows_json.append({
                "m": m,
                "backend": backend,
                "rounds_per_sec": 1.0 / sec,
                "round_ms": sec * 1e3,
            })
            rows_csv.append((f"round_step_m{m}_{backend}",
                             f"{sec * 1e6:.0f}", f"{1.0 / sec:.3f}"))
        speedup = per_m[m]["loop"] / per_m[m]["batched"]
        rows_csv.append((f"round_step_m{m}_speedup", "", f"{speedup:.2f}"))
    if not quick:
        # Only full runs update the tracked artifact: a --quick sweep must
        # not clobber the M=200 rows of the cross-PR perf trajectory.
        with open(JSON_PATH, "w") as f:
            json.dump(rows_json, f, indent=2)
            f.write("\n")
    return "name,us_per_round,rounds_per_sec_or_x", rows_csv


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    header, rows = run(quick=args.quick)
    print(header)
    for r in rows:
        print(",".join(map(str, r)))


if __name__ == "__main__":
    main()
