"""Benchmark entry point: one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig1a,...] \
      [--scenario <name>] [--seeds N] [--json PATH]

Emits ``name,...`` CSV blocks per benchmark. ``--scenario`` restricts the
scenario-aware benchmarks (fig2, straggler) to one registered edge
scenario (federated/scenarios.py); ``--seeds N`` runs seed-aware
benchmarks (fig2) as a vmapped N-seed fleet per method and reports
mean +/- std confidence bands instead of single-run numbers. Benchmarks
that don't take a flag run unchanged, with a note.

``--json PATH`` additionally writes one machine-readable JSON document
for everything that ran: Study-backed figures emit their full
`StudyResult.to_json()` payload (per-arm histories, grouping report,
summaries — what the CI study gate consumes), other benchmarks emit
their header/rows. The roofline table reads the dry-run dumps in
experiments/dryrun (run launch/dryrun.py first for the full 40-pair
baseline)."""
from __future__ import annotations

import argparse
import inspect
import json
import sys
import time

sys.path.insert(0, __file__.rsplit("/", 2)[0])

from benchmarks import (  # noqa: E402
    ablation_compression,
    ablation_straggler,
    async_vs_sync,
    bench_round_step,
    bench_study,
    fig1a_epsilon,
    fig1b_batch,
    fig1c_theta,
    fig1d_rounds,
    fig2_defl_vs_fedavg,
    roofline_table,
)
from repro.federated import scenarios  # noqa: E402

BENCHES = {
    "fig1a": fig1a_epsilon.run,
    "fig1b": fig1b_batch.run,
    "fig1c": fig1c_theta.run,
    "fig1d": fig1d_rounds.run,
    "fig2": fig2_defl_vs_fedavg.run,
    "async": async_vs_sync.run,
    "straggler": ablation_straggler.run,
    "compression": ablation_compression.run,
    "roofline": roofline_table.run,
    "round_step": bench_round_step.run,
    "study": bench_study.run,
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced round budgets (single-core CPU container)")
    ap.add_argument("--only", default="")
    ap.add_argument("--scenario", default="", choices=("",) + scenarios.names(),
                    help="restrict scenario-aware benchmarks to one "
                         "registered edge scenario")
    ap.add_argument("--seeds", type=int, default=1,
                    help="run seed-aware benchmarks as a vmapped N-seed "
                         "fleet per configuration (mean +/- std bands)")
    ap.add_argument("--json", default="",
                    help="write a machine-readable JSON document of every "
                         "benchmark that ran (StudyResult payloads for "
                         "study-backed figures)")
    ap.add_argument("--checkpoint-dir", default="",
                    help="crash-safe per-(arm, seed) autosave for study-"
                         "backed benchmarks (Study.run(checkpoint_dir=...)): "
                         "a killed run resumes from the saved members "
                         "bit-identically")
    ap.add_argument("--no-resume", action="store_true",
                    help="with --checkpoint-dir: ignore existing member "
                         "checkpoints and re-run everything (files are "
                         "overwritten)")
    args = ap.parse_args(argv)
    names = args.only.split(",") if args.only else list(BENCHES)
    payloads = {}
    for name in names:
        fn = BENCHES[name]
        kw = {"quick": args.quick}
        if args.scenario:
            if "scenario" in inspect.signature(fn).parameters:
                kw["scenario"] = args.scenario
            else:
                print(f"# === {name}: not scenario-aware; running as-is ===",
                      flush=True)
        if args.seeds > 1:
            if "seeds" in inspect.signature(fn).parameters:
                kw["seeds"] = args.seeds
            else:
                print(f"# === {name}: not seed-aware; running as-is ===",
                      flush=True)
        if args.checkpoint_dir:
            if "checkpoint_dir" in inspect.signature(fn).parameters:
                kw["checkpoint_dir"] = args.checkpoint_dir
                kw["resume"] = not args.no_resume
            else:
                print(f"# === {name}: not checkpoint-aware; running "
                      "as-is ===", flush=True)
        t0 = time.time()
        out = fn(**kw)
        header, rows = out[0], out[1]
        payloads[name] = (out[2] if len(out) > 2
                          else {"header": header, "rows": [list(r) for r in rows]})
        print(f"# === {name} ({time.time() - t0:.1f}s) ===", flush=True)
        print(header)
        for r in rows:
            print(",".join(map(str, r)))
        print(flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(payloads, f, indent=2, default=float)
            f.write("\n")
        print(f"# wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
