"""Ablation (beyond-paper): int8 update compression re-balances the
talk/work trade-off.

Compression shrinks s (update bits) ~4x, which shrinks T_cm; the DEFL
optimizer then chooses LESS local work (smaller alpha/V) and the overall
time drops — i.e. the paper's trade-off surface shifts, it doesn't just
scale. Quantifies Eq. 29 under both update sizes.
"""
from __future__ import annotations

from benchmarks.common import CALIBRATED_C, cnn_update_bits, paper_population
from repro.configs.base import FedConfig
from repro.core import defl


def run(quick: bool = False):
    pop = paper_population(10)
    bits = cnn_update_bits("mnist")
    rows = []
    for compress, label in ((False, "fp32"), (True, "int8")):
        fed = FedConfig(n_devices=10, epsilon=0.01, nu=2.0, c=CALIBRATED_C,
                        compress_updates=compress)
        plan = defl.make_plan(fed, pop, bits)
        rows.append(("compression", label, round(plan.T_cm, 4), plan.b,
                     round(plan.theta, 4), plan.V,
                     round(plan.H_pred, 1), round(plan.T_round, 3),
                     round(plan.overall_pred, 1)))
    return ("name,update_dtype,T_cm_s,b_star,theta_star,V,H,T_round_s,"
            "overall_pred_s", rows)


if __name__ == "__main__":
    header, rows = run()
    print(header)
    for r in rows:
        print(",".join(map(str, r)))
