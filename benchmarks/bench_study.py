"""Study throughput: grouped vmapped multi-arm execution vs the same arms
run sequentially — the dispatch-amortization win the Study API exists for.

Builds the Fig. 2 quick-scale comparison STRUCTURE (DEFL/FedAvg/Rand
arms x 2 edge scenarios x realization seeds) at overhead-scale model size
(mnist_cnn_tiny, eval_every=1): with compute at dispatch-overhead scale,
what remains is exactly what grouping amortizes — one vmapped dispatch +
one stacked transfer per chunk for a whole (arm x seed) group instead of
one per member. At full Fig. 2 model scale the envelope's padded compute
dominates on a 2-core CPU and grouping breaks even instead (documented in
EXPERIMENTS.md §Study API) — the gate guards the driver, not the GEMMs.

  PYTHONPATH=src python benchmarks/bench_study.py [--check] [--out PATH]

--check exits 1 if grouped execution is below GATE x sequential (CI's
bench-smoke job). --out writes the StudyResult JSON + timing rows (the
uploaded CI artifact).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

sys.path.insert(0, __file__.rsplit("/", 2)[0])

from benchmarks.common import make_cnn_spec  # noqa: E402
from repro.configs.base import FedConfig  # noqa: E402
from repro.federated.study import Study  # noqa: E402

SCENARIOS = ("uniform", "dropout")  # the 2-scenario smoke
SEEDS = (0, 1, 2, 3)  # 3 arms x 4 seeds = 12 members per scenario group
ROUNDS = 8
GATE = 1.2

# Mixed (b, V) per method — the fig2 shape structure at overhead scale:
# the three arms of a scenario share one envelope group (b_env=4,
# V_env=2). Envelope execution pays padded compute to buy dispatch
# amortization, so the smoke keeps per-step compute at dispatch-overhead
# scale where the trade is visible (the same reasoning as the fleet_s8
# rows in bench_round_step.py — at full Fig. 2 model scale on the 2-core
# CPU the padded GEMMs dominate instead; see EXPERIMENTS.md §Study API).
ARM_FEDS = (
    ("DEFL", dict(batch_size=4, theta=0.62)),                   # V=1
    ("FedAvg", dict(batch_size=1, theta=float(np.exp(-1.0)))),  # V=2
    ("Rand", dict(batch_size=2, theta=0.62)),                   # V=1
)


def build_study(seeds=SEEDS, rounds=ROUNDS) -> Study:
    # with_eval=True at eval_every=1: the Fig. 2 time-to-accuracy cadence.
    # Eval is where grouping bites hardest — one vmapped eval dispatch per
    # chunk for the whole (arm x seed) group vs one host eval per member
    # (the vmapped-fleet-eval satellite of PR 5).
    arms = []
    for scen in SCENARIOS:
        for label, fkw in ARM_FEDS:
            fed = FedConfig(n_devices=10, nu=2.0, lr=0.05, **fkw)
            arms.append((f"{label}@{scen}", make_cnn_spec(
                "mnist", fed, f"{label}@{scen}", n_train=240, n_test=40,
                scenario=scen, cnn_cfg="mnist_cnn_tiny")))
    return Study(arms=arms, seeds=seeds, max_rounds=rounds, eval_every=1)


def run(quick: bool = False, out: str = "", speedup_out=None):
    """(header, rows, payload): grouped vs sequential seconds per
    member-round, their ratio, and the smoke StudyResult JSON.
    quick=True (benchmarks/run.py --quick) halves the member/round
    budget — informational only; the gated CI configuration is main()'s
    full smoke. `speedup_out` (a dict) receives the raw ratio."""
    study = (build_study(seeds=SEEDS[:2], rounds=4) if quick
             else build_study())
    rounds = study.max_rounds
    # Prebuilt sims on BOTH sides: the timing compares execution (chunk
    # prep + dispatch + fetch per member), not dataset/plan build cost.
    built = study.build_sims()
    members = len(study.arms) * len(study.seeds)
    work = members * rounds

    # Warm both paths (absorbs jit compilation on each side).
    study.run(sims=built)
    for label, _ in study.arms:
        for seed in study.seeds:
            built[label].run(built[label].init(seed), max_rounds=rounds,
                             eval_every=1)

    def grouped():
        study.run(sims=built)
        return work

    def sequential():
        for label, _ in study.arms:
            for seed in study.seeds:
                built[label].run(built[label].init(seed), max_rounds=rounds,
                                 eval_every=1)
        return work

    best = {"grouped": float("inf"), "sequential": float("inf")}
    sample = {"grouped": grouped, "sequential": sequential}
    for _ in range(3):
        # Interleaved best-of sampling (same rationale as
        # bench_round_step): CPU frequency drift biases both sides
        # equally; min drops contended samples.
        for k, fn in sample.items():
            t0 = time.perf_counter()
            n = fn()
            best[k] = min(best[k], (time.perf_counter() - t0) / n)
    ratio = best["sequential"] / best["grouped"]
    if speedup_out is not None:
        speedup_out["grouped_over_sequential"] = ratio
    result = study.run(sims=built)  # the artifact payload (post-timing)
    rows = [
        ("study_grouped", f"{best['grouped'] * 1e6:.0f}",
         f"{1.0 / best['grouped']:.3f}"),
        ("study_sequential", f"{best['sequential'] * 1e6:.0f}",
         f"{1.0 / best['sequential']:.3f}"),
        ("study_grouped_over_sequential", "", f"{ratio:.2f}"),
    ]
    payload = {
        "study": result.to_json(),
        "members": members,
        "rounds": rounds,
        "grouped_s_per_member_round": best["grouped"],
        "sequential_s_per_member_round": best["sequential"],
        "grouped_over_sequential": ratio,
        "gate": GATE,
    }
    if out:
        with open(out, "w") as f:
            json.dump(payload, f, indent=2, default=float)
            f.write("\n")
    return "name,us_per_member_round,member_rounds_per_sec_or_x", rows, payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help=f"exit 1 if grouped multi-arm execution is below "
                         f"{GATE}x the same arms run sequentially")
    ap.add_argument("--out", default="",
                    help="write the StudyResult JSON + timings here "
                         "(CI artifact)")
    args = ap.parse_args(argv)
    speed: dict = {}
    header, rows, _ = run(out=args.out, speedup_out=speed)
    print(header)
    for r in rows:
        print(",".join(map(str, r)))
    if args.check:
        x = speed["grouped_over_sequential"]
        if x < GATE:
            # Noisy-runner tolerance: one re-measurement before failing —
            # a genuine regression fails twice, a scheduler hiccup doesn't
            # (artifact JSON from the first run is kept; only the gate
            # ratio is re-measured).
            print(f"check: grouped study {x:.2f}x sequential (< {GATE}x); "
                  "re-measuring once")
            speed = {}
            run(speedup_out=speed)
            x = speed["grouped_over_sequential"]
        if x < GATE:
            print(f"FAIL: grouped study {x:.2f}x sequential (< {GATE}x)")
            raise SystemExit(1)
        print(f"check: grouped study >= {GATE}x sequential ({x:.2f}x)")


if __name__ == "__main__":
    main()
