"""Fig. 1(b): batch-size impact — simulated FL runs at b in {16, 32, 64}
reporting overall time and test accuracy at a matched round budget.

Declared as one `Study`: the three b-arms share (model, V, lr), so the
shape-envelope grouping pads every arm to b_env=64 and runs the whole
sweep as ONE vmapped fleet dispatch stream instead of three sequential
runs."""
from __future__ import annotations

from benchmarks.common import make_cnn_spec
from repro.configs.base import FedConfig
from repro.federated.study import Study

BATCHES = (16, 32, 64)


def study(quick: bool = False) -> Study:
    n_train = 800 if quick else 1500
    arms = [
        (f"b{b}", make_cnn_spec(
            "mnist",
            FedConfig(n_devices=10, batch_size=b, theta=0.15, nu=2.0,
                      lr=0.05),
            f"b{b}", n_train=n_train))
        for b in BATCHES
    ]
    return Study(arms=arms, max_rounds=6 if quick else 12, eval_every=3)


def run(quick: bool = False):
    res = study(quick).run()
    rows = []
    for b, label in zip(BATCHES, res.labels):
        r = res[label][0]
        last_acc = next((h.test_acc for h in reversed(r.history)
                         if h.test_acc is not None), float("nan"))
        rows.append(("fig1b", b, r.rounds, round(r.total_time, 2),
                     round(r.history[-1].train_loss, 4),
                     round(last_acc, 4)))
    return ("name,batch,rounds,overall_time_s,final_loss,test_acc", rows,
            res.to_json())


if __name__ == "__main__":
    header, rows, _ = run()
    print(header)
    for r in rows:
        print(",".join(map(str, r)))
