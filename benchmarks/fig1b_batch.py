"""Fig. 1(b): batch-size impact — simulated FL runs at b in {16, 32, 64}
reporting overall time and test accuracy at a matched round budget."""
from __future__ import annotations

from benchmarks.common import run_cnn_fl
from repro.configs.base import FedConfig


def run(quick: bool = False):
    rounds = 6 if quick else 12
    rows = []
    for b in (16, 32, 64):
        fed = FedConfig(n_devices=10, batch_size=b, theta=0.15, nu=2.0,
                        lr=0.05)
        res = run_cnn_fl("mnist", fed, label=f"b{b}", rounds=rounds,
                         n_train=800 if quick else 1500)
        last_acc = next((r.test_acc for r in reversed(res.history)
                         if r.test_acc is not None), float("nan"))
        rows.append(("fig1b", b, res.rounds, round(res.total_time, 2),
                     round(res.history[-1].train_loss, 4),
                     round(last_acc, 4)))
    return ("name,batch,rounds,overall_time_s,final_loss,test_acc", rows)


if __name__ == "__main__":
    header, rows = run()
    print(header)
    for r in rows:
        print(",".join(map(str, r)))
