"""Ablation: device heterogeneity and the synchronous straggler bound
(Eqs. 5/7 — T_cp and T_cm are max_m over devices).

Sweeps the heterogeneity level of the device population and reports how
the straggler terms inflate the DEFL-optimal plan and its predicted
overall time, vs a hypothetical mean-device (asynchronous-ideal) system.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    CALIBRATED_C,
    CALIBRATED_COMPUTE,
    cnn_update_bits,
)
from repro.configs.base import WirelessConfig
from repro.core import delay, kkt


def run(quick: bool = False):
    bits = cnn_update_bits("mnist")
    wc = WirelessConfig()
    rows = []
    for het in (0.0, 0.2, 0.5, 1.0):
        pop = delay.draw_population(10, CALIBRATED_COMPUTE, wc, seed=0,
                                    heterogeneity=het)
        T_cm_max = delay.round_comm_time(bits, wc, pop.p, pop.h)
        T_cm_mean = float(np.mean(
            [delay.uplink_time(bits, wc, p, h) for p, h in zip(pop.p, pop.h)]))
        g_max = float(max(pop.G / pop.f))
        g_mean = float(np.mean(pop.G / pop.f))
        prob = kkt.DelayProblem(T_cm=T_cm_max, g=g_max, M=10, eps=0.01,
                                nu=2.0, c=CALIBRATED_C)
        sol = kkt.closed_form(prob).quantized(prob)
        prob_mean = kkt.DelayProblem(T_cm=T_cm_mean, g=g_mean, M=10,
                                     eps=0.01, nu=2.0, c=CALIBRATED_C)
        sol_mean = kkt.closed_form(prob_mean).quantized(prob_mean)
        rows.append(("straggler", het,
                     round(T_cm_max / T_cm_mean, 2),
                     round(g_max / g_mean, 2),
                     sol.b, sol.V, round(sol.overall, 1),
                     round(sol_mean.overall, 1),
                     round(sol.overall / sol_mean.overall, 2)))
    return ("name,heterogeneity,Tcm_max_over_mean,g_max_over_mean,"
            "b_star,V,overall_straggler_s,overall_mean_s,slowdown", rows)


if __name__ == "__main__":
    header, rows = run()
    print(header)
    for r in rows:
        print(",".join(map(str, r)))
