"""Ablation: the synchronous straggler bound per edge scenario
(Eqs. 5/7 — T_cp and T_cm are max_m over devices).

Declared as a `Study` with one plan=True arm per registered scenario
(federated/scenarios.py): each arm's analytic operating point
(`Study.plans()`) is the DEFL plan solved against that scenario's
realized population — straggler terms inflate it — compared against a
hypothetical mean-device (asynchronous-ideal) system on the same draw.
Partial-participation scenarios additionally shrink the effective M in
the Eq. 12 round-count model (visible as plan.problem.M).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import CALIBRATED_C
from repro.configs.base import FedConfig
from repro.core import delay, kkt
from repro.federated import scenarios
from repro.federated.experiment import ExperimentSpec
from repro.federated.study import Study

M_DEVICES = 10  # the paper's population size


def study(scenario: str = "") -> Study:
    names = (scenario,) if scenario else scenarios.names()
    fed = FedConfig(n_devices=M_DEVICES, epsilon=0.01, nu=2.0,
                    c=CALIBRATED_C)
    arms = [
        (name, ExperimentSpec(fed=fed, model="mnist_cnn", dataset="mnist",
                              scenario=name, plan=True, batch_cap=None,
                              label=name))
        for name in names
    ]
    return Study(arms=arms)


def run(quick: bool = False, scenario: str = ""):
    st = study(scenario)
    plans = st.plans()
    rows = []
    for (name, spec), (label, plan) in zip(st.arms, plans.items()):
        pop = spec.device_population()
        t_cm = delay.per_client_uplink_time(
            spec.update_bits(), spec.wireless, pop.p, pop.h)
        T_cm_max, T_cm_mean = float(t_cm.max()), float(t_cm.mean())
        g_max = float(max(pop.G / pop.f))
        g_mean = float(np.mean(pop.G / pop.f))
        sol, M_eff = plan.solution, plan.problem.M
        # Mean-device hypothetical (asynchronous-ideal) on the same draw.
        prob_mean = kkt.DelayProblem(T_cm=T_cm_mean, g=g_mean, M=M_eff,
                                     eps=0.01, nu=2.0, c=CALIBRATED_C)
        sol_mean = kkt.closed_form(prob_mean).quantized(prob_mean)
        rows.append(("straggler", name,
                     round(T_cm_max / T_cm_mean, 2),
                     round(g_max / g_mean, 2),
                     M_eff,
                     plan.b, plan.V, round(sol.overall, 1),
                     round(sol_mean.overall, 1),
                     round(sol.overall / sol_mean.overall, 2)))
    return ("name,scenario,Tcm_max_over_mean,g_max_over_mean,M_eff,"
            "b_star,V,overall_straggler_s,overall_mean_s,slowdown", rows)


if __name__ == "__main__":
    header, rows = run()
    print(header)
    for r in rows:
        print(",".join(map(str, r)))
