"""Ablation: the synchronous straggler bound per edge scenario
(Eqs. 5/7 — T_cp and T_cm are max_m over devices).

Runs the scenario registry (federated/scenarios.py) and reports how each
population's straggler terms inflate the DEFL-optimal plan and its
predicted overall time, vs a hypothetical mean-device (asynchronous-ideal)
system on the same draw. Partial-participation scenarios additionally
shrink the effective M in the Eq. 12 round-count model
(defl.make_plan(participation=...)).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (
    CALIBRATED_C,
    CALIBRATED_COMPUTE,
    cnn_update_bits,
)
from repro.configs.base import FedConfig, WirelessConfig
from repro.core import delay, kkt
from repro.federated import scenarios

M_DEVICES = 10  # the paper's population size


def run(quick: bool = False, scenario: str = ""):
    bits = cnn_update_bits("mnist")
    wc = WirelessConfig()
    fed = FedConfig(n_devices=M_DEVICES, epsilon=0.01, nu=2.0, c=CALIBRATED_C)
    rows = []
    names = (scenario,) if scenario else scenarios.names()
    for name in names:
        scen = scenarios.get(name)
        pop = scen.population(M_DEVICES, CALIBRATED_COMPUTE, wc, seed=0)
        t_cm = delay.per_client_uplink_time(bits, wc, pop.p, pop.h)
        T_cm_max, T_cm_mean = float(t_cm.max()), float(t_cm.mean())
        g_max = float(max(pop.G / pop.f))
        g_mean = float(np.mean(pop.G / pop.f))
        # Straggler side: the actual planner (same seed -> same draw), so
        # the effective-M participation shrinkage stays whatever
        # defl.make_plan implements rather than a reimplementation here.
        plan = scenarios.plan_for_scenario(
            fed, scen, bits, cc=CALIBRATED_COMPUTE, wc=wc, seed=0)
        sol, M_eff = plan.solution, plan.problem.M
        # Mean-device hypothetical (asynchronous-ideal) on the same draw.
        prob_mean = kkt.DelayProblem(T_cm=T_cm_mean, g=g_mean, M=M_eff,
                                     eps=0.01, nu=2.0, c=CALIBRATED_C)
        sol_mean = kkt.closed_form(prob_mean).quantized(prob_mean)
        rows.append(("straggler", name,
                     round(T_cm_max / T_cm_mean, 2),
                     round(g_max / g_mean, 2),
                     M_eff,
                     plan.b, plan.V, round(sol.overall, 1),
                     round(sol_mean.overall, 1),
                     round(sol.overall / sol_mean.overall, 2)))
    return ("name,scenario,Tcm_max_over_mean,g_max_over_mean,M_eff,"
            "b_star,V,overall_straggler_s,overall_mean_s,slowdown", rows)


if __name__ == "__main__":
    header, rows = run()
    print(header)
    for r in rows:
        print(",".join(map(str, r)))
