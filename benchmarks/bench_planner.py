"""Planner service throughput: batched plan queries vs a per-query loop.

The online planner (federated/planner.py) answers Q concurrent plan
queries through ONE vectorized `kkt.solve_batch` dispatch per method
(`PlannerService.plan_batch`); the alternative a naive service would run
is Q scalar `plan()` calls. Both paths are bit-identical per lane
(tests/test_planner.py), so the only question is throughput — measured
here at the ISSUE's serving shape, Q=256 queries against a 64-device
rolling population, for both the closed-form and the vectorized
golden-section ('numerical') solver.

  PYTHONPATH=src python benchmarks/bench_planner.py [--check] [--out PATH]

--check exits 1 if the batched closed-form path is below GATE x the
sequential per-query loop at Q=256 (CI's bench-smoke job). --out writes
the timing rows as JSON (the uploaded CI artifact).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

sys.path.insert(0, __file__.rsplit("/", 2)[0])

from repro.configs.base import FedConfig  # noqa: E402
from repro.federated.planner import (  # noqa: E402
    DeviceStateUpdate, PlannerService, PlanQuery,
)

Q = 256
M = 64
GATE = 2.0
FED = FedConfig(n_devices=M, epsilon=0.01, nu=2.0, c=4.0)
UPDATE_BITS = 8e5


def build_service(seed: int = 0) -> PlannerService:
    rng = np.random.default_rng(seed)
    svc = PlannerService(FED, UPDATE_BITS)
    svc.observe([DeviceStateUpdate(
        i, g=float(rng.uniform(1e-4, 2e-3)), p=0.2,
        h=float(rng.uniform(1e-9, 1e-8))) for i in range(M)])
    return svc


def build_queries(method: str, q: int = Q, seed: int = 1):
    """q tenants with distinct participation estimates and cohort sizes —
    the heterogeneous-query shape one batched dispatch must absorb."""
    rng = np.random.default_rng(seed)
    return [PlanQuery(participation=float(rng.uniform(0.3, 1.0)),
                      cohort_size=int(rng.integers(4, M + 1)),
                      method=method, tag=f"q{i}")
            for i in range(q)]


def _time_best(fn, reps: int = 3) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(quick: bool = False, out: str = "", speedup_out=None):
    """(header, rows, payload): batched vs sequential seconds per query
    and their ratio, for both solver methods. The gated configuration is
    closed_form at Q=256; `quick` shrinks Q — informational only."""
    q = 64 if quick else Q
    svc = build_service()
    rows, payload = [], {"q": q, "devices": M, "gate": GATE, "methods": {}}
    for method, reps in (("closed_form", 3), ("numerical", 1)):
        queries = build_queries(method, q=q)
        svc.plan_batch(queries[:2])  # warm caches on both paths
        svc.plan(queries[0])
        t_batch = _time_best(lambda: svc.plan_batch(queries), reps=reps)

        def sequential():
            for qq in queries:
                svc.plan(qq)

        t_seq = _time_best(sequential, reps=reps)
        ratio = t_seq / t_batch
        rows += [
            (f"plan_batch[{method}]", f"{t_batch / q * 1e6:.1f}",
             f"{q / t_batch:.0f}"),
            (f"plan_loop[{method}]", f"{t_seq / q * 1e6:.1f}",
             f"{q / t_seq:.0f}"),
            (f"plan_batch_over_loop[{method}]", "", f"{ratio:.2f}"),
        ]
        payload["methods"][method] = {
            "batched_s": t_batch, "sequential_s": t_seq, "speedup": ratio}
        if method == "closed_form" and speedup_out is not None:
            speedup_out["batch_over_loop"] = ratio
    if out:
        with open(out, "w") as f:
            json.dump(payload, f, indent=2, default=float)
            f.write("\n")
    return "name,us_per_query,queries_per_sec_or_x", rows, payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help=f"exit 1 if batched planning is below {GATE}x the "
                         f"sequential per-query loop at Q={Q}")
    ap.add_argument("--out", default="",
                    help="write the timing JSON here (CI artifact)")
    args = ap.parse_args(argv)
    speed: dict = {}
    header, rows, _ = run(out=args.out, speedup_out=speed)
    print(header)
    for r in rows:
        print(",".join(map(str, r)))
    if args.check:
        x = speed["batch_over_loop"]
        if x < GATE:
            # Noisy-runner tolerance: one re-measurement before failing
            # (same convention as bench_study).
            print(f"check: batched planning {x:.2f}x loop (< {GATE}x); "
                  "re-measuring once")
            speed = {}
            run(speedup_out=speed)
            x = speed["batch_over_loop"]
        if x < GATE:
            print(f"FAIL: batched planning {x:.2f}x loop (< {GATE}x)")
            raise SystemExit(1)
        print(f"check: batched planning >= {GATE}x loop ({x:.2f}x)")


if __name__ == "__main__":
    main()
