"""Roofline table: aggregates launch/dryrun.py JSON dumps into the
per-(arch x shape x mesh) three-term roofline report (deliverable g)."""
from __future__ import annotations

import glob
import json
import os

DEFAULT_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "dryrun")


def load_records(dryrun_dir: str = DEFAULT_DIR, tag: str = ""):
    recs = []
    for fn in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        base = os.path.basename(fn)[:-5]
        parts = base.split("--")
        rec_tag = parts[3] if len(parts) > 3 else ""
        if rec_tag != tag:
            continue
        with open(fn) as f:
            recs.append(json.load(f))
    return recs


def run(quick: bool = False, dryrun_dir: str = DEFAULT_DIR):
    """Analytic terms are primary (XLA cost_analysis visits while bodies
    once — see utils/analytic.py); raw HLO terms kept as *_hlo columns."""
    rows = []
    for r in load_records(dryrun_dir):
        if not r.get("ok"):
            rows.append(("roofline", r["arch"], r["shape"], r["mesh"],
                         "FAIL", r.get("error", ""), "", "", "", "", "", ""))
            continue
        ta = r.get("terms_analytic_seconds", r["terms_seconds"])
        th = r["terms_seconds"]
        ratio = r.get("useful_flops_ratio_analytic")
        rows.append((
            "roofline", r["arch"], r["shape"], r["mesh"],
            f"{ta['compute']:.3e}", f"{ta['memory']:.3e}",
            f"{ta['collective']:.3e}",
            r.get("dominant_analytic", r["dominant"]),
            f"{r['model_flops']:.3e}",
            f"{ratio:.3f}" if ratio else "",
            f"{th['compute']:.3e}", f"{th['collective']:.3e}"))
    return ("name,arch,shape,mesh,t_compute_s,t_memory_s,t_collective_s,"
            "dominant,model_flops,useful_ratio,t_compute_hlo,t_coll_hlo",
            rows)


if __name__ == "__main__":
    header, rows = run()
    print(header)
    for r in rows:
        print(",".join(map(str, r)))
