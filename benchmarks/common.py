"""Shared benchmark scaffolding: the paper's calibrated system settings and
the CNN-FL harness used by Figs. 1-2, now thin wrappers over the
declarative experiment API (repro.federated.experiment.ExperimentSpec)."""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.configs.base import FedConfig, WirelessConfig
from repro.core import delay, kkt
from repro.federated.experiment import (
    CALIBRATED_C,
    CALIBRATED_COMPUTE,
    ExperimentSpec,
)
from repro.federated.simulation import SimResult, Simulator

__all__ = [
    "CALIBRATED_C", "CALIBRATED_COMPUTE", "paper_population",
    "paper_problem", "cnn_update_bits", "make_cnn_spec", "make_cnn_sim",
    "run_cnn_fl", "emit",
]


def paper_population(M: int = 10, heterogeneity: float = 0.0,
                     seed: int = 0) -> delay.DevicePopulation:
    return delay.draw_population(
        M, CALIBRATED_COMPUTE, WirelessConfig(), seed, heterogeneity)


def paper_problem(update_bits: float, M: int = 10, eps: float = 0.01,
                  nu: float = 2.0, c: float = CALIBRATED_C,
                  pop: Optional[delay.DevicePopulation] = None,
                  ) -> kkt.DelayProblem:
    pop = pop if pop is not None else paper_population(M)
    T_cm = delay.round_comm_time(update_bits, WirelessConfig(), pop.p, pop.h)
    g = float(max(pop.G / pop.f))
    return kkt.DelayProblem(T_cm=T_cm, g=g, M=M, eps=eps, nu=nu, c=c)


def cnn_update_bits(dataset: str = "mnist") -> float:
    model = "mnist_cnn" if dataset == "mnist" else "cifar_cnn"
    return ExperimentSpec(model=model, dataset=dataset).update_bits()


def make_cnn_spec(
    dataset: str,
    fed: FedConfig,
    label: str,
    n_train: int = 1500,
    n_test: int = 400,
    seed: int = 0,
    backend: str = "scan",
    impl: str = "xla",
    with_eval: bool = True,
    cnn_cfg=None,  # model registry name | cnn.CNNConfig | None (default per dataset)
    scenario=None,  # registered scenario name | None
    population=None,  # PopulationSpec | None (None: dense fed.n_devices)
    async_spec=None,  # events.AsyncSpec | None (requires backend='async')
) -> ExperimentSpec:
    """The CNN-FL harness (Figs. 1-2) as an ExperimentSpec: data,
    partitions, population and model wiring all live in the spec;
    `spec.build()` returns the functional-core Simulator.

    One seed governs everything: the dataset/partition/population draw,
    and — by syncing fed.seed to `seed` — the default `init()` run state
    (PRNG key, batch order, realization stream), so `run_cnn_fl(...,
    seed=3)` actually runs at seed 3 and a scenario run is timed on the
    population it was planned for (plan_for_scenario at the same seed)."""
    if fed.seed != seed:
        fed = dataclasses.replace(fed, seed=seed)
    model = cnn_cfg if cnn_cfg is not None else (
        "mnist_cnn" if dataset == "mnist" else "cifar_cnn")
    return ExperimentSpec(
        fed=fed, model=model, dataset=dataset, n_train=n_train,
        n_test=n_test, seed=seed, scenario=scenario, backend=backend,
        impl=impl, with_eval=with_eval, label=label,
        population=population, async_spec=async_spec)


def make_cnn_sim(*args, **kw) -> Simulator:
    """`make_cnn_spec(...).build()` — returns the state-in/state-out
    Simulator (call `sim.init(seed)` for a run state)."""
    return make_cnn_spec(*args, **kw).build()


def run_cnn_fl(
    dataset: str,
    fed: FedConfig,
    label: str,
    rounds: int = 15,
    n_train: int = 1500,
    n_test: int = 400,
    eval_every: int = 3,
    target_acc: Optional[float] = None,
    seed: int = 0,
    backend: str = "scan",
    impl: str = "xla",
    scenario=None,
) -> SimResult:
    sim = make_cnn_sim(dataset, fed, label, n_train=n_train, n_test=n_test,
                       seed=seed, backend=backend, impl=impl,
                       scenario=scenario)
    _, res = sim.run(sim.init(), max_rounds=rounds, eval_every=eval_every,
                     target_acc=target_acc)
    # The masked/per-scenario/chunked path must not cost recompilation:
    # one trace per (scenario, backend) run — for 'scan' that covers every
    # chunk including a ragged final one — so the donation + deferred-sync
    # story holds.
    if backend in ("batched", "scan"):
        assert sim.trace_count == 1, (
            f"round step retraced {sim.trace_count}x for {label}")
    return res


def emit(rows, header=None):
    """CSV emission: name,us_per_call,derived columns."""
    if header:
        print(header)
    for r in rows:
        print(",".join(str(x) for x in r))
