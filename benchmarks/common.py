"""Shared benchmark scaffolding: the paper's calibrated system settings and
the CNN-FL harness used by Figs. 1-2."""
from __future__ import annotations

import functools
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ComputeConfig, FedConfig, WirelessConfig
from repro.core import defl, delay, kkt
from repro.data import BatchIterator, make_cifar_like, make_mnist_like
from repro.federated import scenarios
from repro.federated.partition import partition_dirichlet, partition_sizes
from repro.federated.simulation import FLSimulation, SimResult
from repro.models import cnn
from repro.optim import sgd
from repro.utils.tree import tree_bytes

# Calibration (see EXPERIMENTS.md §Claims): per-sample compute ~10 ms at
# b=1 on the 2 GHz edge GPU pins theta* ~= 0.13-0.15 (the paper's reported
# operating point, independent of c), and c ~= 4.0 then pins b* ~= 32
# (the paper's "rounded off" batch size) at eps = 0.01.
CALIBRATED_COMPUTE = ComputeConfig(bits_per_sample=6.8e5)
CALIBRATED_C = 4.0


def paper_population(M: int = 10, heterogeneity: float = 0.0,
                     seed: int = 0) -> delay.DevicePopulation:
    return delay.draw_population(
        M, CALIBRATED_COMPUTE, WirelessConfig(), seed, heterogeneity)


def paper_problem(update_bits: float, M: int = 10, eps: float = 0.01,
                  nu: float = 2.0, c: float = CALIBRATED_C,
                  pop: Optional[delay.DevicePopulation] = None,
                  ) -> kkt.DelayProblem:
    pop = pop if pop is not None else paper_population(M)
    T_cm = delay.round_comm_time(update_bits, WirelessConfig(), pop.p, pop.h)
    g = float(max(pop.G / pop.f))
    return kkt.DelayProblem(T_cm=T_cm, g=g, M=M, eps=eps, nu=nu, c=c)


def cnn_update_bits(dataset: str = "mnist") -> float:
    cfg = cnn.mnist_cnn() if dataset == "mnist" else cnn.cifar_cnn()
    params = cnn.init_cnn(cfg, jax.random.PRNGKey(0))
    return tree_bytes(params) * 8.0


def make_cnn_sim(
    dataset: str,
    fed: FedConfig,
    label: str,
    n_train: int = 1500,
    n_test: int = 400,
    seed: int = 0,
    backend: str = "scan",
    impl: str = "xla",
    with_eval: bool = True,
    cnn_cfg: Optional[cnn.CNNConfig] = None,
    scenario=None,  # scenarios.Scenario | registered name | None
) -> FLSimulation:
    """The CNN-FL harness (Figs. 1-2): data, partitions, population, sim.

    `backend` selects the chunk-fused scan driver ('scan', the default),
    the per-round compiled round step ('batched'), or the per-client
    reference loop ('loop'); M scales with
    fed.n_devices well past the paper's 10 — small partitions resample
    with replacement. `cnn_cfg` overrides the paper model (e.g.
    cnn.mnist_cnn_small() for overhead-dominated benching). `scenario`
    draws the device population from a registered edge scenario and runs
    its per-round participation/channel stream through the simulator."""
    make = make_mnist_like if dataset == "mnist" else make_cifar_like
    data = make(n_train, seed=seed)
    cfg = cnn_cfg or (cnn.mnist_cnn() if dataset == "mnist" else cnn.cifar_cnn())
    params = cnn.init_cnn(cfg, jax.random.PRNGKey(seed))
    parts = partition_dirichlet(data, fed.n_devices, alpha=1.0, seed=seed)
    iters = [BatchIterator(data, p, fed.batch_size, seed=seed + i)
             for i, p in enumerate(parts)]
    if scenario is not None:
        scenario = scenarios.get(scenario)
        pop = scenario.population(
            fed.n_devices, CALIBRATED_COMPUTE, WirelessConfig(), seed)
        # One seed governs population draw, realization stream (seeded
        # from fed.seed inside FLSimulation) and any plan_for_scenario
        # call made with the same seed — passing seed != fed.seed would
        # otherwise time a different population than the one planned for.
        if fed.seed != seed:
            import dataclasses
            fed = dataclasses.replace(fed, seed=seed)
    else:
        pop = paper_population(fed.n_devices)
    eval_fn = None
    if with_eval:
        test = make(n_test, seed=seed + 1)
        xb, yb = jnp.asarray(test.x), jnp.asarray(test.y)

        @jax.jit
        def eval_acc(p):
            logits = cnn.cnn_forward(cfg, p, xb)
            return jnp.mean((jnp.argmax(logits, -1) == yb).astype(jnp.float32))

        eval_fn = lambda p: {"acc": float(eval_acc(p))}  # noqa: E731

    return FLSimulation(
        functools.partial(cnn.cnn_loss, cfg), params, iters,
        partition_sizes(parts), fed, sgd(fed.lr), pop,
        eval_fn=eval_fn, label=label, backend=backend, impl=impl,
        scenario=scenario)


def run_cnn_fl(
    dataset: str,
    fed: FedConfig,
    label: str,
    rounds: int = 15,
    n_train: int = 1500,
    n_test: int = 400,
    eval_every: int = 3,
    target_acc: Optional[float] = None,
    seed: int = 0,
    backend: str = "scan",
    impl: str = "xla",
    scenario=None,
) -> SimResult:
    sim = make_cnn_sim(dataset, fed, label, n_train=n_train, n_test=n_test,
                       seed=seed, backend=backend, impl=impl,
                       scenario=scenario)
    res = sim.run(max_rounds=rounds, eval_every=eval_every,
                  target_acc=target_acc)
    # The masked/per-scenario/chunked path must not cost recompilation:
    # one trace per (scenario, backend) run — for 'scan' that covers every
    # chunk including a ragged final one — so the donation + deferred-sync
    # story holds.
    if backend in ("batched", "scan"):
        assert sim.trace_count == 1, (
            f"round step retraced {sim.trace_count}x for {label}")
    return res


def emit(rows, header=None):
    """CSV emission: name,us_per_call,derived columns."""
    if header:
        print(header)
    for r in rows:
        print(",".join(str(x) for x in r))
