"""Asynchronous vs synchronous execution: DEFL's synchronized rounds vs
buffered asynchronous aggregation (backend='async', FedBuff-style) —
time to a matched accuracy per edge scenario.

The synchronous round clock (Eq. 8) pays the straggler max every round;
the asynchronous event clock pays each client only its own service span
and aggregates every K buffered arrivals, so on straggler-skewed
populations the wall-clock trade flips. Each (scenario) comparison is
one declarative Study:

  * ``DEFL``    — plan=True scan arm: Alg. 1's (b*, theta*) against the
                  scenario population, synchronized rounds.
  * ``FedBuff`` — backend='async' arm at the SAME (b, theta): buffer
                  K=ASYNC_BUFFER, polynomial staleness discount. One
                  RoundRecord per buffer fill; sim_time is the event
                  clock, so time-to-target is like-for-like with sync.
  * ``FedBuff+`` (full runs only) — FedBuff at the (b, V) of the async
                  Eq. 12 re-derivation (defl.async_plan: expected
                  concurrency K replaces M, K over the harmonic sum of
                  service spans replaces the straggler max).

Async arms run solo inside the Study (their event clock cannot be
vmapped against synchronous round loops); the sync arm keeps the grouped
fleet path. The per-comparison `predicted_*` columns report both models'
J = H * T (Eq. 13 vs its async re-derivation) next to the measured
times."""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import make_cnn_spec
from repro.configs.base import FedConfig
from repro.core import defl
from repro.federated.events import AsyncSpec
from repro.federated.experiment import CALIBRATED_C
from repro.federated.study import Study

# >= 3 registered scenarios: the homogeneous baseline (async should tie,
# it has nothing to hide from), the compute-skewed population (the async
# win case) and Bernoulli dropout (faults compose with the event queue).
SCENARIO_NAMES = ("uniform", "stragglers", "dropout")
TARGET_ACC = 0.90
ASYNC_BUFFER = 5  # K: half the population per aggregate
M = 10


def arm_specs(scenario: str, seed: int = 0, n_train: int = 1500,
              quick: bool = False):
    """The comparison arms as ExperimentSpecs (mnist task, M=10)."""
    defl_fed = FedConfig(n_devices=M, epsilon=0.01, nu=2.0,
                         c=CALIBRATED_C, lr=0.05)

    def spec(label, fed, **kw):
        return make_cnn_spec("mnist", fed, f"{label}@{scenario}",
                             n_train=n_train, seed=seed, scenario=scenario,
                             **kw)

    sync = spec("DEFL", defl_fed).replace(plan=True)
    # FedBuff at the sync arm's solved operating point: isolates the
    # execution model (round clock vs event clock) from the plan.
    planned = sync.resolve_fed()
    buff = spec("FedBuff", planned, backend="async",
                async_spec=AsyncSpec(buffer_size=ASYNC_BUFFER,
                                     staleness="poly"))
    arms = [("DEFL", sync), ("FedBuff", buff)]
    if not quick:
        # FedBuff+ re-plans (b, V) under the async delay model itself.
        aplan = defl.async_plan(
            sync.base_fed(), sync.device_population(), sync.update_bits(),
            buffer_size=ASYNC_BUFFER, wireless=sync.wireless)
        b = min(aplan.b, 32)  # same dataset-bounded cap as batch_cap
        afed = FedConfig(n_devices=M, batch_size=b, theta=aplan.theta,
                         nu=2.0, lr=0.05)
        arms.append(("FedBuff+", spec("FedBuff+", afed, backend="async",
                                      async_spec=AsyncSpec(
                                          buffer_size=ASYNC_BUFFER,
                                          staleness="poly"))))
    return arms


def study_for(scenario: str, seed: int = 0, seeds: int = 1,
              quick: bool = False) -> Study:
    return Study(
        arms=arm_specs(scenario, seed, n_train=600 if quick else 1500,
                       quick=quick),
        seeds=range(seed, seed + seeds),
        max_rounds=4 if quick else 12, eval_every=1,
        target_acc=TARGET_ACC)


def run(quick: bool = False, scenario: str = "", seed: int = 0,
        seeds: int = 1, checkpoint_dir: str = "", resume: bool = True):
    """One row per (scenario, method): measured rounds/time/acc/
    time-to-target plus each arm's model-predicted overall time — Eq. 13
    for the sync arm, the async re-derivation (defl.async_plan at the
    arm's buffer) for async arms — and a reduction row (FedBuff vs DEFL
    on mean time-to-target-or-total)."""
    rows = []
    payload = {}
    scens = (scenario,) if scenario else SCENARIO_NAMES
    for scen in scens:
        study = study_for(scen, seed=seed, seeds=seeds, quick=quick)
        res = study.run(
            checkpoint_dir=(os.path.join(checkpoint_dir, scen)
                            if checkpoint_dir else None),
            resume=resume)
        payload[scen] = res.to_json()
        multi = seeds > 1
        for label, spec in study.arms:
            s = res.summary(label)
            fed = res[label][0].fed
            if spec.backend == "async":
                pred = defl.async_plan(
                    spec.base_fed(), spec.device_population(),
                    spec.update_bits(),
                    buffer_size=spec.async_spec.buffer_size,
                    wireless=spec.wireless).overall_pred
            else:
                pred = spec.analytic_plan().overall_pred
            tta = res.time_to_target(label)
            hit = bool(np.isfinite(tta).any())
            band = lambda m, sd, nd: (  # noqa: E731
                f"{m:.{nd}f}+-{sd:.{nd}f}" if multi else round(m, nd))
            rows.append((
                "async_vs_sync", scen, label, fed.batch_size,
                fed.local_rounds,
                res.async_modes.get(label) or "sync",
                round(s["rounds_mean"], 1),
                band(s["total_time_mean"], s["total_time_std"], 2),
                band(s["final_acc_mean"], s["final_acc_std"], 4),
                (band(float(np.nanmean(tta)), float(np.nanstd(tta)), 2)
                 if hit else ""),
                round(pred, 2)))
        rows.append(("async_vs_sync", scen, "reduction_vs_defl", "", "",
                     "", "", round(res.reduction("FedBuff", "DEFL"), 1),
                     "", "", ""))
    return ("name,scenario,method,b,V,agg,rounds,overall_time_s,acc,"
            "time_to_90,predicted_overall_s", rows, payload)


if __name__ == "__main__":
    header, rows, _ = run()
    print(header)
    for r in rows:
        print(",".join(map(str, r)))
