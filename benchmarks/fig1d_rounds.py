"""Fig. 1(d): communication rounds H and computation-time split vs theta —
the talk/work decomposition (Eq. 12 x Eq. 8).

Declared as a `Study` of fixed-(b=32, theta) arms; each arm's analytic
operating point (`Study.plans()` -> defl.fixed_plan) supplies H and the
round-time split, decomposed into talking (H * T_cm) and working
(H * V * T_cp) seconds."""
from __future__ import annotations

from repro.configs.base import FedConfig
from repro.federated.experiment import CALIBRATED_C, ExperimentSpec
from repro.federated.study import Study

THETAS = (0.5, 0.3, 0.15, 0.05, 0.01)


def study() -> Study:
    arms = [
        (f"theta{t}", ExperimentSpec(
            fed=FedConfig(n_devices=10, epsilon=0.01, batch_size=32,
                          theta=t, nu=2.0, c=CALIBRATED_C, lr=0.05),
            model="mnist_cnn", dataset="mnist", label=f"theta{t}"))
        for t in THETAS
    ]
    return Study(arms=arms)


def run(quick: bool = False):
    plans = study().plans()
    rows = []
    for t, (label, plan) in zip(THETAS, plans.items()):
        # Eq. 13 decomposed at the integer V actually run (H itself is
        # evaluated at the exact swept theta — fixed_plan(theta=...)).
        talk = plan.H_pred * plan.T_cm
        work = plan.H_pred * plan.V * plan.T_cp
        rows.append(("fig1d", t, plan.V, round(plan.H_pred, 1),
                     round(talk, 2), round(work, 2),
                     round(talk + work, 2)))
    return ("name,theta,V,H,talk_time_s,work_time_s,overall_s", rows)


if __name__ == "__main__":
    header, rows = run()
    print(header)
    for r in rows:
        print(",".join(map(str, r)))
