"""Fig. 1(d): communication rounds H and computation-time split vs theta —
the talk/work decomposition (Eq. 12 x Eq. 8)."""
from __future__ import annotations

from benchmarks.common import cnn_update_bits, paper_problem
from repro.core import tradeoff


def run(quick: bool = False):
    bits = cnn_update_bits("mnist")
    prob = paper_problem(bits)
    rows = []
    for pt in tradeoff.sweep_theta(prob, b=32,
                                   thetas=[0.5, 0.3, 0.15, 0.05, 0.01]):
        rows.append(("fig1d", pt.theta, pt.V, round(pt.H, 1),
                     round(pt.talk_time, 2), round(pt.work_time, 2),
                     round(pt.overall, 2)))
    return ("name,theta,V,H,talk_time_s,work_time_s,overall_s", rows)


if __name__ == "__main__":
    header, rows = run()
    print(header)
    for r in rows:
        print(",".join(map(str, r)))
