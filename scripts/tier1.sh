#!/usr/bin/env bash
# Tier-1 gate (see ROADMAP.md): the repo's fast verification command plus
# the simulator backend-parity suite, pinned to CPU so results match CI.
# Tests slower than ~30s carry @pytest.mark.slow and are skipped here;
# run `scripts/tier1.sh -m ""` (or `pytest -m slow`) for the long tail.
#
# This is the single entrypoint shared by CI (.github/workflows/ci.yml)
# and humans: extra args are forwarded to both pytest invocations
# (e.g. `scripts/tier1.sh -k scenarios`, `scripts/tier1.sh -m ""`), and
# pytest's exit code is propagated explicitly — a test failure in either
# invocation fails the script.
set -uo pipefail
cd "$(dirname "$0")/.."
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# test_properties.py needs hypothesis; skip it where the container lacks
# the dependency (seed-state condition) instead of failing collection.
EXTRA=()
if ! python -c "import hypothesis" 2>/dev/null; then
  echo "tier1: hypothesis not installed — skipping tests/test_properties.py"
  EXTRA+=(--ignore=tests/test_properties.py)
fi

# Backend-parity and fault-layer suites first (fast, and -x below stops
# at the first failure anywhere in the tree), then the ROADMAP tier-1
# command. Exit 5 ("no tests collected") is tolerated on the pre-pass
# only, so a forwarded -k/-m filter that deselects it doesn't fail the
# gate.
python -m pytest -q tests/test_simulation_backends.py tests/test_faults.py "$@"
rc=$?
if [ "$rc" -ne 0 ] && [ "$rc" -ne 5 ]; then
  exit "$rc"
fi
python -m pytest -x -q -m "not slow" "${EXTRA[@]}" "$@"
exit $?
