"""Integration: DEFL (Algorithm 1) end-to-end on the paper's CNN task with
delay accounting; DEFL vs FedAvg predicted-time ordering."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ComputeConfig, FedConfig, WirelessConfig
from repro.core import defl, delay
from repro.data import BatchIterator, make_mnist_like
from repro.federated.partition import partition_dirichlet, partition_sizes
from repro.federated.simulation import Simulator
from repro.models import cnn
from repro.optim import sgd
from repro.utils.tree import tree_bytes

# Calibrated compute model: ~10 ms/sample at b=1 (matches the paper's
# empirically reported theta* ~ 0.15 operating point; see benchmarks).
CAL_CC = ComputeConfig(bits_per_sample=6.8e5)


@pytest.fixture(scope="module")
def mnist_setup():
    data = make_mnist_like(600, seed=0)
    test = make_mnist_like(200, seed=1)
    cfg = cnn.mnist_cnn()
    params = cnn.init_cnn(cfg, jax.random.PRNGKey(0))
    return data, test, cfg, params


def _make_sim(data, test, cfg, params, fed, pop, label):
    parts = partition_dirichlet(data, fed.n_devices, alpha=1.0, seed=0)
    iters = [BatchIterator(data, p, fed.batch_size, seed=i)
             for i, p in enumerate(parts)]
    xb, yb = jnp.asarray(test.x), jnp.asarray(test.y)

    @jax.jit
    def eval_acc(p):
        logits = cnn.cnn_forward(cfg, p, xb)
        return jnp.mean((jnp.argmax(logits, -1) == yb).astype(jnp.float32))

    return Simulator(
        functools.partial(cnn.cnn_loss, cfg), params, iters,
        partition_sizes(parts), fed, sgd(fed.lr), pop,
        eval_fn=lambda p: {"acc": float(eval_acc(p))}, label=label)


def test_defl_trains_and_tracks_time(mnist_setup):
    data, test, cfg, params = mnist_setup
    fed = FedConfig(n_devices=4, batch_size=16, theta=0.15, nu=2.0, lr=0.05)
    pop = delay.draw_population(4, CAL_CC, WirelessConfig(), 0, 0.2)
    sim = _make_sim(data, test, cfg, params, fed, pop, "defl")
    _, res = sim.run(sim.init(), max_rounds=4, eval_every=2)
    assert res.rounds == 4
    # Simulated clock strictly increases by Eq. 8 per round.
    times = [r.sim_time for r in res.history]
    assert all(t2 > t1 for t1, t2 in zip(times, times[1:]))
    dt = np.diff([0.0] + times)
    T_cm, T_cp = sim.round_times()
    np.testing.assert_allclose(dt, T_cm + fed.local_rounds * T_cp, rtol=1e-6)
    # Training makes progress.
    assert res.history[-1].train_loss < res.history[0].train_loss


def test_defl_plan_reduces_predicted_time_vs_fedavg(mnist_setup):
    """The paper's headline claim, at the model level: DEFL's optimized
    (b*, theta*) yields lower predicted overall time (Eq. 13) than the
    FedAvg reference configuration (b=10, V=20)."""
    data, test, cfg, params = mnist_setup
    fed = FedConfig(n_devices=10, epsilon=0.01, nu=2.0, c=0.4)
    pop = delay.draw_population(10, CAL_CC, WirelessConfig(), 0, 0.0)
    bits = tree_bytes(params) * 8
    plan = defl.make_plan(fed, pop, bits)
    fedavg = defl.fixed_plan(fed, pop, bits, b=10, V=20)
    rand = defl.fixed_plan(fed, pop, bits, b=16, V=15)
    assert plan.overall_pred < fedavg.overall_pred
    assert plan.overall_pred < rand.overall_pred
    assert plan.V >= 1 and plan.b >= 1


def test_compression_shrinks_talk_time(mnist_setup):
    data, test, cfg, params = mnist_setup
    pop = delay.draw_population(4, CAL_CC, WirelessConfig(), 0, 0.0)
    bits = tree_bytes(params) * 8
    fed = FedConfig(n_devices=4)
    plain = defl.make_plan(fed, pop, bits)
    comp = defl.make_plan(
        FedConfig(n_devices=4, compress_updates=True), pop, bits)
    assert comp.T_cm < plain.T_cm / 3.5
    # With cheaper talk, the optimizer shifts toward less local work.
    assert comp.solution.alpha <= plain.solution.alpha + 1e-9
