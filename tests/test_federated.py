"""Federated substrate tests: aggregation, local updates, partitioning,
compression, mesh round-step equivalence."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import make_mnist_like
from repro.federated import compression
from repro.federated.client import client_round, make_local_update
from repro.federated.mesh_rounds import build_round_step, replicate_clients
from repro.federated.partition import (
    partition_dirichlet,
    partition_iid,
    partition_sizes,
)
from repro.federated.server import aggregate_updates
from repro.optim import sgd


def _quadratic_loss(params, batch):
    # f(w) = 0.5 || w - target ||^2 per client target
    diff = params["w"] - batch["target"]
    return 0.5 * jnp.sum(diff * diff), {}


def test_aggregate_weighted_mean():
    g = {"w": jnp.zeros(3)}
    deltas = [{"w": jnp.ones(3)}, {"w": 3 * jnp.ones(3)}]
    out = aggregate_updates(g, deltas, [1, 3])
    np.testing.assert_allclose(np.asarray(out["w"]), 2.5 * np.ones(3))


def test_local_update_descends_quadratic():
    params = {"w": jnp.zeros(4)}
    opt = sgd(0.1)
    lu = make_local_update(_quadratic_loss, opt)
    target = jnp.ones(4)
    batches = {"target": jnp.tile(target[None], (10, 1))}
    delta, _, losses = client_round(lu, params, opt.init(params), batches)
    assert float(losses[-1]) < float(losses[0])
    # 10 steps of lr=0.1 on quadratic: w -> 1 - 0.9^10
    np.testing.assert_allclose(
        np.asarray(delta["w"]), (1 - 0.9 ** 10) * np.ones(4), rtol=1e-5)


def test_partitions_disjoint_and_complete():
    data = make_mnist_like(500, seed=0)
    for parts in (partition_iid(500, 7, 0),
                  partition_dirichlet(data, 7, alpha=0.5, seed=0)):
        allidx = np.concatenate(parts)
        assert len(allidx) == 500
        assert len(np.unique(allidx)) == 500
        assert all(len(p) > 0 for p in parts)
        assert partition_sizes(parts).sum() == 500


def test_compression_roundtrip_error_bounded():
    key = jax.random.PRNGKey(0)
    update = {"a": jax.random.normal(key, (333,)) * 0.01,
              "b": jax.random.normal(jax.random.fold_in(key, 1), (64, 65))}
    comp = compression.compress_update(update, key)
    rec = compression.decompress_update(comp)
    for k in update:
        x, r = np.asarray(update[k]), np.asarray(rec[k])
        # error bounded by one quantization step per 1024-row
        assert np.max(np.abs(x - r)) <= np.max(np.abs(x)) / 127.0 + 1e-7
    assert compression.compressed_bits(update) < compression.raw_bits(update) / 3


def test_compression_unbiased():
    key = jax.random.PRNGKey(0)
    x = {"w": jnp.linspace(-0.01, 0.01, 256).reshape(1, -1) + 0.0031}
    recs = []
    for i in range(200):
        c = compression.compress_update(x, jax.random.PRNGKey(i))
        recs.append(np.asarray(compression.decompress_update(c)["w"]))
    mean = np.mean(recs, axis=0)
    scale = np.max(np.abs(np.asarray(x["w"]))) / 127.0
    assert np.max(np.abs(mean - np.asarray(x["w"]))) < 0.2 * scale


def test_mesh_round_step_equals_host_fedavg():
    """The vmapped stacked round step == per-client host loop + weighted mean."""
    C = 3
    params = {"w": jnp.arange(4, dtype=jnp.float32)}
    opt = sgd(0.1)
    targets = jnp.stack([jnp.full(4, t, jnp.float32) for t in (0.0, 1.0, 2.0)])
    V = 5
    batches = {"target": jnp.stack(
        [jnp.tile(targets[c][None], (V, 1)) for c in range(C)])}
    weights = jnp.asarray([0.2, 0.3, 0.5])

    step = build_round_step(_quadratic_loss, opt, V)
    stacked = replicate_clients(params, C)
    opt_c = jax.vmap(lambda _: opt.init(params))(jnp.arange(C))
    new_p, _, metrics = jax.jit(step)(stacked, (), batches, weights)

    # Host-side: each client runs V steps then weighted mean.
    lu = make_local_update(_quadratic_loss, opt)
    client_params = []
    for c in range(C):
        p, _, _ = lu(params, opt.init(params),
                     {"target": batches["target"][c]})
        client_params.append(np.asarray(p["w"]))
    expect = sum(w * p for w, p in zip(np.asarray(weights), client_params))
    for c in range(C):  # broadcast: every row equals the aggregate
        np.testing.assert_allclose(np.asarray(new_p["w"][c]), expect,
                                   rtol=1e-5)


def test_mesh_int8_gather_close_to_allreduce():
    C, V = 2, 3
    params = {"w": jnp.ones(8, jnp.float32)}
    opt = sgd(0.05)
    batches = {"target": jnp.stack(
        [jnp.tile(jnp.full(8, t)[None], (V, 1)) for t in (0.0, 2.0)])}
    weights = jnp.asarray([0.5, 0.5])
    stacked = replicate_clients(params, C)
    ref_step = build_round_step(_quadratic_loss, opt, V, "allreduce")
    q_step = build_round_step(_quadratic_loss, opt, V, "int8_gather")
    p_ref, _, _ = jax.jit(ref_step)(stacked, (), batches, weights)
    p_q, _, _ = jax.jit(q_step)(stacked, (), batches, weights)
    delta = np.max(np.abs(np.asarray(p_ref["w"]) - np.asarray(p_q["w"])))
    # Error bounded by one int8 step of the per-client delta magnitude
    # (each client moved by +-(1 - 0.95^3) before aggregation).
    client_delta = 1.0 - 0.95 ** V
    assert delta <= client_delta / 127 + 1e-7
