"""Checkpoint/resume of the functional simulator core.

`SimState` is the whole run state — stacked params/opt, PRNG key, round
cursor, Eq. 8 clock, scenario-stream position and data-iterator
positions — so a state saved mid-run (`save_state`), restored in a fresh
process-like context (a freshly built Simulator) and resumed must
produce the remaining history bit-identically to an uninterrupted run:
losses, clocks, participation counts, uplink bits and final params. Per
backend, with and without a scenario, across ragged chunk boundaries.
"""
import os

import jax
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.federated import experiment
from repro.federated.simulation import SimState, load_state, save_state


def _spec(backend, scenario):
    return experiment.get("mnist_smoke").replace(
        with_eval=False, backend=backend, scenario=scenario,
        fed=FedConfig(n_devices=3, batch_size=8, theta=0.62, lr=0.05,
                      compress_updates=True))


def _tail_matches(full_tail, resumed):
    assert len(full_tail) == len(resumed)
    for x, y in zip(full_tail, resumed):
        assert x.round == y.round
        np.testing.assert_array_equal(x.train_loss, y.train_loss)
        assert x.sim_time == y.sim_time
        assert x.T_cm == y.T_cm and x.T_cp == y.T_cp
        assert x.n_participants == y.n_participants
        assert x.uplink_bits == y.uplink_bits


@pytest.mark.parametrize("backend", ["loop", "batched", "scan"])
@pytest.mark.parametrize("scenario", [None, "hetero_storm"])
def test_resume_bit_identical(backend, scenario, tmp_path):
    """Interrupt at round 3 of 6 with eval_every=2 (so the scan backend
    crosses a ragged chunk boundary both before and after the save),
    round-trip the state through disk, resume on a FRESH Simulator."""
    spec = _spec(backend, scenario)
    _, full = spec.build().run(spec.build().init(7), max_rounds=6,
                               eval_every=2)
    simA = spec.build()
    mid, _ = simA.run(simA.init(7), max_rounds=3, eval_every=2)
    path = os.path.join(tmp_path, "state.pkl")
    save_state(path, mid)
    restored = load_state(path)
    assert isinstance(restored, SimState)
    assert restored.round == 3 and restored.seed == 7
    simB = spec.build()  # fresh context: new iterators, new compiled fns
    end, resumed = simB.run(restored, max_rounds=3, eval_every=2)
    _tail_matches(full.history[3:], resumed.history)
    # Device state converged to the same model, bit for bit.
    for a, b in zip(jax.tree.leaves(full.params),
                    jax.tree.leaves(resumed.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert end.round == 6


def test_state_device_get_roundtrip():
    """SimState is a pytree: jax.device_get materializes the device
    leaves in place and the result still runs."""
    spec = _spec("scan", "dropout")
    sim = spec.build()
    state, _ = sim.run(sim.init(0), max_rounds=2, eval_every=2)
    host_state = jax.device_get(state)
    assert isinstance(host_state, SimState)
    for leaf in jax.tree.leaves(host_state):
        assert isinstance(leaf, np.ndarray)
    # host fields survive the tree map
    assert host_state.round == state.round
    assert host_state.sim_time == state.sim_time
    _, resumed_from_host = sim.run(host_state, max_rounds=2, eval_every=2)
    _, resumed_from_dev = sim.run(state, max_rounds=2, eval_every=2)
    _tail_matches(resumed_from_dev.history, resumed_from_host.history)


def test_load_state_rejects_non_state(tmp_path):
    import pickle

    path = os.path.join(tmp_path, "junk.pkl")
    with open(path, "wb") as f:
        pickle.dump({"not": "a state"}, f)
    with pytest.raises(ValueError, match="SimState"):
        load_state(path)


def _saved_state(spec, tmp_path, seed=0, name="ckpt.pkl"):
    sim = spec.build()
    state, _ = sim.run(sim.init(seed), max_rounds=2, eval_every=2)
    path = os.path.join(tmp_path, name)
    save_state(path, state)
    return path


def test_load_state_rejects_version_skew(tmp_path):
    import pickle

    path = _saved_state(_spec("scan", None), tmp_path)
    with open(path, "rb") as f:
        payload = pickle.load(f)
    payload["__repro_simstate__"] = 999
    with open(path, "wb") as f:
        pickle.dump(payload, f)
    with pytest.raises(ValueError, match="schema v999"):
        load_state(path)


def test_load_state_rejects_corrupt_signature(tmp_path):
    import pickle

    path = _saved_state(_spec("scan", None), tmp_path)
    with open(path, "rb") as f:
        payload = pickle.load(f)
    treedef, leaves = payload["signature"]
    payload["signature"] = (treedef, leaves[:-1])  # truncated leaf list
    with open(path, "wb") as f:
        pickle.dump(payload, f)
    with pytest.raises(ValueError, match="corrupt"):
        load_state(path)


def test_load_state_rejects_wrong_spec_via_like(tmp_path):
    path = _saved_state(_spec("scan", None), tmp_path)
    other = _spec("scan", None).replace(
        fed=FedConfig(n_devices=4, batch_size=8, theta=0.62, lr=0.05,
                      compress_updates=True))
    with pytest.raises(ValueError, match="different spec"):
        load_state(path, like=other.build().init(0))
    # the matching spec passes the same check
    state = load_state(path, like=_spec("scan", None).build().init(0))
    assert isinstance(state, SimState)


def test_load_state_rejects_truncated_pickle(tmp_path):
    path = _saved_state(_spec("scan", None), tmp_path)
    raw = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(raw[: len(raw) // 2])
    with pytest.raises(ValueError, match="not a readable checkpoint"):
        load_state(path)


def test_load_state_accepts_legacy_raw_pickle(tmp_path):
    """Pre-envelope checkpoints were a bare pickled SimState; they must
    keep loading (and resuming) unchanged."""
    import pickle

    spec = _spec("scan", "dropout")
    sim = spec.build()
    state, _ = sim.run(sim.init(0), max_rounds=2, eval_every=2)
    host = jax.device_get(state)
    path = os.path.join(tmp_path, "legacy.pkl")
    with open(path, "wb") as f:
        pickle.dump(host, f)
    restored = load_state(path, like=spec.build().init(0))
    assert isinstance(restored, SimState) and restored.round == 2
    _, resumed = spec.build().run(restored, max_rounds=2, eval_every=2)
    _, ref = spec.build().run(state, max_rounds=2, eval_every=2)
    _tail_matches(ref.history, resumed.history)


def test_max_sim_time_stop_leaves_resumable_state():
    """A max_sim_time stop that truncates mid-chunk must leave the
    state's host streams at the truncation round, not the chunk end: the
    resumed run's stream-driven accounting (clocks, participation,
    sim_time) continues exactly where the uninterrupted run's would.
    (Device params remain end-of-chunk — the documented deviation — so
    losses may differ; the realization stream must not.)"""
    spec = _spec("scan", "hetero_storm")
    simA = spec.build()
    _, full = simA.run(simA.init(3), max_rounds=6, eval_every=4)
    budget = full.history[1].sim_time  # stops at round 2, mid 4-chunk
    simB = spec.build()
    state, res = simB.run(simB.init(3), max_rounds=6, eval_every=4,
                          max_sim_time=budget)
    assert len(res.history) == 2 and state.round == 2
    assert state.sim_time == full.history[1].sim_time
    # Resume one round: round 3 must see round 3's realization, not
    # round 5's (the chunk end).
    state2, nxt = simB.run(state, max_rounds=1)
    rec, ref = nxt.history[0], full.history[2]
    assert rec.round == 3
    assert rec.n_participants == ref.n_participants
    assert rec.T_cm == ref.T_cm and rec.T_cp == ref.T_cp
    assert rec.sim_time == ref.sim_time


def test_iterator_snapshot_is_small_and_legacy_restores():
    """BatchIterator snapshots store (rng, epoch rng, ptr) — O(rng
    state), not O(partition) — regenerate the epoch permutation on
    restore bit-identically, and still accept pre-PR5 snapshots that
    carried the permutation inline."""
    from repro.data import BatchIterator, make_mnist_like

    data = make_mnist_like(200, seed=0)
    it = BatchIterator(data, np.arange(120), 16, seed=3)
    for _ in range(9):  # crosses an epoch reshuffle (120 // 16 = 7)
        it.next_indices()
    snap = it.state()
    assert set(snap) == {"rng", "epoch_rng", "ptr"}  # no order array
    ref = [it.next_indices() for _ in range(20)]
    fresh = BatchIterator(data, np.arange(120), 16, seed=999)
    fresh.set_state(snap)
    got = [fresh.next_indices() for _ in range(20)]
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a, b)
    # legacy snapshot shape (pre-PR5 pickles) still restores
    legacy = {"rng": it.rng.bit_generator.state, "order": it._order.copy(),
              "ptr": it._ptr}
    old = BatchIterator(data, np.arange(120), 16, seed=5)
    old.set_state(legacy)
    # ...and a RE-snapshot taken right after a legacy restore (epoch-start
    # RNG position unknowable) must itself be restorable: it stays in the
    # legacy form until the next reshuffle records an epoch_rng.
    resnap = old.state()
    assert "order" in resnap
    again = BatchIterator(data, np.arange(120), 16, seed=6)
    again.set_state(resnap)
    for a, b in zip([it.next_indices() for _ in range(10)],
                    [old.next_indices() for _ in range(10)],
                    ):
        np.testing.assert_array_equal(a, b)
    for _ in range(10):
        again.next_indices()
    assert "epoch_rng" in again.state()  # converted at the reshuffle


def test_fleet_resumes_from_checkpoints(tmp_path):
    """Checkpointed states can come back as a vmapped fleet: restore S
    saved mid-run states and run_fleet them in lockstep, bit-identical to
    resuming each sequentially."""
    spec = _spec("scan", "dropout")
    sim = spec.build()
    paths = []
    for s in (0, 1):
        mid, _ = sim.run(sim.init(s), max_rounds=2, eval_every=2)
        p = os.path.join(tmp_path, f"m{s}.pkl")
        save_state(p, mid)
        paths.append(p)
    states = [load_state(p) for p in paths]
    fleet = sim.run_fleet(states=states, max_rounds=4, eval_every=2)
    for i, p in enumerate(paths):
        _, ref = sim.run(load_state(p), max_rounds=4, eval_every=2)
        _tail_matches(ref.history, fleet.results[i].history)
