"""Backend parity: the compiled stacked-client round (backend='batched',
with donated buffers and optional in-graph int8 compression) must
reproduce the per-client host loop (backend='loop') under a fixed seed —
through the functional core (Simulator + SimState threading)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ComputeConfig, FedConfig, WirelessConfig
from repro.core import delay
from repro.federated.simulation import Simulator
from repro.models import cnn
from repro.optim import sgd


def _quad_loss(params, batch):
    diff = params["w"] - batch["target"]
    return 0.5 * jnp.sum(diff * diff), {}


class _TargetIterator:
    """Deterministic per-client batch source for the quadratic problem."""

    def __init__(self, target, batch_size):
        self.target = np.asarray(target, np.float32)
        self.batch_size = batch_size

    def next_batch(self):
        return {"target": np.tile(self.target, (self.batch_size, 1))}


def _quad_sim(backend, compress, impl="xla", momentum=0.0, seed=0):
    M, d, b = 4, 16, 2
    fed = FedConfig(n_devices=M, batch_size=b, lr=0.05, seed=seed,
                    compress_updates=compress)
    pop = delay.draw_population(M, ComputeConfig(), WirelessConfig(), 0, 0.0)
    iters = [_TargetIterator(np.linspace(0.0, m, d) * 0.1, b)
             for m in range(M)]
    return Simulator(
        _quad_loss, {"w": jnp.zeros(d)}, iters,
        np.array([10, 20, 30, 40]), fed, sgd(fed.lr, momentum), pop,
        backend=backend, impl=impl)


def _run(sim, **kw):
    _, res = sim.run(sim.init(), **kw)
    return res


def _run_pair(make_sim, rounds=5, **kw):
    out = {}
    for backend in ("loop", "batched"):
        res = _run(make_sim(backend, **kw), max_rounds=rounds)
        out[backend] = (res.params, [r.train_loss for r in res.history])
    return out


def _assert_parity(out, atol):
    for a, b in zip(jax.tree.leaves(out["loop"][0]),
                    jax.tree.leaves(out["batched"][0])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=atol)
    np.testing.assert_allclose(out["loop"][1], out["batched"][1], atol=atol)


@pytest.mark.parametrize("compress", [False, True])
def test_backend_parity_quadratic(compress):
    """Elementwise model: loop and batched agree to fp32 tolerance, with
    and without the int8 compression roundtrip (the sequential key
    schedule makes the stochastic-rounding noise bit-identical)."""
    _assert_parity(_run_pair(_quad_sim, compress=compress), atol=1e-5)


def test_backend_parity_quadratic_momentum():
    """Stacked opt state (momentum buffers) follows the same parity."""
    _assert_parity(_run_pair(_quad_sim, compress=True, momentum=0.9),
                   atol=1e-5)


def test_backend_parity_quadratic_pallas_impl():
    """impl='pallas' routes quantize/dequantize through kernels/quantize/
    ops (interpret mode on CPU) and must match the xla reference path."""
    ref = _run(_quad_sim("batched", compress=True, impl="xla"), max_rounds=3)
    pal = _run(_quad_sim("batched", compress=True, impl="pallas"), max_rounds=3)
    for a, b in zip(jax.tree.leaves(ref.params), jax.tree.leaves(pal.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def _cnn_sim(backend, compress, seed=0):
    from repro.data import BatchIterator, make_mnist_like
    from repro.federated.partition import partition_dirichlet, partition_sizes

    M, b = 3, 8
    fed = FedConfig(n_devices=M, batch_size=b, theta=0.62, lr=0.05, seed=seed,
                    compress_updates=compress)
    cfg = cnn.mnist_cnn_small()
    data = make_mnist_like(240, seed=seed)
    parts = partition_dirichlet(data, M, alpha=1.0, seed=seed)
    iters = [BatchIterator(data, p, b, seed=seed + i)
             for i, p in enumerate(parts)]
    pop = delay.draw_population(M, ComputeConfig(), WirelessConfig(), 0, 0.0)
    return Simulator(
        functools.partial(cnn.cnn_loss, cfg), cnn.init_cnn(cfg, jax.random.PRNGKey(seed)),
        iters, partition_sizes(parts), fed, sgd(fed.lr), pop, backend=backend)


def test_backend_parity_cnn():
    _assert_parity(_run_pair(_cnn_sim, rounds=3, compress=False), atol=1e-5)


def test_backend_parity_cnn_compressed():
    """With compression, vmap-vs-loop fp32 reduction differences can flip
    individual stochastic-rounding buckets, so agreement is bounded by a
    few int8 steps of the per-round delta rather than raw fp32 tolerance."""
    _assert_parity(_run_pair(_cnn_sim, rounds=3, compress=True), atol=2e-3)


def test_batched_resumed_run_after_donation():
    """run() twice on one sim: donated buffers from run #1's last round
    must not poison run #2 (state is rebound to the returned arrays)."""
    sim = _quad_sim("batched", compress=True)
    state = sim.init()
    state, r1 = sim.run(state, max_rounds=2)
    state, r2 = sim.run(state, max_rounds=2)
    assert r1.rounds == 2 and r2.rounds == 2
    for leaf in jax.tree.leaves(r2.params):
        assert np.all(np.isfinite(np.asarray(leaf)))
    # training continued: run #2 starts from run #1's state
    assert r2.history[-1].train_loss < r1.history[0].train_loss
    assert all(isinstance(r.train_loss, float) for r in r2.history)


def test_batched_eval_boundary_sync():
    """Metrics stay on device between eval_every boundaries but the
    returned history is fully materialized floats."""
    sim = _cnn_sim("batched", compress=False)
    acc_calls = []
    sim.eval_fn = lambda p: acc_calls.append(1) or {"acc": 0.0}
    res = _run(sim, max_rounds=4, eval_every=2)
    assert len(acc_calls) == 2  # rounds 2 and 4 only
    assert all(isinstance(r.train_loss, float) for r in res.history)


def test_compressed_bits_delay_accounting():
    """T_cm uses compression.compressed_bits (int8 payload + per-1024-chunk
    fp32 scales), not the bits/4 approximation."""
    from repro.federated import compression
    from repro.utils.tree import tree_bytes

    plain = _quad_sim("batched", compress=False)
    comp = _quad_sim("batched", compress=True)
    raw_bits = tree_bytes(plain.params(plain.init())) * 8.0
    assert plain._update_bits() == raw_bits
    assert comp._update_bits() == compression.compressed_bits(
        comp.params(comp.init()))
    assert comp._update_bits() != raw_bits / 4.0
    assert comp._update_bits() < raw_bits / 3.0
