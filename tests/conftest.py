"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches see
the real single CPU device; only launch/dryrun.py forces 512 placeholders
(in a subprocess for tests)."""
import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
