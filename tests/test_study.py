"""The Study API: shape-envelope arm grouping, per-member early stop, and
vmapped fleet eval.

  * A mixed-(b, V) study executes its arms in grouped vmapped dispatches,
    bit-identical per arm (train-loss history, Eq. 8 clocks,
    participation, uplink bits, trained params) to sequential
    `Simulator.run()` calls — the padding/masking envelope
    (mesh_rounds.build_round_chunk(envelope=True) + cnn_loss_masked +
    the pad-stable conv backward) must be a bitwise no-op.
  * target_acc / max_sim_time stop members individually inside a fleet:
    a finished member rides along frozen (device-side done-mask) and its
    history/final state match a solo early-stopped run.
  * Chunk-boundary eval is one vmapped dispatch over the stacked member
    axis, exactly agreeing with the per-member host eval.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.federated import experiment
from repro.federated.experiment import ExperimentSpec
from repro.federated.study import Study


def _tiny_spec(b, V, scenario=None, compress=False, lr=0.05,
               with_eval=False):
    return ExperimentSpec(
        fed=FedConfig(n_devices=3, batch_size=b,
                      theta=float(np.exp(-V / 2.0)), nu=2.0, lr=lr,
                      compress_updates=compress),
        model="mnist_cnn_tiny", dataset="mnist", n_train=120, n_test=40,
        seed=0, scenario=scenario, with_eval=with_eval)


def _assert_member_matches(ref, got, params=True):
    assert len(ref.history) == len(got.history)
    for a, b in zip(ref.history, got.history):
        assert a.round == b.round
        assert np.float32(a.train_loss).tobytes() == \
            np.float32(b.train_loss).tobytes()
        assert a.sim_time == b.sim_time
        assert a.T_cm == b.T_cm and a.T_cp == b.T_cp
        assert a.n_participants == b.n_participants
        assert a.uplink_bits == b.uplink_bits
        assert a.test_acc == b.test_acc
    if params:
        for x, y in zip(jax.tree.leaves(ref.params),
                        jax.tree.leaves(got.params)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# Envelope grouping: bit-identity with sequential runs
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scenario,compress", [
    (None, False), ("dropout", True)])
def test_three_mixed_arms_bit_identical_to_sequential(scenario, compress):
    """The acceptance contract: a 3-arm study with distinct (b, V) —
    grouped into ONE vmapped envelope fleet — reproduces three sequential
    run() calls bit for bit (loss/clock/participation/uplink_bits and the
    trained params), with and without a scenario + int8 compression."""
    study = Study(
        arms=[("A", _tiny_spec(4, 2, scenario, compress)),
              ("B", _tiny_spec(8, 1, scenario, compress)),
              ("C", _tiny_spec(6, 3, scenario, compress))],
        seeds=(0, 1), max_rounds=5, eval_every=2, bit_check=True)
    res = study.run()
    assert res.groups == (("A", "B", "C"),)  # one envelope group
    for label, spec in study.arms:
        for i, seed in enumerate(study.seeds):
            sim = spec.build()
            _, ref = sim.run(sim.init(seed), max_rounds=5, eval_every=2)
            _assert_member_matches(ref, res[label][i])


def test_exact_grouping_splits_and_matches():
    study = Study(
        arms=[("A", _tiny_spec(4, 2)), ("B", _tiny_spec(8, 1))],
        seeds=(0,), max_rounds=3, grouping="exact")
    res = study.run()
    assert res.groups == (("A",), ("B",))
    for label, spec in study.arms:
        sim = spec.build()
        _, ref = sim.run(sim.init(0), max_rounds=3)
        _assert_member_matches(ref, res[label][0])


def test_different_scenarios_group_separately():
    study = Study(
        arms=[("u1", _tiny_spec(4, 2, "uniform")),
              ("u2", _tiny_spec(8, 1, "uniform")),
              ("d1", _tiny_spec(4, 2, "dropout"))],
        seeds=(0,), max_rounds=2)
    res = study.run()
    assert res.groups == (("u1", "u2"), ("d1",))


# ---------------------------------------------------------------------------
# Per-member early stop (done-mask)
# ---------------------------------------------------------------------------


def _smoke_spec(lr=0.2):
    return experiment.get("mnist_smoke").replace(
        n_train=240, n_test=80,
        fed=FedConfig(n_devices=3, batch_size=8, theta=0.62, lr=lr))


def test_fleet_member_freezes_at_target_acc_matching_solo():
    """A fleet member that reaches target_acc mid-study freezes (all-zero
    valid rows; params/opt/PRNG untouched) while the rest continue; its
    history AND final state match a solo early-stopped run."""
    spec = _smoke_spec()
    fleet = spec.build().run_fleet(seeds=[0, 1, 2], max_rounds=8,
                                   eval_every=2, target_acc=0.15)
    rounds = [r.rounds for r in fleet.results]
    assert min(rounds) < 8, f"no member early-stopped: {rounds}"
    assert max(rounds) == 8, f"every member stopped: {rounds}"
    for i, seed in enumerate([0, 1, 2]):
        sim = spec.build()
        st, ref = sim.run(sim.init(seed), max_rounds=8, eval_every=2,
                          target_acc=0.15)
        _assert_member_matches(ref, fleet.results[i])
        assert fleet.states[i].round == ref.rounds
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(st.key)),
            np.asarray(jax.device_get(fleet.states[i].key)))


def test_study_members_freeze_at_max_sim_time_matching_solo():
    """max_sim_time stops each study member at its own Eq. 8 clock: arms
    with larger V cross the budget earlier and ride along frozen."""
    arms = [("fast", _tiny_spec(4, 1)), ("slow", _tiny_spec(4, 3))]
    budget = 0.5
    res = Study(arms=arms, seeds=(0,), max_rounds=6, eval_every=2,
                max_sim_time=budget).run()
    assert res["slow"][0].rounds < res["fast"][0].rounds
    for label, spec in arms:
        sim = spec.build()
        _, ref = sim.run(sim.init(0), max_rounds=6, eval_every=2,
                         max_sim_time=budget)
        _assert_member_matches(ref, res[label][0])


def test_run_fleet_target_acc_requires_eval():
    sim = _tiny_spec(4, 1).build()  # with_eval=False
    with pytest.raises(ValueError, match="eval"):
        sim.run_fleet(seeds=[0], max_rounds=2, target_acc=0.5)
    with pytest.raises(ValueError, match="eval"):
        Study(arms=[("A", _tiny_spec(4, 1))], target_acc=0.5,
              max_rounds=2).run()


# ---------------------------------------------------------------------------
# Vmapped fleet eval
# ---------------------------------------------------------------------------


def test_eval_batch_fn_matches_host_eval():
    """The stacked-member eval is ONE vmapped dispatch whose per-member
    accuracies equal the host eval_fn exactly (hit sums are integral, so
    no reduction order can perturb them)."""
    spec = _tiny_spec(4, 1, with_eval=True)
    sim = spec.build()
    assert sim.eval_batch_fn is not None
    keys = [jax.random.PRNGKey(i) for i in range(3)]
    from repro.models import cnn
    cfg = spec.model_config()
    params = [cnn.init_cnn(cfg, k) for k in keys]
    stacked = jax.tree.map(lambda *xs: np.stack(xs), *params)
    batch = sim.eval_batch_fn(stacked)["acc"]
    for i, p in enumerate(params):
        assert float(batch[i]) == sim.eval_fn(p)["acc"]


# ---------------------------------------------------------------------------
# Study construction, plans, result frame
# ---------------------------------------------------------------------------


def test_study_validation():
    spec = _tiny_spec(4, 1)
    with pytest.raises(ValueError, match="at least one arm"):
        Study(arms=[])
    with pytest.raises(ValueError, match="duplicate"):
        Study(arms=[("A", spec), ("A", spec)])
    with pytest.raises(ValueError, match="at least one seed"):
        Study(arms=[("A", spec)], seeds=())
    with pytest.raises(ValueError, match="grouping"):
        Study(arms=[("A", spec)], grouping="nope")
    with pytest.raises(TypeError, match="ExperimentSpec"):
        Study(arms=[("A", object())])
    with pytest.raises(ValueError, match="scan"):
        Study(arms=[("A", spec.replace(backend="batched"))])
    with pytest.raises(ValueError):
        Study(arms=[("A", spec)], max_rounds=0).run()


def test_study_plans_resolve_plan_or_fixed():
    planned = ExperimentSpec(
        fed=FedConfig(n_devices=10, epsilon=0.01, nu=2.0,
                      c=experiment.CALIBRATED_C, lr=0.05),
        model="mnist_cnn", dataset="mnist", plan=True)
    fixed = _tiny_spec(8, 2)
    plans = Study(arms=[("defl", planned), ("base", fixed)]).plans()
    assert plans["defl"].b == planned.resolve_plan().b
    assert plans["base"].b == 8 and plans["base"].V == 2
    assert plans["base"].overall_pred > 0


def test_study_result_frame_and_json():
    study = Study(arms=[("A", _tiny_spec(4, 2, with_eval=True)),
                        ("B", _tiny_spec(8, 1, with_eval=True))],
                  seeds=(0, 1), max_rounds=4, eval_every=2,
                  target_acc=0.999)  # unreachable: every seed misses
    res = study.run()
    assert res.labels == ("A", "B")
    header, rows = res.table()
    assert header.startswith("label,b,V,")
    assert [r[0] for r in rows] == ["A", "B"]
    tta = res.time_to_target("A")
    assert tta.shape == (2,)
    # Missed seeds are NaN (not silently their total time) and the hit
    # rate reports the miss; the _or_total variant keeps the old finite
    # fallback for the headline comparisons.
    assert np.isnan(tta).all()
    assert res.target_hit_rate("A") == 0.0
    s = res.summary("A")
    assert np.isnan(s["time_to_target_mean"]) and s["target_hit_rate"] == 0.0
    np.testing.assert_allclose(
        res.time_to_target_or_total("A"),
        [r.total_time for r in res["A"]])  # never hit -> total time
    assert np.isfinite(res.reduction("A", "B"))
    js = res.to_json()
    assert set(js["arms"]) == {"A", "B"}
    arm = js["arms"]["A"]
    assert arm["b"] == 4 and len(arm["per_seed"]) == 2
    h = arm["per_seed"][0]["history"]
    assert len(h["round"]) == res["A"][0].rounds
    assert js["groups"] and js["seeds"] == [0, 1]


def test_group_graph_cache_shared_across_studies():
    """Two studies over the same arm shapes share one compiled envelope
    graph (the _GROUP_FNS cache keyed on envelope_key + dims)."""
    from repro.federated import study as study_mod
    arms = [("A", _tiny_spec(4, 2)), ("B", _tiny_spec(8, 1))]
    Study(arms=arms, seeds=(0,), max_rounds=2).run()
    n = len(study_mod._GROUP_FNS)
    Study(arms=arms, seeds=(1,), max_rounds=2).run()
    assert len(study_mod._GROUP_FNS) == n  # cache hit, no new graph


def test_solo_fallback_for_sims_without_masked_loss():
    """A hand-built Simulator without the envelope capabilities (passed
    through run(sims=...)) falls back to sequential per-seed run() calls
    — its own group, not an envelope — and matches them exactly."""
    spec_a, spec_b = _tiny_spec(4, 2), _tiny_spec(8, 1)
    sims = {"A": spec_a.build(), "B": spec_b.build()}
    sims["B"].masked_loss_fn = None  # strip the envelope capability
    res = Study(arms=[("A", spec_a), ("B", spec_b)], seeds=(0, 1),
                max_rounds=3).run(sims=sims)
    assert res.groups == (("A",), ("B",))
    for label, spec in (("A", spec_a), ("B", spec_b)):
        for i, seed in enumerate((0, 1)):
            sim = spec.build()
            _, ref = sim.run(sim.init(seed), max_rounds=3)
            _assert_member_matches(ref, res[label][i])


def test_envelope_key_on_spec_sims():
    sim = _tiny_spec(4, 2).build()
    assert sim.masked_loss_fn is not None
    assert sim.envelope_key is not None
    # lr is part of the graph signature (baked into the opt closure).
    other = dataclasses.replace(
        _tiny_spec(4, 2), fed=dataclasses.replace(
            _tiny_spec(4, 2).fed, lr=0.31)).build()
    assert other.envelope_key != sim.envelope_key
