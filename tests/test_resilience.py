"""Resilient execution (PR: quorum-gated rounds, over-provisioned
cohorts, crash-safe auto-recovering drivers):

  * quorum gate: a below-quorum round under 'reject' leaves params and
    optimizer state byte-identical while the clock still pays the round's
    wall time plus `redispatch_cost`; scan == batched bit-for-bit on
    every quorum path; an inactive quorum lowers a byte-identical HLO
    graph.
  * over-provisioned cohorts: K + spare candidates, keep the K deadline-
    feasible-fastest — when K + spare covers the whole population the
    sampled run reproduces the dense run's losses/clock/params exactly.
  * recovery: DivergenceError carries a resumable payload;
    Simulator.run(recovery=...) rewinds + lr-backoff + optional guard
    tightening, audited in SimResult.restarts; Study.run(checkpoint_dir)
    autosaves each (arm, seed) member atomically and resumes
    bit-identically.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ComputeConfig, FedConfig, WirelessConfig
from repro.core import defl, delay
from repro.federated import scenarios
from repro.federated.experiment import (CohortSpec, ExperimentSpec,
                                        PopulationSpec)
from repro.federated.faults import (DivergenceError, FaultModel,
                                    RecoveryPolicy)
from repro.federated.simulation import (Simulator, load_state, save_state)
from repro.federated.study import Study
from repro.optim import sgd


def _quad_loss(params, batch):
    diff = params["w"] - batch["target"]
    return 0.5 * jnp.sum(diff * diff), {}


class _TargetIterator:
    def __init__(self, target, batch_size):
        self.target = np.asarray(target, np.float32)
        self.batch_size = batch_size

    def next_batch(self):
        return {"target": np.tile(self.target, (self.batch_size, 1))}


def _sim(backend, scenario=None, faults=None, compress=True, momentum=0.9,
         seed=0, lr=0.05, M=4, cohort=None, spare=0, heterogeneity=0.0,
         targets=None):
    d, b = 16, 2
    fed = FedConfig(n_devices=M, batch_size=b, lr=lr, seed=seed,
                    compress_updates=compress)
    scen = scenarios.get(scenario) if scenario is not None else None
    pop = (scen.population(M, seed=seed) if scen is not None else
           delay.draw_population(M, ComputeConfig(), WirelessConfig(), 0,
                                 heterogeneity))
    if targets is None:
        targets = [np.linspace(0.0, m, d) * 0.1 for m in range(M)]
    iters = [_TargetIterator(t, b) for t in targets]
    return Simulator(
        _quad_loss, {"w": jnp.zeros(d)}, iters, 10 * np.arange(1, M + 1),
        fed, sgd(fed.lr, momentum), pop, backend=backend, scenario=scen,
        faults=faults, cohort=cohort, cohort_spare=spare)


def _run(sim, **kw):
    _, res = sim.run(sim.init(), **kw)
    return res


def _assert_bit_identical(res_a, res_b):
    for a, b in zip(jax.tree.leaves(res_a.params),
                    jax.tree.leaves(res_b.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert len(res_a.history) == len(res_b.history)
    for ra, rb in zip(res_a.history, res_b.history):
        assert ra.round == rb.round
        np.testing.assert_array_equal(ra.train_loss, rb.train_loss)
        assert ra.sim_time == rb.sim_time
        assert ra.n_participants == rb.n_participants
        # None (quorum off: no flag recorded) and False both mean applied
        assert bool(ra.rejected) == bool(rb.rejected)


def _leaves_bytes(tree):
    return [np.asarray(x).tobytes() for x in jax.tree.leaves(tree)]


# ---------------------------------------------------------------------------
# Quorum: validation + resolution
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bad", [
    dict(min_quorum=0),
    dict(min_quorum=-1),
    dict(min_quorum=0.0),
    dict(min_quorum=1.5),
    dict(quorum_policy="maybe"),
    dict(redispatch_cost=-1.0),
])
def test_quorum_validate_rejects(bad):
    with pytest.raises(ValueError):
        FaultModel(**bad).validate()


def test_quorum_activates_and_resolves():
    assert FaultModel(min_quorum=2).active is True
    assert FaultModel().resolve_quorum(4) is None
    assert FaultModel(min_quorum=3).resolve_quorum(4) == 3
    assert FaultModel(min_quorum=0.5).resolve_quorum(4) == 2   # ceil
    assert FaultModel(min_quorum=0.1).resolve_quorum(4) == 1   # floor at 1
    assert FaultModel(min_quorum=1.0).resolve_quorum(4) == 4
    with pytest.raises(ValueError):
        FaultModel(min_quorum=5).resolve_quorum(4)


# ---------------------------------------------------------------------------
# Quorum: the reject no-op property
# ---------------------------------------------------------------------------


def test_quorum_reject_noops_params_but_clock_advances():
    """Under 'reject', a below-quorum round leaves params AND optimizer
    state byte-identical while sim_time still advances and the RNG stream
    keeps moving (rejection must not stall the compression-noise
    schedule). Under 'accept' the same rounds are flagged but applied."""
    fm = FaultModel(min_quorum=3)
    # dropout @ M=4, min_quorum=3: round 1 passes, rounds 2 and 3 fail
    # quorum (participation dips to 2).
    sim = _sim("scan", "dropout", faults=fm)
    st1, r1 = sim.run(sim.init(), max_rounds=1)
    st3, r3 = sim.run(sim.init(), max_rounds=3)
    assert [r.rejected for r in r3.history] == [False, True, True]
    assert _leaves_bytes(st3.params_C) == _leaves_bytes(st1.params_C)
    assert _leaves_bytes(st3.opt_C) == _leaves_bytes(st1.opt_C)
    assert st3.sim_time > st1.sim_time
    assert _leaves_bytes(st3.key) != _leaves_bytes(st1.key)

    acc = _sim("scan", "dropout",
               faults=FaultModel(min_quorum=3, quorum_policy="accept"))
    sta, ra = acc.run(acc.init(), max_rounds=3)
    assert [r.rejected for r in ra.history] == [False, True, True]
    assert _leaves_bytes(sta.params_C) != _leaves_bytes(st1.params_C)


def test_quorum_redispatch_cost_paid_exactly_on_rejected_rounds():
    """redispatch_cost is billed on rejected rounds and ONLY there: the
    per-round durations of a redispatch_cost=1.5 run exceed the cost=0
    run's by exactly 1.5 on each rejected round and 0 elsewhere."""
    free = _run(_sim("scan", "dropout", faults=FaultModel(min_quorum=3)),
                max_rounds=10)
    paid = _run(_sim("scan", "dropout",
                     faults=FaultModel(min_quorum=3, redispatch_cost=1.5)),
                max_rounds=10)
    flags = [r.rejected for r in free.history]
    assert flags == [False, True, True, False, True,
                     False, False, False, True, False]
    assert [r.rejected for r in paid.history] == flags
    assert free.rounds_rejected == 4 and paid.rounds_rejected == 4
    d_free = np.diff([0.0] + [r.sim_time for r in free.history])
    d_paid = np.diff([0.0] + [r.sim_time for r in paid.history])
    assert (d_free > 0).all() and (d_paid > 0).all()
    np.testing.assert_allclose(
        d_paid - d_free, np.where(flags, 1.5, 0.0), atol=1e-9)


# ---------------------------------------------------------------------------
# Quorum: scan == batched on every path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy,cohort,compress,quorum", [
    ("reject", None, True, 3),
    ("accept", None, False, 3),
    ("reject", 3, False, 0.99),   # sampled K<M + fraction form (ceil->3)
    ("accept", 3, True, 3),
])
def test_quorum_parity_scan_vs_batched(policy, cohort, compress, quorum):
    fm = FaultModel(min_quorum=quorum, quorum_policy=policy,
                    redispatch_cost=0.25)
    mom = 0.0 if cohort else 0.9
    kw = dict(scenario="dropout", faults=fm, compress=compress,
              momentum=mom, cohort=cohort)
    res_s = _run(_sim("scan", **kw), max_rounds=8)
    res_b = _run(_sim("batched", **kw), max_rounds=8)
    _assert_bit_identical(res_s, res_b)
    assert res_s.rounds_rejected > 0  # the path under test actually fired


def test_quorum_never_triggered_is_bit_identical():
    """A quorum that never fires (min_quorum=1 on a scenario with full
    attendance) must not change a single bit of the run."""
    base = FaultModel(deadline_factor=5.0)
    gated = FaultModel(deadline_factor=5.0, min_quorum=1)
    res_a = _run(_sim("scan", "stragglers", faults=base), max_rounds=6)
    res_b = _run(_sim("scan", "stragglers", faults=gated), max_rounds=6)
    _assert_bit_identical(res_a, res_b)
    assert all(r.rejected is None for r in res_a.history)  # quorum off
    assert all(r.rejected is False for r in res_b.history)
    assert res_b.rounds_rejected == 0


def _chunk_hlo(faults):
    """Lowered HLO text of the compiled scan-chunk graph — lowering is
    deterministic, so equal configs produce equal text."""
    sim = _sim("scan", "dropout", faults=faults)
    st = sim.init()
    iters, stream = sim._materialize(st)
    xs, _ = sim._chunk_inputs(iters, stream, 2, 2)
    weights, t_cp = sim._chunk_args()
    args = [st.params_C, st.opt_C, st.key, weights, t_cp, sim._data_dev, xs]
    if sim._envelope:
        args.append(sim._trivial_env())
    return sim._chunk_fn.lower(*args).as_text()


def test_quorum_inactive_graph_byte_identical():
    """The compile-time contract: min_quorum=None compiles ZERO quorum
    ops (HLO byte-identical to faults=None through an inactive
    FaultModel), and setting it changes the graph — the identity probe is
    not vacuous."""
    plain = _chunk_hlo(None)
    assert _chunk_hlo(FaultModel()) == plain
    base = _chunk_hlo(FaultModel(deadline_factor=2.0))
    assert _chunk_hlo(FaultModel(deadline_factor=2.0, min_quorum=2)) != base


# ---------------------------------------------------------------------------
# Over-provisioned cohorts
# ---------------------------------------------------------------------------


def test_spare_validation():
    with pytest.raises(ValueError):
        CohortSpec(K=2, spare=-1).validate()
    with pytest.raises(ValueError):
        _sim("scan", cohort=None, spare=1)       # spare needs a cohort
    with pytest.raises(ValueError):
        _sim("scan", M=4, cohort=3, spare=2)     # K + spare > M
    with pytest.raises(ValueError):
        PopulationSpec(M=4, cohort=CohortSpec(K=3, spare=2)).validate()


def test_deadline_plan_spare_requires_cohort_and_helps():
    """spare needs cohort_size, and over-provisioning can only raise the
    Eq. 12 effective M (more feasible candidates per round), capped at
    K."""
    fed = FedConfig(n_devices=10, epsilon=0.01, nu=2.0, lr=0.05)
    pop = delay.draw_population(10, ComputeConfig(), WirelessConfig(), 0, 0.5)
    bits = 1e5
    t_cm = delay.per_client_uplink_time(bits, WirelessConfig(), pop.p, pop.h)
    dl = float(np.median(pop.G / pop.f) * 8 * 4 + np.median(t_cm))
    with pytest.raises(ValueError):
        defl.deadline_plan(fed, pop, bits, dl, spare=2)
    with pytest.raises(ValueError):
        defl.deadline_plan(fed, pop, bits, dl, cohort_size=4, spare=-1)
    plain = defl.deadline_plan(fed, pop, bits, dl, cohort_size=4)
    spared = defl.deadline_plan(fed, pop, bits, dl, cohort_size=4, spare=4)
    assert spared.problem.M >= plain.problem.M  # Eq. 12 effective M
    assert spared.problem.M <= 4                # saturates at K
    # spare=0 reduces exactly to the plain cohort plan
    zero = defl.deadline_plan(fed, pop, bits, dl, cohort_size=4, spare=0)
    assert (zero.b, zero.V, zero.problem.M) == \
        (plain.b, plain.V, plain.problem.M)


def test_spare_covering_population_matches_dense():
    """When K + spare == M the candidate set is the whole population, so
    keeping the K deadline-feasible-fastest reproduces the dense run
    exactly: with a deadline that admits only 2 clients, losses, clocks,
    participation and trained params are byte-identical to the dense
    sim."""
    M = 5
    mk = lambda **kw: _sim("scan", M=M, momentum=0.0, compress=False,  # noqa: E731
                           heterogeneity=0.5, **kw)
    probe = mk(faults=FaultModel(deadline=1e9))
    bits = probe._update_bits()
    t_cm = delay.per_client_uplink_time(bits, probe.wireless,
                                        probe.pop.p, probe.pop.h)
    finish = np.sort(delay.finish_times(probe._t_cp_clients, t_cm,
                                        probe.fed.local_rounds))
    fm = FaultModel(deadline=float((finish[1] + finish[2]) / 2))
    dense = mk(faults=fm)
    _, rd = dense.run(dense.init(), max_rounds=6)
    assert [r.n_participants for r in rd.history] == [2] * 6
    samp = mk(faults=fm, cohort=3, spare=2)
    _, rs = samp.run(samp.init(), max_rounds=6)
    for a, b in zip(rd.history, rs.history):
        assert np.float32(a.train_loss).tobytes() == \
            np.float32(b.train_loss).tobytes()
        assert a.sim_time == b.sim_time
        assert a.n_participants == b.n_participants
    assert _leaves_bytes(rd.params) == _leaves_bytes(rs.params)
    # dispatch-billed uplink accounting: M clients dense, K sampled
    assert rd.history[0].uplink_bits == M * bits
    assert rs.history[0].uplink_bits == 3 * bits


def test_spare_parity_and_midrun_resume():
    """spare > 0 keeps the twin-backend contract (scan == batched) and
    survives a mid-run save_state/load_state round trip bit-identically."""
    fm = FaultModel(deadline_factor=1.2)
    kw = dict(scenario="stragglers", faults=fm, momentum=0.0, M=6, cohort=3,
              spare=2)
    res_s = _run(_sim("scan", **kw), max_rounds=8)
    res_b = _run(_sim("batched", **kw), max_rounds=8)
    _assert_bit_identical(res_s, res_b)

    sim = _sim("scan", **kw)
    st4, _ = sim.run(sim.init(), max_rounds=4)
    path = os.path.join(os.environ.get("TMPDIR", "/tmp"),
                        "resilience_spare_state.pkl")
    save_state(path, st4)
    try:
        sim2 = _sim("scan", **kw)
        st_resumed, res_tail = sim2.run(load_state(path), max_rounds=4)
    finally:
        os.remove(path)
    full = res_s.history
    assert [r.round for r in res_tail.history] == [r.round for r in full[4:]]
    for a, b in zip(full[4:], res_tail.history):
        np.testing.assert_array_equal(a.train_loss, b.train_loss)
        assert a.sim_time == b.sim_time
        assert a.n_participants == b.n_participants
    assert _leaves_bytes(res_s.params) == _leaves_bytes(res_tail.params)


# ---------------------------------------------------------------------------
# Divergence recovery
# ---------------------------------------------------------------------------


def _div_sim(lr=1000.0):
    """A run that genuinely diverges under an ACTIVE guard: the huge lr
    blows the quadratic up, reject_nonfinite=False lets the non-finite
    aggregate through (a norm guard alone keeps the divergence check
    armed), and the loss goes inf at round 3."""
    return _sim("scan", faults=FaultModel(max_update_norm=1e9,
                                          reject_nonfinite=False),
                momentum=0.0, compress=False, lr=lr)


def test_divergence_error_payload_is_resumable():
    sim = _div_sim()
    with pytest.raises(DivergenceError) as ei:
        sim.run(sim.init(), max_rounds=12, eval_every=3)
    e = ei.value
    assert e.round == 3
    assert e.state is not None and e.state.round == 0  # chunk-boundary
    assert e.guard == (1e9, False)
    assert e.faults is not None and e.faults.max_update_norm == 1e9
    assert e.history[-1].round == 3
    assert e.finite_mask is not None
    assert e.finite_mask.dtype == np.bool_ and e.finite_mask.shape == (4,)
    assert not e.finite_mask.any()  # global blow-up, not one bad client


def test_recovery_restarts_and_audits():
    """run(recovery=...) rewinds to the carried state, backs the lr off,
    and completes: one audited restart, contiguous round numbering, a
    monotone clock, and a finite final loss."""
    sim = _div_sim()
    st, res = sim.run(sim.init(), max_rounds=12, eval_every=3,
                      recovery=RecoveryPolicy(max_restarts=8,
                                              lr_backoff=1e-4))
    assert len(res.restarts) == 1
    audit = res.restarts[0]
    assert set(audit) == {"attempt", "round", "resume_round", "lr_scale",
                          "max_update_norm", "error"}
    assert (audit["attempt"], audit["round"], audit["resume_round"]) == \
        (1, 3, 0)
    assert audit["lr_scale"] == pytest.approx(1e-4)
    assert audit["max_update_norm"] == pytest.approx(1e9)
    assert [r.round for r in res.history] == list(range(1, 13))
    times = [r.sim_time for r in res.history]
    assert all(b > a for a, b in zip(times, times[1:]))
    assert np.isfinite(res.history[-1].train_loss)
    assert res.history[-1].train_loss < 1.0


def test_recovery_budget_exhausted_reraises():
    sim = _div_sim()
    with pytest.raises(DivergenceError) as ei:
        sim.run(sim.init(), max_rounds=12, eval_every=3,
                recovery=RecoveryPolicy(max_restarts=1, lr_backoff=0.9))
    assert ei.value.round == 3


def test_recovery_tightens_guard():
    sim = _div_sim()
    _, res = sim.run(sim.init(), max_rounds=12, eval_every=3,
                     recovery=RecoveryPolicy(max_restarts=8, lr_backoff=1e-4,
                                             tighten_guard=0.5))
    assert [(a["attempt"], a["lr_scale"], a["max_update_norm"])
            for a in res.restarts] == [(1, pytest.approx(1e-4),
                                        pytest.approx(5e8))]
    assert np.isfinite(res.history[-1].train_loss)


@pytest.mark.parametrize("bad", [
    dict(max_restarts=0),
    dict(lr_backoff=0.0),
    dict(lr_backoff=1.5),
    dict(tighten_guard=0.0),
])
def test_recovery_policy_validate_rejects(bad):
    with pytest.raises(ValueError):
        RecoveryPolicy(**bad).validate()


# ---------------------------------------------------------------------------
# Crash-safe checkpointing
# ---------------------------------------------------------------------------


def test_save_state_is_atomic_no_stray_files(tmp_path):
    sim = _sim("scan")
    st, _ = sim.run(sim.init(), max_rounds=2)
    path = tmp_path / "state.pkl"
    save_state(str(path), st)
    assert sorted(p.name for p in tmp_path.iterdir()) == ["state.pkl"]
    st2 = load_state(str(path))
    assert _leaves_bytes(st2.params_C) == _leaves_bytes(st.params_C)


def _tiny_spec(b, V, scenario=None):
    return ExperimentSpec(
        fed=FedConfig(n_devices=3, batch_size=b,
                      theta=float(np.exp(-V / 2.0)), nu=2.0, lr=0.05,
                      compress_updates=False),
        model="mnist_cnn_tiny", dataset="mnist", n_train=120, n_test=40,
        seed=0, scenario=scenario, with_eval=False)


def _tiny_study(labels=("A", "B")):
    return Study(arms=[(labels[0], _tiny_spec(4, 2)),
                       (labels[1], _tiny_spec(8, 1))],
                 seeds=(0, 1), max_rounds=2, eval_every=2)


def test_study_checkpoint_resume_is_bit_identical(tmp_path):
    """Study.run(checkpoint_dir=...) autosaves one file per (arm, seed);
    deleting a member and re-running resumes ONLY that member and the
    assembled StudyResult is byte-identical to an uncheckpointed run —
    and a fully-restored directory reproduces it without any compute."""
    import json
    ckpt = str(tmp_path / "ckpt")
    ref = _tiny_study().run()
    ref_json = json.dumps(ref.to_json(), sort_keys=True, default=float)
    res = _tiny_study().run(checkpoint_dir=ckpt)
    assert sorted(os.listdir(ckpt)) == [
        "arm000_seed0.pkl", "arm000_seed1.pkl",
        "arm001_seed0.pkl", "arm001_seed1.pkl"]
    assert json.dumps(res.to_json(), sort_keys=True, default=float) == \
        ref_json
    os.remove(os.path.join(ckpt, "arm001_seed1.pkl"))
    resumed = _tiny_study().run(checkpoint_dir=ckpt)
    assert json.dumps(resumed.to_json(), sort_keys=True, default=float) == \
        ref_json
    # fully restored: no member re-runs, same payload
    restored = _tiny_study().run(checkpoint_dir=ckpt)
    assert json.dumps(restored.to_json(), sort_keys=True, default=float) == \
        ref_json
    # a checkpoint from a different study shape is refused, not absorbed
    with pytest.raises(ValueError):
        _tiny_study(labels=("X", "B")).run(checkpoint_dir=ckpt)


def test_study_summary_exposes_resilience_columns():
    res = _tiny_study().run()
    for label in res.labels:
        s = res.summary(label)
        assert s["rounds_rejected"] == 0 and s["restarts"] == 0
    header, rows = res.table()
    assert header.endswith("rounds_rejected,restarts")
    assert all(len(row) == len(header.split(",")) for row in rows)
    assert all(row[-2:] == (0, 0) for row in rows)
