"""MoE layer tests: routing correctness, capacity semantics, aux loss."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.models.mlp import mlp_forward
from repro.models.moe import init_moe, moe_capacity, moe_forward


def _cfg(**kw):
    base = dict(n_experts=4, top_k=2, d_ff_expert=16, capacity_factor=8.0)
    base.update(kw)
    return MoEConfig(**base)


def test_ample_capacity_matches_dense_computation(key):
    """With no drops, MoE output == explicit per-token expert mixture."""
    cfg = _cfg()
    d = 8
    p = init_moe(key, d, cfg)
    x = jax.random.normal(key, (2, 6, d))
    out, metrics = moe_forward(p, x, cfg)
    assert float(metrics["drop_frac"]) == 0.0

    xt = x.reshape(-1, d)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    vals, idx = jax.lax.top_k(probs, cfg.top_k)
    vals = vals / vals.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xt)
    for e in range(cfg.n_experts):
        g = jax.nn.silu(xt @ p["wg"][e]) * (xt @ p["wi"][e])
        oe = g @ p["wo"][e]
        w = jnp.where(idx == e, vals, 0.0).sum(-1)
        ref = ref + w[:, None] * oe
    np.testing.assert_allclose(
        np.asarray(out.reshape(-1, d)), np.asarray(ref), atol=1e-4)


def test_shared_expert_added(key):
    cfg = _cfg(shared_expert_d_ff=16)
    d = 8
    p = init_moe(key, d, cfg)
    x = jax.random.normal(key, (1, 4, d))
    out, _ = moe_forward(p, x, cfg)
    p_no = dict(p)
    del p_no["shared"]
    out_no, _ = moe_forward(p_no, x, cfg)
    shared = mlp_forward(p["shared"], x.reshape(-1, d), "silu")
    np.testing.assert_allclose(
        np.asarray(out - out_no).reshape(-1, d), np.asarray(shared), atol=1e-4)


def test_capacity_drops_tokens(key):
    cfg = _cfg(capacity_factor=0.25)
    d = 8
    p = init_moe(key, d, cfg)
    x = jax.random.normal(key, (4, 16, d))
    out, metrics = moe_forward(p, x, cfg)
    assert float(metrics["drop_frac"]) > 0.0
    assert bool(jnp.all(jnp.isfinite(out)))


def test_capacity_formula():
    cfg = _cfg()
    c = moe_capacity(1024, cfg, 1.25)
    assert c >= 1024 * cfg.top_k * 1.25 / cfg.n_experts - 8
    assert c % 8 == 0


def test_aux_loss_prefers_balance(key):
    cfg = _cfg(n_experts=2, top_k=1)
    d = 4
    p = init_moe(key, d, cfg)
    x = jax.random.normal(key, (8, 8, d))
    # Force a collapsed router: all tokens to expert 0.
    p_collapsed = dict(p)
    p_collapsed["router"] = jnp.zeros_like(p["router"]).at[:, 0].set(10.0)
    _, m_bal = moe_forward(p, x, cfg)
    _, m_col = moe_forward(p_collapsed, x, cfg)
    assert float(m_col["aux_loss"]) > float(m_bal["aux_loss"])


def test_batched_dispatch_matches_global(key):
    """dispatch='batched' (per-row capacity buffers) == global dispatch
    when capacity is ample."""
    cfg_g = _cfg()
    cfg_b = dataclasses.replace(cfg_g, dispatch="batched")
    d = 8
    p = init_moe(key, d, cfg_g)
    x = jax.random.normal(key, (3, 10, d))
    og, mg = moe_forward(p, x, cfg_g)
    ob, mb = moe_forward(p, x, cfg_b)
    np.testing.assert_allclose(np.asarray(og), np.asarray(ob), atol=1e-5)
    assert float(mb["drop_frac"]) == 0.0
