"""Chaos smoke (CI chaos-smoke job): SIGKILL a checkpointing Study
mid-run and prove the resumed run is bit-identical to an uninterrupted
one.

The study has two arms with DIFFERENT scenarios, so they land in two
envelope groups that execute sequentially — the parent watches the
checkpoint directory, kills the child the moment the first group's
members hit disk, and resumes in-process. `Study.run(checkpoint_dir=...)`
members are saved atomically (tmp + fsync + rename), so whatever the
kill left behind is either absent or complete — never torn."""
import json
import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.federated.experiment import ExperimentSpec
from repro.federated.study import Study

pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _spec(scenario):
    return ExperimentSpec(
        fed=FedConfig(n_devices=3, batch_size=4,
                      theta=float(np.exp(-2 / 2.0)), nu=2.0, lr=0.05,
                      compress_updates=False),
        model="mnist_cnn_tiny", dataset="mnist", n_train=120, n_test=40,
        seed=0, scenario=scenario, with_eval=False)


def _study():
    # different scenarios -> different group signatures -> two groups
    # that run sequentially, giving the kill a real window between them
    return Study(arms=[("plain", _spec(None)), ("dropout", _spec("dropout"))],
                 seeds=(0, 1), max_rounds=2, eval_every=2)


def _payload(res):
    return json.dumps(res.to_json(), sort_keys=True, default=float)


def test_sigkill_mid_study_then_resume_bit_identical(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    ref = _payload(_study().run())

    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    child = subprocess.Popen([sys.executable, os.path.abspath(__file__),
                              ckpt], env=env, cwd=REPO,
                             stdout=subprocess.DEVNULL,
                             stderr=subprocess.DEVNULL)
    first = os.path.join(ckpt, "arm000_seed0.pkl")
    deadline = time.time() + 600
    try:
        while not os.path.exists(first):
            assert child.poll() is None, \
                "child exited before writing its first member checkpoint"
            assert time.time() < deadline, "child never wrote a checkpoint"
            time.sleep(0.05)
    finally:
        if child.poll() is None:
            child.send_signal(signal.SIGKILL)
    assert child.wait(timeout=60) == -signal.SIGKILL

    saved = sorted(os.listdir(ckpt))
    assert "arm000_seed0.pkl" in saved
    assert len(saved) < 4, "child finished everything before the kill — " \
        "the resume below would be vacuous"

    resumed = _study().run(checkpoint_dir=ckpt)
    assert _payload(resumed) == ref
    assert sorted(os.listdir(ckpt)) == [
        "arm000_seed0.pkl", "arm000_seed1.pkl",
        "arm001_seed0.pkl", "arm001_seed1.pkl"]


if __name__ == "__main__":  # the chaos child: run until killed
    _study().run(checkpoint_dir=sys.argv[1])
