"""Flash-attention kernel: shape/dtype sweep vs the jnp oracle (interpret
mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import attention_ref


def _mk(key, B, S, H, KV, hd, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    return q, k, v


def _expand_ref(q, k, v, causal=True, window=None):
    B, S, H, hd = q.shape
    KV = k.shape[2]
    rep = H // KV
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kt = jnp.repeat(k.transpose(0, 2, 1, 3), rep, axis=1).reshape(B * H, S, hd)
    vt = jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1).reshape(B * H, S, hd)
    out = attention_ref(qt, kt, vt, causal=causal, window=window)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("S", [64, 128, 200, 384])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_sweep_shapes_dtypes(key, S, dtype):
    q, k, v = _mk(key, 2, S, 4, 2, 64, dtype)
    out = fa_ops.flash_attention(q, k, v, causal=True, block_q=128, block_k=128)
    ref = _expand_ref(q, k, v, causal=True)
    atol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=atol)


@pytest.mark.parametrize("window", [32, 128])
def test_flash_sliding_window(key, window):
    q, k, v = _mk(key, 1, 256, 2, 2, 32, jnp.float32)
    out = fa_ops.flash_attention(q, k, v, causal=True, window=window)
    ref = _expand_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


@pytest.mark.parametrize("hd", [32, 128])
def test_flash_head_dims(key, hd):
    q, k, v = _mk(key, 1, 128, 2, 1, hd, jnp.float32)
    out = fa_ops.flash_attention(q, k, v)
    ref = _expand_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


def test_flash_kernel_direct_blocks(key):
    """Exercise the raw kernel with a non-default block shape."""
    BH, S, hd = 3, 256, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (BH, S, hd))
    k = jax.random.normal(ks[1], (BH, S, hd))
    v = jax.random.normal(ks[2], (BH, S, hd))
    out = flash_attention_kernel(q, k, v, causal=True, block_q=64, block_k=128)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


def test_flash_backward_matches_ref_grad(key):
    q, k, v = _mk(key, 1, 128, 2, 2, 32, jnp.float32)

    def f_ker(q, k, v):
        return jnp.sum(fa_ops.flash_attention(q, k, v) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(_expand_ref(q, k, v) ** 2)

    g_ker = jax.grad(f_ker, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_ker, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
