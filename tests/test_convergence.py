"""Convergence-model tests (Theorem 1 / Corollaries 1-2 / Remark 3)."""
import numpy as np

from repro.core import convergence as cv


def test_theorem1_bound_decreases_with_K():
    vals = [cv.theorem1_bound(1.0, 1.0, 1.0, M=10, K=k, V=4, b=8)
            for k in [10, 100, 1000, 10000]]
    assert all(v2 < v1 for v1, v2 in zip(vals, vals[1:]))


def test_corollary1_batch_reduces_variance_terms():
    # Remark 2: larger b shrinks the sigma terms.
    b1 = cv.theorem1_bound(1.0, 1.0, 1.0, M=10, K=100, V=4, b=1)
    b8 = cv.theorem1_bound(1.0, 1.0, 1.0, M=10, K=100, V=4, b=8)
    assert b8 < b1
    # The w0 term is b-independent: bound difference == sigma-term difference.
    t1_only = 8.0 / np.sqrt(10 * 100)
    assert b8 > t1_only


def test_local_rounds_remark3():
    assert cv.local_rounds(1.0, 2.0) == 1  # log(1) = 0 -> floor 1
    assert cv.local_rounds(np.exp(-2), 2.0) == 4
    assert cv.local_rounds(0.15, 2.0) == 4
    assert cv.local_rounds(1e-30, 2.0) >= 1


def test_rounds_eq12_monotonicity():
    base = dict(M=10, eps=0.01, nu=2.0, c=1.0)
    h = cv.communication_rounds(16, 0.15, **base)
    # More local work (lower theta) -> fewer rounds.
    assert cv.communication_rounds(16, 0.05, **base) < h
    # Bigger batch -> fewer rounds.
    assert cv.communication_rounds(64, 0.15, **base) < h
    # Tighter eps -> more rounds.
    assert cv.communication_rounds(16, 0.15, 10, 0.001, 2.0, 1.0) > h


def test_gradient_steps_inversion():
    K = cv.gradient_steps_for_eps(0.05, 1.0, 1.0, 1.0, M=4, V=2, b=8)
    assert cv.theorem1_bound(1.0, 1.0, 1.0, 4, K, 2, 8) <= 0.05
    if K > 1:
        assert cv.theorem1_bound(1.0, 1.0, 1.0, 4, K - 1, 2, 8) > 0.05
