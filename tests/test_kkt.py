"""KKT solution tests (Eq. 29) + the documented reproduction finding."""
import numpy as np
import pytest

from repro.core import kkt


PROB = kkt.DelayProblem(T_cm=0.167, g=1e-2, M=10, eps=0.01, nu=2.0, c=0.4)


def test_closed_form_positive_finite():
    s = kkt.closed_form(PROB)
    assert s.b >= 1 and np.isfinite(s.b)
    assert 0 < s.theta < 1
    assert s.V >= 1
    assert s.H > 0 and np.isfinite(s.overall)


def test_paper_alpha_is_b_times_stationary():
    """REPRODUCTION FINDING: Eq. 29's alpha* == b * argmin_alpha J(b, alpha)
    for every b — the paper's formula drops a 1/b factor (see kkt.py)."""
    paper_alpha = kkt.closed_form(PROB).alpha
    for b in [2.0, 8.0, 32.0, 128.0]:
        assert b * kkt.stationary_alpha(PROB, b) == pytest.approx(
            paper_alpha, rel=1e-9)


def test_stationary_alpha_is_argmin():
    for b in [4.0, 32.0]:
        a_star = kkt.stationary_alpha(PROB, b)
        j_star = kkt.objective(PROB, b, a_star)
        for mult in [0.5, 0.9, 1.1, 2.0]:
            assert kkt.objective(PROB, b, a_star * mult) >= j_star - 1e-12


def test_objective_decreasing_in_b():
    a = 1.0
    js = [kkt.objective(PROB, b, a) for b in [1, 2, 4, 8, 16, 64, 256]]
    assert all(j2 <= j1 + 1e-12 for j1, j2 in zip(js, js[1:]))


def test_numerical_beats_or_matches_closed_form_on_bounded_problem():
    num = kkt.solve(PROB, "numerical", b_max=64)
    closed = kkt.closed_form(PROB)
    closed_bounded = kkt.evaluate(
        PROB, min(closed.b, 64.0), closed.alpha, "cf-bounded")
    assert num.overall <= closed_bounded.overall * (1 + 1e-6)


def test_quantize_batch_powers_of_two():
    for b, expect in [(1.0, 1), (1.6, 2), (3.0, 4), (32.0, 32), (84.87, 64),
                      (0.3, 1)]:
        q = kkt.quantize_batch(b)
        assert q == expect
        assert q & (q - 1) == 0  # power of two


def test_corrected_solution_respects_v_floor():
    s = kkt.corrected_solution(PROB, b_max=64)
    assert s.V >= 1
    assert s.alpha >= 1.0 / PROB.nu - 1e-12
