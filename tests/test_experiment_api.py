"""The declarative experiment API and the vmapped fleet:

  * ExperimentSpec.build() wires model/data/population/plan into a
    functional-core Simulator; the spec registry resolves by name.
  * run_fleet over 8 seeds is bit-identical per-seed to 8 sequential
    run() calls at those seeds (loss history, Eq. 8 clocks, participation
    counts, final params) on multiple registry scenarios — vmap batches
    the pure chunk graph, it must not change its math.
  * The legacy FLSimulation shim delegates to the same core (bit-parity)
    and emits its DeprecationWarning exactly once per process.
  * run()/run_fleet() validate their arguments up front on every backend
    and run_round(real=...) without a scenario raises.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ComputeConfig, FedConfig, WirelessConfig
from repro.core import delay
from repro.federated import experiment, scenarios, simulation
from repro.federated.experiment import ExperimentSpec
from repro.federated.simulation import FLSimulation, Simulator
from repro.optim import sgd


def _quad_loss(params, batch):
    diff = params["w"] - batch["target"]
    return 0.5 * jnp.sum(diff * diff), {}


class _TargetIterator:
    def __init__(self, target, batch_size):
        self.target = np.asarray(target, np.float32)
        self.batch_size = batch_size

    def next_batch(self):
        return {"target": np.tile(self.target, (self.batch_size, 1))}


def _quad_sim(backend="scan", scenario=None, compress=True, seed=0):
    M, d, b = 4, 16, 2
    fed = FedConfig(n_devices=M, batch_size=b, lr=0.05, seed=seed,
                    compress_updates=compress)
    scen = scenarios.get(scenario) if scenario is not None else None
    pop = (scen.population(M, seed=seed) if scen is not None else
           delay.draw_population(M, ComputeConfig(), WirelessConfig(), 0, 0.0))

    def factory(s):
        return [_TargetIterator(np.linspace(0.0, m, d) * 0.1, b)
                for m in range(M)]

    return Simulator(
        _quad_loss, {"w": jnp.zeros(d)}, factory,
        np.array([10, 20, 30, 40]), fed, sgd(fed.lr, 0.9), pop,
        backend=backend, scenario=scen)


# ---------------------------------------------------------------------------
# ExperimentSpec
# ---------------------------------------------------------------------------


def test_spec_build_and_run_smoke():
    spec = experiment.get("mnist_smoke").replace(with_eval=False)
    sim = spec.build()
    assert sim._data_dev is not None  # BatchIterator clients -> device path
    state, res = sim.run(sim.init(), max_rounds=3, eval_every=3)
    assert res.rounds == 3 and sim.trace_count == 1
    assert np.isfinite(res.history[-1].train_loss)
    assert state.round == 3


def test_spec_registry():
    names = experiment.names()
    for required in ("mnist_paper", "cifar_paper", "mnist_smoke",
                     "mnist_storm"):
        assert required in names
    spec = experiment.get("mnist_smoke")
    assert experiment.get(spec) is spec  # idempotent on instances
    with pytest.raises(KeyError):
        experiment.get("no_such_experiment")
    with pytest.raises(ValueError):
        experiment.register("mnist_smoke", spec)


def test_spec_plan_or_fed():
    """plan=True re-solves (b*, theta*) against the population; the
    resolved fed carries the planned values (batch capped) while
    plan=False runs fed as-is."""
    base = ExperimentSpec(
        fed=FedConfig(n_devices=10, epsilon=0.01, nu=2.0,
                      c=experiment.CALIBRATED_C, lr=0.05))
    assert base.resolve_plan() is None
    assert base.resolve_fed() == base.fed
    planned = base.replace(plan=True)
    plan = planned.resolve_plan()
    fed = planned.resolve_fed()
    assert fed.batch_size == min(plan.b, planned.batch_cap)
    assert fed.theta == plan.theta
    # A straggler population shifts the plan (scenario-aware solve).
    storm = planned.replace(scenario="stragglers")
    assert storm.resolve_plan().overall_pred > plan.overall_pred


def test_spec_unknown_names_raise():
    with pytest.raises(KeyError):
        ExperimentSpec(model="no_such_model").model_config()


# ---------------------------------------------------------------------------
# Fleet: bit-identity with sequential runs
# ---------------------------------------------------------------------------


def _assert_member_matches(res, fres):
    for a, b in zip(jax.tree.leaves(res.params), jax.tree.leaves(fres.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert len(res.history) == len(fres.history)
    for x, y in zip(res.history, fres.history):
        assert x.round == y.round
        # nan == nan must pass (zero-participation rounds).
        np.testing.assert_array_equal(x.train_loss, y.train_loss)
        assert x.sim_time == y.sim_time
        assert x.T_cm == y.T_cm and x.T_cp == y.T_cp
        assert x.n_participants == y.n_participants
        assert x.uplink_bits == y.uplink_bits


@pytest.mark.parametrize("scenario", ["dropout", "hetero_storm"])
def test_fleet_bit_identical_to_sequential_8_seeds(scenario):
    """The acceptance contract: run_fleet(seeds=8) == 8 sequential run()
    calls at those seeds, bit for bit, on registry scenarios (loss
    history, Eq. 8 clocks, participation, params)."""
    sim = _quad_sim("scan", scenario)
    seeds = list(range(8))
    fleet = sim.run_fleet(seeds=seeds, max_rounds=7, eval_every=3)
    assert len(fleet) == 8
    for s in seeds:
        _, res = sim.run(sim.init(s), max_rounds=7, eval_every=3)
        _assert_member_matches(res, fleet.results[s])
        assert fleet.states[s].seed == s and fleet.states[s].round == 7


def test_fleet_bit_identical_cnn_device_resident():
    """Same contract on the real CNN harness with the device-resident
    in-graph data gather (BatchIterator factory -> per-seed streams)."""
    spec = experiment.get("mnist_smoke").replace(
        with_eval=False, scenario="dropout",
        fed=FedConfig(n_devices=3, batch_size=8, theta=0.62, lr=0.05,
                      compress_updates=True))
    sim = spec.build()
    seeds = [0, 1, 2, 3]
    fleet = sim.run_fleet(seeds=seeds, max_rounds=5, eval_every=2)
    for s in seeds:
        _, res = sim.run(sim.init(s), max_rounds=5, eval_every=2)
        _assert_member_matches(res, fleet.results[s])


def test_fleet_accepts_prebuilt_states_and_summary():
    sim = _quad_sim("scan", None)
    states = [sim.init(s) for s in (3, 5)]
    fleet = sim.run_fleet(states=states, max_rounds=4, eval_every=2)
    _, ref = sim.run(sim.init(3), max_rounds=4, eval_every=2)
    _assert_member_matches(ref, fleet.results[0])
    s = fleet.summary()
    assert set(s) == {"final_loss_mean", "final_loss_std",
                      "total_time_mean", "total_time_std"}
    assert fleet.loss_history().shape == (2, 4)


def test_fleet_validation():
    sim = _quad_sim("scan", None)
    with pytest.raises(ValueError):
        sim.run_fleet(max_rounds=3)  # neither seeds nor states
    with pytest.raises(ValueError):
        sim.run_fleet(states=[], max_rounds=3)
    with pytest.raises(ValueError):
        _quad_sim("batched", None).run_fleet(seeds=[0], max_rounds=3)
    # mismatched round cursors can't run in lockstep
    s0 = sim.init(0)
    s1, _ = sim.run(sim.init(1), max_rounds=2)
    with pytest.raises(ValueError):
        sim.run_fleet(states=[s0, s1], max_rounds=2)


def test_fleet_rejects_shared_iterator_list():
    """A Simulator built on a fixed iterator list (legacy form) cannot
    fleet: every member would alias — and advance — the same live
    iterators, silently breaking per-seed bit-identity. Must raise, not
    produce wrong results."""
    M, d, b = 4, 16, 2
    fed = FedConfig(n_devices=M, batch_size=b, lr=0.05)
    pop = delay.draw_population(M, ComputeConfig(), WirelessConfig(), 0, 0.0)
    iters = [_TargetIterator(np.linspace(0.0, m, d) * 0.1, b)
             for m in range(M)]
    sim = Simulator(_quad_loss, {"w": jnp.zeros(d)}, iters,
                    np.array([10, 20, 30, 40]), fed, sgd(fed.lr), pop,
                    backend="scan")
    with pytest.raises(ValueError, match="factory"):
        sim.run_fleet(seeds=[0, 1], max_rounds=2)


# ---------------------------------------------------------------------------
# Validation & error semantics (all backends)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["loop", "batched", "scan"])
def test_run_args_validated_up_front(backend):
    """No silent clamping: bad max_rounds/eval_every raise on every
    backend before any work is dispatched."""
    sim = _quad_sim(backend, None, compress=False)
    state = sim.init()
    for bad in (0, -1, 1.5):
        with pytest.raises(ValueError):
            sim.run(state, max_rounds=bad)
        with pytest.raises(ValueError):
            sim.run(state, max_rounds=3, eval_every=bad)


@pytest.mark.parametrize("backend", ["loop", "batched"])
def test_run_round_real_requires_scenario(backend):
    """run_round(real=...) on a scenario-less sim used to be silently
    ignored; it now raises."""
    sim = _quad_sim(backend, None, compress=False)
    scen = scenarios.get("dropout")
    real = scen.stream(scen.population(4), 0).next_round()
    with pytest.raises(ValueError, match="no scenario"):
        sim.run_round(sim.init(), real=real)
    # With a scenario, an explicit realization is accepted.
    ssim = _quad_sim(backend, "dropout", compress=False)
    _, metrics = ssim.run_round(ssim.init(), real=real)
    assert metrics["n_participants"] == real.n_participants


def test_run_chunk_requires_scan_and_validates():
    sim = _quad_sim("scan", None)
    state, records = sim.run_chunk(sim.init(), rounds=3)
    assert [r.round for r in records] == [1, 2, 3]
    assert state.round == 3
    with pytest.raises(ValueError):
        sim.run_chunk(sim.init(), rounds=0)
    with pytest.raises(ValueError):
        _quad_sim("batched", None).run_chunk(sim.init(), rounds=2)


# ---------------------------------------------------------------------------
# Deprecated shim
# ---------------------------------------------------------------------------


def _shim_args(seed=0):
    M, d, b = 4, 16, 2
    fed = FedConfig(n_devices=M, batch_size=b, lr=0.05, seed=seed,
                    compress_updates=True)
    pop = delay.draw_population(M, ComputeConfig(), WirelessConfig(), 0, 0.0)
    iters = [_TargetIterator(np.linspace(0.0, m, d) * 0.1, b)
             for m in range(M)]
    return (_quad_loss, {"w": jnp.zeros(d)}, iters,
            np.array([10, 20, 30, 40]), fed, sgd(fed.lr, 0.9), pop)


def test_shim_warns_exactly_once_and_matches_core():
    simulation._FLSIM_WARNED = False
    with pytest.warns(DeprecationWarning, match="FLSimulation is deprecated"):
        shim = FLSimulation(*_shim_args(), backend="scan")
    # Second construction: no second warning (module-level once latch).
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        FLSimulation(*_shim_args(), backend="scan")
    assert simulation._FLSIM_WARNED
    # The shim is the same math as the functional core, bit for bit.
    res = shim.run(max_rounds=5, eval_every=2)
    core = _quad_sim("scan", None)
    _, ref = core.run(core.init(), max_rounds=5, eval_every=2)
    for a, b in zip(jax.tree.leaves(ref.params), jax.tree.leaves(res.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ([r.train_loss for r in ref.history]
            == [r.train_loss for r in res.history])
    # Stateful conveniences still work: params view, round_times, bits.
    assert shim.trace_count == 1
    assert shim._update_bits() == core._update_bits()
    assert shim.state.round == 5
