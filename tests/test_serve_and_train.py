"""Runnable-driver smoke tests (examples/launch entry points)."""
import numpy as np

from repro.launch import serve, train


def test_serve_driver_generates():
    gen = serve.main(["--arch", "qwen2-0.5b", "--smoke", "--batch", "2",
                      "--prompt-len", "16", "--gen", "4"])
    assert gen.shape[0] == 2 and gen.shape[1] == 4
    assert (gen >= 0).all()


def test_serve_driver_audio():
    gen = serve.main(["--arch", "musicgen-large", "--smoke", "--batch", "1",
                      "--prompt-len", "8", "--gen", "3"])
    assert gen.shape[-1] == 4  # codebooks


def test_train_driver_runs_rounds():
    params = train.main(["--arch", "qwen2-0.5b", "--smoke", "--rounds", "2",
                         "--clients", "2", "--batch", "2", "--seq", "32",
                         "--V", "2"])
    leaves = [np.asarray(x) for x in
              __import__("jax").tree.leaves(params)]
    assert all(np.isfinite(l).all() for l in leaves)
