"""Sampled-participation round engine: K-client cohorts drawn per round
from an M-client population, device state O(K).

Contracts under test:
  * K = M sampled is bit-identical to dense on every registered scenario
    (losses, clocks, participation, uplink bits, params) — with and
    without compression, with and without faults;
  * sampled scan == sampled batched bit-parity at K < M (one trace);
  * cohort draws are deterministic per seed, survive a state
    snapshot/restore, and the K = M draw consumes NO cohort RNG;
  * checkpoint/resume mid-run is bit-identical to an uninterrupted run;
  * device state really is O(K) (stacked params carry K lanes, not M);
  * the spec API: PopulationSpec/CohortSpec wiring, the dense-M
    deprecation, population-scale (M >> n_train) smoke;
  * the DEFL plan sees the cohort-conditional effective M (Eq. 12);
  * misuse errors: stateful local optimizer, loop backend, run_round
    with a pre-drawn realization.
"""
import dataclasses
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ComputeConfig, FedConfig, WirelessConfig
from repro.core import defl, delay
from repro.data.pipeline import BatchIterator, ClientDataPool
from repro.federated import experiment, scenarios
from repro.federated.experiment import (CohortSpec, ExperimentSpec,
                                        PopulationSpec)
from repro.federated.faults import FaultModel
from repro.federated.simulation import Simulator, load_state, save_state
from repro.federated.study import Study
from repro.optim import sgd


def _quad_loss(params, batch):
    diff = params["w"] - batch["target"]
    return 0.5 * jnp.sum(diff * diff), {}


class _TargetIterator:
    """Batch source without the index protocol (generic pre-stacked
    data path)."""

    def __init__(self, target, batch_size):
        self.target = np.asarray(target, np.float32)
        self.batch_size = batch_size

    def next_batch(self):
        return {"target": np.tile(self.target, (self.batch_size, 1))}


def _quad_sim(backend, scenario, *, M=6, K=None, sampler="uniform",
              compress=False, faults=None, seed=0):
    d, b = 16, 2
    fed = FedConfig(n_devices=M, batch_size=b, lr=0.05, seed=seed,
                    compress_updates=compress)
    scen = scenarios.get(scenario) if scenario is not None else None
    pop = (scen.population(M, seed=seed) if scen is not None else
           delay.draw_population(M, ComputeConfig(), WirelessConfig(), 0, 0.0))
    iters = [_TargetIterator(np.linspace(0.0, m, d) * 0.1, b)
             for m in range(M)]
    return Simulator(
        _quad_loss, {"w": jnp.zeros(d)}, iters,
        10 * np.arange(1, M + 1), fed, sgd(fed.lr), pop,
        backend=backend, scenario=scen, faults=faults,
        cohort=K, cohort_sampler=sampler)


def _run(sim, **kw):
    _, res = sim.run(sim.init(), **kw)
    return res


def _assert_bit_identical(res_a, res_b):
    for a, b in zip(jax.tree.leaves(res_a.params),
                    jax.tree.leaves(res_b.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert len(res_a.history) == len(res_b.history)
    for ra, rb in zip(res_a.history, res_b.history):
        assert ra.round == rb.round
        np.testing.assert_array_equal(ra.train_loss, rb.train_loss)
        assert ra.sim_time == rb.sim_time
        assert ra.T_cm == rb.T_cm and ra.T_cp == rb.T_cp
        assert ra.n_participants == rb.n_participants
        assert ra.uplink_bits == rb.uplink_bits


# -- K = M dense equivalence --------------------------------------------------

# 7 rounds at eval_every=3: ragged final chunk included.
@pytest.mark.parametrize("scenario", list(scenarios.names()))
@pytest.mark.parametrize("compress", [False, True])
def test_sampled_K_eq_M_bit_identical_to_dense(scenario, compress):
    dense = _run(_quad_sim("scan", scenario, M=4, compress=compress),
                 max_rounds=7, eval_every=3)
    sampled = _run(_quad_sim("scan", scenario, M=4, K=4, compress=compress),
                   max_rounds=7, eval_every=3)
    _assert_bit_identical(sampled, dense)


def test_sampled_K_eq_M_with_faults_matches_dense():
    fm = FaultModel(deadline_factor=1.5, max_retries=1)
    dense = _run(_quad_sim("scan", "stragglers", M=4, faults=fm),
                 max_rounds=6, eval_every=3)
    sampled = _run(_quad_sim("scan", "stragglers", M=4, K=4, faults=fm),
                   max_rounds=6, eval_every=3)
    _assert_bit_identical(sampled, dense)


# -- sampled scan == batched --------------------------------------------------

@pytest.mark.parametrize("scenario", ["dropout", "unreliable_edge", None])
@pytest.mark.parametrize("compress", [False, True])
def test_sampled_scan_matches_batched(scenario, compress):
    rb = _run(_quad_sim("batched", scenario, M=6, K=3, compress=compress),
              max_rounds=7, eval_every=3)
    sim = _quad_sim("scan", scenario, M=6, K=3, compress=compress)
    rs = _run(sim, max_rounds=7, eval_every=3)
    _assert_bit_identical(rs, rb)
    assert sim.trace_count == 1


def test_sampled_faults_scan_matches_batched():
    fm = FaultModel(deadline_factor=1.5, max_retries=2)
    rb = _run(_quad_sim("batched", "stragglers", M=6, K=3, faults=fm),
              max_rounds=6, eval_every=3)
    rs = _run(_quad_sim("scan", "stragglers", M=6, K=3, faults=fm),
              max_rounds=6, eval_every=3)
    _assert_bit_identical(rs, rb)


def test_weighted_sampler_runs_and_matches_across_backends():
    rb = _run(_quad_sim("batched", "dropout", M=6, K=3, sampler="weighted"),
              max_rounds=5, eval_every=2)
    rs = _run(_quad_sim("scan", "dropout", M=6, K=3, sampler="weighted"),
              max_rounds=5, eval_every=2)
    _assert_bit_identical(rs, rb)
    parts = [r.n_participants for r in rs.history]
    assert all(p is None or p <= 3 for p in parts)


# -- cohort draws -------------------------------------------------------------

def _stream(K=3, M=6, seed=0, weights=None):
    scen = scenarios.get("dropout")
    pop = scen.population(M, seed=seed)
    return scen.stream(pop, seed, cohort_size=K, cohort_weights=weights)


def test_cohort_draw_deterministic_sorted_unique():
    a = [_stream(seed=3).draw_cohort() for _ in range(5)]
    b = [_stream(seed=3).draw_cohort() for _ in range(5)]
    np.testing.assert_array_equal(a[0], b[0])
    for c in a:
        assert c.dtype == np.int32 and c.shape == (3,)
        assert (np.diff(c) > 0).all()  # sorted, unique
        assert c.min() >= 0 and c.max() < 6
    # draw_cohorts(R) == R x draw_cohort(), bit for bit
    s1, s2 = _stream(seed=3), _stream(seed=3)
    stacked = s1.draw_cohorts(4)
    singles = np.stack([s2.draw_cohort() for _ in range(4)])
    np.testing.assert_array_equal(stacked, singles)


def test_cohort_draw_K_eq_M_is_arange_and_consumes_no_rng():
    s = _stream(K=6, M=6)
    before = s.state()["cohort_rng"]
    np.testing.assert_array_equal(s.draw_cohort(), np.arange(6))
    assert s.state()["cohort_rng"] == before


def test_cohort_state_snapshot_restore():
    s = _stream(seed=9)
    s.draw_cohorts(3)
    snap = s.state()
    ahead = s.draw_cohorts(4)
    s.set_state(snap)
    np.testing.assert_array_equal(s.draw_cohorts(4), ahead)


def test_weighted_cohort_favors_heavy_clients():
    w = np.array([1e-6, 1e-6, 1e-6, 1.0, 1.0, 1.0])
    s = _stream(K=3, M=6, weights=w)
    draws = np.concatenate([s.draw_cohort() for _ in range(50)])
    heavy = (draws >= 3).mean()
    assert heavy > 0.95


# -- checkpoint / resume ------------------------------------------------------

def test_sampled_resume_bit_identical(tmp_path):
    full = _run(_quad_sim("scan", "dropout", M=6, K=3, seed=5),
                max_rounds=6, eval_every=2)
    simA = _quad_sim("scan", "dropout", M=6, K=3, seed=5)
    mid, _ = simA.run(simA.init(), max_rounds=3, eval_every=2)
    path = os.path.join(tmp_path, "state.pkl")
    save_state(path, mid)
    simB = _quad_sim("scan", "dropout", M=6, K=3, seed=5)
    _, resumed = simB.run(load_state(path), max_rounds=3, eval_every=2)
    for x, y in zip(full.history[3:], resumed.history):
        assert x.round == y.round
        np.testing.assert_array_equal(x.train_loss, y.train_loss)
        assert x.sim_time == y.sim_time
        assert x.n_participants == y.n_participants
    for a, b in zip(jax.tree.leaves(full.params),
                    jax.tree.leaves(resumed.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- O(K) device state --------------------------------------------------------

def test_sampled_device_state_is_O_K():
    sim = _quad_sim("scan", "dropout", M=64, K=4)
    st = sim.init()
    for leaf in jax.tree.leaves(st.params_C):
        assert leaf.shape[0] == 4  # K lanes, not M
    st, _ = sim.run(st, max_rounds=2, eval_every=2)
    for leaf in jax.tree.leaves(st.params_C):
        assert leaf.shape[0] == 4


# -- spec API -----------------------------------------------------------------

def test_population_spec_validation():
    with pytest.raises(ValueError):
        CohortSpec(K=0)
    with pytest.raises(ValueError):
        CohortSpec(K=2, sampler="roulette")
    with pytest.raises(ValueError):
        PopulationSpec(M=4, cohort=CohortSpec(K=8))  # K > M


def test_population_spec_dense_sugar_bit_parity():
    base = dict(model="mnist_cnn_tiny", dataset="mnist", n_train=48,
                n_test=16, scenario="dropout")
    via_fed = ExperimentSpec(
        fed=FedConfig(n_devices=4, batch_size=4, theta=0.62, lr=0.05),
        **base)
    via_pop = ExperimentSpec(
        fed=FedConfig(batch_size=4, theta=0.62, lr=0.05),
        population=PopulationSpec(M=4), **base)
    ra = _run(via_fed.build(), max_rounds=3, eval_every=3)
    rb = _run(via_pop.build(), max_rounds=3, eval_every=3)
    _assert_bit_identical(ra, rb)


def test_dense_M_above_threshold_deprecated():
    spec = ExperimentSpec(
        fed=FedConfig(batch_size=4, theta=0.62, lr=0.05),
        model="mnist_cnn_tiny", dataset="mnist", n_train=48, n_test=16,
        population=PopulationSpec(
            M=experiment.DENSE_M_DEPRECATION_THRESHOLD))
    with warnings.catch_warnings():
        # The tier-1 filter turns first-party DeprecationWarnings into
        # errors; the warning fires before any M-sized work happens.
        warnings.simplefilter("error", DeprecationWarning)
        with pytest.raises(DeprecationWarning, match="PopulationSpec"):
            spec.build()


def test_registered_sampled_spec_runs():
    spec = experiment.get("mnist_sampled")
    K = spec.cohort_spec().K
    sim = spec.build()
    st = sim.init(0)
    for leaf in jax.tree.leaves(st.params_C):
        assert leaf.shape[0] == K
    _, res = sim.run(st, max_rounds=2, eval_every=2)
    assert len(res.history) == 2
    assert sim.trace_count == 1


def test_population_scale_smoke():
    """The headline acceptance shape: M far beyond n_train (virtual
    shard partition, no M-long host lists) with O(K) device state."""
    spec = ExperimentSpec(
        fed=FedConfig(batch_size=4, theta=0.62, lr=0.05),
        model="mnist_cnn_tiny", dataset="mnist", n_train=96, n_test=16,
        scenario="dropout",
        population=PopulationSpec(M=100_000, cohort=CohortSpec(K=8)))
    sim = spec.build()
    st = sim.init(0)
    for leaf in jax.tree.leaves(st.params_C):
        assert leaf.shape[0] == 8
    _, res = sim.run(st, max_rounds=2, eval_every=2)
    assert len(res.history) == 2
    for rec in res.history:
        assert rec.n_participants is None or rec.n_participants <= 8


# -- data pool ----------------------------------------------------------------

def test_client_pool_matches_dense_iterators():
    """Pool-backed clients replay the exact dense per-client batch
    streams (same seeds, same RNG consumption)."""
    from repro.data.synthetic import make_mnist_like
    data = make_mnist_like(64, seed=0)
    parts = [np.arange(m * 16, (m + 1) * 16) for m in range(4)]
    dense = [BatchIterator(data, p, 8, seed=7 + m)
             for m, p in enumerate(parts)]
    pool = ClientDataPool.from_parts(data, parts, 8, seed=7)
    for m in range(4):
        it = pool.client(m)
        for _ in range(3):
            a, b = dense[m].next_batch(), it.next_batch()
            np.testing.assert_array_equal(a["x"], b["x"])
            np.testing.assert_array_equal(a["y"], b["y"])


def test_client_pool_state_is_O_touched():
    from repro.data.synthetic import make_mnist_like
    data = make_mnist_like(64, seed=0)
    pool = ClientDataPool(data, lambda m: np.arange(16),
                          np.full(1000, 16), 8, seed=0)
    pool.client(3).next_batch()
    pool.client(998).next_batch()
    assert set(pool.state()["clients"].keys()) == {3, 998}


# -- DEFL plan ----------------------------------------------------------------

def test_make_plan_cohort_conditional_M_eff():
    fed = FedConfig(n_devices=1000, epsilon=0.01, nu=2.0)
    pop = delay.draw_population(16, ComputeConfig(), WirelessConfig(), 0, 0.5)
    dense = defl.make_plan(fed, pop, 8e6)
    cohort = defl.make_plan(fed, pop, 8e6, cohort_size=10)
    assert dense.problem.M == 1000
    assert cohort.problem.M == 10
    # Population stats (straggler T_cm, bottleneck g) are unchanged —
    # any of the M clients can be drawn.
    assert cohort.T_cm == dense.T_cm
    # Fewer averaged updates per round -> more predicted rounds.
    assert cohort.H_pred >= dense.H_pred


def test_deadline_plan_cohort_conditional_M_eff():
    fed = FedConfig(n_devices=1000, epsilon=0.01, nu=2.0)
    pop = delay.draw_population(16, ComputeConfig(), WirelessConfig(), 0, 0.5)
    dense = defl.deadline_plan(fed, pop, 8e6, deadline=1e4)
    cohort = defl.deadline_plan(fed, pop, 8e6, deadline=1e4, cohort_size=10)
    assert cohort.problem.M <= 10 < dense.problem.M


# -- study integration --------------------------------------------------------

def test_study_sampled_arm_groups_and_table():
    fed = FedConfig(batch_size=8, theta=0.62, lr=0.05)
    base = dict(model="mnist_cnn_tiny", dataset="mnist", n_train=48,
                n_test=16, scenario="dropout")
    pop = PopulationSpec(M=12, cohort=CohortSpec(K=4))
    arms = [
        ("sA", ExperimentSpec(fed=fed, population=pop, **base)),
        ("sB", ExperimentSpec(fed=dataclasses.replace(fed, batch_size=4),
                              population=pop, **base)),
        ("dense", ExperimentSpec(
            fed=dataclasses.replace(fed, n_devices=4), **base)),
    ]
    res = Study(arms=arms, seeds=(0,), max_rounds=3, eval_every=3).run()
    # Sampled arms fuse into one vmapped group; dense shapes differ.
    assert ("sA", "sB") in res.groups
    header, rows = res.table()
    cols = header.split(",")
    assert "K" in cols
    k_idx = cols.index("K")
    by_label = {r[0]: r for r in rows}
    assert by_label["sA"][k_idx] == 4 and by_label["dense"][k_idx] == ""
    assert res.to_json()["arms"]["sA"]["K"] == 4
    # Grouped sampled member == solo sampled run, bit for bit.
    sim = arms[0][1].build()
    _, solo = sim.run(sim.init(0), max_rounds=3, eval_every=3)
    _assert_bit_identical(res["sA"][0], solo)


# -- misuse errors ------------------------------------------------------------

def test_sampled_requires_stateless_local_opt():
    with pytest.raises(ValueError, match="stateless"):
        d, M = 16, 6
        fed = FedConfig(n_devices=M, batch_size=2, lr=0.05)
        pop = delay.draw_population(M, ComputeConfig(), WirelessConfig(),
                                    0, 0.0)
        iters = [_TargetIterator(np.zeros(d), 2) for _ in range(M)]
        Simulator(_quad_loss, {"w": jnp.zeros(d)}, iters,
                  np.full(M, 10), fed, sgd(fed.lr, momentum=0.9), pop,
                  backend="scan", cohort=3)


def test_sampled_rejects_loop_backend():
    with pytest.raises(ValueError):
        _quad_sim("loop", "dropout", M=6, K=3)


def test_sampled_run_round_rejects_predrawn_realization():
    sim = _quad_sim("batched", "dropout", M=6, K=3)
    st = sim.init()
    stream = sim._materialize(st)[1]
    real = stream.next_round()
    with pytest.raises(ValueError):
        sim.run_round(st, real=real)
