"""Config registry + analytic parameter counts vs published sizes."""
import pytest

from repro.configs.registry import ARCH_IDS, all_configs, get_config

# Published (approximate) total parameter counts, billions.
PUBLISHED_TOTALS = {
    "qwen3-moe-30b-a3b": 30.5,
    "qwen2-0.5b": 0.49,
    "gemma-7b": 8.5,  # embedding-heavy: 8.54B with 256k vocab
    "zamba2-2.7b": 2.7,
    "qwen3-32b": 32.8,
    "falcon-mamba-7b": 7.3,
    "llama4-scout-17b-a16e": 109.0,
    "llava-next-34b": 34.4,
    "musicgen-large": 3.3,
}


def test_registry_has_all_10():
    assert len(ARCH_IDS) == 10
    cfgs = all_configs()
    assert set(cfgs) == set(ARCH_IDS)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    assert cfg.name == arch
    assert cfg.source
    total, active = cfg.param_count()
    assert 0 < active <= total
    if arch in PUBLISHED_TOTALS:
        pub = PUBLISHED_TOTALS[arch] * 1e9
        assert abs(total - pub) / pub < 0.15, (
            f"{arch}: {total / 1e9:.2f}B vs published {pub / 1e9:.2f}B")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_config_is_reduced(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4
    # Same family knobs as the full config.
    full = get_config(arch)
    assert cfg.mixer == full.mixer and cfg.mlp == full.mlp
    assert cfg.arch_type == full.arch_type


def test_exact_assignment_numbers():
    c = get_config("qwen3-moe-30b-a3b")
    assert (c.n_layers, c.d_model, c.attention.n_heads,
            c.attention.n_kv_heads) == (48, 2048, 32, 4)
    assert (c.moe.n_experts, c.moe.top_k, c.moe.d_ff_expert) == (128, 8, 768)
    assert c.vocab_size == 151936
    c = get_config("gemma-7b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab_size) == (
        28, 3072, 24576, 256000)
    assert c.attention.head_dim == 256 and c.act == "gelu"
    c = get_config("falcon-mamba-7b")
    assert (c.n_layers, c.d_model, c.vocab_size) == (64, 4096, 65024)
    assert c.ssm.kind == "mamba1" and c.ssm.d_state == 16
    c = get_config("zamba2-2.7b")
    assert c.ssm.kind == "mamba2" and c.ssm.d_state == 64
    assert c.n_layers == 54 and c.shared_attn_every == 6
    c = get_config("llama4-scout-17b-a16e")
    assert (c.moe.n_experts, c.moe.top_k) == (16, 1)
    assert c.vocab_size == 202048
    c = get_config("musicgen-large")
    assert c.modality.n_codebooks == 4 and c.vocab_size == 2048
    c = get_config("llava-next-34b")
    assert (c.n_layers, c.d_model, c.attention.n_heads) == (60, 7168, 56)
