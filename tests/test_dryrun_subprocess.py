"""Dry-run smoke: one (arch x shape) pair lowered + compiled on the real
16x16 production mesh, in a subprocess (XLA device-count flag must not
leak into this test process)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("arch,shape", [("qwen2-0.5b", "decode_32k")])
def test_dryrun_single_pair(tmp_path, arch, shape):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "single", "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    rec = json.load(open(tmp_path / f"{arch}--{shape}--single.json"))
    assert rec["ok"], rec.get("error")
    assert rec["flops_per_device"] > 0
    assert rec["terms_seconds"]["memory"] > 0
    assert rec["dominant"] in ("compute", "memory", "collective")
