"""Selective-scan kernel: shape sweep vs sequential oracle (interpret)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.selective_scan import ops as ss_ops
from repro.kernels.selective_scan.kernel import selective_scan_kernel
from repro.kernels.selective_scan.ref import selective_scan_sequential


def _inputs(key, B, S, D, N):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, D))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, D))) * 0.2
    A = -jnp.exp(jax.random.normal(ks[2], (D, N)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    Dskip = jnp.linspace(0.5, 1.5, D)
    return x, dt, A, Bm, Cm, Dskip


@pytest.mark.parametrize("B,S,D,N", [
    (1, 64, 128, 8), (2, 128, 256, 16), (1, 96, 512, 16), (2, 100, 128, 8),
])
def test_scan_kernel_sweep(key, B, S, D, N):
    args = _inputs(key, B, S, D, N)
    y_ref, h_ref = selective_scan_sequential(*args)
    y, h = ss_ops.selective_scan(*args, chunk=32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=3e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=3e-5)


@pytest.mark.parametrize("block_d", [64, 128])
def test_scan_kernel_block_shapes(key, block_d):
    args = _inputs(key, 1, 64, 128, 8)
    y_ref, h_ref = selective_scan_sequential(*args)
    y, h = selective_scan_kernel(*args, chunk=32, block_d=block_d)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=3e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=3e-5)


def test_scan_kernel_chunk_invariance(key):
    args = _inputs(key, 1, 128, 128, 8)
    y16, h16 = ss_ops.selective_scan(*args, chunk=16)
    y64, h64 = ss_ops.selective_scan(*args, chunk=64)
    np.testing.assert_allclose(np.asarray(y16), np.asarray(y64), atol=3e-5)
    np.testing.assert_allclose(np.asarray(h16), np.asarray(h64), atol=3e-5)
