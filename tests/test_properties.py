"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs.base import WirelessConfig
from repro.core import delay, kkt
from repro.core.convergence import communication_rounds
from repro.federated.partition import partition_iid
from repro.kernels.quantize.ref import dequantize_ref, quantize_ref
from repro.kernels.selective_scan.ref import (
    selective_scan_ref,
    selective_scan_sequential,
)
from repro.utils.tree import tree_weighted_mean

_SETTINGS = dict(max_examples=25, deadline=None)


@given(T_cm=st.floats(1e-4, 10), g=st.floats(1e-6, 1.0),
       M=st.integers(2, 100), eps=st.floats(1e-4, 0.5),
       nu=st.floats(0.5, 8.0), c=st.floats(0.05, 5.0))
@settings(**_SETTINGS)
def test_kkt_closed_form_always_feasible(T_cm, g, M, eps, nu, c):
    prob = kkt.DelayProblem(T_cm=T_cm, g=g, M=M, eps=eps, nu=nu, c=c)
    s = kkt.closed_form(prob)
    assert s.b >= 1 and np.isfinite(s.b)
    # theta = exp(-alpha) may underflow to exactly 0 for extreme channels;
    # constraint (16) allows theta = 0 ("exact local solution").
    assert 0 <= s.theta <= 1
    assert s.V >= 1 and s.H > 0
    assert np.isfinite(s.overall) and s.overall > 0
    # Eq. 29 relation: alpha* = b * stationary_alpha(b) for any b.
    assert np.isclose(4.0 * kkt.stationary_alpha(prob, 4.0), s.alpha,
                      rtol=1e-6)


@given(b=st.floats(0.1, 5000))
@settings(**_SETTINGS)
def test_quantize_batch_power_of_two(b):
    q = kkt.quantize_batch(b)
    assert q >= 1 and (q & (q - 1)) == 0


@given(b=st.integers(1, 512), theta=st.floats(0.01, 0.95),
       M=st.integers(2, 50))
@settings(**_SETTINGS)
def test_rounds_positive_and_monotone_in_b(b, theta, M):
    h = communication_rounds(b, theta, M, 0.01, 2.0, 1.0)
    h2 = communication_rounds(2 * b, theta, M, 0.01, 2.0, 1.0)
    assert h > 0 and h2 < h


@given(bits=st.floats(1e3, 1e10), p=st.floats(0.01, 2.0),
       h=st.floats(1e-10, 1e-6))
@settings(**_SETTINGS)
def test_uplink_time_monotone(bits, p, h):
    wc = WirelessConfig()
    t = delay.uplink_time(bits, wc, p, h)
    assert t > 0
    assert delay.uplink_time(bits * 2, wc, p, h) > t
    assert delay.uplink_time(bits, wc, p * 2, h) < t


@given(n=st.integers(20, 300), m=st.integers(2, 10),
       seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_partition_complete_disjoint(n, m, seed):
    parts = partition_iid(n, m, seed)
    allidx = np.concatenate(parts)
    assert len(np.unique(allidx)) == n == len(allidx)


@given(seed=st.integers(0, 50), scale=st.floats(1e-4, 10.0))
@settings(max_examples=10, deadline=None)
def test_quantize_error_bound_property(seed, scale):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (4, 256)) * scale
    q, s = quantize_ref(x, jax.random.fold_in(key, 1))
    rec = dequantize_ref(q, s)
    assert np.max(np.abs(np.asarray(rec - x))) <= float(np.max(np.asarray(s))) + 1e-6


@given(seed=st.integers(0, 50), chunk=st.sampled_from([4, 8, 16, 32]),
       S=st.sampled_from([16, 32, 48]))
@settings(max_examples=10, deadline=None)
def test_selective_scan_chunk_invariance(seed, chunk, S):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    B, D, N = 1, 8, 4
    x = jax.random.normal(ks[0], (B, S, D))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, D))) * 0.3
    A = -jnp.exp(jax.random.normal(ks[2], (D, N)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    Dk = jnp.ones((D,))
    y_ref, h_ref = selective_scan_sequential(x, dt, A, Bm, Cm, Dk)
    y, h = selective_scan_ref(x, dt, A, Bm, Cm, Dk, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=5e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=5e-5)


@given(w=st.lists(st.floats(0.1, 10.0), min_size=2, max_size=5))
@settings(**_SETTINGS)
def test_weighted_mean_scale_invariant(w):
    trees = [{"x": jnp.full(3, float(i))} for i in range(len(w))]
    a = tree_weighted_mean(trees, np.asarray(w))
    b = tree_weighted_mean(trees, np.asarray(w) * 7.3)
    np.testing.assert_allclose(np.asarray(a["x"]), np.asarray(b["x"]),
                               rtol=1e-5)
    vals = np.asarray([float(i) for i in range(len(w))])
    expect = (vals * np.asarray(w)).sum() / np.sum(w)
    np.testing.assert_allclose(np.asarray(a["x"]), expect, rtol=1e-5)
