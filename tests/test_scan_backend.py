"""Chunk-fused scan backend: backend='scan' must be bit-identical to
backend='batched' over identical scenario streams (params, losses, clocks,
participation, uplink bits) while compiling exactly once per run — across
multiple chunks and a ragged final chunk — with both data paths (generic
pre-stacked batches and the device-resident in-graph gather)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ComputeConfig, FedConfig, WirelessConfig
from repro.core import delay
from repro.federated import scenarios
from repro.federated.simulation import Simulator
from repro.models import cnn
from repro.optim import sgd


def _quad_loss(params, batch):
    diff = params["w"] - batch["target"]
    return 0.5 * jnp.sum(diff * diff), {}


class _TargetIterator:
    """Batch source WITHOUT the index protocol: forces the scan backend
    onto the generic pre-stacked (R, C, V, ...) data path."""

    def __init__(self, target, batch_size):
        self.target = np.asarray(target, np.float32)
        self.batch_size = batch_size

    def next_batch(self):
        return {"target": np.tile(self.target, (self.batch_size, 1))}


def _quad_sim(backend, scenario, compress=True, momentum=0.9, seed=0):
    M, d, b = 4, 16, 2
    fed = FedConfig(n_devices=M, batch_size=b, lr=0.05, seed=seed,
                    compress_updates=compress)
    scen = scenarios.get(scenario) if scenario is not None else None
    pop = (scen.population(M, seed=seed) if scen is not None else
           delay.draw_population(M, ComputeConfig(), WirelessConfig(), 0, 0.0))
    iters = [_TargetIterator(np.linspace(0.0, m, d) * 0.1, b)
             for m in range(M)]
    return Simulator(
        _quad_loss, {"w": jnp.zeros(d)}, iters,
        np.array([10, 20, 30, 40]), fed, sgd(fed.lr, momentum), pop,
        backend=backend, scenario=scen)


def _run(sim, **kw):
    _, res = sim.run(sim.init(), **kw)
    return res


def _assert_bit_identical(res_scan, res_batched):
    for a, b in zip(jax.tree.leaves(res_batched.params),
                    jax.tree.leaves(res_scan.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for rb, rs in zip(res_batched.history, res_scan.history):
        assert rb.round == rs.round
        # nan == nan must pass (zero-participation rounds).
        np.testing.assert_array_equal(rb.train_loss, rs.train_loss)
        assert rb.sim_time == rs.sim_time
        assert rb.T_cm == rs.T_cm and rb.T_cp == rs.T_cp
        assert rb.n_participants == rs.n_participants
        assert rb.uplink_bits == rs.uplink_bits
    assert len(res_batched.history) == len(res_scan.history)


# 7 rounds at eval_every=3 -> chunks of 3, 3, and a ragged final 1
# (padded in-graph): the parity sweep also covers chunk raggedness.
@pytest.mark.parametrize("scenario", [None] + list(scenarios.names()))
@pytest.mark.parametrize("compress", [False, True])
def test_scan_bit_identical_to_batched(scenario, compress):
    rb = _run(_quad_sim("batched", scenario, compress),
              max_rounds=7, eval_every=3)
    sim = _quad_sim("scan", scenario, compress)
    rs = _run(sim, max_rounds=7, eval_every=3)
    _assert_bit_identical(rs, rb)
    assert sim.trace_count == 1


def test_scan_single_trace_over_chunks_and_ragged_tail():
    """8 rounds at eval_every=3 -> two full chunks + a padded 2-round
    final chunk, all through ONE compiled trace."""
    sim = _quad_sim("scan", "hetero_storm")
    state = sim.init()
    state, res = sim.run(state, max_rounds=8, eval_every=3)
    assert sim.trace_count == 1
    assert [r.round for r in res.history] == list(range(1, 9))
    # A second run from the returned state reuses the trace (same chunk
    # length) and continues the round numbering.
    state, res2 = sim.run(state, max_rounds=8, eval_every=3)
    assert sim.trace_count == 1
    assert [r.round for r in res2.history] == list(range(9, 17))


def test_scan_eval_every_longer_than_run():
    """eval_every > max_rounds clamps the chunk to max_rounds (no padded
    compute for the common short-run case) and still evals at the end."""
    sim = _quad_sim("scan", None)
    calls = []
    sim.eval_fn = lambda p: calls.append(1) or {"acc": 0.0}
    res = _run(sim, max_rounds=4, eval_every=50)
    assert sim.trace_count == 1
    assert len(res.history) == 4 and len(calls) == 1
    assert res.history[-1].test_acc is not None


def test_scan_eval_boundary_calls():
    """Evals land exactly on the per-round driver's boundaries: every
    eval_every rounds plus the final round."""
    sim = _quad_sim("scan", None)
    calls = []
    sim.eval_fn = lambda p: calls.append(1) or {"acc": 0.0}
    res = _run(sim, max_rounds=7, eval_every=3)
    assert len(calls) == 3  # rounds 3, 6, 7
    evald = [r.round for r in res.history if r.test_acc is not None]
    assert evald == [3, 6, 7]


def test_scan_resumed_run_after_donation():
    """run() twice on one sim: donated carry buffers from run #1's last
    chunk must not poison run #2 (state is rebound to the returned
    arrays), and training continues from run #1's state."""
    sim = _quad_sim("scan", None)
    state = sim.init()
    state, r1 = sim.run(state, max_rounds=4, eval_every=2)
    state, r2 = sim.run(state, max_rounds=4, eval_every=2)
    assert r1.rounds == 4 and r2.rounds == 4
    for leaf in jax.tree.leaves(r2.params):
        assert np.all(np.isfinite(np.asarray(leaf)))
    assert r2.history[-1].train_loss < r1.history[0].train_loss
    assert all(isinstance(r.train_loss, float) for r in r2.history)


def test_scan_max_sim_time_truncates_history():
    """History stops at the first round exceeding max_sim_time, like the
    per-round backends (the already-in-flight chunk still completes on
    device — documented deviation for the params)."""
    ref = _run(_quad_sim("batched", "uniform"), max_rounds=6)
    budget = ref.history[2].sim_time  # exactly 3 rounds' worth
    rb = _run(_quad_sim("batched", "uniform"), max_rounds=6, eval_every=2,
              max_sim_time=budget)
    rs = _run(_quad_sim("scan", "uniform"), max_rounds=6, eval_every=2,
              max_sim_time=budget)
    assert len(rs.history) == len(rb.history)
    assert rs.history[-1].sim_time == rb.history[-1].sim_time


def _cnn_sim(backend, compress, seed=0):
    from repro.data import BatchIterator, make_mnist_like
    from repro.federated.partition import partition_dirichlet, partition_sizes

    M, b = 3, 8
    fed = FedConfig(n_devices=M, batch_size=b, theta=0.62, lr=0.05, seed=seed,
                    compress_updates=compress)
    cfg = cnn.mnist_cnn_small()
    data = make_mnist_like(240, seed=seed)
    parts = partition_dirichlet(data, M, alpha=1.0, seed=seed)
    iters = [BatchIterator(data, p, b, seed=seed + i)
             for i, p in enumerate(parts)]
    pop = delay.draw_population(M, ComputeConfig(), WirelessConfig(), 0, 0.0)
    return Simulator(
        functools.partial(cnn.cnn_loss, cfg),
        cnn.init_cnn(cfg, jax.random.PRNGKey(seed)),
        iters, partition_sizes(parts), fed, sgd(fed.lr), pop, backend=backend)


@pytest.mark.parametrize("compress", [False, True])
def test_scan_cnn_device_resident_parity(compress):
    """BatchIterator clients share one dataset, so the scan backend takes
    the device-resident path (uploaded arrays + in-graph index gather) —
    and stays bit-identical to the batched backend's host-gathered
    batches."""
    rb = _run(_cnn_sim("batched", compress), max_rounds=5, eval_every=2)
    sim = _cnn_sim("scan", compress)
    assert sim._data_dev is not None  # in-graph gather path actually taken
    rs = _run(sim, max_rounds=5, eval_every=2)
    _assert_bit_identical(rs, rb)
    assert sim.trace_count == 1


def test_batch_iterator_index_protocol_stream_aligned():
    """next_batch == batch_from(arrays, next_indices()) draw-for-draw: the
    two consumption styles advance one RNG stream identically, so mixing
    them (or switching backends) never desynchronizes the data order."""
    from repro.data import BatchIterator, make_mnist_like

    data = make_mnist_like(40, seed=0)
    ia = BatchIterator(data, np.arange(17), 8, seed=3)
    ib = BatchIterator(data, np.arange(17), 8, seed=3)
    for _ in range(6):  # crosses a reshuffle boundary (17 // 8)
        a = ia.next_batch()
        b = BatchIterator.batch_from(ib.device_arrays(), ib.next_indices())
        np.testing.assert_array_equal(a["x"], b["x"])
        np.testing.assert_array_equal(a["y"], b["y"])
    # Small partition (n < batch_size): replacement sampling, same stream.
    ia = BatchIterator(data, np.arange(3), 8, seed=5)
    ib = BatchIterator(data, np.arange(3), 8, seed=5)
    np.testing.assert_array_equal(ia.next_batch()["y"],
                                  data.y[ib.next_indices()])


def test_scan_uplink_bits_accounting():
    """uplink_bits = participants x exact compressed wire size, on every
    backend (full M on the no-scenario path)."""
    from repro.federated import compression

    sim = _quad_sim("scan", "dropout")
    res = _run(sim, max_rounds=5, eval_every=2)
    bits = compression.compressed_bits(res.params)
    for r in res.history:
        assert r.uplink_bits == r.n_participants * bits
    res = _run(_quad_sim("batched", None), max_rounds=2)
    assert all(r.uplink_bits == 4 * bits for r in res.history)
