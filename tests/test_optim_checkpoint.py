"""Optimizer + checkpoint substrate tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.optim import adamw, apply_updates, sgd


def _rosen_quad(params):
    return jnp.sum((params["w"] - 3.0) ** 2) + jnp.sum(params["b"] ** 2)


@pytest.mark.parametrize("opt_name", ["sgd", "sgd_momentum", "adamw"])
def test_optimizers_converge_quadratic(opt_name):
    opt = {"sgd": sgd(0.1), "sgd_momentum": sgd(0.05, momentum=0.9),
           "adamw": adamw(0.3)}[opt_name]
    params = {"w": jnp.zeros(4), "b": jnp.ones(3)}
    state = opt.init(params)
    for _ in range(150):
        grads = jax.grad(_rosen_quad)(params)
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    assert float(_rosen_quad(params)) < 1e-3


def test_adamw_weight_decay_shrinks():
    opt = adamw(0.01, weight_decay=0.5)
    params = {"w": jnp.full(4, 10.0)}
    state = opt.init(params)
    zero_grad = {"w": jnp.zeros(4)}
    updates, state = opt.update(zero_grad, state, params)
    assert float(updates["w"][0]) < 0  # decay pulls toward zero


def test_checkpoint_roundtrip(tmp_path, key):
    tree = {"layer": {"w": jax.random.normal(key, (4, 5)),
                      "b": jnp.arange(3.0)},
            "step": jnp.asarray(7, jnp.int32)}
    path = os.path.join(tmp_path, "ckpt.msgpack")
    save_checkpoint(path, tree, metadata={"round": 7})
    restored, meta = load_checkpoint(path, jax.tree.map(jnp.zeros_like, tree))
    assert meta["round"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises(tmp_path, key):
    tree = {"w": jnp.zeros((2, 2))}
    path = os.path.join(tmp_path, "c.msgpack")
    save_checkpoint(path, tree)
    with pytest.raises(ValueError):
        load_checkpoint(path, {"w": jnp.zeros((3, 3))})
    with pytest.raises(ValueError):
        load_checkpoint(path, {"w2": jnp.zeros((2, 2))})
