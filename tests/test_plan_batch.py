"""Batched plan solving: kkt.solve_batch / defl.make_plan_batch must be
bit-identical to the scalar path lane by lane, Study.plans() must route
through them without changing a single plan, and defl.async_plan's
Eq. 12 re-derivation must behave like the buffered-asynchronous model
it claims to be."""
import numpy as np
import pytest

from repro.configs.base import FedConfig, WirelessConfig
from repro.core import defl, delay, kkt
from repro.federated import experiment
from repro.federated.experiment import CALIBRATED_COMPUTE
from repro.federated.study import Study


def _problems():
    return [
        kkt.DelayProblem(T_cm=t, g=g, M=m, eps=e, nu=2.0, c=4.0)
        for t in (0.01, 0.5, 3.0)
        for g in (1e-4, 2e-3)
        for m in (2, 10, 64)
        for e in (0.01, 0.1)
    ]


@pytest.mark.parametrize("method", ["closed_form", "numerical", "corrected"])
def test_solve_batch_bit_identical(method):
    probs = _problems()
    for p, sb in zip(probs, kkt.solve_batch(probs, method=method)):
        ss = kkt.solve(p, method=method)
        assert float(sb.b) == float(ss.b)
        assert float(sb.alpha) == float(ss.alpha)
        assert sb.H == ss.H
        assert sb.T_round == ss.T_round
        assert sb.overall == ss.overall
        assert sb.V == ss.V and sb.theta == ss.theta


def test_solve_batch_empty():
    assert kkt.solve_batch([]) == []


def test_make_plan_batch_bit_identical():
    fed = FedConfig(n_devices=10, epsilon=0.01, nu=2.0, c=4.0)
    reqs = []
    for seed, het, part, K in [(0, 0.0, 1.0, None), (1, 0.4, 0.7, None),
                               (2, 0.8, 1.0, 6), (3, 0.2, 0.9, 4)]:
        pop = delay.draw_population(
            10, CALIBRATED_COMPUTE, WirelessConfig(), seed, het)
        reqs.append(defl.PlanRequest(
            fed=fed, pop=pop, update_bits=1e6, participation=part,
            cohort_size=K))
    batched = defl.make_plan_batch(reqs)
    for r, pb in zip(reqs, batched):
        ps = defl.make_plan(r.fed, r.pop, r.update_bits,
                            wireless=r.wireless, method=r.method,
                            participation=r.participation,
                            cohort_size=r.cohort_size)
        assert pb.b == ps.b and pb.V == ps.V
        assert pb.theta == ps.theta
        assert pb.H_pred == ps.H_pred
        assert pb.T_cm == ps.T_cm and pb.T_cp == ps.T_cp
        assert pb.overall_pred == ps.overall_pred
        assert pb.solution.alpha == ps.solution.alpha
        assert pb.problem == ps.problem


def test_study_plans_match_scalar():
    """Study.plans() (one vectorized KKT dispatch for the batchable
    arms, scalar fallback for fixed/deadline arms) agrees exactly with
    per-arm analytic_plan() across the registry's plan regimes."""
    arms = [
        ("defl", experiment.get("mnist_paper")),
        ("storm", experiment.get("mnist_storm")),  # scenario, no deadline
        ("fedavg", experiment.get("mnist_paper").replace(
            plan=False, label="fedavg")),          # fixed_plan fallback
        ("smoke", experiment.get("mnist_smoke")),  # plan=False
    ]
    st = Study(arms=arms, seeds=(0,))
    batched = st.plans()
    for label, spec in arms:
        scalar = spec.analytic_plan()
        got = batched[label]
        assert got.b == scalar.b and got.V == scalar.V
        assert got.theta == scalar.theta
        assert got.H_pred == scalar.H_pred
        assert got.overall_pred == scalar.overall_pred


def test_plan_request_routing():
    assert experiment.get("mnist_paper").plan_request() is not None
    assert experiment.get("mnist_smoke").plan_request() is None  # plan=False
    # deadline-fault scenario re-derives over the truncated model: scalar
    deadline_spec = experiment.get("mnist_paper").replace(
        scenario="unreliable_edge")
    assert deadline_spec.plan_request() is None


def test_async_plan_model():
    fed = FedConfig(n_devices=10, epsilon=0.01, nu=2.0, c=4.0)
    pop = delay.draw_population(
        10, CALIBRATED_COMPUTE, WirelessConfig(), 0, 0.4)
    plan = defl.async_plan(fed, pop, 1e6, buffer_size=4)
    assert plan.solution.method == "async_grid"
    assert plan.problem.M == 4  # expected concurrency replaces M_eff
    assert plan.b >= 1 and plan.V >= 1
    assert plan.overall_pred == plan.H_pred * plan.T_round
    # T_agg is K over the harmonic sum of service spans at (b*, V*):
    t_cm_m = delay.per_client_uplink_time(
        1e6, WirelessConfig(), pop.p, pop.h)
    slopes = np.asarray(pop.G, np.float64) / np.asarray(pop.f, np.float64)
    spans = plan.V * slopes * plan.b + t_cm_m
    T_agg = 4 / float(np.sum(1.0 / spans))
    np.testing.assert_allclose(plan.T_round, T_agg, rtol=1e-12)
    # the swept point is optimal over the quantized decision space: no
    # probed (b, alpha) beats it under the async objective J = H * T_agg
    best_J = plan.H_pred * plan.T_round
    for b in (1.0, 4.0, 16.0, 64.0):
        for alpha in np.geomspace(1.0 / fed.nu, 20.0, 96):
            V = max(int(round(fed.nu * alpha)), 1)
            spans = V * slopes * b + t_cm_m
            T = 4 / float(np.sum(1.0 / spans))
            H = kkt.communication_rounds_alpha(
                b, alpha, 4, fed.epsilon, fed.nu, fed.c)
            assert H * T >= best_J * (1.0 - 1e-12)
    with pytest.raises(ValueError, match="buffer_size"):
        defl.async_plan(fed, pop, 1e6, buffer_size=11)
