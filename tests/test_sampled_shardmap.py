"""Client-axis sharding (shard_map over a ("clients",) mesh) must agree
with the unsharded scan backend. XLA's virtual-device flag has to be set
before JAX initializes, so the comparison runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=2 (same pattern as
test_dryrun_subprocess.py) — this process keeps its real single device.

Aggregation order differs between the in-graph allreduce (one jnp.sum
over the stacked client axis) and the psum of per-shard partials, so
params/losses are compared to float tolerance; the host-side clock and
participation accounting is unaffected by sharding and must match
exactly.
"""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CHILD = r"""
import jax
import jax.numpy as jnp
import numpy as np

assert jax.device_count() == 2, jax.devices()

from repro.configs.base import FedConfig
from repro.core import delay
from repro.federated import scenarios
from repro.federated.simulation import Simulator
from repro.optim import sgd


def quad_loss(params, batch):
    diff = params["w"] - batch["target"]
    return 0.5 * jnp.sum(diff * diff), {}


class TargetIterator:
    def __init__(self, target, batch_size):
        self.target = np.asarray(target, np.float32)
        self.batch_size = batch_size

    def next_batch(self):
        return {"target": np.tile(self.target, (self.batch_size, 1))}


def make(shard, K=None, M=6):
    d, b = 16, 2
    fed = FedConfig(n_devices=M, batch_size=b, lr=0.05, seed=0)
    scen = scenarios.get("dropout")
    pop = scen.population(M, seed=0)
    iters = [TargetIterator(np.linspace(0.0, m, d) * 0.1, b)
             for m in range(M)]
    return Simulator(
        quad_loss, {"w": jnp.zeros(d)}, iters, 10 * np.arange(1, M + 1),
        fed, sgd(fed.lr), pop, backend="scan", scenario=scen,
        cohort=K, shard_clients=shard)


def run(sim):
    _, res = sim.run(sim.init(), max_rounds=5, eval_every=2)
    return res


for K in (None, 4):
    ref, shd = run(make(False, K)), run(make(True, K))
    for a, b in zip(jax.tree.leaves(ref.params),
                    jax.tree.leaves(shd.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    for x, y in zip(ref.history, shd.history):
        np.testing.assert_allclose(x.train_loss, y.train_loss,
                                   rtol=1e-5, atol=1e-6)
        assert x.sim_time == y.sim_time
        assert x.n_participants == y.n_participants
        assert x.uplink_bits == y.uplink_bits
    print(f"SHARD_PARITY_OK K={K}")
"""


def test_shardmap_parity_two_virtual_devices():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=2")
    out = subprocess.run([sys.executable, "-c", _CHILD], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "SHARD_PARITY_OK K=None" in out.stdout
    assert "SHARD_PARITY_OK K=4" in out.stdout
