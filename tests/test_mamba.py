"""Mamba-1/2 unit tests: chunked-vs-sequential scan equivalence, conv
causality, decode-vs-forward consistency (fp32)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SSMConfig
from repro.kernels.selective_scan.ref import (
    selective_scan_ref,
    selective_scan_sequential,
)
from repro.models import mamba as m1
from repro.models import mamba2 as m2


def _scan_inputs(key, B=2, S=48, D=32, N=8):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, D))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, D))) * 0.2
    A = -jnp.exp(jax.random.normal(ks[2], (D, N)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    Dskip = jnp.ones((D,))
    return x, dt, A, Bm, Cm, Dskip


@pytest.mark.parametrize("chunk", [8, 16, 48, 64])
def test_chunked_scan_matches_sequential(key, chunk):
    args = _scan_inputs(key)
    y0, h0 = selective_scan_sequential(*args)
    y1, h1 = selective_scan_ref(*args, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), atol=2e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h0), atol=2e-5)


def test_scan_carries_initial_state(key):
    args = _scan_inputs(key, S=16)
    h_init = jax.random.normal(jax.random.fold_in(key, 9),
                               (2, 32, 8)) * 0.5
    y0, h0 = selective_scan_sequential(*args, h0=h_init)
    y1, h1 = selective_scan_ref(*args, chunk=8, h0=h_init)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), atol=2e-5)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h0), atol=2e-5)


def test_causal_conv_is_causal(key):
    B, S, D, K = 1, 10, 4, 4
    x = jax.random.normal(key, (B, S, D))
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, D))
    b = jnp.zeros((D,))
    y = m1.causal_conv1d(x, w, b)
    # Perturb the future: outputs at earlier positions must not change.
    x2 = x.at[:, 5:].add(100.0)
    y2 = m1.causal_conv1d(x2, w, b)
    np.testing.assert_allclose(np.asarray(y[:, :5]), np.asarray(y2[:, :5]),
                               atol=1e-5)
    assert float(jnp.max(jnp.abs(y[:, 5:] - y2[:, 5:]))) > 1.0


def _decode_consistency(cfg_ssm, init_fn, fwd_fn, step_fn, cache_fn, key,
                        d_model=32, S=24, atol=2e-3):
    p = init_fn(key, d_model, cfg_ssm)
    B = 2
    x = jax.random.normal(key, (B, S, d_model))
    full = fwd_fn(p, x, cfg_ssm)
    cache = cache_fn(B, d_model, cfg_ssm)
    outs = []
    for t in range(S):
        o, cache = step_fn(p, x[:, t : t + 1], cfg_ssm, cache)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=atol)


def test_mamba1_decode_matches_forward(key):
    cfg = SSMConfig(kind="mamba1", d_state=8, d_conv=4, expand=2, chunk=8)
    _decode_consistency(cfg, m1.init_mamba1, m1.mamba1_forward,
                        m1.mamba1_decode_step, m1.init_mamba1_cache, key)


def test_mamba2_decode_matches_forward(key):
    cfg = SSMConfig(kind="mamba2", d_state=8, d_conv=4, expand=2,
                    head_dim=16, chunk=8)
    _decode_consistency(cfg, m2.init_mamba2, m2.mamba2_forward,
                        m2.mamba2_decode_step, m2.init_mamba2_cache, key,
                        atol=5e-3)


def test_ssd_chunk_invariance(key):
    B, S, H, P, N = 1, 32, 2, 8, 4
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))) * 0.2
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(jax.random.fold_in(key, 9), (B, S, N))
    y8, h8 = m2.ssd_chunked(x, dt, A, Bm, Cm, chunk=8)
    y32, h32 = m2.ssd_chunked(x, dt, A, Bm, Cm, chunk=32)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y32), atol=2e-4)
    np.testing.assert_allclose(np.asarray(h8), np.asarray(h32), atol=2e-4)
