"""Event-queue invariants for backend='async' (federated/events.py +
mesh_rounds.build_async_chunk): monotone event clock, update
conservation, mid-buffer checkpoint bit-identity, scan-vs-Python-
reference parity, the synchronous-limit identity, and the knob
compatibility contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ComputeConfig, FedConfig, WirelessConfig
from repro.core import delay
from repro.federated import events, mesh_rounds
from repro.federated.events import AsyncSpec
from repro.federated.simulation import Simulator, load_state, save_state
from repro.optim import sgd


def _quad_loss(params, batch):
    diff = params["w"] - batch["target"]
    return 0.5 * jnp.sum(diff * diff), {}


class _TargetIterator:
    def __init__(self, target, batch_size):
        self.target = np.asarray(target, np.float32)
        self.batch_size = batch_size

    def next_batch(self):
        return {"target": np.tile(self.target, (self.batch_size, 1))}


_M, _D, _B = 4, 16, 2
_SIZES = np.array([10, 20, 30, 40])


def _quad_sim(backend, spec=None, scenario=None, seed=0, heterogeneity=0.3,
              **kw):
    fed = FedConfig(n_devices=_M, batch_size=_B, lr=0.05, seed=seed)
    pop = delay.draw_population(
        _M, ComputeConfig(), WirelessConfig(), seed, heterogeneity)

    def iters(s):
        return [_TargetIterator(np.linspace(0.0, m, _D) * 0.1, _B)
                for m in range(_M)]

    return Simulator(
        _quad_loss, {"w": jnp.zeros(_D)}, iters, _SIZES, fed, sgd(fed.lr),
        pop, backend=backend, async_spec=spec, scenario=scenario, **kw)


# ---------------------------------------------------------------------------
# AsyncSpec value contract
# ---------------------------------------------------------------------------

def test_async_spec_validation():
    with pytest.raises(ValueError, match="buffer_size"):
        AsyncSpec(buffer_size=0)
    with pytest.raises(ValueError, match="staleness"):
        AsyncSpec(buffer_size=2, staleness="bogus")
    with pytest.raises(ValueError, match="mode"):
        AsyncSpec(buffer_size=2, mode="bogus")
    spec = AsyncSpec(buffer_size=2).replace(staleness="exp", staleness_a=0.3)
    assert spec.staleness == "exp" and spec.staleness_a == 0.3


def test_staleness_weights():
    spec_c = AsyncSpec(buffer_size=2, staleness="constant")
    spec_p = AsyncSpec(buffer_size=2, staleness="poly", staleness_a=0.5)
    spec_e = AsyncSpec(buffer_size=2, staleness="exp", staleness_a=0.5)
    s = np.arange(4, dtype=np.float32)
    np.testing.assert_array_equal(
        events.staleness_weight(spec_c, s, np), np.ones(4, np.float32))
    np.testing.assert_allclose(
        events.staleness_weight(spec_p, s, np), (1.0 + s) ** -0.5,
        rtol=1e-6)
    np.testing.assert_allclose(
        events.staleness_weight(spec_e, s, np), np.exp(-0.5 * s), rtol=1e-6)
    # fresh updates carry full weight under every discipline
    for spec in (spec_c, spec_p, spec_e):
        assert float(events.staleness_weight(
            spec, np.zeros(1, np.float32), np)[0]) == 1.0


# ---------------------------------------------------------------------------
# Event-clock invariants
# ---------------------------------------------------------------------------

def test_event_times_monotone_and_trace_count():
    sim = _quad_sim("async", AsyncSpec(buffer_size=2, staleness="poly"))
    st, res = sim.run(sim.init(), max_rounds=6)
    times = [r.sim_time for r in res.history]
    assert len(times) == 6
    assert all(b >= a for a, b in zip(times, times[1:]))
    assert sim.trace_count == 1
    # continuation reuses the compiled chunk and the clock keeps running
    _, res2 = sim.run(st, max_rounds=3)
    assert sim.trace_count == 1
    assert res2.history[0].round == 7
    assert res2.history[0].sim_time >= times[-1]


def test_update_conservation_reference():
    """Every event is consumed exactly once: dropped, or buffered — and
    each aggregation consumes exactly buffer_size buffered updates."""
    K = 2
    spec = AsyncSpec(buffer_size=K, staleness="poly")
    sim = _quad_sim("async", spec, scenario="dropout", seed=3)
    stream = sim.scenario.stream(sim.pop, 3)
    local = jax.jit(mesh_rounds.local_steps_fn(_quad_loss, sim.opt))
    iters = [_TargetIterator(np.linspace(0.0, m, _D) * 0.1, _B)
             for m in range(_M)]

    def next_batches(c):
        bs = [iters[c].next_batch() for _ in range(sim.fed.local_rounds)]
        return jax.tree.map(lambda *x: np.stack(x), *bs)

    def draw_dispatch():
        t_svc, drop, _, _ = sim._async_dispatch_draw(stream)
        return t_svc, drop

    _, evs = events.reference_run(
        spec, 24, jax.device_get(sim._init_params),
        sim.opt.init(sim._init_params), lambda p, s, b: local(p, s, b),
        next_batches, _SIZES, draw_dispatch)
    accepted = 0
    for e in evs:
        assert isinstance(e["dropped"], bool)
        if e["dropped"]:
            assert not e["aggregated"]  # a dropped update never aggregates
        else:
            accepted += 1
        if e["aggregated"]:
            assert accepted % K == 0  # fills consume exactly K updates
    n_aggs = sum(1 for e in evs if e["aggregated"])
    assert n_aggs == accepted // K
    assert accepted - K * n_aggs < K  # leftover buffer is partial


def test_scan_matches_python_reference():
    spec = AsyncSpec(buffer_size=2, staleness="poly", staleness_a=0.7)
    sim = _quad_sim("async", spec, seed=3)
    st = sim.init()
    stream = sim.scenario.stream(sim.pop, 3)
    local = jax.jit(mesh_rounds.local_steps_fn(_quad_loss, sim.opt))
    iters = [_TargetIterator(np.linspace(0.0, m, _D) * 0.1, _B)
             for m in range(_M)]

    def next_batches(c):
        bs = [iters[c].next_batch() for _ in range(sim.fed.local_rounds)]
        return jax.tree.map(lambda *x: np.stack(x), *bs)

    def draw_dispatch():
        t_svc, drop, _, _ = sim._async_dispatch_draw(stream)
        return t_svc, drop

    n_ev = 11
    p_ref, evs = events.reference_run(
        spec, n_ev, jax.device_get(sim._init_params),
        sim.opt.init(sim._init_params), lambda p, s, b: local(p, s, b),
        next_batches, _SIZES, draw_dispatch)
    st2, hist = sim.run_events(st, n_ev)
    p_scan = jax.device_get(sim.params(st2))
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_scan)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-6)
    assert sum(1 for e in evs if e["aggregated"]) == len(hist)


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def test_mid_buffer_checkpoint_bit_identity(tmp_path):
    """Stopping after an event count that strands updates mid-buffer,
    round-tripping through save_state/load_state, and continuing is
    bit-identical to the uninterrupted run."""
    spec = AsyncSpec(buffer_size=2, staleness="poly")
    sim_a = _quad_sim("async", spec, seed=1)
    st_a, res_a = sim_a.run(sim_a.init(), max_rounds=8)

    sim_b = _quad_sim("async", spec, seed=1)
    st_b, hist_b = sim_b.run_events(sim_b.init(), 5)  # odd: mid-buffer
    path = str(tmp_path / "async_ck.pkl")
    save_state(path, st_b)
    st_b = load_state(path, like=st_b)
    st_b, res_b = sim_b.run(st_b, max_rounds=8 - len(hist_b))

    pa = jax.device_get(sim_a.params(st_a))
    pb = jax.device_get(sim_b.params(st_b))
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        np.testing.assert_array_equal(a, b)
    la = [r.train_loss for r in res_a.history]
    lb = ([r.train_loss for r in hist_b]
          + [r.train_loss for r in res_b.history])
    np.testing.assert_array_equal(la, lb)
    ta = [r.sim_time for r in res_a.history]
    tb = ([r.sim_time for r in hist_b]
          + [r.sim_time for r in res_b.history])
    np.testing.assert_array_equal(ta, tb)


# ---------------------------------------------------------------------------
# Synchronous limit
# ---------------------------------------------------------------------------

def test_sync_limit_identity():
    """AsyncSpec(buffer_size=M, staleness='constant') on the uniform
    scenario reproduces the synchronous scan trajectory: under
    ack-at-aggregation the buffer fills with exactly one update per
    client, all dispatched from the same global model — FedAvg. The
    association (delta accumulation vs direct weighted mean) differs at
    the ulp level in principle, hence allclose rather than array_equal;
    in practice the shipped configuration reproduces bitwise."""
    spec = AsyncSpec(buffer_size=_M, staleness="constant")
    sim_a = _quad_sim("async", spec, scenario="uniform", seed=2)
    st_a, res_a = sim_a.run(sim_a.init(), max_rounds=5)
    sim_s = _quad_sim("scan", scenario="uniform", seed=2)
    st_s, res_s = sim_s.run(sim_s.init(), max_rounds=5)
    la = [r.train_loss for r in res_a.history]
    ls = [r.train_loss for r in res_s.history]
    np.testing.assert_allclose(la, ls, rtol=2e-5, atol=1e-6)
    pa = jax.device_get(sim_a.params(st_a))
    ps = jax.device_get(sim_s.params(st_s))
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(ps)):
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=1e-6)
    # every aggregation saw the full population
    assert all(r.n_participants == _M for r in res_a.history)


def test_fedasync_differs_from_fedbuff():
    base = dict(scenario=None, seed=0)
    r_buf = _quad_sim("async", AsyncSpec(buffer_size=1, mode="fedbuff"),
                      **base)
    r_asy = _quad_sim("async", AsyncSpec(buffer_size=1, mode="fedasync",
                                         server_lr=0.5), **base)
    st_b, _ = r_buf.run(r_buf.init(), max_rounds=4)
    st_a, _ = r_asy.run(r_asy.init(), max_rounds=4)
    pb = jax.device_get(r_buf.params(st_b))["w"]
    pa = jax.device_get(r_asy.params(st_a))["w"]
    assert not np.allclose(pb, pa)


# ---------------------------------------------------------------------------
# Fault composition
# ---------------------------------------------------------------------------

def test_async_composes_with_faults():
    from repro.federated.faults import FaultModel
    spec = AsyncSpec(buffer_size=2, staleness="poly")
    sim = _quad_sim("async", spec, scenario="unreliable_edge", seed=4)
    assert sim._faults is not None
    st, res = sim.run(sim.init(), max_rounds=5)
    assert len(res.history) == 5
    times = [r.sim_time for r in res.history]
    assert all(b >= a for a, b in zip(times, times[1:]))
    # retransmission accounting: uplink bits accumulate per attempt
    assert all(r.uplink_bits > 0 for r in res.history)
    # quorum gating is named in the rejection
    fm = FaultModel(deadline_factor=1.5, min_quorum=3)
    with pytest.raises(ValueError, match="min_quorum"):
        _quad_sim("async", spec, scenario="uniform", faults=fm)


# ---------------------------------------------------------------------------
# Spec / Study integration
# ---------------------------------------------------------------------------

def test_async_arm_in_study():
    """An async ExperimentSpec builds, runs solo inside a Study next to
    a synchronous arm, and surfaces its aggregation regime in the
    table/JSON emits."""
    from repro.federated.experiment import ExperimentSpec
    from repro.federated.study import Study
    sync = ExperimentSpec(
        fed=FedConfig(n_devices=3, batch_size=8, theta=0.62, lr=0.05),
        model="mnist_cnn_small", dataset="mnist", n_train=96, n_test=48,
        label="sync")
    asyn = sync.replace(
        backend="async",
        async_spec=AsyncSpec(buffer_size=2, staleness="poly"),
        label="asyn")
    res = Study(arms=[("sync", sync), ("asyn", asyn)], seeds=(0,),
                max_rounds=3).run()
    assert ("asyn",) in res.groups  # async arms run solo, never grouped
    assert res.async_modes == {"sync": None, "asyn": "fedbuff/K=2/poly"}
    header, rows = res.table()
    assert ",agg," in header
    by_label = {r[0]: r for r in rows}
    assert by_label["sync"][4] == "sync"
    assert by_label["asyn"][4] == "fedbuff/K=2/poly"
    assert res.to_json()["arms"]["asyn"]["async"] == "fedbuff/K=2/poly"
    assert len(res["asyn"][0].history) == 3


def test_spec_knob_validation():
    """Satellite contract: mutually-exclusive ExperimentSpec knobs fail
    at construction, naming the offending fields."""
    from repro.federated.experiment import (CohortSpec, ExperimentSpec,
                                            PopulationSpec)
    from repro.federated.faults import FaultModel
    spec = AsyncSpec(buffer_size=2)
    with pytest.raises(ValueError, match="async_spec"):
        ExperimentSpec(backend="async")
    with pytest.raises(ValueError, match="backend"):
        ExperimentSpec(async_spec=spec)
    with pytest.raises(ValueError, match="population.cohort"):
        ExperimentSpec(backend="async", async_spec=spec,
                       population=PopulationSpec(M=40,
                                                 cohort=CohortSpec(K=8)))
    with pytest.raises(ValueError, match="shard_clients"):
        ExperimentSpec(backend="async", async_spec=spec, shard_clients=True)
    with pytest.raises(ValueError, match="min_quorum"):
        ExperimentSpec(backend="async", async_spec=spec,
                       faults=FaultModel(deadline_factor=1.5, min_quorum=3))
    with pytest.raises(ValueError, match="max_update_norm"):
        ExperimentSpec(backend="async", async_spec=spec,
                       faults=FaultModel(deadline_factor=1.5,
                                         max_update_norm=1.0))
    # deadline/retransmission/crash channels DO compose
    ok = ExperimentSpec(backend="async", async_spec=spec,
                        faults=FaultModel(deadline_factor=1.5,
                                          max_retries=2))
    assert ok.effective_faults() is not None


# ---------------------------------------------------------------------------
# Knob compatibility contract (Simulator level)
# ---------------------------------------------------------------------------

def test_async_knob_validation():
    spec = AsyncSpec(buffer_size=2)
    with pytest.raises(ValueError, match="async_spec"):
        _quad_sim("async", None)
    with pytest.raises(ValueError, match="backend"):
        _quad_sim("scan", spec)
    with pytest.raises(ValueError, match="buffer_size"):
        _quad_sim("async", AsyncSpec(buffer_size=_M + 1))
    sim = _quad_sim("async", spec)
    with pytest.raises(ValueError, match="run_events"):
        sim.run_round(sim.init())
