"""CI plumbing: the workflow file is valid and wired to scripts/tier1.sh,
and tier1.sh propagates pytest's exit code / forwards extra args (the
'act-style dry check' of the CI pipeline, minus the network)."""
import os
import subprocess

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
WORKFLOW = os.path.join(REPO, ".github", "workflows", "ci.yml")
TIER1 = os.path.join(REPO, "scripts", "tier1.sh")


def _load_workflow():
    yaml = pytest.importorskip("yaml")
    with open(WORKFLOW) as f:
        return yaml.safe_load(f)


def test_workflow_parses_and_has_jobs():
    wf = _load_workflow()
    assert wf["name"] == "ci"
    jobs = wf["jobs"]
    for job in ("lint", "tier1", "bench-smoke", "chaos-smoke", "slow"):
        assert job in jobs, f"missing job {job}"
        assert "runs-on" in jobs[job]
        steps = jobs[job]["steps"]
        assert any("checkout" in str(s.get("uses", "")) for s in steps)


def test_workflow_triggers():
    wf = _load_workflow()
    # pyyaml parses the `on:` key as boolean True (YAML 1.1).
    on = wf.get("on", wf.get(True))
    assert "pull_request" in on
    assert "workflow_dispatch" in on
    assert "schedule" in on and on["schedule"][0]["cron"]


def test_workflow_jobs_share_tier1_entrypoint():
    wf = _load_workflow()
    jobs = wf["jobs"]

    def runs(job):
        return " && ".join(s.get("run", "") for s in jobs[job]["steps"])

    assert "scripts/tier1.sh" in runs("tier1")
    # Nightly/dispatch job includes the slow markers via the same script.
    assert 'tier1.sh -m ""' in runs("slow")
    sched = jobs["slow"]["if"]
    assert "schedule" in sched and "workflow_dispatch" in sched
    # Default jobs must NOT run on the nightly schedule.
    for job in ("lint", "tier1", "bench-smoke", "chaos-smoke"):
        assert "schedule" in jobs[job]["if"]
    # Chaos smoke runs the slow-marked SIGKILL/resume test explicitly.
    chaos = runs("chaos-smoke")
    assert "test_chaos_resume.py" in chaos and '-m ""' in chaos
    # Bench smoke guards the batched-vs-loop speedup and keeps an artifact.
    smoke = runs("bench-smoke")
    assert "bench_round_step.py" in smoke and "--check" in smoke
    # ...and the grouped-study-vs-sequential gate, with its StudyResult
    # JSON uploaded alongside the timing rows.
    assert "bench_study.py" in smoke
    # ...and the async-vs-sync quick sweep (PR 9), whose StudyResult JSON
    # joins the artifact next to the event-engine gates inside --check.
    assert "async_vs_sync.py" in smoke and "--quick" in smoke
    # ...and the PR 10 planner gates: batched plan queries vs the
    # sequential loop (bench_planner --check) plus the replanning demo's
    # beats-worst-fixed-plan bar, regret report JSON as an artifact.
    assert "bench_planner.py" in smoke
    assert "planner_service_demo.py" in smoke
    uploads = [s for s in jobs["bench-smoke"]["steps"]
               if "upload-artifact" in str(s.get("uses", ""))]
    assert uploads
    paths = " ".join(str(s["with"]["path"]) for s in uploads)
    assert "study_smoke.json" in paths and "bench_smoke.json" in paths
    assert "async_smoke.json" in paths
    assert "planner_bench.json" in paths and "planner_smoke.json" in paths


def test_workflow_caches_jax_install_keyed_on_pin():
    """Every wheel-installing job restores a venv via actions/cache keyed
    on the JAX_PIN env var, installs only on a cache miss, and pins the
    jax[cpu] wheel to JAX_PIN — so bumping the pin invalidates every job's
    cache at once and a warm run skips the install entirely."""
    wf = _load_workflow()
    assert wf["env"]["JAX_PIN"]
    for job in ("tier1", "bench-smoke", "chaos-smoke", "slow"):
        steps = wf["jobs"][job]["steps"]
        caches = [s for s in steps if "actions/cache" in str(s.get("uses", ""))]
        assert caches, f"{job}: no actions/cache step"
        key = caches[0]["with"]["key"]
        assert "env.JAX_PIN" in key, f"{job}: cache key not on the JAX pin"
        installs = [s for s in steps
                    if "pip install" in s.get("run", "") and "jax" in s["run"]]
        assert installs, f"{job}: no jax install step"
        assert "cache-hit" in str(installs[0].get("if", "")), (
            f"{job}: install must be skipped on a cache hit")
        assert "JAX_PIN" in installs[0]["run"], f"{job}: wheel not pinned"


def _tier1(*args):
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    return subprocess.run(["bash", TIER1, *args], env=env,
                          capture_output=True, text=True, timeout=600)


def test_tier1_propagates_failure_exit_code():
    """With a -k filter that matches nothing, collect-only exits 5 (pytest
    'no tests collected') — tier1.sh must forward a nonzero code, not
    swallow it. Also proves extra args reach pytest."""
    out = _tier1("--collect-only", "-k", "zz_no_such_test_zz", "-q")
    assert out.returncode != 0, out.stdout + out.stderr


def test_tier1_zero_exit_on_success():
    """Collect-only over one fast file: arg passthrough narrows the run and
    a successful pytest yields exit 0 through the script."""
    out = _tier1("--collect-only", "-q", "tests/test_kkt.py")
    assert out.returncode == 0, out.stdout + out.stderr
