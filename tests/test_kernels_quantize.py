"""Quantize kernel: sweep vs jnp oracle; determinism; error bounds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.quantize import ops as q_ops
from repro.kernels.quantize.ref import dequantize_ref, quantize_ref


@pytest.mark.parametrize("R,D", [(256, 128), (300, 1024), (8, 64)])
def test_quantize_matches_ref(key, R, D):
    x = jax.random.normal(key, (R, D)) * 0.05
    q_k, s_k = q_ops.quantize(x, key)
    q_r, s_r = quantize_ref(x, key)
    # identical PRNG stream + identical math -> bit-identical
    np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_r))
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-6)


def test_dequantize_error_bound(key):
    x = jax.random.normal(key, (64, 512))
    q, s = q_ops.quantize(x, key)
    rec = dequantize_ref(q, s)
    err = np.abs(np.asarray(rec) - np.asarray(x))
    bound = np.asarray(s) + 1e-7  # one step of stochastic rounding
    assert (err <= bound).all()


def test_quantize_zero_rows(key):
    x = jnp.zeros((16, 128))
    q, s = q_ops.quantize(x, key)
    assert np.asarray(s).min() > 0  # guarded scale
    rec = dequantize_ref(q, s)
    # stochastic rounding of exact 0/scale = floor(0 + u) is 0 except u=1-eps
    assert np.abs(np.asarray(rec)).max() <= np.asarray(s).max()


def test_int8_range(key):
    x = jax.random.normal(key, (32, 256)) * 100
    q, s = q_ops.quantize(x, key)
    assert np.asarray(q).min() >= -127 and np.asarray(q).max() <= 127
