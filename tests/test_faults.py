"""Fault-injection & recovery layer (federated/faults.py): deadline
truncation of the Eq. 8 clock, retransmission time/bits accounting,
crash/rejoin lifecycle, divergence guards, and the invariant everything
rests on — an inactive FaultModel is bit-identical to no FaultModel, and
an active one keeps scan == batched bit-for-bit through one trace."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ComputeConfig, FedConfig, WirelessConfig
from repro.core import delay, defl
from repro.federated import scenarios
from repro.federated.faults import DivergenceError, FaultModel
from repro.federated.simulation import (SimState, Simulator, load_state,
                                        save_state)
from repro.optim import sgd


def _quad_loss(params, batch):
    diff = params["w"] - batch["target"]
    return 0.5 * jnp.sum(diff * diff), {}


class _TargetIterator:
    """Batch source WITHOUT the index protocol (generic pre-stacked data
    path on the scan backend)."""

    def __init__(self, target, batch_size):
        self.target = np.asarray(target, np.float32)
        self.batch_size = batch_size

    def next_batch(self):
        return {"target": np.tile(self.target, (self.batch_size, 1))}


def _quad_sim(backend, scenario=None, faults=None, compress=True,
              momentum=0.9, seed=0, targets=None):
    M, d, b = 4, 16, 2
    fed = FedConfig(n_devices=M, batch_size=b, lr=0.05, seed=seed,
                    compress_updates=compress)
    scen = scenarios.get(scenario) if scenario is not None else None
    pop = (scen.population(M, seed=seed) if scen is not None else
           delay.draw_population(M, ComputeConfig(), WirelessConfig(), 0, 0.0))
    if targets is None:
        targets = [np.linspace(0.0, m, d) * 0.1 for m in range(M)]
    iters = [_TargetIterator(t, b) for t in targets]
    return Simulator(
        _quad_loss, {"w": jnp.zeros(d)}, iters,
        np.array([10, 20, 30, 40]), fed, sgd(fed.lr, momentum), pop,
        backend=backend, scenario=scen, faults=faults)


def _run(sim, **kw):
    _, res = sim.run(sim.init(), **kw)
    return res


def _assert_bit_identical(res_scan, res_batched):
    for a, b in zip(jax.tree.leaves(res_batched.params),
                    jax.tree.leaves(res_scan.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for rb, rs in zip(res_batched.history, res_scan.history):
        assert rb.round == rs.round
        np.testing.assert_array_equal(rb.train_loss, rs.train_loss)
        assert rb.sim_time == rs.sim_time
        assert rb.T_cm == rs.T_cm and rb.T_cp == rs.T_cp
        assert rb.n_participants == rs.n_participants
        assert rb.uplink_bits == rs.uplink_bits
    assert len(res_batched.history) == len(res_scan.history)


def _durations(res):
    times = [r.sim_time for r in res.history]
    return np.diff([0.0] + times)


# ---------------------------------------------------------------------------
# FaultModel: activation, validation, derived quantities
# ---------------------------------------------------------------------------


def test_fault_model_activation_flags():
    assert FaultModel().active is False
    # the guards alone don't activate (they're on whenever ANY fault is)
    assert FaultModel(reject_nonfinite=False).active is False
    assert FaultModel(divergence_guard=False).active is False
    assert FaultModel(max_retries=1).active is True
    assert FaultModel(deadline=1.0).active is True
    assert FaultModel(deadline_factor=2.0).active is True
    assert FaultModel(crash_rate=0.1).active is True
    assert FaultModel(max_update_norm=1.0).active is True
    assert FaultModel(max_retries=2).n_attempts == 3


@pytest.mark.parametrize("bad", [
    dict(deadline=0.0),
    dict(deadline_factor=-1.0),
    dict(deadline=1.0, deadline_factor=1.5),
    dict(max_retries=-1),
    dict(backoff_base=-0.1),
    dict(backoff_factor=0.5),
    dict(crash_rate=1.0),
    dict(crash_rate=-0.1),
    dict(rejoin_rounds=0),
    dict(max_update_norm=0.0),
])
def test_fault_model_validate_rejects(bad):
    with pytest.raises(ValueError):
        FaultModel(**bad).validate()


def test_fault_model_resolve_deadline_and_guard_spec():
    assert FaultModel().resolve_deadline(3.0) is None
    assert FaultModel(deadline=2.5).resolve_deadline(3.0) == 2.5
    assert FaultModel(deadline_factor=1.5).resolve_deadline(3.0) == 4.5
    assert FaultModel().guard_spec() == (float("inf"), True)
    assert FaultModel(max_update_norm=0.1,
                      reject_nonfinite=False).guard_spec() == (0.1, False)


def test_expected_participation_composes_fault_knobs():
    scen = scenarios.get("unreliable_edge")
    fm = scen.faults
    assert fm is not None and fm.active
    want = (fm.availability() * (1.0 - scen.dropout)
            * fm.link_success(scen.link_failure))
    assert scen.expected_participation == pytest.approx(want)
    # and the legacy formula when no faults are attached
    plain = scenarios.get("dropout")
    assert plain.expected_participation == pytest.approx(
        (1 - plain.dropout) * (1 - plain.link_failure))


# ---------------------------------------------------------------------------
# Inactive model == no model (bit-identical)
# ---------------------------------------------------------------------------


def test_inactive_fault_model_is_bit_identical():
    ref = _run(_quad_sim("scan", "hetero_storm"), max_rounds=5, eval_every=2)
    res = _run(_quad_sim("scan", "hetero_storm", faults=FaultModel()),
               max_rounds=5, eval_every=2)
    _assert_bit_identical(res, ref)


def test_faults_without_scenario_overlay_uniform():
    sim = _quad_sim("scan", None, faults=FaultModel(max_retries=1))
    assert sim.scenario is not None and sim.scenario.name == "uniform"
    assert sim._faults is not None
    res = _run(sim, max_rounds=3)
    assert sim.trace_count == 1
    assert all(r.n_participants == 4 for r in res.history)


# ---------------------------------------------------------------------------
# Deadline-bounded rounds
# ---------------------------------------------------------------------------


def test_deadline_truncates_clock_and_excludes_stragglers():
    ref = _run(_quad_sim("scan", "stragglers"), max_rounds=8, eval_every=4)
    d0 = float(_durations(ref).max())
    D = 0.75 * d0
    fm = FaultModel(deadline=D)
    sim = _quad_sim("scan", "stragglers", faults=fm)
    assert sim._deadline == pytest.approx(D)
    res = _run(sim, max_rounds=8, eval_every=4)
    assert sim.trace_count == 1
    durs = _durations(res)
    # property: no round's wall clock exceeds the deadline...
    assert np.all(durs <= D * (1 + 1e-12))
    # ...the straggler cohort misses it and is cut from aggregation...
    assert all(r.n_participants < 4 for r in res.history)
    assert all(r.n_participants >= 1 for r in res.history)
    # ...and the run finishes strictly sooner than without the deadline.
    assert res.total_time < ref.total_time
    # scan == batched bit parity on the deadline path
    rb = _run(_quad_sim("batched", "stragglers", faults=fm),
              max_rounds=8, eval_every=4)
    _assert_bit_identical(res, rb)


def test_deadline_factor_resolves_against_nominal():
    sim = _quad_sim("scan", "stragglers",
                    faults=FaultModel(deadline_factor=1.5))
    nominal = delay.round_time(*sim.round_times(), sim.fed.local_rounds)
    assert sim._deadline == pytest.approx(1.5 * nominal)


# ---------------------------------------------------------------------------
# Retransmission: time & bits accounting
# ---------------------------------------------------------------------------


def test_effective_uplink_times_accounting():
    wc = WirelessConfig()
    M, A, bits = 5, 3, 1e5
    rng = np.random.default_rng(7)
    p = np.full(M, wc.tx_power_w)
    h_att = wc.mean_channel_gain * rng.lognormal(0.0, 0.3, (M, A))
    t_att = np.stack([delay.per_client_uplink_time(bits, wc, p, h_att[:, k])
                      for k in range(A)], axis=-1)
    # one attempt == the single-shot Eq. 6 time against the attempt-0 gain
    one = delay.effective_uplink_times(bits, wc, p, h_att, np.ones(M, int))
    np.testing.assert_array_equal(one, t_att[:, 0])
    # a attempts == sum of the a airtimes + exponential backoff waits
    three = delay.effective_uplink_times(
        bits, wc, p, h_att, np.full(M, 3), backoff_base=0.1,
        backoff_factor=2.0)
    np.testing.assert_allclose(three, t_att.sum(axis=-1) + 0.1 + 0.2,
                               rtol=1e-12)
    # absent clients (0 attempts) fall back to the attempt-0 time
    zero = delay.effective_uplink_times(bits, wc, p, h_att, np.zeros(M, int))
    np.testing.assert_array_equal(zero, t_att[:, 0])


def test_effective_uplink_times_vectorized_rows_bit_identical():
    wc = WirelessConfig()
    R, M, A, bits = 4, 6, 3, 2e5
    rng = np.random.default_rng(3)
    p = np.full(M, wc.tx_power_w)
    h_att = wc.mean_channel_gain * rng.lognormal(0.0, 0.4, (R, M, A))
    attempts = rng.integers(0, A + 1, (R, M))
    stacked = delay.effective_uplink_times(
        bits, wc, p, h_att, attempts, backoff_base=0.02, backoff_factor=2.0)
    for r in range(R):
        row = delay.effective_uplink_times(
            bits, wc, p, h_att[r], attempts[r], backoff_base=0.02,
            backoff_factor=2.0)
        np.testing.assert_array_equal(stacked[r], row)


def test_retry_stream_and_uplink_bits_accounting():
    lossy = scenarios.Scenario(
        "lossy", "retry accounting fixture", link_failure=0.4)
    fm = FaultModel(max_retries=2, backoff_base=0.05)
    sim = _quad_sim("scan", lossy, faults=fm)
    state0 = sim.init()
    seed = state0.seed
    _, res = sim.run(state0, max_rounds=6, eval_every=2)
    assert sim.trace_count == 1
    bits = sim._update_bits()
    # replay the realization stream and check per-round accounting
    stream = sim.scenario.stream(sim.pop, seed)
    A = fm.n_attempts
    for rec in res.history:
        real = stream.next_round()
        # every attempt's bits hit the channel, landed or not
        assert rec.uplink_bits == pytest.approx(real.attempts.sum() * bits)
        # lifecycle invariants of the attempts vector
        assert np.all(real.attempts[~real.clock_mask] == 0)
        assert np.all(real.attempts[real.clock_mask] >= 1)
        assert np.all(real.attempts <= A)
        assert np.all(real.mask <= real.clock_mask)  # uploads need presence
        assert real.h_att.shape == (4, A)
        np.testing.assert_array_equal(real.h_att[:, 0], real.h)
    # retries actually happened at link_failure=0.4 over 6 rounds
    assert res.history[-1].uplink_bits >= 0
    # parity across backends on the retry path
    rb = _run(_quad_sim("batched", lossy, faults=fm),
              max_rounds=6, eval_every=2)
    _assert_bit_identical(res, rb)


def test_backoff_waits_match_closed_form():
    fm = FaultModel(max_retries=3, backoff_base=0.1, backoff_factor=2.0)
    np.testing.assert_allclose(
        fm.backoff_waits(np.array([0, 1, 2, 3, 4])),
        [0.0, 0.0, 0.1, 0.3, 0.7])
    assert FaultModel(max_retries=2).backoff_waits(np.array([3])).sum() == 0.0


# ---------------------------------------------------------------------------
# Crash / rejoin lifecycle
# ---------------------------------------------------------------------------


def test_crash_rejoin_epochs_span_rejoin_rounds():
    scen = scenarios.get("uniform").replace(
        faults=FaultModel(crash_rate=0.5, rejoin_rounds=3))
    pop = scen.population(4, seed=0)
    stream = scen.stream(pop, seed=0)
    present = np.stack([stream.next_round().clock_mask for _ in range(60)])
    crashes = 0
    for m in range(4):
        up = present[:, m]
        # maximal absence streaks (drop a window-truncated trailing one)
        streaks, run = [], 0
        for v in up:
            if not v:
                run += 1
            elif run:
                streaks.append(run)
                run = 0
        for s in streaks:
            assert s % 3 == 0  # epochs chain in whole rejoin_rounds units
        crashes += len(streaks)
    assert crashes > 0  # at crash_rate=0.5 over 60 rounds, certain


def test_stream_state_roundtrip_mid_crash_epoch():
    scen = scenarios.get("dropout").replace(
        faults=FaultModel(crash_rate=0.5, rejoin_rounds=4, max_retries=1))
    pop = scen.population(4, seed=0)
    a = scen.stream(pop, seed=3)
    for _ in range(5):
        a.next_round()
    snap = a.state()
    assert "down" in snap
    b = scen.stream(pop, seed=999)  # wrong seed, then restored
    b.set_state(snap)
    for _ in range(6):
        ra, rb = a.next_round(), b.next_round()
        np.testing.assert_array_equal(ra.mask, rb.mask)
        np.testing.assert_array_equal(ra.clock_mask, rb.clock_mask)
        np.testing.assert_array_equal(ra.h, rb.h)
        np.testing.assert_array_equal(ra.attempts, rb.attempts)


def test_stream_state_legacy_snapshot_without_down_counters():
    scen = scenarios.get("dropout")
    pop = scen.population(4, seed=0)
    stream = scen.stream(pop, seed=0)
    snap = stream.state()
    snap.pop("down")  # pre-fault checkpoints have no down-counters
    stream.set_state(snap)
    assert np.all(stream._down == 0)
    stream.next_round()  # still draws


# ---------------------------------------------------------------------------
# Divergence guards
# ---------------------------------------------------------------------------


def test_nonfinite_client_rejected_from_aggregation():
    targets = [np.full(16, np.nan)] + [np.linspace(0.0, m, 16) * 0.1
                                       for m in range(1, 4)]
    fm = FaultModel(max_update_norm=1e9)  # activates; reject_nonfinite on
    sim = _quad_sim("scan", None, faults=fm, targets=targets)
    res = _run(sim, max_rounds=4, eval_every=2)
    assert sim.trace_count == 1
    for r in res.history:
        assert np.isfinite(r.train_loss)
        assert r.n_participants == 3  # the NaN client is dropped in-graph
    for leaf in jax.tree.leaves(res.params):
        assert np.all(np.isfinite(np.asarray(leaf)))
    rb = _run(_quad_sim("batched", None, faults=fm, targets=targets),
              max_rounds=4, eval_every=2)
    _assert_bit_identical(res, rb)
    # loop backend mirrors the guard (tolerance parity, same accounting)
    rl = _run(_quad_sim("loop", None, faults=fm, targets=targets),
              max_rounds=4, eval_every=2)
    assert [r.n_participants for r in rl.history] == [3] * 4
    for a, b in zip(jax.tree.leaves(rl.params), jax.tree.leaves(res.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-7)


def test_max_update_norm_clips_aggregate():
    targets = [np.full(16, 10.0)] * 4
    clipped = _quad_sim(
        "scan", None, faults=FaultModel(max_update_norm=0.05),
        momentum=0.0, targets=targets)
    res = _run(clipped, max_rounds=1)
    norm = float(np.linalg.norm(np.asarray(res.params["w"])))
    # the aggregate is a convex combination of per-client clipped deltas
    assert norm <= 0.05 * (1 + 1e-5)
    free = _run(_quad_sim("scan", None, momentum=0.0, targets=targets),
                max_rounds=1)
    assert float(np.linalg.norm(np.asarray(free.params["w"]))) > 0.05


@pytest.mark.parametrize("backend", ["scan", "batched"])
def test_divergence_error_carries_last_good_state(backend):
    targets = [np.full(16, np.nan)] * 4
    fm = FaultModel(max_update_norm=1e9, reject_nonfinite=False)
    sim = _quad_sim(backend, None, faults=fm, targets=targets)
    with pytest.raises(DivergenceError) as ei:
        sim.run(sim.init(), max_rounds=4, eval_every=1)
    err = ei.value
    assert err.round == 1
    assert err.history[-1].round == 1
    assert np.isnan(err.history[-1].train_loss)
    assert isinstance(err.state, SimState)
    assert err.state.round == 0  # the pre-divergence snapshot, resumable
    for leaf in jax.tree.leaves(err.state.params_C):
        assert np.all(np.isfinite(np.asarray(leaf)))


def test_divergence_guard_off_returns_nan_history():
    targets = [np.full(16, np.nan)] * 4
    fm = FaultModel(max_update_norm=1e9, reject_nonfinite=False,
                    divergence_guard=False)
    res = _run(_quad_sim("scan", None, faults=fm, targets=targets),
               max_rounds=3)
    assert all(np.isnan(r.train_loss) for r in res.history)


# ---------------------------------------------------------------------------
# Zero participation
# ---------------------------------------------------------------------------


def test_zero_participation_rounds_leave_params_untouched():
    allout = scenarios.Scenario(
        "allout", "every client absent every round", dropout=1.0)
    results = {}
    for backend in ("scan", "batched", "loop"):
        res = _run(_quad_sim(backend, allout), max_rounds=3)
        results[backend] = res
        for r in res.history:
            assert np.isnan(r.train_loss)  # no participants, no loss
            assert r.n_participants == 0
            assert r.uplink_bits == 0.0
        # the clock still advances (server waits out the full population)
        assert res.total_time > 0.0
        np.testing.assert_array_equal(np.asarray(res.params["w"]),
                                      np.zeros(16, np.float32))
    _assert_bit_identical(results["scan"], results["batched"])
    assert results["loop"].total_time == pytest.approx(
        results["scan"].total_time)


# ---------------------------------------------------------------------------
# Checkpoint / resume under an active fault stream
# ---------------------------------------------------------------------------


def test_checkpoint_resume_mid_crash_epoch(tmp_path):
    fm = FaultModel(crash_rate=0.4, rejoin_rounds=3, max_retries=1)

    def mk():
        return _quad_sim("scan", "dropout", faults=fm)

    full = mk()
    _, ref = full.run(full.init(), max_rounds=6, eval_every=2)

    first = mk()
    state, r1 = first.run(first.init(), max_rounds=3, eval_every=2)
    assert state.stream is not None and "down" in state.stream
    path = str(tmp_path / "fault.ckpt")
    save_state(path, state)

    fresh = mk()
    loaded = load_state(path, like=fresh.init())
    _, r2 = fresh.run(loaded, max_rounds=3, eval_every=2)

    hist = list(r1.history) + list(r2.history)
    assert [r.round for r in hist] == [r.round for r in ref.history]
    for a, b in zip(hist, ref.history):
        np.testing.assert_array_equal(a.train_loss, b.train_loss)
        assert a.sim_time == b.sim_time
        assert a.n_participants == b.n_participants
        assert a.uplink_bits == b.uplink_bits
    for a, b in zip(jax.tree.leaves(r2.params), jax.tree.leaves(ref.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Deadline-aware planning (Alg. 1 under truncation)
# ---------------------------------------------------------------------------


def _plan_fixture():
    fed = FedConfig(n_devices=6, epsilon=0.01, nu=2.0, c=4.0)
    pop = scenarios.get("stragglers").population(6, seed=0)
    return fed, pop, 1e5


def test_deadline_plan_respects_deadline():
    fed, pop, bits = _plan_fixture()
    base = defl.make_plan(fed, pop, bits)
    D = 1.2 * base.T_round
    plan = defl.deadline_plan(fed, pop, bits, D)
    assert plan.T_round <= D * (1 + 1e-12)
    assert plan.b >= 1 and (plan.b & (plan.b - 1)) == 0  # power of two
    assert plan.V >= 1
    assert np.isfinite(plan.overall_pred) and plan.overall_pred > 0
    assert plan.overall_pred == pytest.approx(plan.H_pred * plan.T_round)


def test_deadline_plan_infeasible_raises():
    fed, pop, bits = _plan_fixture()
    with pytest.raises(ValueError, match="infeasible"):
        defl.deadline_plan(fed, pop, bits, 1e-12)


def test_plan_for_scenario_uses_deadline_plan():
    fed, _, bits = _plan_fixture()
    plan = defl.make_plan(fed, scenarios.get(
        "unreliable_edge").population(6, seed=0), bits)
    dplan = scenarios.plan_for_scenario(fed, "unreliable_edge", bits)
    fm = scenarios.get("unreliable_edge").faults
    D = fm.resolve_deadline(plan.T_round)
    assert dplan.T_round <= D * (1 + 1e-9)
    assert dplan.solution.method == "deadline_grid"


# ---------------------------------------------------------------------------
# Study integration (the _run_group fault path)
# ---------------------------------------------------------------------------


def test_study_smoke_on_unreliable_edge():
    from repro.federated.experiment import ExperimentSpec
    from repro.federated.study import Study

    def spec(label, lr):
        return ExperimentSpec(
            fed=FedConfig(n_devices=3, batch_size=4, theta=0.62, lr=lr),
            model="mnist_cnn_tiny", n_train=120, n_test=40,
            scenario="unreliable_edge", with_eval=False, label=label)

    study = Study(arms=[("a", spec("a", 0.05)), ("b", spec("b", 0.02))],
                  seeds=(0,), max_rounds=3, eval_every=3)
    res = study.run()
    for label in ("a", "b"):
        (r,) = res[label]
        assert len(r.history) == 3
        assert np.isfinite(r.total_time) and r.total_time > 0
        for rec in r.history:
            assert 0 <= rec.n_participants <= 3
    # same arm standalone == in-study (the fault path through _run_group)
    solo = spec("a", 0.05).build()
    _, ref = solo.run(solo.init(0), max_rounds=3, eval_every=3)
    (ra,) = res["a"]
    for a, b in zip(ra.history, ref.history):
        np.testing.assert_array_equal(a.train_loss, b.train_loss)
        assert a.sim_time == b.sim_time
        assert a.n_participants == b.n_participants
