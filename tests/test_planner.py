"""Online planner service (federated/planner.py): rolling device-state
ingest, ONE-dispatch batched plan queries bit-identical per lane to the
scalar path, and the trace-replanning driver whose report scores the
adaptive sequence against fixed plans on the realized rounds."""
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.core import delay
from repro.federated import planner, scenarios
from repro.federated.planner import (
    DeviceStateUpdate, EpochPlan, PlannerService, PlanQuery, ReplanReport,
    replan_trace,
)

FED = FedConfig(n_devices=16, epsilon=0.01, nu=2.0, c=4.0)
BITS = 8e5


def _service(m=16, seed=0, **kw):
    rng = np.random.default_rng(seed)
    svc = PlannerService(FED, BITS, **kw)
    svc.observe([DeviceStateUpdate(
        i, g=float(rng.uniform(1e-4, 2e-3)), p=0.2,
        h=float(rng.uniform(1e-9, 1e-8)), t=float(i)) for i in range(m)])
    return svc


# ---------------------------------------------------------------------------
# Telemetry ingest
# ---------------------------------------------------------------------------


def test_update_validation():
    with pytest.raises(ValueError, match="client_id"):
        DeviceStateUpdate(-1, g=1e-3, p=0.2, h=1e-9)
    for bad in (dict(g=0.0, p=0.2, h=1e-9), dict(g=1e-3, p=-1.0, h=1e-9),
                dict(g=1e-3, p=0.2, h=0.0)):
        with pytest.raises(ValueError, match="must be > 0"):
            DeviceStateUpdate(0, **bad)


def test_observe_latest_wins_and_snapshot_sorted():
    svc = PlannerService(FED, BITS)
    svc.observe(DeviceStateUpdate(3, g=2e-3, p=0.2, h=1e-9))
    svc.observe([DeviceStateUpdate(1, g=1e-3, p=0.2, h=2e-9),
                 DeviceStateUpdate(3, g=5e-3, p=0.3, h=3e-9)])  # overwrite
    assert svc.n_devices == 2
    pop = svc.population()
    # Sorted by client id; G carries the observed slope with f = 1.
    np.testing.assert_array_equal(pop.G, [1e-3, 5e-3])
    np.testing.assert_array_equal(pop.f, [1.0, 1.0])
    np.testing.assert_array_equal(pop.h, [2e-9, 3e-9])


def test_observe_population_encodes_slope():
    pop = delay.DevicePopulation(
        G=np.array([10.0, 20.0]), f=np.array([2.0, 4.0]),
        p=np.array([0.2, 0.2]), h=np.array([1e-9, 1e-9]))
    svc = PlannerService(FED, BITS)
    svc.observe_population(pop)
    snap = svc.population()
    # Only G/f is observable: the snapshot reproduces the slopes exactly.
    np.testing.assert_array_equal(snap.G / snap.f, pop.G / pop.f)


def test_staleness_eviction():
    svc = _service(m=4, stale_after=1.5)  # update i has timestamp t=i
    assert svc.population(now=3.0).n == 2  # t >= 3.0 - 1.5: ids 2 and 3
    assert svc.population().n == 4  # no `now` -> nothing evicted
    with pytest.raises(ValueError, match="no .fresh. device state"):
        svc.population(now=100.0)
    with pytest.raises(ValueError, match="observe"):
        PlannerService(FED, BITS).population()


def test_participation_ewma():
    svc = PlannerService(FED, BITS)
    assert svc.participation_estimate(default=0.7) == 0.7
    svc.observe_participation(0.8)
    assert svc.participation_estimate() == 0.8
    svc.observe_participation(0.4)
    assert svc.participation_estimate() == pytest.approx(0.6)  # beta = 0.5
    svc.observe_participation(2.0)  # clipped into [0, 1]
    assert svc.participation_estimate() <= 1.0


def test_observe_round_updates_channels():
    svc = _service(m=6)
    scen = scenarios.get("hetero_storm")
    pop = scen.population(6, seed=0)
    real = scen.stream(pop, seed=0).next_round()
    svc.observe_round(real, t=9.0)
    snap = svc.population()
    for i in np.flatnonzero(real.clock_mask):
        assert snap.h[i] == real.h[i]
    assert svc.participation_estimate() == pytest.approx(
        float(np.mean(real.clock_mask)), abs=1e-12)


# ---------------------------------------------------------------------------
# Batched solves: one dispatch, lane bit-identity
# ---------------------------------------------------------------------------


def test_plan_batch_empty():
    assert PlannerService(FED, BITS).plan_batch([]) == []


def test_plan_defaults_to_service_snapshot():
    svc = _service()
    p = svc.plan()
    assert p.b >= 1 and p.V >= 1 and np.isfinite(p.T_round)


def test_plan_batch_bit_identical_to_scalar_q256():
    """The ISSUE's serving contract: Q=256 heterogeneous queries (mixed
    participation, cohort sizes, and BOTH solver methods) answered by
    plan_batch are bit-identical per lane to 256 scalar plan() calls."""
    svc = _service(m=16)
    rng = np.random.default_rng(42)
    queries = [PlanQuery(
        participation=float(rng.uniform(0.3, 1.0)),
        cohort_size=int(rng.integers(4, 17)),
        method="numerical" if i % 4 == 0 else "closed_form",
        tag=f"q{i}")
        for i in range(256)]
    batched = svc.plan_batch(queries)
    assert len(batched) == 256
    for q, bp in zip(queries, batched):
        sp = svc.plan(q)
        assert (bp.b, bp.V) == (sp.b, sp.V)
        assert bp.theta == sp.theta
        assert bp.H_pred == sp.H_pred and bp.T_round == sp.T_round
        assert bp.overall_pred == sp.overall_pred
        assert bp.solution.alpha == sp.solution.alpha


def test_plan_batch_explicit_pop_skips_snapshot():
    """Queries that all carry explicit population snapshots plan without
    any service-side device state (the replanning driver's shape)."""
    pop = scenarios.get("uniform").population(8, seed=0)
    svc = PlannerService(FED, BITS)  # never observed anything
    plans = svc.plan_batch([PlanQuery(pop=pop, participation=0.9),
                            PlanQuery(pop=pop, participation=0.5)])
    assert len(plans) == 2
    # Lower expected participation shrinks Eq. 12's effective M.
    assert plans[1].problem.M < plans[0].problem.M


def test_query_overrides_route_through():
    svc = _service()
    loose = FedConfig(n_devices=16, epsilon=0.1, nu=2.0, c=4.0)
    a, b = svc.plan_batch([PlanQuery(), PlanQuery(fed=loose, update_bits=1e4)])
    assert b.problem.eps == pytest.approx(0.1)
    assert b.update_bits == pytest.approx(1e4)
    assert a.update_bits == pytest.approx(BITS)


# ---------------------------------------------------------------------------
# The replanning driver
# ---------------------------------------------------------------------------


def _quick_report(**kw):
    fed = FedConfig(n_devices=12, epsilon=0.1, nu=2.0, c=1.0)
    return replan_trace("diurnal_edge", fed, update_bits=1e5,
                        epochs=4, rounds_per_epoch=8, seed=0, **kw)


def test_replan_report_invariants():
    rep = _quick_report()
    assert isinstance(rep, ReplanReport)
    assert rep.scenario == "diurnal_edge"
    assert len(rep.plans) == 4
    assert [p.epoch for p in rep.plans] == [0, 1, 2, 3]
    for p in rep.plans:
        assert isinstance(p, EpochPlan)
        assert p.b >= 1 and p.V >= 1 and 0.0 < p.participation <= 1.0
    # The deliberately-bad corners are always scored as fixed candidates.
    assert "b1.V1" in rep.fixed_times and "b64.V16" in rep.fixed_times
    assert np.isfinite(rep.replanned_time)
    assert rep.oracle_time == min(rep.fixed_times.values())
    assert rep.worst_time == max(rep.fixed_times.values())
    assert rep.regret == rep.replanned_time - rep.oracle_time
    # The acceptance bar the demo/CI gate enforce.
    assert rep.beats_worst()
    tbl = rep.table()
    assert "oracle" in tbl and "worst" in tbl and "replanned" in tbl
    js = rep.to_json()
    assert js["beats_worst"] is True
    assert set(js["fixed_times"]) == set(rep.fixed_times)


def test_replan_is_deterministic():
    a, b = _quick_report(), _quick_report()
    assert a.replanned_time == b.replanned_time
    assert a.fixed_times == b.fixed_times
    assert [(p.b, p.V) for p in a.plans] == [(p.b, p.V) for p in b.plans]


def test_replan_single_batched_dispatch():
    """All E epoch solves route through exactly ONE plan_batch call (the
    trace is open-loop, so every query is known upfront)."""
    calls = []

    class Counting(PlannerService):
        def plan_batch(self, queries):
            calls.append(len(queries))
            return super().plan_batch(queries)

    fed = FedConfig(n_devices=12, epsilon=0.1, nu=2.0, c=1.0)
    svc = Counting(fed, 1e5)
    rep = replan_trace("diurnal_edge", fed, update_bits=1e5, epochs=4,
                       rounds_per_epoch=8, seed=0, service=svc)
    assert calls == [4]
    assert len(rep.plans) == 4


def test_replan_causality():
    """Epoch e's query carries only telemetry from before e: epoch 0 plans
    on the analytic prior, and a later epoch's participation estimate
    reflects the realized rounds (differs from the prior once the trace
    disagrees with it)."""
    rep = _quick_report()
    prior = scenarios.get("diurnal_edge").expected_participation
    assert rep.plans[0].participation == pytest.approx(prior)
    assert any(abs(p.participation - prior) > 1e-6 for p in rep.plans[1:])


def test_replan_explicit_target():
    rep = _quick_report(target=0.05)
    assert rep.target == pytest.approx(0.05)
    # An unreachable budget scores inf for everyone, replanned included.
    far = _quick_report(target=1e9)
    assert far.replanned_time == np.inf
    assert all(t == np.inf for t in far.fixed_times.values())


def test_walk_linear_credit():
    """_walk interpolates inside the crossing round: a target of 1.5
    rounds' progress costs 1.5 rounds' time under a constant-rate chunk."""
    fed = FedConfig(n_devices=2, epsilon=0.1, nu=2.0, c=1.0)
    pop = scenarios.get("uniform").population(2, seed=0)
    scen = scenarios.get("uniform")
    chunk = scen.stream(pop, seed=0).draw_chunk(4)
    _, per_round = planner._walk(fed, planner.WirelessConfig(), pop, 1e5,
                                 [chunk], [(2, 2)])
    t_full, _ = planner._walk(fed, planner.WirelessConfig(), pop, 1e5,
                              [chunk], [(2, 2)])
    rate = per_round / 4.0
    t_half = planner._walk(fed, planner.WirelessConfig(), pop, 1e5,
                           [chunk], [(2, 2)], target=1.5 * rate)
    assert t_half == pytest.approx(1.5 / 4.0 * t_full, rel=1e-9)
