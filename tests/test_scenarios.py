"""Scenario engine: registry, masked aggregation parity between backends,
straggler-max round clock, and the zero-participation edge case."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ComputeConfig, FedConfig, WirelessConfig
from repro.core import delay
from repro.federated import scenarios
from repro.federated.mesh_rounds import build_round_step, replicate_clients
from repro.federated.simulation import Simulator
from repro.optim import sgd


def _quad_loss(params, batch):
    diff = params["w"] - batch["target"]
    return 0.5 * jnp.sum(diff * diff), {}


class _TargetIterator:
    def __init__(self, target, batch_size):
        self.target = np.asarray(target, np.float32)
        self.batch_size = batch_size

    def next_batch(self):
        return {"target": np.tile(self.target, (self.batch_size, 1))}


def _quad_sim(backend, scenario, compress=True, momentum=0.9, seed=0):
    M, d, b = 4, 16, 2
    fed = FedConfig(n_devices=M, batch_size=b, lr=0.05, seed=seed,
                    compress_updates=compress)
    scen = scenarios.get(scenario) if scenario is not None else None
    pop = (scen.population(M, seed=seed) if scen is not None else
           delay.draw_population(M, ComputeConfig(), WirelessConfig(), 0, 0.0))
    iters = [_TargetIterator(np.linspace(0.0, m, d) * 0.1, b)
             for m in range(M)]
    return Simulator(
        _quad_loss, {"w": jnp.zeros(d)}, iters,
        np.array([10, 20, 30, 40]), fed, sgd(fed.lr, momentum), pop,
        backend=backend, scenario=scen)


def _run(sim, **kw):
    _, res = sim.run(sim.init(), **kw)
    return res


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def test_registry_names_and_lookup():
    names = scenarios.names()
    for required in ("uniform", "stragglers", "cell_edge", "dropout",
                     "drifting", "hetero_storm"):
        assert required in names
    s = scenarios.get("stragglers")
    assert scenarios.get(s) is s  # idempotent on Scenario instances
    with pytest.raises(KeyError):
        scenarios.get("no_such_scenario")
    with pytest.raises(ValueError):
        scenarios.register(scenarios.Scenario("uniform", "dup"))


def test_population_shapes_and_skew():
    M = 20
    pop = scenarios.get("stragglers").population(M, seed=0)
    assert pop.n == M and all(
        arr.shape == (M,) for arr in (pop.G, pop.f, pop.p, pop.h))
    # The straggler cohort (leading 20%) is materially slower than the rest.
    assert np.median(pop.f[:4]) < 0.5 * np.median(pop.f[4:])
    edge = scenarios.get("cell_edge").population(M, seed=0)
    assert np.median(edge.h[:6]) < 0.2 * np.median(edge.h[6:])
    uni = scenarios.get("uniform").population(M, seed=0)
    assert np.ptp(uni.f) == 0 and np.ptp(uni.h) == 0  # homogeneous


def test_stream_dropout_and_drift():
    scen = scenarios.get("hetero_storm")
    pop = scen.population(10, seed=1)
    stream = scen.stream(pop, seed=1)
    reals = [stream.next_round() for _ in range(40)]
    masks = np.stack([r.mask for r in reals])
    clocks = np.stack([r.clock_mask for r in reals])
    # mask (upload arrived) is always a subset of clock_mask (present).
    assert not np.any(masks & ~clocks)
    frac = masks.mean()
    assert abs(frac - scen.expected_participation) < 0.15
    # The drifting channel actually varies over rounds.
    hs = np.stack([r.h for r in reals])
    assert np.ptp(hs, axis=0).min() > 0
    # Same seed -> identical realizations (backend parity relies on this).
    stream2 = scen.stream(pop, seed=1)
    r2 = [stream2.next_round() for _ in range(40)]
    assert np.array_equal(masks, np.stack([r.mask for r in r2]))


@pytest.mark.parametrize("name", ["uniform", "dropout", "drifting",
                                  "hetero_storm"])
def test_draw_chunk_equals_sequential_draws(name):
    """draw_chunk(R) is bit-identical to R sequential next_round() calls —
    the scan backend's chunked stream is the same realization stream —
    including when the two call styles are interleaved mid-stream."""
    scen = scenarios.get(name)
    pop = scen.population(7, seed=2)
    seq = scen.stream(pop, seed=9)
    chk = scen.stream(pop, seed=9)
    R = 13
    reals = [seq.next_round() for _ in range(R)]
    chunk = chk.draw_chunk(R)
    assert len(chunk) == R
    np.testing.assert_array_equal(np.stack([r.mask for r in reals]),
                                  chunk.mask)
    np.testing.assert_array_equal(np.stack([r.clock_mask for r in reals]),
                                  chunk.clock_mask)
    np.testing.assert_array_equal(np.stack([r.h for r in reals]), chunk.h)
    np.testing.assert_array_equal(
        chunk.n_participants, [r.n_participants for r in reals])
    # Mixed consumption: next_round / draw_chunk(3) / next_round continues
    # the same stream as 5 more sequential draws.
    more = [seq.next_round() for _ in range(5)]
    mix = [chk.next_round().h, *chk.draw_chunk(3).h, chk.next_round().h]
    np.testing.assert_array_equal(np.stack([r.h for r in more]),
                                  np.stack(mix))
    # round(i) views slice the stacked realization consistently.
    r0 = chunk.round(0)
    np.testing.assert_array_equal(r0.mask, chunk.mask[0])


def test_plan_for_scenario_replans():
    fed = FedConfig(n_devices=10, epsilon=0.01, nu=2.0, c=4.0)
    bits = 1e6
    base = scenarios.plan_for_scenario(fed, "uniform", bits)
    slow = scenarios.plan_for_scenario(fed, "stragglers", bits)
    edge = scenarios.plan_for_scenario(fed, "cell_edge", bits)
    # Straggler cohort inflates the compute slope; cell edge inflates T_cm.
    assert slow.T_cp > base.T_cp
    assert edge.T_cm > base.T_cm
    assert slow.overall_pred > base.overall_pred
    # Partial participation shrinks effective M in the round-count model.
    drop = scenarios.plan_for_scenario(fed, "dropout", bits)
    assert drop.problem.M < base.problem.M


# ---------------------------------------------------------------------------
# Masked round step: backend parity
# ---------------------------------------------------------------------------


def _run_pair(scenario, rounds=6, **kw):
    out = {}
    for backend in ("loop", "batched"):
        out[backend] = _run(_quad_sim(backend, scenario, **kw),
                            max_rounds=rounds)
    return out


@pytest.mark.parametrize("scenario", ["dropout", "hetero_storm"])
def test_mask_parity_loop_vs_batched(scenario):
    """Masked batched round == loop backend skipping dropped clients, under
    the fixed seed's identical realization stream (params, losses, clock,
    and participant counts)."""
    out = _run_pair(scenario)
    rl, rb = out["loop"], out["batched"]
    for a, b in zip(jax.tree.leaves(rl.params), jax.tree.leaves(rb.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    np.testing.assert_allclose([r.train_loss for r in rl.history],
                               [r.train_loss for r in rb.history], atol=1e-5)
    np.testing.assert_allclose([r.sim_time for r in rl.history],
                               [r.sim_time for r in rb.history], rtol=1e-9)
    assert ([r.n_participants for r in rl.history]
            == [r.n_participants for r in rb.history])
    assert any(r.n_participants < 4 for r in rb.history)  # masking happened


def test_full_mask_bit_compatible_with_legacy_batched():
    """backend='batched' under the uniform scenario (full participation
    mask through the new masked path) is bit-identical to the legacy
    no-scenario batched path at the same seed."""
    ra = _run(_quad_sim("batched", None), max_rounds=5)
    rb = _run(_quad_sim("batched", "uniform"), max_rounds=5)
    for a, b in zip(jax.tree.leaves(ra.params), jax.tree.leaves(rb.params)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert ([r.train_loss for r in ra.history]
            == [r.train_loss for r in rb.history])


def test_scenario_run_single_trace():
    """Per-round masks / channel drift are traced values: one compile for
    the whole run (the donation/deferred-sync perf story is intact)."""
    sim = _quad_sim("batched", "hetero_storm")
    _run(sim, max_rounds=8)
    assert sim.trace_count == 1


def test_mesh_round_step_mask_drops_client():
    """A masked-out client influences neither the aggregate nor its own
    opt state, and weights renormalize over the participants."""
    C, d, V = 3, 4, 2
    params = {"w": jnp.zeros(d)}
    opt = sgd(0.1, momentum=0.9)
    targets = [0.0, 1.0, 10.0]
    batches = {"target": jnp.stack(
        [jnp.tile(jnp.full(d, t)[None], (V, 1)) for t in targets])}
    sizes = jnp.asarray([1.0, 1.0, 2.0])
    step = build_round_step(_quad_loss, opt, V)
    stacked = replicate_clients(params, C)
    opt_c = jax.vmap(lambda _: opt.init(params))(jnp.arange(C))
    mask = jnp.asarray([1.0, 1.0, 0.0])  # client 2 (target 10) dropped
    new_p, new_s, metrics = jax.jit(step)(
        stacked, opt_c, batches, sizes, mask=mask)
    # Aggregate is the equal-weight mean over clients 0 and 1 only:
    # far from 10, between 0 and 1.
    agg = np.asarray(new_p["w"][0])
    assert np.all(agg >= 0.0) and np.all(agg <= 1.0)
    assert metrics["n_participants"] == 2
    # Dropped client's momentum buffer stayed at init (zeros) while a
    # participating client with nonzero gradient (target 1.0) advanced.
    mom = jax.tree.leaves(new_s)
    assert any(np.all(np.asarray(m)[2] == 0.0) for m in mom if np.ndim(m) > 0)
    assert any(np.any(np.asarray(m)[1] != 0.0) for m in mom if np.ndim(m) > 0)


# ---------------------------------------------------------------------------
# Round clock (Eq. 8 over participating clients)
# ---------------------------------------------------------------------------


def test_masked_clock_property():
    """Host (delay.masked_round_times) and in-graph (mesh_rounds) clocks
    agree with the straggler max over participating clients, for random
    populations and masks."""
    rng = np.random.default_rng(0)
    step = build_round_step(_quad_loss, sgd(0.1), 1)
    for trial in range(25):
        M = int(rng.integers(2, 9))
        t_cp = rng.uniform(0.1, 5.0, M)
        t_cm = rng.uniform(0.1, 5.0, M)
        mask = rng.random(M) < 0.6
        T_cm, T_cp = delay.masked_round_times(t_cp, t_cm, mask)
        if mask.any():
            assert T_cm == t_cm[mask].max() and T_cp == t_cp[mask].max()
        else:
            assert T_cm == t_cm.max() and T_cp == t_cp.max()
        # in-graph twin
        params = replicate_clients({"w": jnp.zeros(2)}, M)
        batches = {"target": jnp.zeros((M, 1, 1, 2))}
        _, _, metrics = jax.jit(step)(
            params, (), batches, jnp.ones(M),
            mask=jnp.asarray(mask, jnp.float32),
            t_cp=jnp.asarray(t_cp, jnp.float32),
            t_cm=jnp.asarray(t_cm, jnp.float32))
        np.testing.assert_allclose(float(metrics["T_cm"]), T_cm, rtol=1e-6)
        np.testing.assert_allclose(float(metrics["T_cp"]), T_cp, rtol=1e-6)
        np.testing.assert_allclose(
            float(metrics["T_round"]), T_cm + 1 * T_cp, rtol=1e-6)


@pytest.mark.parametrize("backend", ["loop", "batched"])
def test_zero_participation_round(backend):
    """A round nobody attends: the wall clock still advances (server
    timeout at the full-population straggler max) and params are unchanged."""
    blackout = scenarios.get("dropout").replace(
        name="blackout_tmp", dropout=1.0, link_failure=0.0)
    sim = _quad_sim(backend, blackout)
    state = sim.init()
    before = jax.tree.map(np.asarray, sim.params(state))
    _, res = sim.run(state, max_rounds=3)
    assert all(r.n_participants == 0 for r in res.history)
    times = [r.sim_time for r in res.history]
    assert times[0] > 0 and all(b > a for a, b in zip(times, times[1:]))
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(res.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert all(np.isnan(r.train_loss) for r in res.history)


def test_simulation_clock_matches_manual_accounting():
    """RoundRecord sim_time accumulates Eq. 8 with the per-round realized
    channel and participation (independent recomputation)."""
    scen = scenarios.get("hetero_storm")
    sim = _quad_sim("batched", scen, seed=3)
    res = _run(sim, max_rounds=5)
    pop = sim.pop
    stream = scen.stream(pop, sim.fed.seed)
    bits = sim._update_bits()
    t_cp = delay.per_client_compute_time(sim.fed.batch_size, pop.G, pop.f)
    expect = 0.0
    for rec in res.history:
        real = stream.next_round()
        t_cm = delay.per_client_uplink_time(
            bits, sim.wireless, pop.p, real.h)
        T_cm, T_cp = delay.masked_round_times(t_cp, t_cm, real.clock_mask)
        expect += delay.round_time(T_cm, T_cp, sim.fed.local_rounds)
        np.testing.assert_allclose(rec.sim_time, expect, rtol=1e-12)
        assert rec.n_participants == real.n_participants
