"""Paper delay-model tests (Eqs. 3-8)."""
import numpy as np
import pytest

from repro.configs.base import ComputeConfig, WirelessConfig
from repro.core import delay


def test_gpu_frequency_eq3():
    cc = ComputeConfig(a_s=0.0, a_c=1.0, a_m=0.0, core_freq_hz=2e9)
    assert delay.gpu_frequency(cc) == pytest.approx(2e9)
    cc2 = ComputeConfig()
    f = delay.gpu_frequency(cc2)
    # Harmonic combination is below each individual bound.
    assert f <= cc2.core_freq_hz / cc2.a_c + 1e-9
    assert f > 0


def test_compute_time_eq4_linear_in_batch():
    t1 = delay.local_compute_time(1, 1e7, 2e9)
    t32 = delay.local_compute_time(32, 1e7, 2e9)
    assert t32 == pytest.approx(32 * t1)


def test_straggler_max_eq5():
    G = [1e7, 2e7, 1.5e7]
    f = [2e9, 2e9, 2e9]
    assert delay.round_compute_time(4, G, f) == pytest.approx(
        delay.local_compute_time(4, 2e7, 2e9))


def test_uplink_rate_eq6():
    wc = WirelessConfig()
    r = delay.uplink_rate(wc, 0.5, 1e-8)
    assert r > 0
    # Rate increases with power and gain, decreases with noise.
    assert delay.uplink_rate(wc, 1.0, 1e-8) > r
    assert delay.uplink_rate(wc, 0.5, 2e-8) > r
    t = delay.uplink_time(1e6, wc, 0.5, 1e-8)
    assert t == pytest.approx(1e6 / r)


def test_round_time_eq8():
    assert delay.round_time(0.5, 0.1, 5) == pytest.approx(1.0)
    assert delay.overall_time(10, 1.0) == pytest.approx(10.0)


def test_population_homogeneous_vs_heterogeneous():
    cc, wc = ComputeConfig(), WirelessConfig()
    hom = delay.draw_population(10, cc, wc, seed=0, heterogeneity=0.0)
    assert np.allclose(hom.f, hom.f[0]) and np.allclose(hom.G, hom.G[0])
    het = delay.draw_population(10, cc, wc, seed=0, heterogeneity=0.5)
    assert het.f.std() > 0
    # Straggler bound: heterogeneous max time >= homogeneous.
    assert delay.round_compute_time(8, het.G, het.f) >= \
        delay.round_compute_time(8, hom.G, hom.f) * 0.5


def test_chunk_round_times_matches_per_round():
    """Vectorized chunk clocks == per-round masked_round_times bit for bit,
    for random populations/masks including zero-participation rounds."""
    rng = np.random.default_rng(7)
    for _ in range(20):
        R, M = int(rng.integers(1, 9)), int(rng.integers(2, 7))
        t_cp = rng.uniform(0.1, 5.0, M)
        t_cm = rng.uniform(0.1, 5.0, (R, M))
        mask = rng.random((R, M)) < 0.5
        T_cm, T_cp = delay.chunk_round_times(t_cp, t_cm, mask)
        assert T_cm.shape == (R,) and T_cp.shape == (R,)
        for r in range(R):
            cm, cp = delay.masked_round_times(t_cp, t_cm[r], mask[r])
            assert T_cm[r] == cm and T_cp[r] == cp
