"""Per-architecture smoke tests: REDUCED variant (<=2 layers, d_model<=512,
<=4 experts), one forward + one train step on CPU, asserting output shapes
and absence of NaNs. (Assignment deliverable f.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config
from repro.models import transformer as tfm

B, S = 2, 32


def _batch(cfg, key, seq=S):
    if cfg.modality and cfg.modality.kind == "audio":
        toks = jax.random.randint(
            key, (B, seq, cfg.modality.n_codebooks), 0, cfg.vocab_size)
    else:
        toks = jax.random.randint(key, (B, seq), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.modality:
        batch["prefix_embeds"] = jax.random.normal(
            key, (B, cfg.modality.prefix_len, cfg.modality.embed_dim),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_no_nan(arch, key):
    cfg = get_config(arch, smoke=True)
    params = tfm.init_params(cfg, key)
    batch = _batch(cfg, key)
    logits, aux, P = tfm.forward(
        cfg, params, batch["tokens"], batch.get("prefix_embeds"))
    S_total = S + (cfg.modality.prefix_len if cfg.modality and
                   "prefix_embeds" in batch else 0)
    if cfg.modality and cfg.modality.kind == "audio":
        assert logits.shape == (B, S_total, cfg.modality.n_codebooks,
                                cfg.vocab_size)
    else:
        assert logits.shape == (B, S_total, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_no_nan(arch, key):
    cfg = get_config(arch, smoke=True)
    params = tfm.init_params(cfg, key)
    batch = _batch(cfg, key)

    def loss(p):
        return tfm.loss_fn(cfg, p, batch)[0]

    val, grads = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(val))
    # Loss at init should be near ln(vocab) (uniform predictions).
    assert abs(float(val) - np.log(cfg.vocab_size)) < 2.0
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves)
    # At least one nonzero gradient leaf.
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in leaves)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_shapes(arch, key):
    cfg = get_config(arch, smoke=True)
    params = tfm.init_params(cfg, key)
    cache = tfm.init_cache(cfg, B, max_len=S)
    if cfg.modality and cfg.modality.kind == "audio":
        tok = jax.random.randint(key, (B, 1, cfg.modality.n_codebooks),
                                 0, cfg.vocab_size)
    else:
        tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
    logits, cache2 = tfm.decode_step(cfg, params, cache, tok)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert int(cache2["pos"]) == 1
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)
