"""Trace-driven scenarios (federated/traces.py): device-class fleets with
battery/thermal/diurnal state machines, deterministic JSONL replay, and
their integration with the ScenarioStream wire format — draw_chunk parity,
mid-stream checkpoint/resume bit-identity, composition with FaultModel
retransmission and cohort sampling, and the ExperimentSpec trace field
riding the unchanged scan backend."""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.configs.base import FedConfig, WirelessConfig
from repro.federated import experiment, scenarios, traces
from repro.federated.faults import FaultModel
from repro.federated.traces import (
    IOT, PHONE, TABLET, DeviceClassSpec, ReplayScenario, TraceScenario,
    TraceSpec, record_trace, replay_scenario, write_trace,
)


# ---------------------------------------------------------------------------
# Device classes and the generative TraceScenario
# ---------------------------------------------------------------------------


def test_diurnal_edge_registered():
    assert "diurnal_edge" in scenarios.names()
    scen = scenarios.get("diurnal_edge")
    assert isinstance(scen, TraceScenario)
    assert scenarios.get(scen) is scen  # idempotent on instances
    assert 0.0 < scen.expected_participation < 1.0


def test_device_class_validation():
    with pytest.raises(ValueError, match="frac"):
        DeviceClassSpec("bad", frac=0.0)
    with pytest.raises(ValueError, match="compute_scale"):
        DeviceClassSpec("bad", frac=0.5, compute_scale=-1.0)
    with pytest.raises(ValueError, match="avail_base"):
        DeviceClassSpec("bad", frac=0.5, avail_base=1.5)
    with pytest.raises(ValueError, match="at least one"):
        TraceScenario("t", "empty", classes=())
    with pytest.raises(ValueError, match="battery_init"):
        TraceScenario("t", "bad", battery_init=(0.9, 0.2))


def test_class_index_largest_remainder():
    scen = scenarios.get("diurnal_edge")
    idx = scen.class_index(12)
    assert idx.shape == (12,)
    # 60/25/15 over 12 devices: 7.2/3.0/1.8 -> 7/3/2 by largest remainder.
    counts = np.bincount(idx, minlength=3)
    assert counts.tolist() == [7, 3, 2]
    assert np.all(np.diff(idx) >= 0)  # contiguous leading blocks
    # Tiny fleets: 1.8/0.75/0.45 floors to 1/0/0, the two largest
    # remainders take the leftovers -> 2 phones, 1 tablet, no IoT.
    assert np.bincount(scen.class_index(3), minlength=3).tolist() == [2, 1, 0]


def test_population_class_scaling():
    scen = scenarios.get("diurnal_edge")
    M = 40
    pop = scen.population(M, seed=0)
    idx = scen.class_index(M)
    assert pop.n == M
    # IoT gateways (compute_scale=4) are materially slower than phones and
    # their channel (channel_scale=0.3) materially worse.
    slope = pop.G / pop.f
    assert np.median(slope[idx == 2]) > 2.0 * np.median(slope[idx == 0])
    assert np.median(pop.h[idx == 2]) < 0.6 * np.median(pop.h[idx == 0])
    # Same seed -> same draw (the dedicated population RNG stream).
    pop2 = scen.population(M, seed=0)
    np.testing.assert_array_equal(pop.f, pop2.f)
    np.testing.assert_array_equal(pop.h, pop2.h)


def test_trace_stream_state_machines():
    scen = scenarios.get("diurnal_edge")
    pop = scen.population(24, seed=3)
    stream = scen.stream(pop, seed=3)
    chunk = stream.draw_chunk(96)  # two simulated days at 30-min rounds
    # Participation is partial and the diurnal wave moves it round to round.
    frac = chunk.clock_mask.mean(axis=1)
    assert 0.0 < frac.mean() < 1.0
    assert np.ptp(frac) > 0.2
    # Battery/thermal state stays in [0, 1] and rides the snapshot.
    s = stream.state()
    assert np.all((s["trace"]["battery"] >= 0) & (s["trace"]["battery"] <= 1))
    assert np.all((s["trace"]["thermal"] >= 0) & (s["trace"]["thermal"] <= 1))
    assert s["trace"]["tick"] == 96


def test_trace_overlay_only_gates_presence():
    """With the state machines made vacuous (always available, no battery/
    thermal gates), a TraceScenario realizes the SAME stream as a plain
    Scenario with identical base knobs at the same seed — the trace
    overlay must not consume the shared scenario RNG."""
    always_on = DeviceClassSpec(
        "on", frac=1.0, avail_base=1.0, battery_min=0.0, battery_drain=0.0,
        battery_idle_drain=0.0, heat_per_round=0.0)
    knobs = dict(dropout=0.2, link_failure=0.1, drift_sigma=0.15,
                 drift_rho=0.9)
    tscen = TraceScenario("t_on", "vacuous trace", classes=(always_on,),
                          **knobs)
    plain = scenarios.Scenario("plain", "same knobs", **knobs)
    pop = plain.population(9, seed=5)
    R = 30
    tc = tscen.stream(pop, seed=5).draw_chunk(R)
    pc = plain.stream(pop, seed=5).draw_chunk(R)
    np.testing.assert_array_equal(tc.mask, pc.mask)
    np.testing.assert_array_equal(tc.clock_mask, pc.clock_mask)
    np.testing.assert_array_equal(tc.h, pc.h)


# ---------------------------------------------------------------------------
# Wire-format parity and checkpoint/resume
# ---------------------------------------------------------------------------


def test_trace_draw_chunk_equals_sequential():
    """draw_chunk(R) == R next_round() calls bit for bit for the trace
    stream, including mixed consumption mid-stream — the scan backend's
    chunked reads are the same realization stream."""
    scen = scenarios.get("diurnal_edge")
    pop = scen.population(10, seed=2)
    seq = scen.stream(pop, seed=7)
    chk = scen.stream(pop, seed=7)
    R = 17
    reals = [seq.next_round() for _ in range(R)]
    chunk = chk.draw_chunk(R)
    np.testing.assert_array_equal(np.stack([r.mask for r in reals]),
                                  chunk.mask)
    np.testing.assert_array_equal(np.stack([r.clock_mask for r in reals]),
                                  chunk.clock_mask)
    np.testing.assert_array_equal(np.stack([r.h for r in reals]), chunk.h)
    # Interleaved: next_round / draw_chunk(4) / next_round continues the
    # same stream as 6 more sequential draws.
    more = [seq.next_round() for _ in range(6)]
    mix = [chk.next_round().clock_mask, *chk.draw_chunk(4).clock_mask,
           chk.next_round().clock_mask]
    np.testing.assert_array_equal(np.stack([r.clock_mask for r in more]),
                                  np.stack(mix))


def test_trace_stream_checkpoint_resume_bit_identical():
    """state()/set_state() mid-stream resumes the trace state machines
    (battery, thermal, tick, trace RNG) bit-identically on a FRESH
    stream object."""
    scen = scenarios.get("diurnal_edge")
    pop = scen.population(12, seed=1)
    full = scen.stream(pop, seed=4).draw_chunk(25)
    a = scen.stream(pop, seed=4)
    a.draw_chunk(11)
    snap = a.state()
    b = scen.stream(pop, seed=4)  # fresh object, then restore
    b.set_state(snap)
    tail = b.draw_chunk(14)
    np.testing.assert_array_equal(full.mask[11:], tail.mask)
    np.testing.assert_array_equal(full.clock_mask[11:], tail.clock_mask)
    np.testing.assert_array_equal(full.h[11:], tail.h)


def test_replay_stream_checkpoint_resume(tmp_path):
    path = os.path.join(tmp_path, "t.jsonl")
    spec = record_trace("hetero_storm", 8, 12, path, seed=0)
    scen = replay_scenario(spec)
    pop = scen.population(8, seed=0)
    full = scen.stream(pop, seed=0).draw_chunk(12)
    a = scen.stream(pop, seed=0)
    a.draw_chunk(5)
    b = scen.stream(pop, seed=0)
    b.set_state(a.state())
    tail = b.draw_chunk(7)
    np.testing.assert_array_equal(full.mask[5:], tail.mask)
    np.testing.assert_array_equal(full.h[5:], tail.h)


# ---------------------------------------------------------------------------
# JSONL record / replay
# ---------------------------------------------------------------------------


def test_record_replay_roundtrip(tmp_path):
    """record_trace over a lossy drifting scenario, replayed through a
    fresh ReplayScenario: masks bit-exact, realized channels recovered
    (scale round-trips through one divide/multiply)."""
    path = os.path.join(tmp_path, "storm.jsonl")
    M, R = 8, 15
    src = scenarios.get("hetero_storm")
    spec = record_trace(src, M, R, path, seed=2)
    src_pop = src.population(M, seed=2)
    src_chunk = src.stream(src_pop, seed=2).draw_chunk(R)

    scen = replay_scenario(spec)
    pop = scen.population(M, seed=2)
    chunk = scen.stream(pop, seed=2).draw_chunk(R)
    np.testing.assert_array_equal(src_chunk.mask, chunk.mask)
    np.testing.assert_array_equal(src_chunk.clock_mask, chunk.clock_mask)
    np.testing.assert_allclose(src_chunk.h, chunk.h, rtol=1e-12)
    # The meta population reproduces the recorded compute slopes exactly.
    np.testing.assert_allclose(pop.G / pop.f, src_pop.G / src_pop.f,
                               rtol=1e-12)
    # Empirical participation estimate matches the recorded stream.
    assert abs(scen.expected_participation - src_chunk.mask.mean()) < 1e-9


def test_replay_on_end_modes(tmp_path):
    path = os.path.join(tmp_path, "short.jsonl")
    recs = [{"present": [0, 1]}, {"present": [1]}, {"present": [2]}]
    write_trace(path, recs, meta={"devices": 3})
    pop = scenarios.get("uniform").population(3, seed=0)

    def masks(on_end, rounds):
        scen = replay_scenario(TraceSpec(path, on_end=on_end),
                               name=f"t_{on_end}")
        return scen.stream(pop, seed=0).draw_chunk(rounds).clock_mask

    cyc = masks("cycle", 5)
    np.testing.assert_array_equal(cyc[3], cyc[0])  # wrapped to round 0
    np.testing.assert_array_equal(cyc[4], cyc[1])
    hold = masks("hold", 5)
    np.testing.assert_array_equal(hold[3], hold[2])  # repeats the last
    np.testing.assert_array_equal(hold[4], hold[2])
    with pytest.raises(RuntimeError, match="exhausted"):
        masks("error", 4)


def test_trace_jsonl_validation(tmp_path):
    with pytest.raises(ValueError, match="on_end"):
        TraceSpec("x.jsonl", on_end="loop")
    with pytest.raises(ValueError, match="TraceSpec"):
        ReplayScenario("r", "no trace")
    path = os.path.join(tmp_path, "bad.jsonl")
    with open(path, "w") as f:
        f.write(json.dumps({"present": [0]}) + "\n")
        f.write(json.dumps({"meta": {"devices": 2}}) + "\n")
    with pytest.raises(ValueError, match="first line"):
        traces._load_trace(path)
    write_trace(path, [{"lost": [0]}])
    with pytest.raises(ValueError, match="present"):
        traces._load_trace(path)
    write_trace(path, [])
    with pytest.raises(ValueError, match="no round records"):
        traces._load_trace(path)
    # Out-of-range ids and malformed h_scale surface at replay time.
    write_trace(path, [{"present": [0, 9]}], meta={"devices": 4})
    pop = scenarios.get("uniform").population(4, seed=0)
    with pytest.raises(ValueError, match="out of range"):
        replay_scenario(TraceSpec(path), name="t_oor").stream(
            pop, seed=0).next_round()
    write_trace(path, [{"present": [0], "h_scale": [1.0, 1.0]}],
                meta={"devices": 4})
    with pytest.raises(ValueError, match="h_scale"):
        replay_scenario(TraceSpec(path), name="t_hs").stream(
            pop, seed=0).next_round()
    # Fleet-size mismatch names the trace.
    write_trace(path, [{"present": [0]}], meta={"devices": 7})
    with pytest.raises(ValueError, match="7 devices"):
        replay_scenario(TraceSpec(path), name="t_m").population(4, seed=0)


# ---------------------------------------------------------------------------
# Composition: faults and cohorts over traces
# ---------------------------------------------------------------------------


def test_replay_lost_final_under_retransmission(tmp_path):
    """Recorded losses are final: a FaultModel retransmission layer over a
    replayed trace never resurrects a recorded 'lost' upload (the log
    says the update did not arrive)."""
    path = os.path.join(tmp_path, "lossy.jsonl")
    recs = [{"present": [0, 1, 2, 3], "lost": [1, 2]} for _ in range(6)]
    write_trace(path, recs, meta={"devices": 4})
    scen = replay_scenario(TraceSpec(path), name="t_lossy",
                           faults=FaultModel(max_retries=3))
    pop = scen.population(4, seed=0)
    chunk = scen.stream(pop, seed=0).draw_chunk(6)
    assert not chunk.mask[:, 1].any() and not chunk.mask[:, 2].any()
    assert chunk.mask[:, 0].all() and chunk.mask[:, 3].all()
    # ...but the lost clients were present: the server waited for them.
    assert chunk.clock_mask.all()
    # and the retransmission machinery stayed live (attempts recorded).
    assert chunk.attempts is not None


def test_trace_composes_with_cohort_sampling():
    """Cohort sampling composes with the trace overlay: cohort draws ride
    their own RNG stream, so a cohort-configured trace stream realizes
    the SAME rounds as a dense one, and both the cohort RNG and the trace
    state machines survive one snapshot/restore."""
    scen = scenarios.get("diurnal_edge")
    pop = scen.population(20, seed=0)
    K = 6
    coh = scen.stream(pop, seed=0, cohort_size=K)
    dense = scen.stream(pop, seed=0)
    ids = coh.draw_cohorts(8)
    assert ids.shape == (8, K)
    for row in ids:
        assert np.all(np.diff(row) > 0)  # sorted, unique client ids
    cc = coh.draw_chunk(8)
    dc = dense.draw_chunk(8)
    np.testing.assert_array_equal(cc.mask, dc.mask)
    np.testing.assert_array_equal(cc.h, dc.h)
    # Snapshot carries cohort RNG and trace machines together.
    snap = coh.state()
    ahead_ids = coh.draw_cohorts(5)
    ahead = coh.draw_chunk(5)
    fresh = scen.stream(pop, seed=0, cohort_size=K)
    fresh.set_state(snap)
    np.testing.assert_array_equal(fresh.draw_cohorts(5), ahead_ids)
    np.testing.assert_array_equal(fresh.draw_chunk(5).clock_mask,
                                  ahead.clock_mask)


def test_trace_composes_with_faults():
    """A TraceScenario carrying an active FaultModel produces the fault
    wire format (attempts / per-attempt gains) while the state machines
    keep gating presence."""
    base = scenarios.get("diurnal_edge")
    scen = dataclasses.replace(
        base, name="diurnal_faulty",
        faults=FaultModel(max_retries=2, crash_rate=0.1, rejoin_rounds=3))
    assert isinstance(scen, TraceScenario)  # replace preserves the subclass
    pop = scen.population(12, seed=0)
    chunk = scen.stream(pop, seed=0).draw_chunk(20)
    assert chunk.attempts is not None and chunk.h_att is not None
    assert chunk.h_att.shape == (20, 12, 3)
    assert not np.any(chunk.mask & ~chunk.clock_mask)
    assert 0.0 < chunk.clock_mask.mean() < 1.0


# ---------------------------------------------------------------------------
# ExperimentSpec integration (the scan backend is unchanged)
# ---------------------------------------------------------------------------


def _trace_spec(tmp_path, n_devices=3, rounds=8):
    path = os.path.join(tmp_path, "exp.jsonl")
    tspec = record_trace("hetero_storm", n_devices, rounds, path, seed=1)
    return experiment.get("mnist_smoke").replace(
        with_eval=False, backend="scan", scenario=None, trace=tspec,
        fed=FedConfig(n_devices=n_devices, batch_size=8, theta=0.62,
                      lr=0.05, compress_updates=True))


def test_experiment_trace_scenario_mutually_exclusive(tmp_path):
    path = os.path.join(tmp_path, "x.jsonl")
    write_trace(path, [{"present": [0]}])
    with pytest.raises(ValueError) as ei:
        experiment.get("mnist_smoke").replace(
            trace=TraceSpec(path), scenario="dropout")
    msg = str(ei.value)
    assert "scenario" in msg and "trace" in msg  # names both fields


def test_experiment_trace_runs_on_scan_backend(tmp_path):
    """A trace-driven ExperimentSpec runs on the unchanged scan backend
    and its realized participation is exactly the recorded arrivals."""
    spec = _trace_spec(tmp_path)
    sim = spec.build()
    _, res = sim.run(sim.init(0), max_rounds=6, eval_every=3)
    ref = spec.scenario_ref()
    pop = ref.population(spec.n_devices(), seed=spec.fed.seed)
    chunk = ref.stream(pop, seed=spec.fed.seed).draw_chunk(6)
    assert ([r.n_participants for r in res.history]
            == chunk.n_participants.tolist())


def test_experiment_trace_checkpoint_resume_bit_identical(tmp_path):
    spec = _trace_spec(tmp_path)
    simF = spec.build()
    _, full = simF.run(simF.init(0), max_rounds=6, eval_every=2)
    simA = spec.build()
    mid, _ = simA.run(simA.init(0), max_rounds=3, eval_every=2)
    simB = spec.build()
    _, resumed = simB.run(mid, max_rounds=3, eval_every=2)
    for x, y in zip(full.history[3:], resumed.history):
        np.testing.assert_array_equal(x.train_loss, y.train_loss)
        assert x.sim_time == y.sim_time
        assert x.n_participants == y.n_participants
    import jax
    for a, b in zip(jax.tree.leaves(full.params),
                    jax.tree.leaves(resumed.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_mnist_diurnal_spec():
    spec = experiment.get("mnist_diurnal")
    assert spec.scenario == "diurnal_edge" and spec.plan
    scen = scenarios.get(spec.scenario_ref())
    assert isinstance(scen, TraceScenario)
    assert spec.n_devices() == 12


def test_presets_cover_fleet():
    fr = [c.frac for c in (PHONE, TABLET, IOT)]
    assert abs(sum(fr) - 1.0) < 1e-9
    wc = WirelessConfig()
    assert wc.mean_channel_gain > 0  # presets scale a positive baseline
