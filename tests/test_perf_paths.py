"""Perf-lever paths: blocked-causal attention and the explicit int8
shard_map sync (EXPERIMENTS.md §Perf)."""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.models import attention as attn


@pytest.mark.parametrize("window", [None, 96])
def test_blocked_causal_matches_dense(key, window):
    B, S, H, hd = 2, 300, 4, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    ref = attn._sdpa(q, k, v, attn._causal_mask(S, window))
    out = attn._blocked_causal_sdpa(q, k, v, window, block=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


def test_remat_flag_changes_nothing_numerically(key):
    from repro.configs.registry import get_config
    from repro.models import transformer as tfm

    cfg = get_config("qwen2-0.5b", smoke=True)
    params = tfm.init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab_size)}
    l1 = tfm.loss_fn(cfg, params, batch)[0]
    l2 = tfm.loss_fn(cfg.replace(remat=False), params, batch)[0]
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    g1 = jax.grad(lambda p: tfm.loss_fn(cfg, p, batch)[0])(params)
    g2 = jax.grad(lambda p: tfm.loss_fn(cfg.replace(remat=False), p,
                                        batch)[0])(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_int8_shardmap_sync_subprocess():
    """shard_map needs multiple devices -> run in a flagged subprocess."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.federated.mesh_rounds import build_round_step, replicate_clients
from repro.optim import sgd

mesh = jax.make_mesh((4, 2), ("data", "model"))
def loss(params, batch):
    diff = params["w"] - batch["target"]
    return 0.5 * jnp.sum(diff * diff), {}
C, V = 4, 3
stacked = replicate_clients({"w": jnp.ones(8, jnp.float32)}, C)
specs = {"w": P("data", None)}
batches = {"target": jnp.stack(
    [jnp.tile(jnp.full(8, float(t))[None], (V, 1)) for t in range(C)])}
weights = jnp.full((C,), 0.25)
ref = build_round_step(loss, sgd(0.05), V, "allreduce")
sm = build_round_step(loss, sgd(0.05), V, "int8_shardmap", mesh=mesh,
                      param_specs_tree=specs, client_axes=("data",))
with mesh:
    pr, _, _ = jax.jit(ref)(stacked, (), batches, weights)
    ps, _, _ = jax.jit(sm)(stacked, (), batches, weights)
    txt = jax.jit(sm).lower(stacked, (), batches, weights).compile().as_text()
err = float(jnp.max(jnp.abs(pr["w"] - ps["w"])))
assert err < 2.0 / 127 + 1e-6, err
assert any("all-gather" in l and "s8[" in l for l in txt.splitlines()), \\
    "int8 not on the wire"
print("OK")
"""
    out = subprocess.run(
        [sys.executable, "-c", code],
        env=dict(os.environ, PYTHONPATH=os.path.join(repo, "src")),
        capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK" in out.stdout
